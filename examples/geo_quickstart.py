"""Geo-distributed quickstart: shifting cluster load in space AND time.

Declares a 2-region geo scenario (capacity split across regions with
aligned CI traces) and sweeps the three geo policies over several seeds:

- ``geo-static``  — jobs pinned to their arrival region (status quo);
- ``geo-greedy``  — admission into the currently cleanest region;
- ``geo-flex``    — per-region CI-rank suspend/resume plus
  suspend-migrate-resume when the forecast gap between regions exceeds
  the migration carbon cost (checkpoint/restore slots + transfer energy).

  PYTHONPATH=src python examples/geo_quickstart.py
  PYTHONPATH=src python examples/geo_quickstart.py --tiny    # CI smoke run
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.experiment import DEFAULT_GEO_POLICIES, Scenario, Sweep


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--regions", nargs="+",
                    default=["south-australia", "california"])
    ap.add_argument("--capacity", type=int, default=40,
                    help="total capacity, split evenly across regions")
    ap.add_argument("--seeds", type=int, nargs="+", default=[1, 2, 3])
    ap.add_argument("--tiny", action="store_true",
                    help="minutes-not-hours smoke configuration for CI")
    args = ap.parse_args()

    if args.tiny:
        args.capacity, args.seeds = 10, [1]

    base = Scenario(regions=tuple(args.regions), capacity=args.capacity,
                    learn_weeks=1, family="azure", seed=args.seeds[0])
    mat = base.materialize()
    print(f"{len(mat.eval_jobs)} evaluation jobs over "
          f"{'+'.join(base.regions)} "
          f"(per-region capacity {mat.geo.capacities}), "
          f"migration cost: {mat.geo.migration.base_slots}+ slots, "
          f"{mat.geo.migration.energy_kwh_per_gb} kWh/GB\n")

    sweep = Sweep(base=base, seeds=args.seeds,
                  policies=list(DEFAULT_GEO_POLICIES))
    sr = sweep.run(progress=print)
    print()
    print(sr.table())

    flex = [r for r in sr.rows() if r["policy"] == "geo-flex"]
    migs = sum(r["migrations"] for r in flex)
    print(f"\ngeo-flex migrated {migs} jobs across "
          f"{len(flex)} runs; migration carbon "
          f"{sum(r['migration_carbon_g'] for r in flex) / 1e3:.2f} kg "
          f"is charged inside its savings above")


if __name__ == "__main__":
    main()
