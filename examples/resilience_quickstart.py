"""Resilience quickstart: how gracefully does each policy degrade when
the world breaks?

Three disturbance families (``core/faults.py``) hit the same scenario:

- ``correlated`` — Markov burst outages over failure domains (rack /
  zone slices): capacity disappears for a duration and the jobs placed
  there are evicted;
- ``preemption`` — per-job kills with checkpoint/restore: work since the
  last checkpoint is lost and the restore transfer is billed at the
  current CI;
- ``ci-outage``  — the carbon feed goes stale: policies forward-fill
  last-known-good values and fall back to persistence forecasts past the
  staleness threshold, while carbon accounting stays on the true trace.

The sweep prints per-policy savings under each regime plus the recovery
metrics (evictions, lost work, MTTR, degraded time) from
``SimResult.resilience``.

  PYTHONPATH=src python examples/resilience_quickstart.py
  PYTHONPATH=src python examples/resilience_quickstart.py --tiny  # CI smoke
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import CarbonDataOutage, CorrelatedFaults, PreemptionFaults
from repro.experiment import Scenario, Sweep


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--capacity", type=int, default=40)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--outage-rate", type=float, default=0.05,
                    help="per-slot failure probability of each domain")
    ap.add_argument("--preempt-rate", type=float, default=0.05,
                    help="per-slot kill probability of each running job")
    ap.add_argument("--tiny", action="store_true",
                    help="minutes-not-hours smoke configuration for CI")
    args = ap.parse_args()

    if args.tiny:
        args.capacity, args.seed = 8, 11

    base = Scenario(capacity=args.capacity, learn_weeks=1,
                    family="alibaba" if args.tiny else "azure",
                    seed=args.seed)
    policies = ("carbon-agnostic", "wait-awhile", "carbonflex")

    # 1) structured fault processes: clean vs correlated vs preemption
    faults = [None,
              CorrelatedFaults(n_domains=4, rate=args.outage_rate,
                               mean_duration=8.0, seed=args.seed),
              PreemptionFaults(rate=args.preempt_rate, checkpoint_every=4,
                               seed=args.seed)]
    res = Sweep(base=base, policies=policies, faults=faults).run(
        progress=None if args.tiny else print)
    print("\nsavings by fault regime (baseline: carbon-agnostic):")
    print(f"  {'policy':16s} {'fault':28s} {'savings%':>9s} "
          f"{'evict':>6s} {'preempt':>8s} {'lost-work':>10s} {'mttr':>5s}")
    for row in res.rows():
        r = row.get("resilience") or {}
        print(f"  {row['policy']:16s} {row['fault']:28s} "
              f"{row['savings_pct']:9.2f} {r.get('evictions', 0):6d} "
              f"{r.get('preemptions', 0):8d} "
              f"{r.get('lost_work_slots', 0.0):10.1f} "
              f"{r.get('mttr_slots', 0.0):5.1f}")

    # 2) carbon-feed outage: the policies go (partially) blind
    import dataclasses
    blind = dataclasses.replace(
        base, ci_outage=CarbonDataOutage(rate=0.05, mean_duration=6.0,
                                         stale_after=3, seed=args.seed))
    res2 = Sweep(base=blind, policies=policies).run()
    print("\nsavings with a flaky carbon feed (stale -> last-known-good + "
          "persistence):")
    for row in res2.rows():
        r = row.get("resilience") or {}
        print(f"  {row['policy']:16s} savings {row['savings_pct']:+7.2f}%  "
              f"degraded {r.get('degraded_slots', 0)} slots")
    print("\n(accounting always reads the true CI trace — only the "
          "policies' view goes stale)")


if __name__ == "__main__":
    main()
