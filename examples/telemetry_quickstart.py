"""Telemetry quickstart: trace every per-slot decision, attribute the
carbon savings to named causes, and profile where the wall-clock goes.

Attach a ``Telemetry`` bundle to any sweep and three observability
surfaces light up, none of which changes a single result float:

- **decision traces** — every engine emits the same per-slot event
  stream (admit / suspend / resume / scale / migrate / evict / preempt /
  checkpoint / restore / tier-switch / forecast-read) through the
  recorder, identical across scalar, vector and scan paths;
- **carbon attribution** — each policy's savings against its cell
  baseline decomposes into named causes (temporal shifting, capacity
  scaling, geo placement, migration overhead, precision tiering, fault
  restore) that sum float-exact to the measured delta;
- **phase profiling** — learn / provision / decide / execute wall-clock,
  ``block_until_ready``-bracketed so device work is charged to the phase
  that launched it.

  PYTHONPATH=src python examples/telemetry_quickstart.py
  PYTHONPATH=src python examples/telemetry_quickstart.py --tiny  # CI smoke
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.experiment import Scenario, Sweep
from repro.telemetry import MemoryRecorder, PhaseProfiler, Telemetry, explain


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--capacity", type=int, default=24)
    ap.add_argument("--weeks", type=int, default=2)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-not-minutes smoke configuration for CI")
    args = ap.parse_args()
    if args.tiny:
        args.capacity, args.weeks = 8, 1

    tel = Telemetry(recorder=MemoryRecorder(), profiler=PhaseProfiler())
    sweep = Sweep(
        base=Scenario(capacity=args.capacity, learn_weeks=args.weeks,
                      family="alibaba" if args.tiny else "google",
                      seed=args.seed),
        policies=["carbon-agnostic", "wait-awhile", "carbonflex"],
        telemetry=tel)
    res = sweep.run(progress=print)
    print()
    print(res.table())

    # -- carbon attribution: why did each policy save what it saved? ------
    print()
    for att in res.attributions():       # additivity checked inside
        print(att.table())
        print()

    # -- decision traces: what did carbonflex actually *do*? --------------
    row = next(r for r in res.rows() if r["policy"] == "carbonflex")
    label = f"{row['region']}/s{row['seed']}/{row['fault']}/carbonflex"
    counts = tel.recorder.counts(run=label)
    print(f"events[{label}]: "
          + ", ".join(f"{k}={n}" for k, n in counts.items()))

    # -- the whole story for one run, in one report -----------------------
    sims = dict(zip((r["policy"] for r in res.rows()), res.results))
    print()
    print(explain(sims["carbonflex"], baseline=sims["carbon-agnostic"],
                  recorder=tel.recorder, profiler=tel.profiler, run=label))


if __name__ == "__main__":
    main()
