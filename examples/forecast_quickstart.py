"""Forecast-uncertainty quickstart: how robust is each policy to
realistic carbon-forecast error?

The paper assumes accurate day-ahead CI forecasts; this example swaps the
forecast model (``core/forecast.py``) under every policy and measures the
savings-gap-to-oracle (the oracle reads the true trace, so it is
forecast-independent):

- ``perfect``      — the paper's assumption (the default everywhere);
- ``noisy(s)``     — seeded AR(1) multiplicative error whose std grows
  with lead time; re-querying a slot closer in time shrinks its error;
- ``quantile``     — a seeded ensemble exposing per-horizon quantile
  bands, which the ``*-robust`` policy variants threshold on.

  PYTHONPATH=src python examples/forecast_quickstart.py
  PYTHONPATH=src python examples/forecast_quickstart.py --tiny  # CI smoke
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import NoisyForecast, QuantileForecast
from repro.experiment import OracleGap, Scenario, sigma_ladder


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--capacity", type=int, default=40)
    ap.add_argument("--seeds", type=int, nargs="+", default=[1, 2, 3])
    ap.add_argument("--sigmas", type=float, nargs="+",
                    default=[0.0, 0.1, 0.2, 0.4])
    ap.add_argument("--tiny", action="store_true",
                    help="minutes-not-hours smoke configuration for CI")
    args = ap.parse_args()

    if args.tiny:
        args.capacity, args.seeds, args.sigmas = 8, [11], [0.0, 0.2]

    # the EXPERIMENTS.md §Forecast configuration (tiny shrinks it for CI)
    base = Scenario(capacity=args.capacity,
                    learn_weeks=1 if args.tiny else 2,
                    family="alibaba" if args.tiny else "azure",
                    seed=args.seeds[0] if args.tiny else 7)

    # Peek at the forecast models themselves before the policy study.
    mat = base.materialize()
    noisy = NoisyForecast(sigma=0.2)
    t = mat.t0
    truth = mat.ci.forecast(t, 24)
    seen = noisy.predict(mat.ci.trace, t, 24)
    rel = np.abs(seen / np.clip(truth, 1e-9, None) - 1.0)
    print(f"noisy(s=0.2) at t0: |rel err| lead-1h {rel[1]:.1%}, "
          f"lead-23h {rel[23]:.1%} "
          f"(analytic band: {noisy.lead_std(24)[1]:.1%} -> "
          f"{noisy.lead_std(24)[23]:.1%})")
    qf = QuantileForecast(sigma=0.2)
    q10 = qf.quantile(mat.ci.trace, t, 24, 0.1)
    q90 = qf.quantile(mat.ci.trace, t, 24, 0.9)
    print(f"quantile(s=0.2) at t0: q10-q90 band width grows "
          f"{q90[1] - q10[1]:.0f} -> {q90[23] - q10[23]:.0f} g/kWh "
          f"over the day\n")

    gap = OracleGap(base=base, seeds=args.seeds,
                    forecasts=sigma_ladder(args.sigmas))
    res = gap.run(progress=print)
    print()
    print(res.table())
    print()
    for pol in ("carbonflex", "carbonflex-robust"):
        curve = ", ".join(f"{fc}={g:+.2f}pp"
                          for fc, g in res.degradation_curve(pol))
        print(f"gap-to-oracle[{pol}]: {curve}")
    print("\n(the oracle reads the true trace; a flat curve = robust, "
          "a rising curve = savings lost to forecast error)")


if __name__ == "__main__":
    main()
