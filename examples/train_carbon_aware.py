"""End-to-end driver: carbon-aware elastic training of an LM.

CarbonFlex decides, hour by hour, how many data-parallel slices the
training job gets (scale up at low carbon intensity, pause at high); the
ElasticTrainer executes the plan with checkpoint/restart rescaling and
fault recovery — the full paper loop (provision -> schedule -> scancel ->
resume) on a real JAX model.

Defaults train a ~100M-parameter llama-style model; the CPU container is
far below one TPU slice, so ``--preset tiny`` (CI) and ``--steps`` exist
to bound wall time.  On real hardware run e.g.:

  python examples/train_carbon_aware.py --preset 100m --steps 300 --max-dp 8
"""
import argparse
import os
import sys

# elastic DP needs multiple host devices on CPU (example-local, NOT global)
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import CarbonService
from repro.elastic import ElasticTrainer, RescalePlan, make_compressor
from repro.models.common import ModelConfig
from repro.train import DataConfig, OptimizerConfig, SyntheticLM

PRESETS = {
    # ~100M params: 12 x 640 with 32k vocab ≈ 103M
    "100m": ModelConfig(name="lm-100m", family="dense", num_layers=12,
                        d_model=640, num_heads=10, num_kv_heads=10,
                        d_ff=1792, vocab_size=32000),
    "10m": ModelConfig(name="lm-10m", family="dense", num_layers=6,
                       d_model=256, num_heads=8, num_kv_heads=4,
                       d_ff=704, vocab_size=8192),
    "tiny": ModelConfig(name="lm-tiny", family="dense", num_layers=2,
                        d_model=64, num_heads=4, num_kv_heads=2,
                        d_ff=128, vocab_size=512),
}


def carbon_plan(ci: CarbonService, hours: int, steps_per_slot: int,
                max_dp: int) -> list[RescalePlan]:
    """CarbonFlex-style elastic plan: allocation tracks the day-ahead CI
    rank through the job's roofline-derived scaling profile."""
    plan = []
    for t in range(hours):
        rank = ci.rank(t)
        if rank < 0.25:
            k = 0                       # pause at high carbon
        else:
            # scale by rank through the marginal-throughput profile
            k = 1 + int(round((max_dp - 1) * max(rank - 0.25, 0) / 0.75))
        plan.append(RescalePlan(k=k, steps=steps_per_slot if k else 0))
    return plan


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--max-dp", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--region", default="south-australia")
    ap.add_argument("--ckpt", default="/tmp/carbonflex_train")
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--fault-at", type=int, default=None)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    from repro.models import param_count
    print(f"model {cfg.name}: {param_count(cfg) / 1e6:.1f}M params")

    ci = CarbonService.synthetic(args.region, 24 * 7, seed=3)
    hours = 12
    steps_per_slot = max(args.steps // hours, 1)
    plan = carbon_plan(ci, hours, steps_per_slot, args.max_dp)
    print("elastic plan (k per hour):", [p.k for p in plan])

    data = SyntheticLM(DataConfig(batch=args.batch, seq_len=args.seq,
                                  vocab_size=cfg.vocab_size, seed=0))
    trainer = ElasticTrainer(
        cfg, data, OptimizerConfig(lr=1e-3, warmup_steps=10,
                                   total_steps=args.steps),
        args.ckpt,
        compression=make_compressor("int8") if args.compress else None)
    out = trainer.run(plan, checkpoint_every=max(steps_per_slot, 2),
                      fault_at=args.fault_at)

    losses = out["losses"]
    print(f"\nsteps {out['final_step']}  rescales {out['rescales']}  "
          f"recoveries {out['recoveries']}  stragglers {out['stragglers']}")
    print(f"loss: first {losses[0]:.3f} -> last {losses[-1]:.3f} "
          f"(improved: {losses[-1] < losses[0]})")
    assert np.isfinite(losses).all()


if __name__ == "__main__":
    main()
