"""End-to-end serving driver: batched decode with a KV cache.

Serves a small dense LM: a prefill pass builds the sequence-sharded KV
cache for a batch of prompts, then batched decode steps generate new
tokens — the ``serve_step`` lowered by the decode_* dry-run cells, run for
real at CPU scale.

  PYTHONPATH=src python examples/serve_elastic.py --tokens 32
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.launch.mesh import make_mesh
from repro.models import LogicalRules, forward, init_params
from repro.serve import make_prefill, make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = LogicalRules(mesh)
    params = init_params(cfg, jax.random.key(0))
    max_seq = args.prompt_len + args.tokens

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    step = jax.jit(make_serve_step(cfg, rules))
    prefill = jax.jit(make_prefill(cfg, rules, max_seq))

    # prefill: one forward pass builds the KV cache for the whole prompt
    t0 = time.time()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    # batched decode: greedy sampling
    generated = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.tokens):
        generated.append(tok)
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    gen = np.stack([np.asarray(g) for g in generated], axis=1)
    print(f"arch {cfg.name} batch {args.batch} prompt {args.prompt_len} "
          f"-> {args.tokens} new tokens")
    print(f"prefill {t_prefill:.2f}s  decode {t_decode:.2f}s "
          f"({args.tokens * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    print("first sequence:", gen[0][:16], "...")
    assert gen.shape == (args.batch, args.tokens)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()


if __name__ == "__main__":
    main()
