"""Quickstart: CarbonFlex end-to-end on a synthetic cluster.

Declares the experiment as a ``Scenario`` (3 weeks of history feeding the
continuous-learning loop, 1 evaluation week) and lets the driver do the
rest: oracle replay into the knowledge base, policy construction through
the registry, batched evaluation against the carbon-agnostic status quo
and the offline-optimal oracle.

  PYTHONPATH=src python examples/quickstart.py
  PYTHONPATH=src python examples/quickstart.py --tiny     # CI smoke run
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.experiment import Scenario, run


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--region", default="south-australia")
    ap.add_argument("--capacity", type=int, default=40)
    ap.add_argument("--learn-weeks", type=int, default=3)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--tiny", action="store_true",
                    help="minutes-not-hours smoke configuration for CI")
    args = ap.parse_args()

    if args.tiny:
        args.capacity, args.learn_weeks = 10, 1

    scenario = Scenario(region=args.region, capacity=args.capacity,
                        learn_weeks=args.learn_weeks, seed=args.seed)
    world = scenario.materialize()
    print(f"{len(world.hist)} historical jobs, {len(world.eval_jobs)} "
          f"evaluation jobs, M={world.cluster.capacity}")

    result = run(scenario, ["carbon-agnostic", "wait-awhile", "carbonflex",
                            "carbonflex-mpc", "oracle"])
    print(f"knowledge base: {result.kb_size} (STATE -> m, rho) cases\n")
    print(result.table())


if __name__ == "__main__":
    main()
