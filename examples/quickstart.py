"""Quickstart: CarbonFlex end-to-end on a synthetic cluster.

Learns provisioning/scheduling from 3 weeks of history (continuous
learning over the offline oracle), then manages a 1-week evaluation
window, comparing against the carbon-agnostic status quo and the oracle.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (CarbonFlexPolicy, CarbonService, ClusterConfig,
                        KnowledgeBase, OraclePolicy, baselines, learn_window,
                        simulate)
from repro.core.policy import CarbonFlexMPCPolicy
from repro.traces import TraceSpec, generate_trace

WEEK = 24 * 7


def main() -> None:
    cluster = ClusterConfig.default(capacity=40)
    ci = CarbonService.synthetic("south-australia", WEEK * 5, seed=1)
    spec = TraceSpec(family="azure", hours=WEEK * 4, capacity=40, seed=2)
    jobs = generate_trace(spec, cluster.queues)
    hist = [j for j in jobs if j.arrival < WEEK * 3]
    ev = [j for j in jobs if WEEK * 3 <= j.arrival < WEEK * 4]
    print(f"{len(hist)} historical jobs, {len(ev)} evaluation jobs, "
          f"M={cluster.capacity}")

    # --- learning phase: replay history through the offline oracle --------
    kb = KnowledgeBase()
    learn_window(kb, hist, ci, 0, WEEK, cluster.capacity,
                 len(cluster.queues), offsets=(0, WEEK, 2 * WEEK))
    print(f"knowledge base: {len(kb)} (STATE -> m, rho) cases")

    # --- execution phase ---------------------------------------------------
    mpc = CarbonFlexMPCPolicy()
    mpc.warm_start(hist)
    policies = [
        baselines.CarbonAgnosticPolicy(),
        baselines.WaitAwhilePolicy(),
        CarbonFlexPolicy(kb),
        mpc,
        OraclePolicy(),
    ]
    results = {}
    for pol in policies:
        results[pol.name] = simulate(ev, ci, cluster, pol,
                                     t0=WEEK * 3, horizon=WEEK)
    base = results["carbon-agnostic"]
    print(f"\n{'policy':18s} {'carbon kg':>10s} {'savings':>8s} "
          f"{'wait h':>7s} {'viol':>6s}")
    for name, r in results.items():
        print(f"{name:18s} {r.carbon_g / 1e3:10.1f} "
              f"{r.savings_vs(base):7.1f}% {r.mean_wait:7.1f} "
              f"{r.violation_rate:6.3f}")


if __name__ == "__main__":
    main()
