"""DAG quickstart: carbon-aware scheduling of precedence-constrained jobs.

Declares a DAG scenario (every job is a pipeline of tasks — chains,
map-reduce stages, random layered DAGs — with per-task elasticity
profiles; the engines gate each task until its predecessors complete) and
sweeps the three precedence-aware policies:

- ``dag-fcfs``   — precedence-only baseline: FCFS over ready tasks;
- ``dag-carbon`` — CarbonFlex-style CI-rank suspend/resume applied per
  ready task (the per-job carbon scheduler on DAG structure);
- ``dag-cap``    — PCAPS-style criticality: critical-path tasks exempt
  from suspension, slack tasks deferred into clean windows.

It then runs the *independent-task twin* (same tasks, edges stripped) to
show what a per-job scheduler would report without precedence, and
compares per-pipeline completion stretch.

  PYTHONPATH=src python examples/dag_quickstart.py
  PYTHONPATH=src python examples/dag_quickstart.py --tiny    # CI smoke run
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.experiment import DEFAULT_DAG_POLICIES, Scenario, Sweep
from repro.traces import DagConfig


def pipeline_stretch(result, jobs) -> float:
    """Mean per-DAG completion stretch: (last task completion - arrival) /
    critical-path work, over the DAGs whose tasks all finished.  The
    critical path is recomputed from ``Job.deps`` (longest work chain),
    so a back-to-back pipeline scores ~1.0x and anything above it is
    queueing/suspension delay."""
    rows = sorted(jobs, key=lambda j: (j.arrival, j.job_id))
    by_dag: dict[str, list[int]] = {}
    for i, j in enumerate(rows):
        by_dag.setdefault(j.arch.split("/")[0], []).append(i)
    stretches = []
    for members in by_dag.values():
        comp = result.completion[members]
        if (comp < 0).any():
            continue
        arrival = min(rows[i].arrival for i in members)
        span = max(1.0, float(comp.max() - arrival + 1))
        head: dict[int, float] = {}
        for i in members:                # members are job_id-ordered: topo
            j = rows[i]
            head[j.job_id] = j.length + max(
                (head[d] for d in j.deps if d in head), default=0.0)
        stretches.append(span / max(1.0, max(head.values())))
    return float(np.mean(stretches)) if stretches else 0.0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--capacity", type=int, default=40)
    ap.add_argument("--seeds", type=int, nargs="+", default=[1, 2, 3])
    ap.add_argument("--width", type=int, default=4)
    ap.add_argument("--depth", type=int, default=3)
    ap.add_argument("--tiny", action="store_true",
                    help="minutes-not-hours smoke configuration for CI")
    args = ap.parse_args()

    if args.tiny:
        args.capacity, args.seeds = 12, [1]

    dag = DagConfig(width=args.width, depth=args.depth)
    base = Scenario(dag=dag, capacity=args.capacity, learn_weeks=1,
                    seed=args.seeds[0])
    mat = base.materialize()
    n_dags = len({j.arch.split("/")[0] for j in mat.eval_jobs})
    print(f"{len(mat.eval_jobs)} evaluation tasks in {n_dags} DAGs "
          f"(shapes {'/'.join(dag.shapes)}, width<={dag.width}, "
          f"depth<={dag.depth}), capacity {args.capacity}\n")

    sweep = Sweep(base=base, seeds=args.seeds,
                  policies=list(DEFAULT_DAG_POLICIES))
    sr = sweep.run(progress=print)
    print()
    print(sr.table())

    # The independent-task twin: identical tasks, precedence stripped —
    # what a per-job carbon scheduler would report on this workload.
    indep = Sweep(base=Scenario(dag=DagConfig(
                      width=args.width, depth=args.depth, independent=True),
                      capacity=args.capacity, learn_weeks=1,
                      seed=args.seeds[0]),
                  seeds=args.seeds, policies=["dag-fcfs", "dag-carbon"])
    si = indep.run()
    pick = lambda rows: next(r for r in rows if r["policy"] == "dag-carbon"  # noqa: E731
                             and r["seed"] == args.seeds[0])
    print(f"\ndag-carbon savings, seed {args.seeds[0]}: "
          f"{pick(sr.rows())['savings_pct']:.1f}% with precedence gating vs "
          f"{pick(si.rows())['savings_pct']:.1f}% on the independent-task "
          f"twin")

    # Per-pipeline stretch: what the savings cost in end-to-end latency.
    from repro.core import simulate
    from repro.experiment import make_policy, prepare_context

    ctx = prepare_context(mat, DEFAULT_DAG_POLICIES)
    print("\nper-pipeline completion stretch (makespan / critical work):")
    for name in DEFAULT_DAG_POLICIES:
        res = simulate(mat.eval_jobs, mat.ci, mat.cluster,
                       make_policy(name, ctx), t0=mat.t0, horizon=24 * 7)
        print(f"  {name:12s} {pipeline_stretch(res, mat.eval_jobs):5.2f}x")


if __name__ == "__main__":
    main()
