"""Serving-tier quickstart: trade a bounded slice of answer quality for
carbon by routing requests across precision tiers.

A ``Scenario(serving=ServingConfig())`` carries an interactive request
stream (diurnal x weekly seasonality, Poisson arrivals, burst spikes)
instead of batch jobs.  Every slot, a serve policy splits the request
mix across precision tiers (fp32 / bf16 / int8 — energy and quality
derived from the decode cost model and measured quantization error), a
credit ledger keeps time-averaged quality on target, and an SLO model
charges latency violations when utilization passes the knee:

- ``serve-static`` — everything on fp32 (the status quo; eats the SLO
  violations that the cheaper tiers' capacity headroom would absorb);
- ``serve-greedy`` — degrade above the p70 carbon intensity of the
  day-ahead forecast, repay below p30, ledger-bounded;
- ``serve-flex``   — forecast-aware: CI trend + demand look-ahead +
  quantile forecast + an emissions budget, weighted and ledger-scaled.

  PYTHONPATH=src python examples/serving_quickstart.py
  PYTHONPATH=src python examples/serving_quickstart.py --tiny  # CI smoke
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.experiment import Scenario, ServingConfig, run
from repro.serving import derive_tiers


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests-per-day", type=float, default=1.5e6)
    ap.add_argument("--servers", type=int, default=48)
    ap.add_argument("--weeks", type=int, default=2)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--quality-target", type=float, default=0.98)
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-not-minutes smoke configuration for CI")
    args = ap.parse_args()

    if args.tiny:
        args.requests_per_day, args.servers, args.weeks = 2e5, 12, 1

    cfg = ServingConfig(requests_per_day=args.requests_per_day,
                        servers=args.servers,
                        quality_target=args.quality_target)

    # The tier table is derived, not asserted: energy scales with bytes
    # moved (decode is memory-bandwidth-bound), quality with measured
    # quantization RMS error (elastic/compression.py).
    print("tier        bytes  kWh/kreq  quality   req/server-slot")
    for t in derive_tiers(quality_kappa=cfg.quality_kappa):
        print(f"{t.name:10s} {t.bytes_per_value:5.0f} {t.energy_kwh_per_kreq:9.2f} "
              f"{t.quality:8.4f} {t.capacity_per_server:15.0f}")
    print()

    sc = Scenario(serving=cfg, learn_weeks=1, eval_weeks=args.weeks,
                  seed=args.seed)
    res = run(sc, progress=print)
    print()
    print(res.table())
    print()
    for pol in res.policies:
        w = res.weekly[pol]
        bal = np.concatenate([r.serving.balance for r in w])
        print(f"{pol:14s} quality={res.quality_mean(pol):.4f}  "
              f"ledger [{bal.min():+.3f}, {bal.max():+.3f}] "
              f"final {w[-1].serving.ledger_final:+.3f}")
    print("\n(a negative ledger = quality debt being spent in dirty hours; "
          "the bound [-1, +1] caps how far any policy can drift from the "
          "quality target)")


if __name__ == "__main__":
    main()
