"""Year-long CarbonFlex-Simulator run (paper §5 'Simulation Environment').

Simulates 52 weeks of cluster operation with weekly continuous re-learning
(the rolling knowledge-base window of §4.2), reporting cumulative carbon
per policy.  Scale knobs keep the default run to a few minutes; raise
--weeks / --capacity for the paper's full scale.

  PYTHONPATH=src python examples/cluster_sim_year.py --weeks 8
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (CarbonFlexPolicy, CarbonService, ClusterConfig,
                        KnowledgeBase, baselines, learn_window, simulate)
from repro.core.policy import CarbonFlexMPCPolicy
from repro.traces import TraceSpec, generate_trace

WEEK = 24 * 7


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--weeks", type=int, default=6)
    ap.add_argument("--capacity", type=int, default=30)
    ap.add_argument("--region", default="california")
    ap.add_argument("--seed", type=int, default=5)
    args = ap.parse_args()

    cluster = ClusterConfig.default(capacity=args.capacity)
    hours = WEEK * (args.weeks + 2)
    ci = CarbonService.synthetic(args.region, hours + 24 * 30, seed=args.seed)
    spec = TraceSpec(family="azure", hours=hours, capacity=args.capacity,
                     seed=args.seed + 1)
    jobs = generate_trace(spec, cluster.queues)

    kb = KnowledgeBase(max_windows=4)        # rolling aging window
    totals = {"carbon-agnostic": 0.0, "wait-awhile": 0.0,
              "carbonflex": 0.0, "carbonflex-mpc": 0.0}
    waits = {k: [] for k in totals}
    mpc = CarbonFlexMPCPolicy()

    for week in range(1, args.weeks + 1):
        # continuous learning: replay last week through the oracle
        hist = [j for j in jobs if (week - 1) * WEEK <= j.arrival < week * WEEK]
        learn_window(kb, hist, ci, 0, WEEK, cluster.capacity,
                     len(cluster.queues), offsets=((week - 1) * WEEK,),
                     backend="numpy")
        mpc.warm_start(hist)

        ev = [j for j in jobs if week * WEEK <= j.arrival < (week + 1) * WEEK]
        if not ev:
            continue
        for name, pol in [
            ("carbon-agnostic", baselines.CarbonAgnosticPolicy()),
            ("wait-awhile", baselines.WaitAwhilePolicy()),
            ("carbonflex", CarbonFlexPolicy(kb)),
            ("carbonflex-mpc", mpc),
        ]:
            r = simulate(ev, ci, cluster, pol, t0=week * WEEK, horizon=WEEK)
            totals[name] += r.carbon_g
            waits[name].append(r.mean_wait)
        print(f"week {week}: kb={len(kb)} cases; cumulative savings "
              f"flex={100 * (1 - totals['carbonflex'] / totals['carbon-agnostic']):.1f}% "
              f"mpc={100 * (1 - totals['carbonflex-mpc'] / totals['carbon-agnostic']):.1f}%")

    base = totals["carbon-agnostic"]
    print(f"\n{'policy':18s} {'carbon kg':>10s} {'savings':>8s} {'wait h':>7s}")
    for name, tot in totals.items():
        print(f"{name:18s} {tot / 1e3:10.1f} {100 * (1 - tot / base):7.1f}% "
              f"{np.mean(waits[name]):7.1f}")


if __name__ == "__main__":
    main()
