"""Year-long CarbonFlex-Simulator run (paper §5 'Simulation Environment').

Simulates many weeks of cluster operation with weekly continuous
re-learning (the rolling knowledge-base window of §4.2): the experiment
driver replays each evaluated week through the offline oracle before the
next, ages old windows out of the knowledge base (``max_windows``), and
keeps the MPC policy's length histories warm.  Scale knobs keep the
default run to a few minutes; raise --weeks / --capacity for the paper's
full scale.

  PYTHONPATH=src python examples/cluster_sim_year.py --weeks 8
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.experiment import Scenario, run


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--weeks", type=int, default=6)
    ap.add_argument("--capacity", type=int, default=30)
    ap.add_argument("--region", default="california")
    ap.add_argument("--seed", type=int, default=5)
    args = ap.parse_args()

    scenario = Scenario(region=args.region, capacity=args.capacity,
                        seed=args.seed, learn_weeks=1, eval_weeks=args.weeks)
    result = run(scenario,
                 ["carbon-agnostic", "wait-awhile", "carbonflex",
                  "carbonflex-mpc"],
                 kb_kwargs=dict(max_windows=4),      # rolling aging window
                 progress=print)
    print()
    print(result.table())


if __name__ == "__main__":
    main()
