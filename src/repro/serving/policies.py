"""Serving policies: per-slot precision-tier splits under a quality ledger.

The serve family is the interactive-traffic counterpart of the batch
policy registry: a policy sees the slot's demand, the (possibly degraded)
carbon view, the demand-rate forecast, and the current ledger balance, and
returns the fraction of the slot's requests routed to each precision tier.

- ``serve-static`` — everything on the full-precision tier, always: the
  status-quo baseline every savings number is measured against.
- ``serve-greedy`` — current-CI threshold (Wait-Awhile in quality space):
  degrade toward the cheap tier when CI sits above the 70th percentile of
  the day-ahead forecast, repay with full quality below the 30th,
  ledger-bounded both ways.
- ``serve-flex`` — the forecast-aware-global exemplar (SNIPPETS.md §2):
  a multi-factor weighted adjustment combining the short-term CI trend,
  the demand forecast, an extended look-ahead read through PR 5's
  :class:`~repro.core.forecast.QuantileCIView`, and a cumulative-emissions
  budget, scaled by the ledger headroom.

Policies are deterministic functions of their inputs — the engine's
vector/scalar parity rests on calling the *same* policy code from both
paths, so nothing here may read a clock or an unseeded RNG.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.forecast import QuantileCIView

from .tiers import PrecisionTier, ServingConfig, SloModel, mix_for_quality


@dataclasses.dataclass
class ServeWindow:
    """Everything a serving policy may read during one simulated window,
    handed to ``on_window_start`` by the engine.

    ``ci`` is the *policy-visible* carbon view (``CarbonService.degraded()``
    — forward-filled during feed outages); the engine keeps accounting on
    the true trace.  ``rate`` is the full-span expected request-rate curve
    (``traces.requests.expected_request_rate``) — the demand *forecast*,
    not the realized demand, so policies face genuine error at bursts."""

    config: ServingConfig
    tiers: tuple[PrecisionTier, ...]
    q_vec: np.ndarray                # per-tier quality, descending
    e_vec: np.ndarray                # per-tier kWh per 1000 requests
    inv_cap: np.ndarray              # per-tier 1 / (requests per server-slot)
    slo: SloModel
    ci: object                       # CarbonService / DegradedCIView
    rate: np.ndarray                 # expected requests/slot, absolute index
    t0: int                          # first slot of the window
    servers: int


def relieve_capacity(frac: np.ndarray, demand: float,
                     w: ServeWindow) -> np.ndarray:
    """Shift routed mass toward the highest-capacity (cheapest) tier until
    projected utilization drops to the SLO knee, or everything movable has
    moved.  Deterministic greedy from the most expensive tier down — the
    overload response of the adaptive policies (``serve-static``
    deliberately does not call this: eating the violations is what the
    status quo does)."""
    scale = demand / w.servers
    util = float(np.sum(frac * w.inv_cap)) * scale
    knee = w.slo.knee
    if util <= knee or scale <= 0.0:
        return frac
    frac = frac.copy()
    last = len(frac) - 1
    for i in range(last):
        if util <= knee:
            break
        gain = (w.inv_cap[i] - w.inv_cap[last]) * scale
        if gain <= 0.0 or frac[i] <= 0.0:
            continue
        move = min(frac[i], (util - knee) / gain)
        frac[i] -= move
        frac[last] += move
        util -= move * gain
    return frac


class ServeStaticPolicy:
    """All requests on the full-precision tier, every slot."""

    name = "serve-static"

    def on_window_start(self, w: ServeWindow) -> None:
        self._frac = np.zeros(len(w.tiers))
        self._frac[0] = 1.0

    def decide(self, t: int, demand: float, balance: float,
               cum_carbon_g: float, cum_requests: float) -> np.ndarray:
        return self._frac


class ServeGreedyPolicy:
    """Current-CI percentile threshold, ledger-bounded.

    Above the 70th percentile of the day-ahead forecast the target quality
    drops toward the cheapest tier's, scaled by the ledger's remaining
    spend headroom (deep in debt -> barely degrade); below the 30th it
    repays at full quality; in between it holds ``quality_target``."""

    name = "serve-greedy"

    def on_window_start(self, w: ServeWindow) -> None:
        self.w = w

    def decide(self, t: int, demand: float, balance: float,
               cum_carbon_g: float, cum_requests: float) -> np.ndarray:
        w = self.w
        ci_now = w.ci.ci(t)
        target = w.config.quality_target
        if ci_now >= w.ci.percentile_threshold(t, 70.0):
            spend = (balance + 1.0) / 2.0
            q = target - spend * (target - float(w.q_vec[-1]))
        elif ci_now <= w.ci.percentile_threshold(t, 30.0):
            q = 1.0
        else:
            q = target
        return relieve_capacity(mix_for_quality(w.q_vec, q), demand, w)


class ServeFlexPolicy:
    """Forecast-aware-global routing (SNIPPETS.md §2 exemplar).

    Four factors, each in [-1, +1] with positive = *degrade now* (now is
    carbon-expensive relative to the future) and negative = *repay now*:

    - ``trend`` (0.35): the CI gradient — falling CI means the near future
      is cleaner, so spend quality debt now and repay in the clean slots;
    - ``demand`` (0.25): the rate forecast over the next ``horizon`` slots
      vs now — a spike ahead means capacity relief will soon *force*
      cheap-tier debt, so repay now to conserve ledger headroom for it;
    - ``look`` (0.20): current CI vs the mean of the extended look-ahead,
      read at the conservative ``quantile`` through
      :class:`QuantileCIView` (<60% -> strong repay, >140% -> strong
      degrade, linear between);
    - ``budget`` (0.20): realized grams/request so far vs the window's
      budget (serving at ``quality_target`` under the day-ahead mean CI)
      — over budget pushes toward cheap tiers regardless of the moment.

    The weighted sum is scaled by ledger headroom on the chosen side, so a
    maxed-out ledger mutes further movement in that direction."""

    name = "serve-flex"

    def __init__(self, quantile: float = 0.7, horizon: int = 6) -> None:
        self.quantile = float(quantile)
        self.horizon = int(horizon)

    def on_window_start(self, w: ServeWindow) -> None:
        self.w = w
        self.view = QuantileCIView(w.ci, self.quantile)
        frac0 = mix_for_quality(w.q_vec, w.config.quality_target)
        ci_ref = float(np.mean(w.ci.forecast(w.t0, 24)))
        self.budget_g_per_req = \
            float(np.sum(frac0 * w.e_vec)) * ci_ref / 1000.0

    def decide(self, t: int, demand: float, balance: float,
               cum_carbon_g: float, cum_requests: float) -> np.ndarray:
        w = self.w
        ci_now = w.ci.ci(t)
        f_trend = float(np.clip(-w.ci.gradient(t) / 0.05, -1.0, 1.0))
        ahead = w.rate[t + 1: t + 1 + self.horizon]
        if len(ahead):
            rate_now = max(float(w.rate[min(t, len(w.rate) - 1)]), 1.0)
            ratio_d = float(np.mean(ahead)) / rate_now
        else:
            ratio_d = 1.0
        f_demand = float(np.clip(-(ratio_d - 1.0) / 0.5, -1.0, 1.0))
        look = self.view.forecast_extended(t, self.horizon)
        ratio_c = ci_now / max(float(np.mean(look)), 1e-9)
        f_look = float(np.clip((ratio_c - 1.0) / 0.4, -1.0, 1.0))
        if cum_requests > 0.0:
            rate_g = cum_carbon_g / cum_requests
            f_budget = float(np.clip(
                (rate_g / max(self.budget_g_per_req, 1e-12) - 1.0) / 0.2,
                -1.0, 1.0))
        else:
            f_budget = 0.0
        adj = (0.35 * f_trend + 0.25 * f_demand
               + 0.20 * f_look + 0.20 * f_budget)
        target = w.config.quality_target
        if adj >= 0.0:
            spend = (balance + 1.0) / 2.0
            q = target - adj * spend * (target - float(w.q_vec[-1]))
        else:
            repay = (1.0 - balance) / 2.0
            q = target + (-adj) * repay * (1.0 - target)
        return relieve_capacity(mix_for_quality(w.q_vec, q), demand, w)
