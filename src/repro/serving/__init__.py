"""Carbon-aware serving tier: SLO-bounded request routing across precision
tiers with a quality credit ledger (the interactive-traffic counterpart of
the batch suspend/resume engine — see ``serving/engine.py``)."""
from .engine import (MaterializedServing, ServeCase,  # noqa: F401
                     simulate_serving, simulate_serving_many)
from .policies import (ServeFlexPolicy, ServeGreedyPolicy,  # noqa: F401
                       ServeStaticPolicy, ServeWindow, relieve_capacity)
from .tiers import (CreditLedger, PrecisionTier, ServingConfig,  # noqa: F401
                    SloModel, derive_tiers, mix_for_quality)
