"""Precision tiers, the SLO/latency model, and the quality credit ledger.

The serving tier trades **quality for carbon**: when carbon intensity is
high, traffic is routed to cheaper reduced-precision model flavours;
the quality shortfall is tracked as *debt* in a :class:`CreditLedger`
and repaid with above-target quality when carbon is low (the
demand-shaping idea of Radovanović et al.'s carbon-aware datacenter work,
tier-granular like the k8s-carbonrouter ``precision_tier`` stack).

The tier table is **derived from the repo's own cost models** rather than
invented:

- ``serve/decode.py``'s decode step is memory-bandwidth-bound (the KV
  cache sharding analysis there), so per-request energy and latency scale
  with *bytes moved* — halving the precision halves the energy per
  request and doubles the per-server throughput.  Tier energy/capacity
  therefore scale by ``bytes / 4`` relative to the fp32 reference.
- ``elastic/compression.py``'s int8 path quantises with per-tensor
  max-abs scaling to 127 levels; :func:`_int8_rms_rel_error` replicates
  that exact scheme in numpy on a seeded gaussian tensor to *measure* the
  RMS relative error it introduces (the jax original is pinned against
  this replica in tests), and bf16 rounding error is measured the same
  way by truncating fp32 mantissas.  Tier quality is then
  ``1 - quality_kappa * rms_error`` — a linear response-quality proxy.
"""
from __future__ import annotations

import dataclasses

import numpy as np


# --- measured quantisation error (the quality model's input) -----------------


def _int8_rms_rel_error(n: int = 1 << 14, seed: int = 0) -> float:
    """RMS relative error of the ``elastic/compression.py`` int8 scheme
    (per-tensor max-abs scaling to [-127, 127]) on a seeded standard
    gaussian tensor — a pure-numpy replica of ``_int8_roundtrip`` so the
    serving layer derives tier quality without importing jax.  Pinned
    against the jax original in tests/test_serving.py."""
    g = np.random.default_rng(seed).normal(0.0, 1.0, n)
    scale = max(np.max(np.abs(g)), 1e-12) / 127.0
    q = np.clip(np.round(g / scale), -127, 127)
    rt = q * scale
    return float(np.sqrt(np.mean((rt - g) ** 2) / np.mean(g ** 2)))


def _bf16_rms_rel_error(n: int = 1 << 14, seed: int = 0) -> float:
    """RMS relative error of bf16 rounding (truncate fp32 to the top 16
    bits, round-to-nearest) on the same seeded gaussian tensor."""
    g = np.random.default_rng(seed).normal(0.0, 1.0, n).astype(np.float32)
    bits = g.view(np.uint32)
    rt = ((bits + 0x8000) & 0xFFFF0000).view(np.float32).astype(np.float64)
    g64 = g.astype(np.float64)
    return float(np.sqrt(np.mean((rt - g64) ** 2) / np.mean(g64 ** 2)))


# --- the tier table ----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PrecisionTier:
    """One model flavour requests can be routed to.

    ``energy_kwh_per_kreq`` is the energy of serving 1000 requests on this
    tier; ``capacity_per_server`` the requests one server sustains per
    slot; ``quality`` the response-quality score in [0, 1] (fp32 = 1)."""

    name: str
    bytes_per_value: float
    energy_kwh_per_kreq: float
    quality: float
    capacity_per_server: float

    def __post_init__(self) -> None:
        if not 0.0 < self.quality <= 1.0:
            raise ValueError(f"tier {self.name!r}: quality must be in "
                             f"(0, 1], got {self.quality}")
        if self.energy_kwh_per_kreq <= 0 or self.capacity_per_server <= 0:
            raise ValueError(f"tier {self.name!r}: energy and capacity "
                             f"must be positive")


def derive_tiers(base_energy_kwh_per_kreq: float = 1.0,
                 base_capacity_per_server: float = 2500.0,
                 quality_kappa: float = 5.0) -> tuple[PrecisionTier, ...]:
    """The default fp32/bf16/int8 tier table, quality descending.

    Energy and capacity scale with bytes moved (the memory-bound decode
    argument of ``serve/decode.py``); quality is ``1 - kappa * rms_err``
    with the rms errors *measured* from the compression schemes above."""
    e_bf16, e_int8 = _bf16_rms_rel_error(), _int8_rms_rel_error()
    tiers = []
    for name, nbytes, err in (("fp32", 4.0, 0.0), ("bf16", 2.0, e_bf16),
                              ("int8", 1.0, e_int8)):
        ratio = nbytes / 4.0
        tiers.append(PrecisionTier(
            name=name, bytes_per_value=nbytes,
            energy_kwh_per_kreq=base_energy_kwh_per_kreq * ratio,
            quality=max(1.0 - quality_kappa * err, 1e-3),
            capacity_per_server=base_capacity_per_server / ratio))
    return tuple(tiers)


def mix_for_quality(qualities: np.ndarray, target: float) -> np.ndarray:
    """Fractional split over tiers (quality-descending order) whose
    fraction-weighted quality equals ``target``: the convex combination of
    the two *adjacent* tiers bracketing the target.  Adjacent pairs are
    the marginal-efficiency choice — under the byte-scaling cost model the
    cheapest grams-per-quality-point trade is always between neighbours
    (CarbonScaler-style marginal reasoning).  Targets outside the table's
    range clamp to the nearest pure tier."""
    n = len(qualities)
    frac = np.zeros(n)
    if target >= qualities[0]:
        frac[0] = 1.0
        return frac
    if target <= qualities[n - 1]:
        frac[n - 1] = 1.0
        return frac
    for i in range(n - 1):
        q_hi, q_lo = qualities[i], qualities[i + 1]
        if q_hi >= target >= q_lo:
            f = (target - q_lo) / (q_hi - q_lo)
            frac[i] = f
            frac[i + 1] = 1.0 - f
            return frac
    raise AssertionError("unreachable: qualities not sorted descending")


# --- SLO / latency model -----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SloModel:
    """Utilization -> SLO-violation-fraction map.

    A knee curve standing in for the queueing-latency tail: below
    ``knee`` utilization the fleet meets its latency SLO for every
    request; above it the violating fraction rises as
    ``((u - knee) / (1 - knee)) ** gamma`` and saturates at 1 (at u >= 1
    the fleet is overrun and every request blows the latency budget).
    Works elementwise on scalars and arrays — the engine calls it once
    per window over the whole utilization vector."""

    knee: float = 0.75
    gamma: float = 2.0

    def __post_init__(self) -> None:
        if not 0.0 < self.knee < 1.0:
            raise ValueError(f"knee must be in (0, 1), got {self.knee}")
        if self.gamma <= 0:
            raise ValueError(f"gamma must be positive, got {self.gamma}")

    def violation_frac(self, util):
        x = np.maximum((util - self.knee) / (1.0 - self.knee), 0.0)
        return np.minimum(x ** self.gamma, 1.0)


# --- quality credit ledger ---------------------------------------------------


@dataclasses.dataclass
class CreditLedger:
    """Cumulative quality credit/debt, bounded in [-1, +1] at every slot.

    Positive balance: quality served above target (credit available to
    spend on cheap tiers when carbon is high).  Negative: quality debt
    accumulated by reduced-precision serving, to be repaid when carbon is
    low.  ``gain`` converts a one-slot quality surplus/deficit into
    balance movement; the hard clip makes unbounded debt unrepresentable
    (the k8s-carbonrouter ``CreditLedger`` contract)."""

    gain: float = 0.1
    balance: float = 0.0

    def update(self, quality: float, target: float) -> float:
        b = self.balance + self.gain * (quality - target)
        self.balance = float(min(1.0, max(-1.0, b)))
        return self.balance

    def spend_headroom(self) -> float:
        """How much of the debt range is still available, in [0, 1]."""
        return (self.balance + 1.0) / 2.0

    def repay_headroom(self) -> float:
        """How much of the credit range is still available, in [0, 1]."""
        return (1.0 - self.balance) / 2.0


# --- the serving scenario config ---------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Everything a serving scenario adds to a :class:`Scenario` — trace
    shape, fleet size, tier-table knobs, SLO curve, and ledger gain.  All
    fields are JSON scalars so ``Scenario.to_dict`` round-trips it."""

    # request-trace shape (traces/requests.py)
    requests_per_day: float = 1.5e6
    diurnal: float = 0.45
    weekly: float = 0.15
    peak_hour: int = 14
    burst_rate: float = 0.01
    burst_mult: float = 3.0
    burst_mean_slots: float = 2.0
    # serving fleet + tier table (derive_tiers)
    servers: int = 48
    base_energy_kwh_per_kreq: float = 1.0
    base_capacity_per_server: float = 2500.0
    quality_kappa: float = 5.0
    # SLO + ledger
    knee: float = 0.75
    gamma: float = 2.0
    quality_target: float = 0.98
    ledger_gain: float = 0.1

    def __post_init__(self) -> None:
        if self.requests_per_day <= 0:
            raise ValueError("requests_per_day must be positive")
        if self.servers < 1:
            raise ValueError("servers must be >= 1")
        if not 0.0 < self.quality_target <= 1.0:
            raise ValueError(f"quality_target must be in (0, 1], "
                             f"got {self.quality_target}")
        if self.ledger_gain <= 0:
            raise ValueError("ledger_gain must be positive")

    def tiers(self) -> tuple[PrecisionTier, ...]:
        """The derived tier table (cached — the rms-error measurement runs
        once per config instance)."""
        cached = self.__dict__.get("_tiers")
        if cached is None:
            cached = derive_tiers(self.base_energy_kwh_per_kreq,
                                  self.base_capacity_per_server,
                                  self.quality_kappa)
            object.__setattr__(self, "_tiers", cached)
        return cached

    def slo(self) -> SloModel:
        return SloModel(knee=self.knee, gamma=self.gamma)
