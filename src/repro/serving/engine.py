"""The serving engine: vectorized slot loop over per-slot demand vectors.

Interactive requests cannot be suspended, so unlike the batch engine there
is no queue state — each slot the policy splits the slot's demand across
precision tiers, the engine charges carbon as energy x true CI, maps fleet
utilization through the SLO model to a violated-request fraction, and
updates the quality :class:`~repro.serving.tiers.CreditLedger`.

Parity discipline (mirroring ``core/engine``): ``simulate_serving`` runs
either the ``"vector"`` or the ``"scalar"`` path.  Both drive the *same*
policy code and the same sequential in-loop signals (ledger balance,
cumulative policy-visible carbon/requests — inherently serial, since each
decision feeds the next); they differ in the accounting.  The scalar
reference computes every per-slot quantity as a Python scalar inside the
loop; the vector path records only the decisions and does all accounting
as bulk numpy afterwards, with expressions chosen operation-for-operation
identical (elementwise multiply + sum, never ``dot``), so results are
bit-identical — tested per policy in ``tests/test_serving.py``.

Demand is *always* per-slot binned (``traces/requests.py``): a two-week,
1.5M-requests/day trace is 336 float64 slots, so a full sweep cell runs in
milliseconds with zero per-request Python.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.carbon import CarbonService
from repro.core.types import ServingMetrics, SimResult
from repro.telemetry import Telemetry

from .policies import ServeWindow
from .tiers import CreditLedger, ServingConfig


@dataclasses.dataclass
class MaterializedServing:
    """Concrete serving world resolved from ``Scenario(serving=...)``:
    the config plus the full-span realized demand and the expected-rate
    curve policies read as their demand forecast (``rate`` extends past
    the nominal span so look-ahead near the window end stays on real
    data)."""

    config: ServingConfig
    demand: np.ndarray               # realized requests per slot, full span
    rate: np.ndarray                 # expected requests per slot (forecast)


@dataclasses.dataclass
class ServeCase:
    """One serving simulation: a demand window under one policy.

    ``demand`` is the evaluation window's slice (slot ``i`` is absolute
    slot ``t0 + i``); ``rate`` stays full-span and absolute-indexed so
    policies can look ahead across the window boundary."""

    demand: np.ndarray
    rate: np.ndarray
    ci: CarbonService
    config: ServingConfig
    policy: object                   # ServeStaticPolicy / ... (duck-typed)
    t0: int = 0
    label: str = ""
    telemetry: Telemetry | None = None

    def __post_init__(self) -> None:
        self.demand = np.asarray(self.demand, dtype=np.float64)
        if self.demand.ndim != 1 or len(self.demand) < 1:
            raise ValueError("demand must be a non-empty 1-D per-slot vector")
        if self.t0 + len(self.demand) > len(self.ci.trace):
            raise ValueError(
                f"CI trace too short: window [{self.t0}, "
                f"{self.t0 + len(self.demand)}) needs "
                f"{self.t0 + len(self.demand)} slots, trace has "
                f"{len(self.ci.trace)}")


def _window(case: ServeCase, ci_pol) -> ServeWindow:
    cfg = case.config
    tiers = cfg.tiers()
    return ServeWindow(
        config=cfg, tiers=tiers,
        q_vec=np.array([t.quality for t in tiers]),
        e_vec=np.array([t.energy_kwh_per_kreq for t in tiers]),
        inv_cap=np.array([1.0 / t.capacity_per_server for t in tiers]),
        slo=cfg.slo(), ci=ci_pol, rate=case.rate, t0=case.t0,
        servers=cfg.servers)


def _serve_hooks(case: ServeCase):
    """Split the case's telemetry into (event-emitter, profiler); both
    None when telemetry is off so the hot loop pays a single branch."""
    telemetry = case.telemetry
    if telemetry is None:
        return None, None
    tele = telemetry if telemetry.recorder is not None else None
    return tele, telemetry.profiler


def _check_frac(frac: np.ndarray, policy_name: str) -> np.ndarray:
    frac = np.asarray(frac, dtype=np.float64)
    if np.any(frac < -1e-9) or abs(float(np.sum(frac)) - 1.0) > 1e-6:
        raise ValueError(f"policy {policy_name!r} returned an invalid tier "
                         f"split {frac} (must be >= 0 and sum to 1)")
    return frac


def _finalize(case: ServeCase, w: ServeWindow, fracs: np.ndarray,
              energy: np.ndarray, carbon: np.ndarray, util: np.ndarray,
              viol: np.ndarray, quality: np.ndarray,
              balance: np.ndarray) -> SimResult:
    """Reduce identical per-slot arrays to one SimResult — shared by both
    engine paths, so any parity break must come from the arrays."""
    demand = case.demand
    violated = demand * viol
    splits = fracs * demand[:, None]
    requests = float(np.sum(demand))
    q_mean = float(np.sum(quality * demand) / requests) if requests > 0 \
        else 1.0
    metrics = ServingMetrics(
        requests=requests,
        violated_requests=float(np.sum(violated)),
        quality_mean=q_mean,
        ledger_final=float(balance[-1]),
        ledger_min=float(np.min(balance)),
        ledger_max=float(np.max(balance)),
        tier_names=tuple(t.name for t in w.tiers),
        tier_requests=tuple(float(x) for x in np.sum(splits, axis=0)),
        balance=balance, utilization=util, quality=quality,
        violation_frac=viol, energy=energy, carbon=carbon)
    name = getattr(case.policy, "name", "serve")
    return SimResult(
        policy=name, carbon_g=float(np.sum(carbon)),
        energy_kwh=float(np.sum(energy)), slots=[],
        wait_slots=np.zeros(0), violations=np.zeros(0, dtype=bool),
        completion=np.zeros(0, dtype=np.int64), num_jobs=0,
        serving=metrics)


def _run_scalar(case: ServeCase) -> SimResult:
    """Reference path: every per-slot quantity a Python scalar in-loop."""
    cfg = case.config
    ci_pol = case.ci.degraded()
    w = _window(case, ci_pol)
    case.policy.on_window_start(w)
    tele, prof = _serve_hooks(case)
    prev_tier = -1
    ledger = CreditLedger(gain=cfg.ledger_gain)
    T = len(case.demand)
    n = len(w.tiers)
    fracs = np.zeros((T, n))
    energy, carbon, util, viol, quality, balance = \
        (np.zeros(T) for _ in range(6))
    cum_carbon = 0.0
    cum_requests = 0.0
    for i in range(T):
        t = case.t0 + i
        d = float(case.demand[i])
        if tele is not None and ci_pol is not case.ci:
            tele.emit(t, "forecast-read", value=float(ci_pol.staleness(t)))
        if prof is not None:
            _pt = time.perf_counter()
        frac = _check_frac(
            case.policy.decide(t, d, ledger.balance, cum_carbon,
                               cum_requests),
            getattr(case.policy, "name", "serve"))
        if prof is not None:
            _now = time.perf_counter()
            prof.add("decide", _now - _pt)
            _pt = _now
        if tele is not None:
            tier = int(np.argmax(frac))
            if tier != prev_tier and prev_tier >= 0:
                tele.emit(t, "tier-switch", value=float(tier),
                          detail=f"from={prev_tier}")
            prev_tier = tier
        q_t = float(np.sum(frac * w.q_vec))
        e_t = float(np.sum(frac * w.e_vec)) * (d / 1000.0)
        u_t = float(np.sum(frac * w.inv_cap)) * (d / w.servers)
        fracs[i] = frac
        energy[i] = e_t
        carbon[i] = e_t * case.ci.ci(t)
        util[i] = u_t
        viol[i] = float(w.slo.violation_frac(u_t))
        quality[i] = q_t
        balance[i] = ledger.update(q_t, cfg.quality_target)
        # the policy-visible running totals read the *degraded* CI view —
        # a policy must not learn the true CI through its budget signal
        cum_carbon = cum_carbon + e_t * ci_pol.ci(t)
        cum_requests = cum_requests + d
        if prof is not None:
            prof.add("execute", time.perf_counter() - _pt)
    return _finalize(case, w, fracs, energy, carbon, util, viol, quality,
                     balance)


def _run_vector(case: ServeCase) -> SimResult:
    """Fast path: the loop records only the sequential state (decisions,
    ledger, policy-visible totals); all accounting is bulk numpy."""
    cfg = case.config
    ci_pol = case.ci.degraded()
    w = _window(case, ci_pol)
    case.policy.on_window_start(w)
    tele, prof = _serve_hooks(case)
    prev_tier = -1
    ledger = CreditLedger(gain=cfg.ledger_gain)
    T = len(case.demand)
    fracs = np.zeros((T, len(w.tiers)))
    quality = np.zeros(T)
    balance = np.zeros(T)
    cum_carbon = 0.0
    cum_requests = 0.0
    for i in range(T):
        t = case.t0 + i
        d = float(case.demand[i])
        if tele is not None and ci_pol is not case.ci:
            tele.emit(t, "forecast-read", value=float(ci_pol.staleness(t)))
        if prof is not None:
            _pt = time.perf_counter()
        frac = _check_frac(
            case.policy.decide(t, d, ledger.balance, cum_carbon,
                               cum_requests),
            getattr(case.policy, "name", "serve"))
        if prof is not None:
            _now = time.perf_counter()
            prof.add("decide", _now - _pt)
            _pt = _now
        if tele is not None:
            tier = int(np.argmax(frac))
            if tier != prev_tier and prev_tier >= 0:
                tele.emit(t, "tier-switch", value=float(tier),
                          detail=f"from={prev_tier}")
            prev_tier = tier
        fracs[i] = frac
        q_t = float(np.sum(frac * w.q_vec))
        quality[i] = q_t
        balance[i] = ledger.update(q_t, cfg.quality_target)
        cum_carbon = cum_carbon + \
            float(np.sum(frac * w.e_vec)) * (d / 1000.0) * ci_pol.ci(t)
        cum_requests = cum_requests + d
        if prof is not None:
            prof.add("execute", time.perf_counter() - _pt)
    demand = case.demand
    if prof is not None:
        _pt = time.perf_counter()
    energy = (fracs * w.e_vec).sum(axis=1) * (demand / 1000.0)
    ci_true = np.array([case.ci.ci(case.t0 + i) for i in range(T)])
    carbon = energy * ci_true
    util = (fracs * w.inv_cap).sum(axis=1) * (demand / w.servers)
    viol = w.slo.violation_frac(util)
    if prof is not None:
        prof.add("execute", time.perf_counter() - _pt)
    return _finalize(case, w, fracs, energy, carbon, util, viol, quality,
                     balance)


def simulate_serving(case: ServeCase, engine: str = "vector",
                     telemetry: Telemetry | None = None) -> SimResult:
    """Run one serving case; ``engine`` picks the vector path (default) or
    the scalar reference (bit-identical, for parity tests).  ``telemetry``
    attaches a recorder/profiler without rebuilding the case."""
    if telemetry is not None:
        case = dataclasses.replace(case, telemetry=telemetry)
    if engine == "vector":
        return _run_vector(case)
    if engine == "scalar":
        return _run_scalar(case)
    raise ValueError(f"unknown serving engine {engine!r}; "
                     f"use 'vector' or 'scalar'")


def simulate_serving_many(cases, engine: str = "vector") -> list[SimResult]:
    """Batch dispatch, mirroring ``simulate_many`` for the sweep layer."""
    return [simulate_serving(c, engine=engine) for c in cases]
