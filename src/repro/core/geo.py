"""Geo-distributed placement and scheduling policies.

CarbonFlex shifts work in *time*; the policies here extend the same
cluster machinery to shifting work in *space* across regions with aligned
CI traces (Radovanović et al.'s cross-location flexible load, CarbonScaler
elasticity profiles telling us which jobs tolerate relocation):

- ``geo-static``  — the spatial status quo: every job pinned to its
  arrival region, FCFS at base scale (carbon-agnostic per region);
- ``geo-greedy``  — admission-time placement into the currently cleanest
  region with free capacity; no migration afterwards;
- ``geo-flex``    — CarbonFlex-style state extended with the per-region
  day-ahead CI rank: placement by forecast over the job's estimated run,
  per-region suspend/resume on the forecast-percentile threshold, and
  suspend-migrate-resume when the forecast gap between regions exceeds
  the migration carbon cost (checkpoint/restore slots + transfer energy
  charged by the engine's :class:`~repro.core.types.MigrationModel`).

All three run non-elastically at ``k_min`` — the spatial axis is studied
orthogonally to the elasticity axis, as in the paper's §6 ablations.

The engine drives them through the :class:`GeoPolicy` protocol: per slot
``decide_geo`` sees the active set (views exposing ``region`` and
``migrating`` on top of the single-region attributes) and returns a
per-region provisioning vector plus ``{job_id: (region, k)}``.  Returning
a region different from the job's current one is a *placement* while the
job has never run (free) and a *migration request* once it has (the
engine suspends the job for the migration window and charges the cost).
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np

from .carbon import MultiRegionCarbonService
from .types import GeoCluster, Job

_EPS = 1e-9


@runtime_checkable
class GeoPolicy(Protocol):
    """Placement+scheduling protocol the geo engines drive."""

    name: str

    def on_window_start(self, mci: MultiRegionCarbonService, t0: int,
                        horizon: int, jobs: list[Job],
                        geo: GeoCluster) -> None: ...

    def decide_geo(self, t: int, active: list, mci: MultiRegionCarbonService,
                   geo: GeoCluster) -> tuple[np.ndarray, dict[int, tuple[int, int]]]: ...

    def on_completion(self, t: int, job, violated: bool) -> None: ...


def _fcfs_order(active) -> list:
    """FCFS decision order shared by every geo policy: forced jobs first,
    then arrival/job_id; done and in-transit jobs are not schedulable."""
    return sorted((a for a in active if not a.done and not a.migrating),
                  key=lambda a: (not a.forced, a.job.arrival, a.job.job_id))


@dataclasses.dataclass
class GeoStaticPolicy:
    """Spatial status quo: jobs pinned to their arrival region, FCFS at
    base scale with full per-region capacity — the baseline every geo
    policy is measured against."""

    name: str = "geo-static"

    def on_window_start(self, mci, t0, horizon, jobs, geo) -> None:
        pass

    def decide_geo(self, t, active, mci, geo):
        m_vec = geo.capacity_vec()
        used = np.zeros(geo.n_regions, dtype=np.int64)
        alloc: dict[int, tuple[int, int]] = {}
        for a in _fcfs_order(active):
            r, k = a.region, a.job.k_min
            if used[r] + k <= m_vec[r]:
                alloc[a.job.job_id] = (r, k)
                used[r] += k
        return m_vec, alloc

    def on_completion(self, t, job, violated) -> None:
        pass


@dataclasses.dataclass
class GeoGreedyPolicy:
    """Admit each job to the currently cleanest region with free base
    capacity (ties -> lowest region index), and migrate started jobs when
    the *instantaneous* CI gap pays for the move.

    Greedy means myopic, not immobile: every decision — placement and
    migration alike — reads only the current CI vector, never the
    forecast (that is geo-flex's edge).  The original sticky-placement
    variant reported ``migrations: 0`` in BENCH_engine.json §geo not
    because migration was never profitable (geo-flex found 171 moves on
    the same trace) but because the policy had no migration rule at all;
    the myopic rule below closes that gap while preserving the
    greedy/flex contrast, and is pinned by a two-region large-CI-gap
    regression test (tests/test_geo.py)."""

    saving_margin: float = 0.25        # relative saving required to move
    max_migrations_per_job: int = 1    # ping-pong guard
    name: str = "geo-greedy"

    def on_window_start(self, mci, t0, horizon, jobs, geo) -> None:
        self._placed: dict[int, int] = {}
        self._moves: dict[int, int] = {}

    def decide_geo(self, t, active, mci, geo):
        m_vec = geo.capacity_vec()
        used = np.zeros(geo.n_regions, dtype=np.int64)
        ci_now = mci.ci_vec(t)
        clean_order = np.argsort(ci_now, kind="stable")
        alloc: dict[int, tuple[int, int]] = {}
        for a in _fcfs_order(active):
            jid, k = a.job.job_id, a.job.k_min
            if jid not in self._placed:
                if a.started:
                    self._placed[jid] = a.region
                else:
                    r = next((int(rr) for rr in clean_order
                              if used[rr] + k <= m_vec[rr]), None)
                    if r is None:
                        continue          # nothing free: retry next slot
                    self._placed[jid] = r
            r = self._placed[jid]
            if a.started:
                dest = self._migration_target(a, r, ci_now, geo)
                if dest is not None:
                    alloc[jid] = (dest, k)        # engine starts the move
                    self._placed[jid] = dest
                    self._moves[jid] = self._moves.get(jid, 0) + 1
                    continue
            if used[r] + k <= m_vec[r]:
                alloc[jid] = (r, k)
                used[r] += k
        return m_vec, alloc

    def _migration_target(self, a, r: int, ci_now: np.ndarray,
                          geo: GeoCluster) -> int | None:
        """Destination iff moving beats staying *at current CI* by the
        margin — the forecast-free analogue of geo-flex's rule, with the
        same slack/remaining guards against unfinishable moves."""
        if self._moves.get(a.job.job_id, 0) >= self.max_migrations_per_job:
            return None
        mig_slots = geo.migration.slots(a.job)
        if a.slack_left <= mig_slots + 1 or a.remaining <= mig_slots:
            return None
        h = int(max(1, np.ceil(a.remaining)))
        power = a.job.power if a.job.power > 0 else geo.power_per_server
        e_run = a.job.k_min * power * geo.slot_hours * h
        stay = float(ci_now[r]) * e_run
        mig_carbon = np.array([geo.migration.carbon_g(a.job, c)
                               for c in ci_now])
        move = ci_now * e_run + mig_carbon
        move[r] = np.inf
        best = int(np.argmin(move))
        if move[best] < stay * (1.0 - self.saving_margin):
            return best
        return None

    def on_completion(self, t, job, violated) -> None:
        jid = job.job.job_id
        self._placed.pop(jid, None)
        self._moves.pop(jid, None)


@dataclasses.dataclass
class GeoFlexPolicy:
    """CarbonFlex's provisioning/scheduling state extended in space.

    Per region the policy keeps the day-ahead forecast block and runs the
    suspend/resume rule on a forecast-percentile threshold (the rank
    feature of Table 2 generalised per region: a slot is runnable when it
    is among the region's cleanest ``percentile`` % of the next day, or
    the job is forced).  On top:

    - *placement* — an arriving job goes to the region with the lowest
      mean forecast over its estimated run (capacity permitting);
    - *migration* — a started job suspends-migrates-resumes when some
      other region's forecast over the remaining work, shifted past the
      migration window, undercuts staying put by more than the migration
      carbon (transfer energy at the destination's current CI) times the
      hysteresis margin — and only while enough slack remains to absorb
      the checkpoint/restore slots.
    """

    percentile: float = 40.0
    lookahead: int = 24
    saving_margin: float = 0.25        # relative saving required to move
    max_migrations_per_job: int = 1    # ping-pong guard
    name: str = "geo-flex"

    def on_window_start(self, mci, t0, horizon, jobs, geo) -> None:
        self._placed: dict[int, int] = {}
        self._moves: dict[int, int] = {}

    def decide_geo(self, t, active, mci, geo):
        m_vec = geo.capacity_vec()
        n_regions = geo.n_regions
        fc = mci.forecast_matrix(t, self.lookahead)       # (R, H)
        ci_now = mci.ci_vec(t)
        thresh = np.percentile(fc, self.percentile, axis=1)
        used = np.zeros(n_regions, dtype=np.int64)
        alloc: dict[int, tuple[int, int]] = {}
        for a in _fcfs_order(active):
            jid, k = a.job.job_id, a.job.k_min
            if not a.started:
                if jid not in self._placed:
                    h = int(min(self.lookahead, max(1, np.ceil(a.remaining))))
                    means = fc[:, :h].mean(axis=1)
                    order = np.argsort(means, kind="stable")
                    r = next((int(rr) for rr in order
                              if used[rr] + k <= m_vec[rr]), None)
                    if r is None:
                        continue          # nothing free: retry next slot
                    self._placed[jid] = r
                r = self._placed[jid]
            else:
                r = a.region
                dest = self._migration_target(a, r, fc, ci_now, geo)
                if dest is not None:
                    alloc[jid] = (dest, k)        # engine starts the move
                    self._placed[jid] = dest
                    self._moves[jid] = self._moves.get(jid, 0) + 1
                    continue
            if a.forced or ci_now[r] <= thresh[r] + _EPS:
                if used[r] + k <= m_vec[r]:
                    alloc[jid] = (r, k)
                    used[r] += k
        return m_vec, alloc

    def _migration_target(self, a, r: int, fc: np.ndarray,
                          ci_now: np.ndarray, geo: GeoCluster) -> int | None:
        """Destination region iff moving beats staying by the margin."""
        if self._moves.get(a.job.job_id, 0) >= self.max_migrations_per_job:
            return None
        mig_slots = geo.migration.slots(a.job)
        if a.slack_left <= mig_slots + 1 or a.remaining <= mig_slots:
            return None
        h = int(min(self.lookahead - mig_slots, max(1, np.ceil(a.remaining))))
        if h < 1:
            return None
        power = a.job.power if a.job.power > 0 else geo.power_per_server
        e_run = a.job.k_min * power * geo.slot_hours * h
        stay = float(fc[r, :h].mean()) * e_run
        mig_carbon = np.array([geo.migration.carbon_g(a.job, c)
                               for c in ci_now])
        move = fc[:, mig_slots:mig_slots + h].mean(axis=1) * e_run + mig_carbon
        move[r] = np.inf
        best = int(np.argmin(move))
        if move[best] < stay * (1.0 - self.saving_margin):
            return best
        return None

    def on_completion(self, t, job, violated) -> None:
        jid = job.job.job_id
        self._placed.pop(jid, None)
        self._moves.pop(jid, None)
