"""State featurisation + KNN knowledge base (paper §4.2, Table 2).

The learning phase replays recent traces through the offline oracle and
stores ``STATE -> (m_t, rho_t)`` mappings.  The execution phase queries the
top-k nearest historical states (Euclidean distance over z-scored features;
the paper uses a scikit-learn KD-tree with k=5 — we use a vectorised
brute-force top-k in JAX, with an optional Pallas kernel backend, which is
both simpler and faster at the case-base sizes involved: a few thousand
slots per window).

Aging (paper: "older mappings ... are aged out over a rolling window"): the
base keeps the most recent ``max_windows`` learning windows and drops older
ones on insert.

Hot-path note (EXPERIMENTS.md §Perf): the normalised, weighted case matrix
is computed once per ``_rebuild`` and cached — both as a host array and,
for the jax/pallas backends, as a device-resident ``float32`` array — so a
per-slot query touches only the query vector (O(D)) instead of re-z-scoring
the whole base (O(N·D)) and re-uploading it every slot.  ``add_window``
invalidates the cache.  ``query_batch`` answers Q queries per dispatch
(tiled (Q, N) Pallas distance kernel / one jitted top-k on the other
backends) for sweep-scale workloads.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from .carbon import CarbonService
from .types import Job


def build_state(
    ci: CarbonService,
    t: int,
    queue_counts: np.ndarray,
    mean_elasticity: float,
    arrivals_24h: np.ndarray | None = None,
    rel_backlog: float = 1.0,
) -> np.ndarray:
    """Table-2 state vector: [CI, CI gradient, CI day-ahead rank,
    per-queue (running+paused) job counts ..., per-queue trailing-24h
    arrival counts ..., mean elasticity].

    The trailing-arrival block is our addition to Table 2 (documented in
    EXPERIMENTS.md): in-system queue counts are *policy-dependent* — at
    runtime they drift away from the oracle's trajectory and corrupt the
    match — whereas arrival pressure is a pure function of the trace, so
    its distribution is identical in the learning and execution phases.
    """
    if arrivals_24h is None:
        arrivals_24h = np.zeros_like(np.asarray(queue_counts, dtype=np.float64))
    fc = ci.forecast(t)
    cur = ci.ci(t)
    ratio_min = cur / max(float(np.min(fc)), 1e-9)
    ratio_mean = cur / max(float(np.mean(fc)), 1e-9)
    return np.concatenate(
        [
            np.array([cur, ci.gradient(t), ci.rank(t), ratio_min, ratio_mean]),
            np.asarray(queue_counts, dtype=np.float64),
            np.asarray(arrivals_24h, dtype=np.float64),
            np.array([rel_backlog, mean_elasticity]),
        ]
    )


def relative_backlog(counts_history: np.ndarray) -> np.ndarray:
    """Policy-scale-invariant backlog signal: per-slot total in-system count
    divided by its running mean over the trajectory so far.

    Raw queue counts are policy-dependent (the runtime's backlog equilibrium
    differs from the oracle's), but *relative* deviation from one's own
    typical backlog transfers between the two trajectories.
    """
    counts = np.asarray(counts_history, dtype=np.float64)
    csum = np.cumsum(counts)
    denom = np.maximum(csum / np.arange(1, len(counts) + 1), 1e-9)
    return counts / denom


def states_from_schedule(
    jobs: list[Job],
    alloc: np.ndarray,
    ci: CarbonService,
    num_queues: int,
    t0: int = 0,
) -> np.ndarray:
    """Recompute the Table-2 state at each slot of an oracle run.

    ``alloc`` is the oracle's (N, T) allocation; a job is "in the system" at
    slot t if it has arrived and still has unfinished work (queued, paused,
    or running) — matching the runtime definition used by the simulator.
    """
    n, horizon = alloc.shape
    lengths = np.array([j.length for j in jobs])
    arrivals = np.array([j.arrival for j in jobs])
    queues = np.array([j.queue for j in jobs])
    elast = np.array([j.elasticity() for j in jobs])
    # Cumulative work done by each job before slot t, via the per-job
    # cumulative-throughput lookup table (no per-slot Python).
    kmax = int(alloc.max()) if alloc.size else 0
    thr_tab = np.zeros((n, kmax + 1))
    for i, job in enumerate(jobs):
        for k in range(1, kmax + 1):
            thr_tab[i, k] = job.throughput(k)
    thr = thr_tab[np.arange(n)[:, None], alloc]
    done_after = np.cumsum(thr, axis=1)
    ts = np.arange(horizon)
    done_before = np.concatenate([np.zeros((n, 1)), done_after[:, :-1]], axis=1)
    in_system = (arrivals[:, None] <= ts[None, :]) & \
        (done_before < (lengths - 1e-9)[:, None])               # (n, T)
    recent = (arrivals[:, None] > ts[None, :] - 24) & \
        (arrivals[:, None] <= ts[None, :])                      # (n, T)
    onehot = np.zeros((n, num_queues))
    onehot[np.arange(n), queues] = 1.0
    counts = in_system.T.astype(np.float64) @ onehot            # (T, nq)
    arr24 = recent.T.astype(np.float64) @ onehot                # (T, nq)
    n_in = in_system.sum(axis=0)
    el_sum = in_system.T.astype(np.float64) @ elast
    mean_el = np.where(n_in > 0, el_sum / np.maximum(n_in, 1), 0.0)
    rel = relative_backlog(counts.sum(axis=1))
    states = [
        build_state(ci, t0 + t, counts[t], float(mean_el[t]), arr24[t], rel[t])
        for t in range(horizon)
    ]
    return np.stack(states)


@partial(jax.jit, static_argnames=("k",))
def _knn_jax(cases: jnp.ndarray, query: jnp.ndarray, k: int):
    d2 = jnp.sum((cases - query[None, :]) ** 2, axis=1)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx


@partial(jax.jit, static_argnames=("k",))
def _knn_jax_batch(cases: jnp.ndarray, queries: jnp.ndarray, k: int):
    qn = jnp.sum(queries * queries, axis=1, keepdims=True)
    xn = jnp.sum(cases * cases, axis=1)[None, :]
    d2 = qn + xn - 2.0 * queries @ cases.T
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx


@dataclasses.dataclass
class KnowledgeBase:
    """Rolling case base of ``STATE -> (m_t, rho_t)`` oracle decisions.

    Distance details (beyond the paper's plain KD-tree Euclidean, which we
    found brittle under closed-loop state drift — see EXPERIMENTS.md):

    - queue-count features are ``log1p``-compressed, since the runtime
      policy's backlog distribution differs from the oracle's and raw counts
      otherwise dominate the metric when out-of-distribution;
    - features carry weights (CI level / day-ahead rank are the
      policy-relevant signal; queue counts provide demand context);
    - neighbour decisions are combined inverse-distance weighted.
    """

    max_windows: int = 8
    k: int = 5
    # "auto" resolves once per instance: brute-force numpy on CPU (a few
    # thousand cases x ~20 features is below the per-call dispatch cost of
    # jax on host), the jitted jax path when an accelerator is attached.
    backend: str = "auto"          # "auto" | "jax" | "pallas" | "numpy"
    # [CI, gradient, rank, queues..., arrivals..., elasticity] — the queue
    # and arrival weights broadcast over their blocks.
    ci_weight: float = 2.0
    rank_weight: float = 2.0
    gradient_weight: float = 1.0
    queue_weight: float = 0.0
    arrival_weight: float = 0.0
    backlog_weight: float = 1.0
    elasticity_weight: float = 0.0
    ratio_weight: float = 2.0
    log_queues: bool = True
    # cache=False recomputes the normalised case matrix on every query (the
    # pre-vectorisation behaviour) — kept for the engine micro-benchmark.
    cache: bool = True
    # None = auto-detect (Pallas interpret mode everywhere but TPU).
    pallas_interpret: bool | None = None

    def __post_init__(self) -> None:
        if self.backend == "auto":
            self.backend = "numpy" if jax.default_backend() == "cpu" else "jax"
        self._windows: deque[tuple[np.ndarray, np.ndarray]] = deque(maxlen=self.max_windows)
        self._dirty = True
        self._X = None
        self._Y = None
        self._mu = None
        self._sigma = None
        self._Xn = None            # normalised, weighted case matrix (host)
        self._Xn_dev = None        # same, device-resident float32

    def _weights(self, dim: int) -> np.ndarray:
        nq = (dim - 7) // 2
        return np.array(
            [self.ci_weight, self.gradient_weight, self.rank_weight,
             self.ratio_weight, self.ratio_weight]
            + [self.queue_weight] * nq
            + [self.arrival_weight] * nq
            + [self.backlog_weight, self.elasticity_weight]
        )

    def _transform(self, x: np.ndarray) -> np.ndarray:
        x = np.array(x, dtype=np.float64, copy=True)
        if self.log_queues:
            x[..., 5:-2] = np.log1p(np.maximum(x[..., 5:-2], 0.0))
        return x

    # --- learning-phase API -------------------------------------------------

    def add_window(self, states: np.ndarray, m_curve: np.ndarray, rho_curve: np.ndarray) -> None:
        y = np.stack([np.asarray(m_curve, np.float64), np.asarray(rho_curve, np.float64)], axis=1)
        self._windows.append((np.asarray(states, np.float64), y))
        self._dirty = True

    def _rebuild(self) -> None:
        xs = [w[0] for w in self._windows]
        ys = [w[1] for w in self._windows]
        self._X = self._transform(np.concatenate(xs)) if xs else np.zeros((0, 1))
        self._Y = np.concatenate(ys) if ys else np.zeros((0, 2))
        self._Xn = None
        self._Xn_dev = None
        if len(self._X):
            self._mu = self._X.mean(axis=0)
            self._sigma = np.maximum(self._X.std(axis=0), 1e-9)
            if self.cache:
                self._Xn = self._normalize_cases()
                if self.backend in ("jax", "pallas"):
                    # one host->device transfer per rebuild, not per query
                    self._Xn_dev = jnp.asarray(self._Xn, jnp.float32)
        self._dirty = False

    def _normalize_cases(self) -> np.ndarray:
        w = self._weights(self._X.shape[1])
        return np.clip((self._X - self._mu) / self._sigma, -3.0, 3.0) * w[None, :]

    def _normalize_query(self, state: np.ndarray) -> np.ndarray:
        """Z-score + clip + weight one state (or a (Q, D) batch of states).

        Clip z-scores: a low-variance feature (e.g. mean elasticity under a
        stable mix) must not dominate the metric when the runtime drifts
        slightly out of the training distribution."""
        w = self._weights(self._X.shape[1])
        q = self._transform(np.asarray(state, np.float64))
        return np.clip((q - self._mu) / self._sigma, -3.0, 3.0) * w

    def _cases(self) -> np.ndarray:
        if self._Xn is not None:
            return self._Xn
        return self._normalize_cases()

    def _cases_dev(self) -> jnp.ndarray:
        if self._Xn_dev is not None:
            return self._Xn_dev
        return jnp.asarray(self._cases(), jnp.float32)

    def __len__(self) -> int:
        if self._dirty:
            self._rebuild()
        return len(self._X)

    def rho_values(self) -> np.ndarray:
        """All stored oracle rho decisions (the learned marginal-capacity
        curve's samples) — ``carbonflex-scale`` derives its scale-up
        threshold from their median (core/mpc.py)."""
        if self._dirty:
            self._rebuild()
        return self._Y[:, 1] if len(self._X) else np.zeros(0)

    # --- execution-phase API ------------------------------------------------

    def _prepare(self, state: np.ndarray, k: int | None):
        if self._dirty:
            self._rebuild()
        if not len(self._X):
            raise RuntimeError("empty knowledge base — run a learning window first")
        return min(k or self.k, len(self._X)), self._normalize_query(state)

    def query(self, state: np.ndarray, k: int | None = None):
        """Top-k nearest cases.  Returns (m_values, rho_values, distances)."""
        k, q = self._prepare(state, k)
        if self.backend == "numpy":
            xs = self._cases()
            d2 = np.sum((xs - q[None, :]) ** 2, axis=1)
            idx = np.argpartition(d2, k - 1)[:k]
            idx = idx[np.argsort(d2[idx])]
            dist = np.sqrt(d2[idx])
        elif self.backend == "pallas":
            from repro.kernels import knn as knn_kernel

            dist, idx = knn_kernel.knn_topk(
                self._cases_dev(), jnp.asarray(q, jnp.float32), k,
                interpret=self.pallas_interpret)
            dist, idx = np.asarray(dist), np.asarray(idx)
        else:
            dist, idx = _knn_jax(self._cases_dev(), jnp.asarray(q, jnp.float32), k)
            dist, idx = np.asarray(dist), np.asarray(idx)
        return self._Y[idx, 0], self._Y[idx, 1], dist

    def query_batch(self, states: np.ndarray, k: int | None = None):
        """Top-k nearest cases for a (Q, D) batch of states in one dispatch.

        Returns ((Q, k) m_values, (Q, k) rho_values, (Q, k) distances).
        Distances use the MXU-friendly dot-product expansion and can differ
        from ``query`` in the final ulps (ties may reorder)."""
        states = np.atleast_2d(np.asarray(states, np.float64))
        k, qs = self._prepare(states, k)
        if self.backend == "numpy":
            xs = self._cases()
            qn = np.sum(qs * qs, axis=1, keepdims=True)
            xn = np.sum(xs * xs, axis=1)[None, :]
            d2 = np.maximum(qn + xn - 2.0 * qs @ xs.T, 0.0)
            idx = np.argpartition(d2, k - 1, axis=1)[:, :k]
            order = np.argsort(np.take_along_axis(d2, idx, axis=1), axis=1)
            idx = np.take_along_axis(idx, order, axis=1)
            dist = np.sqrt(np.take_along_axis(d2, idx, axis=1))
        elif self.backend == "pallas":
            from repro.kernels import knn as knn_kernel

            dist, idx = knn_kernel.knn_topk_batch(
                self._cases_dev(), jnp.asarray(qs, jnp.float32), k,
                interpret=self.pallas_interpret)
            dist, idx = np.asarray(dist), np.asarray(idx)
        else:
            dist, idx = _knn_jax_batch(self._cases_dev(),
                                       jnp.asarray(qs, jnp.float32), k)
            dist, idx = np.asarray(dist), np.asarray(idx)
        return self._Y[idx, 0], self._Y[idx, 1], dist
