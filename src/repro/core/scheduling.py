"""CarbonFlex runtime scheduling — Algorithm 3 (psi).

Given the provisioned capacity ``m_t`` and the learned marginal-throughput
threshold ``rho``, allocate servers to queued/running jobs:

- enumerate (job, scale) pairs with ``p_j(k) >= rho``;
- sort by marginal throughput desc, remaining slack asc (line 6);
- allocate incrementally until ``m_t`` is filled;
- jobs are not scaled past ``k_min`` until every eligible job holds
  ``k_min`` (starvation freedom) — this falls out of the sort because
  ``p_j(k_min) = 1`` dominates every scaling marginal;
- jobs whose slack is exhausted are *forced*: they are allocated ``k_min``
  first, bypassing ``rho`` (run-to-completion after the permitted delay,
  §6.1), mirroring how every baseline in the paper honours SLOs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .types import Job

_EPS = 1e-9


@dataclasses.dataclass
class ActiveJob:
    """Runtime view of a job inside the cluster."""

    job: Job
    remaining: float            # work left, in k_min-slots
    slack_left: int             # waiting budget left (slots)
    waited: int = 0             # slots spent queued/paused so far
    started: bool = False

    @property
    def forced(self) -> bool:
        return self.slack_left <= 0

    @property
    def done(self) -> bool:
        return self.remaining <= _EPS


def schedule(
    active: list[ActiveJob],
    m_t: int,
    rho: float,
    fill_spare: bool = False,
) -> dict[int, int]:
    """Algorithm 3.  Returns {job_id: k} for jobs to run this slot.

    ``fill_spare``: when the rho-filtered pass leaves provisioned capacity
    idle (the runtime backlog is smaller than the oracle's was in the
    matched historical state), continue down the marginal-throughput list
    rho-free.  The oracle never leaves provisioned capacity idle while
    positive-marginal work exists, so this keeps the mimicry faithful; the
    provisioning decision m_t (not rho) is what protects high-carbon slots.
    """
    alloc: dict[int, int] = {}
    used = 0

    # Forced jobs first (slack exhausted): base allocation, ignore rho.
    forced = sorted((a for a in active if a.forced and not a.done),
                    key=lambda a: a.slack_left)
    for a in forced:
        k = a.job.k_min
        if used + k > m_t:
            break
        alloc[a.job.job_id] = k
        used += k

    # Candidate (job, scale) list (lines 2–5); spare-fill entries kept aside.
    entries: list[tuple[float, int, int, int]] = []   # (p, slack, job_id, k)
    spares: list[tuple[float, int, int, int]] = []
    by_id = {a.job.job_id: a for a in active}
    for a in active:
        if a.done:
            continue
        for k in range(a.job.k_min, a.job.k_max + 1):
            p = a.job.marginal(k)
            if p <= 0:
                continue
            if p >= rho - _EPS:
                entries.append((p, a.slack_left, a.job.job_id, k))
            elif fill_spare:
                spares.append((p, a.slack_left, a.job.job_id, k))
    # Sort: marginal throughput desc, then remaining slack asc (line 6).
    entries.sort(key=lambda e: (-e[0], e[1]))
    spares.sort(key=lambda e: (-e[0], e[1]))

    def fill(cands: list[tuple[float, int, int, int]], used: int) -> int:
        for p, _, jid, k in cands:                     # lines 7–9
            a = by_id[jid]
            cur = alloc.get(jid, 0)
            is_base = k == a.job.k_min
            add = a.job.k_min if is_base else 1
            if is_base and cur != 0:
                continue
            if not is_base and cur != k - 1:
                continue
            if used + add > m_t:
                continue
            alloc[jid] = k
            used += add
        return used

    used = fill(entries, used)
    if fill_spare and used < m_t:
        used = fill(spares, used)
    return alloc


def apply_slot(active: list[ActiveJob], alloc: dict[int, int]) -> None:
    """Advance one slot: progress allocated jobs, charge waiting to others."""
    for a in active:
        if a.done:
            continue
        k = alloc.get(a.job.job_id, 0)
        if k > 0:
            a.remaining -= a.job.throughput(k)
            a.started = True
        else:
            a.slack_left -= 1
            a.waited += 1
