"""CarbonFlex runtime scheduling — Algorithm 3 (psi).

Given the provisioned capacity ``m_t`` and the learned marginal-throughput
threshold ``rho``, allocate servers to queued/running jobs:

- enumerate (job, scale) pairs with ``p_j(k) >= rho``;
- sort by marginal throughput desc, remaining slack asc (line 6);
- allocate incrementally until ``m_t`` is filled;
- jobs are not scaled past ``k_min`` until every eligible job holds
  ``k_min`` (starvation freedom) — this falls out of the sort because
  ``p_j(k_min) = 1`` dominates every scaling marginal;
- jobs whose slack is exhausted are *forced*: they are allocated ``k_min``
  first, bypassing ``rho`` (run-to-completion after the permitted delay,
  §6.1), mirroring how every baseline in the paper honours SLOs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .types import Job

_EPS = 1e-9


@dataclasses.dataclass
class ActiveJob:
    """Runtime view of a job inside the cluster."""

    job: Job
    remaining: float            # work left, in k_min-slots
    slack_left: int             # waiting budget left (slots)
    waited: int = 0             # slots spent queued/paused so far
    started: bool = False

    @property
    def forced(self) -> bool:
        return self.slack_left <= 0

    @property
    def done(self) -> bool:
        return self.remaining <= _EPS


def schedule(
    active: list[ActiveJob],
    m_t: int,
    rho: float,
    fill_spare: bool = False,
) -> dict[int, int]:
    """Algorithm 3.  Returns {job_id: k} for jobs to run this slot.

    ``fill_spare``: when the rho-filtered pass leaves provisioned capacity
    idle (the runtime backlog is smaller than the oracle's was in the
    matched historical state), continue down the marginal-throughput list
    rho-free.  The oracle never leaves provisioned capacity idle while
    positive-marginal work exists, so this keeps the mimicry faithful; the
    provisioning decision m_t (not rho) is what protects high-carbon slots.
    """
    alloc: dict[int, int] = {}
    used = 0

    # Forced jobs first (slack exhausted): base allocation, ignore rho.
    forced = sorted((a for a in active if a.forced and not a.done),
                    key=lambda a: a.slack_left)
    for a in forced:
        k = a.job.k_min
        if used + k > m_t:
            break
        alloc[a.job.job_id] = k
        used += k

    # Candidate (job, scale) list (lines 2–5); spare-fill entries kept aside.
    entries: list[tuple[float, int, int, int]] = []   # (p, slack, job_id, k)
    spares: list[tuple[float, int, int, int]] = []
    by_id = {a.job.job_id: a for a in active}
    for a in active:
        if a.done:
            continue
        for k in range(a.job.k_min, a.job.k_max + 1):
            p = a.job.marginal(k)
            if p <= 0:
                continue
            if p >= rho - _EPS:
                entries.append((p, a.slack_left, a.job.job_id, k))
            elif fill_spare:
                spares.append((p, a.slack_left, a.job.job_id, k))
    # Sort: marginal throughput desc, then remaining slack asc (line 6).
    entries.sort(key=lambda e: (-e[0], e[1]))
    spares.sort(key=lambda e: (-e[0], e[1]))

    def fill(cands: list[tuple[float, int, int, int]], used: int) -> int:
        for p, _, jid, k in cands:                     # lines 7–9
            a = by_id[jid]
            cur = alloc.get(jid, 0)
            is_base = k == a.job.k_min
            add = a.job.k_min if is_base else 1
            if is_base and cur != 0:
                continue
            if not is_base and cur != k - 1:
                continue
            if used + add > m_t:
                continue
            alloc[jid] = k
            used += add
        return used

    used = fill(entries, used)
    if fill_spare and used < m_t:
        used = fill(spares, used)
    return alloc


def apply_slot(active: list[ActiveJob], alloc: dict[int, int]) -> None:
    """Advance one slot: progress allocated jobs, charge waiting to others."""
    for a in active:
        if a.done:
            continue
        k = alloc.get(a.job.job_id, 0)
        if k > 0:
            a.remaining -= a.job.throughput(k)
            a.started = True
        else:
            a.slack_left -= 1
            a.waited += 1


# --- packed (struct-of-arrays) fast path -----------------------------------
#
# The vectorised simulator engine keeps per-job state in flat arrays; the
# helpers below run Algorithm 3 against those arrays without building
# ActiveJob lists or per-slot (job, scale) Python enumerations.  Candidate
# (p, k) pairs per job are static — they depend only on the profile — so
# they are concatenated once per packed-job build and gathered per slot.


@dataclasses.dataclass
class EntryBlocks:
    """Per-job candidate (marginal, scale) pairs, concatenated row-major.

    Row j's pairs (k ascending, positive marginals only) live at
    ``flat_p/flat_k[off[j]:off[j] + cnt[j]]``."""

    flat_p: np.ndarray           # float64 marginals
    flat_k: np.ndarray           # int64 scales
    off: np.ndarray              # int64 per-row offset
    cnt: np.ndarray              # int64 per-row pair count

    @classmethod
    def build(cls, jobs: list[Job]) -> "EntryBlocks":
        ps, ks, off, cnt = [], [], [], []
        pos = 0
        for job in jobs:
            pairs = [(job.marginal(k), k)
                     for k in range(job.k_min, job.k_max + 1)
                     if job.marginal(k) > 0]
            off.append(pos)
            cnt.append(len(pairs))
            pos += len(pairs)
            ps.extend(p for p, _ in pairs)
            ks.extend(k for _, k in pairs)
        return cls(np.array(ps, dtype=np.float64),
                   np.array(ks, dtype=np.int64),
                   np.array(off, dtype=np.int64),
                   np.array(cnt, dtype=np.int64))

    def gather(self, rows: np.ndarray):
        """(P, K, R) candidate arrays for ``rows``, preserving row order."""
        cnt = self.cnt[rows]
        total = int(cnt.sum())
        if total == 0:
            z = np.zeros(0, dtype=np.int64)
            return np.zeros(0), z, z
        starts = np.cumsum(cnt) - cnt
        pos = np.arange(total, dtype=np.int64) - np.repeat(starts, cnt) \
            + np.repeat(self.off[rows], cnt)
        return self.flat_p[pos], self.flat_k[pos], np.repeat(rows, cnt)


def schedule_packed(
    blocks: EntryBlocks,
    k_min: np.ndarray,
    slack_left: np.ndarray,
    rows: np.ndarray,
    m_t: int,
    rho: float,
) -> np.ndarray:
    """Algorithm 3 over packed arrays; returns a full-length ``k`` vector.

    Produces exactly the allocation of ``schedule`` (same candidate order,
    same stable sort keys, same fill semantics) for ``fill_spare=False`` —
    asserted by tests/test_engine_parity.py."""
    kcur = [0] * len(k_min)
    kml = k_min.tolist()
    used = 0

    # Forced jobs first (slack exhausted): base allocation, ignore rho.
    forced = rows[slack_left[rows] <= 0]
    for r in forced[np.argsort(slack_left[forced], kind="stable")].tolist():
        k = kml[r]
        if used + k > m_t:
            break
        kcur[r] = k
        used += k

    # Candidate (job, scale) list (lines 2–5), rho-filtered.
    P, K, R = blocks.gather(rows)
    keep = P >= rho - _EPS
    K, R = K[keep], R[keep]
    # Sort: marginal throughput desc, then remaining slack asc (line 6);
    # lexsort is stable, so ties keep (row, k) order like list.sort did.
    order = np.lexsort((slack_left[R], -P[keep]))
    rl, kl = R[order].tolist(), K[order].tolist()
    for i in range(len(rl)):                           # lines 7–9
        r = rl[i]
        k = kl[i]
        cur = kcur[r]
        if k == kml[r]:
            if cur != 0:
                continue
            add = k
        else:
            if cur != k - 1:
                continue
            add = 1
        if used + add > m_t:
            continue
        kcur[r] = k
        used += add
    return np.array(kcur, dtype=np.int64)
