"""CarbonFlex offline oracle — Algorithm 1 of the paper.

Greedy carbon-optimal scheduling: enumerate ``(job, slot, scale)`` triples,
score each by marginal throughput per unit carbon ``p_j(k) / CI_t``, sort
descending (ties broken by earliest deadline), and allocate greedily subject
to the cluster capacity ``M``.  Optimal for monotonically decreasing
marginal-throughput profiles on homogeneous clusters (Theorem 4.1, via
Federgruen & Groenevelt's greedy resource-allocation result).

We interpret each list entry *incrementally*: the entry ``(j, t, k)`` raises
job j's allocation in slot t from ``k-1`` to ``k`` (the base entry
``k = k_min`` raises 0 -> k_min).  Because profiles are monotone decreasing,
the sorted order guarantees the ``k-1`` entry is considered before ``k`` for
the same slot, so the greedy pass visits allocations in a consistent order.

Three implementations, tested to agree:

- ``solve_numpy``      — the default: vectorised (meshgrid) entry
                         construction + a tight early-exit greedy pass.
- ``backend="numpy-ref"`` — the original readable reference pass, kept for
                         parity tests and the engine micro-benchmark.
- ``backend="jax"``    — the same greedy pass as a ``lax.fori_loop`` jitted
                         scan over the pre-sorted entry arrays.  Only worth
                         it on accelerators: on CPU the per-iteration
                         dispatch makes it ~20x slower than numpy, so the
                         default everywhere in this repo is ``numpy``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from .types import Job, Schedule

_EPS = 1e-9


@dataclasses.dataclass
class OracleResult:
    schedule: Schedule
    capacity_curve: np.ndarray       # m_t (decision output, Table 2)
    rho_curve: np.ndarray            # rho_t: lowest scheduled marginal throughput
    work_done: np.ndarray            # per-job completed work


def _marginal_table(jobs: list[Job]) -> np.ndarray:
    """(n, K+1) lookup: row j, column k = p_j(k) (0 outside [k_min, k_max])."""
    kmax_g = max((j.k_max for j in jobs), default=0)
    tab = np.zeros((len(jobs), kmax_g + 1))
    for i, job in enumerate(jobs):
        tab[i, job.k_min:job.k_max + 1] = job.profile
    return tab


def _build_entries(jobs: list[Job], ci: np.ndarray, horizon: int):
    """Flattened (job, slot, scale) entry arrays, sorted by the greedy key.

    Returns int64/float64 arrays: j_idx, t_idx, k_val, gain (marginal
    throughput), score, in greedy order (score desc, deadline asc, stable).

    Vectorised construction: the (job, scale) pair grid comes from the
    padded marginal table (meshgrid over jobs x scales, masked to each
    job's [k_min, k_max] positive-marginal range), then each pair is
    expanded over its admissible slot window with a ragged-arange — no
    per-job x per-scale Python loop.  Pair order (job-major, k ascending)
    and the stable lexsort keep the entry order identical to the original
    loop-based builder, so greedy results are bit-for-bit unchanged.
    """
    n = len(jobs)
    z = np.zeros(0, dtype=np.int64)
    if n == 0:
        return z, z, z, np.zeros(0), np.zeros(0)
    marg = _marginal_table(jobs)                     # (n, K+1)
    kmin = np.array([j.k_min for j in jobs], dtype=np.int64)
    kmax = np.array([j.k_max for j in jobs], dtype=np.int64)
    dl = np.array([j.deadline for j in jobs], dtype=np.int64)
    t0 = np.maximum(np.array([j.arrival for j in jobs], dtype=np.int64), 0)
    t1 = np.minimum(horizon, dl + 1)
    ks = np.arange(marg.shape[1], dtype=np.int64)   # scale meshgrid axis
    pair_ok = (ks[None, :] >= kmin[:, None]) & (ks[None, :] <= kmax[:, None]) \
        & (marg > 0) & (t1 > t0)[:, None]
    pj, pk = np.nonzero(pair_ok)                    # job-major, k ascending
    if not len(pj):
        return z, z, z, np.zeros(0), np.zeros(0)
    pgain = marg[pj, pk]
    pt0, pt1, pdl = t0[pj], t1[pj], dl[pj]
    counts = pt1 - pt0                              # slots per (job, k) pair
    total = int(counts.sum())
    starts = np.cumsum(counts) - counts
    # ragged arange: for each pair, the slots [t0, t1)
    t_idx = np.arange(total, dtype=np.int64) - np.repeat(starts - pt0, counts)
    j_idx = np.repeat(pj.astype(np.int64), counts)
    k_val = np.repeat(pk, counts)
    gain = np.repeat(pgain, counts)
    deadline = np.repeat(pdl, counts)
    score = gain / ci[t_idx]
    # Sort: score desc, then deadline asc (earliest-deadline tie-break, line 6).
    order = np.lexsort((deadline, -score))
    return j_idx[order], t_idx[order], k_val[order], gain[order], score[order]


def _greedy_numpy(jobs, ci, capacity, horizon, lengths, k_extra):
    """Fast greedy pass: plain-Python element access over the pre-sorted
    entry lists (numpy scalar indexing is ~5x slower per element) and an
    early exit once every job has finished — the sorted tail past that
    point is all skips.  Output is identical to ``numpy-ref``."""
    j_idx, t_idx, k_val, gain, _ = _build_entries(jobs, ci, horizon)
    n = len(jobs)
    kmin = [j.k_min for j in jobs]
    lens = [float(v) - _EPS for v in lengths]
    work = [0.0] * n
    used = [0] * horizon
    alloc = [[0] * horizon for _ in range(n)]
    unfinished = sum(1 for i in range(n) if work[i] < lens[i])
    jl, tl = j_idx.tolist(), t_idx.tolist()
    kl, gl = k_val.tolist(), gain.tolist()
    for i in range(len(jl)):
        j = jl[i]
        if work[j] >= lens[j]:
            continue                         # line 11: job already done
        t, k = tl[i], kl[i]
        row = alloc[j]
        prev = row[t]
        km = kmin[j]
        if k == km:                          # base entry adds k_min servers
            if prev != 0:
                continue                     # incremental consistency
            add, g = km, 1.0                 # base throughput p(k_min)=1
        else:
            if prev != k - 1:
                continue
            add, g = 1, gl[i]
        if used[t] + add > capacity:
            continue                         # line 9: capacity exceeded
        row[t] = k
        used[t] += add
        w = work[j] + g
        work[j] = w
        if w >= lens[j]:
            unfinished -= 1
            if unfinished == 0:
                break                        # all jobs done: the rest skip
    return (np.array(alloc, dtype=np.int64).reshape(n, horizon),
            np.array(used, dtype=np.int64), np.array(work))


def _greedy_numpy_ref(jobs, ci, capacity, horizon, lengths, k_extra):
    """Readable reference pass (the original implementation)."""
    j_idx, t_idx, k_val, gain, _ = _build_entries(jobs, ci, horizon)
    n = len(jobs)
    alloc = np.zeros((n, horizon), dtype=np.int64)
    used = np.zeros(horizon, dtype=np.int64)
    work = np.zeros(n)
    kmin = np.array([j.k_min for j in jobs], dtype=np.int64)
    for i in range(len(j_idx)):
        j, t, k, g = j_idx[i], t_idx[i], k_val[i], gain[i]
        if work[j] >= lengths[j] - _EPS:
            continue  # line 11: job already done
        prev = alloc[j, t]
        add = kmin[j] if k == kmin[j] else 1  # base entry adds k_min servers
        if (k == kmin[j] and prev != 0) or (k != kmin[j] and prev != k - 1):
            continue  # incremental consistency
        if used[t] + add > capacity:
            continue  # line 9: capacity exceeded
        alloc[j, t] = k
        used[t] += add
        work[j] += g if k != kmin[j] else 1.0  # base throughput p(k_min)=1
    return alloc, used, work


@partial(jax.jit, static_argnames=("capacity", "n", "horizon"))
def _greedy_jax(j_idx, t_idx, k_val, gain, kmin, lengths, capacity, n, horizon):
    """The same greedy pass as a fori_loop over pre-sorted entries."""

    def body(i, state):
        alloc, used, work = state
        j, t, k, g = j_idx[i], t_idx[i], k_val[i], gain[i]
        prev = alloc[j, t]
        is_base = k == kmin[j]
        add = jnp.where(is_base, kmin[j], 1)
        consistent = jnp.where(is_base, prev == 0, prev == k - 1)
        ok = (
            (work[j] < lengths[j] - _EPS)
            & consistent
            & (used[t] + add <= capacity)
        )
        gain_i = jnp.where(is_base, 1.0, g)
        alloc = alloc.at[j, t].set(jnp.where(ok, k, prev))
        used = used.at[t].add(jnp.where(ok, add, 0))
        work = work.at[j].add(jnp.where(ok, gain_i, 0.0))
        return alloc, used, work

    alloc0 = jnp.zeros((n, horizon), dtype=jnp.int32)
    used0 = jnp.zeros(horizon, dtype=jnp.int32)
    work0 = jnp.zeros(n, dtype=jnp.float32)
    return jax.lax.fori_loop(0, len(j_idx), body, (alloc0, used0, work0))


def _greedy(jobs, ci, capacity, horizon, lengths, backend):
    if backend == "numpy":
        return _greedy_numpy(jobs, ci, capacity, horizon, lengths, None)
    if backend == "numpy-ref":
        return _greedy_numpy_ref(jobs, ci, capacity, horizon, lengths, None)
    j_idx, t_idx, k_val, gain, _ = _build_entries(jobs, ci, horizon)
    kmin = np.array([j.k_min for j in jobs], dtype=np.int32)
    if len(j_idx) == 0:
        n = len(jobs)
        return (np.zeros((n, horizon), np.int64), np.zeros(horizon, np.int64), np.zeros(n))
    alloc, used, work = _greedy_jax(
        jnp.asarray(j_idx, jnp.int32),
        jnp.asarray(t_idx, jnp.int32),
        jnp.asarray(k_val, jnp.int32),
        jnp.asarray(gain, jnp.float32),
        jnp.asarray(kmin),
        jnp.asarray(lengths, jnp.float32),
        int(capacity),
        len(jobs),
        int(horizon),
    )
    return np.asarray(alloc, np.int64), np.asarray(used, np.int64), np.asarray(work, np.float64)


def solve(
    jobs: list[Job],
    ci: np.ndarray,
    capacity: int,
    horizon: int | None = None,
    backend: str = "numpy",
    max_extensions: int = 8,
    extension_slots: int = 24,
) -> OracleResult:
    """Run Algorithm 1; on infeasibility, extend deadlines of unfinished jobs
    and retry (the paper's fix, §4.2 'Retaining Oracle decisions').

    Retries stop early when no unfinished job's admissible window
    ``[arrival, min(horizon, deadline+1))`` can still grow — once every
    unfinished deadline has hit the horizon, further extensions cannot
    admit a single new (job, slot) entry or make any job newly feasible.
    (They *can* still reshuffle score ties via the deadline tie-break
    key, so on such degenerate windows the returned allocation may
    differ from the pre-break behaviour among equal-score entries; we
    deliberately trade that incidental reordering away, since at
    evaluation scale it made every overloaded window pay the full
    ``max_extensions`` budget for jobs arriving too late to ever finish
    in-window.)"""
    horizon = int(horizon or len(ci))
    jobs = [dataclasses.replace(j) for j in jobs]
    lengths = np.array([j.length for j in jobs])
    extended = np.zeros(len(jobs), dtype=np.int64)
    for attempt in range(max_extensions + 1):
        alloc, used, work = _greedy(jobs, ci, capacity, horizon, lengths, backend)
        unfinished = work < lengths - 1e-6
        if not unfinished.any() or attempt == max_extensions:
            break
        if not any(jobs[idx].deadline + 1 < horizon
                   for idx in np.nonzero(unfinished)[0]):
            break
        for idx in np.nonzero(unfinished)[0]:
            jobs[idx] = dataclasses.replace(jobs[idx], delay=jobs[idx].delay + extension_slots)
            extended[idx] += extension_slots
    feasible = bool((work >= lengths - 1e-6).all())
    schedule = Schedule(alloc=alloc, jobs=jobs, feasible=feasible, extended=extended)
    rho = _rho_curve(jobs, alloc)
    return OracleResult(
        schedule=schedule,
        capacity_curve=used.astype(np.int64),
        rho_curve=rho,
        work_done=work,
    )


def _rho_curve(jobs: list[Job], alloc: np.ndarray) -> np.ndarray:
    """rho_t = lowest marginal throughput among scheduled jobs at t (Table 2).
    1.0 (= p(k_min), the most permissive threshold) when nothing runs.

    Vectorised: one gather from the per-job marginal lookup table and a
    masked column-min — no per-slot Python."""
    n, horizon = alloc.shape
    if n == 0:
        return np.ones(horizon)
    marg = _marginal_table(jobs)                     # (n, K+1)
    vals = np.take_along_axis(marg, np.minimum(alloc, marg.shape[1] - 1), axis=1)
    vals = np.where(alloc > 0, vals, np.inf)
    rho = vals.min(axis=0)
    return np.where(np.isfinite(rho), rho, 1.0)
