"""CarbonFlex offline oracle — Algorithm 1 of the paper.

Greedy carbon-optimal scheduling: enumerate ``(job, slot, scale)`` triples,
score each by marginal throughput per unit carbon ``p_j(k) / CI_t``, sort
descending (ties broken by earliest deadline), and allocate greedily subject
to the cluster capacity ``M``.  Optimal for monotonically decreasing
marginal-throughput profiles on homogeneous clusters (Theorem 4.1, via
Federgruen & Groenevelt's greedy resource-allocation result).

We interpret each list entry *incrementally*: the entry ``(j, t, k)`` raises
job j's allocation in slot t from ``k-1`` to ``k`` (the base entry
``k = k_min`` raises 0 -> k_min).  Because profiles are monotone decreasing,
the sorted order guarantees the ``k-1`` entry is considered before ``k`` for
the same slot, so the greedy pass visits allocations in a consistent order.

Two implementations, tested to agree:

- ``solve_numpy``   — readable reference, plain numpy;
- ``solve_jax``     — the same greedy pass as a ``lax.fori_loop`` jitted
                      scan over the pre-sorted entry arrays (fast path used
                      by the continuous-learning loop).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from .types import Job, Schedule

_EPS = 1e-9


@dataclasses.dataclass
class OracleResult:
    schedule: Schedule
    capacity_curve: np.ndarray       # m_t (decision output, Table 2)
    rho_curve: np.ndarray            # rho_t: lowest scheduled marginal throughput
    work_done: np.ndarray            # per-job completed work


def _build_entries(jobs: list[Job], ci: np.ndarray, horizon: int):
    """Flattened (job, slot, scale) entry arrays, sorted by the greedy key.

    Returns int32/float64 arrays: j_idx, t_idx, k_val, gain (marginal
    throughput), in greedy order (score desc, deadline asc, stable).
    """
    js, ts, ks, gains, scores, deadlines = [], [], [], [], [], []
    for idx, job in enumerate(jobs):
        t0 = max(0, job.arrival)
        t1 = min(horizon, job.deadline + 1)
        if t1 <= t0:
            continue
        trange = np.arange(t0, t1, dtype=np.int64)
        civ = ci[trange]
        for k in range(job.k_min, job.k_max + 1):
            p = job.marginal(k)
            if p <= 0:
                continue
            js.append(np.full(len(trange), idx, dtype=np.int64))
            ts.append(trange)
            ks.append(np.full(len(trange), k, dtype=np.int64))
            gains.append(np.full(len(trange), p))
            scores.append(p / civ)
            deadlines.append(np.full(len(trange), job.deadline, dtype=np.int64))
    if not js:
        z = np.zeros(0, dtype=np.int64)
        return z, z, z, np.zeros(0), np.zeros(0)
    j_idx = np.concatenate(js)
    t_idx = np.concatenate(ts)
    k_val = np.concatenate(ks)
    gain = np.concatenate(gains)
    score = np.concatenate(scores)
    deadline = np.concatenate(deadlines)
    # Sort: score desc, then deadline asc (earliest-deadline tie-break, line 6).
    order = np.lexsort((deadline, -score))
    return j_idx[order], t_idx[order], k_val[order], gain[order], score[order]


def _greedy_numpy(jobs, ci, capacity, horizon, lengths, k_extra):
    j_idx, t_idx, k_val, gain, _ = _build_entries(jobs, ci, horizon)
    n = len(jobs)
    alloc = np.zeros((n, horizon), dtype=np.int64)
    used = np.zeros(horizon, dtype=np.int64)
    work = np.zeros(n)
    kmin = np.array([j.k_min for j in jobs], dtype=np.int64)
    for i in range(len(j_idx)):
        j, t, k, g = j_idx[i], t_idx[i], k_val[i], gain[i]
        if work[j] >= lengths[j] - _EPS:
            continue  # line 11: job already done
        prev = alloc[j, t]
        need_prev = kmin[j] if k == kmin[j] else k  # base entry adds k_min servers
        add = kmin[j] if k == kmin[j] else 1
        if (k == kmin[j] and prev != 0) or (k != kmin[j] and prev != k - 1):
            continue  # incremental consistency
        if used[t] + add > capacity:
            continue  # line 9: capacity exceeded
        alloc[j, t] = k
        used[t] += add
        work[j] += g if k != kmin[j] else 1.0  # base throughput p(k_min)=1
    return alloc, used, work


@partial(jax.jit, static_argnames=("capacity", "n", "horizon"))
def _greedy_jax(j_idx, t_idx, k_val, gain, kmin, lengths, capacity, n, horizon):
    """The same greedy pass as a fori_loop over pre-sorted entries."""

    def body(i, state):
        alloc, used, work = state
        j, t, k, g = j_idx[i], t_idx[i], k_val[i], gain[i]
        prev = alloc[j, t]
        is_base = k == kmin[j]
        add = jnp.where(is_base, kmin[j], 1)
        consistent = jnp.where(is_base, prev == 0, prev == k - 1)
        ok = (
            (work[j] < lengths[j] - _EPS)
            & consistent
            & (used[t] + add <= capacity)
        )
        gain_i = jnp.where(is_base, 1.0, g)
        alloc = alloc.at[j, t].set(jnp.where(ok, k, prev))
        used = used.at[t].add(jnp.where(ok, add, 0))
        work = work.at[j].add(jnp.where(ok, gain_i, 0.0))
        return alloc, used, work

    alloc0 = jnp.zeros((n, horizon), dtype=jnp.int32)
    used0 = jnp.zeros(horizon, dtype=jnp.int32)
    work0 = jnp.zeros(n, dtype=jnp.float32)
    return jax.lax.fori_loop(0, len(j_idx), body, (alloc0, used0, work0))


def _greedy(jobs, ci, capacity, horizon, lengths, backend):
    if backend == "numpy":
        return _greedy_numpy(jobs, ci, capacity, horizon, lengths, None)
    j_idx, t_idx, k_val, gain, _ = _build_entries(jobs, ci, horizon)
    kmin = np.array([j.k_min for j in jobs], dtype=np.int32)
    if len(j_idx) == 0:
        n = len(jobs)
        return (np.zeros((n, horizon), np.int64), np.zeros(horizon, np.int64), np.zeros(n))
    alloc, used, work = _greedy_jax(
        jnp.asarray(j_idx, jnp.int32),
        jnp.asarray(t_idx, jnp.int32),
        jnp.asarray(k_val, jnp.int32),
        jnp.asarray(gain, jnp.float32),
        jnp.asarray(kmin),
        jnp.asarray(lengths, jnp.float32),
        int(capacity),
        len(jobs),
        int(horizon),
    )
    return np.asarray(alloc, np.int64), np.asarray(used, np.int64), np.asarray(work, np.float64)


def solve(
    jobs: list[Job],
    ci: np.ndarray,
    capacity: int,
    horizon: int | None = None,
    backend: str = "jax",
    max_extensions: int = 8,
    extension_slots: int = 24,
) -> OracleResult:
    """Run Algorithm 1; on infeasibility, extend deadlines of unfinished jobs
    and retry (the paper's fix, §4.2 'Retaining Oracle decisions')."""
    horizon = int(horizon or len(ci))
    jobs = [dataclasses.replace(j) for j in jobs]
    lengths = np.array([j.length for j in jobs])
    extended = np.zeros(len(jobs), dtype=np.int64)
    for attempt in range(max_extensions + 1):
        alloc, used, work = _greedy(jobs, ci, capacity, horizon, lengths, backend)
        unfinished = work < lengths - 1e-6
        if not unfinished.any() or attempt == max_extensions:
            break
        for idx in np.nonzero(unfinished)[0]:
            jobs[idx] = dataclasses.replace(jobs[idx], delay=jobs[idx].delay + extension_slots)
            extended[idx] += extension_slots
    feasible = bool((work >= lengths - 1e-6).all())
    schedule = Schedule(alloc=alloc, jobs=jobs, feasible=feasible, extended=extended)
    rho = _rho_curve(jobs, alloc)
    return OracleResult(
        schedule=schedule,
        capacity_curve=used.astype(np.int64),
        rho_curve=rho,
        work_done=work,
    )


def _rho_curve(jobs: list[Job], alloc: np.ndarray) -> np.ndarray:
    """rho_t = lowest marginal throughput among scheduled jobs at t (Table 2).
    1.0 (= p(k_min), the most permissive threshold) when nothing runs."""
    horizon = alloc.shape[1]
    rho = np.ones(horizon)
    for t in range(horizon):
        ks = alloc[:, t]
        marginals = [jobs[j].marginal(int(ks[j])) for j in np.nonzero(ks)[0]]
        if marginals:
            rho[t] = min(marginals)
    return rho
