"""Core datatypes for the CarbonFlex cluster resource manager.

The unit model follows Section 3 of the paper:

- time is discretised into slots (1 hour in the paper, configurable);
- a *job* j arrives at slot ``a_j``, carries ``l_j`` slots of work measured
  at its base scale ``k_min`` (throughput at ``k_min`` is normalised to 1),
  and is submitted to a queue with slack ``d_i`` slots;
- allocating ``k`` servers to job j during one slot advances its progress by
  ``throughput(k) = sum_{i<=k} p_j(i)`` where ``p_j`` is the (monotone
  decreasing) marginal-throughput profile with ``p_j(k_min) = 1``.

"Server" is the abstract resource unit; in the TPU mapping of this repo a
server is one data-parallel slice (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class QueueConfig:
    """A submission queue with a slack (maximum tolerated delay), in slots."""

    name: str
    delay: int                     # d_i: max waiting/paused slots
    max_length: float = np.inf     # jobs with l_j <= max_length go here


# The paper's default queue setup (Section 6.1): short<=2h -> 6h slack,
# medium<=12h -> 24h, long -> 48h.
def default_queues(scale: float = 1.0) -> list[QueueConfig]:
    return [
        QueueConfig("short", delay=max(1, int(6 * scale)), max_length=2),
        QueueConfig("medium", delay=max(1, int(24 * scale)), max_length=12),
        QueueConfig("long", delay=max(1, int(48 * scale)), max_length=np.inf),
    ]


@dataclasses.dataclass
class Job:
    """An elastic batch job (Section 3)."""

    job_id: int
    arrival: int                   # a_j, slot index
    length: float                  # l_j, slots of work at scale k_min
    queue: int                     # index into the cluster's queue list
    delay: int                     # d_j, slack in slots (from the queue)
    profile: np.ndarray            # marginal throughput, profile[i] = p(k_min + i)
    k_min: int = 1
    # Per-server-slot energy in kWh (E^R of Eq. 2) and per-slot network
    # traffic at scale k in GB (feeds E^net = eta_net * Mem, Eq. 3).
    power: float = 1.0
    comm_size: float = 0.0
    arch: str = "generic"          # which assigned architecture this job trains

    @property
    def k_max(self) -> int:
        return self.k_min + len(self.profile) - 1

    @property
    def deadline(self) -> int:
        """Latest slot (exclusive) by which the job must finish."""
        return int(self.arrival + int(np.ceil(self.length)) + self.delay)

    def throughput(self, k: int) -> float:
        """Cumulative normalised throughput at scale k."""
        if k <= 0:
            return 0.0
        k = min(k, self.k_max)
        return float(np.sum(self.profile[: k - self.k_min + 1]))

    def marginal(self, k: int) -> float:
        """Marginal throughput p_j(k) of the k-th server."""
        if k < self.k_min or k > self.k_max:
            return 0.0
        return float(self.profile[k - self.k_min])

    def elasticity(self) -> float:
        """Scalar elasticity summary used in the Table-2 state (mean marginal
        throughput over the profile — 1.0 means perfectly linear scaling)."""
        return float(np.mean(self.profile))


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Cluster-level configuration (Section 3)."""

    capacity: int                          # M: max concurrently usable servers
    queues: tuple[QueueConfig, ...]
    slot_hours: float = 1.0
    power_per_server: float = 1.0          # kW per server (CPU-cluster mode)
    eta_net: float = 0.1                   # W/Gbps network energy (Section 5)

    @staticmethod
    def default(capacity: int = 150) -> "ClusterConfig":
        return ClusterConfig(capacity=capacity, queues=tuple(default_queues()))


@dataclasses.dataclass
class Schedule:
    """A full allocation matrix produced by the oracle: alloc[j, t] servers."""

    alloc: np.ndarray              # (num_jobs, T) int
    jobs: list[Job]
    feasible: bool
    extended: np.ndarray           # per-job extra slots granted (paper §4.2 fix)

    def capacity_curve(self) -> np.ndarray:
        return self.alloc.sum(axis=0)

    def completion_slots(self) -> np.ndarray:
        """First slot (inclusive) at which each job's work is done."""
        out = np.full(len(self.jobs), -1, dtype=np.int64)
        for idx, job in enumerate(self.jobs):
            work = 0.0
            for t in range(self.alloc.shape[1]):
                k = int(self.alloc[idx, t])
                if k > 0:
                    work += job.throughput(k)
                    if work >= job.length - 1e-9:
                        out[idx] = t
                        break
        return out


@dataclasses.dataclass
class SlotLog:
    """Per-slot accounting emitted by the simulator."""

    slot: int
    ci: float                       # g CO2 / kWh
    provisioned: int                # m_t
    used: int                       # sum of allocations
    energy_kwh: float
    carbon_g: float
    running: int
    queued: int


@dataclasses.dataclass
class SimResult:
    """Aggregate result of one simulated window under one policy."""

    policy: str
    carbon_g: float
    energy_kwh: float
    slots: list[SlotLog]
    wait_slots: np.ndarray          # per-job waiting time (first-run delay + pauses)
    violations: np.ndarray          # per-job bool: finished after deadline
    completion: np.ndarray          # per-job completion slot (-1 = unfinished)
    num_jobs: int

    @property
    def mean_wait(self) -> float:
        return float(np.mean(self.wait_slots)) if len(self.wait_slots) else 0.0

    @property
    def violation_rate(self) -> float:
        return float(np.mean(self.violations)) if len(self.violations) else 0.0

    def savings_vs(self, baseline: "SimResult") -> float:
        """Carbon savings (%) relative to a baseline run."""
        if baseline.carbon_g <= 0:
            return 0.0
        return 100.0 * (1.0 - self.carbon_g / baseline.carbon_g)

    def to_dict(self, include_per_job: bool = False,
                include_slots: bool = False) -> dict:
        """JSON-serialisable summary (sweep rows, benchmark caches).

        Aggregates only by default; ``include_per_job`` adds the per-job
        wait/violation/completion arrays, ``include_slots`` the full
        per-slot log."""
        d = {
            "policy": self.policy,
            "carbon_g": float(self.carbon_g),
            "energy_kwh": float(self.energy_kwh),
            "num_jobs": int(self.num_jobs),
            "mean_wait": self.mean_wait,
            "violation_rate": self.violation_rate,
        }
        if include_per_job:
            d["wait_slots"] = np.asarray(self.wait_slots, dtype=float).tolist()
            d["violations"] = np.asarray(self.violations, dtype=bool).tolist()
            d["completion"] = np.asarray(self.completion, dtype=np.int64).tolist()
        if include_slots:
            d["slots"] = [dataclasses.asdict(s) for s in self.slots]
        return d
