"""Core datatypes for the CarbonFlex cluster resource manager.

The unit model follows Section 3 of the paper:

- time is discretised into slots (1 hour in the paper, configurable);
- a *job* j arrives at slot ``a_j``, carries ``l_j`` slots of work measured
  at its base scale ``k_min`` (throughput at ``k_min`` is normalised to 1),
  and is submitted to a queue with slack ``d_i`` slots;
- allocating ``k`` servers to job j during one slot advances its progress by
  ``throughput(k) = sum_{i<=k} p_j(i)`` where ``p_j`` is the (monotone
  decreasing) marginal-throughput profile with ``p_j(k_min) = 1``.

"Server" is the abstract resource unit; in the TPU mapping of this repo a
server is one data-parallel slice (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class QueueConfig:
    """A submission queue with a slack (maximum tolerated delay), in slots."""

    name: str
    delay: int                     # d_i: max waiting/paused slots
    max_length: float = np.inf     # jobs with l_j <= max_length go here


# The paper's default queue setup (Section 6.1): short<=2h -> 6h slack,
# medium<=12h -> 24h, long -> 48h.
def default_queues(scale: float = 1.0) -> list[QueueConfig]:
    return [
        QueueConfig("short", delay=max(1, int(6 * scale)), max_length=2),
        QueueConfig("medium", delay=max(1, int(24 * scale)), max_length=12),
        QueueConfig("long", delay=max(1, int(48 * scale)), max_length=np.inf),
    ]


@dataclasses.dataclass
class Job:
    """An elastic batch job (Section 3), optionally one task of a DAG.

    ``deps`` lists the ``job_id`` s of predecessor tasks in the same
    submitted job list: the engines gate this job until every predecessor
    has completed (see ``core/dag.py`` for the DAG model and the
    precedence-aware policies).  Independent jobs leave it empty.  While
    gated the job is invisible to the policy, burns no waiting budget, and
    its slack/deadline count from its *release* slot instead of arrival."""

    job_id: int
    arrival: int                   # a_j, slot index
    length: float                  # l_j, slots of work at scale k_min
    queue: int                     # index into the cluster's queue list
    delay: int                     # d_j, slack in slots (from the queue)
    profile: np.ndarray            # marginal throughput, profile[i] = p(k_min + i)
    k_min: int = 1
    # Per-server-slot energy in kWh (E^R of Eq. 2) and per-slot network
    # traffic at scale k in GB (feeds E^net = eta_net * Mem, Eq. 3).
    power: float = 1.0
    comm_size: float = 0.0
    arch: str = "generic"          # which assigned architecture this job trains
    deps: tuple[int, ...] = ()     # predecessor job_ids (precedence gating)

    @property
    def k_max(self) -> int:
        return self.k_min + len(self.profile) - 1

    @property
    def deadline(self) -> int:
        """Latest slot (exclusive) by which the job must finish."""
        return int(self.arrival + int(np.ceil(self.length)) + self.delay)

    def throughput(self, k: int) -> float:
        """Cumulative normalised throughput at scale k."""
        if k <= 0:
            return 0.0
        k = min(k, self.k_max)
        return float(np.sum(self.profile[: k - self.k_min + 1]))

    def marginal(self, k: int) -> float:
        """Marginal throughput p_j(k) of the k-th server."""
        if k < self.k_min or k > self.k_max:
            return 0.0
        return float(self.profile[k - self.k_min])

    def elasticity(self) -> float:
        """Scalar elasticity summary used in the Table-2 state (mean marginal
        throughput over the profile — 1.0 means perfectly linear scaling)."""
        return float(np.mean(self.profile))


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Cluster-level configuration (Section 3)."""

    capacity: int                          # M: max concurrently usable servers
    queues: tuple[QueueConfig, ...]
    slot_hours: float = 1.0
    power_per_server: float = 1.0          # kW per server (CPU-cluster mode)
    eta_net: float = 0.1                   # W/Gbps network energy (Section 5)

    @staticmethod
    def default(capacity: int = 150) -> "ClusterConfig":
        return ClusterConfig(capacity=capacity, queues=tuple(default_queues()))


@dataclasses.dataclass(frozen=True)
class MigrationModel:
    """Cost of moving a running job between regions (checkpoint + WAN
    transfer + restore).

    A migration suspends the job for ``slots(job)`` slots — a fixed
    checkpoint/restore overhead plus a term scaling with the job's size
    (bigger jobs have more state to serialise) — during which the job
    burns waiting budget like any paused job.  It also charges a one-off
    transfer energy proportional to the job's state size (``comm_size``
    stands in for the checkpoint payload, floored at ``min_gb``), billed
    at the *destination* region's CI on the initiation slot (restore-side
    accounting)."""

    base_slots: int = 1                # fixed checkpoint+restore slots
    slots_per_length: float = 0.02     # extra suspended slots per slot of work
    energy_kwh_per_gb: float = 0.05    # WAN transfer + restore energy
    min_gb: float = 1.0                # checkpoint payload floor

    def slots(self, job: "Job") -> int:
        return int(self.base_slots + np.ceil(self.slots_per_length * job.length))

    def data_gb(self, job: "Job") -> float:
        return float(max(self.min_gb, job.comm_size))

    def energy_kwh(self, job: "Job") -> float:
        return self.energy_kwh_per_gb * self.data_gb(job)

    def carbon_g(self, job: "Job", ci_dest: float) -> float:
        """Estimated migration carbon when the destination runs at
        ``ci_dest`` (the break-even input of the geo-flex trigger)."""
        return self.energy_kwh(job) * ci_dest


@dataclasses.dataclass(frozen=True)
class GeoCluster:
    """A geo-distributed cluster: per-region capacities over aligned CI
    traces, with a migration cost model (Section 3 generalised in space).

    The scalar knobs (``slot_hours``, ``power_per_server``, ``eta_net``)
    are shared across regions — regions differ in carbon intensity and
    capacity, not hardware — so the energy model (Eq. 2-3) applies
    unchanged per region."""

    regions: tuple[str, ...]
    capacities: tuple[int, ...]
    queues: tuple[QueueConfig, ...]
    migration: MigrationModel = MigrationModel()
    slot_hours: float = 1.0
    power_per_server: float = 1.0
    eta_net: float = 0.1

    def __post_init__(self) -> None:
        if len(self.regions) != len(self.capacities):
            raise ValueError("regions and capacities must align")
        if not self.regions:
            raise ValueError("GeoCluster needs >= 1 region")
        if any(c <= 0 for c in self.capacities):
            raise ValueError(f"capacities must be positive: {self.capacities}")

    @property
    def n_regions(self) -> int:
        return len(self.regions)

    @property
    def capacity(self) -> int:
        """Total capacity across regions (M of the aggregate cluster)."""
        return int(sum(self.capacities))

    def capacity_vec(self) -> np.ndarray:
        return np.array(self.capacities, dtype=np.int64)

    def home_region(self, row: int) -> int:
        """Arrival region of the job at (arrival, job_id)-sorted row
        ``row``: deterministic round-robin, so every region sees a
        balanced submission stream."""
        return row % self.n_regions

    def region_cluster(self, r: int) -> ClusterConfig:
        """Single-region view (capacity of region ``r``, shared queues)."""
        return ClusterConfig(capacity=self.capacities[r], queues=self.queues,
                             slot_hours=self.slot_hours,
                             power_per_server=self.power_per_server,
                             eta_net=self.eta_net)

    @staticmethod
    def split(capacity: int, regions: Sequence[str],
              queues: tuple[QueueConfig, ...] | None = None,
              migration: MigrationModel | None = None,
              **kw) -> "GeoCluster":
        """Split a total capacity evenly across ``regions`` (remainder to
        the first regions), the Scenario default."""
        n = len(regions)
        if n == 0:
            raise ValueError("GeoCluster.split needs >= 1 region")
        base, rem = divmod(int(capacity), n)
        caps = tuple(base + (1 if i < rem else 0) for i in range(n))
        return GeoCluster(regions=tuple(regions), capacities=caps,
                          queues=queues if queues is not None
                          else tuple(default_queues()),
                          migration=migration or MigrationModel(), **kw)


@dataclasses.dataclass
class Schedule:
    """A full allocation matrix produced by the oracle: alloc[j, t] servers."""

    alloc: np.ndarray              # (num_jobs, T) int
    jobs: list[Job]
    feasible: bool
    extended: np.ndarray           # per-job extra slots granted (paper §4.2 fix)

    def capacity_curve(self) -> np.ndarray:
        return self.alloc.sum(axis=0)

    def completion_slots(self) -> np.ndarray:
        """First slot (inclusive) at which each job's work is done."""
        out = np.full(len(self.jobs), -1, dtype=np.int64)
        for idx, job in enumerate(self.jobs):
            work = 0.0
            for t in range(self.alloc.shape[1]):
                k = int(self.alloc[idx, t])
                if k > 0:
                    work += job.throughput(k)
                    if work >= job.length - 1e-9:
                        out[idx] = t
                        break
        return out


@dataclasses.dataclass
class SlotLog:
    """Per-slot accounting emitted by the simulator."""

    slot: int
    ci: float                       # g CO2 / kWh
    provisioned: int                # m_t
    used: int                       # sum of allocations
    energy_kwh: float
    carbon_g: float
    running: int
    queued: int


@dataclasses.dataclass(frozen=True)
class ResilienceMetrics:
    """Recovery accounting of one simulated window (``core/faults.py``).

    ``lost_work_slots`` counts progress destroyed by faults (evicted /
    failed slots plus checkpoint rollbacks), in base-scale work slots.
    ``mttr_slots`` is the mean duration of *recovered* capacity outages;
    ``degraded_slots`` the slots the policy stack ran on a stale carbon
    feed (:class:`~repro.core.faults.DegradedCIView`)."""

    evictions: int = 0
    preemptions: int = 0
    lost_work_slots: float = 0.0
    restore_energy_kwh: float = 0.0
    capacity_outages: int = 0
    mttr_slots: float = 0.0
    degraded_slots: int = 0

    def to_dict(self) -> dict:
        return {
            "evictions": int(self.evictions),
            "preemptions": int(self.preemptions),
            "lost_work_slots": float(self.lost_work_slots),
            "restore_energy_kwh": float(self.restore_energy_kwh),
            "capacity_outages": int(self.capacity_outages),
            "mttr_slots": float(self.mttr_slots),
            "degraded_slots": int(self.degraded_slots),
        }


@dataclasses.dataclass
class ServingMetrics:
    """Request-serving accounting of one simulated window
    (``serving/engine.py``) — the interactive-traffic counterpart of the
    per-job arrays, which stay empty on serving runs.

    Lives here (like :class:`ResilienceMetrics`) so :class:`SimResult`
    never imports the serving package.  The trajectory arrays
    (``balance`` / ``utilization`` / ``quality`` / ``violation_frac``,
    one entry per slot) are in-memory extras for figures and tests and
    are dropped by ``to_dict``."""

    requests: float = 0.0
    violated_requests: float = 0.0        # SLO-violating requests
    quality_mean: float = 1.0             # request-weighted quality
    ledger_final: float = 0.0
    ledger_min: float = 0.0
    ledger_max: float = 0.0
    tier_names: tuple[str, ...] = ()
    tier_requests: tuple[float, ...] = ()
    balance: np.ndarray | None = None
    utilization: np.ndarray | None = None
    quality: np.ndarray | None = None
    violation_frac: np.ndarray | None = None
    energy: np.ndarray | None = None      # per-slot kWh (telemetry)
    carbon: np.ndarray | None = None      # per-slot gCO2 at true CI

    @property
    def violation_rate(self) -> float:
        """Fraction of requests that blew the latency SLO."""
        if self.requests <= 0:
            return 0.0
        return float(self.violated_requests / self.requests)

    def to_dict(self) -> dict:
        return {
            "requests": float(self.requests),
            "violated_requests": float(self.violated_requests),
            "violation_rate": self.violation_rate,
            "quality_mean": float(self.quality_mean),
            "ledger_final": float(self.ledger_final),
            "ledger_min": float(self.ledger_min),
            "ledger_max": float(self.ledger_max),
            "tier_names": list(self.tier_names),
            "tier_requests": [float(x) for x in self.tier_requests],
        }


@dataclasses.dataclass
class SimResult:
    """Aggregate result of one simulated window under one policy."""

    policy: str
    carbon_g: float
    energy_kwh: float
    slots: list[SlotLog]
    wait_slots: np.ndarray          # per-job waiting time (first-run delay + pauses)
    violations: np.ndarray          # per-job bool: finished after deadline
    completion: np.ndarray          # per-job completion slot (-1 = unfinished)
    num_jobs: int
    # Geo-engine extras (None/zero for single-region runs).  Migration
    # carbon is included in carbon_g and attributed to the destination
    # region in region_carbon_g; migration_carbon_g breaks it out.
    regions: tuple[str, ...] | None = None
    region_carbon_g: np.ndarray | None = None
    region_energy_kwh: np.ndarray | None = None
    final_region: np.ndarray | None = None   # per-job region at completion
    migrations: int = 0
    migration_carbon_g: float = 0.0
    # Recovery metrics (core/faults.py); None on fault-free, fresh-feed
    # runs so pre-resilience payloads (and golden fixtures) are unchanged.
    resilience: ResilienceMetrics | None = None
    # Serving metrics (serving/engine.py); None on batch runs so batch
    # payloads (and golden fixtures) are unchanged.  On serving runs the
    # per-job arrays are empty and violation_rate is request-weighted.
    serving: ServingMetrics | None = None

    @property
    def mean_wait(self) -> float:
        return float(np.mean(self.wait_slots)) if len(self.wait_slots) else 0.0

    @property
    def violation_rate(self) -> float:
        if self.serving is not None:
            return self.serving.violation_rate
        return float(np.mean(self.violations)) if len(self.violations) else 0.0

    def savings_vs(self, baseline: "SimResult") -> float:
        """Carbon savings (%) relative to a baseline run."""
        if baseline.carbon_g <= 0:
            return 0.0
        return 100.0 * (1.0 - self.carbon_g / baseline.carbon_g)

    def to_dict(self, include_per_job: bool = False,
                include_slots: bool = False) -> dict:
        """JSON-serialisable summary (sweep rows, benchmark caches).

        Aggregates only by default; ``include_per_job`` adds the per-job
        wait/violation/completion arrays, ``include_slots`` the full
        per-slot log."""
        d = {
            "policy": self.policy,
            "carbon_g": float(self.carbon_g),
            "energy_kwh": float(self.energy_kwh),
            "num_jobs": int(self.num_jobs),
            "mean_wait": self.mean_wait,
            "violation_rate": self.violation_rate,
        }
        if self.regions is not None:
            d["regions"] = list(self.regions)
            d["region_carbon_g"] = np.asarray(
                self.region_carbon_g, dtype=float).tolist()
            d["region_energy_kwh"] = np.asarray(
                self.region_energy_kwh, dtype=float).tolist()
            d["migrations"] = int(self.migrations)
            d["migration_carbon_g"] = float(self.migration_carbon_g)
        if self.resilience is not None:
            d["resilience"] = self.resilience.to_dict()
        if self.serving is not None:
            d["serving"] = self.serving.to_dict()
        if include_per_job:
            d["wait_slots"] = np.asarray(self.wait_slots, dtype=float).tolist()
            d["violations"] = np.asarray(self.violations, dtype=bool).tolist()
            d["completion"] = np.asarray(self.completion, dtype=np.int64).tolist()
            if self.regions is not None:
                d["final_region"] = np.asarray(self.final_region,
                                               dtype=np.int64).tolist()
        if include_slots:
            d["slots"] = [dataclasses.asdict(s) for s in self.slots]
        return d
