"""Operational energy & carbon accounting (paper §5, Eq. 1–3).

    C_t     = sum_j E_js * CI_t                                   (1)
    E_js    = E^R_js + E^net_js                                   (2)
    E^net_js = eta_net * Mem_js                                   (3)

``E^R`` is compute energy: servers x per-server power x slot length.  The
CPU-cluster mode uses a fixed per-server power (the paper's carbon-
accounting convention); the GPU/TPU mode uses per-job heterogeneous power
(the paper measures nvidia-smi; we carry an analytic per-arch power derived
from roofline utilisation — DESIGN.md §2).  ``Mem_js`` is the data moved by
the job at scale s during the slot; for ring-all-reduce DP training that is
``2 (k-1)/k * model_bytes * steps_per_slot`` — we fold this into the job's
``comm_size`` (GB per server-slot at base scale) scaled by the ring factor.
"""
from __future__ import annotations

from .types import ClusterConfig, Job


def slot_energy_kwh(job: Job, k: int, cluster: ClusterConfig, frac: float = 1.0) -> float:
    """Energy of running ``job`` at scale ``k`` for ``frac`` of one slot."""
    if k <= 0 or frac <= 0:
        return 0.0
    power = job.power if job.power > 0 else cluster.power_per_server
    e_compute = k * power * cluster.slot_hours * frac
    # Ring all-reduce traffic grows as 2(k-1)/k of the payload per step;
    # comm_size is GB transferred per server-slot at base scale.
    ring = 0.0 if k <= 1 else 2.0 * (k - 1) / k
    gbits = job.comm_size * 8.0 * ring * k * frac
    # eta_net is W/Gbps; energy = eta * (Gbit / 3600s) ... expressed per slot:
    e_net_kwh = cluster.eta_net * gbits / 3600.0 / 1000.0 * cluster.slot_hours
    return e_compute + e_net_kwh


def slot_carbon_g(energy_kwh: float, ci_g_per_kwh: float) -> float:
    return energy_kwh * ci_g_per_kwh
