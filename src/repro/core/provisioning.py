"""CarbonFlex runtime provisioning — Algorithm 2 (phi).

Given the current Table-2 state, query the knowledge base for the top-k
closest historical states and mimic the oracle's capacity choice:

- normal case: provision the mean matched capacity;
- recent delay violations above the tolerance ``epsilon``: be conservative,
  provision the max of the matches and the current capacity;
- violations *and* poor match quality (distance above ``delta``): fall back
  to carbon-agnostic provisioning (the full capacity ``M``).

The same query also yields the scheduling threshold ``rho`` consumed by
Algorithm 3, so ``provision`` returns both.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .knowledge import KnowledgeBase


@dataclasses.dataclass
class ProvisioningConfig:
    delta: float = 2.0        # max acceptable match distance (z-scored units)
    epsilon: float = 0.05     # tolerated recent delay-violation rate
    k: int = 5


def provision(
    state: np.ndarray,
    kb: KnowledgeBase,
    capacity: int,
    current_m: int,
    violation_rate: float,
    cfg: ProvisioningConfig = ProvisioningConfig(),
    min_required: int = 0,
) -> tuple[int, float]:
    """Returns (m_t, rho).  ``min_required`` lower-bounds the capacity with
    the servers needed by jobs whose slack is exhausted (run-to-completion
    guarantee, §6.1) — the provisioning never starves forced jobs."""
    m_vals, rho_vals, dist = kb.query(state, k=cfg.k)
    w = 1.0 / np.maximum(dist, 1e-6)
    w = w / w.sum()
    if float(np.min(dist)) > cfg.delta and violation_rate > cfg.epsilon:
        m = capacity                                  # line 3: fall back to M
        rho = 1.0
    elif violation_rate > cfg.epsilon:
        m = int(max(np.max(m_vals), current_m))       # line 5
        rho = float(np.min(rho_vals))
    else:
        m = int(round(float(np.sum(w * m_vals))))     # line 6 (dist-weighted)
        rho = float(np.sum(w * rho_vals))
    m = int(np.clip(max(m, min_required), 0, capacity))
    return m, rho
