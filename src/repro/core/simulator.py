"""CarbonFlex-Simulator: slot-level cluster engine (paper §5, §6).

Discrete-time simulation of a cloud cluster running elastic batch jobs
under a pluggable provisioning+scheduling policy.  Per slot:

  1. admit arrivals into the active set;
  2. ask the policy for ``(m_t, allocations)``;
  3. enforce the capacity invariant (sum of allocations <= min(m_t, M));
  4. advance job progress / waiting budgets;
  5. account energy (Eq. 2–3) and carbon (Eq. 1);
  6. record completions, waiting times and SLO violations.

The engine runs past the nominal window until all admitted jobs finish
(run-to-completion semantics shared by every policy in §6).
"""
from __future__ import annotations

import dataclasses
from typing import Protocol

import numpy as np

from . import emissions
from .carbon import CarbonService
from .scheduling import ActiveJob, apply_slot
from .types import ClusterConfig, Job, SimResult, SlotLog


@dataclasses.dataclass
class FaultModel:
    """Cluster-level fault/straggler injection (DESIGN.md §10).

    Each slot, every job independently suffers a *straggler* event with
    probability ``straggler_rate`` (progress that slot scaled by
    ``straggler_slowdown`` — a slow host in the allocation), or a *failure*
    with probability ``failure_rate`` (the slot's progress is lost entirely:
    the job restarts the slot from its last checkpoint).  Seeded and
    deterministic.  CarbonFlex's Algorithm-2 violation feedback is the
    compensating control loop — see tests/test_faults.py."""

    straggler_rate: float = 0.0
    straggler_slowdown: float = 0.5
    failure_rate: float = 0.0
    seed: int = 0

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    def progress_factor(self, t: int, job_id: int) -> float:
        u = self._rng.random()
        if u < self.failure_rate:
            return 0.0
        if u < self.failure_rate + self.straggler_rate:
            return self.straggler_slowdown
        return 1.0


class Policy(Protocol):
    name: str

    def on_window_start(self, ci: CarbonService, t0: int, horizon: int,
                        jobs: list[Job], cluster: ClusterConfig) -> None: ...

    def decide(self, t: int, active: list[ActiveJob], ci: CarbonService,
               cluster: ClusterConfig) -> tuple[int, dict[int, int]]: ...

    def on_completion(self, t: int, job: ActiveJob, violated: bool) -> None: ...


def simulate(
    jobs: list[Job],
    ci: CarbonService,
    cluster: ClusterConfig,
    policy: Policy,
    t0: int = 0,
    horizon: int | None = None,
    max_overrun: int = 24 * 21,
    faults: FaultModel | None = None,
) -> SimResult:
    horizon = int(horizon if horizon is not None else len(ci) - t0)
    jobs = sorted(jobs, key=lambda j: (j.arrival, j.job_id))
    policy.on_window_start(ci, t0, horizon, jobs, cluster)

    active: list[ActiveJob] = []
    pending = list(jobs)
    n = len(jobs)
    wait = np.zeros(n)
    violations = np.zeros(n, dtype=bool)
    completion = np.full(n, -1, dtype=np.int64)
    id2row = {j.job_id: i for i, j in enumerate(jobs)}

    logs: list[SlotLog] = []
    total_energy = 0.0
    total_carbon = 0.0
    t = t0
    t_end = t0 + horizon
    while t < t_end + max_overrun:
        while pending and pending[0].arrival <= t:
            j = pending.pop(0)
            active.append(ActiveJob(job=j, remaining=j.length, slack_left=j.delay))
        if not active and not pending and t >= t_end:
            break

        m_t, alloc = policy.decide(t, active, ci, cluster)
        m_t = int(min(m_t, cluster.capacity))
        alloc = _enforce_capacity(alloc, active, m_t)

        civ = ci.ci(t)
        energy = 0.0
        for a in active:
            k = alloc.get(a.job.job_id, 0)
            if k > 0:
                # Fractional final slot (paper footnote 4): only the work
                # actually needed is charged.
                frac = min(1.0, a.remaining / max(a.job.throughput(k), 1e-9))
                energy += emissions.slot_energy_kwh(a.job, k, cluster, frac)
        carbon = emissions.slot_carbon_g(energy, civ)
        total_energy += energy
        total_carbon += carbon

        if faults is None:
            apply_slot(active, alloc)
        else:
            # degraded slots: scale each allocated job's progress; energy
            # was already charged (a slow/failed host still burns power)
            for a in active:
                if a.done:
                    continue
                k = alloc.get(a.job.job_id, 0)
                if k > 0:
                    f = faults.progress_factor(t, a.job.job_id)
                    a.remaining -= a.job.throughput(k) * f
                    a.started = True
                else:
                    a.slack_left -= 1
                    a.waited += 1

        finished = [a for a in active if a.done]
        for a in finished:
            row = id2row[a.job.job_id]
            completion[row] = t
            wait[row] = a.waited
            violations[row] = t > a.job.deadline
            policy.on_completion(t, a, bool(violations[row]))
        active = [a for a in active if not a.done]

        used = sum(alloc.values())
        logs.append(SlotLog(slot=t, ci=civ, provisioned=m_t, used=used,
                            energy_kwh=energy, carbon_g=carbon,
                            running=len(alloc), queued=len(active) - len(alloc)))
        t += 1

    return SimResult(
        policy=policy.name,
        carbon_g=total_carbon,
        energy_kwh=total_energy,
        slots=logs,
        wait_slots=wait,
        violations=violations,
        completion=completion,
        num_jobs=n,
    )


def _enforce_capacity(alloc: dict[int, int], active: list[ActiveJob], m_t: int) -> dict[int, int]:
    """Capacity invariant: trim allocations (lowest marginal first) to m_t."""
    by_id = {a.job.job_id: a for a in active}
    alloc = {jid: int(k) for jid, k in alloc.items()
             if jid in by_id and k > 0}
    for jid in list(alloc):
        a = by_id[jid]
        alloc[jid] = int(np.clip(alloc[jid], a.job.k_min, a.job.k_max))
    total = sum(alloc.values())
    if total <= m_t:
        return alloc
    # Shed the least carbon-efficient increments first.
    incs = []
    for jid, k in alloc.items():
        a = by_id[jid]
        for kk in range(a.job.k_min + 1, k + 1):
            incs.append((a.job.marginal(kk), jid, kk))
    incs.sort()                      # lowest marginal first
    for p, jid, kk in incs:
        if total <= m_t:
            break
        if alloc.get(jid, 0) == kk:
            alloc[jid] = kk - 1
            total -= 1
    # Still above capacity: drop whole base allocations, latest-slack first.
    if total > m_t:
        order = sorted(alloc, key=lambda jid: -by_id[jid].slack_left)
        for jid in order:
            if total <= m_t:
                break
            total -= alloc[jid]
            del alloc[jid]
    return alloc
