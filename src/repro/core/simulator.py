"""CarbonFlex-Simulator: slot-level cluster engine (paper §5, §6).

Discrete-time simulation of a cloud cluster running elastic batch jobs
under a pluggable provisioning+scheduling policy.  Per slot:

  1. admit arrivals into the active set;
  2. ask the policy for ``(m_t, allocations)``;
  3. enforce the capacity invariant (sum of allocations <= min(m_t, M));
  4. advance job progress / waiting budgets;
  5. account energy (Eq. 2–3) and carbon (Eq. 1);
  6. record completions, waiting times and SLO violations.

The engine runs past the nominal window until all admitted jobs finish
(run-to-completion semantics shared by every policy in §6).

Precedence-aware workloads (``core/dag.py``): a job whose ``deps`` name
unfinished predecessors is *gated* — kept out of the active set, invisible
to the policy, burning no waiting budget.  When its last predecessor
completes at slot ``t`` it is *released* at ``t + 1``, and its slack and
deadline count from the release slot.  The vector engine keeps a packed
predecessor-count array decremented through a successor CSR on parent
completion; the scalar path mirrors it with per-job counters — both
bit-identical (tests/test_dag.py).

Two engines, bit-for-bit identical outputs (tests/test_engine_parity.py):

- ``engine="vector"`` (default) — struct-of-arrays fast path: per-job
  state lives in packed numpy vectors (``remaining``, ``slack_left``,
  ``waited``, allocations), energy/carbon accounting and fault injection
  are vectorised per slot, and arrivals admit through a sorted pointer.
  Policies that implement the optional ``decide_packed(t, eng, ci,
  cluster)`` protocol skip the per-job Python path entirely; others are
  served lightweight array-backed ``ActiveJob`` views.
- ``engine="scalar"`` — the readable per-ActiveJob reference
  implementation, kept as the parity oracle.

``simulate_many`` batches a (seeds x regions x policies) sweep through
the vector engine, packing each distinct job list once.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Sequence

import numpy as np

from . import emissions
from .carbon import CarbonService, MultiRegionCarbonService
from .faults import (FaultModel, FaultProcess,  # noqa: F401  (re-export)
                     ensure_fault_process)
from .policy import Policy
from .scheduling import ActiveJob, EntryBlocks, apply_slot
from .types import (ClusterConfig, GeoCluster, Job, ResilienceMetrics,
                    SimResult, SlotLog)
from ..telemetry import (SlotEventTracker, Telemetry, emit_fault_events)

_EPS = 1e-9

# ``FaultModel`` moved to ``core/faults.py`` (it aliases ``IidFaults``
# there); the import above keeps ``repro.core.simulator.FaultModel``
# working for existing call sites.


def _policy_ci_view(ci):
    """The CI view the *policy* reads: the service's ``degraded()`` view
    when the feed has outage injection (``core/faults.py``), else the
    service itself.  Accounting always reads the true service."""
    deg = getattr(ci, "degraded", None)
    return deg() if deg is not None else ci


def _count_degraded(ci_pol, t0: int, t_end: int) -> int:
    return sum(1 for t in range(t0, t_end) if ci_pol.staleness(t) > 0)


def _run_resilience(faults, ci_pol, ci, t0: int,
                    t_end: int) -> ResilienceMetrics | None:
    """Fold fault-process metrics and feed-degradation time into the
    ``SimResult.resilience`` record (None when neither is in play)."""
    resil = faults.run_metrics() if faults is not None else None
    if ci_pol is not ci:
        if resil is None:
            resil = ResilienceMetrics()
        resil = dataclasses.replace(
            resil, degraded_slots=_count_degraded(ci_pol, t0, t_end))
    return resil


def _telemetry_hooks(telemetry: Telemetry | None, faults):
    """(event facade, profiler, tracker, fault kind) for one engine run —
    all None/"" when telemetry is off, so the hot-loop guards stay single
    branches and the off path performs zero extra work."""
    if telemetry is None:
        return None, None, None, ""
    tele = telemetry if telemetry.recorder is not None else None
    tracker = SlotEventTracker(tele) if tele is not None else None
    kind = getattr(faults, "kind", "") if faults is not None else ""
    return tele, telemetry.profiler, tracker, kind


# --- packed job tables ------------------------------------------------------


class PackedJobs:
    """Static struct-of-arrays view of a (arrival, job_id)-sorted job list.

    Throughput/marginal lookups go through tables built with the *same*
    ``Job.throughput``/``Job.marginal`` calls the scalar engine makes, so
    gathered values are bit-identical to the scalar path."""

    __slots__ = ("jobs", "n", "job_ids", "arrival", "length", "queue",
                 "k_min", "k_max", "deadline", "elast", "power", "comm",
                 "thr_tab", "blocks", "id2row", "has_deps", "dl_span",
                 "pred0", "succ_ptr", "succ_rows")

    def __init__(self, jobs_sorted: list[Job]) -> None:
        self.jobs = jobs_sorted
        n = self.n = len(jobs_sorted)
        self.job_ids = np.array([j.job_id for j in jobs_sorted], dtype=np.int64)
        self.arrival = np.array([j.arrival for j in jobs_sorted], dtype=np.int64)
        self.length = np.array([j.length for j in jobs_sorted], dtype=np.float64)
        self.queue = np.array([j.queue for j in jobs_sorted], dtype=np.int64)
        self.k_min = np.array([j.k_min for j in jobs_sorted], dtype=np.int64)
        self.k_max = np.array([j.k_max for j in jobs_sorted], dtype=np.int64)
        self.deadline = np.array([j.deadline for j in jobs_sorted], dtype=np.int64)
        self.elast = np.array([j.elasticity() for j in jobs_sorted], dtype=np.float64)
        self.power = np.array([j.power for j in jobs_sorted], dtype=np.float64)
        self.comm = np.array([j.comm_size for j in jobs_sorted], dtype=np.float64)
        kmax_g = int(self.k_max.max()) if n else 0
        self.thr_tab = np.zeros((n, kmax_g + 1))
        for i, job in enumerate(jobs_sorted):
            for k in range(1, kmax_g + 1):
                self.thr_tab[i, k] = job.throughput(k)
        self.blocks = EntryBlocks.build(jobs_sorted)
        self.id2row = {j.job_id: i for i, j in enumerate(jobs_sorted)}
        # Precedence structure (DAG workloads, core/dag.py): initial
        # in-degree per row plus a successor CSR so parent completions can
        # decrement child counters without a per-slot scan.
        self.dl_span = self.deadline - self.arrival
        pred0 = np.zeros(n, dtype=np.int64)
        succ_lists: list[list[int]] = [[] for _ in range(n)]
        has_deps = False
        for i, job in enumerate(jobs_sorted):
            for d in job.deps:
                p = self.id2row.get(d)
                if p is None:
                    raise ValueError(
                        f"job {job.job_id} depends on job {d}, which is not "
                        f"in the submitted job list (DAGs must be submitted "
                        f"whole)")
                if p == i:
                    raise ValueError(f"job {job.job_id} depends on itself")
                has_deps = True
                pred0[i] += 1
                succ_lists[p].append(i)
        self.has_deps = has_deps
        self.pred0 = pred0
        self.succ_ptr = np.zeros(n + 1, dtype=np.int64)
        if n:
            np.cumsum([len(s) for s in succ_lists], out=self.succ_ptr[1:])
        self.succ_rows = np.array([s for lst in succ_lists for s in lst],
                                  dtype=np.int64)
        if has_deps:
            self._check_acyclic()

    def _check_acyclic(self) -> None:
        """Kahn's algorithm: a cycle would deadlock the gating (jobs never
        released), so reject it at pack time."""
        indeg = self.pred0.copy()
        order = list(np.flatnonzero(indeg == 0))
        i = 0
        while i < len(order):
            r = int(order[i])
            for s in self.succ_rows[self.succ_ptr[r]:self.succ_ptr[r + 1]]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    order.append(int(s))
            i += 1
        if len(order) != self.n:
            stuck = [int(self.job_ids[r])
                     for r in np.flatnonzero(indeg > 0)[:5]]
            raise ValueError(f"dependency cycle among jobs {stuck}")


_PACK_CACHE: dict[int, tuple[tuple[int, ...], PackedJobs]] = {}
_PACK_CACHE_MAX = 8


def _packed_for(jobs: list[Job]) -> PackedJobs:
    """Memoised PackedJobs for a job list (throughput tables and entry
    blocks are pure functions of the jobs, so re-simulating the same trace
    — e.g. one run per policy in a sweep — packs once).  The cache keys on
    the element identities plus the scalar fields the tables are built
    from, so rebuilt lists, ``dataclasses.replace``d jobs, and in-place
    field edits all repack.  (In-place mutation of a ``profile`` array's
    *contents* is the one change this cannot see.)"""
    key = id(jobs)
    sig = tuple((id(j), j.arrival, j.length, j.delay, j.queue, j.k_min,
                 j.power, j.comm_size, id(j.profile), j.deps) for j in jobs)
    hit = _PACK_CACHE.get(key)
    if hit is not None and hit[0] == sig:
        return hit[1]
    packed = PackedJobs(sorted(jobs, key=lambda j: (j.arrival, j.job_id)))
    if len(_PACK_CACHE) >= _PACK_CACHE_MAX:
        _PACK_CACHE.pop(next(iter(_PACK_CACHE)))
    _PACK_CACHE[key] = (sig, packed)
    return packed


class _PackedActiveJob:
    """ActiveJob-compatible view over the engine's packed arrays.

    Dict-protocol policies (and ``on_completion`` hooks) read the same
    attribute names as the scalar ``ActiveJob``; reads resolve into the
    engine state, so views are always current without per-slot syncing."""

    __slots__ = ("_eng", "row", "job")

    def __init__(self, eng: "EngineState", row: int) -> None:
        self._eng = eng
        self.row = row
        self.job = eng.packed.jobs[row]

    @property
    def remaining(self) -> float:
        return self._eng.remaining[self.row]

    @property
    def slack_left(self) -> int:
        return self._eng.slack_left[self.row]

    @property
    def waited(self) -> int:
        return self._eng.waited[self.row]

    @property
    def started(self) -> bool:
        return bool(self._eng.started[self.row])

    @property
    def forced(self) -> bool:
        return self._eng.slack_left[self.row] <= 0

    @property
    def done(self) -> bool:
        return self._eng.remaining[self.row] <= _EPS


class EngineState:
    """Dynamic per-run state of the vector engine (exposed to
    ``decide_packed`` policies as their struct-of-arrays view)."""

    __slots__ = ("packed", "remaining", "slack_left", "waited", "started",
                 "in_system", "admitted", "rows", "_views", "pred_left",
                 "deadline_eff", "pending_release", "blocked")

    def __init__(self, packed: PackedJobs) -> None:
        self.packed = packed
        self.remaining = packed.length.copy()
        self.slack_left = np.array([j.delay for j in packed.jobs], dtype=np.int64)
        self.waited = np.zeros(packed.n, dtype=np.int64)
        self.started = np.zeros(packed.n, dtype=bool)
        self.in_system = np.zeros(packed.n, dtype=bool)
        self.admitted = 0                  # sorted-arrival admission pointer
        self.rows = np.zeros(0, dtype=np.int64)
        self._views: dict[int, _PackedActiveJob] = {}
        # DAG gating state (no-ops for independent jobs): per-row live
        # in-degree, release-adjusted deadlines, rows becoming admissible
        # next slot, and the count of arrival-passed-but-gated rows.
        self.pred_left = packed.pred0.copy()
        self.deadline_eff = packed.deadline.copy()
        self.pending_release: list[int] = []
        self.blocked = 0

    def view(self, row: int) -> _PackedActiveJob:
        v = self._views.get(row)
        if v is None:
            v = self._views[row] = _PackedActiveJob(self, row)
        return v

    def active_views(self) -> list[_PackedActiveJob]:
        return [self.view(r) for r in self.rows.tolist()]


def simulate(
    jobs: list[Job],
    ci: CarbonService | MultiRegionCarbonService,
    cluster: ClusterConfig | GeoCluster,
    policy: Policy,
    t0: int = 0,
    horizon: int | None = None,
    max_overrun: int = 24 * 21,
    faults: FaultProcess | None = None,
    engine: str = "vector",
    telemetry: Telemetry | None = None,
) -> SimResult:
    if engine not in ("vector", "scalar", "scan"):
        raise ValueError(f"unknown engine {engine!r}")
    if isinstance(cluster, GeoCluster) and not isinstance(
            ci, MultiRegionCarbonService):
        raise TypeError("a GeoCluster needs a MultiRegionCarbonService")
    if engine == "scan":
        from .scan_engine import simulate_scan
        return simulate_scan(jobs, ci, cluster, policy, t0, horizon,
                             max_overrun, faults, telemetry=telemetry)
    if isinstance(cluster, GeoCluster):
        fn = _simulate_geo_scalar if engine == "scalar" else _simulate_geo_vector
        return fn(jobs, ci, cluster, policy, t0, horizon, max_overrun, faults,
                  telemetry=telemetry)
    if engine == "scalar":
        return _simulate_scalar(jobs, ci, cluster, policy, t0, horizon,
                                max_overrun, faults, telemetry=telemetry)
    return _simulate_vector(jobs, ci, cluster, policy, t0, horizon,
                            max_overrun, faults, telemetry=telemetry)


# --- vector engine ----------------------------------------------------------


def _simulate_vector(
    jobs: list[Job],
    ci: CarbonService,
    cluster: ClusterConfig,
    policy: Policy,
    t0: int = 0,
    horizon: int | None = None,
    max_overrun: int = 24 * 21,
    faults: FaultProcess | None = None,
    packed: PackedJobs | None = None,
    telemetry: Telemetry | None = None,
) -> SimResult:
    horizon = int(horizon if horizon is not None else len(ci) - t0)
    if packed is None:
        packed = _packed_for(jobs)
    ci_pol = _policy_ci_view(ci)        # policies read the (maybe degraded)
    faults = ensure_fault_process(faults)  # view; accounting the true feed
    if faults is not None:
        faults.on_run_start(t0, cluster.capacity)
    tele, prof, tracker, fault_kind = _telemetry_hooks(telemetry, faults)
    policy.on_window_start(ci_pol, t0, horizon, packed.jobs, cluster)
    decide_packed = getattr(policy, "decide_packed", None)
    packed_safe = bool(getattr(policy, "packed_safe", False))

    eng = EngineState(packed)
    n = packed.n
    id2row = packed.id2row
    # per-server power: job-specific when set, cluster default otherwise
    power = np.where(packed.power > 0, packed.power, cluster.power_per_server)
    thr_tab = packed.thr_tab
    slot_h = cluster.slot_hours
    eta = cluster.eta_net

    wait = np.zeros(n)
    violations = np.zeros(n, dtype=bool)
    completion = np.full(n, -1, dtype=np.int64)
    arrival = packed.arrival

    logs: list[SlotLog] = []
    total_energy = 0.0
    total_carbon = 0.0
    has_deps = packed.has_deps
    t = t0
    t_end = t0 + horizon
    rows_dirty = True
    while t < t_end + max_overrun:
        admits = [] if tracker is not None else None
        if has_deps and eng.pending_release:
            # Tasks whose last predecessor completed last slot: released
            # now, with slack/deadline counting from the release slot.
            for r in eng.pending_release:
                eng.in_system[r] = True
                eng.deadline_eff[r] = t + packed.dl_span[r]
            if admits is not None:
                admits.extend(eng.pending_release)
            eng.blocked -= len(eng.pending_release)
            eng.pending_release.clear()
            rows_dirty = True
        while eng.admitted < n and arrival[eng.admitted] <= t:
            if has_deps and eng.pred_left[eng.admitted] > 0:
                eng.blocked += 1       # gated: enters via the release path
            else:
                eng.in_system[eng.admitted] = True
                if admits is not None:
                    admits.append(eng.admitted)
                rows_dirty = True
            eng.admitted += 1
        if admits:
            for r in sorted(admits):
                tracker.admit(t, int(packed.job_ids[r]))
        if rows_dirty:
            eng.rows = np.flatnonzero(eng.in_system)
            rows_dirty = False
        rows = eng.rows
        if (not len(rows) and eng.admitted == n and not eng.blocked
                and t >= t_end):
            break

        if faults is not None:
            faults.begin_slot(t)
            cap_t = faults.available_capacity(cluster.capacity)
        else:
            cap_t = cluster.capacity
        if tele is not None and ci_pol is not ci:
            tele.emit(t, "forecast-read", value=float(ci_pol.staleness(t)))

        if prof is not None:
            _pt = time.perf_counter()
        if decide_packed is not None:
            m_pol, kvec = decide_packed(t, eng, ci_pol, cluster)
            m_t = int(min(m_pol, cap_t))
            if packed_safe:
                # Compliance is a class-level invariant of the decider
                # (``packed_safe = True``: k in {0} | [k_min, k_max],
                # active rows only, total within the m_t it was shown —
                # pinned by the engine parity suite), so the per-slot
                # host-sync guards reduce to one check that only fires
                # when faults shrank capacity below what the policy saw.
                bad = m_t < int(m_pol) and int(kvec.sum()) > m_t
            else:
                # Defensive: the scalar engine unconditionally clips every
                # allocation into [k_min, k_max] and trims over-capacity
                # totals; route any non-compliant packed allocation
                # through the same trimmer instead of gathering
                # out-of-table scales.
                bad = (int(kvec.sum()) > m_t
                       or bool(((kvec > 0) & ((kvec < packed.k_min)
                                              | (kvec > packed.k_max))).any()))
                if has_deps and not bad:
                    # A gated row must never run (engine invariant); the
                    # trimmer drops non-active allocations.
                    bad = bool((kvec[~eng.in_system] > 0).any())
            if bad:
                kvec = _kvec_enforced(kvec, eng, m_t)
        else:
            m_t, alloc = policy.decide(t, eng.active_views(), ci_pol, cluster)
            m_t = int(min(m_t, cap_t))
            alloc = _enforce_capacity(alloc, eng.active_views(), m_t)
            kvec = np.zeros(n, dtype=np.int64)
            for jid, k in alloc.items():
                kvec[id2row[jid]] = k
        if prof is not None:
            _now = time.perf_counter()
            prof.add("decide", _now - _pt)
            _pt = _now

        civ = ci.ci(t)
        k_rows = kvec[rows]
        live = eng.remaining[rows] > _EPS      # "not done", pre-progress
        arows = rows[k_rows > 0]               # energy: done jobs included,
        k_a = kvec[arows]                      # matching the scalar loop
        if tracker is not None:
            tracker.step(t, packed.job_ids[arows].tolist(), k_a.tolist())
        thr_a = thr_tab[arows, k_a]
        # Fractional final slot (paper footnote 4): only the work actually
        # needed is charged.  Each elementwise op mirrors the scalar
        # ``emissions.slot_energy_kwh`` expression order, so per-job values
        # (and hence the sequential slot sum) are bit-identical.
        frac = np.minimum(1.0, eng.remaining[arows] / np.maximum(thr_a, 1e-9))
        e_comp = k_a * power[arows] * slot_h * frac
        ring = np.where(k_a <= 1, 0.0, 2.0 * (k_a - 1) / k_a)
        gbits = packed.comm[arows] * 8.0 * ring * k_a * frac
        e_vec = e_comp + eta * gbits / 3600.0 / 1000.0 * slot_h
        energy = 0.0
        for v in e_vec.tolist():               # sequential sum, scalar order
            energy += v
        # fault disturbance over the allocated live jobs, row order (the
        # same sequence the scalar engine builds — parity by construction);
        # restore/transfer energy is billed into this slot, at this CI
        prows = rows[(k_rows > 0) & live]
        thr_p = thr_tab[prows, kvec[prows]]
        dist = None
        if faults is not None:
            dist = faults.apply(t, [packed.jobs[r] for r in prows.tolist()],
                                kvec[prows], eng.remaining[prows], thr_p)
            if dist.extra_energy is not None:
                for v in dist.extra_energy.tolist():
                    if v:
                        energy += v
            if tele is not None:
                emit_fault_events(tele, t, packed.job_ids[prows].tolist(),
                                  dist, fault_kind)
        carbon = emissions.slot_carbon_g(energy, civ)
        total_energy += energy
        total_carbon += carbon

        # advance progress; degraded slots scale each allocated job's
        # progress (energy was already charged — a slow/failed host still
        # burns power); unallocated jobs spend waiting budget
        if dist is None:
            eng.remaining[prows] -= thr_p
        else:
            eng.remaining[prows] -= thr_p * dist.factors
            if dist.lost is not None:
                eng.remaining[prows] += dist.lost
        eng.started[prows] = True
        wrows = rows[(k_rows == 0) & live]
        eng.slack_left[wrows] -= 1
        eng.waited[wrows] += 1

        fin = rows[eng.remaining[rows] <= _EPS]
        if len(fin):
            completion[fin] = t
            wait[fin] = eng.waited[fin]
            violations[fin] = t > eng.deadline_eff[fin]
            for r in fin.tolist():
                policy.on_completion(t, eng.view(r), bool(violations[r]))
                if tracker is not None:
                    tracker.finish(int(packed.job_ids[r]))
                if has_deps:
                    for s in packed.succ_rows[
                            packed.succ_ptr[r]:packed.succ_ptr[r + 1]]:
                        eng.pred_left[s] -= 1
                        if eng.pred_left[s] == 0 and s < eng.admitted:
                            eng.pending_release.append(int(s))
            eng.in_system[fin] = False
            rows_dirty = True

        used = int(k_a.sum())
        running = len(arows)
        logs.append(SlotLog(slot=t, ci=civ, provisioned=m_t, used=used,
                            energy_kwh=energy, carbon_g=carbon,
                            running=running,
                            queued=len(rows) - len(fin) - running))
        if prof is not None:
            prof.add("execute", time.perf_counter() - _pt)
        t += 1

    return SimResult(
        policy=policy.name,
        carbon_g=total_carbon,
        energy_kwh=total_energy,
        slots=logs,
        wait_slots=wait,
        violations=violations,
        completion=completion,
        num_jobs=n,
        resilience=_run_resilience(faults, ci_pol, ci, t0, t),
    )


def _kvec_enforced(kvec: np.ndarray, eng: EngineState, m_t: int) -> np.ndarray:
    """Route an over-capacity packed allocation through the scalar trimmer."""
    alloc = {int(eng.packed.job_ids[r]): int(kvec[r])
             for r in np.flatnonzero(kvec)}
    alloc = _enforce_capacity(alloc, eng.active_views(), m_t)
    out = np.zeros_like(kvec)
    for jid, k in alloc.items():
        out[eng.packed.id2row[jid]] = k
    return out


# --- batch sweep API --------------------------------------------------------


@dataclasses.dataclass
class SimCase:
    """One (trace, CI, cluster, policy) configuration of a sweep.

    A ``GeoCluster`` + ``MultiRegionCarbonService`` pair makes the case
    geo-distributed (multi-region engine, geo policy)."""

    jobs: list[Job]
    ci: CarbonService | MultiRegionCarbonService
    cluster: ClusterConfig | GeoCluster
    policy: Policy
    t0: int = 0
    horizon: int | None = None
    max_overrun: int = 24 * 21
    faults: FaultProcess | None = None
    label: str = ""
    engine: str = "vector"
    telemetry: Telemetry | None = None


def simulate_many(cases: Iterable[SimCase] | Sequence[SimCase]) -> list[SimResult]:
    """Run a (seeds x regions x policies) sweep through the batch engines.

    Each distinct ``jobs`` list is packed into its struct-of-arrays form
    exactly once (sorting, throughput/marginal tables, scheduling entry
    blocks), so per-configuration cost is the slot loop itself rather
    than per-configuration re-setup — the batch path for the paper's
    Fig. 6–14 sweeps at ``--full`` scale.  Cases whose ``cluster`` is a
    :class:`GeoCluster` dispatch to the multi-region engine; cases with
    ``engine="scan"`` run through the jitted lax.scan path, and
    structurally identical scan cases fuse into one vmapped device
    program (``scan_engine.simulate_many_scan``)."""
    cases = list(cases)
    scan_idx = [i for i, c in enumerate(cases)
                if getattr(c, "engine", "vector") == "scan"]
    out: list[SimResult | None] = [None] * len(cases)
    if scan_idx:
        from .scan_engine import simulate_many_scan
        for i, res in zip(scan_idx,
                          simulate_many_scan([cases[i] for i in scan_idx])):
            out[i] = res
    for i, case in enumerate(cases):
        if out[i] is not None:
            continue
        telemetry = getattr(case, "telemetry", None)
        if isinstance(case.cluster, GeoCluster):
            out[i] = _simulate_geo_vector(
                case.jobs, case.ci, case.cluster, case.policy, case.t0,
                case.horizon, case.max_overrun, case.faults,
                packed=_packed_for(case.jobs), telemetry=telemetry)
        else:
            out[i] = _simulate_vector(
                case.jobs, case.ci, case.cluster, case.policy, case.t0,
                case.horizon, case.max_overrun, case.faults,
                packed=_packed_for(case.jobs), telemetry=telemetry)
    return out


# --- scalar reference engine ------------------------------------------------


def _simulate_scalar(
    jobs: list[Job],
    ci: CarbonService,
    cluster: ClusterConfig,
    policy: Policy,
    t0: int = 0,
    horizon: int | None = None,
    max_overrun: int = 24 * 21,
    faults: FaultProcess | None = None,
    telemetry: Telemetry | None = None,
) -> SimResult:
    horizon = int(horizon if horizon is not None else len(ci) - t0)
    jobs = sorted(jobs, key=lambda j: (j.arrival, j.job_id))
    ci_pol = _policy_ci_view(ci)
    faults = ensure_fault_process(faults)
    if faults is not None:
        faults.on_run_start(t0, cluster.capacity)
    tele, prof, tracker, fault_kind = _telemetry_hooks(telemetry, faults)
    policy.on_window_start(ci_pol, t0, horizon, jobs, cluster)

    active: list[ActiveJob] = []
    n = len(jobs)
    next_arrival = 0                  # pointer into the arrival-sorted list
    wait = np.zeros(n)
    violations = np.zeros(n, dtype=bool)
    completion = np.full(n, -1, dtype=np.int64)
    id2row = {j.job_id: i for i, j in enumerate(jobs)}

    # DAG gating (mirrors the vector engine's packed predecessor counters;
    # see PackedJobs): live in-degree per job, successor adjacency,
    # release-adjusted deadlines, and tasks pending release next slot.
    has_deps = any(j.deps for j in jobs)
    pred_left: dict[int, int] = {}
    succ: dict[int, list[Job]] = {}
    deadline_eff: dict[int, int] = {}
    pending_release: list[Job] = []
    blocked = 0
    if has_deps:
        by_id = {j.job_id: j for j in jobs}
        pred_left = {j.job_id: 0 for j in jobs}
        succ = {j.job_id: [] for j in jobs}
        for j in jobs:
            for d in j.deps:
                if d not in by_id:
                    raise ValueError(
                        f"job {j.job_id} depends on job {d}, which is not "
                        f"in the submitted job list (DAGs must be "
                        f"submitted whole)")
                if d == j.job_id:
                    raise ValueError(f"job {j.job_id} depends on itself")
                pred_left[j.job_id] += 1
                succ[d].append(j)
        order = [j for j in jobs if pred_left[j.job_id] == 0]
        indeg = dict(pred_left)
        i = 0
        while i < len(order):
            for c in succ[order[i].job_id]:
                indeg[c.job_id] -= 1
                if indeg[c.job_id] == 0:
                    order.append(c)
            i += 1
        if len(order) != n:
            stuck = [jid for jid, d in indeg.items() if d > 0][:5]
            raise ValueError(f"dependency cycle among jobs {stuck}")

    logs: list[SlotLog] = []
    total_energy = 0.0
    total_carbon = 0.0
    t = t0
    t_end = t0 + horizon
    while t < t_end + max_overrun:
        released = False
        admits = [] if tracker is not None else None
        if has_deps and pending_release:
            for j in pending_release:
                active.append(ActiveJob(job=j, remaining=j.length,
                                        slack_left=j.delay))
                if admits is not None:
                    admits.append(id2row[j.job_id])
                deadline_eff[j.job_id] = t + (j.deadline - j.arrival)
            blocked -= len(pending_release)
            pending_release = []
            released = True
        while next_arrival < n and jobs[next_arrival].arrival <= t:
            j = jobs[next_arrival]
            next_arrival += 1
            if has_deps and pred_left[j.job_id] > 0:
                blocked += 1          # gated: enters via the release path
                continue
            active.append(ActiveJob(job=j, remaining=j.length, slack_left=j.delay))
            if admits is not None:
                admits.append(id2row[j.job_id])
        if admits:
            for r in sorted(admits):
                tracker.admit(t, jobs[r].job_id)
        if released:
            # keep active in (arrival, job_id) row order, matching the
            # vector engine's sorted-row iteration (float-sum parity)
            active.sort(key=lambda a: id2row[a.job.job_id])
        if not active and next_arrival == n and not blocked and t >= t_end:
            break

        if faults is not None:
            faults.begin_slot(t)
            cap_t = faults.available_capacity(cluster.capacity)
        else:
            cap_t = cluster.capacity
        if tele is not None and ci_pol is not ci:
            tele.emit(t, "forecast-read", value=float(ci_pol.staleness(t)))

        if prof is not None:
            _pt = time.perf_counter()
        m_t, alloc = policy.decide(t, active, ci_pol, cluster)
        m_t = int(min(m_t, cap_t))
        alloc = _enforce_capacity(alloc, active, m_t)
        if prof is not None:
            _now = time.perf_counter()
            prof.add("decide", _now - _pt)
            _pt = _now
        if tracker is not None:
            ids = [a.job.job_id for a in active
                   if alloc.get(a.job.job_id, 0) > 0]
            tracker.step(t, ids, [alloc[j] for j in ids])

        civ = ci.ci(t)
        energy = 0.0
        for a in active:
            k = alloc.get(a.job.job_id, 0)
            if k > 0:
                # Fractional final slot (paper footnote 4): only the work
                # actually needed is charged.
                frac = min(1.0, a.remaining / max(a.job.throughput(k), 1e-9))
                energy += emissions.slot_energy_kwh(a.job, k, cluster, frac)
        # fault disturbance over the allocated live jobs in list order
        # (= row order), through the same arrays the vector engine gathers
        dist = None
        run: list[ActiveJob] = []
        if faults is not None:
            run = [a for a in active
                   if not a.done and alloc.get(a.job.job_id, 0) > 0]
            ks = np.array([alloc[a.job.job_id] for a in run], dtype=np.int64)
            rem = np.array([a.remaining for a in run], dtype=np.float64)
            thr = np.array([a.job.throughput(int(k))
                            for a, k in zip(run, ks)], dtype=np.float64)
            dist = faults.apply(t, [a.job for a in run], ks, rem, thr)
            if dist.extra_energy is not None:
                for v in dist.extra_energy.tolist():
                    if v:
                        energy += v
            if tele is not None:
                emit_fault_events(tele, t, [a.job.job_id for a in run],
                                  dist, fault_kind)
        carbon = emissions.slot_carbon_g(energy, civ)
        total_energy += energy
        total_carbon += carbon

        if dist is None:
            apply_slot(active, alloc)
        else:
            # degraded slots: scale each allocated job's progress; energy
            # was already charged (a slow/failed host still burns power)
            for i, a in enumerate(run):
                a.remaining -= thr[i] * dist.factors[i]
                if dist.lost is not None:
                    a.remaining += dist.lost[i]
                a.started = True
            for a in active:
                if a.done or alloc.get(a.job.job_id, 0) > 0:
                    continue
                a.slack_left -= 1
                a.waited += 1

        finished = [a for a in active if a.done]
        for a in finished:
            jid = a.job.job_id
            row = id2row[jid]
            completion[row] = t
            wait[row] = a.waited
            violations[row] = t > deadline_eff.get(jid, a.job.deadline)
            policy.on_completion(t, a, bool(violations[row]))
            if tracker is not None:
                tracker.finish(jid)
            if has_deps:
                for child in succ[jid]:
                    pred_left[child.job_id] -= 1
                    if pred_left[child.job_id] == 0 and child.arrival <= t:
                        pending_release.append(child)
        active = [a for a in active if not a.done]

        used = sum(alloc.values())
        logs.append(SlotLog(slot=t, ci=civ, provisioned=m_t, used=used,
                            energy_kwh=energy, carbon_g=carbon,
                            running=len(alloc), queued=len(active) - len(alloc)))
        if prof is not None:
            prof.add("execute", time.perf_counter() - _pt)
        t += 1

    return SimResult(
        policy=policy.name,
        carbon_g=total_carbon,
        energy_kwh=total_energy,
        slots=logs,
        wait_slots=wait,
        violations=violations,
        completion=completion,
        num_jobs=n,
        resilience=_run_resilience(faults, ci_pol, ci, t0, t),
    )


def _enforce_capacity(alloc: dict[int, int], active: list[ActiveJob], m_t: int) -> dict[int, int]:
    """Capacity invariant: trim allocations (lowest marginal first) to m_t."""
    by_id = {a.job.job_id: a for a in active}
    alloc = {jid: int(k) for jid, k in alloc.items()
             if jid in by_id and k > 0}
    for jid in list(alloc):
        a = by_id[jid]
        alloc[jid] = int(np.clip(alloc[jid], a.job.k_min, a.job.k_max))
    total = sum(alloc.values())
    if total <= m_t:
        return alloc
    # Shed the least carbon-efficient increments first.
    incs = []
    for jid, k in alloc.items():
        a = by_id[jid]
        for kk in range(a.job.k_min + 1, k + 1):
            incs.append((a.job.marginal(kk), jid, kk))
    incs.sort()                      # lowest marginal first
    for p, jid, kk in incs:
        if total <= m_t:
            break
        if alloc.get(jid, 0) == kk:
            alloc[jid] = kk - 1
            total -= 1
    # Still above capacity: drop whole base allocations, latest-slack first.
    if total > m_t:
        order = sorted(alloc, key=lambda jid: -by_id[jid].slack_left)
        for jid in order:
            if total <= m_t:
                break
            total -= alloc[jid]
            del alloc[jid]
    return alloc


# --- geo-distributed engines ------------------------------------------------
#
# The multi-region path generalises the slot loop in *space*: per-job state
# gains a region axis (current region, migration countdown), provisioning
# and capacity enforcement run per region, and energy turns into a
# per-region vector multiplied by the aligned CI vector.  Semantics:
#
# - every job arrives in its home region (``GeoCluster.home_region`` over
#   the (arrival, job_id)-sorted row index);
# - a policy returning a different region for a job that has NOT started is
#   a free *placement* (queued work has no state to move);
# - for a started job it is a *migration*: the job suspends for
#   ``MigrationModel.slots(job)`` slots (burning waiting budget like any
#   pause), and the checkpoint-transfer energy is charged once, billed at
#   the destination region's CI on the initiation slot;
# - per-slot carbon is sum_r energy_r * CI_r(t); migration energy counts
#   into the destination region's total.
#
# Both engines (vector = region-axis state arrays + vectorised accounting,
# scalar = the readable per-GeoActiveJob reference) share the placement/
# migration resolution and the per-region accumulation helpers, and are
# bit-for-bit identical (tests/test_geo.py).


@dataclasses.dataclass
class GeoActiveJob(ActiveJob):
    """ActiveJob + the region axis (scalar geo reference engine)."""

    region: int = 0
    mig_left: int = 0               # remaining suspended migration slots

    @property
    def migrating(self) -> bool:
        return self.mig_left > 0


class _GeoPackedActiveJob(_PackedActiveJob):
    """Packed view + the region axis (vector geo engine)."""

    __slots__ = ()

    @property
    def region(self) -> int:
        return int(self._eng.region[self.row])

    @region.setter
    def region(self, value: int) -> None:
        self._eng.region[self.row] = value

    @property
    def mig_left(self) -> int:
        return int(self._eng.mig_left[self.row])

    @mig_left.setter
    def mig_left(self, value: int) -> None:
        self._eng.mig_left[self.row] = value

    @property
    def migrating(self) -> bool:
        return self._eng.mig_left[self.row] > 0


class GeoEngineState(EngineState):
    """EngineState + per-job region / migration-countdown vectors."""

    __slots__ = ("region", "mig_left")

    def __init__(self, packed: PackedJobs, geo: GeoCluster) -> None:
        super().__init__(packed)
        self.region = np.array([geo.home_region(i) for i in range(packed.n)],
                               dtype=np.int64)
        self.mig_left = np.zeros(packed.n, dtype=np.int64)

    def view(self, row: int) -> _GeoPackedActiveJob:
        v = self._views.get(row)
        if v is None:
            v = self._views[row] = _GeoPackedActiveJob(self, row)
        return v


def _resolve_geo(active, alloc: dict[int, tuple[int, int]], geo: GeoCluster,
                 tele: Telemetry | None = None, t: int = 0):
    """Apply placement/migration semantics to a policy's raw decision.

    Walks the active set in engine order, mutating each view's
    ``region``/``mig_left`` (free placement for never-started jobs,
    migration initiation for started ones) and splitting the surviving
    allocations per region.  Returns ``(per_region_alloc, migrations)``
    where ``migrations`` lists ``(view, dest_region)`` in decision order.
    Shared verbatim by both geo engines so their state transitions (and
    the migrate events emitted here) are identical."""
    per_r: list[dict[int, int]] = [dict() for _ in range(geo.n_regions)]
    migs = []
    for a in active:
        if a.done or a.migrating:
            continue
        entry = alloc.get(a.job.job_id)
        if entry is None:
            continue
        r, k = int(entry[0]), int(entry[1])
        if not 0 <= r < geo.n_regions:
            raise ValueError(f"policy placed job {a.job.job_id} in region "
                             f"{r}; cluster has {geo.n_regions} regions")
        if r != a.region:
            if a.started:
                if tele is not None:
                    tele.emit(t, "migrate", job=a.job.job_id, value=float(r),
                              detail=f"from={int(a.region)}")
                a.region = r
                a.mig_left = geo.migration.slots(a.job)
                migs.append((a, r))
                continue               # suspended while state moves
            a.region = r               # free placement before first start
        if k > 0:
            per_r[r][a.job.job_id] = k
    return per_r, migs


def _charge_migrations(migs, geo: GeoCluster, ci_vec: np.ndarray,
                       energy_r: np.ndarray) -> float:
    """Add each initiated migration's transfer energy to its destination
    region (event order) and return the migration carbon charged."""
    mig_carbon = 0.0
    for a, dest in migs:
        e = geo.migration.energy_kwh(a.job)
        energy_r[dest] += e
        mig_carbon += e * ci_vec[dest]
    return mig_carbon


def _accumulate_regions(energy_r: np.ndarray, ci_vec: np.ndarray,
                        region_energy: np.ndarray,
                        region_carbon: np.ndarray) -> tuple[float, float]:
    """Fold one slot's per-region energy into the run totals; returns the
    slot's (energy, carbon) scalars.  Sequential region order keeps the
    float stream identical across engines."""
    energy = 0.0
    carbon = 0.0
    for r in range(len(energy_r)):
        c = energy_r[r] * ci_vec[r]
        energy += energy_r[r]
        carbon += c
        region_energy[r] += energy_r[r]
        region_carbon[r] += c
    return energy, carbon


def _simulate_geo_vector(
    jobs: list[Job],
    mci: MultiRegionCarbonService,
    geo: GeoCluster,
    policy,
    t0: int = 0,
    horizon: int | None = None,
    max_overrun: int = 24 * 21,
    faults: FaultProcess | None = None,
    packed: PackedJobs | None = None,
    telemetry: Telemetry | None = None,
) -> SimResult:
    horizon = int(horizon if horizon is not None else len(mci) - t0)
    if packed is None:
        packed = _packed_for(jobs)
    if packed.has_deps:
        raise ValueError("the geo engines do not support DAG jobs yet; "
                         "run precedence-gated workloads single-region")
    ci_pol = _policy_ci_view(mci)
    faults = ensure_fault_process(faults)
    if faults is not None:
        faults.on_run_start(t0, geo.capacity_vec())
    tele, prof, tracker, fault_kind = _telemetry_hooks(telemetry, faults)
    policy.on_window_start(ci_pol, t0, horizon, packed.jobs, geo)

    eng = GeoEngineState(packed, geo)
    n = packed.n
    n_regions = geo.n_regions
    caps = geo.capacity_vec()
    id2row = packed.id2row
    power = np.where(packed.power > 0, packed.power, geo.power_per_server)
    thr_tab = packed.thr_tab
    slot_h = geo.slot_hours
    eta = geo.eta_net

    wait = np.zeros(n)
    violations = np.zeros(n, dtype=bool)
    completion = np.full(n, -1, dtype=np.int64)
    final_region = np.full(n, -1, dtype=np.int64)
    region_energy = np.zeros(n_regions)
    region_carbon = np.zeros(n_regions)
    migrations = 0
    mig_carbon_total = 0.0
    arrival = packed.arrival

    logs: list[SlotLog] = []
    total_energy = 0.0
    total_carbon = 0.0
    t = t0
    t_end = t0 + horizon
    rows_dirty = True
    while t < t_end + max_overrun:
        admits = [] if tracker is not None else None
        while eng.admitted < n and arrival[eng.admitted] <= t:
            if admits is not None:
                admits.append(eng.admitted)
            eng.in_system[eng.admitted] = True
            eng.admitted += 1
            rows_dirty = True
        if admits:
            for r in sorted(admits):
                tracker.admit(t, int(packed.job_ids[r]))
        if rows_dirty:
            eng.rows = np.flatnonzero(eng.in_system)
            rows_dirty = False
        rows = eng.rows
        if not len(rows) and eng.admitted == n and t >= t_end:
            break

        if faults is not None:
            faults.begin_slot(t)
            caps_t = faults.available_capacity_vec(caps)
        else:
            caps_t = caps
        if tele is not None and ci_pol is not mci:
            tele.emit(t, "forecast-read", value=float(ci_pol.staleness(t)))
        if prof is not None:
            _pt = time.perf_counter()

        active_views = eng.active_views()
        m_vec, alloc = policy.decide_geo(t, active_views, ci_pol, geo)
        m_vec = np.minimum(np.asarray(m_vec, dtype=np.int64), caps_t)
        per_r, migs = _resolve_geo(active_views, alloc, geo, tele, t)
        kvec = np.zeros(n, dtype=np.int64)
        for r in range(n_regions):
            for jid, k in _enforce_capacity(per_r[r], active_views,
                                            int(m_vec[r])).items():
                kvec[id2row[jid]] = k
        if prof is not None:
            _now = time.perf_counter()
            prof.add("decide", _now - _pt)
            _pt = _now

        ci_vec = mci.ci_vec(t)
        k_rows = kvec[rows]
        live = eng.remaining[rows] > _EPS
        arows = rows[k_rows > 0]
        k_a = kvec[arows]
        if tracker is not None:
            tracker.step(t, packed.job_ids[arows].tolist(), k_a.tolist())
        thr_a = thr_tab[arows, k_a]
        # Elementwise ops mirror the scalar ``emissions.slot_energy_kwh``
        # expression order (see the single-region vector engine).
        frac = np.minimum(1.0, eng.remaining[arows] / np.maximum(thr_a, 1e-9))
        e_comp = k_a * power[arows] * slot_h * frac
        ring = np.where(k_a <= 1, 0.0, 2.0 * (k_a - 1) / k_a)
        gbits = packed.comm[arows] * 8.0 * ring * k_a * frac
        e_vec = e_comp + eta * gbits / 3600.0 / 1000.0 * slot_h
        a_regions = eng.region[arows]
        energy_r = np.zeros(n_regions)
        for r in range(n_regions):
            for v in e_vec[a_regions == r].tolist():   # sequential, row order
                energy_r[r] += v

        prows = rows[(k_rows > 0) & live]
        thr_p = thr_tab[prows, kvec[prows]]
        dist = None
        if faults is not None:
            p_reg = eng.region[prows]
            dist = faults.apply(t, [packed.jobs[r] for r in prows.tolist()],
                                kvec[prows], eng.remaining[prows], thr_p,
                                regions=p_reg)
            if dist.extra_energy is not None:
                for i, v in enumerate(dist.extra_energy.tolist()):
                    if v:
                        energy_r[int(p_reg[i])] += v
            if tele is not None:
                emit_fault_events(tele, t, packed.job_ids[prows].tolist(),
                                  dist, fault_kind)

        mc = _charge_migrations(migs, geo, ci_vec, energy_r)
        mig_carbon_total += mc
        migrations += len(migs)
        energy, carbon = _accumulate_regions(energy_r, ci_vec,
                                             region_energy, region_carbon)
        total_energy += energy
        total_carbon += carbon

        if dist is None:
            eng.remaining[prows] -= thr_p
        else:
            eng.remaining[prows] -= thr_p * dist.factors
            if dist.lost is not None:
                eng.remaining[prows] += dist.lost
        eng.started[prows] = True
        wrows = rows[(k_rows == 0) & live]
        eng.slack_left[wrows] -= 1
        eng.waited[wrows] += 1
        mrows = wrows[eng.mig_left[wrows] > 0]
        eng.mig_left[mrows] -= 1

        fin = rows[eng.remaining[rows] <= _EPS]
        if len(fin):
            completion[fin] = t
            wait[fin] = eng.waited[fin]
            violations[fin] = t > packed.deadline[fin]
            final_region[fin] = eng.region[fin]
            for r in fin.tolist():
                policy.on_completion(t, eng.view(r), bool(violations[r]))
                if tracker is not None:
                    tracker.finish(int(packed.job_ids[r]))
            eng.in_system[fin] = False
            rows_dirty = True

        used = int(k_a.sum())
        running = len(arows)
        logs.append(SlotLog(slot=t, ci=float(np.mean(ci_vec)),
                            provisioned=int(m_vec.sum()), used=used,
                            energy_kwh=energy, carbon_g=carbon,
                            running=running,
                            queued=len(rows) - len(fin) - running))
        if prof is not None:
            prof.add("execute", time.perf_counter() - _pt)
        t += 1

    return SimResult(
        policy=policy.name,
        carbon_g=total_carbon,
        energy_kwh=total_energy,
        slots=logs,
        wait_slots=wait,
        violations=violations,
        completion=completion,
        num_jobs=n,
        regions=geo.regions,
        region_carbon_g=region_carbon,
        region_energy_kwh=region_energy,
        final_region=final_region,
        migrations=migrations,
        migration_carbon_g=mig_carbon_total,
        resilience=_run_resilience(faults, ci_pol, mci, t0, t),
    )


def _simulate_geo_scalar(
    jobs: list[Job],
    mci: MultiRegionCarbonService,
    geo: GeoCluster,
    policy,
    t0: int = 0,
    horizon: int | None = None,
    max_overrun: int = 24 * 21,
    faults: FaultProcess | None = None,
    telemetry: Telemetry | None = None,
) -> SimResult:
    horizon = int(horizon if horizon is not None else len(mci) - t0)
    if any(j.deps for j in jobs):
        raise ValueError("the geo engines do not support DAG jobs yet; "
                         "run precedence-gated workloads single-region")
    jobs = sorted(jobs, key=lambda j: (j.arrival, j.job_id))
    ci_pol = _policy_ci_view(mci)
    faults = ensure_fault_process(faults)
    if faults is not None:
        faults.on_run_start(t0, geo.capacity_vec())
    tele, prof, tracker, fault_kind = _telemetry_hooks(telemetry, faults)
    policy.on_window_start(ci_pol, t0, horizon, jobs, geo)

    n_regions = geo.n_regions
    caps = geo.capacity_vec()
    active: list[GeoActiveJob] = []
    n = len(jobs)
    next_arrival = 0
    wait = np.zeros(n)
    violations = np.zeros(n, dtype=bool)
    completion = np.full(n, -1, dtype=np.int64)
    final_region = np.full(n, -1, dtype=np.int64)
    region_energy = np.zeros(n_regions)
    region_carbon = np.zeros(n_regions)
    migrations = 0
    mig_carbon_total = 0.0
    id2row = {j.job_id: i for i, j in enumerate(jobs)}

    logs: list[SlotLog] = []
    total_energy = 0.0
    total_carbon = 0.0
    t = t0
    t_end = t0 + horizon
    while t < t_end + max_overrun:
        admits = [] if tracker is not None else None
        while next_arrival < n and jobs[next_arrival].arrival <= t:
            j = jobs[next_arrival]
            if admits is not None:
                admits.append(next_arrival)
            active.append(GeoActiveJob(
                job=j, remaining=j.length, slack_left=j.delay,
                region=geo.home_region(next_arrival)))
            next_arrival += 1
        if admits:
            for r in sorted(admits):
                tracker.admit(t, jobs[r].job_id)
        if not active and next_arrival == n and t >= t_end:
            break

        if faults is not None:
            faults.begin_slot(t)
            caps_t = faults.available_capacity_vec(caps)
        else:
            caps_t = caps
        if tele is not None and ci_pol is not mci:
            tele.emit(t, "forecast-read", value=float(ci_pol.staleness(t)))
        if prof is not None:
            _pt = time.perf_counter()

        m_vec, alloc = policy.decide_geo(t, active, ci_pol, geo)
        m_vec = np.minimum(np.asarray(m_vec, dtype=np.int64), caps_t)
        per_r, migs = _resolve_geo(active, alloc, geo, tele, t)
        final: dict[int, tuple[int, int]] = {}
        for r in range(n_regions):
            for jid, k in _enforce_capacity(per_r[r], active,
                                            int(m_vec[r])).items():
                final[jid] = (r, k)
        if prof is not None:
            _now = time.perf_counter()
            prof.add("decide", _now - _pt)
            _pt = _now
        if tracker is not None:
            ids = [a.job.job_id for a in active
                   if final.get(a.job.job_id, (0, 0))[1] > 0]
            tracker.step(t, ids, [final[j][1] for j in ids])

        ci_vec = mci.ci_vec(t)
        energy_r = np.zeros(n_regions)
        for a in active:
            entry = final.get(a.job.job_id)
            if entry is None:
                continue
            r, k = entry
            frac = min(1.0, a.remaining / max(a.job.throughput(k), 1e-9))
            energy_r[r] += emissions.slot_energy_kwh(a.job, k, geo, frac)

        dist = None
        run: list[GeoActiveJob] = []
        if faults is not None:
            run = [a for a in active
                   if not a.done and final.get(a.job.job_id) is not None]
            ks = np.array([final[a.job.job_id][1] for a in run],
                          dtype=np.int64)
            rem = np.array([a.remaining for a in run], dtype=np.float64)
            thr = np.array([a.job.throughput(int(k))
                            for a, k in zip(run, ks)], dtype=np.float64)
            regs = np.array([final[a.job.job_id][0] for a in run],
                            dtype=np.int64)
            dist = faults.apply(t, [a.job for a in run], ks, rem, thr,
                                regions=regs)
            if dist.extra_energy is not None:
                for i, v in enumerate(dist.extra_energy.tolist()):
                    if v:
                        energy_r[int(regs[i])] += v
            if tele is not None:
                emit_fault_events(tele, t, [a.job.job_id for a in run],
                                  dist, fault_kind)

        mc = _charge_migrations(migs, geo, ci_vec, energy_r)
        mig_carbon_total += mc
        migrations += len(migs)
        energy, carbon = _accumulate_regions(energy_r, ci_vec,
                                             region_energy, region_carbon)
        total_energy += energy
        total_carbon += carbon

        if dist is None:
            for a in active:
                if a.done:
                    continue
                entry = final.get(a.job.job_id)
                if entry is not None:
                    r, k = entry
                    a.remaining -= a.job.throughput(k)
                    a.started = True
                else:
                    a.slack_left -= 1
                    a.waited += 1
                    if a.mig_left > 0:
                        a.mig_left -= 1
        else:
            for i, a in enumerate(run):
                a.remaining -= thr[i] * dist.factors[i]
                if dist.lost is not None:
                    a.remaining += dist.lost[i]
                a.started = True
            for a in active:
                if a.done or final.get(a.job.job_id) is not None:
                    continue
                a.slack_left -= 1
                a.waited += 1
                if a.mig_left > 0:
                    a.mig_left -= 1

        finished = [a for a in active if a.done]
        for a in finished:
            row = id2row[a.job.job_id]
            completion[row] = t
            wait[row] = a.waited
            violations[row] = t > a.job.deadline
            final_region[row] = a.region
            policy.on_completion(t, a, bool(violations[row]))
            if tracker is not None:
                tracker.finish(a.job.job_id)
        active = [a for a in active if not a.done]

        used = sum(k for _, k in final.values())
        running = len(final)
        logs.append(SlotLog(slot=t, ci=float(np.mean(ci_vec)),
                            provisioned=int(m_vec.sum()), used=used,
                            energy_kwh=energy, carbon_g=carbon,
                            running=running,
                            queued=len(active) - running))
        if prof is not None:
            prof.add("execute", time.perf_counter() - _pt)
        t += 1

    return SimResult(
        policy=policy.name,
        carbon_g=total_carbon,
        energy_kwh=total_energy,
        slots=logs,
        wait_slots=wait,
        violations=violations,
        completion=completion,
        num_jobs=n,
        regions=geo.regions,
        region_carbon_g=region_carbon,
        region_energy_kwh=region_energy,
        final_region=final_region,
        migrations=migrations,
        migration_carbon_g=mig_carbon_total,
        resilience=_run_resilience(faults, ci_pol, mci, t0, t),
    )
