"""Precedence-aware workloads: DAG jobs, criticality, and DAG policies.

Real cluster traces are dominated by multi-stage pipelines whose
precedence constraints change what carbon-aware suspension can save:
Bostandoost et al. ("Quantifying the Carbon Reduction of DAG Workloads")
show DAG structure caps the savings per-job schedulers report, and PCAPS
(Lechowicz et al., "Carbon- and Precedence-Aware Scheduling for Data
Processing Clusters") shows criticality-weighted scheduling recovers most
of it.  This module is the DAG subsystem on top of the existing engine:

- :class:`TaskNode` / :class:`DagSpec` — a job as a DAG of tasks, each
  task keeping the existing elasticity-profile machinery (``profile``,
  ``k_min``, ``power``, ``comm_size``);
- :func:`chain_tasks` / :func:`map_reduce_tasks` / :func:`layered_tasks`
  — builders for the published pipeline shapes (linear chains, fan-out/
  fan-in map-reduce stages, random layered DAGs);
- :func:`expand_dags` — flatten DAG specs into the engine's ``Job`` list,
  precedence carried as ``Job.deps`` (predecessor job_ids) that both
  engine paths gate on (``core/simulator.py``);
- :func:`criticality_from_jobs` — longest-path-to-sink analysis over an
  expanded job list (the PCAPS criticality weights);
- the three DAG policies registered as ``dag-fcfs`` / ``dag-carbon`` /
  ``dag-cap`` in ``experiment/registry.py``.

Engine semantics (shared bit-for-bit by the vector and scalar paths): a
task with unfinished predecessors is *gated* — not admitted to the active
set, invisible to the policy, burning no waiting budget.  When its last
predecessor completes at slot ``t`` the task is *released* at ``t + 1``;
its slack and deadline then count from the release slot, so a deep task
is not pre-expired by time its ancestors spent running.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .baselines import CarbonAgnosticPolicy, WaitAwhilePolicy, _fcfs_base_alloc
from .types import Job, QueueConfig

_EPS = 1e-9


# --- the DAG model -----------------------------------------------------------


@dataclasses.dataclass
class TaskNode:
    """One task of a DAG job.

    ``deps`` are indices into the owning :class:`DagSpec`'s task tuple and
    must point strictly backwards (topological authoring order), which
    makes cycles unrepresentable by construction."""

    length: float                       # slots of work at k_min
    deps: tuple[int, ...] = ()          # predecessor indices within the DAG
    profile: np.ndarray = dataclasses.field(
        default_factory=lambda: np.ones(1))
    k_min: int = 1
    power: float = 1.0
    comm_size: float = 0.0
    name: str = "task"


@dataclasses.dataclass
class DagSpec:
    """A job that is a DAG of tasks (arriving as a unit at ``arrival``)."""

    dag_id: int
    arrival: int
    tasks: tuple[TaskNode, ...]
    name: str = "dag"

    def __post_init__(self) -> None:
        self.tasks = tuple(self.tasks)
        if not self.tasks:
            raise ValueError(f"dag {self.dag_id}: needs >= 1 task")
        for i, task in enumerate(self.tasks):
            for d in task.deps:
                if not 0 <= d < i:
                    raise ValueError(
                        f"dag {self.dag_id}: task {i} depends on {d}; deps "
                        f"must point to earlier tasks (topological order)")

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    def total_work(self) -> float:
        return float(sum(t.length for t in self.tasks))

    def edges(self) -> list[tuple[int, int]]:
        return [(d, i) for i, t in enumerate(self.tasks) for d in t.deps]

    def depth(self) -> int:
        """Number of tasks on the longest chain (1 for independent tasks)."""
        lvl = [0] * self.n_tasks
        for i, t in enumerate(self.tasks):
            lvl[i] = 1 + max((lvl[d] for d in t.deps), default=0)
        return max(lvl)

    def critical_path_length(self) -> float:
        """Work (in k_min-slots) along the longest path to any sink."""
        head = [0.0] * self.n_tasks
        for i, t in enumerate(self.tasks):
            head[i] = t.length + max((head[d] for d in t.deps), default=0.0)
        return float(max(head))


# --- shape builders ----------------------------------------------------------


def chain_tasks(lengths: Sequence[float], **task_kw) -> tuple[TaskNode, ...]:
    """A linear pipeline: task i depends on task i-1."""
    return tuple(TaskNode(length=float(ln), deps=(i - 1,) if i else (),
                          name=f"stage{i}", **task_kw)
                 for i, ln in enumerate(lengths))


def map_reduce_tasks(source_length: float, map_lengths: Sequence[float],
                     reduce_length: float, **task_kw) -> tuple[TaskNode, ...]:
    """Fan-out/fan-in: source -> W parallel mappers -> reducer."""
    if not len(map_lengths):
        raise ValueError("map_reduce_tasks needs >= 1 mapper")
    tasks = [TaskNode(length=float(source_length), name="source", **task_kw)]
    for i, ln in enumerate(map_lengths):
        tasks.append(TaskNode(length=float(ln), deps=(0,),
                              name=f"map{i}", **task_kw))
    w = len(map_lengths)
    tasks.append(TaskNode(length=float(reduce_length),
                          deps=tuple(range(1, w + 1)), name="reduce",
                          **task_kw))
    return tuple(tasks)


def layered_tasks(layer_sizes: Sequence[int], lengths: Sequence[float],
                  rng: np.random.Generator, max_parents: int = 3,
                  **task_kw) -> tuple[TaskNode, ...]:
    """A random layered DAG: every task in layer ``i`` draws 1..max_parents
    predecessors uniformly from layer ``i - 1`` (layer 0 tasks are roots).
    ``lengths`` supplies one work length per task, layer by layer."""
    if sum(layer_sizes) != len(lengths):
        raise ValueError(f"layered_tasks: {sum(layer_sizes)} tasks in "
                         f"layer_sizes but {len(lengths)} lengths")
    if any(s < 1 for s in layer_sizes):
        raise ValueError(f"layer sizes must be >= 1: {tuple(layer_sizes)}")
    tasks: list[TaskNode] = []
    prev: list[int] = []
    li = 0
    for depth, size in enumerate(layer_sizes):
        cur = []
        for _ in range(size):
            deps: tuple[int, ...] = ()
            if prev:
                n_par = int(rng.integers(1, min(max_parents, len(prev)) + 1))
                deps = tuple(sorted(int(p) for p in rng.choice(
                    prev, size=n_par, replace=False)))
            cur.append(len(tasks))
            tasks.append(TaskNode(length=float(lengths[li]), deps=deps,
                                  name=f"l{depth}t{len(cur) - 1}", **task_kw))
            li += 1
        prev = cur
    return tuple(tasks)


# --- expansion to engine jobs ------------------------------------------------


def expand_dags(dags: Sequence[DagSpec], queues: tuple[QueueConfig, ...],
                id_base: int = 0, independent: bool = False) -> list[Job]:
    """Flatten DAG specs into the engine's ``Job`` list.

    Every task becomes one ``Job`` arriving at its DAG's arrival slot
    (the engines gate non-root tasks until their predecessors finish, so
    a DAG never straddles an arrival-based trace split); task -> queue
    assignment follows the existing per-length rule.  ``independent=True``
    strips the precedence edges — the independent-task *upper bound* the
    DAG studies compare against."""
    jobs: list[Job] = []
    jid = id_base
    for dag in dags:
        base = jid
        for task in dag.tasks:
            qidx = next(i for i, q in enumerate(queues)
                        if task.length <= q.max_length)
            deps = () if independent else tuple(base + d for d in task.deps)
            jobs.append(Job(
                job_id=jid, arrival=dag.arrival, length=task.length,
                queue=qidx, delay=queues[qidx].delay, profile=task.profile,
                k_min=task.k_min, power=task.power, comm_size=task.comm_size,
                arch=f"{dag.name}/{task.name}", deps=deps))
            jid += 1
    return jobs


# --- criticality (the PCAPS weights) ----------------------------------------


def criticality_from_jobs(jobs: Sequence[Job]) -> dict[int, bool]:
    """Longest-path analysis over an expanded job list.

    Returns ``{job_id: on_critical_path}``: a task is critical when some
    longest path of its (weakly connected) DAG component runs through it —
    ``head(v) + tail(v) - length(v)`` reaches the component's critical-path
    length.  Tasks with no edges form their own component and are always
    critical (they ARE their longest path).  Dependencies pointing outside
    ``jobs`` are ignored (the engine validates closure separately)."""
    by_id = {j.job_id: j for j in jobs}
    preds = {j.job_id: [d for d in j.deps if d in by_id] for j in jobs}
    succs: dict[int, list[int]] = {j.job_id: [] for j in jobs}
    for jid, ps in preds.items():
        for p in ps:
            succs[p].append(jid)

    # Kahn topological order (job lists from expand_dags are already
    # topological by construction; hand-built lists might not be).
    indeg = {jid: len(ps) for jid, ps in preds.items()}
    order = [jid for jid, d in indeg.items() if d == 0]
    i = 0
    while i < len(order):
        for s in succs[order[i]]:
            indeg[s] -= 1
            if indeg[s] == 0:
                order.append(s)
        i += 1
    if len(order) != len(jobs):
        raise ValueError("dependency cycle in job list")

    head: dict[int, float] = {}
    tail: dict[int, float] = {}
    for jid in order:
        head[jid] = by_id[jid].length + max(
            (head[p] for p in preds[jid]), default=0.0)
    for jid in reversed(order):
        tail[jid] = by_id[jid].length + max(
            (tail[s] for s in succs[jid]), default=0.0)

    # Weakly-connected components via union-find over the edges.
    root = {jid: jid for jid in by_id}

    def find(x: int) -> int:
        while root[x] != x:
            root[x] = root[root[x]]
            x = root[x]
        return x

    for jid, ps in preds.items():
        for p in ps:
            root[find(p)] = find(jid)
    cp: dict[int, float] = {}
    for jid in by_id:
        r = find(jid)
        cp[r] = max(cp.get(r, 0.0), head[jid])
    return {jid: head[jid] + tail[jid] - by_id[jid].length
            >= cp[find(jid)] - _EPS for jid in by_id}


# --- DAG policies ------------------------------------------------------------


@dataclasses.dataclass
class DagFcfsPolicy(CarbonAgnosticPolicy):
    """Precedence-only baseline: FCFS at base scale over *ready* tasks.

    Identical to ``carbon-agnostic`` (including the packed vector fast
    path) — all precedence handling lives in the engine's gating, so this
    measures what the pipeline costs with no carbon awareness at all."""

    name: str = "dag-fcfs"


@dataclasses.dataclass
class DagCarbonPolicy(WaitAwhilePolicy):
    """CarbonFlex-style CI-rank suspend/resume applied per ready task.

    Every released task independently waits for the cleanest
    ``percentile`` % of the next-24h forecast (forced tasks run
    regardless, the run-to-completion SLO shared by all policies).  This
    IS ``wait-awhile`` — inherited, so the two stay equivalent — at a
    wider percentile, applied per ready task: the per-job carbon
    scheduler of the Bostandoost et al. study.  On independent tasks it
    is the savings upper bound; on real DAGs the precedence structure
    serialises the waits of successive stages."""

    percentile: float = 40.0
    name: str = "dag-carbon"


@dataclasses.dataclass
class DagCapPolicy:
    """PCAPS-style criticality-aware carbon scheduling.

    Longest-path-to-sink weights are computed once per DAG at window
    start: tasks on the critical path are exempt from suspension (every
    slot they spend waiting extends the whole pipeline), while slack
    tasks are deferred into the cleanest ``percentile`` % CI windows —
    recovering most of ``dag-carbon``'s savings at a fraction of its
    completion-time cost."""

    percentile: float = 40.0
    name: str = "dag-cap"

    def on_window_start(self, ci, t0, horizon, jobs, cluster) -> None:
        self._critical = criticality_from_jobs(jobs)

    def decide(self, t, active, ci, cluster):
        thresh = ci.percentile_threshold(t, self.percentile)
        low_carbon = ci.ci(t) <= thresh + 1e-12
        crit = self._critical
        alloc = _fcfs_base_alloc(
            active, cluster.capacity,
            eligible=lambda a: low_carbon or crit.get(a.job.job_id, True))
        return cluster.capacity, alloc

    def on_completion(self, t, job, violated) -> None:
        pass
