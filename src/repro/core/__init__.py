"""CarbonFlex core: the paper's contribution as a composable library.

Public surface:

- ``oracle.solve``                 — Algorithm 1 (offline optimal)
- ``knowledge.KnowledgeBase``      — Table-2 state -> (m, rho) case base
- ``provisioning.provision``       — Algorithm 2 (phi)
- ``scheduling.schedule``          — Algorithm 3 (psi)
- ``policy.CarbonFlexPolicy``      — the runtime resource manager
- ``mpc.CarbonFlexMPCPolicy``      — receding-horizon execution planner
                                     (+ ``CarbonFlexScalePolicy`` marginal-
                                     capacity scale-up, ``oracle-estimated``
                                     oracle on learned lengths)
- ``policy.learn_window``          — the continuous-learning phase
- ``simulator.simulate``           — the CarbonFlex-Simulator engine
                                     (vectorised; ``engine="scalar"`` for
                                     the reference path)
- ``simulator.simulate_many``      — batched (seeds x regions x policies)
                                     sweeps through the vector engine
- ``baselines``                    — §6 baselines (agnostic/GAIA/WaitAwhile/
                                     CarbonScaler/VCC)
- ``policy.Policy``                — the protocol every policy implements
- ``geo``                          — geo-distributed placement policies
                                     (``geo-static``/``geo-greedy``/
                                     ``geo-flex``) over ``GeoCluster`` +
                                     ``MultiRegionCarbonService`` worlds
- ``dag``                          — precedence-aware DAG workloads:
                                     ``DagSpec``/``TaskNode``, criticality
                                     analysis, and the ``dag-fcfs``/
                                     ``dag-carbon``/``dag-cap`` policies
                                     over dependency-gated engine runs
- ``forecast``                     — pluggable carbon-forecast models
                                     (perfect / persistence / noisy AR(1)
                                     / quantile ensemble) behind
                                     ``CarbonService.forecast``, plus the
                                     quantile view robust policies use
- ``faults``                       — resilience layer: pluggable fault
                                     processes (iid stragglers, correlated
                                     failure-domain outages, preemption
                                     with checkpoint/restore) and
                                     carbon-feed outage injection with a
                                     degraded policy-side CI view

The declarative experiment layer (policy registry, ``Scenario``, ``run``,
``Sweep``) lives one level up in ``repro.experiment``.
"""
from . import baselines, carbon, dag, emissions, faults, forecast, geo, knowledge, mpc, oracle, policy, profiles, provisioning, scheduling, simulator, types  # noqa: F401
from .carbon import CarbonService, MultiRegionCarbonService, synthesize_trace  # noqa: F401
from .dag import (DagCapPolicy, DagCarbonPolicy, DagFcfsPolicy, DagSpec,  # noqa: F401
                  TaskNode, criticality_from_jobs, expand_dags)
from .faults import (CarbonDataOutage, CorrelatedFaults, FaultProcess,  # noqa: F401
                     IidFaults, PreemptionFaults, fault_from_dict,
                     fault_label, fault_to_dict, outage_from_dict,
                     outage_to_dict)
from .forecast import (ForecastModel, NoisyForecast, PerfectForecast,  # noqa: F401
                       PersistenceForecast, QuantileForecast,
                       StaticNoiseForecast, forecast_from_dict,
                       forecast_label, forecast_to_dict)
from .geo import GeoFlexPolicy, GeoGreedyPolicy, GeoPolicy, GeoStaticPolicy  # noqa: F401
from .knowledge import KnowledgeBase  # noqa: F401
from .mpc import (CarbonFlexMPCPolicy, CarbonFlexScalePolicy,  # noqa: F401
                  EstimatedOraclePolicy, MPCConfig)
from .policy import (CarbonFlexPolicy, LearnOutcome, OraclePolicy, Policy,  # noqa: F401
                     learn_window)
from .simulator import FaultModel, SimCase, simulate, simulate_many  # noqa: F401
from .types import (ClusterConfig, GeoCluster, Job, MigrationModel,  # noqa: F401
                    QueueConfig, ResilienceMetrics, SimResult)
