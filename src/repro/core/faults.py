"""Resilience subsystem: structured fault processes + carbon-feed outages.

CarbonFlex's value proposition is suspend/resume and rescale under a
*changing* environment, yet the original disturbance model was a single
iid per-job straggler/failure coin-flip plus a carbon feed that is always
fresh.  This module makes failure a pluggable, structured process:

- :class:`IidFaults`         — the historical ``FaultModel`` semantics,
  bit-for-bit (``FaultModel`` is kept as an alias / deprecation shim);
- :class:`CorrelatedFaults`  — a seeded Markov (burst on/off) outage
  process over *failure domains* (node group / rack / region slice) that
  removes capacity for a duration and evicts the jobs placed there;
- :class:`PreemptionFaults`  — per-job kill events with checkpoint/restore
  semantics: work since the last checkpoint is lost, a configurable
  checkpoint cadence charges overhead slots, and the restore transfer is
  billed at the *current* CI (the :class:`~repro.core.types.MigrationModel`
  accounting shape).

Separately, :class:`CarbonDataOutage` + :class:`DegradedCIView` inject
stale/gap windows into ``CarbonService`` / ``MultiRegionCarbonService``:
while the feed is stale the policy stack sees last-known-good values, and
past ``stale_after`` slots it falls back to last-known-good +
:class:`~repro.core.forecast.PersistenceForecast` instead of reading
garbage.  ``fetch`` exposes the retry/backoff schedule.  Recovery metrics
(evictions, lost work, time degraded, MTTR) land on
``SimResult.resilience``.

Both simulator engines consume a fault process through the *same*
``begin_slot``/``available_capacity``/``apply`` calls in the same
row-ordered job sequence, so cross-engine bit-identity holds by
construction (tests/test_resilience.py).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Protocol, Sequence, runtime_checkable

import numpy as np

from .forecast import (ForecastFeatureMixin, PersistenceForecast,
                       _trace_salt)
from .types import Job, ResilienceMetrics


@dataclasses.dataclass
class SlotDisturbance:
    """What a fault process did to one slot's allocated live jobs.

    ``factors`` scales each job's progress this slot (0 = slot lost).
    ``lost`` is per-job work *re-added* to ``remaining`` after the progress
    update (checkpoint rollback).  ``extra_energy`` is per-job energy (kWh)
    charged this slot at the current CI (restore transfer).  ``evicted``
    flags jobs kicked off failed capacity.  The optional arrays stay
    ``None`` when untouched so the legacy paths skip them entirely —
    bit-identical floats to the pre-subsystem engines."""

    factors: np.ndarray
    lost: np.ndarray | None = None
    extra_energy: np.ndarray | None = None
    evicted: np.ndarray | None = None


@runtime_checkable
class FaultProcess(Protocol):
    """The disturbance protocol both simulator engines drive.

    Per run: ``on_run_start(t0, capacity)`` resets the seeded RNG and all
    per-run state (so one instance is reusable across ``simulate`` calls
    with reproducible streams).  Per slot, in engine order:
    ``begin_slot(t)`` advances environment chains (before the policy
    decides), ``available_capacity``/``available_capacity_vec`` report the
    capacity the scheduler may use, and ``apply`` disturbs the allocated
    live jobs (row order — identical across engines).  ``run_metrics``
    summarises the run."""

    kind: str

    def on_run_start(self, t0: int, capacity) -> None: ...

    def begin_slot(self, t: int) -> None: ...

    def available_capacity(self, capacity: int) -> int: ...

    def available_capacity_vec(self, caps: np.ndarray) -> np.ndarray: ...

    def apply(self, t: int, jobs: Sequence[Job], k: np.ndarray,
              remaining: np.ndarray, thr: np.ndarray,
              regions: np.ndarray | None = None) -> SlotDisturbance: ...

    def run_metrics(self) -> ResilienceMetrics: ...


@dataclasses.dataclass
class IidFaults:
    """Iid per-job straggler/failure injection (DESIGN.md §10).

    Each slot, every allocated job independently suffers a *straggler*
    event with probability ``straggler_rate`` (progress scaled by
    ``straggler_slowdown``) or a *failure* with probability
    ``failure_rate`` (the slot's progress is lost).  Seeded and
    deterministic; bit-for-bit the historical ``FaultModel`` behaviour
    (``FaultModel`` aliases this class).  ``on_run_start`` re-seeds the
    stream, so reusing one instance across simulations is reproducible."""

    straggler_rate: float = 0.0
    straggler_slowdown: float = 0.5
    failure_rate: float = 0.0
    seed: int = 0

    kind: ClassVar[str] = "iid"

    def __post_init__(self) -> None:
        self._reset()

    def _reset(self) -> None:
        self._rng = np.random.default_rng(self.seed)
        self._lost_work = 0.0

    # --- FaultProcess protocol ---------------------------------------------

    def on_run_start(self, t0: int, capacity) -> None:
        self._reset()

    def begin_slot(self, t: int) -> None:
        pass

    def available_capacity(self, capacity: int) -> int:
        return capacity

    def available_capacity_vec(self, caps: np.ndarray) -> np.ndarray:
        return caps

    def apply(self, t: int, jobs: Sequence[Job], k: np.ndarray,
              remaining: np.ndarray, thr: np.ndarray,
              regions: np.ndarray | None = None) -> SlotDisturbance:
        f = self.draw_factors(len(thr))
        if len(thr):
            self._lost_work += float(np.sum(thr * (1.0 - f)))
        return SlotDisturbance(factors=f)

    def run_metrics(self) -> ResilienceMetrics:
        return ResilienceMetrics(lost_work_slots=self._lost_work)

    # --- historical FaultModel surface -------------------------------------

    def progress_factor(self, t: int, job_id: int) -> float:
        u = self._rng.random()
        if u < self.failure_rate:
            return 0.0
        if u < self.failure_rate + self.straggler_rate:
            return self.straggler_slowdown
        return 1.0

    def draw_factors(self, count: int) -> np.ndarray:
        """Vectorised batch of ``count`` progress factors.

        ``Generator.random(count)`` consumes exactly the same underlying
        bit stream as ``count`` successive ``progress_factor`` calls, so
        the vector engine's per-slot batch draw reproduces the scalar
        engine's sequential draws bit-for-bit (asserted by the parity
        tests)."""
        u = self._rng.random(count)
        return np.where(
            u < self.failure_rate, 0.0,
            np.where(u < self.failure_rate + self.straggler_rate,
                     self.straggler_slowdown, 1.0))


#: Deprecation shim: the historical name resolves to the iid process.  An
#: alias (not a subclass) so dataclass equality, ``isinstance`` checks and
#: ``dataclasses.replace`` keep working across old and new call sites.
FaultModel = IidFaults


@dataclasses.dataclass
class CorrelatedFaults:
    """Markov burst outages over failure domains (rack / zone slices).

    The cluster's server positions are partitioned into ``n_domains``
    near-equal contiguous domains.  Each slot every *up* domain fails with
    probability ``rate`` and every *down* domain recovers with probability
    ``1/mean_duration`` (geometric outage length with mean
    ``mean_duration`` slots).  A failure is revealed mid-slot: the
    scheduler only sees the shrunken capacity from the *next* slot on,
    and every job whose servers land in the failed domain this slot is
    evicted (the slot's progress is lost; the job re-queues under the
    reduced capacity).  Job placement is the engines' row-ordered
    sequential packing into the domains that were up at decision time —
    deterministic, hence bit-identical across engines."""

    n_domains: int = 4
    rate: float = 0.02
    mean_duration: float = 8.0
    seed: int = 0

    kind: ClassVar[str] = "correlated"

    def __post_init__(self) -> None:
        if self.n_domains < 1:
            raise ValueError("CorrelatedFaults needs n_domains >= 1")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        if self.mean_duration < 1.0:
            raise ValueError("mean_duration must be >= 1 slot")
        self.on_run_start(0, 0)

    # --- FaultProcess protocol ---------------------------------------------

    def on_run_start(self, t0: int, capacity) -> None:
        caps = np.atleast_1d(np.asarray(capacity, dtype=np.int64))
        self._region_caps = caps
        self._rlo = np.concatenate(([0], np.cumsum(caps)))
        total = int(caps.sum())
        base, rem = divmod(total, self.n_domains)
        self._dcaps = np.array([base + (1 if i < rem else 0)
                                for i in range(self.n_domains)],
                               dtype=np.int64)
        self._dlo = np.concatenate(([0], np.cumsum(self._dcaps)))
        self._down = np.zeros(self.n_domains, dtype=bool)
        self._newly = np.zeros(self.n_domains, dtype=bool)
        self._down_at = np.zeros(self.n_domains, dtype=np.int64)
        self._rng = np.random.default_rng(self.seed)
        self._evictions = 0
        self._lost_work = 0.0
        self._outages = 0
        self._mttr_sum = 0
        self._mttr_n = 0

    def begin_slot(self, t: int) -> None:
        # last slot's failures become known to the scheduler now
        self._down |= self._newly
        self._newly = np.zeros(self.n_domains, dtype=bool)
        u = self._rng.random(self.n_domains)
        p_rec = 1.0 / self.mean_duration
        for i in range(self.n_domains):
            if self._down[i]:
                if u[i] < p_rec:
                    self._down[i] = False
                    self._mttr_sum += int(t - self._down_at[i])
                    self._mttr_n += 1
            elif u[i] < self.rate and self._dcaps[i] > 0:
                self._newly[i] = True
                self._down_at[i] = t
                self._outages += 1

    def available_capacity(self, capacity: int) -> int:
        lost = int(self._dcaps[self._down].sum())
        return max(0, int(capacity) - lost)

    def available_capacity_vec(self, caps: np.ndarray) -> np.ndarray:
        out = np.asarray(caps, dtype=np.int64).copy()
        for d in np.flatnonzero(self._down):
            dlo, dhi = int(self._dlo[d]), int(self._dlo[d + 1])
            for r in range(len(out)):
                a = max(dlo, int(self._rlo[r]))
                b = min(dhi, int(self._rlo[r + 1]))
                if a < b:
                    out[r] -= b - a
        return np.maximum(out, 0)

    def _up_segments(self, lo: int, hi: int) -> list[tuple[int, bool]]:
        """(length, failed_this_slot) runs of up-at-decision-time server
        positions inside ``[lo, hi)``, in position order."""
        segs = []
        for d in range(self.n_domains):
            a = max(lo, int(self._dlo[d]))
            b = min(hi, int(self._dlo[d + 1]))
            if a < b and not self._down[d]:
                segs.append((b - a, bool(self._newly[d])))
        return segs

    def apply(self, t: int, jobs: Sequence[Job], k: np.ndarray,
              remaining: np.ndarray, thr: np.ndarray,
              regions: np.ndarray | None = None) -> SlotDisturbance:
        m = len(thr)
        f = np.ones(m)
        if m == 0 or not self._newly.any():
            return SlotDisturbance(factors=f)
        regs = (np.zeros(m, dtype=np.int64) if regions is None
                else np.asarray(regions, dtype=np.int64))
        ev = np.zeros(m, dtype=bool)
        for r in range(len(self._region_caps)):
            segs = self._up_segments(int(self._rlo[r]), int(self._rlo[r + 1]))
            total_up = sum(length for length, _ in segs)
            off = 0
            for i in np.flatnonzero(regs == r):
                kk = int(k[i])
                lo, hi = off, off + kk
                off = hi
                if hi > total_up:
                    ev[i] = True       # spilled past usable capacity
                    continue
                pos = 0
                for length, newly in segs:
                    nxt = pos + length
                    if newly and lo < nxt and hi > pos:
                        ev[i] = True
                        break
                    pos = nxt
                    if pos >= hi:
                        break
        if ev.any():
            f[ev] = 0.0
            self._evictions += int(ev.sum())
            self._lost_work += float(np.sum(thr[ev]))
            return SlotDisturbance(factors=f, evicted=ev)
        return SlotDisturbance(factors=f)

    def run_metrics(self) -> ResilienceMetrics:
        mttr = self._mttr_sum / self._mttr_n if self._mttr_n else 0.0
        return ResilienceMetrics(
            evictions=self._evictions, lost_work_slots=self._lost_work,
            capacity_outages=self._outages, mttr_slots=mttr)


@dataclasses.dataclass
class PreemptionFaults:
    """Per-job preemption with checkpoint/restore semantics.

    Each slot every allocated job is killed with probability ``rate``:
    progress since its last checkpoint is rolled back, the checkpoint
    payload (``max(min_gb, comm_size)`` GB — the
    :class:`~repro.core.types.MigrationModel` shape) is re-transferred at
    ``energy_kwh_per_gb``, billed at the *current* CI, and the job then
    spends ``restore_slots`` slots restoring: holding its servers and
    burning energy without progress.  Every ``checkpoint_every``-th
    uninterrupted running slot is a checkpoint slot, charging
    ``checkpoint_overhead`` of that slot's progress to save state."""

    rate: float = 0.05
    checkpoint_every: int = 4
    checkpoint_overhead: float = 0.25
    restore_slots: int = 1
    energy_kwh_per_gb: float = 0.05
    min_gb: float = 1.0
    seed: int = 0

    kind: ClassVar[str] = "preemption"

    def __post_init__(self) -> None:
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        if self.checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1 slot")
        if not 0.0 <= self.checkpoint_overhead < 1.0:
            raise ValueError("checkpoint_overhead must be in [0, 1)")
        if self.restore_slots < 0:
            raise ValueError("restore_slots must be >= 0")
        self.on_run_start(0, 0)

    # --- FaultProcess protocol ---------------------------------------------

    def on_run_start(self, t0: int, capacity) -> None:
        self._rng = np.random.default_rng(self.seed)
        self._ckpt: dict[int, float] = {}        # remaining at last ckpt
        self._run_slots: dict[int, int] = {}     # slots since last restart
        self._restore: dict[int, int] = {}       # restore slots left
        self._preemptions = 0
        self._lost_work = 0.0
        self._restore_energy = 0.0

    def begin_slot(self, t: int) -> None:
        pass

    def available_capacity(self, capacity: int) -> int:
        return capacity

    def available_capacity_vec(self, caps: np.ndarray) -> np.ndarray:
        return caps

    def apply(self, t: int, jobs: Sequence[Job], k: np.ndarray,
              remaining: np.ndarray, thr: np.ndarray,
              regions: np.ndarray | None = None) -> SlotDisturbance:
        m = len(thr)
        f = np.ones(m)
        lost: np.ndarray | None = None
        extra: np.ndarray | None = None
        u = self._rng.random(m)
        for i in range(m):
            jid = jobs[i].job_id
            rleft = self._restore.get(jid, 0)
            if rleft > 0:
                # restoring: holds servers, burns energy, no progress
                f[i] = 0.0
                self._restore[jid] = rleft - 1
                continue
            if u[i] < self.rate:
                # killed: roll back to the last checkpoint and re-transfer
                f[i] = 0.0
                ckpt = self._ckpt.get(jid, jobs[i].length)
                rb = float(ckpt - remaining[i])
                if rb != 0.0:
                    if lost is None:
                        lost = np.zeros(m)
                    lost[i] = rb
                e = self.energy_kwh_per_gb * max(self.min_gb,
                                                 jobs[i].comm_size)
                if extra is None:
                    extra = np.zeros(m)
                extra[i] = e
                self._preemptions += 1
                self._lost_work += rb + float(thr[i])
                self._restore_energy += e
                if self.restore_slots > 0:
                    self._restore[jid] = self.restore_slots
                self._run_slots[jid] = 0
                continue
            ns = self._run_slots.get(jid, 0) + 1
            self._run_slots[jid] = ns
            if ns % self.checkpoint_every == 0:
                # checkpoint slot: part of the slot goes to saving state;
                # the stored value is the engine's exact post-slot
                # remaining (same IEEE expression), so a later rollback
                # restores it bit-for-bit
                f[i] = 1.0 - self.checkpoint_overhead
                self._ckpt[jid] = float(remaining[i] - thr[i] * f[i])
        return SlotDisturbance(factors=f, lost=lost, extra_energy=extra)

    def run_metrics(self) -> ResilienceMetrics:
        return ResilienceMetrics(
            preemptions=self._preemptions, lost_work_slots=self._lost_work,
            restore_energy_kwh=self._restore_energy)


class _LegacyFaultAdapter:
    """FaultProcess facade over a foreign object that only implements the
    historical ``draw_factors`` surface (API compat for user-defined fault
    models predating the protocol)."""

    kind = "legacy"

    def __init__(self, inner) -> None:
        self.inner = inner

    def on_run_start(self, t0: int, capacity) -> None:
        pass                           # legacy models manage their own stream

    def begin_slot(self, t: int) -> None:
        pass

    def available_capacity(self, capacity: int) -> int:
        return capacity

    def available_capacity_vec(self, caps: np.ndarray) -> np.ndarray:
        return caps

    def apply(self, t: int, jobs: Sequence[Job], k: np.ndarray,
              remaining: np.ndarray, thr: np.ndarray,
              regions: np.ndarray | None = None) -> SlotDisturbance:
        return SlotDisturbance(factors=self.inner.draw_factors(len(thr)))

    def run_metrics(self) -> ResilienceMetrics:
        return ResilienceMetrics()


def ensure_fault_process(faults):
    """Adapt whatever the caller passed as ``faults`` to the protocol."""
    if faults is None or hasattr(faults, "apply"):
        return faults
    if hasattr(faults, "draw_factors"):
        return _LegacyFaultAdapter(faults)
    raise TypeError(f"{type(faults).__name__} implements neither the "
                    f"FaultProcess protocol nor the legacy draw_factors "
                    f"surface")


# --- carbon-data outages ------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CarbonDataOutage:
    """Stale/gap windows of the carbon-intensity feed.

    Either explicit ``windows`` (``(lo, hi)`` slot ranges, hi exclusive)
    or a seeded Markov process: each slot the feed goes stale with
    probability ``rate`` and recovers with probability
    ``1/mean_duration``.  Slot 0 is always fresh (a last-known-good value
    must exist).  ``stale_after`` is the staleness threshold past which
    policies stop trusting the last issued forecast and fall back to
    last-known-good + persistence (:class:`DegradedCIView`).
    ``retry_delay`` is the exponential-backoff schedule of the feed
    re-fetch loop surfaced by :meth:`DegradedCIView.fetch`."""

    rate: float = 0.01
    mean_duration: float = 6.0
    stale_after: int = 3
    backoff_base: int = 1
    backoff_cap: int = 16
    seed: int = 0
    windows: tuple[tuple[int, int], ...] = ()

    kind: ClassVar[str] = "carbon-outage"

    def __post_init__(self) -> None:
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        if self.mean_duration < 1.0:
            raise ValueError("mean_duration must be >= 1 slot")
        if self.stale_after < 0:
            raise ValueError("stale_after must be >= 0")
        if self.backoff_base < 1 or self.backoff_cap < self.backoff_base:
            raise ValueError("need 1 <= backoff_base <= backoff_cap")
        # normalize (JSON round-trips lists of lists)
        object.__setattr__(self, "windows", tuple(
            (int(lo), int(hi)) for lo, hi in self.windows))
        for lo, hi in self.windows:
            if lo >= hi:
                raise ValueError(f"empty outage window ({lo}, {hi})")

    def stale_mask(self, n: int, trace: np.ndarray) -> np.ndarray:
        """Boolean per-slot staleness over an ``n``-slot trace.  The RNG
        stream is salted per trace so aligned multi-region services sharing
        one config see *independent* outages."""
        mask = np.zeros(n, dtype=bool)
        if self.windows:
            for lo, hi in self.windows:
                mask[max(lo, 0):min(hi, n)] = True
        elif self.rate > 0.0:
            rng = np.random.default_rng(np.random.SeedSequence(
                [3, self.seed, _trace_salt(trace)]))
            u = rng.random(n)
            p_rec = 1.0 / self.mean_duration
            down = False
            for t in range(n):
                if down:
                    if u[t] < p_rec:
                        down = False
                elif u[t] < self.rate:
                    down = True
                mask[t] = down
        if n:
            mask[0] = False            # slot 0 is always observed
        return mask

    def retry_delay(self, attempt: int) -> int:
        """Backoff (slots) before retry number ``attempt`` (0-based)."""
        return int(min(self.backoff_cap,
                       self.backoff_base * 2 ** max(int(attempt), 0)))


@dataclasses.dataclass(frozen=True)
class FeedSample:
    """One read of the (possibly stale) carbon feed."""

    value: float
    fresh: bool
    staleness: int                    # slots since the last fresh sample
    attempts: int                     # re-fetches issued since it went stale
    next_retry_in: int                # slots until the next scheduled retry


# NOTE on imports: carbon.py imports this module (CarbonService grows an
# ``outage`` field + ``degraded()``), so nothing here may import carbon.
# The views below duck-type over any service exposing trace/forecast.


class DegradedCIView(ForecastFeatureMixin):
    """What the *policy stack* sees when the carbon feed has outages.

    Observed values forward-fill from the last fresh slot.  Forecasts
    degrade in two stages: while staleness is within ``stale_after`` the
    view re-serves the forecast *issued at the last fresh slot* (shifted
    to the query horizon — stale but still model-grade); past the
    threshold it stops trusting the feed and falls back to
    last-known-good + :class:`PersistenceForecast` over the observed
    (forward-filled) trace.  Deterministic per (service, outage), so both
    engines reading it stay bit-identical.  Accounting always uses the
    *true* service — physics does not go stale."""

    def __init__(self, base, outage: CarbonDataOutage) -> None:
        self.base = base
        self.outage = outage
        n = len(base.trace)
        self._stale = outage.stale_mask(n, base.trace)
        idx = np.arange(n)
        self._lkg = np.maximum.accumulate(np.where(~self._stale, idx, -1))
        self._ffill = np.asarray(base.trace)[self._lkg]
        self._fallback = PersistenceForecast()

    # --- observed surface ---------------------------------------------------

    @property
    def trace(self) -> np.ndarray:
        return self._ffill

    @property
    def horizon(self) -> int:
        return self.base.horizon

    def __len__(self) -> int:
        return len(self.base)

    def staleness(self, t: int) -> int:
        """Slots since the last fresh feed sample at slot ``t`` (0 = fresh)."""
        tt = min(max(int(t), 0), len(self._lkg) - 1)
        return int(tt - self._lkg[tt])

    def ci(self, t: int) -> float:
        return float(self._ffill[min(t, len(self._ffill) - 1)])

    def gradient(self, t: int) -> float:
        if t == 0:
            return 0.0
        prev, cur = self._ffill[t - 1], self._ffill[t]
        return float((cur - prev) / max(prev, 1e-9))

    # --- degraded forecasts -------------------------------------------------

    def forecast(self, t: int, horizon: int | None = None) -> np.ndarray:
        h = int(horizon or self.horizon)
        s = self.staleness(t)
        if s == 0:
            return self.base.forecast(t, h)
        if s <= self.outage.stale_after:
            # stale but trusted: the forecast issued at the last fresh
            # slot, shifted onto the queried horizon
            return self.base.forecast(t - s, s + h)[s:]
        return self._fallback.predict(self._ffill, t, h)

    def forecast_quantile(self, t: int, horizon: int | None = None,
                          q: float = 0.5) -> np.ndarray:
        if self.staleness(t) == 0:
            return self.base.forecast_quantile(t, horizon, q=q)
        return self.forecast(t, horizon)   # degraded mode has no bands

    # --- feed access --------------------------------------------------------

    def fetch(self, t: int) -> FeedSample:
        """Read the feed at slot ``t``, reporting the retry/backoff state
        of the re-fetch loop (exponential backoff per
        :meth:`CarbonDataOutage.retry_delay`)."""
        s = self.staleness(t)
        if s == 0:
            return FeedSample(value=self.ci(t), fresh=True, staleness=0,
                              attempts=0, next_retry_in=0)
        attempts = 0
        elapsed = 0
        while elapsed + self.outage.retry_delay(attempts) <= s:
            elapsed += self.outage.retry_delay(attempts)
            attempts += 1
        nxt = elapsed + self.outage.retry_delay(attempts) - s
        return FeedSample(value=self.ci(t), fresh=False, staleness=s,
                          attempts=attempts, next_retry_in=int(nxt))


class DegradedMultiRegionView:
    """Per-region :class:`DegradedCIView` s behind the
    ``MultiRegionCarbonService`` surface the geo policies read."""

    def __init__(self, base) -> None:
        self.base = base
        self.regions = base.regions
        self.views = tuple(s.degraded() for s in base.services)

    @property
    def n_regions(self) -> int:
        return len(self.regions)

    def __len__(self) -> int:
        return len(self.base)

    def index(self, region: str) -> int:
        return self.base.index(region)

    def service(self, region):
        if isinstance(region, str):
            region = self.index(region)
        return self.views[region]

    def ci(self, t: int, region=0) -> float:
        return self.service(region).ci(t)

    def ci_vec(self, t: int) -> np.ndarray:
        return np.array([v.ci(t) for v in self.views])

    def forecast_matrix(self, t: int, horizon: int | None = None) -> np.ndarray:
        return np.stack([v.forecast(t, horizon) for v in self.views])

    def rank_vec(self, t: int) -> np.ndarray:
        return np.array([v.rank(t) for v in self.views])

    def cleanest(self, t: int) -> int:
        return int(np.argmin(self.ci_vec(t)))

    def staleness(self, t: int) -> int:
        """Worst staleness across regions (drives the degraded-slot count)."""
        out = 0
        for v in self.views:
            s = getattr(v, "staleness", None)
            if s is not None:
                out = max(out, s(t))
        return out


# --- registry / serialization / labels ---------------------------------------


FAULT_KINDS: dict[str, type] = {
    IidFaults.kind: IidFaults,
    CorrelatedFaults.kind: CorrelatedFaults,
    PreemptionFaults.kind: PreemptionFaults,
}


def fault_to_dict(faults) -> dict | None:
    """JSON-safe payload round-tripped by :func:`fault_from_dict`."""
    if faults is None:
        return None
    kind = getattr(faults, "kind", None)
    if kind not in FAULT_KINDS:
        raise ValueError(f"unregistered fault kind {kind!r}; known kinds: "
                         f"{', '.join(sorted(FAULT_KINDS))}")
    return {"kind": kind,
            **{f.name: getattr(faults, f.name)
               for f in dataclasses.fields(faults)}}


def fault_from_dict(d: dict | None):
    """Inverse of :func:`fault_to_dict`.  A payload without ``kind`` is
    the legacy 4-field ``FaultModel`` shape and resolves to
    :class:`IidFaults`; an unknown kind raises naming the registry."""
    if d is None:
        return None
    d = dict(d)
    kind = d.pop("kind", IidFaults.kind)
    try:
        cls = FAULT_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown fault kind {kind!r}; known kinds: "
                         f"{', '.join(sorted(FAULT_KINDS))}") from None
    return cls(**d)


def outage_to_dict(outage: CarbonDataOutage | None) -> dict | None:
    if outage is None:
        return None
    d = {"kind": outage.kind,
         **{f.name: getattr(outage, f.name)
            for f in dataclasses.fields(outage)}}
    d["windows"] = [list(w) for w in outage.windows]
    return d


def outage_from_dict(d: dict | None) -> CarbonDataOutage | None:
    if d is None:
        return None
    d = dict(d)
    kind = d.pop("kind", CarbonDataOutage.kind)
    if kind != CarbonDataOutage.kind:
        raise ValueError(f"unknown carbon-outage kind {kind!r}; expected "
                         f"{CarbonDataOutage.kind!r}")
    return CarbonDataOutage(**d)


def fault_label(fm) -> str:
    """Short sweep-row label per fault process (the iid format is frozen —
    golden fixtures and EXPERIMENTS tables key on it)."""
    if fm is None:
        return "none"
    kind = getattr(fm, "kind", None)
    if kind == "iid":
        return f"straggler={fm.straggler_rate:g},failure={fm.failure_rate:g}"
    if kind == "correlated":
        return (f"outage(d={fm.n_domains},p={fm.rate:g},"
                f"len={fm.mean_duration:g})")
    if kind == "preemption":
        return f"preempt(p={fm.rate:g},ckpt={fm.checkpoint_every})"
    return str(kind or "fault")
