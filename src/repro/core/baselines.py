"""Baseline policies from the paper's evaluation (§6.1) + VCC (§6.7).

All baselines honour run-to-completion after the permitted delay, share the
capacity limit M, and (for fairness, as in the paper) may use the *mean
historical job length* where the real length is needed.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .carbon import CarbonService
from .forecast import QuantileCIView
from .scheduling import ActiveJob
from .types import ClusterConfig


def _fcfs_base_alloc(active: list[ActiveJob], m_t: int,
                     eligible=lambda a: True) -> dict[int, int]:
    """FCFS non-elastic allocation at k_min; forced jobs always first."""
    alloc: dict[int, int] = {}
    used = 0
    ordered = sorted((a for a in active if not a.done),
                     key=lambda a: (not a.forced, a.job.arrival, a.job.job_id))
    for a in ordered:
        if not a.forced and not eligible(a):
            continue
        k = a.job.k_min
        if used + k > m_t:
            continue
        alloc[a.job.job_id] = k
        used += k
    return alloc


def _elastic_fill(active: list[ActiveJob], alloc: dict[int, int], m_t: int,
                  min_marginal: float = 0.35) -> None:
    """Scale allocated jobs up by marginal throughput until m_t is filled.

    ``min_marginal`` floors the scaling: below it the energy per unit work
    (1/p) exceeds the typical clean/dirty CI ratio, so filling capacity
    with such increments *increases* carbon (observed on Fig. 14's
    VCC-scaling before the floor was added)."""
    by_id = {a.job.job_id: a for a in active}
    used = sum(alloc.values())
    entries = []
    for jid, k0 in alloc.items():
        a = by_id[jid]
        for k in range(k0 + 1, a.job.k_max + 1):
            if a.job.marginal(k) >= min_marginal:
                entries.append((-a.job.marginal(k), jid, k))
    entries.sort()
    for negp, jid, k in entries:
        if used >= m_t:
            break
        if alloc.get(jid, 0) == k - 1:
            alloc[jid] = k
            used += 1


@dataclasses.dataclass
class CarbonAgnosticPolicy:
    """Status quo: FCFS, no elasticity, run immediately, full capacity."""

    # decide_packed is compliant by construction (k in {0, k_min}, active
    # rows only, fill capped at the m_t it returns) -> the vector engine
    # skips its per-slot defensive re-validation (see _simulate_vector)
    packed_safe = True

    name: str = "carbon-agnostic"

    def on_window_start(self, ci, t0, horizon, jobs, cluster) -> None:
        pass

    def decide(self, t, active, ci, cluster):
        return cluster.capacity, _fcfs_base_alloc(active, cluster.capacity)

    def decide_packed(self, t, eng, ci, cluster):
        """Vector-engine fast path: FCFS over packed arrays.  Active rows
        are already (arrival, job_id)-sorted, so the FCFS order is forced
        rows then unforced rows, each in row order — identical to the
        ``_fcfs_base_alloc`` sort key."""
        rows = eng.rows[eng.remaining[eng.rows] > 1e-9]   # skip done jobs
        slack = eng.slack_left[rows]
        order = np.concatenate([rows[slack <= 0], rows[slack > 0]])
        kmin = eng.packed.k_min
        kvec = np.zeros(eng.packed.n, dtype=np.int64)
        m_t = cluster.capacity
        used = 0
        for r in order.tolist():
            k = int(kmin[r])
            if used + k > m_t:
                continue
            kvec[r] = k
            used += k
        return m_t, kvec

    def on_completion(self, t, job, violated) -> None:
        pass


@dataclasses.dataclass
class GaiaPolicy:
    """GAIA's Lowest-Window policy: per job, at arrival, choose the start
    time within its slack minimising mean CI over the *estimated* (mean
    historical) job length; non-elastic; FCFS on conflicts."""

    mean_length: float = 4.0
    name: str = "gaia"

    def on_window_start(self, ci, t0, horizon, jobs, cluster) -> None:
        self._start: dict[int, int] = {}

    def _plan(self, a: ActiveJob, t: int, ci: CarbonService) -> int:
        ell = max(1, int(round(self.mean_length)))
        horizon = a.job.delay + ell
        fc = ci.forecast(t, horizon)
        best_s, best_c = 0, np.inf
        for s in range(0, a.job.delay + 1):
            c = float(np.mean(fc[s:s + ell])) if s + ell <= len(fc) else np.inf
            if c < best_c:
                best_s, best_c = s, c
        return t + best_s

    def decide(self, t, active, ci, cluster):
        for a in active:
            if a.job.job_id not in self._start:
                self._start[a.job.job_id] = self._plan(a, t, ci)
        alloc = _fcfs_base_alloc(
            active, cluster.capacity,
            eligible=lambda a: t >= self._start[a.job.job_id] or a.started,
        )
        return cluster.capacity, alloc

    def on_completion(self, t, job, violated) -> None:
        pass


@dataclasses.dataclass
class WaitAwhilePolicy:
    """Threshold Wait-Awhile: suspend/resume on the 30th percentile of the
    next-24h CI forecast; run to completion once the delay is spent."""

    percentile: float = 30.0
    name: str = "wait-awhile"

    def on_window_start(self, ci, t0, horizon, jobs, cluster) -> None:
        pass

    def decide(self, t, active, ci, cluster):
        thresh = ci.percentile_threshold(t, self.percentile)
        low_carbon = ci.ci(t) <= thresh + 1e-12
        alloc = _fcfs_base_alloc(active, cluster.capacity,
                                 eligible=lambda a: low_carbon)
        return cluster.capacity, alloc

    def on_completion(self, t, job, violated) -> None:
        pass


@dataclasses.dataclass
class RobustWaitAwhilePolicy(WaitAwhilePolicy):
    """Wait-Awhile thresholding on a configurable forecast *quantile*
    instead of the point forecast (ISSUE-5 robust variant).

    Under noisy forecasts the plain policy chases phantom dips: spurious
    low-CI slots in a single noisy path drag the 30th-percentile threshold
    down, the job waits for clean slots that never materialize, and runs
    forced at whatever CI the deadline lands on.  Computing the threshold
    from the ``quantile`` band of the forecast distribution (the ensemble
    quantile for :class:`~repro.core.forecast.QuantileForecast`, the
    analytic band for :class:`~repro.core.forecast.NoisyForecast`) filters
    that single-path noise; under a perfect forecast every band collapses
    onto the truth and the policy is bit-identical to ``wait-awhile``."""

    quantile: float = 0.7
    name: str = "wait-awhile-robust"

    def decide(self, t, active, ci, cluster):
        # the plain rule, with every forecast read routed through the
        # quantile band (ci()/gradient() still read the truth) — one
        # shared threshold implementation, one quantile knob
        return super().decide(t, active, QuantileCIView(ci, self.quantile),
                              cluster)


@dataclasses.dataclass
class CarbonScalerPolicy:
    """CarbonScaler adapted to a multi-job cluster (§6.1): each job plans
    its own elastic schedule over its window using the mean historical
    length; at runtime, cluster capacity is reconciled by prioritising
    higher-marginal-throughput increments."""

    mean_length: float = 4.0
    name: str = "carbonscaler"

    def on_window_start(self, ci, t0, horizon, jobs, cluster) -> None:
        self._plan: dict[int, np.ndarray] = {}
        self._plan_t0: dict[int, int] = {}

    def _make_plan(self, a: ActiveJob, t: int, ci: CarbonService) -> np.ndarray:
        """Single-job Algorithm-1 greedy over the job's own window, using the
        estimated length (this is CarbonScaler's per-job schedule)."""
        job = a.job
        est = max(1.0, self.mean_length)
        span = int(np.ceil(est)) + job.delay
        fc = ci.forecast(t, span)
        entries = []
        for s in range(span):
            for k in range(job.k_min, job.k_max + 1):
                p = job.marginal(k)
                entries.append((-p / max(fc[s], 1e-9), s, k, p))
        entries.sort()
        alloc = np.zeros(span, dtype=np.int64)
        work = 0.0
        for negscore, s, k, p in entries:
            if work >= est - 1e-9:
                break
            is_base = k == job.k_min
            if is_base and alloc[s] != 0:
                continue
            if not is_base and alloc[s] != k - 1:
                continue
            alloc[s] = k
            work += 1.0 if is_base else p
        return alloc

    def decide(self, t, active, ci, cluster):
        desired: dict[int, int] = {}
        for a in active:
            if a.done:
                continue
            if a.forced or (a.started and a.job.job_id not in self._plan):
                desired[a.job.job_id] = a.job.k_min
                continue
            if a.job.job_id not in self._plan:
                self._plan[a.job.job_id] = self._make_plan(a, t, ci)
                self._plan_t0[a.job.job_id] = t
            plan = self._plan[a.job.job_id]
            rel = t - self._plan_t0[a.job.job_id]
            if rel < len(plan) and plan[rel] > 0:
                desired[a.job.job_id] = int(plan[rel])
            elif rel >= len(plan):
                desired[a.job.job_id] = a.job.k_min   # plan exhausted: run out
        # Cluster-capacity reconciliation: highest marginal increments win.
        by_id = {a.job.job_id: a for a in active}
        incs = []
        for jid, k in desired.items():
            job = by_id[jid].job
            incs.append((-1.0, by_id[jid].slack_left, jid, job.k_min, job.k_min))
            for kk in range(job.k_min + 1, k + 1):
                incs.append((-job.marginal(kk), by_id[jid].slack_left, jid, kk, 1))
        incs.sort()
        alloc: dict[int, int] = {}
        used = 0
        for negp, slack, jid, k, add in incs:
            cur = alloc.get(jid, 0)
            is_base = k == by_id[jid].job.k_min
            if is_base and cur != 0:
                continue
            if not is_base and cur != k - 1:
                continue
            if used + add > cluster.capacity:
                continue
            alloc[jid] = k
            used += add
        return cluster.capacity, alloc

    def on_completion(self, t, job, violated) -> None:
        self._plan.pop(job.job.job_id, None)


@dataclasses.dataclass
class VCCPolicy:
    """Google's Variable Capacity Curve (§6.7): shape the day's capacity to
    the lowest-CI slots while meeting expected daily demand; schedule FCFS
    (non-elastic) or elastically (``scaling=True``)."""

    scaling: bool = False
    utilization: float = 0.5
    name: str = "vcc"

    def __post_init__(self) -> None:
        if self.scaling:
            self.name = "vcc-scaling"

    def on_window_start(self, ci, t0, horizon, jobs, cluster) -> None:
        self._curve: dict[int, int] = {}
        self._daily_demand = self.utilization * cluster.capacity * 24

    def _plan_day(self, day_start: int, ci: CarbonService, cluster: ClusterConfig) -> None:
        fc = ci.forecast(day_start, 24)
        order = np.argsort(fc)
        m = np.zeros(24, dtype=np.int64)
        remaining = self._daily_demand
        for idx in order:
            give = int(min(cluster.capacity, np.ceil(remaining)))
            m[idx] = give
            remaining -= give
            if remaining <= 0:
                break
        for i in range(24):
            self._curve[day_start + i] = int(m[i])

    def decide(self, t, active, ci, cluster):
        if t not in self._curve:
            self._plan_day(t, ci, cluster)
        m_t = self._curve[t]
        forced_need = sum(a.job.k_min for a in active if a.forced and not a.done)
        m_t = max(m_t, min(forced_need, cluster.capacity))
        alloc = _fcfs_base_alloc(active, m_t)
        if self.scaling:
            _elastic_fill(active, alloc, m_t)
        return m_t, alloc

    def on_completion(self, t, job, violated) -> None:
        pass
