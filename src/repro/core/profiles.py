"""Elastic scaling profiles (paper §2.3 / §3).

Two sources of profiles:

1. Parametric families (Amdahl-style) mirroring the paper's Table 3
   High/Moderate/Low scalability classes — used by unit tests and the
   cluster simulator when no compiled artifact is available.

2. Roofline-derived profiles (DESIGN.md §7): given the compiled step's
   per-slice FLOPs, HBM bytes and DP-collective bytes (from the dry-run's
   ``cost_analysis`` + HLO collective scan), derive step time at DP degree k

       tau(k) = max(compute / k, memory / k, collective(k))

   with ring-all-reduce collective time ~ 2*(k-1)/k * grad_bytes / link_bw
   (flat-ish in k), then normalised marginal throughput

       T(k) = tau(1) / tau(k) * k        (work per unit time, k chunks)
       p(k) = T(k) - T(k-1),  p(k_min) = 1 by construction.

This replaces the paper's one-time wall-clock profiling (§6.1) — the
analytic profile has the same monotone-decreasing shape and plays the same
role in Algorithms 1–3.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# --- parametric profiles -------------------------------------------------

# Mirrors Table 3 scalability classes. Values chosen so that the mean
# marginal throughput (elasticity) is ~0.95 / ~0.75 / ~0.45.
_CLASS_SIGMA = {"high": 0.05, "moderate": 0.35, "low": 0.9}


def amdahl_profile(k_min: int, k_max: int, sigma: float) -> np.ndarray:
    """Marginal-throughput profile from an Amdahl-like throughput curve.

    Throughput at scale k: T(k) = k / (1 + sigma * (k - 1)).  sigma = 0 is
    linear scaling; larger sigma = more communication per unit compute.
    Returns marginals p[i] = T(k_min+i) - T(k_min+i-1), normalised so
    p(k_min) = 1 (paper §3 requires p_j(k_min) = 1).
    """
    ks = np.arange(k_min - 1, k_max + 1, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        t = np.where(ks > 0, ks / (1.0 + sigma * (ks - 1.0)), 0.0)
    marg = np.diff(t)
    base = marg[0]
    if base <= 0:
        raise ValueError("degenerate profile")
    # Negative marginals (sigma > 1: adding servers would *hurt*) clamp to
    # zero — a rational scheduler simply never allocates past the peak.
    p = np.maximum(marg / base, 0.0)
    # Guard strict monotone decrease (Theorem 4.1 condition 1).
    p = np.minimum.accumulate(p)
    return p


def class_profile(scalability: str, k_min: int = 1, k_max: int = 16) -> np.ndarray:
    return amdahl_profile(k_min, k_max, _CLASS_SIGMA[scalability])


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One entry of the paper's Table 3: a profiled elastic workload."""

    name: str
    impl: str                  # "MPI" | "Pytorch" | "JAX"
    comm_size_mb: float
    scalability: str           # "high" | "moderate" | "low"
    power_kw: float = 1.0      # per-server draw (GPU cluster: heterogeneous)

    def profile(self, k_min: int = 1, k_max: int = 16) -> np.ndarray:
        return class_profile(self.scalability, k_min, k_max)


# The paper's Table 3 workload mix (names + comm sizes + classes).  Power
# numbers for the GPU cluster follow the paper's observation that highly
# scalable (compute-dense) workloads draw more power.
TABLE3_WORKLOADS: tuple[WorkloadSpec, ...] = (
    WorkloadSpec("nbody-100k", "MPI", 5.3, "high", 1.00),
    WorkloadSpec("nbody-50k", "MPI", 0.53, "high", 1.00),
    WorkloadSpec("nbody-2k", "MPI", 0.16, "moderate", 0.85),
    WorkloadSpec("jacobi-10k", "MPI", 0.1, "moderate", 0.85),
    WorkloadSpec("jacobi-1k", "MPI", 51.2, "low", 0.70),
    WorkloadSpec("lammps", "MPI", 28.6, "low", 0.70),
    WorkloadSpec("gromacs", "MPI", 7.16, "low", 0.70),
    WorkloadSpec("vgg16", "Pytorch", 233.1, "low", 0.70),
    WorkloadSpec("resnet18", "Pytorch", 44.7, "low", 0.72),
    WorkloadSpec("resnet50", "Pytorch", 97.8, "moderate", 0.85),
    WorkloadSpec("efficientnetv2-s", "Pytorch", 170.5, "high", 1.00),
    WorkloadSpec("effnet-s", "Pytorch", 82.7, "high", 1.00),
    WorkloadSpec("vit-b32", "Pytorch", 336.6, "moderate", 0.85),
)


# --- roofline-derived profiles -------------------------------------------


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """Per-slice compiled-step roofline inputs (seconds are derived)."""

    flops: float                 # HLO FLOPs per step per slice
    hbm_bytes: float             # HLO bytes accessed per step per slice
    grad_bytes: float            # DP all-reduce payload per step (model grads)
    peak_flops: float = 197e12   # TPU v5e bf16
    hbm_bw: float = 819e9        # bytes/s
    link_bw: float = 50e9        # ICI bytes/s/link

    def step_time(self, k: int) -> float:
        """Roofline step time when the job's work is split over k slices."""
        compute = self.flops / k / self.peak_flops
        memory = self.hbm_bytes / k / self.hbm_bw
        if k == 1:
            coll = 0.0
        else:
            coll = 2.0 * (k - 1) / k * self.grad_bytes / self.link_bw
        return max(compute, memory) + coll


def roofline_profile(terms: RooflineTerms, k_min: int = 1, k_max: int = 16) -> np.ndarray:
    """Marginal-throughput profile from compiled roofline terms.

    Strong scaling of a fixed per-step workload: throughput at k slices is
    T(k) = tau(k_min) / tau(k) (normalised so T(k_min) = 1 slice-unit of
    work rate times k_min...); marginals are the discrete derivative,
    normalised to p(k_min) = 1 per the paper's §3 convention."""
    ks = np.arange(k_min - 1, k_max + 1)
    t = np.zeros(len(ks))
    base = terms.step_time(max(k_min, 1))
    for i, k in enumerate(ks):
        t[i] = 0.0 if k <= 0 else base / terms.step_time(int(k)) * max(k_min, 1)
    marg = np.diff(t)
    base_m = marg[0]
    if base_m <= 0:
        raise ValueError("degenerate roofline profile")
    p = np.maximum(marg / base_m, 0.0)
    return np.minimum.accumulate(p)


def elasticity_of(profile: np.ndarray) -> float:
    return float(np.mean(profile))


def terms_from_dryrun(arch: str, dryrun_dir: str = "results/dryrun_opt",
                      shape: str = "train_4k", mesh: str = "16x16",
                      tokens_per_step: int = 65_536) -> RooflineTerms:
    """Build RooflineTerms for an architecture from its compiled dry-run
    cell (closes the loop: the scheduling layer's scaling profiles come
    from the same artifacts as EXPERIMENTS.md §Roofline).

    Unit convention (per CHIP, job on k fixed-size DP slices of
    ``slice_chips`` chips): the cell was measured with the job spread over
    ``chips/slice_chips`` slices, so per-chip compute at k=1 is the cell's
    per-device flops scaled back up; the DP all-reduce payload per chip is
    the slice's shard of the gradients (2 bytes x active params /
    slice_chips), with the ring factor applied inside
    ``RooflineTerms.step_time``."""
    import json
    import os

    slice_chips = 16
    path = os.path.join(dryrun_dir, f"{arch}__{shape}__{mesh}.json")
    with open(path) as f:
        d = json.load(f)
    slices_measured = max(d["chips"] // slice_chips, 1)
    # The cell was measured at train_4k's 1M-token global batch; a cluster
    # job's per-step batch (tokens_per_step) scales the compute/memory
    # terms while the gradient-sync payload stays fixed — this is what
    # produces the monotone-decreasing marginal-throughput curve and why
    # bigger models (more compute per sync byte) are MORE elastic, the
    # paper's §2.3 compute-to-communication observation.
    cell_tokens = 256 * 4096 if shape == "train_4k" else 32 * 32_768
    scale = tokens_per_step / cell_tokens
    return RooflineTerms(
        flops=float(d["hlo_stats"]["flops"]) * slices_measured * scale,
        hbm_bytes=float(d["hlo_stats"]["hbm_bytes"]) * slices_measured * scale,
        grad_bytes=2.0 * float(d["params_active"]) / slice_chips,
    )


def profile_from_dryrun(arch: str, k_min: int = 1, k_max: int = 16,
                        **kw) -> np.ndarray:
    return roofline_profile(terms_from_dryrun(arch, **kw), k_min, k_max)
