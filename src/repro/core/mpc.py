"""Receding-horizon (MPC) execution phase for CarbonFlex.

PR 9's oracle-gap attribution measured carbonflex's perfect-forecast gap
as +17.2pp temporal_shifting vs -0.3pp capacity_scaling: the oracle's
whole advantage is *when* jobs run, not how many servers are provisioned.
This module attacks exactly that axis with a model-predictive execution
phase:

- Each decision epoch the planner scores, for every live job, whether the
  current slot belongs to the cheapest ``need`` slots of the job's
  feasible window (the next ``slack + need`` slots, capped at the
  planning horizon) under the day-ahead forecast.  ``need`` is the job's
  *estimated* remaining work from a learned per-queue conditional length
  distribution — MPC gets the same information the paper grants every
  baseline (historical lengths), never the true length.
- The argmin-carbon plan under that rule is "run each job in its cheapest
  feasible slots"; executing its first step and replanning next epoch is
  the classic receding-horizon loop.  Jobs whose slack is exhausted are
  forced at ``k_min`` first, so deadline safety is identical to every
  baseline (a job forced at slack 0 running at ``k_min`` finishes exactly
  at its deadline regardless of estimate quality).
- ``CarbonFlexScalePolicy`` adds CarbonScaler-style marginal-capacity
  scale-up: in *clean* slots (current slot within the cheapest
  ``clean_frac`` of the horizon) unforced jobs request the largest scale
  whose marginal throughput still clears a rho threshold learned from the
  knowledge base's oracle rho-curve (median of the KB's stored rho
  values) — pulling work forward into clean windows at good efficiency.

Everything the per-slot decision needs is precomputed host-side at
``on_window_start`` into integer tables (``rank``/``clean`` per slot from
the forecast, a ``need`` LUT per (queue, done-bucket) from history).  The
per-slot rule is pure integer logic over those tables plus the engine's
own ``remaining``/``slack`` state, which is why the scalar, vector, and
scan engines produce bit-identical decisions (the scan engine consumes
the same tables as device constants; see ``core/scan_engine.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import oracle
from .knowledge import KnowledgeBase

_EPS = 1e-9

#: Decision tables extend this far past the nominal window so
#: run-to-completion overruns (simulator default ``max_overrun=24*21``)
#: stay on planned slots; further slots clamp to the last table row.
PLAN_TAIL = 24 * 21


@dataclasses.dataclass(frozen=True)
class MPCConfig:
    """Knobs of the receding-horizon execution phase.

    Defaults come from the ``scripts/tune_policy.py`` sweep (see
    EXPERIMENTS.md §Forecast).  ``horizon=0`` is reserved for the
    registry's degenerate pin: the ``carbonflex-mpc`` builder then
    returns plain ``CarbonFlexPolicy`` (no look-ahead means no plan), a
    bit-identity asserted by tests/test_mpc.py."""

    horizon: int = 48            # H: planning look-ahead (slots)
    replan_every: int = 1        # refresh cadence of the forecast tables
    percentile: float = 85.0     # conditional remaining-length percentile
    prior_mean: float = 6.0      # length prior before any history (slots)
    history_cap: int = 512       # per-queue completed-length window
    max_done: int = 64           # D: done-work buckets of the need LUT
    clean_frac: float = 0.25     # scale-up window (carbonflex-scale only)
    scale_rho: float | None = None   # None = learn from the KB rho curve

    def __post_init__(self) -> None:
        if self.horizon < 0:
            raise ValueError(f"horizon must be >= 0, got {self.horizon}")
        if self.replan_every < 1:
            raise ValueError(
                f"replan_every must be >= 1, got {self.replan_every}")
        if self.max_done < 1:
            raise ValueError(f"max_done must be >= 1, got {self.max_done}")
        if not 0.0 <= self.clean_frac <= 1.0:
            raise ValueError(
                f"clean_frac must be in [0, 1], got {self.clean_frac}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "MPCConfig":
        return cls(**d)


@dataclasses.dataclass
class CarbonFlexMPCPolicy:
    """Receding-horizon temporal shifting over the forecast window.

    Per slot, a live unforced job is *eligible* to run iff the current
    slot ranks among its estimated-``need`` cheapest slots within its
    feasible window ``W = clip(slack + need, 1, H)``::

        eligible  <=>  #{u in 1..W-1 : forecast[t+u] < forecast[t]} < need

    (strict comparison: ties prefer running now — earlier is always safer
    under estimate error).  Forced jobs (slack exhausted) run at ``k_min``
    unconditionally.  Capacity fills forced rows first, then eligible rows,
    both in engine row order with continue-on-overflow semantics — the
    exact walk the scan engine's device fill performs.
    """

    # decide_packed allocates live active rows only, at k in
    # [k_min, k_max], total capped at the capacity it reports -> the
    # vector engine skips per-slot re-validation (see _simulate_vector).
    packed_safe = True
    # Subclass hook: CarbonFlexScalePolicy turns on clean-window scale-up.
    scales = False

    cfg: MPCConfig = dataclasses.field(default_factory=MPCConfig)
    name: str = "carbonflex-mpc"

    def __post_init__(self) -> None:
        if self.cfg.horizon < 1:
            raise ValueError(
                "CarbonFlexMPCPolicy needs horizon >= 1; the registry maps "
                "MPCConfig(horizon=0) to plain CarbonFlexPolicy instead")
        self._hist: dict[int, list[float]] = {}

    # --- learned per-queue length history ---------------------------------

    def _q_hist(self, q: int) -> list[float]:
        h = self._hist.get(q)
        if h is None:
            h = self._hist[q] = [float(self.cfg.prior_mean)]
        return h

    def warm_start(self, historical_jobs) -> None:
        """Seed the per-queue length histories from completed historical
        jobs (the same logs the learning phase replays).  History changes
        only here — never mid-window — so all three engines see identical
        need tables (the scan engine has no per-completion callback)."""
        for j in historical_jobs:
            h = self._q_hist(j.queue)
            h.append(float(j.length))
            if len(h) > self.cfg.history_cap:
                del h[0]

    def _build_need(self, nq: int) -> np.ndarray:
        """(nq, D) LUT of estimated remaining k_min-slots given floor(done).

        Entry [q, d] is the ``percentile`` of the conditional distribution
        {L | L > d} minus d (a plain mean under-schedules the heavy tail
        and blows deadlines), floored at one slot."""
        cfg = self.cfg
        lut = np.ones((nq, cfg.max_done), dtype=np.int64)
        for q in range(nq):
            arr = np.asarray(self._q_hist(q), dtype=np.float64)
            for d in range(cfg.max_done):
                longer = arr[arr > d]
                if len(longer):
                    est = float(np.percentile(longer, cfg.percentile)) - d
                else:
                    # beyond the longest seen: assume a mean-chunk remains
                    est = max(float(arr.mean()) * 0.5, 1.0)
                lut[q, d] = max(int(np.ceil(est - 1e-9)), 1)
        return lut

    # --- forecast decision tables -----------------------------------------

    def _build_tables(self, ci, t0: int, horizon: int) -> None:
        """Per-slot rank rows + clean flags over window + overrun tail.

        ``rank[s, j] = #{u in 1..j : fc[u] < fc[0]}`` for the forecast
        window anchored at slot ``t0 + s``; with replan cadence R the
        window is anchored at the epoch start and offset to the slot, so
        slots between replans reuse the stale forecast — exactly what a
        live replanning loop would see.  Forecast models are deterministic
        per (seed, trace, slot) (core/forecast.py), so precomputing here
        is equivalent to querying live and keeps all engines identical."""
        cfg = self.cfg
        h = cfg.horizon
        span = int(horizon) + PLAN_TAIL
        rank = np.zeros((span, h), dtype=np.int32)
        clean_cnt = np.zeros(span, dtype=np.int32)
        r = cfg.replan_every
        for e0 in range(0, span, r):
            m = min(r, span - e0)
            fc = np.asarray(ci.forecast_extended(t0 + e0, m + h),
                            dtype=np.float64)
            for o in range(m):
                w = fc[o:o + h + 1]
                cum = np.cumsum((w[1:] < w[0]).astype(np.int32))
                rank[e0 + o, 1:] = cum[:h - 1]
                clean_cnt[e0 + o] = cum[h - 1]
        self._rank = rank
        self._clean = clean_cnt < int(np.ceil(cfg.clean_frac * h))

    # --- scale-up tables (carbonflex-scale) -------------------------------

    def _resolve_rho(self) -> float:
        return 0.5

    def _build_k_up(self, jobs) -> np.ndarray:
        if not self.scales:
            return self._kmin
        rho = self._resolve_rho()
        out = np.empty(len(jobs), dtype=np.int64)
        for i, j in enumerate(jobs):
            k = j.k_min
            for kk in range(j.k_min + 1, j.k_max + 1):
                if j.marginal(kk) >= rho:
                    k = kk
                else:
                    break                 # profiles are monotone decreasing
            out[i] = k
        return out

    # --- Policy protocol --------------------------------------------------

    def on_window_start(self, ci, t0, horizon, jobs, cluster) -> None:
        self._t0 = int(t0)
        self._h = int(self.cfg.horizon)
        self._need = self._build_need(len(cluster.queues))
        self._build_tables(ci, t0, int(horizon))
        self._length = np.array([j.length for j in jobs], dtype=np.float64)
        self._queue = np.array([j.queue for j in jobs], dtype=np.int64)
        self._kmin = np.array([j.k_min for j in jobs], dtype=np.int64)
        self._id2row = {j.job_id: i for i, j in enumerate(jobs)}
        self._k_up = self._build_k_up(jobs)

    def _slot(self, t: int) -> int:
        return min(max(t - self._t0, 0), len(self._rank) - 1)

    def decide(self, t, active, ci, cluster):
        live = [a for a in active if not a.done]
        s = self._slot(t)
        rank_row = self._rank[s]
        clean = bool(self.scales and self._clean[s])
        m_cap = int(cluster.capacity)
        dmax = self._need.shape[1] - 1
        used = 0
        alloc: dict[int, int] = {}
        # Forced rows first (row order, continue semantics), then eligible
        # unforced rows — mirroring the scan engine's device fill walk.
        unforced = []
        for a in live:
            if a.slack_left <= 0:
                k = int(a.job.k_min)
                if used + k <= m_cap:
                    alloc[a.job.job_id] = k
                    used += k
            else:
                unforced.append(a)
        for a in unforced:
            row = self._id2row[a.job.job_id]
            done = self._length[row] - a.remaining
            d = min(max(int(np.floor(done)), 0), dmax)
            need = int(self._need[self._queue[row], d])
            w = min(max(a.slack_left + need, 1), self._h)
            if int(rank_row[w - 1]) >= need:
                continue
            k = int(self._k_up[row]) if clean else int(a.job.k_min)
            if used + k <= m_cap:
                alloc[a.job.job_id] = k
                used += k
        return m_cap, alloc

    def decide_packed(self, t, eng, ci, cluster):
        """Struct-of-arrays fast path: the same table lookups vectorised,
        with the identical forced-then-eligible row-order fill."""
        ps = eng.packed
        rows = eng.rows[eng.remaining[eng.rows] > _EPS]   # live jobs
        kvec = np.zeros(ps.n, dtype=np.int64)
        m_cap = int(cluster.capacity)
        if not len(rows):
            return m_cap, kvec
        s = self._slot(t)
        rank_row = self._rank[s]
        clean = bool(self.scales and self._clean[s])
        slack = eng.slack_left[rows]
        forced = slack <= 0
        done = ps.length[rows] - eng.remaining[rows]
        d = np.clip(np.floor(done).astype(np.int64), 0,
                    self._need.shape[1] - 1)
        need = self._need[ps.queue[rows], d]
        w = np.clip(slack + need, 1, self._h)
        elig = rank_row[w - 1] < need
        used = 0
        for r in rows[forced].tolist():
            k = int(ps.k_min[r])
            if used + k <= m_cap:
                kvec[r] = k
                used += k
        krow = self._k_up if clean else ps.k_min
        for r in rows[~forced & elig].tolist():
            k = int(krow[r])
            if used + k <= m_cap:
                kvec[r] = k
                used += k
        return m_cap, kvec

    def on_completion(self, t, job, violated) -> None:
        # History is intentionally frozen within a window (see warm_start):
        # the scan engine never observes completions mid-flight, so feeding
        # them back here would break cross-engine bit-parity.
        pass

    # --- scan-engine integration (core/scan_engine.py) --------------------

    def scan_tables(self) -> dict[str, np.ndarray]:
        """Row-static device constants of the decision rule."""
        return {"need_lut": self._need}

    def rank_rows(self, ts: np.ndarray) -> np.ndarray:
        """(S, H) rank rows for absolute slots ``ts`` (clamped)."""
        idx = np.clip(np.asarray(ts, dtype=np.int64) - self._t0, 0,
                      len(self._rank) - 1)
        return self._rank[idx]

    def clean_rows(self, ts: np.ndarray) -> np.ndarray:
        """(S,) clean-slot flags for absolute slots ``ts`` (clamped)."""
        idx = np.clip(np.asarray(ts, dtype=np.int64) - self._t0, 0,
                      len(self._clean) - 1)
        return self._clean[idx]


@dataclasses.dataclass
class CarbonFlexScalePolicy(CarbonFlexMPCPolicy):
    """MPC + CarbonScaler marginal-capacity scale-up in clean windows.

    In slots the forecast places within the cheapest ``clean_frac`` of
    the horizon, unforced eligible jobs request the largest scale whose
    marginal throughput clears ``rho`` (learned as the median of the
    knowledge base's oracle rho curve when ``cfg.scale_rho`` is None) —
    pulling work forward into clean energy at acceptable efficiency.
    Forced jobs stay at ``k_min`` (scale-up never eats the safety
    headroom), so deadline behaviour is unchanged from the base MPC."""

    scales = True

    name: str = "carbonflex-scale"
    kb: KnowledgeBase | None = None

    def _resolve_rho(self) -> float:
        if self.cfg.scale_rho is not None:
            return float(self.cfg.scale_rho)
        if self.kb is not None and len(self.kb):
            return float(np.median(self.kb.rho_values()))
        return 0.5


@dataclasses.dataclass
class EstimatedOraclePolicy:
    """Algorithm 1 with perfect CI knowledge but *estimated* job lengths.

    The plain oracle is granted two kinds of clairvoyance carbonflex is
    denied: the true future CI *and* every job's true length.  This
    variant keeps the first and drops the second — each job's length is
    replaced by the per-queue ``percentile`` of the learned length
    history before solving — so ``OracleGap`` can report both gaps and
    separate timing skill from length clairvoyance (EXPERIMENTS.md
    §Forecast).

    Execution follows the solved plan; jobs that outlive their estimate
    (the plan thinks they are done) fall back to forced-at-``k_min`` once
    their slack is exhausted, capacity permitting — the same safety net
    every baseline has."""

    cfg: MPCConfig = dataclasses.field(default_factory=MPCConfig)
    backend: str = "numpy"
    name: str = "oracle-estimated"

    def __post_init__(self) -> None:
        self._hist: dict[int, list[float]] = {}

    def _q_hist(self, q: int) -> list[float]:
        h = self._hist.get(q)
        if h is None:
            h = self._hist[q] = [float(self.cfg.prior_mean)]
        return h

    def warm_start(self, historical_jobs) -> None:
        for j in historical_jobs:
            h = self._q_hist(j.queue)
            h.append(float(j.length))
            if len(h) > self.cfg.history_cap:
                del h[0]

    def on_window_start(self, ci, t0, horizon, jobs, cluster) -> None:
        # Same solve span as OraclePolicy (window + overrun room).
        span = min(len(ci) - t0,
                   horizon + max(q.delay for q in cluster.queues) + 24 * 14)
        est = {q: max(float(np.percentile(
                   np.asarray(self._q_hist(q), dtype=np.float64),
                   self.cfg.percentile)), 1.0)
               for q in sorted({j.queue for j in jobs})}
        shifted = [dataclasses.replace(j, arrival=j.arrival - t0,
                                       length=est[j.queue]) for j in jobs]
        res = oracle.solve(shifted, ci.trace[t0:t0 + span], cluster.capacity,
                           horizon=span, backend=self.backend)
        # row-indexed: the engine packs the same (arrival, job_id)-sorted
        # list it passed here, so plan row i is engine row i
        self._alloc_mat = res.schedule.alloc
        self._t0 = int(t0)
        self._id2row = {j.job_id: i for i, j in enumerate(jobs)}

    def decide(self, t, active, ci, cluster):
        rel = t - self._t0
        span = self._alloc_mat.shape[1]
        m_cap = int(cluster.capacity)
        live = [a for a in active if not a.done]
        used = 0
        alloc: dict[int, int] = {}
        for a in live:
            row = self._id2row[a.job.job_id]
            k = int(self._alloc_mat[row, rel]) if 0 <= rel < span else 0
            if k > 0 and used + k <= m_cap:
                alloc[a.job.job_id] = k
                used += k
        # Underestimated jobs outlive the plan: forced fallback at k_min.
        for a in live:
            if a.slack_left <= 0 and a.job.job_id not in alloc:
                k = int(a.job.k_min)
                if used + k <= m_cap:
                    alloc[a.job.job_id] = k
                    used += k
        return m_cap, alloc

    def on_completion(self, t, job, violated) -> None:
        pass
