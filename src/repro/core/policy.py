"""The CarbonFlex runtime policy: continuous learning + phi + psi.

``learn_window`` is the learning phase (§4.2): replay a historical slice
through the offline oracle (Algorithm 1), featurise each slot's system
state (Table 2) and store ``STATE -> (m_t, rho_t)`` in the knowledge base.
Per the implementation section, the trace can be replayed at several start
offsets to densify the case base.

``CarbonFlexPolicy`` is the execution phase (§4.3): at each slot build the
current state, run Algorithm 2 (provisioning, with delay-violation
feedback) and Algorithm 3 (scheduling) against the learned knowledge base.

``OraclePolicy`` runs Algorithm 1 *on the evaluation trace itself* with
full future knowledge — the CarbonFlex(Oracle) baseline of §6.
"""
from __future__ import annotations

import dataclasses
import logging
import warnings
from collections import deque
from typing import Iterator, Protocol, runtime_checkable

import numpy as np

from . import oracle
from .carbon import CarbonService
from .forecast import QuantileCIView
from .knowledge import KnowledgeBase, build_state, states_from_schedule
from .provisioning import ProvisioningConfig, provision
from .scheduling import ActiveJob, schedule, schedule_packed
from .types import ClusterConfig, Job

_EPS = 1e-9

logger = logging.getLogger(__name__)


@runtime_checkable
class Policy(Protocol):
    """The provisioning+scheduling policy protocol the simulator drives.

    Per slot the engine calls ``decide`` with the active set and expects
    ``(m_t, allocations)``; ``on_window_start`` resets per-window state and
    ``on_completion`` feeds back each finished job (the violation-feedback
    input of Algorithm 2).  Policies may additionally implement the optional
    ``decide_packed(t, eng, ci, cluster)`` fast path to run directly over
    the vector engine's struct-of-arrays state."""

    name: str

    def on_window_start(self, ci: CarbonService, t0: int, horizon: int,
                        jobs: list[Job], cluster: ClusterConfig) -> None: ...

    def decide(self, t: int, active: list[ActiveJob], ci: CarbonService,
               cluster: ClusterConfig) -> tuple[int, dict[int, int]]: ...

    def on_completion(self, t: int, job: ActiveJob, violated: bool) -> None: ...


@dataclasses.dataclass
class LearnOutcome:
    """Result of one ``learn_window`` call: the per-offset oracle solutions
    plus which replay offsets actually contributed cases (an offset whose
    window holds no arrivals is skipped, not an error — ``empty`` records
    it so callers can see a silent gap in the case base)."""

    results: list[oracle.OracleResult]
    contributed: tuple[int, ...]
    empty: tuple[int, ...]

    # list-compat: existing callers iterate / index the oracle results
    def __iter__(self) -> Iterator[oracle.OracleResult]:
        return iter(self.results)

    def __len__(self) -> int:
        return len(self.results)

    def __getitem__(self, i):
        return self.results[i]


def learn_window(
    kb: KnowledgeBase,
    jobs: list[Job],
    ci: CarbonService,
    t0: int,
    horizon: int,
    cluster: ClusterConfig | int,
    num_queues: int | None = None,
    offsets: tuple[int, ...] = (0,),
    backend: str = "numpy",
) -> LearnOutcome:
    """Learning phase over one historical window (optionally replayed at
    several start offsets, §5 'Continuous Learning').

    ``cluster`` is a ``ClusterConfig``; the loose ``(capacity, num_queues)``
    integer pair is still accepted but deprecated.  Offsets whose window
    contains no arrivals are skipped and reported in ``LearnOutcome.empty``.
    """
    if isinstance(cluster, ClusterConfig):
        if num_queues is not None:
            raise TypeError("num_queues is implied by ClusterConfig — "
                            "pass one or the other, not both")
        capacity = cluster.capacity
        nq = len(cluster.queues)
    else:
        if num_queues is None:
            raise TypeError("num_queues is required with the deprecated "
                            "integer-capacity form")
        warnings.warn(
            "learn_window(..., capacity, num_queues) is deprecated; "
            "pass a ClusterConfig instead",
            DeprecationWarning, stacklevel=2)
        capacity = int(cluster)
        nq = int(num_queues)

    results: list[oracle.OracleResult] = []
    contributed: list[int] = []
    empty: list[int] = []
    for off in offsets:
        s0 = t0 + off
        window_jobs = [
            dataclasses.replace(j, arrival=j.arrival - s0)
            for j in jobs
            if s0 <= j.arrival < s0 + horizon
        ]
        if not window_jobs:
            empty.append(off)
            continue
        ci_slice = ci.trace[s0:s0 + horizon]
        res = oracle.solve(window_jobs, ci_slice, capacity, horizon=horizon, backend=backend)
        states = states_from_schedule(window_jobs, res.schedule.alloc,
                                      ci, nq, t0=s0)
        kb.add_window(states, res.capacity_curve, res.rho_curve)
        results.append(res)
        contributed.append(off)
    if empty:
        logger.info("learn_window: offsets %s held no arrivals in "
                    "[t0+off, t0+off+%d) and were skipped", tuple(empty), horizon)
    return LearnOutcome(results=results, contributed=tuple(contributed),
                        empty=tuple(empty))


@dataclasses.dataclass
class CarbonFlexPolicy:
    """Execution-phase policy (Algorithms 2 + 3 over the knowledge base).

    ``forecast_quantile`` (ISSUE-5 robust variant, registered as
    ``carbonflex-robust``): when set, every forecast-derived Table-2
    feature (day-ahead rank, min/mean CI ratios) is computed through a
    :class:`~repro.core.forecast.QuantileCIView` at that quantile instead
    of the point forecast, so single-path forecast noise cannot whipsaw
    the KNN state.  Under a perfect forecast the band collapses onto the
    truth and the robust variant is bit-identical to plain carbonflex."""

    # decide_packed allocates only live active rows, scales from the entry
    # blocks' [k_min, k_max] tables, fill capped at the m_t it returns ->
    # the vector engine skips per-slot re-validation (see _simulate_vector)
    packed_safe = True

    kb: KnowledgeBase
    cfg: ProvisioningConfig = dataclasses.field(default_factory=ProvisioningConfig)
    violation_window: int = 24          # completions remembered for v
    forecast_quantile: float | None = None
    name: str = "carbonflex"

    def __post_init__(self) -> None:
        self._recent: deque[bool] = deque(maxlen=self.violation_window)
        self._current_m = 0

    def _ci_view(self, ci):
        if self.forecast_quantile is None:
            return ci
        return QuantileCIView(ci, self.forecast_quantile)

    # Policy protocol ------------------------------------------------------

    def on_window_start(self, ci, t0, horizon, jobs, cluster) -> None:
        self._recent.clear()
        self._current_m = 0
        self._num_queues = len(cluster.queues)
        self._arrivals: dict[int, tuple[int, int]] = {}   # job_id -> (arrival, queue)
        self._backlog_sum = 0.0
        self._backlog_n = 0

    def decide(self, t, active: list[ActiveJob], ci: CarbonService,
               cluster: ClusterConfig):
        live = [a for a in active if not a.done]
        counts = np.zeros(self._num_queues)
        for a in live:
            counts[a.job.queue] += 1
            self._arrivals.setdefault(a.job.job_id, (a.job.arrival, a.job.queue))
        arr24 = np.zeros(self._num_queues)
        for arr, q in self._arrivals.values():
            if t - 24 < arr <= t:
                arr24[q] += 1
        mean_el = float(np.mean([a.job.elasticity() for a in live])) if live else 0.0
        total = counts.sum()
        self._backlog_sum += total
        self._backlog_n += 1
        rel = float(total / max(self._backlog_sum / self._backlog_n, 1e-9))
        state = build_state(self._ci_view(ci), t, counts, mean_el, arr24, rel)
        v = float(np.mean(self._recent)) if self._recent else 0.0
        min_required = sum(a.job.k_min for a in live if a.forced)
        m_t, rho = provision(state, self.kb, cluster.capacity, self._current_m,
                             v, self.cfg, min_required=min_required)
        self._current_m = m_t
        return m_t, schedule(live, m_t, rho)

    def decide_packed(self, t, eng, ci: CarbonService, cluster: ClusterConfig):
        """Struct-of-arrays fast path for the vector engine.

        Mirrors ``decide`` operation-for-operation (bincounts over the
        packed queue array, arrival pressure over the admission pointer,
        ``schedule_packed`` for Algorithm 3) so decisions are identical —
        asserted by tests/test_engine_parity.py."""
        ps = eng.packed
        nq = self._num_queues
        rows = eng.rows[eng.remaining[eng.rows] > _EPS]   # live jobs
        counts = np.bincount(ps.queue[rows], minlength=nq).astype(np.float64)
        # arrival pressure: every job admitted so far (and long enough to
        # have been live for >= 1 slot, matching _arrivals bookkeeping)
        adm = slice(0, eng.admitted)
        seen = ps.length[adm] > _EPS
        recent = seen & (ps.arrival[adm] > t - 24) & (ps.arrival[adm] <= t)
        arr24 = np.bincount(ps.queue[adm][recent], minlength=nq).astype(np.float64)
        mean_el = float(np.mean(ps.elast[rows])) if len(rows) else 0.0
        total = counts.sum()
        self._backlog_sum += total
        self._backlog_n += 1
        rel = float(total / max(self._backlog_sum / self._backlog_n, 1e-9))
        state = build_state(self._ci_view(ci), t, counts, mean_el, arr24, rel)
        v = float(np.mean(self._recent)) if self._recent else 0.0
        forced = rows[eng.slack_left[rows] <= 0]
        min_required = int(ps.k_min[forced].sum())
        m_t, rho = provision(state, self.kb, cluster.capacity, self._current_m,
                             v, self.cfg, min_required=min_required)
        self._current_m = m_t
        return m_t, schedule_packed(ps.blocks, ps.k_min, eng.slack_left,
                                    rows, m_t, rho)

    def on_completion(self, t, job: ActiveJob, violated: bool) -> None:
        self._recent.append(violated)


# The receding-horizon execution phase (``carbonflex-mpc`` /
# ``carbonflex-scale`` / ``oracle-estimated``) lives in ``core/mpc.py``;
# re-exported here because this module is the historical home of the MPC
# policy and existing call sites import it from ``repro.core.policy``.
from .mpc import (CarbonFlexMPCPolicy, CarbonFlexScalePolicy,  # noqa: E402
                  EstimatedOraclePolicy, MPCConfig)

__all__ = [
    "CarbonFlexMPCPolicy", "CarbonFlexPolicy", "CarbonFlexScalePolicy",
    "EstimatedOraclePolicy", "LearnOutcome", "MPCConfig", "OraclePolicy",
    "Policy", "learn_window",
]


@dataclasses.dataclass
class OraclePolicy:
    """CarbonFlex(Oracle): Algorithm 1 with full future knowledge (§6.1)."""

    backend: str = "numpy"
    name: str = "oracle"

    def on_window_start(self, ci, t0, horizon, jobs, cluster) -> None:
        # Solve over the full run (window + overrun room) so late arrivals fit.
        span = min(len(ci) - t0, horizon + max(q.delay for q in cluster.queues) + 24 * 14)
        shifted = [dataclasses.replace(j, arrival=j.arrival - t0) for j in jobs]
        res = oracle.solve(shifted, ci.trace[t0:t0 + span], cluster.capacity,
                           horizon=span, backend=self.backend)
        self._alloc = {j.job_id: res.schedule.alloc[i] for i, j in enumerate(shifted)}
        # row-indexed view for decide_packed: the engine packs the same
        # (arrival, job_id)-sorted list it passed to us, so oracle row i
        # is engine row i
        self._alloc_mat = res.schedule.alloc
        self._t0 = t0
        self.result = res

    def decide(self, t, active, ci, cluster):
        rel = t - self._t0
        alloc = {}
        for a in active:
            row = self._alloc.get(a.job.job_id)
            if row is not None and 0 <= rel < len(row) and row[rel] > 0:
                alloc[a.job.job_id] = int(row[rel])
        return sum(alloc.values()), alloc

    def decide_packed(self, t, eng, ci, cluster):
        """Vector-engine fast path: one column gather from the solved
        allocation matrix instead of a per-job dict walk."""
        rel = t - self._t0
        kvec = np.zeros(eng.packed.n, dtype=np.int64)
        if 0 <= rel < self._alloc_mat.shape[1]:
            kvec[eng.rows] = self._alloc_mat[eng.rows, rel]
        return int(kvec.sum()), kvec

    def on_completion(self, t, job, violated) -> None:
        pass
