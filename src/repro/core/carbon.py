"""Carbon-intensity service (paper §2.1, §5, Fig. 1/5).

Provides hourly carbon-intensity traces per region plus the day-ahead
forecast features used in the Table-2 state: the raw CI, the CI gradient,
and the rank of the current slot against the next-24h forecast.

Offline substitution (DESIGN.md §5): ElectricityMaps traces are not bundled,
so ``synthesize_trace`` generates seeded synthetic traces calibrated to the
published per-region (mean, CoV) of Fig. 5 — daily + half-daily harmonics,
a weekly component, and AR(1) noise.  The paper assumes accurate day-ahead
forecasts (citing CarbonCast); the *forecast model* is pluggable
(``core/forecast.py``): the default :class:`~repro.core.forecast.
PerfectForecast` exposes the true trace, while persistence / noisy /
quantile-ensemble models stress policies with realistic forecast error.
The old static ``forecast_noise`` knob survives as a deprecated shim.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from .faults import (CarbonDataOutage, DegradedCIView,  # noqa: F401
                     DegradedMultiRegionView)
from .forecast import (ForecastFeatureMixin, ForecastModel,  # noqa: F401
                       PerfectForecast, StaticNoiseForecast)

# (mean g CO2/kWh, daily CoV) per region, calibrated to Fig. 5's spread:
# high-CoV renewable-heavy grids (South Australia) down to flat
# nuclear/gas grids (Virginia, Poland) and low-carbon hydro (Ontario, Sweden).
REGIONS: dict[str, tuple[float, float]] = {
    "south-australia": (250.0, 0.45),
    "california": (230.0, 0.28),
    "texas": (400.0, 0.20),
    "germany": (380.0, 0.30),
    "netherlands": (350.0, 0.22),
    "washington": (100.0, 0.20),
    "ontario": (60.0, 0.12),
    "sweden": (30.0, 0.10),
    "virginia": (350.0, 0.05),
    "poland": (650.0, 0.07),
}


def synthesize_trace(
    region: str,
    hours: int,
    seed: int = 0,
    start_hour: int = 0,
) -> np.ndarray:
    """Seeded synthetic hourly CI trace for ``region`` (g CO2eq/kWh)."""
    try:
        mean, cov = REGIONS[region]
    except KeyError:
        raise ValueError(
            f"unknown region {region!r}; available regions: "
            f"{', '.join(sorted(REGIONS))}") from None
    import zlib

    rng = np.random.default_rng(
        np.random.SeedSequence([seed, zlib.crc32(region.encode()) & 0x7FFFFFFF])
    )
    t = np.arange(start_hour, start_hour + hours, dtype=np.float64)
    # Daily solar/wind-driven swing (trough mid-day for solar-heavy grids),
    # a smaller half-day harmonic, and a weekly demand component.
    phase = rng.uniform(0, 2 * np.pi)
    daily = np.sin(2 * np.pi * (t - 14.0) / 24.0 + 0.0)
    half = 0.35 * np.sin(4 * np.pi * t / 24.0 + phase)
    weekly = 0.15 * np.sin(2 * np.pi * t / (24.0 * 7.0) + phase / 2)
    # AR(1) noise.
    eps = rng.normal(0.0, 1.0, hours)
    ar = np.empty(hours)
    acc = 0.0
    for i in range(hours):
        acc = 0.85 * acc + eps[i]
        ar[i] = acc
    ar *= 0.25 / max(ar.std(), 1e-9)
    shape = daily + half + weekly + ar
    shape /= max(shape.std(), 1e-9)
    ci = mean * (1.0 + cov * shape)
    return np.clip(ci, 10.0, None)


@dataclasses.dataclass
class CarbonService(ForecastFeatureMixin):
    """Day-ahead-capable CI service over a fixed hourly trace.

    ``model`` is the pluggable forecast model (``core/forecast.py``);
    ``None`` resolves to :class:`PerfectForecast` — the historical
    behaviour, bit-identical.  ``forecast_noise`` is the deprecated static
    noise knob: it still works (as a :class:`StaticNoiseForecast` shim,
    matching the old outputs bit-for-bit) but warns; pass
    ``model=NoisyForecast(...)`` for lead-time-aware error instead."""

    trace: np.ndarray
    forecast_noise: float = 0.0
    horizon: int = 24
    seed: int = 0
    model: ForecastModel | None = None
    # Feed-outage injection (core/faults.py): stale/gap windows the policy
    # stack sees through ``degraded()``.  None = the feed is always fresh
    # and ``degraded()`` returns the service itself, bit-identical.
    outage: CarbonDataOutage | None = None

    def __post_init__(self) -> None:
        if self.forecast_noise > 0:
            if self.model is not None:
                raise ValueError("pass either model= or the deprecated "
                                 "forecast_noise=, not both")
            warnings.warn(
                "CarbonService(forecast_noise=...) is deprecated: it draws "
                "one static noise realization over the whole trace, so the "
                "realized error of a future slot never shrinks as it "
                "approaches; pass model=NoisyForecast(sigma=...) for "
                "lead-time-aware error (or model=StaticNoiseForecast(...) "
                "to keep the old semantics explicitly)",
                DeprecationWarning, stacklevel=2)
            self.model = StaticNoiseForecast(sigma=self.forecast_noise,
                                             seed=self.seed)
            # the knob is consumed into the model; zero it so
            # dataclasses.replace(svc, ...) on a shim-built service does
            # not re-trip the model-xor-knob validation above
            self.forecast_noise = 0.0
        elif self.model is None:
            self.model = PerfectForecast()

    @classmethod
    def synthetic(cls, region: str, hours: int, seed: int = 0, **kw) -> "CarbonService":
        return cls(trace=synthesize_trace(region, hours, seed=seed), seed=seed, **kw)

    def __len__(self) -> int:
        return len(self.trace)

    def ci(self, t: int) -> float:
        return float(self.trace[min(t, len(self.trace) - 1)])

    def degraded(self) -> "CarbonService | DegradedCIView":
        """The view the *policy stack* reads: the service itself when the
        feed has no outages, else a cached :class:`DegradedCIView`
        (forward-filled observations, staged forecast fallback).  The
        engines keep reading the true service for carbon accounting."""
        if self.outage is None:
            return self
        cached = self.__dict__.get("_degraded")
        if cached is None:
            cached = DegradedCIView(self, self.outage)
            self._degraded = cached
        return cached

    def forecast(self, t: int, horizon: int | None = None) -> np.ndarray:
        """Day-ahead forecast starting at slot t (paper footnote 3),
        delegated to the configured forecast model."""
        return self.model.predict(self.trace, t, horizon or self.horizon)

    def forecast_quantile(self, t: int, horizon: int | None = None,
                          q: float = 0.5) -> np.ndarray:
        """Per-horizon ``q``-quantile band of the forecast; models without
        uncertainty bands fall back to their point forecast."""
        h = horizon or self.horizon
        quantile = getattr(self.model, "quantile", None)
        if quantile is None:
            return self.model.predict(self.trace, t, h)
        return quantile(self.trace, t, h, q)

    # --- Table-2 features --------------------------------------------------
    # (forecast_extended / rank / percentile_threshold come from
    # ForecastFeatureMixin, shared with the robust policies' QuantileCIView)

    def gradient(self, t: int) -> float:
        """CI gradient: normalised slope at slot t."""
        if t == 0:
            return 0.0
        prev, cur = self.trace[t - 1], self.trace[t]
        return float((cur - prev) / max(prev, 1e-9))


@dataclasses.dataclass
class MultiRegionCarbonService:
    """Aligned per-region CI traces + forecasts for geo-distributed runs.

    Wraps one :class:`CarbonService` per region over traces of identical
    length and slot alignment (slot ``t`` is the same wall-clock hour in
    every region), so a geo policy can compare regions at a glance:
    ``ci_vec(t)`` is the current CI across regions, ``rank_vec(t)`` the
    Table-2 day-ahead rank feature per region, ``cleanest(t)`` the index
    of the currently lowest-CI region.
    """

    regions: tuple[str, ...]
    services: tuple[CarbonService, ...]

    def __post_init__(self) -> None:
        self.regions = tuple(self.regions)
        self.services = tuple(self.services)
        if not self.regions:
            raise ValueError("MultiRegionCarbonService needs >= 1 region")
        if len(self.regions) != len(self.services):
            raise ValueError("regions and services must align")
        if len(set(self.regions)) != len(self.regions):
            raise ValueError(f"duplicate regions: {self.regions}")
        lengths = {len(s) for s in self.services}
        if len(lengths) != 1:
            raise ValueError(f"per-region traces must have equal length, "
                             f"got {sorted(lengths)}")

    @classmethod
    def synthetic(cls, regions, hours: int, seed: int = 0,
                  **kw) -> "MultiRegionCarbonService":
        """Seeded aligned synthetic traces (one ``synthesize_trace`` per
        region; the shared ``seed`` keeps the worlds reproducible while the
        per-region CRC stream keeps the traces distinct)."""
        return cls(tuple(regions),
                   tuple(CarbonService.synthetic(r, hours, seed=seed, **kw)
                         for r in regions))

    @property
    def n_regions(self) -> int:
        return len(self.regions)

    def __len__(self) -> int:
        return len(self.services[0])

    def index(self, region: str) -> int:
        try:
            return self.regions.index(region)
        except ValueError:
            raise ValueError(f"unknown region {region!r}; this service "
                             f"covers: {', '.join(self.regions)}") from None

    def service(self, region: int | str) -> CarbonService:
        if isinstance(region, str):
            region = self.index(region)
        return self.services[region]

    def ci(self, t: int, region: int | str = 0) -> float:
        """Single-region CI accessor (defaults to region 0 so existing
        single-region code paths can read a geo service unambiguously)."""
        return self.service(region).ci(t)

    def degraded(self) -> "MultiRegionCarbonService | DegradedMultiRegionView":
        """Multi-region analogue of :meth:`CarbonService.degraded`: the
        service itself when every regional feed is outage-free, else a
        cached view stitching the per-region degraded views."""
        if all(s.outage is None for s in self.services):
            return self
        cached = self.__dict__.get("_degraded")
        if cached is None:
            cached = DegradedMultiRegionView(self)
            self._degraded = cached
        return cached

    def ci_vec(self, t: int) -> np.ndarray:
        return np.array([s.ci(t) for s in self.services])

    def forecast_matrix(self, t: int, horizon: int | None = None) -> np.ndarray:
        """(n_regions, horizon) day-ahead forecast block at slot t."""
        return np.stack([s.forecast(t, horizon) for s in self.services])

    def rank_vec(self, t: int) -> np.ndarray:
        """Per-region day-ahead rank of slot t (1.0 = region's best slot)."""
        return np.array([s.rank(t) for s in self.services])

    def cleanest(self, t: int) -> int:
        """Index of the currently lowest-CI region (ties -> lowest index)."""
        return int(np.argmin(self.ci_vec(t)))
