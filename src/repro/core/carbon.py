"""Carbon-intensity service (paper §2.1, §5, Fig. 1/5).

Provides hourly carbon-intensity traces per region plus the day-ahead
forecast features used in the Table-2 state: the raw CI, the CI gradient,
and the rank of the current slot against the next-24h forecast.

Offline substitution (DESIGN.md §5): ElectricityMaps traces are not bundled,
so ``synthesize_trace`` generates seeded synthetic traces calibrated to the
published per-region (mean, CoV) of Fig. 5 — daily + half-daily harmonics,
a weekly component, and AR(1) noise.  The paper assumes accurate day-ahead
forecasts (citing CarbonCast); we therefore expose the true trace as the
forecast, with an optional noise knob for sensitivity studies.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# (mean g CO2/kWh, daily CoV) per region, calibrated to Fig. 5's spread:
# high-CoV renewable-heavy grids (South Australia) down to flat
# nuclear/gas grids (Virginia, Poland) and low-carbon hydro (Ontario, Sweden).
REGIONS: dict[str, tuple[float, float]] = {
    "south-australia": (250.0, 0.45),
    "california": (230.0, 0.28),
    "texas": (400.0, 0.20),
    "germany": (380.0, 0.30),
    "netherlands": (350.0, 0.22),
    "washington": (100.0, 0.20),
    "ontario": (60.0, 0.12),
    "sweden": (30.0, 0.10),
    "virginia": (350.0, 0.05),
    "poland": (650.0, 0.07),
}


def synthesize_trace(
    region: str,
    hours: int,
    seed: int = 0,
    start_hour: int = 0,
) -> np.ndarray:
    """Seeded synthetic hourly CI trace for ``region`` (g CO2eq/kWh)."""
    try:
        mean, cov = REGIONS[region]
    except KeyError:
        raise ValueError(
            f"unknown region {region!r}; available regions: "
            f"{', '.join(sorted(REGIONS))}") from None
    import zlib

    rng = np.random.default_rng(
        np.random.SeedSequence([seed, zlib.crc32(region.encode()) & 0x7FFFFFFF])
    )
    t = np.arange(start_hour, start_hour + hours, dtype=np.float64)
    # Daily solar/wind-driven swing (trough mid-day for solar-heavy grids),
    # a smaller half-day harmonic, and a weekly demand component.
    phase = rng.uniform(0, 2 * np.pi)
    daily = np.sin(2 * np.pi * (t - 14.0) / 24.0 + 0.0)
    half = 0.35 * np.sin(4 * np.pi * t / 24.0 + phase)
    weekly = 0.15 * np.sin(2 * np.pi * t / (24.0 * 7.0) + phase / 2)
    # AR(1) noise.
    eps = rng.normal(0.0, 1.0, hours)
    ar = np.empty(hours)
    acc = 0.0
    for i in range(hours):
        acc = 0.85 * acc + eps[i]
        ar[i] = acc
    ar *= 0.25 / max(ar.std(), 1e-9)
    shape = daily + half + weekly + ar
    shape /= max(shape.std(), 1e-9)
    ci = mean * (1.0 + cov * shape)
    return np.clip(ci, 10.0, None)


@dataclasses.dataclass
class CarbonService:
    """Day-ahead-capable CI service over a fixed hourly trace."""

    trace: np.ndarray
    forecast_noise: float = 0.0
    horizon: int = 24
    seed: int = 0

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)
        if self.forecast_noise > 0:
            noise = self._rng.normal(1.0, self.forecast_noise, len(self.trace))
            self._forecast = np.clip(self.trace * noise, 1.0, None)
        else:
            self._forecast = self.trace

    @classmethod
    def synthetic(cls, region: str, hours: int, seed: int = 0, **kw) -> "CarbonService":
        return cls(trace=synthesize_trace(region, hours, seed=seed), seed=seed, **kw)

    def __len__(self) -> int:
        return len(self.trace)

    def ci(self, t: int) -> float:
        return float(self.trace[min(t, len(self.trace) - 1)])

    def forecast(self, t: int, horizon: int | None = None) -> np.ndarray:
        """Day-ahead forecast starting at slot t (paper footnote 3)."""
        h = horizon or self.horizon
        end = min(t + h, len(self._forecast))
        out = self._forecast[t:end]
        if len(out) < h:  # pad by repeating the last known value
            out = np.concatenate([out, np.full(h - len(out), out[-1] if len(out) else 0.0)])
        return out

    def forecast_extended(self, t: int, horizon: int) -> np.ndarray:
        """Forecast beyond the day-ahead horizon by tiling the day-ahead
        diurnal pattern (the standard persistence assumption)."""
        day = self.forecast(t, self.horizon)
        if horizon <= len(day):
            return day[:horizon]
        reps = int(np.ceil(horizon / len(day)))
        return np.tile(day, reps)[:horizon]

    # --- Table-2 features --------------------------------------------------

    def gradient(self, t: int) -> float:
        """CI gradient: normalised slope at slot t."""
        if t == 0:
            return 0.0
        prev, cur = self.trace[t - 1], self.trace[t]
        return float((cur - prev) / max(prev, 1e-9))

    def rank(self, t: int) -> float:
        """Day-ahead rank of slot t: fraction of the next-24h forecast that
        is *more* carbon-intense than now (1.0 = best slot of the day)."""
        fc = self.forecast(t)
        return float(np.mean(fc > self.trace[t]))

    def percentile_threshold(self, t: int, pct: float) -> float:
        """The pct-th percentile of the next-24h forecast (Wait-Awhile)."""
        return float(np.percentile(self.forecast(t), pct))


@dataclasses.dataclass
class MultiRegionCarbonService:
    """Aligned per-region CI traces + forecasts for geo-distributed runs.

    Wraps one :class:`CarbonService` per region over traces of identical
    length and slot alignment (slot ``t`` is the same wall-clock hour in
    every region), so a geo policy can compare regions at a glance:
    ``ci_vec(t)`` is the current CI across regions, ``rank_vec(t)`` the
    Table-2 day-ahead rank feature per region, ``cleanest(t)`` the index
    of the currently lowest-CI region.
    """

    regions: tuple[str, ...]
    services: tuple[CarbonService, ...]

    def __post_init__(self) -> None:
        self.regions = tuple(self.regions)
        self.services = tuple(self.services)
        if not self.regions:
            raise ValueError("MultiRegionCarbonService needs >= 1 region")
        if len(self.regions) != len(self.services):
            raise ValueError("regions and services must align")
        if len(set(self.regions)) != len(self.regions):
            raise ValueError(f"duplicate regions: {self.regions}")
        lengths = {len(s) for s in self.services}
        if len(lengths) != 1:
            raise ValueError(f"per-region traces must have equal length, "
                             f"got {sorted(lengths)}")

    @classmethod
    def synthetic(cls, regions, hours: int, seed: int = 0,
                  **kw) -> "MultiRegionCarbonService":
        """Seeded aligned synthetic traces (one ``synthesize_trace`` per
        region; the shared ``seed`` keeps the worlds reproducible while the
        per-region CRC stream keeps the traces distinct)."""
        return cls(tuple(regions),
                   tuple(CarbonService.synthetic(r, hours, seed=seed, **kw)
                         for r in regions))

    @property
    def n_regions(self) -> int:
        return len(self.regions)

    def __len__(self) -> int:
        return len(self.services[0])

    def index(self, region: str) -> int:
        try:
            return self.regions.index(region)
        except ValueError:
            raise ValueError(f"unknown region {region!r}; this service "
                             f"covers: {', '.join(self.regions)}") from None

    def service(self, region: int | str) -> CarbonService:
        if isinstance(region, str):
            region = self.index(region)
        return self.services[region]

    def ci(self, t: int, region: int | str = 0) -> float:
        """Single-region CI accessor (defaults to region 0 so existing
        single-region code paths can read a geo service unambiguously)."""
        return self.service(region).ci(t)

    def ci_vec(self, t: int) -> np.ndarray:
        return np.array([s.ci(t) for s in self.services])

    def forecast_matrix(self, t: int, horizon: int | None = None) -> np.ndarray:
        """(n_regions, horizon) day-ahead forecast block at slot t."""
        return np.stack([s.forecast(t, horizon) for s in self.services])

    def rank_vec(self, t: int) -> np.ndarray:
        """Per-region day-ahead rank of slot t (1.0 = region's best slot)."""
        return np.array([s.rank(t) for s in self.services])

    def cleanest(self, t: int) -> int:
        """Index of the currently lowest-CI region (ties -> lowest index)."""
        return int(np.argmin(self.ci_vec(t)))
