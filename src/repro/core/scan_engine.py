"""The scan engine: the per-slot simulation loop as a jitted ``lax.scan``.

``BENCH_engine.json`` showed the numpy vector engine's wins collapsing
exactly where the interesting policies live (geo-flex 1.1x, dag-carbon
1.4x vs 3.9-4.1x for simple policies): every slot still round-trips
through Python for the policy decision and the defensive trimming.  This
module lifts the whole slot loop onto the device:

- the *decision* of every nativizable policy is expressed as packed
  array ops inside the scan step (threshold-fill for the single-region
  family, a sequential candidate walk for the geo family);
- admission, dependency gating (pred-count decrement via
  ``kernels/gating.py``), release and deadline-from-release live in the
  carried state;
- whole (seeds x policies x regions x forecasts) grids run as one
  vmapped device program (`simulate_many_scan`), chunked so termination
  is checked on the host between chunks.

Bit-parity contract
-------------------
``engine="scan"`` is **bit-identical** to the scalar/vector references
(asserted across policy families in ``tests/test_scan_engine.py``).  Two
mechanisms make that possible on a backend whose compiler contracts
``a*b + c`` into fused-multiply-add (XLA CPU does, measurably):

1. *No float accounting on device.*  The scan emits only the boolean
   ``take`` grid (which rows ran which slot); the host replays
   fractional progress from it — ``frac = min(1, rem/thr)`` then
   ``rem -= thr`` per slot, single correctly-rounded ops in the same
   order the vector engine performs them — and feeds the exact numpy
   energy expressions over the resulting cells.  Booleans also shrink
   the device->host transfer ~8x vs shipping float grids.
2. *Host-precomputed decision tables.*  Threshold eligibility
   (``percentile_threshold``/quantile views), geo forecast window-means
   and percentile thresholds are computed host-side per chunk with the
   policies' own numpy expressions, then consumed on device as data.
   (Window-mean tables are bitwise equal to the per-slot slices the
   policies take — ``np.mean`` over a leading slice is associativity-
   stable across the batched and scalar forms.)

The single remaining device-side float *combination* is the geo
migration economics ``mean*e_run + mig_carbon`` (one add), where FMA
contraction can differ from numpy in the last ulp; a decision flips only
on an exact tie between move and stay — measure-zero on real traces and
pinned empirically by the randomized parity suite.

Native coverage and delegation
------------------------------
Natively scanned (exact policy types, ``faults is None``):

- single-region: ``carbon-agnostic``, ``dag-fcfs``, ``wait-awhile``,
  ``wait-awhile-robust``, ``dag-carbon``, ``dag-cap`` (the
  threshold-fill family — FCFS at ``k_min`` under an eligibility mask);
- MPC: ``carbonflex-mpc`` / ``carbonflex-scale`` (``core/mpc.py``) — the
  receding-horizon rule consumes its host-precomputed rank/need/clean
  tables as per-slot xs and row constants, so the whole horizon search
  runs inside the scan step as integer gathers; the scale variant's
  per-slot allocations ride back in a ``scaled`` boolean grid that the
  host energy replay resolves to per-cell k;
- geo: ``geo-static``, ``geo-greedy``, ``geo-flex``.

Everything else (host-stateful planners like gaia/carbonscaler/
carbonflex/oracle, policy subclasses, and *any* faulted case — fault
processes draw from host RNG streams mid-slot) transparently delegates
to the numpy vector engine, which is itself bit-identical to the scalar
reference.  Carbon-feed *outages* (degraded CI views) are pure per-slot
functions and run natively.  This is an honest trade: the scan engine
accelerates exactly the policy structure that is expressible as packed
array ops, and ``engine="scan"`` is always safe to request.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import time
from typing import Callable, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from . import emissions
from .baselines import (CarbonAgnosticPolicy, RobustWaitAwhilePolicy,
                        WaitAwhilePolicy)
from .carbon import CarbonService, MultiRegionCarbonService
from .dag import DagCapPolicy, DagCarbonPolicy, DagFcfsPolicy
from .forecast import PerfectForecast, QuantileCIView
from .geo import GeoFlexPolicy, GeoGreedyPolicy, GeoStaticPolicy
from .mpc import CarbonFlexMPCPolicy, CarbonFlexScalePolicy
from .types import GeoCluster, SimResult, SlotLog
from ..telemetry import Telemetry

_EPS = 1e-9
_log = logging.getLogger(__name__)
_BIG_T = np.int64(2 ** 62)     # arrival sentinel for padding rows
ROW_PAD = 256                  # row-count bucket (bounds jit recompiles)
EDGE_PAD = 256
MAX_GATHER_DEG = 64            # in-degree bound for the dense dep transpose
CHUNK = 168                    # slots per device dispatch (horizon region)
OVERRUN_CHUNK = 24             # slots per dispatch past the horizon
BATCH_TILE = 64                # vmapped cells per dispatch (memory bound)


# --- native-policy detection -------------------------------------------------

_MPC_KINDS = {"mpc", "mpc-scale"}
_SINGLE_KINDS = {"plain", "thresh", "cap"} | _MPC_KINDS


def native_kind(policy, cluster, faults) -> str | None:
    """The scan-native program family for this case, or None to delegate.

    Exact ``type()`` checks: a subclass may override ``decide`` in ways
    the packed decision tables cannot see, so only the known closed set
    runs natively (``carbonflex-scale`` is checked before its base MPC
    class for the same reason).  Any fault process delegates (host RNG
    mid-slot).
    """
    if faults is not None:
        return None
    if isinstance(cluster, GeoCluster):
        return {GeoStaticPolicy: "geo-static", GeoGreedyPolicy: "geo-greedy",
                GeoFlexPolicy: "geo-flex"}.get(type(policy))
    tp = type(policy)
    if tp in (CarbonAgnosticPolicy, DagFcfsPolicy):
        return "plain"
    if tp in (WaitAwhilePolicy, RobustWaitAwhilePolicy, DagCarbonPolicy):
        return "thresh"
    if tp is DagCapPolicy:
        return "cap"
    if tp is CarbonFlexScalePolicy:
        return "mpc-scale"
    if tp is CarbonFlexMPCPolicy:
        return "mpc"
    return None


def _pad_rows(n: int) -> int:
    """Smallest ROW_PAD multiple strictly greater than n (the last row is
    always padding — the gating kernel self-loops its edge padding there)."""
    return (n // ROW_PAD + 1) * ROW_PAD


# --- batched CI-table fast paths ---------------------------------------------
# The per-slot CI/forecast APIs (``ci_vec``/``forecast_matrix``/``ci``)
# are Python calls; building a week of decision tables through them costs
# more than the device program itself.  When the view is a plain
# perfect-forecast service the same tables fall out of whole-trace
# indexing — the gathered elements are the identical float64 values the
# per-slot calls return, so the fast path is bitwise equal; any other
# view (forecast models, outage-degraded, subclasses) keeps the
# per-slot loop.


def _perfect_traces(ci_pol) -> np.ndarray | None:
    """(R, T) trace stack when every regional feed is a plain
    perfect-forecast ``CarbonService`` with no outage; None otherwise."""
    if type(ci_pol) is not MultiRegionCarbonService:
        return None
    svs = ci_pol.services
    if any(type(s) is not CarbonService or type(s.model) is not PerfectForecast
           or s.outage is not None or np.asarray(s.trace).dtype != np.float64
           for s in svs):
        return None
    if len({len(s.trace) for s in svs}) != 1:
        return None
    return np.stack([np.asarray(s.trace) for s in svs])


def _ci_vec_block(ci_pol, ts: np.ndarray) -> np.ndarray:
    """(S, R) stack of ``ci_vec`` over the slots ``ts``."""
    tr = _perfect_traces(ci_pol)
    if tr is not None and ts[0] >= 0:
        return tr[:, np.minimum(ts, tr.shape[1] - 1)].T.copy()
    return np.stack([ci_pol.ci_vec(int(t)) for t in ts])


def _forecast_block(ci_pol, ts: np.ndarray, h: int) -> np.ndarray:
    """(S, R, H) stack of ``forecast_matrix`` over the slots ``ts``.

    The fast path mirrors ``forecast._truth_slice`` exactly: windows past
    the trace end repeat the last known value (the padded-trace gather
    reads that same element)."""
    tr = _perfect_traces(ci_pol)
    if tr is not None and ts[0] >= 0 and ts[-1] < tr.shape[1]:
        pad = np.concatenate([tr, np.repeat(tr[:, -1:], h - 1, axis=1)],
                             axis=1)
        idx = ts[:, None] + np.arange(h)[None, :]
        return pad[:, idx].transpose(1, 0, 2)
    return np.stack([ci_pol.forecast_matrix(int(t), h) for t in ts])


def _ci_block(ci, t0: int, n_valid: int) -> np.ndarray:
    """Accounting CI per slot (true service; outages never apply here)."""
    if type(ci) is CarbonService:
        # float64 widening is exact, matching the per-slot float() calls
        tr = np.asarray(ci.trace, dtype=np.float64)
        return tr[np.minimum(np.arange(t0, t0 + n_valid), len(tr) - 1)]
    return np.array([ci.ci(t0 + i) for i in range(n_valid)])


def _ci_vec_acct_block(mci, t0: int, n_valid: int) -> np.ndarray:
    """(S, R) accounting CI vectors (true multi-region service)."""
    ts = np.arange(t0, t0 + n_valid)
    if type(mci) is MultiRegionCarbonService:
        return np.stack(
            [np.asarray(s.trace, dtype=np.float64)[
                np.minimum(ts, len(s.trace) - 1)] for s in mci.services],
            axis=1)
    return np.stack([mci.ci_vec(int(t)) for t in ts]) if n_valid \
        else np.zeros((0, mci.n_regions))


# --- single-region program ---------------------------------------------------


@dataclasses.dataclass
class _SingleProgram:
    """Device constants + host mirrors for one single-region native case."""

    consts: dict                   # jnp arrays / 0-d scalars
    carry0: dict
    n_pad: int
    kind: str                      # plain | thresh | cap | mpc | mpc-scale
    uniform: bool                  # all k_min equal -> cumsum fill
    deps: str                      # none | gather | scatter (gating form)
    xs_fn: Callable                # (ts: np.ndarray) -> host per-slot tables
    xs_dims: tuple                 # xs table shapes (part of the batch key)
    # host accounting mirrors
    power: np.ndarray
    m_t: int
    k_up: np.ndarray | None = None     # mpc-scale: per-row clean-slot k


def _single_elig_fn(policy, ci_pol, kind: str) -> Callable:
    """Per-slot low-carbon eligibility flags, computed with the policy's
    own expressions (bit-parity by construction)."""
    if kind == "plain":
        return lambda ts: np.ones(len(ts), dtype=bool)
    view = ci_pol
    if type(policy) is RobustWaitAwhilePolicy:
        view = QuantileCIView(ci_pol, policy.quantile)
    pct = policy.percentile

    tr = pad_tr = None
    if (type(view) is CarbonService and type(view.model) is PerfectForecast
            and view.outage is None
            and np.asarray(view.trace).dtype == np.float64):
        # perfect-forecast fast path: whole-trace windows are the same
        # float64 elements the per-slot forecast() calls slice (see
        # _forecast_block), so the batched percentile is bitwise equal
        tr = np.asarray(view.trace)
        hor = int(view.horizon)
        pad_tr = np.concatenate([tr, np.full(hor - 1, tr[-1])])

    def elig(ts: np.ndarray) -> np.ndarray:
        if tr is not None and ts[0] >= 0 and ts[-1] < len(tr):
            civ = tr[np.minimum(ts, len(tr) - 1)]
            fcm = pad_tr[ts[:, None] + np.arange(hor)[None, :]]
            return civ <= np.percentile(fcm, pct, axis=1) + 1e-12
        # one percentile call over the stacked windows: np.percentile
        # with axis= partitions + interpolates each row with the same
        # arithmetic as the per-row call, so this is bitwise identical
        # to the policies' per-slot `percentile_threshold(t, pct)` (and
        # ~5x cheaper — the per-call numpy overhead dominated the sweep
        # profile); rows of unequal length (trace tail) fall back.
        tl = ts.tolist()
        civ = np.array([view.ci(t) for t in tl])
        fcs = [view.forecast(t) for t in tl]
        if fcs and all(len(f) == len(fcs[0]) for f in fcs):
            thresh = np.percentile(np.stack(fcs), pct, axis=1)
        else:
            thresh = np.array([float(np.percentile(f, pct)) for f in fcs])
        return civ <= thresh + 1e-12

    return elig


def _build_single(packed, cluster, policy, ci_pol, kind: str,
                  t0: int, horizon: int) -> _SingleProgram:
    n = packed.n
    n_pad = _pad_rows(n)
    power = np.where(packed.power > 0, packed.power, cluster.power_per_server)
    kmin = packed.k_min
    thr = packed.thr_tab[np.arange(n), kmin]
    i64, f64 = np.int64, np.float64

    def padded(src, fill, dtype):
        out = np.full(n_pad, fill, dtype=dtype)
        out[:n] = src
        return out

    arrival = padded(packed.arrival, _BIG_T, i64)
    elig_row = np.zeros(n_pad, dtype=bool)
    if kind == "plain":
        elig_row[:n] = True
    elif kind == "cap":
        # criticality is static per window (DagCapPolicy.on_window_start);
        # a job missing from the map is critical (crit.get(..., True))
        crit = policy._critical
        elig_row[:n] = [bool(crit.get(int(j), True))
                        for j in packed.job_ids.tolist()]

    deps = "none"
    dep_consts: dict = {}
    if packed.has_deps:
        deg = np.diff(packed.succ_ptr[:n + 1])
        par = np.repeat(np.arange(n, dtype=i64), deg)
        chd = packed.succ_rows[
            packed.succ_ptr[0]:packed.succ_ptr[n]].astype(i64)
        ind = np.bincount(chd, minlength=n) if len(chd) \
            else np.zeros(n, dtype=i64)
        d_max = int(ind.max()) if len(chd) else 0
        if d_max <= MAX_GATHER_DEG:
            # transposed gating: per-row padded predecessor lists (the
            # dense (n_pad, D) gather beats XLA:CPU's serial scatter by
            # ~6x for the bounded in-degrees real DAG workloads have)
            deps = "gather"
            d_pad = max(4, -4 * (-max(d_max, 1) // 4))
            pred_rows = np.full((n_pad, d_pad), n_pad - 1, dtype=i64)
            order = np.argsort(chd, kind="stable")
            sc, sp = chd[order], par[order]
            starts = np.concatenate([[0], np.cumsum(ind)])
            pred_rows[sc, np.arange(len(sc)) - starts[sc]] = sp
            dep_consts["pred_rows"] = pred_rows
        else:
            deps = "scatter"
            e_pad = max(EDGE_PAD, ((len(par) + EDGE_PAD - 1) // EDGE_PAD)
                        * EDGE_PAD)
            parents = np.full(e_pad, n_pad - 1, dtype=i64)
            children = np.full(e_pad, n_pad - 1, dtype=i64)
            parents[:len(par)] = par
            children[:len(chd)] = chd
            dep_consts["parents"] = parents
            dep_consts["children"] = children

    k_up = None
    mpc_consts: dict = {}
    if kind in _MPC_KINDS:
        # the MPC rule's row constants: static job length (``remaining``
        # in the carry decays, done-work needs the original), queue ids
        # for the need-LUT gather, and the learned need LUT itself
        mpc_consts["length_c"] = padded(packed.length, 0.0, f64)
        mpc_consts["queue"] = padded(packed.queue, 0, i64)
        mpc_consts["need_lut"] = policy.scan_tables()["need_lut"]
        if kind == "mpc-scale":
            k_up = np.asarray(policy._k_up, dtype=i64)
            mpc_consts["k_scale"] = padded(k_up, 1, i64)
            mpc_consts["thr_up"] = padded(
                packed.thr_tab[np.arange(n), k_up], 1.0, f64)

    # one device_put for the whole tree (per-array jnp.asarray dispatch
    # was a measurable share of short runs)
    consts = jax.device_put(dict(
        arrival=arrival,
        kmin=padded(kmin, 1, i64),
        thr=padded(thr, 1.0, f64),
        thr_guard=padded(np.maximum(thr, 1e-9), 1.0, f64),
        dl_span=padded(packed.dl_span, 0, i64),
        elig_row=elig_row,
        m_cap=i64(cluster.capacity),
        n_real=i64(n),
        t_end=i64(t0 + horizon),
        **dep_consts,
        **mpc_consts,
    ))
    carry0 = jax.device_put(dict(
        remaining=padded(packed.length, 0.0, f64),
        slack=padded([j.delay for j in packed.jobs], 0, i64),
        waited=np.zeros(n_pad, dtype=i64),
        deadline_eff=padded(packed.deadline, 0, i64),
        pred_left=padded(packed.pred0, 0, i64),
        in_sys=np.zeros(n_pad, dtype=bool),
        finished=np.zeros(n_pad, dtype=bool),
        pending=np.zeros(n_pad, dtype=bool),
        ended=np.asarray(False),
    ))
    if kind in _MPC_KINDS:
        # per-slot tables of the MPC rule, straight from the policy's own
        # host-precomputed arrays (bit-parity by construction)
        def xs_fn(ts: np.ndarray) -> dict:
            xs = {"t": ts.astype(i64),
                  "rank_t": policy.rank_rows(ts).astype(i64)}
            if kind == "mpc-scale":
                xs["clean_t"] = policy.clean_rows(ts)
            return xs

        xs_dims = (int(policy.cfg.horizon), mpc_consts["need_lut"].shape)
    else:
        elig = _single_elig_fn(policy, ci_pol, kind)

        def xs_fn(ts: np.ndarray) -> dict:
            return {"t": ts.astype(i64), "elig_t": elig(ts)}

        xs_dims = ()

    # per-slot scale-up makes the requested k slot-varying -> the cumsum
    # fill's uniform-k premise no longer holds
    uniform = bool(n > 0 and (kmin == kmin[0]).all()
                   and kind != "mpc-scale")
    return _SingleProgram(
        consts=consts, carry0=carry0, n_pad=n_pad, kind=kind,
        uniform=uniform, deps=deps, xs_fn=xs_fn, xs_dims=xs_dims,
        power=power, m_t=int(cluster.capacity), k_up=k_up)


def _single_step(consts, carry, x, *, kind: str, uniform: bool, deps: str):
    """One engine slot (mirrors ``_simulate_vector``'s loop body)."""
    t = x["t"]
    rem = carry["remaining"]
    slack = carry["slack"]
    waited = carry["waited"]
    dle = carry["deadline_eff"]
    pred = carry["pred_left"]
    in_sys = carry["in_sys"]
    fin_all = carry["finished"]
    pending = carry["pending"]
    n_pad = rem.shape[0]

    # release (DAG): tasks whose last predecessor finished last slot —
    # slack/deadline count from the release slot
    if deps != "none":
        in_sys = in_sys | pending
        dle = jnp.where(pending, t + consts["dl_span"], dle)
        pending = jnp.zeros_like(pending)
    # admission: arrival passed, not finished, not gated
    arrived = consts["arrival"] <= t
    in_sys = in_sys | (arrived & ~fin_all & (pred == 0))

    n_in = jnp.sum(in_sys)
    n_arr = jnp.sum(arrived)
    blocked = n_arr - n_in - jnp.sum(fin_all)
    ended = carry["ended"] | ((n_in == 0) & (n_arr == consts["n_real"])
                              & (blocked == 0) & (t >= consts["t_end"]))
    act = in_sys & ~ended

    # decision: FCFS threshold-fill at k_min (rows are (arrival, job_id)-
    # sorted, so forced-then-unforced in row order IS the FCFS key)
    forced = slack <= 0
    live = rem > _EPS
    kmin = consts["kmin"]
    m_cap = consts["m_cap"]
    if kind in _MPC_KINDS:
        # MPC eligibility: current slot among the job's estimated-need
        # cheapest within its feasible window (CarbonFlexMPCPolicy.decide
        # — same tables, same integer logic)
        didx = jnp.clip(jnp.floor(consts["length_c"] - rem)
                        .astype(jnp.int64), 0,
                        consts["need_lut"].shape[1] - 1)
        need = consts["need_lut"][consts["queue"], didx]
        w = jnp.clip(slack + need, 1, x["rank_t"].shape[-1])
        cand = act & live & (forced | (x["rank_t"][w - 1] < need))
    else:
        cand = act & live & (forced | x["elig_t"] | consts["elig_row"])
    if kind == "mpc-scale":
        # clean-window scale-up: unforced rows request the learned k_up
        kreq = jnp.where(forced | ~x["clean_t"], kmin, consts["k_scale"])
    else:
        kreq = kmin
    if uniform:
        # uniform k: "continue" fill == rank-prefix per group
        k0 = kmin[0]
        cf = cand & forced
        cr = cand & ~forced
        tf = cf & (jnp.cumsum(cf.astype(jnp.int64)) * k0 <= m_cap)
        used_f = k0 * jnp.sum(tf)
        tr = cr & (used_f + jnp.cumsum(cr.astype(jnp.int64)) * k0 <= m_cap)
        take = tf | tr
    else:
        idx = jnp.arange(n_pad, dtype=jnp.int64)
        key = jnp.where(cand, (~forced).astype(jnp.int64) * n_pad + idx,
                        jnp.int64(2 * n_pad))
        order = jnp.argsort(key, stable=True)

        def fill(used, row):
            ok = cand[row] & (used + kreq[row] <= m_cap)
            return used + jnp.where(ok, kreq[row], 0), ok

        # unroll: the fill body is a handful of scalar ops, so XLA:CPU's
        # per-iteration while-loop dispatch dominates — unrolling trades
        # code size for ~5x less loop overhead (bit-identical: same ops,
        # same order, just fewer loop-carried jumps).
        _, take_o = lax.scan(fill, jnp.int64(0), order, unroll=16)
        take = jnp.zeros_like(cand).at[order].set(take_o)

    # progress (energy + frac replay host-side from take; see module doc)
    if kind == "mpc-scale":
        scaled = take & (kreq > kmin)
        rem2 = jnp.where(take, rem - jnp.where(scaled, consts["thr_up"],
                                               consts["thr"]), rem)
    else:
        rem2 = jnp.where(take, rem - consts["thr"], rem)
    wmask = act & live & ~take
    slack2 = jnp.where(wmask, slack - 1, slack)
    waited2 = jnp.where(wmask, waited + 1, waited)

    fin = act & (rem2 <= _EPS)
    viol = fin & (t > dle)
    waited_fin = jnp.where(fin, waited2, 0)
    fin_all2 = fin_all | fin
    in_sys2 = in_sys & ~fin
    if deps != "none":
        from repro.kernels import gating
        if deps == "gather":
            dec = gating.dep_decrement_gather(fin, consts["pred_rows"])
        else:
            dec = gating.dep_decrement(fin, consts["parents"],
                                       consts["children"], n_pad)
        pred2 = pred - dec.astype(jnp.int64)
        pending2 = (dec > 0) & (pred2 == 0) & arrived
    else:
        pred2, pending2 = pred, pending

    carry2 = dict(remaining=rem2, slack=slack2, waited=waited2,
                  deadline_eff=dle, pred_left=pred2, in_sys=in_sys2,
                  finished=fin_all2, pending=pending2, ended=ended)
    # ys is the device->host transfer per slot, so it is kept lean: the
    # boolean take mask replaces the f64 frac/k_vec grids (the host
    # replays remaining/frac/energy from it exactly), counters fit int32
    ys = dict(take=take, fin=fin, viol=viol,
              waited_fin=waited_fin.astype(jnp.int32),
              n_rows=n_in.astype(jnp.int32), ended=ended)
    if kind == "mpc-scale":
        ys["scaled"] = scaled
    return carry2, ys


@functools.partial(jax.jit, static_argnames=("kind", "uniform", "deps"))
def _single_chunk(consts, carry, xs, kind: str, uniform: bool, deps: str):
    step = functools.partial(_single_step, consts, kind=kind,
                             uniform=uniform, deps=deps)
    return lax.scan(lambda c, x: step(c, x), carry, xs)


@functools.partial(jax.jit, static_argnames=("kind", "uniform", "deps"))
def _single_chunk_batch(consts, carry, xs, kind: str, uniform: bool,
                        deps: str):
    def one(c, ca, x):
        step = functools.partial(_single_step, c, kind=kind,
                                 uniform=uniform, deps=deps)
        return lax.scan(lambda cc, xx: step(cc, xx), ca, x)

    return jax.vmap(one)(consts, carry, xs)


# --- geo program -------------------------------------------------------------


@dataclasses.dataclass
class _GeoProgram:
    consts: dict
    carry0: dict
    n_pad: int
    kind: str                      # geo-static | geo-greedy | geo-flex
    uniform: bool                  # all k_min equal -> fill-key fixpoint
    xs_fn: Callable                # (ts) -> dict of per-slot tables
    power: np.ndarray
    mig_e: np.ndarray              # host transfer energy per row
    caps: np.ndarray
    mig_vals: list


def _build_geo(packed, geo: GeoCluster, policy, ci_pol,
               t0: int, horizon: int, kind: str) -> _GeoProgram:
    n = packed.n
    n_pad = _pad_rows(n)
    n_regions = geo.n_regions
    caps = geo.capacity_vec()
    power = np.where(packed.power > 0, packed.power, geo.power_per_server)
    kmin = packed.k_min
    thr = packed.thr_tab[np.arange(n), kmin]
    i64, f64 = np.int64, np.float64

    def padded(src, fill, dtype):
        out = np.full(n_pad, fill, dtype=dtype)
        out[:n] = src
        return out

    mig_slots = np.array([geo.migration.slots(j) for j in packed.jobs],
                         dtype=i64)
    mig_e = np.array([geo.migration.energy_kwh(j) for j in packed.jobs],
                     dtype=f64)
    mig_vals = sorted(set(mig_slots.tolist())) or [0]
    val2idx = {v: i for i, v in enumerate(mig_vals)}
    mig_idx = np.array([val2idx[int(v)] for v in mig_slots], dtype=i64)
    home = np.array([geo.home_region(i) for i in range(n)], dtype=i64)
    # e_run coefficient: ((k_min * power) * slot_hours), the first three
    # factors of both the energy expression and the policies' e_run
    ec = (kmin * power) * geo.slot_hours

    lookahead = getattr(policy, "lookahead", 24)
    percentile = getattr(policy, "percentile", 40.0)
    margin_c = 1.0 - getattr(policy, "saving_margin", 0.0)
    max_moves = int(getattr(policy, "max_migrations_per_job", 0))

    consts = jax.device_put(dict(
        arrival=padded(packed.arrival, _BIG_T, i64),
        kmin=padded(kmin, 1, i64),
        thr=padded(thr, 1.0, f64),
        thr_guard=padded(np.maximum(thr, 1e-9), 1.0, f64),
        deadline=padded(packed.deadline, 0, i64),
        ec=padded(ec, 0.0, f64),
        mig_e=padded(mig_e, 0.0, f64),
        mig_slots=padded(mig_slots, 0, i64),
        mig_idx=padded(mig_idx, 0, i64),
        caps=caps.astype(i64),
        margin_c=f64(margin_c),
        max_moves=i64(max_moves),
        n_real=i64(n),
        t_end=i64(t0 + horizon),
    ))
    carry0 = jax.device_put(dict(
        remaining=padded(packed.length, 0.0, f64),
        slack=padded([j.delay for j in packed.jobs], 0, i64),
        waited=np.zeros(n_pad, dtype=i64),
        in_sys=np.zeros(n_pad, dtype=bool),
        finished=np.zeros(n_pad, dtype=bool),
        started=np.zeros(n_pad, dtype=bool),
        placed=np.zeros(n_pad, dtype=bool),
        pol_region=padded(home, 0, i64),
        eng_region=padded(home, 0, i64),
        mig_left=np.zeros(n_pad, dtype=i64),
        moves=np.zeros(n_pad, dtype=i64),
        ended=np.asarray(False),
    ))

    # Per-chunk decision tables, one device_put each.  The CI/forecast
    # blocks go through the batched whole-trace fast paths above (the
    # per-slot Python API calls cost more than the device program);
    # batched slice means are bitwise equal to the per-slot
    # `fc[:, :h].mean(axis=1)` the policy computes (same pairwise
    # reduction over the same values — ascontiguousarray only changes
    # strides, never the reduction order).
    def xs_fn(ts: np.ndarray) -> dict:
        s = len(ts)
        xs = {"t": ts.astype(i64)}
        if kind == "geo-static":
            return jax.device_put(xs)
        civ = _ci_vec_block(ci_pol, ts)                           # (S, R)
        xs["ci_now"] = civ
        if kind == "geo-greedy":
            xs["clean_order"] = np.argsort(civ, axis=1,
                                           kind="stable").astype(i64)
            return jax.device_put(xs)
        fc = np.ascontiguousarray(
            _forecast_block(ci_pol, ts, lookahead))               # (S, R, H)
        xs["thresh_eps"] = np.percentile(fc, percentile, axis=2) + _EPS
        means = np.zeros((s, n_regions, lookahead))
        for h in range(1, lookahead + 1):
            means[:, :, h - 1] = fc[:, :, :h].mean(axis=2)
        xs["means"] = means
        movem = np.zeros((s, len(mig_vals), n_regions, lookahead))
        for mi, ms in enumerate(mig_vals):
            for h in range(1, lookahead - ms + 1):
                movem[:, mi, :, h - 1] = fc[:, :, ms:ms + h].mean(axis=2)
        xs["movemeans"] = movem
        return jax.device_put(xs)

    return _GeoProgram(consts=consts, carry0=carry0, n_pad=n_pad, kind=kind,
                       uniform=bool((kmin == kmin[0]).all()), xs_fn=xs_fn,
                       power=power, mig_e=mig_e, caps=caps,
                       mig_vals=mig_vals)


def _geo_step(consts, carry, x, *, kind: str, lookahead: int,
              uniform: bool):
    """One geo engine slot (mirrors ``_simulate_geo_vector`` + the geo
    policies' ``decide_geo`` + ``_resolve_geo``).

    Two exact implementations of the FCFS capacity walk:

    - ``uniform=True`` (every job requests the same ``k_min``): region
      fullness along the walk is binary and monotone, so the walk's
      outcome is characterised by one *fill key* per region — the FCFS
      key of the allocation that consumed the region's last slice; a row
      sees the region open iff its key is <= that.  The fill keys are the
      unique fixpoint of a monotone (non-increasing, componentwise) map,
      found by iterating the fully vectorised round below from "nothing
      fills"; it converges in at most R+1 rounds (each round pins at
      least the earliest not-yet-recorded fill event) and typically one.
      This replaces an n_pad-iteration sequential scan per slot with a
      handful of cumsums — the difference between ~9 ms and ~0.4 ms per
      slot at n_pad=768.
    - ``uniform=False``: the literal sequential row walk (a later small-k
      row may fit where an earlier big-k row did not, so fullness is not
      binary and the key-threshold model does not apply).
    """
    t = x["t"]
    rem = carry["remaining"]
    slack = carry["slack"]
    waited = carry["waited"]
    in_sys = carry["in_sys"]
    fin_all = carry["finished"]
    started = carry["started"]
    n_pad = rem.shape[0]
    i64 = jnp.int64

    arrived = consts["arrival"] <= t
    in_sys = in_sys | (arrived & ~fin_all)
    n_in = jnp.sum(in_sys)
    ended = carry["ended"] | ((n_in == 0)
                              & (jnp.sum(arrived) == consts["n_real"])
                              & (t >= consts["t_end"]))
    act = in_sys & ~ended

    forced = slack <= 0
    live = rem > _EPS
    cand = act & live & (carry["mig_left"] == 0)
    idx = jnp.arange(n_pad, dtype=i64)
    key = jnp.where(cand, (~forced).astype(i64) * n_pad + idx,
                    jnp.int64(2 * n_pad))

    if uniform:
        take, placed, polr, engr, migl, moves, mig_now = _geo_resolve_uniform(
            consts, carry, x, kind, lookahead, cand, forced, key, rem, slack,
            started)
    else:
        take, placed, polr, engr, migl, moves, mig_now = _geo_resolve_walk(
            consts, carry, x, kind, lookahead, cand, forced, key, rem, slack,
            started)

    rem2 = jnp.where(take, rem - consts["thr"], rem)
    started2 = started | take
    wmask = act & live & ~take
    slack2 = jnp.where(wmask, slack - 1, slack)
    waited2 = jnp.where(wmask, waited + 1, waited)
    migl2 = jnp.where(wmask & (migl > 0), migl - 1, migl)

    fin = act & (rem2 <= _EPS)
    viol = fin & (t > consts["deadline"])
    waited_fin = jnp.where(fin, waited2, 0)

    carry2 = dict(remaining=rem2, slack=slack2, waited=waited2,
                  in_sys=in_sys & ~fin, finished=fin_all | fin,
                  started=started2, placed=placed, pol_region=polr,
                  eng_region=engr, mig_left=migl2, moves=moves,
                  ended=ended)
    # lean device->host transfer: frac/k_vec/energy replay host-side from
    # the boolean take mask, region ids and counters fit int32
    ys = dict(take=take, region=engr.astype(jnp.int32),
              mig_now=mig_now, fin=fin, viol=viol,
              waited_fin=waited_fin.astype(jnp.int32),
              n_rows=n_in.astype(jnp.int32), ended=ended)
    return carry2, ys


def _geo_resolve_uniform(consts, carry, x, kind, lookahead, cand, forced,
                         key, rem, slack, started):
    """Vectorised uniform-k resolution: row-local placement preferences,
    migration economics and eligibility, then the fill-key fixpoint for
    the FCFS capacity coupling.  Bit-identical to the walk (same
    expressions evaluated per row; the only cross-row state — region
    fullness — is reproduced exactly by the fill keys)."""
    i64 = jnp.int64
    caps = consts["caps"]
    n_pad = rem.shape[0]
    n_r = caps.shape[0]
    ridx = jnp.arange(n_r, dtype=i64)
    strt = started

    # region bookkeeping before the capacity fixpoint (row-local)
    if kind == "geo-greedy":
        # defensive sync (policy: started & unplaced adopts a.region)
        adopt = cand & strt & ~carry["placed"]
        polr0 = jnp.where(adopt, carry["eng_region"], carry["pol_region"])
        placed0 = carry["placed"] | adopt
        rfix = polr0                    # walk's r for non-newly rows
    elif kind == "geo-flex":
        polr0 = carry["pol_region"]
        placed0 = carry["placed"]
        rfix = jnp.where(strt, carry["eng_region"], polr0)
    else:
        polr0 = carry["pol_region"]
        placed0 = carry["placed"]
        rfix = carry["eng_region"]

    # placement preference order (rows searching for a region)
    if kind == "geo-greedy":
        unplz = cand & ~strt & ~placed0
        pref = jnp.broadcast_to(x["clean_order"][None, :], (n_pad, n_r))
    elif kind == "geo-flex":
        unplz = cand & ~strt & ~placed0
        hp = jnp.minimum(jnp.float64(lookahead),
                         jnp.maximum(1.0, jnp.ceil(rem))).astype(i64)
        means_h = x["means"][:, jnp.clip(hp - 1, 0, lookahead - 1)].T
        pref = jnp.argsort(means_h, axis=1, stable=True)
    else:
        unplz = jnp.zeros_like(cand)
        pref = None

    # migration economics (row-local: greedy prices instantaneous CI,
    # flex prices forecast window means shifted past the migration window)
    if kind == "geo-static":
        do_mig = jnp.zeros_like(cand)
        best = rfix
        msv = jnp.zeros(n_pad, dtype=i64)
    else:
        msv = consts["mig_slots"]
        can = (cand & strt & (carry["moves"] < consts["max_moves"])
               & (slack > msv + 1) & (rem > msv.astype(jnp.float64)))
        if kind == "geo-greedy":
            h = jnp.maximum(1.0, jnp.ceil(rem))
            e_run = consts["ec"] * h
            stay = x["ci_now"][rfix] * e_run
            move = (x["ci_now"][None, :] * e_run[:, None]
                    + consts["mig_e"][:, None] * x["ci_now"][None, :])
        else:
            hm = jnp.minimum(
                (jnp.int64(lookahead) - msv).astype(jnp.float64),
                jnp.maximum(1.0, jnp.ceil(rem)))
            can = can & (hm >= 1.0)
            him = jnp.clip(hm.astype(i64) - 1, 0, lookahead - 1)
            e_run = consts["ec"] * hm
            stay = x["means"][rfix, him] * e_run
            move = (x["movemeans"][consts["mig_idx"][:, None],
                                   ridx[None, :], him[:, None]]
                    * e_run[:, None]
                    + consts["mig_e"][:, None] * x["ci_now"][None, :])
        move = jnp.where(ridx[None, :] == rfix[:, None], jnp.inf, move)
        best = jnp.argmin(move, axis=1)
        do_mig = can & (jnp.take_along_axis(move, best[:, None], 1)[:, 0]
                        < stay * consts["margin_c"])

    # --- fill-key fixpoint ---------------------------------------------------
    k0 = consts["kmin"][0]              # uniform k (real rows; row 0 is real)
    cap_n = caps // k0                  # takers each region can hold
    k_inf = jnp.int64(4 * n_pad)
    k_init = jnp.where(cap_n > 0, k_inf, jnp.int64(-1))
    fvalid = cand & ~do_mig             # rows that may consume capacity

    def decide(kfill):
        """Per-row target region + capacity/eligibility under fill keys."""
        if kind == "geo-static":
            return rfix, key <= kfill[rfix], jnp.ones_like(cand), \
                jnp.zeros_like(cand), rfix
        openp = key[:, None] <= kfill[pref]            # pref order
        first = jnp.argmax(openp, axis=1)
        any_open = jnp.any(openp, axis=1)
        t_pl = jnp.take_along_axis(pref, first[:, None], 1)[:, 0]
        target = jnp.where(unplz, t_pl, rfix)
        attempt = jnp.where(unplz, any_open, key <= kfill[rfix])
        if kind == "geo-flex":
            elig = forced | (x["ci_now"][target] <= x["thresh_eps"][target])
        else:
            elig = jnp.ones_like(cand)
        return target, attempt, elig, any_open, t_pl

    def refill(kfill):
        """One round: takers under current fill keys -> new fill keys.
        Taker counts in FCFS-key order without a sort: the key order is
        forced rows by index then unforced by index, so two cumsums give
        each taker's inclusive rank; the cap-th taker's key is the fill."""
        target, attempt, elig, _, _ = decide(kfill)
        m = fvalid & attempt & elig
        oh = m[:, None] & (target[:, None] == ridx[None, :])
        cf = jnp.cumsum(oh & forced[:, None], axis=0, dtype=i64)
        cu = jnp.cumsum(oh & ~forced[:, None], axis=0, dtype=i64)
        cnt = jnp.where(forced[:, None], cf, cf[-1][None, :] + cu)
        at_fill = oh & (cnt == cap_n[None, :])
        k_new = jnp.min(jnp.where(at_fill, key[:, None], k_inf), axis=0)
        return jnp.minimum(kfill, k_new)

    k1 = refill(k_init)
    kfill, _ = lax.while_loop(
        lambda st: st[1],
        lambda st: (lambda k2: (k2, jnp.any(k2 != st[0])))(refill(st[0])),
        (k1, jnp.any(k1 != k_init)))

    target, attempt, elig, any_open, t_pl = decide(kfill)
    take = fvalid & attempt & elig
    if kind == "geo-static":
        return (take, carry["placed"], carry["pol_region"],
                carry["eng_region"], carry["mig_left"], carry["moves"],
                jnp.zeros_like(cand))
    newly = unplz & any_open            # placed even when ineligible to run
    placed = placed0 | newly | do_mig
    polr = jnp.where(do_mig, best, jnp.where(newly, t_pl, polr0))
    # engine region: migration moves it; a granted allocation on a
    # never-started job is a free placement
    engr = jnp.where(do_mig, best,
                     jnp.where(take & ~strt, target, carry["eng_region"]))
    migl = jnp.where(do_mig, msv, carry["mig_left"])
    moves = carry["moves"] + do_mig.astype(i64)
    return take, placed, polr, engr, migl, moves, do_mig


def _geo_resolve_walk(consts, carry, x, kind, lookahead, cand, forced, key,
                      rem, slack, started):
    """Literal sequential FCFS walk (non-uniform ``k_min`` fallback)."""
    i64 = jnp.int64
    caps = consts["caps"]
    kmin = consts["kmin"]
    n_pad = rem.shape[0]
    order = jnp.argsort(key, stable=True)

    def walk(st, row):
        used, placed, polr, engr, migl, moves, take, mig_now = st
        valid = cand[row]
        k = kmin[row]
        rv = rem[row]
        strt = started[row]

        if kind == "geo-static":
            r = engr[row]
            newly = jnp.asarray(False)
            r_new = r
        elif kind == "geo-greedy":
            # defensive sync (policy: started & unplaced adopts a.region)
            adopt = valid & strt & ~placed[row]
            polr0 = jnp.where(adopt, engr[row], polr[row])
            placed0 = placed[row] | adopt
            co = x["clean_order"]
            fits_vec = used[co] + k <= caps[co]
            r_place = co[jnp.argmax(fits_vec)]
            newly = valid & ~strt & ~placed0 & jnp.any(fits_vec)
            r_new = jnp.where(newly, r_place, polr0)
            placed1 = placed0 | newly
            r = r_new
        else:  # geo-flex
            strt_r = engr[row]                  # started jobs: a.region
            hp = jnp.minimum(jnp.float64(lookahead),
                             jnp.maximum(1.0, jnp.ceil(rv))).astype(i64)
            means_h = x["means"][:, jnp.clip(hp - 1, 0, lookahead - 1)]
            porder = jnp.argsort(means_h, stable=True)
            fits_vec = used[porder] + k <= caps[porder]
            r_place = porder[jnp.argmax(fits_vec)]
            newly = valid & ~strt & ~placed[row] & jnp.any(fits_vec)
            placed1 = placed[row] | newly
            r_new = jnp.where(newly, r_place, polr[row])
            r = jnp.where(strt, strt_r, r_new)

        # migration economics (geo-greedy: instantaneous CI; geo-flex:
        # forecast window means shifted past the migration window)
        if kind == "geo-static":
            do_mig = jnp.asarray(False)
            best = r
            ms = jnp.int64(0)
        else:
            ms = consts["mig_slots"][row]
            can = (valid & strt & (moves[row] < consts["max_moves"])
                   & (slack[row] > ms + 1) & (rv > ms.astype(jnp.float64)))
            if kind == "geo-greedy":
                h = jnp.maximum(1.0, jnp.ceil(rv))
                e_run = consts["ec"][row] * h
                stay = x["ci_now"][r] * e_run
                mig_c = consts["mig_e"][row] * x["ci_now"]
                move = x["ci_now"] * e_run + mig_c
            else:
                hm = jnp.minimum((jnp.int64(lookahead) - ms).astype(
                    jnp.float64), jnp.maximum(1.0, jnp.ceil(rv)))
                can = can & (hm >= 1.0)
                hi = jnp.clip(hm.astype(i64) - 1, 0, lookahead - 1)
                e_run = consts["ec"][row] * hm
                stay = x["means"][r, hi] * e_run
                mig_c = consts["mig_e"][row] * x["ci_now"]
                move = (x["movemeans"][consts["mig_idx"][row], :, hi]
                        * e_run + mig_c)
            stay_m = stay * consts["margin_c"]
            move = move.at[r].set(jnp.inf)
            best = jnp.argmin(move)
            do_mig = can & (move[best] < stay_m)

        # run eligibility + capacity ("continue" on failure)
        if kind == "geo-flex":
            elig = forced[row] | (x["ci_now"][r] <= x["thresh_eps"][r])
        else:
            elig = jnp.asarray(True)
        placeable = (strt | placed[row] | newly) if kind != "geo-static" \
            else jnp.asarray(True)
        fits = used[r] + k <= caps[r]
        do_run = valid & ~do_mig & placeable & elig & fits

        used2 = used.at[r].add(jnp.where(do_run, k, 0))
        take2 = take.at[row].set(do_run)
        mig2 = mig_now.at[row].set(do_mig)
        if kind == "geo-static":
            placed2, polr2 = placed, polr
            engr2 = engr
        else:
            placed2 = placed.at[row].set(placed1 | do_mig)
            polr2 = polr.at[row].set(jnp.where(do_mig, best, r_new))
            # engine region: migration moves it; a granted allocation on a
            # never-started job is a free placement
            engr2 = engr.at[row].set(
                jnp.where(do_mig, best,
                          jnp.where(do_run & ~strt, r, engr[row])))
        migl2 = migl.at[row].set(jnp.where(do_mig, ms, migl[row]))
        moves2 = moves.at[row].add(do_mig.astype(i64))
        return (used2, placed2, polr2, engr2, migl2, moves2, take2,
                mig2), None

    st0 = (jnp.zeros(caps.shape[0], dtype=i64), carry["placed"],
           carry["pol_region"], carry["eng_region"], carry["mig_left"],
           carry["moves"], jnp.zeros(n_pad, dtype=bool),
           jnp.zeros(n_pad, dtype=bool))
    (used, placed, polr, engr, migl, moves, take, mig_now), _ = lax.scan(
        walk, st0, order)
    return take, placed, polr, engr, migl, moves, mig_now


@functools.partial(jax.jit, static_argnames=("kind", "lookahead", "uniform"))
def _geo_chunk(consts, carry, xs, kind: str, lookahead: int, uniform: bool):
    def step(c, x):
        return _geo_step(consts, c, x, kind=kind, lookahead=lookahead,
                         uniform=uniform)

    return lax.scan(step, carry, xs)


# --- host accounting ---------------------------------------------------------


def _active_energy(packed, power, slot_h, eta, take_a):
    """Replay fractional progress and the vector engine's exact energy
    expressions over the active (slot, row) cells of the emitted take
    mask, host-side.

    The device updates ``remaining`` with one subtraction per take slot
    (``rem - thr``) and derives ``frac = min(1, rem / thr_guard)`` from
    the pre-update value; replaying those row-wise here performs the
    identical scalar arithmetic in the identical order — bitwise equal —
    while keeping the device->host transfer to one boolean grid instead
    of an f64 one.  The nonzero cells (row-major: each slot's segment in
    row order) are the per-slot active sets.  Every energy operation is
    elementwise, so each cell sees the identical arithmetic to a
    per-slot replay (active cells have ``k >= 1``, so the ``maximum``
    divisor guard never fires).  Returns per-slot segment bounds plus
    row ids, allocations and energies of the active cells."""
    n = take_a.shape[1]
    s_idx, r_idx = np.nonzero(take_a)
    bounds = np.searchsorted(s_idx, np.arange(take_a.shape[0] + 1))
    thr = packed.thr_tab[np.arange(n), packed.k_min]
    thr_guard = np.maximum(thr, 1e-9)
    rem = packed.length.astype(np.float64, copy=True)
    frac = np.empty(len(r_idx))
    for i in range(take_a.shape[0]):
        rows = r_idx[bounds[i]:bounds[i + 1]]
        frac[bounds[i]:bounds[i + 1]] = np.minimum(
            1.0, rem[rows] / thr_guard[rows])
        rem[rows] -= thr[rows]
    k = packed.k_min[r_idx]
    e_comp = k * power[r_idx] * slot_h * frac
    ring = np.where(k <= 1, 0.0, 2.0 * (k - 1) / np.maximum(k, 1))
    gbits = packed.comm[r_idx] * 8.0 * ring * k * frac
    e = e_comp + eta * gbits / 3600.0 / 1000.0 * slot_h
    return bounds, r_idx, k, e


def _active_energy_cells(packed, power, slot_h, eta, take_a, k_rows):
    """``_active_energy`` for slot-varying allocations (mpc-scale).

    ``k_rows`` is the (S, n) grid of the allocation each take cell ran
    at; throughput is gathered per cell (``thr_tab[row, k]``) and the
    replay performs the identical per-slot scalar arithmetic the vector
    engine's allocated-k path does — bitwise equal by the same argument
    as the k_min replay above."""
    s_idx, r_idx = np.nonzero(take_a)
    bounds = np.searchsorted(s_idx, np.arange(take_a.shape[0] + 1))
    k = k_rows[s_idx, r_idx]
    thr = packed.thr_tab[r_idx, k]
    thr_guard = np.maximum(thr, 1e-9)
    rem = packed.length.astype(np.float64, copy=True)
    frac = np.empty(len(r_idx))
    for i in range(take_a.shape[0]):
        lo, hi = bounds[i], bounds[i + 1]
        rows = r_idx[lo:hi]
        frac[lo:hi] = np.minimum(1.0, rem[rows] / thr_guard[lo:hi])
        rem[rows] -= thr[lo:hi]
    e_comp = k * power[r_idx] * slot_h * frac
    ring = np.where(k <= 1, 0.0, 2.0 * (k - 1) / np.maximum(k, 1))
    gbits = packed.comm[r_idx] * 8.0 * ring * k * frac
    e = e_comp + eta * gbits / 3600.0 / 1000.0 * slot_h
    return bounds, r_idx, k, e


def _collect_chunks(prog_consts, carry, chunk_fn, xs_builder, t0: int,
                    t_mid: int, t_hard: int) -> tuple[dict, int]:
    """Run device chunks until the case ends or t_hard; returns stacked
    host ys + the count of valid (pre-termination) slots.

    Inside the horizon (< ``t_mid``) termination is impossible (the
    engines' ended-check requires ``t >= t0 + horizon``), so full CHUNK
    dispatches are free of waste; past the horizon the case can end any
    slot, so smaller OVERRUN_CHUNK dispatches bound the slots computed
    beyond the actual end."""
    ys_parts = []
    t_lo = t0
    while t_lo < t_hard:
        cap = CHUNK if t_lo < t_mid else OVERRUN_CHUNK
        size = min(cap, t_hard - t_lo)
        ts = np.arange(t_lo, t_lo + size)
        carry, ys = chunk_fn(prog_consts, carry, xs_builder(ts))
        ys_parts.append(jax.device_get(ys))
        t_lo += size
        if bool(np.asarray(carry["ended"])):
            break
    ys = {k: np.concatenate([p[k] for p in ys_parts]) for k in ys_parts[0]}
    ended = np.asarray(ys["ended"], dtype=bool)
    n_valid = int(np.argmax(ended)) if ended.any() else len(ended)
    return ys, n_valid


def _run_single_native(packed, ci, ci_pol, cluster, policy, t0, horizon,
                       max_overrun, kind,
                       telemetry: Telemetry | None = None) -> SimResult:
    from .simulator import _run_resilience

    prog = _build_single(packed, cluster, policy, ci_pol, kind, t0, horizon)
    t_hard = t0 + horizon + max_overrun

    def xs_builder(ts):
        return jax.device_put(prog.xs_fn(ts))

    def chunk_fn(consts, carry, xs):
        return _single_chunk(consts, carry, xs, prog.kind, prog.uniform,
                             prog.deps)

    prof = telemetry.profiler if telemetry is not None else None
    if prof is not None:
        _pt = time.perf_counter()
    ys, n_valid = _collect_chunks(prog.consts, prog.carry0, chunk_fn,
                                  xs_builder, t0, t0 + horizon, t_hard)
    if prof is not None:
        # device_get inside _collect_chunks already synchronised the scan
        prof.add("decide", time.perf_counter() - _pt)
    return _account_single(packed, ci, ci_pol, cluster, policy, t0, ys,
                           n_valid, prog, telemetry=telemetry)


def _scan_admit_slots(packed, t0, n_valid, fs, fr):
    """Reconstruct each row's admission slot from the finish grid.

    Mirrors the vector engine exactly: a row enters the system at
    ``max(arrival, t0)``, except DAG rows wait for every predecessor and
    release the slot *after* the last one finishes.  Rows whose
    predecessors never finish (or that admit past the run) return -1."""
    admit = np.maximum(packed.arrival, t0).astype(np.int64, copy=True)
    if packed.has_deps:
        comp = np.full(packed.n, -1, dtype=np.int64)
        comp[fr] = t0 + fs
        id2row = packed.id2row
        for r, job in enumerate(packed.jobs):
            for dep in job.deps:
                c = comp[id2row[dep]]
                if c < 0:
                    admit[r] = -1
                    break
                admit[r] = max(admit[r], c + 1)
    admit[admit - t0 >= n_valid] = -1
    return admit


def _scan_slot_events(take, fs, fr, n_valid):
    """Vectorised resume/suspend derivation from the dense take grid.

    Semantically identical to feeding ``SlotEventTracker.step`` the
    per-slot allocation stream (the native scan k is always ``k_min``,
    so scale events cannot fire), but computed in a handful of whole-run
    numpy passes instead of a per-slot Python walk — this is what keeps
    scan-path recording inside its 1.3x overhead budget.  Returns
    ``(resume_rows, resume_bounds, suspend_rows, suspend_bounds)`` with
    rows ascending within each slot (scan packing sorts rows by job id,
    so ascending row order == the tracker's sorted-job suspend order).
    """
    m = np.asarray(take, dtype=bool)
    n = m.shape[1]
    # on/off transitions between consecutive slots (transition index i is
    # slot i+1); slot 0 has no transitions — first activations there are
    # starts, and nothing can switch off into it.
    cs, cr = np.nonzero(m[1:] & ~m[:-1])
    # a row's first switch-on is its start (admit covers it), unless the
    # row was already running at slot 0 — then every switch-on resumes.
    uniq, first = np.unique(cr, return_index=True)
    keep = np.ones(len(cr), dtype=bool)
    keep[first[~m[0][uniq]]] = False
    rs, rr = cs[keep] + 1, cr[keep]
    # a switch-off is a suspend unless the row finished at the prior slot
    # (each row finishes at most once, so a per-row slot table suffices)
    os_, orow = np.nonzero(m[:-1] & ~m[1:])
    finslot = np.full(n, -2, dtype=np.int64)
    if len(fs):
        finslot[np.asarray(fr)] = fs
    keep = os_ != finslot[orow]
    ss, sr = os_[keep] + 1, orow[keep]
    return (rr.tolist(), np.searchsorted(rs, np.arange(n_valid + 1)),
            sr.tolist(), np.searchsorted(ss, np.arange(n_valid + 1)))


def _account_single(packed, ci, ci_pol, cluster, policy, t0, ys, n_valid,
                    prog, telemetry: Telemetry | None = None) -> SimResult:
    from .simulator import _run_resilience, _telemetry_hooks

    tele, prof, _, _ = _telemetry_hooks(telemetry, None)
    n = packed.n
    slot_h = cluster.slot_hours
    eta = cluster.eta_net
    wait = np.zeros(n)
    violations = np.zeros(n, dtype=bool)
    completion = np.full(n, -1, dtype=np.int64)
    logs: list[SlotLog] = []
    total_energy = 0.0
    total_carbon = 0.0
    take_a = ys["take"][:n_valid, :n]
    if prog.kind == "mpc-scale":
        k_rows = np.where(np.asarray(ys["scaled"][:n_valid, :n], dtype=bool),
                          prog.k_up[None, :], packed.k_min[None, :])
        bounds, r_idx, k_act, e_act = _active_energy_cells(
            packed, prog.power, slot_h, eta, take_a, k_rows)
    else:
        bounds, r_idx, k_act, e_act = _active_energy(packed, prog.power,
                                                     slot_h, eta, take_a)
    fs, fr = np.nonzero(ys["fin"][:n_valid, :n])
    fbounds = np.searchsorted(fs, np.arange(n_valid + 1))
    wfin_f = ys["waited_fin"][:n_valid, :n][fs, fr]
    viol_f = ys["viol"][:n_valid, :n][fs, fr]
    n_rows_a = ys["n_rows"][:n_valid]
    civ_a = _ci_block(ci, t0, n_valid)
    admits_by: dict[int, list[int]] = {}
    if tele is not None:
        aslots = _scan_admit_slots(packed, t0, n_valid, fs, fr)
        for r, s in enumerate(aslots.tolist()):     # row order == sorted
            if s >= 0:
                admits_by.setdefault(s, []).append(r)
        jids = packed.job_ids.tolist()
        kv = [float(k) for k in packed.k_min.tolist()]
        rr, rb, sr, sb = _scan_slot_events(take_a, fs, fr, n_valid)
        emit = tele.emit
    if prof is not None:
        _pt = time.perf_counter()
    for i in range(n_valid):
        t = t0 + i
        civ = float(civ_a[i])
        lo, hi = bounds[i], bounds[i + 1]
        if tele is not None:
            for r in admits_by.get(t, ()):
                emit(t, "admit", job=jids[r])
            if ci_pol is not ci:
                emit(t, "forecast-read", value=float(ci_pol.staleness(t)))
            for r in rr[rb[i]:rb[i + 1]]:
                emit(t, "resume", job=jids[r], value=kv[r])
            for r in sr[sb[i]:sb[i + 1]]:
                emit(t, "suspend", job=jids[r])
        energy = 0.0
        for v in e_act[lo:hi].tolist():        # sequential sum, scalar order
            energy += v
        carbon = emissions.slot_carbon_g(energy, civ)
        total_energy += energy
        total_carbon += carbon
        flo, fhi = fbounds[i], fbounds[i + 1]
        frows = fr[flo:fhi]
        if len(frows):
            completion[frows] = t
            wait[frows] = wfin_f[flo:fhi]
            violations[frows] = viol_f[flo:fhi]
        used = int(k_act[lo:hi].sum())
        running = int(hi - lo)
        logs.append(SlotLog(slot=t, ci=civ, provisioned=prog.m_t, used=used,
                            energy_kwh=energy, carbon_g=carbon,
                            running=running,
                            queued=int(n_rows_a[i]) - len(frows)
                            - running))
    if prof is not None:
        prof.add("execute", time.perf_counter() - _pt)
    return SimResult(
        policy=policy.name, carbon_g=total_carbon, energy_kwh=total_energy,
        slots=logs, wait_slots=wait, violations=violations,
        completion=completion, num_jobs=n,
        resilience=_run_resilience(None, ci_pol, ci, t0, t0 + n_valid))


def _run_geo_native(packed, mci, ci_pol, geo, policy, t0, horizon,
                    max_overrun, kind,
                    telemetry: Telemetry | None = None) -> SimResult:
    from .simulator import (_accumulate_regions, _run_resilience,
                            _telemetry_hooks)

    lookahead = int(getattr(policy, "lookahead", 24))
    t_hard = t0 + horizon + max_overrun
    prog = _build_geo(packed, geo, policy, ci_pol, t0, horizon, kind)

    def chunk_fn(consts, carry, xs):
        return _geo_chunk(consts, carry, xs, kind, lookahead, prog.uniform)

    tele, prof, _, _ = _telemetry_hooks(telemetry, None)
    if prof is not None:
        _pt = time.perf_counter()
    ys, n_valid = _collect_chunks(prog.consts, prog.carry0, chunk_fn,
                                  prog.xs_fn, t0, t0 + horizon, t_hard)
    if prof is not None:
        prof.add("decide", time.perf_counter() - _pt)

    n = packed.n
    n_regions = geo.n_regions
    slot_h = geo.slot_hours
    eta = geo.eta_net
    wait = np.zeros(n)
    violations = np.zeros(n, dtype=bool)
    completion = np.full(n, -1, dtype=np.int64)
    final_region = np.full(n, -1, dtype=np.int64)
    region_energy = np.zeros(n_regions)
    region_carbon = np.zeros(n_regions)
    migrations = 0
    mig_carbon_total = 0.0
    logs: list[SlotLog] = []
    total_energy = 0.0
    total_carbon = 0.0
    provisioned = int(prog.caps.sum())
    take_a = ys["take"][:n_valid, :n]
    reg_a = ys["region"][:n_valid, :n]
    bounds, r_act, k_act, e_act = _active_energy(packed, prog.power, slot_h,
                                                 eta, take_a)
    areg_act = reg_a[np.repeat(np.arange(n_valid), np.diff(bounds)), r_act]
    fs, fr = np.nonzero(ys["fin"][:n_valid, :n])
    fbounds = np.searchsorted(fs, np.arange(n_valid + 1))
    wfin_f = ys["waited_fin"][:n_valid, :n][fs, fr]
    viol_f = ys["viol"][:n_valid, :n][fs, fr]
    ms_idx, mr_idx = np.nonzero(ys["mig_now"][:n_valid, :n])
    mbounds = np.searchsorted(ms_idx, np.arange(n_valid + 1))
    n_rows_a = ys["n_rows"][:n_valid]
    civ_a = _ci_vec_acct_block(mci, t0, n_valid)
    admits_by: dict[int, list[int]] = {}
    if tele is not None:
        # geo native excludes DAG jobs, so admission is arrival-only
        aslots = _scan_admit_slots(packed, t0, n_valid, (), ())
        for r, s in enumerate(aslots.tolist()):     # row order == sorted
            if s >= 0:
                admits_by.setdefault(s, []).append(r)
        jids = packed.job_ids.tolist()
        kv = [float(k) for k in packed.k_min.tolist()]
        rr, rb, sr, sb = _scan_slot_events(take_a, fs, fr, n_valid)
        emit = tele.emit
    if prof is not None:
        _pt = time.perf_counter()
    for i in range(n_valid):
        t = t0 + i
        ci_vec = civ_a[i]
        lo, hi = bounds[i], bounds[i + 1]
        mrows = mr_idx[mbounds[i]:mbounds[i + 1]]
        if tele is not None:
            for r in admits_by.get(t, ()):
                emit(t, "admit", job=jids[r])
            if ci_pol is not mci:
                emit(t, "forecast-read", value=float(ci_pol.staleness(t)))
            for row in mrows.tolist():             # decision order
                src = (int(reg_a[i - 1, row]) if i > 0
                       else geo.home_region(row))
                emit(t, "migrate", job=jids[row],
                     value=float(reg_a[i, row]), detail=f"from={src}")
            for r in rr[rb[i]:rb[i + 1]]:
                emit(t, "resume", job=jids[r], value=kv[r])
            for r in sr[sb[i]:sb[i + 1]]:
                emit(t, "suspend", job=jids[r])
        e_vec = e_act[lo:hi]
        a_regions = areg_act[lo:hi]
        energy_r = np.zeros(n_regions)
        for r in range(n_regions):
            for v in e_vec[a_regions == r].tolist():
                energy_r[r] += v
        mc = 0.0
        for row in mrows.tolist():             # row order == decision order
            e = prog.mig_e[row]
            dest = int(reg_a[i, row])
            energy_r[dest] += e
            mc += e * ci_vec[dest]
        mig_carbon_total += mc
        migrations += len(mrows)
        energy, carbon = _accumulate_regions(energy_r, ci_vec,
                                             region_energy, region_carbon)
        total_energy += energy
        total_carbon += carbon
        flo, fhi = fbounds[i], fbounds[i + 1]
        frows = fr[flo:fhi]
        if len(frows):
            completion[frows] = t
            wait[frows] = wfin_f[flo:fhi]
            violations[frows] = viol_f[flo:fhi]
            final_region[frows] = reg_a[i, frows]
        used = int(k_act[lo:hi].sum())
        running = int(hi - lo)
        logs.append(SlotLog(slot=t, ci=float(np.mean(ci_vec)),
                            provisioned=provisioned, used=used,
                            energy_kwh=energy, carbon_g=carbon,
                            running=running,
                            queued=int(n_rows_a[i]) - len(frows)
                            - running))
    if prof is not None:
        prof.add("execute", time.perf_counter() - _pt)
    return SimResult(
        policy=policy.name, carbon_g=total_carbon, energy_kwh=total_energy,
        slots=logs, wait_slots=wait, violations=violations,
        completion=completion, num_jobs=n, regions=geo.regions,
        region_carbon_g=region_carbon, region_energy_kwh=region_energy,
        final_region=final_region, migrations=migrations,
        migration_carbon_g=mig_carbon_total,
        resilience=_run_resilience(None, ci_pol, mci, t0, t0 + n_valid))


# --- public API --------------------------------------------------------------


def simulate_scan(jobs, ci, cluster, policy, t0: int = 0,
                  horizon: int | None = None, max_overrun: int = 24 * 21,
                  faults=None, packed=None,
                  telemetry: Telemetry | None = None) -> SimResult:
    """``simulate(..., engine="scan")``: jitted lax.scan slot loop for
    native policies, transparent vector-engine delegation otherwise."""
    from .simulator import (_packed_for, _policy_ci_view, _simulate_vector,
                            _simulate_geo_vector)

    if packed is None:
        packed = _packed_for(jobs)
    kind = native_kind(policy, cluster, faults)
    if (kind == "mpc-scale" and telemetry is not None
            and telemetry.recorder is not None):
        # _scan_slot_events derives resume/suspend assuming k == k_min
        # (no scale events); event-recorded scale runs use the vector
        # engine, whose tracker sees the true per-slot allocations
        kind = None
    if kind is None or packed.n == 0 or (packed.has_deps
                                         and isinstance(cluster, GeoCluster)):
        if isinstance(cluster, GeoCluster):
            # geo + deps delegates so the vector engine raises its usual
            # "geo engines do not support DAG jobs" rejection
            return _simulate_geo_vector(jobs, ci, cluster, policy, t0,
                                        horizon, max_overrun, faults,
                                        packed=packed, telemetry=telemetry)
        return _simulate_vector(jobs, ci, cluster, policy, t0, horizon,
                                max_overrun, faults, packed=packed,
                                telemetry=telemetry)
    horizon = int(horizon if horizon is not None else len(ci) - t0)
    ci_pol = _policy_ci_view(ci)
    policy.on_window_start(ci_pol, t0, horizon, packed.jobs, cluster)
    with enable_x64():
        if kind in _SINGLE_KINDS:
            return _run_single_native(packed, ci, ci_pol, cluster, policy,
                                      t0, horizon, max_overrun, kind,
                                      telemetry=telemetry)
        return _run_geo_native(packed, ci, ci_pol, cluster, policy, t0,
                               horizon, max_overrun, kind,
                               telemetry=telemetry)


def simulate_many_scan(cases: Sequence) -> list[SimResult]:
    """Batch path: group scan-native single-region cases by structure and
    run each group as one vmapped device program (chunked); geo-native
    cases run per-case through the jitted geo scan; everything else
    delegates to the vector engine."""
    from .simulator import (_packed_for, _policy_ci_view, _simulate_vector,
                            _simulate_geo_vector)

    results: list[SimResult | None] = [None] * len(cases)
    groups: dict[tuple, list[tuple[int, object, object, _SingleProgram]]] = {}
    delegated: dict[str, int] = {}
    with enable_x64():
        for i, case in enumerate(cases):
            packed = _packed_for(case.jobs)
            telemetry = getattr(case, "telemetry", None)
            kind = native_kind(case.policy, case.cluster, case.faults)
            if (kind == "mpc-scale" and telemetry is not None
                    and telemetry.recorder is not None):
                kind = None     # see simulate_scan: scale events
            if kind is None or packed.n == 0 or (
                    packed.has_deps and isinstance(case.cluster, GeoCluster)):
                if packed.n > 0:
                    who = (getattr(case, "label", "")
                           or type(case.policy).__name__)
                    delegated[who] = delegated.get(who, 0) + 1
                fn = (_simulate_geo_vector
                      if isinstance(case.cluster, GeoCluster)
                      else _simulate_vector)
                results[i] = fn(case.jobs, case.ci, case.cluster,
                                case.policy, case.t0, case.horizon,
                                case.max_overrun, case.faults, packed=packed,
                                telemetry=telemetry)
                continue
            horizon = int(case.horizon if case.horizon is not None
                          else len(case.ci) - case.t0)
            ci_pol = _policy_ci_view(case.ci)
            case.policy.on_window_start(ci_pol, case.t0, horizon,
                                        packed.jobs, case.cluster)
            if kind not in _SINGLE_KINDS:
                results[i] = _run_geo_native(packed, case.ci, ci_pol,
                                             case.cluster, case.policy,
                                             case.t0, horizon,
                                             case.max_overrun, kind,
                                             telemetry=telemetry)
                continue
            prog = _build_single(packed, case.cluster, case.policy, ci_pol,
                                 kind, case.t0, horizon)
            dep_dim = (prog.consts["pred_rows"].shape[1]
                       if prog.deps == "gather"
                       else prog.consts["parents"].shape[0]
                       if prog.deps == "scatter" else 0)
            key = (prog.n_pad, prog.kind, prog.xs_dims, prog.deps,
                   int(dep_dim), prog.uniform, horizon,
                   horizon + case.max_overrun)
            groups.setdefault(key, []).append((i, case, packed, prog, ci_pol))
        for key, members in groups.items():
            for lo in range(0, len(members), BATCH_TILE):
                _run_single_tile(members[lo:lo + BATCH_TILE], results)
    if delegated:
        # once per batch, not per case: sweeps that think they run on the
        # scan engine should know which cases silently fell back
        _log.info("scan batch: %d case(s) delegated to the vector engine "
                  "(%s)", sum(delegated.values()),
                  ", ".join(f"{k} x{v}" for k, v in sorted(delegated.items())))
    return results  # type: ignore[return-value]


def _run_single_tile(members, results) -> None:
    """One vmapped tile of structurally identical single-region cases."""
    if len(members) == 1:
        i, case, packed, prog, ci_pol = members[0]
        horizon = int(case.horizon if case.horizon is not None
                      else len(case.ci) - case.t0)
        t_hard = case.t0 + horizon + case.max_overrun

        def xs_builder(ts):
            return jax.device_put(prog.xs_fn(ts))

        def chunk_fn(consts, carry, xs):
            return _single_chunk(consts, carry, xs, prog.kind, prog.uniform,
                                 prog.deps)

        telemetry = getattr(case, "telemetry", None)
        prof = telemetry.profiler if telemetry is not None else None
        if prof is not None:
            _pt = time.perf_counter()
        ys, n_valid = _collect_chunks(prog.consts, prog.carry0, chunk_fn,
                                      xs_builder, case.t0,
                                      case.t0 + horizon, t_hard)
        if prof is not None:
            prof.add("decide", time.perf_counter() - _pt)
        results[i] = _account_single(packed, case.ci, ci_pol, case.cluster,
                                     case.policy, case.t0, ys, n_valid, prog,
                                     telemetry=telemetry)
        return

    kind_b = members[0][3].kind
    uniform = members[0][3].uniform
    deps = members[0][3].deps
    consts = {k: jnp.stack([m[3].consts[k] for m in members])
              for k in members[0][3].consts}
    carry = {k: jnp.stack([m[3].carry0[k] for m in members])
             for k in members[0][3].carry0}
    horizon_b = int(members[0][1].horizon
                    if members[0][1].horizon is not None
                    else len(members[0][1].ci) - members[0][1].t0)
    span = members[0][1].max_overrun + horizon_b
    ys_parts = []
    off = 0
    _dev_t0 = time.perf_counter()
    while off < span:
        size = min(CHUNK if off < horizon_b else OVERRUN_CHUNK, span - off)
        xs_host = [m[3].xs_fn(np.arange(m[1].t0 + off, m[1].t0 + off + size))
                   for m in members]
        xs = {k: jnp.asarray(np.stack([d[k] for d in xs_host]))
              for k in xs_host[0]}
        carry, ys = _single_chunk_batch(consts, carry, xs, kind_b, uniform,
                                        deps)
        ys_parts.append(jax.device_get(ys))
        off += size
        if bool(np.asarray(carry["ended"]).all()):
            break
    # the vmapped dispatch is shared; split its wall-clock evenly across
    # the tile so per-case phase totals still sum to real time
    _dev_dt = (time.perf_counter() - _dev_t0) / len(members)
    ys_all = {k: np.concatenate([p[k] for p in ys_parts], axis=1)
              for k in ys_parts[0]}
    for j, (i, case, packed, prog, ci_pol) in enumerate(members):
        telemetry = getattr(case, "telemetry", None)
        if telemetry is not None and telemetry.profiler is not None:
            telemetry.profiler.add("decide", _dev_dt)
        ys = {k: v[j] for k, v in ys_all.items()}
        ended = np.asarray(ys["ended"], dtype=bool)
        n_valid = int(np.argmax(ended)) if ended.any() else len(ended)
        results[i] = _account_single(packed, case.ci, ci_pol, case.cluster,
                                     case.policy, case.t0, ys, n_valid, prog,
                                     telemetry=telemetry)
