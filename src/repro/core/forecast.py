"""Pluggable carbon-forecast models (the §6 robustness axis, ISSUE 5).

The paper assumes accurate day-ahead CI forecasts (citing CarbonCast) and
claims CarbonFlex stays "within ~2% of an oracle" under them; CarbonScaler
and the PCAPS line evaluate against forecasts whose error *grows with
horizon*.  This module makes the forecast a first-class, swappable model
so every policy can be stressed along that axis:

- :class:`PerfectForecast`      — the true trace (bit-identical to the
  historical ``CarbonService.forecast`` behaviour; the default);
- :class:`PersistenceForecast`  — yesterday-as-tomorrow: the prediction
  for slot ``t+h`` is the observation from 24 h earlier (the standard
  day-ahead persistence baseline, no peeking at the future);
- :class:`NoisyForecast`        — seeded AR(1) multiplicative error whose
  std grows with lead time: the realized error of a future slot depends
  on *when it is queried* (re-querying closer in time shrinks the error),
  fixing the old ``forecast_noise`` knob's static-per-trace realization;
- :class:`QuantileForecast`     — a seeded ensemble of AR(1) error paths
  exposing per-horizon quantiles (``quantile(trace, t, h, q)``); its
  point forecast is the ensemble median.  Robust policies threshold on a
  configurable quantile instead of the point forecast;
- :class:`StaticNoiseForecast`  — the deprecated ``forecast_noise``
  behaviour, kept bit-for-bit as a shim (one noise realization drawn over
  the whole trace at construction seed, identical at every lead time).

Models are frozen config dataclasses: stateless, shareable across
scenarios, deterministic per ``(seed, trace, query slot)``.  The RNG
stream is salted with a trace fingerprint so aligned multi-region traces
see *independent* (not perfectly correlated) forecast errors.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class ForecastModel(Protocol):
    """A forecast model maps (true trace, query slot, horizon) to the
    forecast a scheduler would have seen at that slot.

    ``predict`` returns the point forecast for slots ``t .. t+horizon-1``
    (index 0 is the current slot, observed, hence error-free).  Models
    may additionally implement ``quantile(trace, t, horizon, q)`` for
    per-horizon uncertainty bands; callers fall back to ``predict`` when
    it is absent (see ``CarbonService.forecast_quantile``)."""

    kind: str

    def predict(self, trace: np.ndarray, t: int,
                horizon: int) -> np.ndarray: ...


def _truth_slice(trace: np.ndarray, t: int, horizon: int) -> np.ndarray:
    """The historical ``CarbonService.forecast`` semantics, verbatim:
    slice ``[t, t+horizon)``, pad past the trace end by repeating the last
    known value (all zeros when ``t`` is entirely past the end)."""
    end = min(t + horizon, len(trace))
    out = trace[t:end]
    if len(out) < horizon:
        out = np.concatenate(
            [out, np.full(horizon - len(out), out[-1] if len(out) else 0.0)])
    return out


def _trace_salt(trace: np.ndarray) -> int:
    """Cheap per-trace RNG salt (first value's bit pattern + length) so
    aligned per-region traces draw independent error streams."""
    if len(trace) == 0:
        return 0
    bits = int(np.float64(trace[0]).view(np.uint64))
    return (bits ^ (len(trace) << 1)) & 0xFFFFFFFFFFFFFFFF


def _ar1_errors(rng: np.random.Generator, horizon: int, sigma: float,
                phi: float) -> np.ndarray:
    """One AR(1) multiplicative-error path with zero error at lead 0.

    ``e_0 = 0`` (the current slot is observed) and
    ``e_h = phi * e_{h-1} + sigma * sqrt(1 - phi^2) * z_h`` so
    ``std(e_h) = sigma * sqrt(1 - phi^(2h))`` — the error *grows with the
    lead time* from 0 toward the stationary ``sigma``."""
    z = rng.normal(0.0, 1.0, horizon)
    c = sigma * np.sqrt(max(1.0 - phi * phi, 0.0))
    err = np.zeros(horizon)
    acc = 0.0
    for i in range(1, horizon):
        acc = phi * acc + c * z[i]
        err[i] = acc
    return err


def _apply_error(truth: np.ndarray, err: np.ndarray,
                 floor: float) -> np.ndarray:
    """Multiplicative error with a positivity floor; zero truth (past the
    trace end) stays zero, matching the perfect-forecast padding."""
    return np.where(truth > 0.0,
                    np.clip(truth * (1.0 + err), floor, None), truth)


def _memo1(model, trace: np.ndarray, t: int, horizon: int, compute):
    """Per-trace single-slot memo for (trace, t, horizon) -> array.

    The engines read the same query slot several times per decision
    (point forecast, rank, percentile threshold, ratio features), so the
    last result *per trace* is the one that matters — one slot per trace
    (not one global slot) because a geo scenario shares one model
    instance across all region services and interleaves their reads
    every slot.  Entries hold the trace reference and re-check identity
    with ``is``, so recycled ids cannot alias; stored via
    ``object.__setattr__`` because the models are frozen dataclasses
    (the memo is not a field, so equality/serialization are unaffected)."""
    memo = model.__dict__.get("_memo")
    if memo is None:
        memo = {}
        object.__setattr__(model, "_memo", memo)
    hit = memo.get(id(trace))
    if hit is not None and hit[0] is trace and hit[1] == (t, horizon):
        return hit[2]
    val = compute()
    if len(memo) >= 16 and id(trace) not in memo:
        memo.clear()            # bound pathological many-trace churn
    memo[id(trace)] = (trace, (t, horizon), val)
    return val


def _norm_ppf(q: float) -> float:
    """Acklam's rational approximation of the standard-normal inverse CDF
    (|rel err| < 1.2e-9; scipy is not a dependency of this package)."""
    if not 0.0 < q < 1.0:
        raise ValueError(f"quantile must be in (0, 1), got {q}")
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    p_low = 0.02425
    if q < p_low:
        r = np.sqrt(-2.0 * np.log(q))
        return (((((c[0] * r + c[1]) * r + c[2]) * r + c[3]) * r + c[4]) * r
                + c[5]) / ((((d[0] * r + d[1]) * r + d[2]) * r + d[3]) * r
                           + 1.0)
    if q > 1.0 - p_low:
        r = np.sqrt(-2.0 * np.log(1.0 - q))
        return -(((((c[0] * r + c[1]) * r + c[2]) * r + c[3]) * r + c[4]) * r
                 + c[5]) / ((((d[0] * r + d[1]) * r + d[2]) * r + d[3]) * r
                            + 1.0)
    r = q - 0.5
    s = r * r
    return (((((a[0] * s + a[1]) * s + a[2]) * s + a[3]) * s + a[4]) * s
            + a[5]) * r / (((((b[0] * s + b[1]) * s + b[2]) * s + b[3]) * s
                            + b[4]) * s + 1.0)


# --- the four models + the legacy shim ---------------------------------------


@dataclasses.dataclass(frozen=True)
class PerfectForecast:
    """The paper's accurate-day-ahead assumption: the forecast IS the
    trace.  Bit-identical to the pre-forecast-subsystem behaviour."""

    kind: ClassVar[str] = "perfect"

    def predict(self, trace: np.ndarray, t: int, horizon: int) -> np.ndarray:
        return _truth_slice(trace, t, horizon)

    def quantile(self, trace: np.ndarray, t: int, horizon: int,
                 q: float) -> np.ndarray:
        # a perfect forecaster's uncertainty band collapses onto the truth
        return _truth_slice(trace, t, horizon)


@dataclasses.dataclass(frozen=True)
class PersistenceForecast:
    """Yesterday-as-tomorrow: the prediction for slot ``t+h`` is the
    observation from ``period`` slots earlier (tiled for horizons past one
    period).  Index 0 is the observed current slot.  Only past values are
    read (clamped into the trace at its edges), so this is a *realizable*
    day-ahead baseline — the standard no-model reference in the
    CarbonCast/CarbonScaler evaluations."""

    period: int = 24
    kind: ClassVar[str] = "persistence"

    def predict(self, trace: np.ndarray, t: int, horizon: int) -> np.ndarray:
        if len(trace) == 0:
            return np.zeros(horizon)
        last = len(trace) - 1
        out = np.empty(horizon)
        out[0] = trace[min(max(t, 0), last)]
        for h in range(1, horizon):
            # map lead h >= 1 onto yesterday's matching offset: 1..period
            eff = (h - 1) % self.period + 1
            idx = t + eff - self.period
            out[h] = trace[min(max(idx, 0), last)]
        return out


@dataclasses.dataclass(frozen=True)
class NoisyForecast:
    """Seeded AR(1) multiplicative forecast error, std growing with lead.

    Every query slot ``t`` draws its own error path from a stream keyed by
    ``(seed, t, trace)``: re-querying the same future slot closer in time
    yields a *fresh, smaller* error — the lead-time semantics the old
    static ``forecast_noise`` knob got wrong (it drew one realization over
    the whole trace at construction, so the error of a future slot never
    shrank as it approached).  ``std(err at lead h) = sigma *
    sqrt(1 - phi^(2h))``.

    ``quantile`` exposes the model's *self-knowledge*: per-horizon normal
    bands around its own point forecast (no additional truth access)."""

    sigma: float = 0.1
    phi: float = 0.9
    seed: int = 0
    floor: float = 1.0
    kind: ClassVar[str] = "noisy"

    def _rng(self, trace: np.ndarray, t: int) -> np.random.Generator:
        return np.random.default_rng(np.random.SeedSequence(
            [1, self.seed, max(int(t), 0), _trace_salt(trace)]))

    def predict(self, trace: np.ndarray, t: int, horizon: int) -> np.ndarray:
        def compute():
            truth = _truth_slice(trace, t, horizon)
            err = _ar1_errors(self._rng(trace, t), horizon, self.sigma,
                              self.phi)
            return _apply_error(truth, err, self.floor)

        return _memo1(self, trace, t, horizon, compute)

    def lead_std(self, horizon: int) -> np.ndarray:
        """Analytic per-lead error std: sigma * sqrt(1 - phi^(2h))."""
        h = np.arange(horizon, dtype=np.float64)
        return self.sigma * np.sqrt(1.0 - self.phi ** (2.0 * h))

    def quantile(self, trace: np.ndarray, t: int, horizon: int,
                 q: float) -> np.ndarray:
        pred = self.predict(trace, t, horizon)
        band = 1.0 + _norm_ppf(q) * self.lead_std(horizon)
        return np.where(pred > 0.0,
                        np.clip(pred * band, self.floor, None), pred)


@dataclasses.dataclass(frozen=True)
class QuantileForecast:
    """Seeded ensemble forecast: ``members`` independent AR(1) error paths
    per query slot.  ``predict`` is the per-horizon ensemble median;
    ``quantile(trace, t, h, q)`` the empirical per-horizon ``q``-quantile
    (monotone in ``q`` by construction).  Robust policy variants threshold
    on a configurable quantile of this band instead of a point value."""

    sigma: float = 0.1
    phi: float = 0.9
    members: int = 15
    seed: int = 0
    floor: float = 1.0
    kind: ClassVar[str] = "quantile"

    def __post_init__(self) -> None:
        if self.members < 2:
            raise ValueError("a quantile ensemble needs >= 2 members")

    def _ensemble(self, trace: np.ndarray, t: int,
                  horizon: int) -> np.ndarray:
        def compute():
            truth = _truth_slice(trace, t, horizon)
            salt = _trace_salt(trace)
            ens = np.empty((self.members, horizon))
            for m in range(self.members):
                rng = np.random.default_rng(np.random.SeedSequence(
                    [2, self.seed, max(int(t), 0), m, salt]))
                err = _ar1_errors(rng, horizon, self.sigma, self.phi)
                ens[m] = _apply_error(truth, err, self.floor)
            return ens

        return _memo1(self, trace, t, horizon, compute)

    def predict(self, trace: np.ndarray, t: int, horizon: int) -> np.ndarray:
        return np.quantile(self._ensemble(trace, t, horizon), 0.5, axis=0)

    def quantile(self, trace: np.ndarray, t: int, horizon: int,
                 q: float) -> np.ndarray:
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        return np.quantile(self._ensemble(trace, t, horizon), q, axis=0)


@dataclasses.dataclass(frozen=True)
class StaticNoiseForecast:
    """DEPRECATED semantics of ``CarbonService(forecast_noise=...)``, kept
    bit-for-bit: one gaussian multiplicative realization drawn over the
    whole trace at construction (``default_rng(seed)``), identical at
    every query slot and lead time.  Prefer :class:`NoisyForecast`."""

    sigma: float
    seed: int = 0
    kind: ClassVar[str] = "static-noise"

    def _noisy_trace(self, trace: np.ndarray) -> np.ndarray:
        cached = self.__dict__.get("_cache")
        if cached is not None and cached[0] is trace:
            return cached[1]
        noise = np.random.default_rng(self.seed).normal(
            1.0, self.sigma, len(trace))
        noisy = np.clip(trace * noise, 1.0, None)
        object.__setattr__(self, "_cache", (trace, noisy))
        return noisy

    def predict(self, trace: np.ndarray, t: int, horizon: int) -> np.ndarray:
        return _truth_slice(self._noisy_trace(trace), t, horizon)


# --- forecast-derived Table-2 features ---------------------------------------


class ForecastFeatureMixin:
    """The forecast-derived Table-2 features, written once against
    ``self.forecast`` / ``self.horizon`` / ``self.trace``.

    ``CarbonService`` and :class:`QuantileCIView` both inherit these, so
    a view that overrides only ``forecast`` gets feature definitions that
    can never silently diverge from the service's (the robust-variant
    bit-identity under a perfect forecast rests on that)."""

    def forecast_extended(self, t: int, horizon: int) -> np.ndarray:
        """Forecast beyond the day-ahead horizon by tiling the day-ahead
        diurnal pattern (the standard persistence assumption)."""
        day = self.forecast(t, self.horizon)
        if horizon <= len(day):
            return day[:horizon]
        reps = int(np.ceil(horizon / len(day)))
        return np.tile(day, reps)[:horizon]

    def rank(self, t: int) -> float:
        """Day-ahead rank of slot t: fraction of the next-24h forecast
        that is *more* carbon-intense than now (1.0 = best slot)."""
        fc = self.forecast(t)
        return float(np.mean(fc > self.trace[t]))

    def percentile_threshold(self, t: int, pct: float) -> float:
        """The pct-th percentile of the next-24h forecast (Wait-Awhile)."""
        return float(np.percentile(self.forecast(t), pct))


class QuantileCIView(ForecastFeatureMixin):
    """A read-only view of a carbon service whose ``forecast`` is the
    ``q``-quantile band of the underlying forecast model.

    Robust policies (``carbonflex-robust``, ``wait-awhile-robust``) build
    their forecast-derived features (rank, percentile thresholds, ratio
    features) through this view, so a single quantile knob turns any
    forecast-consuming policy conservative.  Observed quantities
    (``ci``, ``gradient``) delegate to the truth unchanged; the derived
    features come from :class:`ForecastFeatureMixin` over the band."""

    def __init__(self, base, q: float) -> None:
        self.base = base
        self.q = float(q)

    @property
    def trace(self) -> np.ndarray:
        return self.base.trace

    @property
    def horizon(self) -> int:
        return self.base.horizon

    def __len__(self) -> int:
        return len(self.base)

    def ci(self, t: int) -> float:
        return self.base.ci(t)

    def gradient(self, t: int) -> float:
        return self.base.gradient(t)

    def forecast(self, t: int, horizon: int | None = None) -> np.ndarray:
        return self.base.forecast_quantile(t, horizon, q=self.q)


# --- serialization / labels --------------------------------------------------


FORECAST_KINDS: dict[str, type] = {
    PerfectForecast.kind: PerfectForecast,
    PersistenceForecast.kind: PersistenceForecast,
    NoisyForecast.kind: NoisyForecast,
    QuantileForecast.kind: QuantileForecast,
    StaticNoiseForecast.kind: StaticNoiseForecast,
}


def forecast_to_dict(model: "ForecastModel | None") -> dict | None:
    """JSON-safe payload round-tripped by :func:`forecast_from_dict`."""
    if model is None:
        return None
    if model.kind not in FORECAST_KINDS:
        raise ValueError(f"unregistered forecast kind {model.kind!r}; "
                         f"known kinds: {', '.join(sorted(FORECAST_KINDS))}")
    return {"kind": model.kind,
            **{f.name: getattr(model, f.name)
               for f in dataclasses.fields(model)}}


def forecast_from_dict(d: dict | None) -> "ForecastModel | None":
    if d is None:
        return None
    d = dict(d)
    kind = d.pop("kind", None)
    try:
        cls = FORECAST_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown forecast kind {kind!r}; known kinds: "
                         f"{', '.join(sorted(FORECAST_KINDS))}") from None
    return cls(**d)


def forecast_label(model: "ForecastModel | None") -> str:
    """Short sweep-row label: ``perfect``, ``noisy(s=0.2)``, ...

    NOT injective over models (seed/phi are omitted for readability) —
    axis code that keys cells on labels must use :func:`forecast_labels`,
    which disambiguates colliding entries."""
    if model is None or model.kind == "perfect":
        return "perfect"
    if model.kind == "persistence":
        return "persistence"
    if model.kind in ("noisy", "static-noise"):
        return f"{model.kind}(s={model.sigma:g})"
    if model.kind == "quantile":
        return f"quantile(s={model.sigma:g},m={model.members})"
    return model.kind


def forecast_labels(models) -> list[str]:
    """Per-axis-entry labels, made unique: when two *different* models
    share a :func:`forecast_label` (e.g. same sigma, different seed or
    phi), later ones gain a ``#k`` suffix so savings/gap cells keyed on
    the label cannot silently merge.  Equal models keep equal labels."""
    labels = []
    by_label: dict[str, list] = {}
    for m in models:
        base = forecast_label(m)
        group = by_label.setdefault(base, [])
        idx = next((i for i, prev in enumerate(group) if prev == m), None)
        if idx is None:
            idx = len(group)
            group.append(m)
        labels.append(base if idx == 0 else f"{base}#{idx + 1}")
    return labels
