from .decode import (abstract_cache, cache_shardings, cache_specs,  # noqa: F401
                     init_cache, make_prefill, make_serve_step,
                     serve_input_specs)
