"""Serving layer: one-new-token decode with a sharded KV cache.

Transformer families: the KV cache is laid out (L, B, S, KV, hd) with the
*sequence dimension sharded over the model axis* (``cache_seq`` rule) and
batch over (pod, data).  Rationale: GQA kv-head counts (4–8 on these
archs) do not divide a 16-way model axis, so head-sharding the cache
either pads or replicates; sequence sharding splits both the memory and
the attention FLOPs/bytes 16 ways, at the cost of one small cross-shard
reduction per step (the flash-style (m, l, o) combine, which XLA emits
from the masked chunked attention below).

The new token's K/V is written with a one-hot mask instead of a dynamic
slice: a masked elementwise update shards cleanly over the sequence axis
with zero collectives (the baseline; see EXPERIMENTS.md §Perf for the
shard_map local-update optimisation that removes the full-cache rewrite).

SSM/hybrid families dispatch to their O(1)-state decode (rwkv6, zamba2).
"""
from __future__ import annotations

import functools
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import rwkv6, zamba2
from repro.models.common import (LogicalRules, ModelConfig, chunked_attention,
                                 constrain, rms_norm, rope, swiglu)
from repro.models.transformer import moe_block


# --------------------------------------------------------------------------
# sequence-sharded decode attention (shard_map)
#
# The cache seq dim is sharded over `model`; in pjit-auto mode the chunked
# attention scan re-gathers remote chunks every layer (68 GB/step measured
# on llama3 decode_32k — §Perf decode-1).  The manual version below keeps
# everything local: each shard (a) writes the new K/V at `length` iff that
# position falls in its slice — a one-position write, no full-cache rewrite
# — and (b) computes flash-style partial (m, l, o) over its slice; one tiny
# renormalised psum combines the partials.


def _decode_attn_local(q, kc, vc, kn, vn, length, *, axis):
    """Per-shard body.  q/kn/vn: (B,1,H|KV,D) replicated; kc/vc:
    (B, S_loc, KV, D) local cache slice.  Returns (o, kc, vc)."""
    b, s_loc, hkv, dh = kc.shape
    hq = q.shape[2]
    group = hq // hkv
    i = jax.lax.axis_index(axis)
    off = i * s_loc
    pos = length - off
    in_range = (pos >= 0) & (pos < s_loc)
    posc = jnp.clip(pos, 0, s_loc - 1)
    def upd(c, n):
        return jax.lax.dynamic_update_slice_in_dim(
            c, jnp.where(in_range, n,
                         jax.lax.dynamic_slice_in_dim(c, posc, 1, 1)),
            posc, axis=1)
    kc = upd(kc, kn)
    vc = upd(vc, vn)

    qg = q.reshape(b, 1, hkv, group, dh).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc.astype(jnp.float32))
    s = s / np.sqrt(dh)
    kpos = off + jnp.arange(s_loc)
    mask = kpos[None, None, None, None, :] <= length
    s = jnp.where(mask, s, -1e30)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    denom = p.sum(axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
    m_glob = jax.lax.pmax(m, axis)
    corr = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(denom * corr, axis)
    o_glob = jax.lax.psum(o * corr[..., None], axis)
    out = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, hq, dh)
    return out.astype(vn.dtype), kc, vc


def sharded_decode_attention(q, kc, vc, kn, vn, length, rules: LogicalRules):
    """Dispatch: shard_map over `model` when the cache seq dim is sharded,
    else the plain masked chunked attention."""
    mesh = rules.mesh
    s = kc.shape[1]
    if "model" not in mesh.shape or mesh.shape["model"] == 1 or \
            s % mesh.shape["model"] != 0:
        max_seq = kc.shape[1]
        onehot = (jnp.arange(max_seq) == length).astype(kc.dtype)
        kc = kc * (1 - onehot)[None, :, None, None] + kn * onehot[None, :, None, None]
        vc = vc * (1 - onehot)[None, :, None, None] + vn * onehot[None, :, None, None]
        o = chunked_attention(q, kc, vc, causal_offset=length, chunk=2048)
        return o, kc, vc
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    batch = tuple(a for a in ("pod", "data") if a in mesh.shape)
    rep = P(batch, None, None, None)
    cachep = P(batch, "model", None, None)
    fn = functools.partial(_decode_attn_local, axis="model")
    return shard_map(
        fn, mesh=mesh,
        in_specs=(rep, cachep, cachep, rep, rep, P()),
        out_specs=(rep, cachep, cachep),
        check_rep=False,
    )(q, kc, vc, kn, vn, length)


# --------------------------------------------------------------------------
# transformer-family cache


def _tf_init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, cfg.compute_dtype),
        "v": jnp.zeros(shape, cfg.compute_dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def _tf_cache_specs(cfg: ModelConfig) -> dict:
    kv = ("layers", "cache_batch", "cache_seq", "kv", "head_dim")
    return {"k": kv, "v": kv, "length": ()}


def _tf_decode_step(params, token, cache, cfg: ModelConfig, rules: LogicalRules):
    x = params["embed"].astype(cfg.compute_dtype)[token][:, None]   # (B,1,d)
    length = cache["length"]
    # Pin the STACKED cache sharding: without this, SPMD propagation shards
    # the layer dim over `model` for the scan and then all-gathers the full
    # (B, S, KV, hd) slice every layer (measured 68 GB/step on llama3
    # decode_32k — EXPERIMENTS.md §Perf decode-1).
    stacked = ("layers", "cache_batch", "cache_seq", "kv", "head_dim")
    cache = dict(cache,
                 k=constrain(cache["k"], rules, *stacked),
                 v=constrain(cache["v"], rules, *stacked))

    stacked_spec = ("layers", "cache_batch", "cache_seq", "kv", "head_dim")

    def body(carry, inputs):
        # KV caches ride in the CARRY (stable sharding across iterations) —
        # as scan xs, SPMD shards the stacked layer dim over `model` and
        # all-gathers a full (B, S, KV, hd) slice every layer (§Perf
        # decode-1: 68 GB -> 4 GB per step).
        x, kall, vall = carry
        lp, li = inputs
        kc = jax.lax.dynamic_index_in_dim(kall, li, axis=0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vall, li, axis=0, keepdims=False)
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"].astype(h.dtype))
        pos = length[None]
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        o, kc, vc = sharded_decode_attention(q, kc, vc, k, v, length, rules)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"].astype(h.dtype))
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.num_experts:
            m = moe_block(h2, lp, cfg, rules)
        else:
            m = swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"], rules)
        x = x + m
        kall = jax.lax.dynamic_update_index_in_dim(kall, kc, li, axis=0)
        vall = jax.lax.dynamic_update_index_in_dim(vall, vc, li, axis=0)
        kall = constrain(kall, rules, *stacked_spec)
        vall = constrain(vall, rules, *stacked_spec)
        return (x, kall, vall), None

    nl = cfg.num_layers
    (x, ks, vs), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["layers"], jnp.arange(nl, dtype=jnp.int32)))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params.get("lm_head", None)
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return logits[:, 0], {"k": ks, "v": vs, "length": length + 1}


# --------------------------------------------------------------------------
# prefill (transformer family): one forward pass builds the KV cache


def make_prefill(cfg: ModelConfig, rules: LogicalRules, max_seq: int):
    """prefill(params, tokens) -> (last_logits, cache): runs the prompt in
    one forward pass (transformer family: collects per-layer K/V from the
    layer scan into a ``max_seq`` cache).  SSM/hybrid families replay
    through their O(1) decode step instead (their state IS the cache)."""
    from repro.models import api

    if cfg.family in ("ssm", "hybrid"):
        step = make_serve_step(cfg, rules)

        def prefill_ssm(params, tokens):
            cache = init_cache(cfg, tokens.shape[0], max_seq)

            def body(cache, tok):
                logits, cache = step(params, cache, tok)
                return cache, logits

            cache, logits = jax.lax.scan(body, cache, tokens.T)
            return logits[-1], cache

        return prefill_ssm

    def prefill(params, tokens):
        b, s = tokens.shape
        logits, kv = api.forward(params, tokens, cfg, rules, return_kv=True)
        k, v = kv                                     # (L, B, S, KV, hd)
        pad = max_seq - s
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        stacked = ("layers", "cache_batch", "cache_seq", "kv", "head_dim")
        cache = {
            "k": constrain(k, rules, *stacked),
            "v": constrain(v, rules, *stacked),
            "length": jnp.int32(s),
        }
        return logits[:, -1], cache

    return prefill


# --------------------------------------------------------------------------
# family dispatch


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    if cfg.family == "ssm":
        return rwkv6.init_cache(cfg, batch)
    if cfg.family == "hybrid":
        return zamba2.init_cache(cfg, batch, max_seq)
    return _tf_init_cache(cfg, batch, max_seq)


def cache_specs(cfg: ModelConfig) -> dict:
    if cfg.family == "ssm":
        return rwkv6.cache_specs(cfg)
    if cfg.family == "hybrid":
        return zamba2.cache_specs(cfg)
    return _tf_cache_specs(cfg)


def make_serve_step(cfg: ModelConfig, rules: LogicalRules):
    """serve_step(params, cache, tokens) -> (logits, new_cache): one new
    token per sequence against the existing context."""
    if cfg.family == "ssm":
        def step(params, cache, tokens):
            return rwkv6.decode_step(params, tokens, cache, cfg, rules)
    elif cfg.family == "hybrid":
        def step(params, cache, tokens):
            return zamba2.decode_step(params, tokens, cache, cfg, rules)
    else:
        def step(params, cache, tokens):
            return _tf_decode_step(params, tokens, cache, cfg, rules)
    return step


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int,
                   rules: LogicalRules) -> Any:
    """ShapeDtypeStruct cache with shardings (dry-run)."""
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))
    specs = cache_specs(cfg)

    def attach(leaf_path, leaf):
        name = leaf_path[0].key
        sp = specs[name]
        sh = rules.sharding(*sp, dims=leaf.shape) if sp else rules.sharding()
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=sh)

    flat = jax.tree_util.tree_flatten_with_path(cache)
    leaves = [attach(p, l) for p, l in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], leaves)


def cache_shardings(cfg: ModelConfig, rules: LogicalRules, batch: int,
                    max_seq: int) -> Any:
    ab = abstract_cache(cfg, batch, max_seq, rules)
    return jax.tree.map(lambda leaf: leaf.sharding, ab)


def serve_input_specs(cfg: ModelConfig, batch: int, rules: LogicalRules):
    return jax.ShapeDtypeStruct(
        (batch,), jnp.int32, sharding=rules.sharding("batch", dims=(batch,)))
