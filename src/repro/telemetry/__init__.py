"""Telemetry layer: decision traces, carbon attribution, phase profiling.

See README §Observability.  Everything here is observation-only: the
engines behave bit-identically with telemetry attached or absent."""
from .attribution import CAUSES, Attribution, attribute
from .events import (EVENT_KINDS, MemoryRecorder, SlotEventTracker,
                     Telemetry, TraceEvent, TraceRecorder,
                     emit_fault_events)
from .profiler import PHASES, PhaseProfiler
from .report import explain

__all__ = [
    "CAUSES", "Attribution", "attribute",
    "EVENT_KINDS", "MemoryRecorder", "SlotEventTracker", "Telemetry",
    "TraceEvent", "TraceRecorder", "emit_fault_events",
    "PHASES", "PhaseProfiler",
    "explain",
]
