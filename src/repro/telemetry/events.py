"""Structured per-slot decision traces (the observability tentpole).

Every engine path (scalar / vector / geo / scan / serving) drives the
same small vocabulary of per-slot events through a
:class:`TraceRecorder`:

=============== ==============================================================
kind            meaning (``job`` = job_id unless noted)
=============== ==============================================================
admit           job entered the active set (arrival or DAG release)
suspend         job was running last slot, received no servers this slot
resume          previously-started job received servers again
scale           running job's allocation changed size (``value`` = new k,
                ``detail`` = ``from=<old k>``)
migrate         started job began moving region (``value`` = destination,
                ``detail`` = ``from=<source region>``)
evict           job kicked off failed capacity (correlated-fault domain)
preempt         job killed; progress rolled back (``value`` = work re-added)
checkpoint      checkpoint slot charged (``value`` = progress factor)
restore         checkpoint re-transfer billed (``value`` = energy kWh)
tier-switch     serving: dominant precision tier changed (``value`` = tier
                index, ``detail`` = ``from=<old index>``; job is None)
forecast-read   policy read a degraded carbon feed (``value`` = staleness
                in slots; job is None)
=============== ==============================================================

Emission is observation-only — recorders never mutate engine state — so
attaching one cannot change results, and ``telemetry=None`` paths skip
every telemetry branch (bit-identity pinned by the golden fixtures).

Cross-engine equality is by construction: the engines feed the shared
:class:`SlotEventTracker` the identical row-ordered (job, k) allocation
stream their float parity already relies on, and the scan engine decodes
the same stream host-side from its packed device grids after the scan
(no per-slot host syncs).
"""
from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Iterable, NamedTuple, Protocol,
                    runtime_checkable)

if TYPE_CHECKING:                    # profiler is an independent module
    from .profiler import PhaseProfiler

EVENT_KINDS = ("admit", "suspend", "resume", "scale", "migrate", "evict",
               "preempt", "checkpoint", "restore", "tier-switch",
               "forecast-read")


class TraceEvent(NamedTuple):
    """One recorded decision/lifecycle event.

    A NamedTuple rather than a dataclass: construction sits on the
    engines' recording hot path (the 1.3x scan-overhead budget), and
    tuple ``__new__`` is several times cheaper than a frozen-dataclass
    ``__init__`` while keeping immutability and field names."""

    t: int                           # slot index
    kind: str                        # one of EVENT_KINDS
    job: int | None = None           # job_id (None for slot-level events)
    value: float | None = None       # kind-specific scalar (see module doc)
    detail: str = ""                 # kind-specific annotation
    run: str = ""                    # run label (sweep case, bench name, ...)

    def to_dict(self) -> dict:
        return {"t": int(self.t), "kind": self.kind, "job": self.job,
                "value": self.value, "detail": self.detail, "run": self.run}


@runtime_checkable
class TraceRecorder(Protocol):
    """Anything that accepts a stream of :class:`TraceEvent` s."""

    def record(self, event: TraceEvent) -> None: ...


class MemoryRecorder:
    """In-memory recorder: events in emission order, with small query
    helpers for tests, reports and figures."""

    def __init__(self) -> None:
        self.events: list[TraceEvent] = []

    def record(self, event: TraceEvent) -> None:
        self.events.append(event)

    def __len__(self) -> int:
        return len(self.events)

    def by_kind(self, kind: str) -> list[TraceEvent]:
        return [e for e in self.events if e.kind == kind]

    def for_run(self, run: str) -> list[TraceEvent]:
        return [e for e in self.events if e.run == run]

    def counts(self, run: str | None = None) -> dict[str, int]:
        """Event count per kind (insertion order follows EVENT_KINDS)."""
        out = {k: 0 for k in EVENT_KINDS}
        for e in self.events:
            if run is not None and e.run != run:
                continue
            out[e.kind] = out.get(e.kind, 0) + 1
        return {k: v for k, v in out.items() if v}

    def clear(self) -> None:
        self.events.clear()


@dataclasses.dataclass
class Telemetry:
    """The bundle threaded (as one optional argument) through every
    engine: an event recorder, a phase profiler, and the label stamped
    onto emitted events.  Either component may be None; ``emit`` is a
    no-op without a recorder, so call sites guard only on the bundle."""

    recorder: TraceRecorder | None = None
    profiler: "PhaseProfiler | None" = None
    run_label: str = ""

    def for_run(self, label: str) -> "Telemetry":
        """A view of the same recorder/profiler stamping ``label``."""
        return dataclasses.replace(self, run_label=label)

    def emit(self, t: int, kind: str, job: int | None = None,
             value: float | None = None, detail: str = "") -> None:
        if self.recorder is not None:
            self.recorder.record(TraceEvent(
                t=int(t), kind=kind, job=job, value=value, detail=detail,
                run=self.run_label))


class SlotEventTracker:
    """Derives suspend / resume / scale events from per-slot allocations.

    Every engine feeds :meth:`step` the same row-ordered stream of
    positive allocations (job_id, k) its float accounting already walks,
    so the derived event sequence is identical across scalar, vector and
    scan paths.  Within a slot, resume/scale fire in feed (row) order,
    then suspends in sorted job order."""

    def __init__(self, telemetry: Telemetry) -> None:
        self.tele = telemetry
        self._k: dict[int, int] = {}       # job_id -> current allocation
        self._started: set[int] = set()
        self._last: tuple[list, list] | None = None

    def admit(self, t: int, job: int) -> None:
        self.tele.emit(t, "admit", job=job)

    def step(self, t: int, ids: list[int] | Iterable[int],
             ks: list[int] | Iterable[int]) -> None:
        # Steady-state fast path: the same positive (id, k) stream as the
        # previous slot (and no finish() in between) derives no events —
        # every job keeps its allocation, so no resume/scale/suspend can
        # fire.  One C-level list comparison replaces the full walk; this
        # is what keeps scan-path recording inside its 1.3x budget.
        if (self._last is not None and isinstance(ids, list)
                and ids == self._last[0] and ks == self._last[1]):
            return
        active: set[int] = set()
        for jid, k in zip(ids, ks):
            jid, k = int(jid), int(k)
            if k <= 0:
                continue
            active.add(jid)
            prev = self._k.get(jid, 0)
            if prev == 0:
                if jid in self._started:
                    self.tele.emit(t, "resume", job=jid, value=float(k))
            elif k != prev:
                self.tele.emit(t, "scale", job=jid, value=float(k),
                               detail=f"from={prev}")
            self._k[jid] = k
            self._started.add(jid)
        for jid in sorted(self._k):
            if jid not in active:
                self.tele.emit(t, "suspend", job=jid)
                del self._k[jid]
        if isinstance(ids, list) and isinstance(ks, list) and (
                len(active) == len(ids)):    # all-positive stream only
            self._last = (ids, ks)
        else:
            self._last = None

    def finish(self, job: int) -> None:
        """Completion: drop tracking so no spurious suspend fires."""
        self._k.pop(int(job), None)
        self._started.discard(int(job))
        self._last = None


def emit_fault_events(tele: Telemetry, t: int, job_ids, dist,
                      fault_kind: str) -> None:
    """Decode a ``SlotDisturbance`` into per-job fault events.

    Row order matches the engines' fault-apply sequence.  A preempted
    job always carries restore-transfer energy (``extra_energy > 0``),
    which distinguishes it from a restore-in-progress slot (factor 0, no
    energy) without peeking at fault-process internals; checkpoint slots
    (fractional factor) are only meaningful for the preemption process —
    iid stragglers also scale progress but are not checkpoints."""
    ev = dist.evicted
    lost = dist.lost
    extra = dist.extra_energy
    for i, jid in enumerate(job_ids):
        if ev is not None and ev[i]:
            tele.emit(t, "evict", job=int(jid))
        elif extra is not None and extra[i] > 0:
            rb = float(lost[i]) if lost is not None else 0.0
            tele.emit(t, "preempt", job=int(jid), value=rb)
            tele.emit(t, "restore", job=int(jid), value=float(extra[i]))
        elif fault_kind == "preemption" and 0.0 < dist.factors[i] < 1.0:
            tele.emit(t, "checkpoint", job=int(jid),
                      value=float(dist.factors[i]))
