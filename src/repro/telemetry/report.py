"""``explain()``: one human-readable report per run.

Combines whatever telemetry is available — the run's aggregates, the
carbon attribution vs a baseline run, the recorded event stream, and
the phase profile — into a plain-text report, so EXPERIMENTS.md claims
become one call instead of scalar archaeology."""
from __future__ import annotations

from .attribution import attribute
from .events import EVENT_KINDS, MemoryRecorder
from .profiler import PhaseProfiler


def explain(result, baseline=None, *, recorder: MemoryRecorder | None = None,
            profiler: PhaseProfiler | None = None,
            run: str | None = None) -> str:
    """Render a report for ``result``.

    ``baseline`` adds the cause decomposition of the carbon delta;
    ``recorder`` adds event counts (restricted to ``run``'s label when
    given); ``profiler`` adds the phase table."""
    lines = [f"run: {result.policy}",
             f"  carbon      {result.carbon_g:,.1f} g",
             f"  energy      {result.energy_kwh:,.3f} kWh",
             f"  mean wait   {result.mean_wait:.2f} slots",
             f"  violations  {result.violation_rate:.2%}"]
    if result.regions is not None:
        lines.append(f"  migrations  {result.migrations} "
                     f"({result.migration_carbon_g:,.1f} g)")
    if result.serving is not None:
        lines.append(f"  quality     {result.serving.quality_mean:.4f} "
                     f"(ledger {result.serving.ledger_final:+.3f})")

    if baseline is not None:
        att = attribute(result, baseline)
        att.check()
        lines.append("")
        lines.append("attribution:")
        lines.extend("  " + ln for ln in att.table().splitlines())

    if recorder is not None:
        counts = recorder.counts(run=run)
        lines.append("")
        if counts:
            lines.append("events:")
            for kind in EVENT_KINDS:
                if kind in counts:
                    lines.append(f"  {kind:<14} {counts[kind]:>8d}")
        else:
            lines.append("events: none recorded")

    if profiler is not None and profiler.seconds:
        lines.append("")
        lines.append("phases:")
        lines.extend("  " + ln for ln in profiler.table().splitlines())
    return "\n".join(lines)
