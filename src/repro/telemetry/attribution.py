"""Carbon attribution: decompose a run's emissions delta vs its baseline
into named causes that sum float-exactly to the total.

The measured causes are first-order decompositions computed from run
aggregates the engines already account exactly:

- ``capacity_scaling``    — the energy delta (batch runs) priced at the
  baseline's realised carbon intensity: carbon moved by using fewer /
  more server-slots at all, CarbonScaler's marginal-capacity axis;
- ``precision_tiering``   — the same energy-delta term for serving runs
  (the tier mix is the only energy knob there; batch runs report 0);
- ``geo_placement``       — spatial advantage: per-slot carbon below
  what the run's own energy would have emitted at the slot's
  region-mean CI, policy minus baseline (exactly 0 for single-region);
- ``migration_overhead``  — baseline-minus-policy migration carbon
  (negative when the policy pays for moves the baseline avoids);
- ``fault_restore``       — restore-transfer energy delta priced at the
  baseline CI (0 on fault-free runs);
- ``temporal_shifting``   — the residual: carbon moved by running the
  *same* work at different hours, which no aggregate delta isolates.

The residual is then nudged by a fixpoint so that the canonical
left-to-right IEEE sum over ``CAUSES`` equals the measured delta to the
last bit — ``check()`` asserts ``sum(causes) == delta_g`` with ``==``,
not a tolerance (pinned by the additivity property test).  One honest
caveat: when causes partially cancel, the achievable canonical sums
form a lattice whose spacing is set by the largest cause's ulp, and the
measured delta can sit between two lattice points; ``delta_g`` is then
the closest achievable sum — off by ulps of the largest cause, i.e.
sub-nanogram at cluster scale (the property test bounds the gap).
"""
from __future__ import annotations

import dataclasses
import math

CAUSES = ("temporal_shifting", "capacity_scaling", "geo_placement",
          "migration_overhead", "precision_tiering", "fault_restore")


def _ltr_sum(values) -> float:
    """Canonical left-to-right IEEE-754 sum (the additivity contract)."""
    total = 0.0
    for v in values:
        total += v
    return total


@dataclasses.dataclass
class Attribution:
    """One run's carbon delta vs its baseline, decomposed by cause.

    ``delta_g = baseline_carbon_g - carbon_g`` (positive = savings) and
    the ``CAUSES``-ordered left-to-right sum of ``causes`` equals it
    float-exactly.  (Under cancelling causes ``delta_g`` is the closest
    canonically-summable value instead, ulps of the largest cause away
    from the measured delta — see the module docstring.)"""

    policy: str
    baseline: str
    carbon_g: float
    baseline_carbon_g: float
    delta_g: float
    causes: dict[str, float]

    @property
    def savings_pct(self) -> float:
        if self.baseline_carbon_g <= 0:
            return 0.0
        return 100.0 * self.delta_g / self.baseline_carbon_g

    def pp_of_baseline(self, cause: str) -> float:
        """One cause's share, in percentage points of baseline carbon."""
        if self.baseline_carbon_g <= 0:
            return 0.0
        return 100.0 * self.causes[cause] / self.baseline_carbon_g

    def check(self) -> None:
        total = _ltr_sum(self.causes[c] for c in CAUSES)
        if total != self.delta_g:
            raise ArithmeticError(
                f"attribution not additive: sum(causes)={total!r} != "
                f"delta={self.delta_g!r} ({self.policy} vs {self.baseline})")

    def to_dict(self) -> dict:
        return {"policy": self.policy, "baseline": self.baseline,
                "carbon_g": float(self.carbon_g),
                "baseline_carbon_g": float(self.baseline_carbon_g),
                "delta_g": float(self.delta_g),
                "savings_pct": self.savings_pct,
                "causes": {c: float(self.causes[c]) for c in CAUSES}}

    def table(self) -> str:
        lines = [f"{self.policy} vs {self.baseline}: "
                 f"{self.delta_g:,.1f} g saved "
                 f"({self.savings_pct:.2f}% of baseline)"]
        for c in CAUSES:
            v = self.causes[c]
            if v == 0.0:
                continue
            lines.append(f"  {c:<20} {v:>14,.1f} g "
                         f"({self.pp_of_baseline(c):+6.2f} pp)")
        return "\n".join(lines)


def _fit_residual(causes: dict[str, float], delta: float) -> bool:
    """Choose ``temporal_shifting`` so the canonical left-to-right sum
    over CAUSES hits ``delta`` to the last bit.

    The additive correction loop converges in one or two steps almost
    always; when the residual dwarfs the delta the correction can be
    sub-ulp (rounding to a no-op, oscillating one ulp around the
    target), so a short ulp-neighbourhood scan finishes the job."""
    resid = 0.0
    for _ in range(4):
        causes["temporal_shifting"] = resid
        total = _ltr_sum(causes[c] for c in CAUSES)
        if total == delta:
            return True
        resid += delta - total
    lo = hi = resid
    for _ in range(4):
        lo = math.nextafter(lo, -math.inf)
        hi = math.nextafter(hi, math.inf)
        for cand in (lo, hi):
            causes["temporal_shifting"] = cand
            if _ltr_sum(causes[c] for c in CAUSES) == delta:
                return True
    causes["temporal_shifting"] = resid
    return False


def _spatial_advantage(result) -> float:
    """Carbon below region-mean placement: sum_t (e_t * mean_ci_t - c_t).

    Geo slot logs store the region-mean CI; a single-region run has no
    spatial freedom, so its advantage is defined as exactly 0.0."""
    if result.regions is None:
        return 0.0
    adv = 0.0
    for s in result.slots:
        adv += s.energy_kwh * s.ci - s.carbon_g
    return adv


def _restore_energy(result) -> float:
    r = result.resilience
    return float(r.restore_energy_kwh) if r is not None else 0.0


def attribute(result, baseline) -> Attribution:
    """Decompose ``baseline.carbon_g - result.carbon_g`` by cause.

    Both runs must cover the same workload window (the sweep pairing:
    same region / seed / fault / forecast cell, different policy)."""
    delta = float(baseline.carbon_g - result.carbon_g)
    ci_ref = (baseline.carbon_g / baseline.energy_kwh
              if baseline.energy_kwh > 0 else 0.0)
    e_delta = (baseline.energy_kwh - result.energy_kwh) * ci_ref
    serving = result.serving is not None or baseline.serving is not None
    # float() coercions: slot logs and migration totals may be numpy
    # scalars, and the causes dict is the public surface (repr'd into
    # the attribution CSV) — same IEEE doubles, plain Python floats.
    causes = {
        "temporal_shifting": 0.0,
        "capacity_scaling": 0.0 if serving else float(e_delta),
        "geo_placement": float(_spatial_advantage(result)
                               - _spatial_advantage(baseline)),
        "migration_overhead": float(baseline.migration_carbon_g
                                    - result.migration_carbon_g),
        "precision_tiering": float(e_delta) if serving else 0.0,
        "fault_restore": float((_restore_energy(baseline)
                                - _restore_energy(result)) * ci_ref),
    }
    fitted = _fit_residual(causes, delta)
    for _ in range(8):
        if fitted:
            break
        # The residual's float grid can be coarser than delta's (when
        # |temporal_shifting| >> |delta|) so no residual value lands on
        # delta exactly: consecutive residuals step the sum past it.
        # Shift the lattice instead: fold the remaining mismatch — at
        # most half an ulp of the residual, meaningless in grams for a
        # first-order decomposition — into the finest-grained (smallest
        # nonzero) measured cause, then refit.  fl(x + y) is monotone
        # in y, so the fold moves the total toward delta by design.
        total = _ltr_sum(causes[c] for c in CAUSES)
        cands = [c for c in CAUSES[1:] if causes[c] != 0.0]
        if not cands:        # others all zero => total == resid == delta
            break
        c = min(cands, key=lambda c: abs(causes[c]))
        nudged = causes[c] + (delta - total)
        if nudged == causes[c]:      # sub-ulp even here: step one ulp
            nudged = math.nextafter(
                causes[c], math.inf if delta > total else -math.inf)
        causes[c] = nudged
        fitted = _fit_residual(causes, delta)
    if not fitted:
        # The measured delta sits between two points of the achievable
        # sum lattice (cancelling decomposition, see module docstring):
        # delta_g becomes the nearest achievable sum, ulps away.
        delta = _ltr_sum(causes[c] for c in CAUSES)
    return Attribution(policy=result.policy, baseline=baseline.policy,
                       carbon_g=float(result.carbon_g),
                       baseline_carbon_g=float(baseline.carbon_g),
                       delta_g=float(delta), causes=causes)
