"""Lightweight phase profilers for the experiment pipeline.

Four canonical phases bracket where each run's wall-clock goes:

- ``learn``     — knowledge-base construction (``learn_window``);
- ``provision`` — scenario materialisation + policy construction;
- ``decide``    — policy decisions (per-slot on the host engines; the
  fused device scan on the scan path, ``block_until_ready``-bracketed);
- ``execute``   — progress/energy accounting and bookkeeping.

Timers use ``perf_counter`` and cost one branch per slot when attached;
the engines skip them entirely when no profiler is threaded.  Device
work is synchronised before a bracket closes (:meth:`sync`) so scan
timings measure compute, not dispatch.  Set ``jax_trace_dir`` to also
export a ``jax.profiler`` trace around whatever :meth:`jax_trace`
wraps (off by default — the flag exists so deep dives don't need code
edits)."""
from __future__ import annotations

import contextlib
import time

PHASES = ("learn", "provision", "decide", "execute")


class PhaseProfiler:
    """Accumulates wall-clock seconds (and bracket counts) per phase."""

    def __init__(self, jax_trace_dir: str | None = None) -> None:
        self.seconds: dict[str, float] = {}
        self.calls: dict[str, int] = {}
        self.jax_trace_dir = jax_trace_dir

    def add(self, phase: str, dt: float) -> None:
        self.seconds[phase] = self.seconds.get(phase, 0.0) + dt
        self.calls[phase] = self.calls.get(phase, 0) + 1

    @contextlib.contextmanager
    def phase(self, name: str, sync=None):
        """Bracket a phase; ``sync`` (any jax pytree) is
        ``block_until_ready``-ed before the timer stops."""
        t = time.perf_counter()
        try:
            yield
        finally:
            if sync is not None:
                self.sync(sync)
            self.add(name, time.perf_counter() - t)

    @staticmethod
    def sync(tree) -> None:
        """Block until device work in ``tree`` has finished (no-op when
        jax is unavailable or the tree holds no device arrays)."""
        try:
            import jax
        except ImportError:          # pragma: no cover - jax is baked in
            return
        jax.block_until_ready(tree)

    @contextlib.contextmanager
    def jax_trace(self):
        """Export a ``jax.profiler`` trace around the wrapped block when
        ``jax_trace_dir`` is set; a plain passthrough otherwise."""
        if not self.jax_trace_dir:
            yield
            return
        import jax
        with jax.profiler.trace(self.jax_trace_dir):
            yield

    def total(self) -> float:
        return sum(self.seconds.values())

    def summary(self) -> dict:
        """Per-phase seconds/calls/share, canonical phases first."""
        order = [p for p in PHASES if p in self.seconds]
        order += [p for p in self.seconds if p not in PHASES]
        tot = self.total()
        return {p: {"seconds": self.seconds[p], "calls": self.calls[p],
                    "share": self.seconds[p] / tot if tot > 0 else 0.0}
                for p in order}

    def table(self) -> str:
        rows = ["phase        seconds   share  brackets"]
        for p, d in self.summary().items():
            rows.append(f"{p:<10} {d['seconds']:>9.4f} {d['share']:>6.1%}"
                        f" {d['calls']:>9d}")
        return "\n".join(rows)
