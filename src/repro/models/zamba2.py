"""Zamba2 hybrid: Mamba2 (SSD) backbone + one *shared* attention block
applied every ``shared_attn_every`` layers (arXiv:2411.15242).

Mamba2 block: in_proj -> (gate z, conv stream x, B, C, dt); causal
depthwise conv (width 4); SSD recurrence with scalar-per-head decay
``a_t = exp(-dt * softplus(A))`` on the shared chunked engine; gated
out_proj.  The shared block (GQA attention + SwiGLU) has ONE set of
weights reused at every application — Zamba2's parameter-saving trick —
and is entered via ``lax.cond`` inside the layer scan, so the HLO stays
one-layer-sized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from .common import (LogicalRules, ModelConfig, attention, constrain,
                     rms_norm, rope, swiglu)
from .ssm import chunked_linear_attention, recurrence_step

CONV_WIDTH = 4
MAMBA_HEAD = 64


def dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    heads = d_inner // MAMBA_HEAD
    return d_inner, heads, cfg.ssm_state or 64


def param_shapes(cfg: ModelConfig) -> dict:
    L, d = cfg.num_layers, cfg.d_model
    di, H, N = dims(cfg)
    hd = cfg.resolved_head_dim
    return {
        "embed": (cfg.vocab_size, d),
        "layers": {
            "ln": (L, d),
            "in_z": (L, d, di), "in_x": (L, d, di),
            # B/C are per-GROUP (shared across heads), as in Mamba2 — a
            # per-head parameterisation would add ~50M params/layer.
            "in_b": (L, d, N), "in_c": (L, d, N), "in_dt": (L, d, H),
            "conv": (L, CONV_WIDTH, di),
            "a_log": (L, H), "dt_bias": (L, H), "d_skip": (L, H),
            "out": (L, di, d),
        },
        "shared": {
            "ln1": (d,), "ln2": (d,),
            "wq": (d, cfg.num_heads, hd), "wk": (d, cfg.num_kv_heads, hd),
            "wv": (d, cfg.num_kv_heads, hd), "wo": (cfg.num_heads, hd, d),
            "w_gate": (d, cfg.d_ff), "w_up": (d, cfg.d_ff), "w_down": (cfg.d_ff, d),
        },
        "ln_f": (d,),
        "lm_head": (d, cfg.vocab_size),
    }


def param_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": ("vocab", "fsdp"),
        "layers": {
            "ln": ("layers", "fsdp"),
            "in_z": ("layers", "fsdp", "mlp"), "in_x": ("layers", "fsdp", "mlp"),
            "in_b": ("layers", "fsdp", "ssm_state"),
            "in_c": ("layers", "fsdp", "ssm_state"),
            "in_dt": ("layers", "fsdp", "heads"),
            "conv": ("layers", None, "mlp"),
            "a_log": ("layers", "heads"), "dt_bias": ("layers", "heads"),
            "d_skip": ("layers", "heads"),
            "out": ("layers", "mlp", "fsdp"),
        },
        "shared": {
            "ln1": ("fsdp",), "ln2": ("fsdp",),
            "wq": ("fsdp", "heads", "head_dim"), "wk": ("fsdp", "kv", "head_dim"),
            "wv": ("fsdp", "kv", "head_dim"), "wo": ("heads", "head_dim", "fsdp"),
            "w_gate": ("fsdp", "mlp"), "w_up": ("fsdp", "mlp"),
            "w_down": ("mlp", "fsdp"),
        },
        "ln_f": ("fsdp",),
        "lm_head": ("fsdp", "vocab"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, carry: jax.Array | None = None):
    """Depthwise causal conv, width CONV_WIDTH.  x: (B,S,di), w: (W,di).
    ``carry``: (B, W-1, di) previous tokens (decode)."""
    pad = carry if carry is not None else jnp.zeros(
        (x.shape[0], CONV_WIDTH - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
        for i in range(CONV_WIDTH)
    )
    return jax.nn.silu(out), xp[:, -(CONV_WIDTH - 1):]


def mamba_block(x, lp, cfg: ModelConfig, rules: LogicalRules,
                state=None, conv_carry=None, return_state=False):
    b, s, d = x.shape
    di, H, N = dims(cfg)
    z = jnp.einsum("bsd,de->bse", x, lp["in_z"].astype(x.dtype))
    xs = jnp.einsum("bsd,de->bse", x, lp["in_x"].astype(x.dtype))
    xs, conv_out = _causal_conv(xs, lp["conv"], conv_carry)
    xs = constrain(xs, rules, "batch", "seq", "mlp")
    B = jnp.einsum("bsd,dn->bsn", x, lp["in_b"].astype(x.dtype))
    C = jnp.einsum("bsd,dn->bsn", x, lp["in_c"].astype(x.dtype))
    B = jnp.broadcast_to(B[:, :, None], (b, s, H, N))
    C = jnp.broadcast_to(C[:, :, None], (b, s, H, N))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, lp["in_dt"].astype(x.dtype)).astype(jnp.float32)
        + lp["dt_bias"].astype(jnp.float32)[None, None])
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))[None, None]       # (1,1,H)
    log_w = (dt * a)[..., None]                                      # (B,S,H,1)
    xh = xs.reshape(b, s, H, MAMBA_HEAD)
    # SSD recurrence: k=B (state dim), v=dt*x (head dim), q=C
    v = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    log_w_full = jnp.broadcast_to(log_w, (b, s, H, N))
    if return_state or state is not None:
        y, new_state = chunked_linear_attention(
            C, B, v, log_w_full, chunk=cfg.attention_chunk // 8 or 128,
            initial_state=state, return_state=True)
    else:
        y = chunked_linear_attention(C, B, v, log_w_full,
                                     chunk=cfg.attention_chunk // 8 or 128)
        new_state = None
    y = y + xh * lp["d_skip"].astype(x.dtype)[None, None, :, None]
    y = (y.reshape(b, s, di) * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y, lp["out"].astype(x.dtype))
    if return_state:
        return out, new_state, conv_out
    return out


def shared_block(x, sp, cfg: ModelConfig, rules: LogicalRules, positions,
                 cache=None):
    """The shared GQA-attention + SwiGLU block (one weight set)."""
    h = rms_norm(x, sp["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, sp["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", h, sp["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h, sp["wv"].astype(h.dtype))
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    o = attention(q, k, v, 0, cfg)
    x = x + jnp.einsum("bshk,hkd->bsd", o, sp["wo"].astype(h.dtype))
    h2 = rms_norm(x, sp["ln2"], cfg.norm_eps)
    x = x + swiglu(h2, sp["w_gate"], sp["w_up"], sp["w_down"], rules)
    return x


def _split_groups(layers: dict, L: int, period: int):
    """Slice the (L, ...)-stacked layer params into (G, period, ...) full
    groups + an (R, ...) remainder (no shared attention after those)."""
    G = L // period
    R = L - G * period

    def head(x):
        return x[: G * period].reshape((G, period) + x.shape[1:])

    def tail(x):
        return x[G * period:]

    import jax

    grouped = jax.tree.map(head, layers) if G else None
    rest = jax.tree.map(tail, layers) if R else None
    return grouped, rest, G, R


def forward(params, tokens, cfg: ModelConfig, rules: LogicalRules,
            return_hidden: bool = False, **_):
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x = constrain(x, rules, "batch", "seq", "embed")
    positions = jnp.arange(x.shape[1])
    sp = params["shared"]
    grouped, rest, G, R = _split_groups(params["layers"], cfg.num_layers,
                                        cfg.shared_attn_every)

    def mamba_body(carry, lp):
        h = rms_norm(carry, lp["ln"], cfg.norm_eps)
        mb = checkpoint_name(mamba_block(h, lp, cfg, rules), "mlp_out")
        carry = carry + constrain(mb, rules, "batch", "seq", "embed")
        return carry, None

    def _remat(fn):
        if cfg.remat == "none":
            return fn
        if cfg.remat == "collectives":
            return jax.checkpoint(fn, policy=jax.checkpoint_policies
                                  .save_only_these_names("attn_out", "mlp_out"))
        return jax.checkpoint(fn)

    mamba_step = _remat(mamba_body)

    def group_body(carry, glp):
        carry, _ = jax.lax.scan(mamba_step, carry, glp)
        carry = checkpoint_name(
            shared_block(carry, sp, cfg, rules, positions), "attn_out")
        return carry, None

    if G:
        x, _ = jax.lax.scan(_remat(group_body), x, grouped)
    if R:
        x, _ = jax.lax.scan(mamba_step, x, rest)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if return_hidden:
        return x, params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return constrain(logits, rules, "batch", "seq", "vocab")


# --------------------------------------------------------------------------
# decode (O(1) mamba state + seq-sharded shared-attention KV cache)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    di, H, N = dims(cfg)
    L = cfg.num_layers
    G = L // cfg.shared_attn_every
    hd = cfg.resolved_head_dim
    return {
        "ssm": jnp.zeros((L, batch, H, N, MAMBA_HEAD), jnp.float32),
        "conv": jnp.zeros((L, batch, CONV_WIDTH - 1, di), cfg.compute_dtype),
        "k": jnp.zeros((G, batch, max_seq, cfg.num_kv_heads, hd), cfg.compute_dtype),
        "v": jnp.zeros((G, batch, max_seq, cfg.num_kv_heads, hd), cfg.compute_dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig) -> dict:
    return {
        "ssm": ("layers", "cache_batch", "heads", "ssm_state", None),
        "conv": ("layers", "cache_batch", None, "mlp"),
        "k": ("layers", "cache_batch", "cache_seq", "kv", "head_dim"),
        "v": ("layers", "cache_batch", "cache_seq", "kv", "head_dim"),
        "length": (),
    }


def _mamba_decode_step(x, lp, cfg, state, conv_carry):
    """x: (B,1,d).  Returns (out, new_state, new_conv_carry)."""
    b = x.shape[0]
    di, H, N = dims(cfg)
    z = jnp.einsum("bsd,de->bse", x, lp["in_z"].astype(x.dtype))
    xs = jnp.einsum("bsd,de->bse", x, lp["in_x"].astype(x.dtype))
    xs, conv_out = _causal_conv(xs, lp["conv"], conv_carry)
    B = jnp.einsum("bsd,dn->bsn", x, lp["in_b"].astype(x.dtype))[:, 0]
    C = jnp.einsum("bsd,dn->bsn", x, lp["in_c"].astype(x.dtype))[:, 0]
    B = jnp.broadcast_to(B[:, None], (B.shape[0], H, N))
    C = jnp.broadcast_to(C[:, None], (C.shape[0], H, N))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, lp["in_dt"].astype(x.dtype)).astype(jnp.float32)
        + lp["dt_bias"].astype(jnp.float32)[None, None])[:, 0]
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))[None]
    log_w = jnp.broadcast_to((dt * a)[..., None], (b, H, N))
    xh = xs.reshape(b, H, MAMBA_HEAD)
    v = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    y, new_state = recurrence_step(C, B, v, log_w, state)
    y = y + xh * lp["d_skip"].astype(x.dtype)[None, :, None]
    y = (y.reshape(b, 1, di) * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y, lp["out"].astype(x.dtype))
    return out, new_state, conv_out


def decode_step(params, token, cache, cfg: ModelConfig, rules: LogicalRules):
    """One decode step.  Shared-attention K/V caches are sequence-sharded
    over the model axis; the new K/V is written with a one-hot mask (no
    cross-shard dynamic slice) and attention runs masked over the cache."""
    from .common import chunked_attention

    x = params["embed"].astype(cfg.compute_dtype)[token][:, None]
    sp = params["shared"]
    length = cache["length"]
    max_seq = cache["k"].shape[2]
    grouped, rest, G, R = _split_groups(params["layers"], cfg.num_layers,
                                        cfg.shared_attn_every)
    p = cfg.shared_attn_every

    def slice_states(tree, lo, n):
        return jax.tree.map(lambda a: a[lo:lo + n], tree)

    def mamba_scan(x, glp, ssm, conv):
        def body(carry, inp):
            lp, st, cv = inp
            h = rms_norm(carry, lp["ln"], cfg.norm_eps)
            out, st2, cv2 = _mamba_decode_step(h, lp, cfg, st, cv)
            return carry + out, (st2, cv2)

        x, (ssm2, conv2) = jax.lax.scan(body, x, (glp, ssm, conv))
        return x, ssm2, conv2

    onehot = (jnp.arange(max_seq) == length).astype(cfg.compute_dtype)

    def shared_decode(x, kc, vc):
        h = rms_norm(x, sp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, sp["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dhk->bshk", h, sp["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dhk->bshk", h, sp["wv"].astype(h.dtype))
        pos = length[None]
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        kc = kc * (1 - onehot)[None, :, None, None] + k * onehot[None, :, None, None]
        vc = vc * (1 - onehot)[None, :, None, None] + v * onehot[None, :, None, None]
        o = chunked_attention(q, kc, vc, causal_offset=length,
                              chunk=cfg.attention_chunk)
        x = x + jnp.einsum("bshk,hkd->bsd", o, sp["wo"].astype(h.dtype))
        h2 = rms_norm(x, sp["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, sp["w_gate"], sp["w_up"], sp["w_down"], rules)
        return x, kc, vc

    new_ssm, new_conv, new_k, new_v = [], [], [], []
    for g in range(G):
        glp = jax.tree.map(lambda a: a[g], grouped)
        x, s2, c2 = mamba_scan(x, glp, slice_states(cache["ssm"], g * p, p),
                               slice_states(cache["conv"], g * p, p))
        x, kc, vc = shared_decode(x, cache["k"][g], cache["v"][g])
        new_ssm.append(s2); new_conv.append(c2)
        new_k.append(kc); new_v.append(vc)
    if R:
        x, s2, c2 = mamba_scan(x, rest, slice_states(cache["ssm"], G * p, R),
                               slice_states(cache["conv"], G * p, R))
        new_ssm.append(s2); new_conv.append(c2)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    new_cache = {
        "ssm": jnp.concatenate(new_ssm, axis=0),
        "conv": jnp.concatenate(new_conv, axis=0),
        "k": jnp.stack(new_k, axis=0) if G else cache["k"],
        "v": jnp.stack(new_v, axis=0) if G else cache["v"],
        "length": length + 1,
    }
    return logits[:, 0], new_cache
