from .api import (FAMILIES, abstract_params, forward, init_params,  # noqa: F401
                  module_for, param_count, param_shardings)
from .common import (DEFAULT_RULES, LogicalRules, ModelConfig, SHAPES,  # noqa: F401
                     ShapeConfig, constrain)
