"""RWKV6 "Finch" (attention-free, data-dependent decay) — arXiv:2404.05892.

Faithful-in-shape implementation: token-shift mixing, per-channel
data-dependent decay ``w = exp(-exp(w0 + lora(x)))``, current-token bonus
``u``, per-head matrix-valued state, squared-ReLU channel mix.  The time
mix runs on the shared chunked linear-recurrence engine (ssm.py), so 32k
prefill and 500k decode are O(chunk)/O(1) in memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from .common import LogicalRules, ModelConfig, constrain, rms_norm
from .ssm import chunked_linear_attention

LORA_RANK = 64
HEAD_DIM = 64


def num_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // HEAD_DIM


def param_shapes(cfg: ModelConfig) -> dict:
    L, d, f = cfg.num_layers, cfg.d_model, cfg.d_ff
    H, hd = num_heads(cfg), HEAD_DIM
    return {
        "embed": (cfg.vocab_size, d),
        "layers": {
            "ln1": (L, d), "ln2": (L, d),
            "mix": (L, 5, d),                      # token-shift mus: r,k,v,w,g
            "wr": (L, d, H, hd), "wk": (L, d, H, hd), "wv": (L, d, H, hd),
            "wg": (L, d, H, hd), "wo": (L, H, hd, d),
            "w0": (L, d), "w1": (L, d, LORA_RANK), "w2": (L, LORA_RANK, d),
            "u": (L, H, hd),
            "mix_c": (L, 2, d),                    # channel-mix mus: k,r
            "ck": (L, d, f), "cv": (L, f, d), "cr": (L, d, d),
        },
        "ln_f": (d,),
        "lm_head": (d, cfg.vocab_size),
    }


def param_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": ("vocab", "fsdp"),
        "layers": {
            "ln1": ("layers", "fsdp"), "ln2": ("layers", "fsdp"),
            "mix": ("layers", None, "fsdp"),
            "wr": ("layers", "fsdp", "heads", "head_dim"),
            "wk": ("layers", "fsdp", "heads", "head_dim"),
            "wv": ("layers", "fsdp", "heads", "head_dim"),
            "wg": ("layers", "fsdp", "heads", "head_dim"),
            "wo": ("layers", "heads", "head_dim", "fsdp"),
            "w0": ("layers", "fsdp"),
            "w1": ("layers", "fsdp", None),
            "w2": ("layers", None, "fsdp"),
            "u": ("layers", "heads", "head_dim"),
            "mix_c": ("layers", None, "fsdp"),
            "ck": ("layers", "fsdp", "mlp"),
            "cv": ("layers", "mlp", "fsdp"),
            "cr": ("layers", "fsdp", None),
        },
        "ln_f": ("fsdp",),
        "lm_head": ("fsdp", "vocab"),
    }


def _shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """Token shift: x_{t-1} (zeros / carried ``prev`` at t=0)."""
    first = prev[:, None] if prev is not None else jnp.zeros_like(x[:, :1])
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def time_mix(x, lp, cfg: ModelConfig, rules: LogicalRules,
             state=None, prev_tok=None, return_state=False):
    b, s, d = x.shape
    H, hd = num_heads(cfg), HEAD_DIM
    xx = _shift(x, prev_tok)
    def mixed(i):
        mu = lp["mix"][i].astype(x.dtype)
        return x + (xx - x) * mu
    r = jnp.einsum("bsd,dhk->bshk", mixed(0), lp["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", mixed(1), lp["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", mixed(2), lp["wv"].astype(x.dtype))
    g = jnp.einsum("bsd,dhk->bshk", mixed(4), lp["wg"].astype(x.dtype))
    # data-dependent per-channel decay (kept in log space, <= 0)
    lora = jnp.einsum("bsr,rd->bsd", jnp.tanh(
        jnp.einsum("bsd,dr->bsr", mixed(3), lp["w1"].astype(x.dtype))
    ), lp["w2"].astype(x.dtype))
    log_w = -jnp.exp(
        (lp["w0"].astype(jnp.float32)[None, None] + lora.astype(jnp.float32))
        .clip(-8.0, 4.0)
    ).reshape(b, s, H, hd)
    r = constrain(r, rules, "batch", "seq", "heads", "head_dim")
    k = constrain(k, rules, "batch", "seq", "heads", "head_dim")
    if return_state or state is not None:
        y, new_state = chunked_linear_attention(
            r, k, v, log_w, u=lp["u"], chunk=cfg.attention_chunk // 8 or 128,
            initial_state=state, return_state=True)
    else:
        y = chunked_linear_attention(r, k, v, log_w, u=lp["u"],
                                     chunk=cfg.attention_chunk // 8 or 128)
        new_state = None
    y = y * jax.nn.silu(g)
    out = jnp.einsum("bshk,hkd->bsd", y, lp["wo"].astype(x.dtype))
    if return_state:
        return out, new_state
    return out


def channel_mix(x, lp, cfg: ModelConfig, prev_tok=None):
    xx = _shift(x, prev_tok)
    mu_k = lp["mix_c"][0].astype(x.dtype)
    mu_r = lp["mix_c"][1].astype(x.dtype)
    xk = x + (xx - x) * mu_k
    xr = x + (xx - x) * mu_r
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, lp["ck"].astype(x.dtype))))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, lp["cr"].astype(x.dtype)))
    return rr * jnp.einsum("bsf,fd->bsd", kk, lp["cv"].astype(x.dtype))


def forward(params, tokens, cfg: ModelConfig, rules: LogicalRules,
            return_hidden: bool = False, **_):
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x = constrain(x, rules, "batch", "seq", "embed")

    def body(carry, lp):
        h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        tm = checkpoint_name(time_mix(h, lp, cfg, rules), "attn_out")
        carry = carry + constrain(tm, rules, "batch", "seq", "embed")
        h2 = rms_norm(carry, lp["ln2"], cfg.norm_eps)
        cm = checkpoint_name(channel_mix(h2, lp, cfg), "mlp_out")
        carry = carry + constrain(cm, rules, "batch", "seq", "embed")
        return carry, None

    if cfg.remat == "none":
        step = body
    elif cfg.remat == "collectives":
        # save the post-TP-all-reduce block outputs so the backward never
        # re-executes the forward collectives (EXPERIMENTS.md §Perf ssm-1)
        step = jax.checkpoint(body, policy=jax.checkpoint_policies
                              .save_only_these_names("attn_out", "mlp_out"))
    else:
        step = jax.checkpoint(body)
    x, _ = jax.lax.scan(step, x, params["layers"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if return_hidden:
        return x, params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return constrain(logits, rules, "batch", "seq", "vocab")


def decode_step(params, token, cache, cfg: ModelConfig, rules: LogicalRules):
    """O(1) decode: cache = {"state": (L,B,H,hd,hd) f32,
    "tok1": (L,B,d), "tok2": (L,B,d)} (token-shift carries per block)."""
    x = params["embed"].astype(cfg.compute_dtype)[token][:, None]   # (B,1,d)

    def body(carry, inputs):
        x = carry
        lp, state, t1, t2 = inputs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, new_state = time_mix(h, lp, cfg, rules, state=state,
                                prev_tok=t1, return_state=True)
        x = x + y
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + channel_mix(h2, lp, cfg, prev_tok=t2)
        return x, (new_state, h[:, 0], h2[:, 0])

    x, (states, t1s, t2s) = jax.lax.scan(
        body, x, (params["layers"], cache["state"], cache["tok1"], cache["tok2"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits[:, 0], {"state": states, "tok1": t1s, "tok2": t2s}


def init_cache(cfg: ModelConfig, batch: int) -> dict:
    H, hd = num_heads(cfg), HEAD_DIM
    L, d = cfg.num_layers, cfg.d_model
    return {
        "state": jnp.zeros((L, batch, H, hd, hd), jnp.float32),
        "tok1": jnp.zeros((L, batch, d), cfg.compute_dtype),
        "tok2": jnp.zeros((L, batch, d), cfg.compute_dtype),
    }


def cache_specs(cfg: ModelConfig) -> dict:
    return {
        "state": ("layers", "cache_batch", "heads", None, None),
        "tok1": ("layers", "cache_batch", "embed"),
        "tok2": ("layers", "cache_batch", "embed"),
    }
