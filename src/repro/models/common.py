"""Shared model substrate: configs, logical sharding, norms, attention, MLP.

Sharding follows the MaxText-style logical-axis-rules pattern: every tensor
dimension carries a *logical* name; ``LogicalRules`` maps logical names to
mesh axes.  The production mesh is ``("pod", "data", "model")`` (or
``("data", "model")`` single-pod):

- ``pod``    — pure data parallelism across pods (gradient all-reduce
               crosses the inter-pod links; this is the term CarbonFlex's
               elastic-scaling profiles model);
- ``data``   — data parallelism + FSDP (weights' contracting dims sharded);
- ``model``  — tensor parallelism (heads / d_ff / experts / vocab).

Head sharding degrades gracefully: if a head count does not divide the
``model`` axis (e.g. minicpm-2b's 36 heads on a 16-way axis), the rule
falls back to replication for that dimension and TP applies to the MLP
only (recorded in DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# --------------------------------------------------------------------------
# configuration


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One assigned architecture (see repro/configs/)."""

    name: str
    family: str                    # "dense" | "moe" | "ssm" | "hybrid" | "vlm" | "audio"
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    shared_attn_every: int = 6     # zamba2: shared attention block period
    # frontend stubs
    prefix_len: int = 0            # vlm/audio: precomputed embedding prefix
    # numerics
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    moment_dtype: Any = jnp.float32
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    use_bias: bool = False
    tie_embeddings: bool = False
    # training
    remat: str = "collectives"     # "full" | "dots" | "collectives" | "none"
    lr_schedule: str = "cosine"    # minicpm uses "wsd"
    # sequence parallelism: shard the residual stream's seq dim over
    # `model` between blocks (Megatron-SP style; evaluated in §Perf)
    sequence_parallel: bool = False
    # attention implementation: "xla" chunked scan | "pallas" flash kernel
    attention_backend: str = "xla"
    attention_chunk: int = 1024

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, h = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":                      # rwkv6-style
            att = self.num_layers * (d * d * 4 + d * d // 2)
            ff = self.num_layers * 2 * d * self.d_ff
            return emb + att + ff
        attn = self.num_layers * (
            d * self.num_heads * h + 2 * d * self.num_kv_heads * h
            + self.num_heads * h * d
        )
        if self.num_experts:
            ff = self.num_layers * (
                3 * d * self.d_ff * self.num_experts + d * self.num_experts
            )
        else:
            ff = self.num_layers * 3 * d * self.d_ff
        if self.family == "hybrid":                   # mamba2 blocks dominate
            ff = self.num_layers * 3 * d * self.d_ff
            attn = attn // max(self.num_layers // self.shared_attn_every, 1)
        return emb + attn + ff

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only routed experts)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.num_layers * 3 * d * self.d_ff * self.num_experts
        return dense + self.num_layers * 3 * d * self.d_ff * self.experts_per_token


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell of the assigned grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# --------------------------------------------------------------------------
# logical sharding rules


DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,          # activation d_model
    "fsdp": "data",         # weight contracting / largest dim (ZeRO-3 style)
    "vocab": "model",
    "heads": "model",
    "kv": None,             # GQA kv heads usually < model axis -> replicate
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "layers": None,
    "seq_sp": "model",      # sequence-parallel residual stream (opt-in)
    "cache_seq": "model",   # decode: sequence-sharded KV cache
    "cache_batch": ("pod", "data"),
    "ssm_state": None,
}


class LogicalRules:
    """Maps logical axis names -> mesh axes, validated against the mesh."""

    def __init__(self, mesh: Mesh, overrides: dict[str, Any] | None = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if overrides:
            self.rules.update(overrides)

    def _mesh_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        size = 1
        for a in axes:
            size *= self.mesh.shape.get(a, 1)
        return size

    def spec(self, *logical: Optional[str], dims: Sequence[int] | None = None) -> P:
        """PartitionSpec for the given logical dims; falls back to
        replication when a dim size does not divide the mesh extent."""
        out = []
        for i, name in enumerate(logical):
            if name is None:
                out.append(None)
                continue
            axes = self.rules.get(name)
            axes_t = (axes,) if isinstance(axes, str) else axes
            if axes_t is None:
                out.append(None)
                continue
            # keep only axes that exist in the mesh
            axes_t = tuple(a for a in axes_t if a in self.mesh.shape)
            if not axes_t:
                out.append(None)
                continue
            if dims is not None and dims[i] % self._mesh_size(axes_t) != 0:
                out.append(None)      # graceful fallback (e.g. 36 heads on 16)
                continue
            out.append(axes_t[0] if len(axes_t) == 1 else axes_t)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, *logical, dims=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical, dims=dims))


def constrain(x: jax.Array, rules: LogicalRules, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical names (size-aware fallback)."""
    return jax.lax.with_sharding_constraint(
        x, rules.sharding(*logical, dims=x.shape)
    )


# --------------------------------------------------------------------------
# initializers / spec helpers


def dense_init(key, shape, dtype, in_axis=0):
    fan_in = max(int(np.prod([shape[i] for i in range(len(shape))
                              if i == in_axis])), 1)
    return (jax.random.normal(key, shape) / np.sqrt(fan_in)).astype(dtype)


# --------------------------------------------------------------------------
# building blocks (pure functions over param dicts)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; x: (..., seq, heads, head_dim)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def _attn_weights_chunk(q, k, mask, scale):
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    return jnp.where(mask, s, -1e30)


def chunked_attention(
    q: jax.Array,          # (B, Sq, H, D)
    k: jax.Array,          # (B, Sk, KV, D)
    v: jax.Array,          # (B, Sk, KV, D)
    causal_offset: int,
    chunk: int,
) -> jax.Array:
    """Memory-efficient causal attention: lax.scan over KV chunks with an
    online-softmax running (m, l, o) — the XLA analogue of flash attention,
    so 32k-token prefill compiles within HBM.  GQA: q heads grouped over kv
    heads.  ``causal_offset``: absolute position of q[0] minus k[0] (for
    decode q is at the end of the cache)."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    scale = 1.0 / np.sqrt(d)
    nchunk = int(np.ceil(sk / chunk))
    pad = nchunk * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunk, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunk, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    q_pos = causal_offset + jnp.arange(sq)

    def body(carry, inputs):
        m, l, o = carry
        idx, kb, vb = inputs
        k_pos = idx * chunk + jnp.arange(chunk)
        mask = (q_pos[:, None] >= k_pos[None, :]) & (k_pos[None, :] < sk)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, hkv, group, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    o0 = jnp.zeros((b, hkv, group, sq, d), jnp.float32)
    idxs = jnp.arange(nchunk)
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), (idxs, kc, vc))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)
    return out.astype(q.dtype)


def attention(q, k, v, causal_offset, cfg: ModelConfig):
    if cfg.attention_backend == "pallas":
        from repro.kernels import flash_attention as fa

        return fa.gqa_flash(q, k, v, causal_offset=causal_offset)
    return chunked_attention(q, k, v, causal_offset, cfg.attention_chunk)


def swiglu(x, w_gate, w_up, w_down, rules: LogicalRules):
    h = jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
    h = constrain(jax.nn.silu(h) * u, rules, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, w_down.astype(x.dtype))
