"""Chunked linear-recurrence engine shared by RWKV6 (Finch) and Mamba2 (SSD).

Both models are linear-attention recurrences over a per-head state
``S in R^{dk x dv}``:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (RWKV6: w_t per-channel;
                                                  Mamba2/SSD: w_t scalar)
    y_t = q_t S_*  (+ current-token term)

A naive ``lax.scan`` over time keeps one carry per step for the backward
pass — O(S) states — which blows HBM at 32k context.  The chunked parallel
form (the SSD trick, adapted to TPU) processes the sequence in chunks of
``chunk`` tokens: within a chunk everything is dense matmuls (MXU-friendly,
mask + cumulative log-decay), and only one state per chunk is carried, so
the backward saves S/chunk states.

Decay products are kept in log space for stability; per-chunk the products
span at most ``chunk`` steps so fp32 suffices.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def chunked_linear_attention(
    q: jax.Array,            # (B, S, H, dk)
    k: jax.Array,            # (B, S, H, dk)
    v: jax.Array,            # (B, S, H, dv)
    log_w: jax.Array,        # (B, S, H, dk) per-channel or (B, S, H, 1) scalar log-decay, <= 0
    u: jax.Array | None = None,   # (H, dk) RWKV6 current-token bonus; None -> SSD style
    chunk: int = 128,
    initial_state: jax.Array | None = None,   # (B, H, dk, dv)
    return_state: bool = False,
):
    """Returns y (B, S, H, dv) [and final state].

    Current-token term: with ``u`` (RWKV6), y_t += (q_t * u * k_t) v_t and
    the state update applies decay *before* adding k_t v_t; without ``u``
    (Mamba2/SSD), the j = t term enters through the decay chain with weight
    exp(0) = 1.
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    nchunk = int(np.ceil(s / chunk))
    pad = nchunk * chunk - s
    if pad:
        zq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, zq)
        k = jnp.pad(k, zq)
        v = jnp.pad(v, zq)
        log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def to_chunks(x):
        return x.reshape(b, nchunk, chunk, h, x.shape[-1]).transpose(1, 0, 2, 3, 4)

    qc, kc, vc, wc = map(to_chunks, (q, k, v, log_w))

    def body(state, inputs):
        qb, kb, vb, wb = (t.astype(jnp.float32) for t in inputs)
        # cumulative log decay within the chunk: cum[t] = sum_{j<=t} logw_j
        cum = jnp.cumsum(wb, axis=1)                       # (B, c, H, dk)
        cum_prev = cum - wb                                # sum_{j<t}
        if u is None:
            # SSD: q_t attends j<=t with decay exp(cum_t - cum_j)
            q_eff = qb * jnp.exp(cum)
            k_eff = kb * jnp.exp(-cum)
            mask = jnp.tril(jnp.ones((chunk, chunk), bool))
            att = jnp.einsum("bthd,bjhd->bhtj", q_eff, k_eff)
            att = jnp.where(mask[None, None], att, 0.0)
            y = jnp.einsum("bhtj,bjhd->bthd", att, vb)
            y = y + jnp.einsum("bthd,bhdv->bthv", q_eff, state)
        else:
            # RWKV6: j<t via decay chain w/ cum_prev; j=t via the u bonus
            q_eff = qb * jnp.exp(cum_prev)
            k_eff = kb * jnp.exp(-cum)
            mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
            att = jnp.einsum("bthd,bjhd->bhtj", q_eff, k_eff)
            att = jnp.where(mask[None, None], att, 0.0)
            y = jnp.einsum("bhtj,bjhd->bthd", att, vb)
            y = y + jnp.einsum("bthd,bhdv->bthv", q_eff, state)
            y = y + jnp.einsum("bthd,bthv->bthv",
                               qb * u.astype(jnp.float32)[None, None] * kb,
                               vb)
        # state to end of chunk
        total = cum[:, -1]                                  # (B, H, dk)
        carry_k = kb * jnp.exp(total[:, None] - cum)        # decay from j to end
        new_state = state * jnp.exp(total)[..., None] + jnp.einsum(
            "bthd,bthv->bhdv", carry_k, vb)
        return new_state, y

    state0 = (initial_state.astype(jnp.float32) if initial_state is not None
              else jnp.zeros((b, h, dk, dv), jnp.float32))
    state, ys = jax.lax.scan(body, state0, (qc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nchunk * chunk, h, dv)[:, :s]
    y = y.astype(v.dtype)
    if return_state:
        return y, state
    return y


def recurrence_step(
    q: jax.Array,            # (B, H, dk)
    k: jax.Array,
    v: jax.Array,            # (B, H, dv)
    log_w: jax.Array,        # (B, H, dk) or (B, H, 1)
    state: jax.Array,        # (B, H, dk, dv)
    u: jax.Array | None = None,
):
    """Single decode step (O(1) memory — this is why SSM archs run the
    long_500k shape).  Returns (y, new_state)."""
    q32, k32, v32 = (t.astype(jnp.float32) for t in (q, k, v))
    w = jnp.exp(log_w.astype(jnp.float32))[..., None]        # (B,H,dk,1)
    kv = k32[..., None] * v32[..., None, :]                  # (B,H,dk,dv)
    if u is None:
        new_state = state * w + kv
        y = jnp.einsum("bhd,bhdv->bhv", q32, new_state)
    else:
        y = jnp.einsum("bhd,bhdv->bhv", q32,
                       state + u.astype(jnp.float32)[None, ..., None] * kv)
        new_state = state * w + kv
    return y.astype(v.dtype), new_state


def reference_scan(q, k, v, log_w, u=None, initial_state=None):
    """Sequential oracle for tests: plain per-step recurrence."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    state = (initial_state.astype(jnp.float32) if initial_state is not None
             else jnp.zeros((b, h, dk, dv), jnp.float32))
    ys = []
    for t in range(s):
        y, state = recurrence_step(q[:, t], k[:, t], v[:, t], log_w[:, t], state, u=u)
        ys.append(y)
    return jnp.stack(ys, axis=1), state
