"""Dense / MoE decoder-only transformer (GQA + RoPE + SwiGLU).

Covers the assigned LM archs: internvl2-2b (vision-prefix stub),
command-r-plus-104b, minicpm-2b, llama3-8b, stablelm-1.6b, musicgen-large
(EnCodec-token decoder), dbrx-132b and qwen3-moe (MoE via sort-based
capacity dispatch with expert parallelism).

Layers are stacked on a leading ``layers`` axis and executed with
``lax.scan`` so the HLO contains one layer body regardless of depth; the
body is rematerialised according to ``cfg.remat``.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import numpy as np

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from .common import (LogicalRules, ModelConfig, attention, constrain,
                     rms_norm, rope, swiglu)

PyTree = Any


# --------------------------------------------------------------------------
# parameter construction


def param_specs(cfg: ModelConfig) -> dict:
    """Logical axis names per parameter (mirrors init_params shapes)."""
    L, d, hd = cfg.num_layers, cfg.d_model, cfg.resolved_head_dim
    layers = {
        "ln1": ("layers", "fsdp"),
        "ln2": ("layers", "fsdp"),
        "wq": ("layers", "fsdp", "heads", "head_dim"),
        "wk": ("layers", "fsdp", "kv", "head_dim"),
        "wv": ("layers", "fsdp", "kv", "head_dim"),
        "wo": ("layers", "heads", "head_dim", "fsdp"),
    }
    if cfg.num_experts:
        layers.update({
            "router": ("layers", "fsdp", "experts"),
            "w_gate": ("layers", "experts", "fsdp", "expert_mlp"),
            "w_up": ("layers", "experts", "fsdp", "expert_mlp"),
            "w_down": ("layers", "experts", "expert_mlp", "fsdp"),
        })
    else:
        layers.update({
            "w_gate": ("layers", "fsdp", "mlp"),
            "w_up": ("layers", "fsdp", "mlp"),
            "w_down": ("layers", "mlp", "fsdp"),
        })
    out = {"embed": ("vocab", "fsdp"), "layers": layers, "ln_f": ("fsdp",)}
    if not cfg.tie_embeddings:
        out["lm_head"] = ("fsdp", "vocab")
    return out


def param_shapes(cfg: ModelConfig) -> dict:
    L, d, hd = cfg.num_layers, cfg.d_model, cfg.resolved_head_dim
    H, KV, f = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff
    layers = {
        "ln1": (L, d), "ln2": (L, d),
        "wq": (L, d, H, hd), "wk": (L, d, KV, hd), "wv": (L, d, KV, hd),
        "wo": (L, H, hd, d),
    }
    if cfg.num_experts:
        E = cfg.num_experts
        layers.update({
            "router": (L, d, E),
            "w_gate": (L, E, d, f), "w_up": (L, E, d, f), "w_down": (L, E, f, d),
        })
    else:
        layers.update({"w_gate": (L, d, f), "w_up": (L, d, f), "w_down": (L, f, d)})
    out = {"embed": (cfg.vocab_size, d), "layers": layers, "ln_f": (d,)}
    if not cfg.tie_embeddings:
        out["lm_head"] = (d, cfg.vocab_size)
    return out


# --------------------------------------------------------------------------
# MoE layer (sort-based capacity dispatch; experts sharded over `model`)
#
# Two implementations:
#
# - ``moe_block_global`` (the original baseline): a single global sort-based
#   dispatch in pjit-auto mode.  The global argsort/scatter over tokens
#   sharded on `data` forces the SPMD partitioner into replication —
#   measured 3744 s of collective time per step on qwen3 x train_4k
#   (EXPERIMENTS.md §Perf, iteration moe-1).
#
# - ``moe_block`` (shard_map local dispatch, the default): activations are
#   already replicated over the `model` axis, so each (data, model) shard
#   routes ITS OWN tokens to ITS OWN E/TP experts entirely locally
#   (local top-k, local sort, local capacity), computes, scatters back a
#   partial output, and one ``psum`` over `model` recombines each token's
#   top-k expert outputs — the same collective shape as a dense
#   tensor-parallel MLP.  Zero dispatch collectives.


def _moe_local_dispatch(xt, router_w, w_gate, w_up, w_down, *, e_total,
                        k_top, cap_frac, axis):
    """Runs inside shard_map.  xt: (T_loc, d) local tokens; router_w: (d, E);
    w_*: (E_loc, ...) local expert weights.  Returns the psum-combined
    (T_loc, d) MoE output."""
    t_loc, d = xt.shape
    e_loc = w_gate.shape[0]
    my0 = jax.lax.axis_index(axis) * e_loc
    cap = max(int(np.ceil(t_loc * k_top / e_total * cap_frac)), 1)

    logits = jnp.einsum("td,de->te", xt, router_w.astype(xt.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, eidx = jax.lax.top_k(probs, k_top)                 # (T_loc, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # keep only (token, expert) pairs routed to experts on THIS shard
    flat_e = eidx.reshape(-1)
    local = (flat_e >= my0) & (flat_e < my0 + e_loc)
    rel_e = jnp.where(local, flat_e - my0, e_loc)            # e_loc = trash
    order = jnp.argsort(rel_e, stable=True)                  # local sort only
    sorted_e = rel_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    ranks = jnp.arange(t_loc * k_top) - first
    keep = (sorted_e < e_loc) & (ranks < cap)
    slot = jnp.where(keep, sorted_e * cap + ranks, e_loc * cap)
    src_tok = order // k_top

    buf = jnp.zeros((e_loc * cap + 1, d), xt.dtype)
    buf = buf.at[slot].set(xt[src_tok] * keep[:, None].astype(xt.dtype))
    eb = buf[: e_loc * cap].reshape(e_loc, cap, d)

    h = jnp.einsum("ecd,edf->ecf", eb, w_gate.astype(xt.dtype))
    u = jnp.einsum("ecd,edf->ecf", eb, w_up.astype(xt.dtype))
    yb = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, w_down.astype(xt.dtype))

    ybuf = jnp.concatenate([yb.reshape(e_loc * cap, d),
                            jnp.zeros((1, d), xt.dtype)])
    contrib = ybuf[slot] * (gate.reshape(-1)[order] * keep)[:, None].astype(xt.dtype)
    y = jnp.zeros((t_loc, d), xt.dtype).at[src_tok].add(contrib)
    return jax.lax.psum(y, axis)      # combine top-k partials across shards


def moe_block(x: jax.Array, lp: dict, cfg: ModelConfig,
              rules: LogicalRules) -> jax.Array:
    mesh = rules.mesh
    if "model" not in mesh.shape or mesh.shape["model"] == 1 or \
            cfg.num_experts % mesh.shape["model"] != 0:
        return moe_block_global(x, lp, cfg, rules)
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    b, s, d = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    xt = x.reshape(b * s, d)
    espec = P("model")
    fn = functools.partial(
        _moe_local_dispatch, e_total=cfg.num_experts,
        k_top=cfg.experts_per_token, cap_frac=cfg.capacity_factor,
        axis="model")
    y = shard_map(
        fn, mesh=mesh,
        in_specs=(P(batch_axes, None), P(None, None), espec, espec, espec),
        out_specs=P(batch_axes, None),
        check_rep=False,
    )(xt, lp["router"], lp["w_gate"], lp["w_up"], lp["w_down"])
    return y.reshape(b, s, d)


def moe_block_global(x: jax.Array, lp: dict, cfg: ModelConfig, rules: LogicalRules) -> jax.Array:
    b, s, d = x.shape
    T = b * s
    E, K = cfg.num_experts, cfg.experts_per_token
    C = int(np.ceil(T * K / E * cfg.capacity_factor))
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt, lp["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                     # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)                                # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    ranks = jnp.arange(T * K) - first
    keep = ranks < C                                         # token-drop beyond capacity
    slot = jnp.where(keep, sorted_e * C + ranks, E * C)      # E*C = trash slot
    src_tok = order // K

    buf = jnp.zeros((E * C + 1, d), x.dtype)
    buf = buf.at[slot].set(xt[src_tok] * keep[:, None].astype(x.dtype))
    eb = buf[: E * C].reshape(E, C, d)
    eb = constrain(eb, rules, "experts", None, "embed")

    h = jnp.einsum("ecd,edf->ecf", eb, lp["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", eb, lp["w_up"].astype(x.dtype))
    h = jax.nn.silu(h) * u
    yb = jnp.einsum("ecf,efd->ecd", h, lp["w_down"].astype(x.dtype))
    yb = constrain(yb, rules, "experts", None, "embed")

    ybuf = jnp.concatenate([yb.reshape(E * C, d), jnp.zeros((1, d), x.dtype)])
    contrib = ybuf[slot] * (gate.reshape(-1)[order] * keep)[:, None].astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[src_tok].add(contrib)
    return y.reshape(b, s, d)


# --------------------------------------------------------------------------
# decoder layer + full forward


def decoder_layer(x, lp, cfg: ModelConfig, rules: LogicalRules,
                  positions, kv_override=None):
    """One decoder layer.  Returns (out, (k, v)) — the fresh K/V are used by
    the prefill path to build a cache."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"].astype(h.dtype))
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, rules, "batch", "seq", "heads", "head_dim")
    k = constrain(k, rules, "batch", "seq", "kv", "head_dim")
    if kv_override is not None:
        k_all, v_all = kv_override
    else:
        k_all, v_all = k, v
    o = attention(q, k_all, v_all, 0, cfg)
    o = jnp.einsum("bshk,hkd->bsd", o, lp["wo"].astype(h.dtype))
    # name the post-all-reduce activations: the "collectives" remat policy
    # saves exactly these, so the backward pass re-runs local compute but
    # never re-executes the TP all-reduces (EXPERIMENTS.md §Perf dense-1).
    o = checkpoint_name(o, "attn_out")
    res_seq = "seq_sp" if cfg.sequence_parallel else "seq"
    x = x + constrain(o, rules, "batch", res_seq, "embed")

    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.num_experts:
        m = moe_block(h2, lp, cfg, rules)
    else:
        m = swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"], rules)
    m = checkpoint_name(m, "mlp_out")
    x = x + constrain(m, rules, "batch", res_seq, "embed")
    return x, (k, v)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    if cfg.remat == "collectives":
        policy = jax.checkpoint_policies.save_only_these_names(
            "attn_out", "mlp_out")
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def forward(params: PyTree, tokens: jax.Array, cfg: ModelConfig,
            rules: LogicalRules, prefix_embeds: Optional[jax.Array] = None,
            return_kv: bool = False, return_hidden: bool = False):
    """Token logits.  ``prefix_embeds`` (B, P, d): precomputed patch/frame
    embeddings of the modality frontend stub (vlm/audio), prepended."""
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.compute_dtype), x], axis=1)
    x = constrain(x, rules, "batch", "seq", "embed")
    positions = jnp.arange(x.shape[1])

    def body(carry, lp):
        y, (k, v) = decoder_layer(carry, lp, cfg, rules, positions)
        return y, (k, v) if return_kv else None

    x, kv = jax.lax.scan(_remat(body, cfg), x, params["layers"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params.get("lm_head", None)
    if head is None:
        head = params["embed"].T
    if return_hidden:
        return x, head
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    logits = constrain(logits, rules, "batch", "seq", "vocab")
    if return_kv:
        return logits, kv
    return logits
