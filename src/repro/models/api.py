"""Family-generic model API: init / abstract params / forward dispatch.

Every family module exposes ``param_shapes(cfg)``, ``param_specs(cfg)``
and ``forward(params, tokens, cfg, rules, **kw)``; this module provides
the generic constructors over those descriptions:

- ``init_params``       — real initialisation (smoke tests, examples);
- ``abstract_params``   — ShapeDtypeStruct tree with shardings (dry-run,
                          no device allocation);
- ``param_shardings``   — NamedSharding tree (jit in_shardings).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import rwkv6, transformer, zamba2
from .common import LogicalRules, ModelConfig, dense_init

FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "audio": transformer,
    "ssm": rwkv6,
    "hybrid": zamba2,
}


def module_for(cfg: ModelConfig):
    return FAMILIES[cfg.family]


def _walk_flat(node, prefix=()):
    for name, v in node.items():
        if isinstance(v, dict):
            yield from _walk_flat(v, prefix + (name,))
        else:
            yield prefix + (name,), v


def init_params(cfg: ModelConfig, key: jax.Array) -> Any:
    shapes = module_for(cfg).param_shapes(cfg)
    flat = dict(_walk_flat(shapes))
    keys = jax.random.split(key, len(flat))
    out: dict = {}
    for (path, shape), k in zip(sorted(flat.items()), keys):
        node = out
        for part in path[:-1]:
            node = node.setdefault(part, {})
        leaf = path[-1]
        if leaf.startswith("ln") or leaf in ("d_skip",):
            node[leaf] = jnp.ones(shape, cfg.param_dtype)
        elif leaf in ("mix", "mix_c"):
            node[leaf] = jnp.full(shape, 0.5, cfg.param_dtype)
        elif leaf in ("w0",):
            node[leaf] = jnp.full(shape, -1.0, cfg.param_dtype)
        elif leaf in ("a_log",):
            node[leaf] = jnp.zeros(shape, cfg.param_dtype)
        elif leaf in ("dt_bias",):
            node[leaf] = jnp.full(shape, -1.0, cfg.param_dtype)
        else:
            node[leaf] = dense_init(k, shape, cfg.param_dtype,
                                    in_axis=max(len(shape) - 2, 0))
    return out


def abstract_params(cfg: ModelConfig, rules: LogicalRules) -> Any:
    mod = module_for(cfg)
    shapes, specs = mod.param_shapes(cfg), mod.param_specs(cfg)

    def walk(sh, sp):
        if isinstance(sh, dict):
            return {k: walk(sh[k], sp[k]) for k in sh}
        return jax.ShapeDtypeStruct(sh, cfg.param_dtype,
                                    sharding=rules.sharding(*sp, dims=sh))

    return walk(shapes, specs)


def param_shardings(cfg: ModelConfig, rules: LogicalRules) -> Any:
    mod = module_for(cfg)
    shapes, specs = mod.param_shapes(cfg), mod.param_specs(cfg)

    def walk(sh, sp):
        if isinstance(sh, dict):
            return {k: walk(sh[k], sp[k]) for k in sh}
        return rules.sharding(*sp, dims=sh)

    return walk(shapes, specs)


def forward(params, tokens, cfg: ModelConfig, rules: LogicalRules, **kw):
    return module_for(cfg).forward(params, tokens, cfg, rules, **kw)


def param_count(cfg: ModelConfig) -> int:
    shapes = module_for(cfg).param_shapes(cfg)
    import numpy as np

    return int(sum(np.prod(s) for _, s in _walk_flat(shapes)))
