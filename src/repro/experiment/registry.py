"""Policy registry: names -> deferred policy constructors.

Every policy in the paper's evaluation (§6.1, §6.7) plus the beyond-paper
MPC variant registers here.  Construction is *deferred*: a builder receives
a :class:`PolicyContext` carrying the runtime objects policies need — the
learned :class:`KnowledgeBase` for CarbonFlex, the completed-job history
for the MPC warm start, the mean historical length the paper grants every
baseline, the oracle backend — so drivers resolve ``"carbonflex"`` to a
ready instance instead of hand-wiring each constructor.

Register additional policies with :func:`register_policy`::

    @register_policy("my-policy", description="...")
    def _build(ctx: PolicyContext) -> Policy:
        return MyPolicy(...)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core import baselines
from repro.core.carbon import CarbonService, MultiRegionCarbonService
from repro.core.dag import DagCapPolicy, DagCarbonPolicy, DagFcfsPolicy
from repro.core.geo import GeoFlexPolicy, GeoGreedyPolicy, GeoStaticPolicy
from repro.core.knowledge import KnowledgeBase
from repro.core.mpc import MPCConfig
from repro.core.policy import (CarbonFlexMPCPolicy, CarbonFlexPolicy,
                               CarbonFlexScalePolicy, EstimatedOraclePolicy,
                               OraclePolicy, Policy)
from repro.core.types import ClusterConfig, GeoCluster, Job
from repro.serving import (ServeFlexPolicy, ServeGreedyPolicy,
                           ServeStaticPolicy)


@dataclasses.dataclass
class PolicyContext:
    """Runtime context handed to deferred policy builders."""

    cluster: ClusterConfig
    ci: CarbonService
    history: list[Job] = dataclasses.field(default_factory=list)
    mean_length: float = 4.0
    utilization: float = 0.5
    kb: KnowledgeBase | None = None
    backend: str = "numpy"           # oracle backend for oracle/learning
    # quantile the `*-robust` policy variants threshold on (configurable
    # per experiment; 0.7 = mildly conservative upper band)
    forecast_quantile: float = 0.7
    # Geo-scenario context (None for single-region scenarios).
    mci: MultiRegionCarbonService | None = None
    geo: GeoCluster | None = None
    # MPC execution-phase knobs (Scenario.mpc); None = tuned defaults.
    mpc: MPCConfig | None = None

    def require_kb(self) -> KnowledgeBase:
        if self.kb is None:
            raise ValueError("policy requires a learned KnowledgeBase; "
                             "the driver must run the learning phase first")
        return self.kb


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """A registered policy: display name, builder, and the context it needs
    (drivers use the flags to decide what to prepare)."""

    name: str
    builder: Callable[[PolicyContext], Policy]
    needs_kb: bool = False
    needs_history: bool = False
    geo: bool = False                # runs on GeoCluster scenarios only
    dag: bool = False                # runs on Scenario(dag=...) only
    serve: bool = False              # runs on Scenario(serving=...) only
    description: str = ""


REGISTRY: dict[str, PolicySpec] = {}


def register_policy(name: str, *, needs_kb: bool = False,
                    needs_history: bool = False, geo: bool = False,
                    dag: bool = False, serve: bool = False,
                    description: str = ""):
    """Decorator registering a ``PolicyContext -> Policy`` builder.

    ``geo=True`` marks a policy implementing the ``GeoPolicy`` protocol:
    it runs only on scenarios with a ``regions`` axis.  ``dag=True`` marks
    a precedence-aware policy: it runs only on ``Scenario(dag=...)``
    workloads.  ``serve=True`` marks a request-serving policy
    (``repro.serving``): it runs only on ``Scenario(serving=...)``
    workloads.  The driver/sweep reject mixing scenario kinds and policy
    families (:func:`check_scenario_policies`)."""

    def deco(builder: Callable[[PolicyContext], Policy]):
        if name in REGISTRY:
            raise ValueError(f"policy {name!r} is already registered")
        REGISTRY[name] = PolicySpec(name=name, builder=builder,
                                    needs_kb=needs_kb,
                                    needs_history=needs_history,
                                    geo=geo, dag=dag, serve=serve,
                                    description=description)
        return builder

    return deco


def get_spec(name: str) -> PolicySpec:
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; registered policies: "
                         f"{', '.join(sorted(REGISTRY))}") from None


def make_policy(name: str, ctx: PolicyContext) -> Policy:
    """Construct a fresh policy instance (policies are stateful — one
    instance per simulation case)."""
    return get_spec(name).builder(ctx)


def available_policies() -> tuple[str, ...]:
    return tuple(REGISTRY)


def needs_kb(names) -> bool:
    return any(get_spec(n).needs_kb for n in names)


def check_scenario_policies(names, is_geo: bool, is_dag: bool = False,
                            is_serving: bool = False) -> None:
    """Reject policies whose family does not match the scenario kind
    (single-region batch / geo / DAG / serving are mutually exclusive
    workload axes)."""
    for n in names:
        spec = get_spec(n)
        if spec.serve and not is_serving:
            raise ValueError(
                f"policy {n!r} routes interactive requests; give the "
                f"Scenario a serving workload (serving=ServingConfig())")
        if not spec.serve and is_serving:
            raise ValueError(
                f"policy {n!r} schedules batch jobs; a serving scenario "
                f"runs the serve policy family (serve-static/serve-greedy/"
                f"serve-flex) — drop Scenario.serving for batch studies")
        if spec.geo and not is_geo:
            raise ValueError(
                f"policy {n!r} is geo-distributed; give the Scenario a "
                f"regions axis (e.g. regions=('california', 'ontario'))")
        if not spec.geo and is_geo:
            raise ValueError(
                f"policy {n!r} is single-region; a geo scenario runs geo "
                f"policies (e.g. geo-static/geo-greedy/geo-flex) — drop "
                f"Scenario.regions for single-region studies")
        if spec.dag and not is_dag:
            raise ValueError(
                f"policy {n!r} is precedence-aware; give the Scenario a "
                f"DAG workload (e.g. dag=DagConfig())")
        if not spec.dag and is_dag:
            raise ValueError(
                f"policy {n!r} assumes independent jobs; a DAG scenario "
                f"runs the dag policy family (dag-fcfs/dag-carbon/dag-cap) "
                f"— drop Scenario.dag for independent-job studies")


# --- the nine §6 policies ---------------------------------------------------


@register_policy("carbon-agnostic",
                 description="status quo: FCFS, run immediately, no elasticity")
def _carbon_agnostic(ctx: PolicyContext) -> Policy:
    return baselines.CarbonAgnosticPolicy()


@register_policy("gaia",
                 description="GAIA lowest-CI-window start-time selection")
def _gaia(ctx: PolicyContext) -> Policy:
    return baselines.GaiaPolicy(mean_length=ctx.mean_length)


@register_policy("wait-awhile",
                 description="suspend/resume on the 30th-percentile CI threshold")
def _wait_awhile(ctx: PolicyContext) -> Policy:
    return baselines.WaitAwhilePolicy()


@register_policy("wait-awhile-robust",
                 description="wait-awhile thresholding on a conservative "
                             "forecast quantile instead of the point "
                             "forecast (forecast-error robust)")
def _wait_awhile_robust(ctx: PolicyContext) -> Policy:
    return baselines.RobustWaitAwhilePolicy(quantile=ctx.forecast_quantile)


@register_policy("carbonscaler",
                 description="per-job elastic CarbonScaler plans, cluster-reconciled")
def _carbonscaler(ctx: PolicyContext) -> Policy:
    return baselines.CarbonScalerPolicy(mean_length=ctx.mean_length)


@register_policy("vcc", description="Google VCC capacity shaping, FCFS")
def _vcc(ctx: PolicyContext) -> Policy:
    return baselines.VCCPolicy(utilization=ctx.utilization)


@register_policy("vcc-scaling",
                 description="VCC capacity shaping + elastic filling")
def _vcc_scaling(ctx: PolicyContext) -> Policy:
    return baselines.VCCPolicy(scaling=True, utilization=ctx.utilization)


@register_policy("carbonflex", needs_kb=True,
                 description="CarbonFlex KNN execution phase (Algorithms 2+3)")
def _carbonflex(ctx: PolicyContext) -> Policy:
    return CarbonFlexPolicy(ctx.require_kb())


@register_policy("carbonflex-robust", needs_kb=True,
                 description="carbonflex with Table-2 forecast features "
                             "computed on a conservative forecast quantile "
                             "(forecast-error robust)")
def _carbonflex_robust(ctx: PolicyContext) -> Policy:
    return CarbonFlexPolicy(ctx.require_kb(),
                            forecast_quantile=ctx.forecast_quantile,
                            name="carbonflex-robust")


@register_policy("carbonflex-mpc", needs_kb=True, needs_history=True,
                 description="receding-horizon execution phase: run each "
                             "job in its estimated-need cheapest forecast "
                             "slots (beyond paper; core/mpc.py)")
def _carbonflex_mpc(ctx: PolicyContext) -> Policy:
    cfg = ctx.mpc or MPCConfig()
    if cfg.horizon == 0:
        # no look-ahead degenerates to the KNN execution phase exactly —
        # a bit-identity pinned by tests/test_mpc.py
        return CarbonFlexPolicy(ctx.require_kb(), name="carbonflex-mpc")
    pol = CarbonFlexMPCPolicy(cfg=cfg)
    if ctx.history:
        pol.warm_start(ctx.history)
    return pol


@register_policy("carbonflex-scale", needs_kb=True, needs_history=True,
                 description="carbonflex-mpc + CarbonScaler marginal-"
                             "capacity scale-up in clean forecast windows "
                             "(rho learned from the KB's oracle curve)")
def _carbonflex_scale(ctx: PolicyContext) -> Policy:
    cfg = ctx.mpc or MPCConfig()
    pol = CarbonFlexScalePolicy(cfg=cfg, kb=ctx.require_kb())
    if ctx.history:
        pol.warm_start(ctx.history)
    return pol


@register_policy("oracle",
                 description="Algorithm 1 with full future knowledge (upper bound)")
def _oracle(ctx: PolicyContext) -> Policy:
    return OraclePolicy(backend=ctx.backend)


@register_policy("oracle-estimated", needs_history=True,
                 description="Algorithm 1 with perfect CI but learned "
                             "per-queue length estimates — separates "
                             "timing skill from length clairvoyance in "
                             "OracleGap")
def _oracle_estimated(ctx: PolicyContext) -> Policy:
    cfg = ctx.mpc or MPCConfig()
    pol = EstimatedOraclePolicy(cfg=cfg, backend=ctx.backend)
    if ctx.history:
        pol.warm_start(ctx.history)
    return pol


# --- geo-distributed policies ------------------------------------------------


@register_policy("geo-static", geo=True,
                 description="jobs pinned to their arrival region, FCFS "
                             "(the spatial status quo)")
def _geo_static(ctx: PolicyContext) -> Policy:
    return GeoStaticPolicy()


@register_policy("geo-greedy", geo=True,
                 description="admit each job to the currently cleanest "
                             "region with free capacity; sticky placement")
def _geo_greedy(ctx: PolicyContext) -> Policy:
    return GeoGreedyPolicy()


@register_policy("geo-flex", geo=True,
                 description="per-region CI-rank suspend/resume + "
                             "suspend-migrate-resume when the forecast gap "
                             "beats the migration carbon cost")
def _geo_flex(ctx: PolicyContext) -> Policy:
    return GeoFlexPolicy()


# --- precedence-aware DAG policies -------------------------------------------


@register_policy("dag-fcfs", dag=True,
                 description="precedence-only baseline: FCFS over ready "
                             "tasks, no carbon awareness")
def _dag_fcfs(ctx: PolicyContext) -> Policy:
    return DagFcfsPolicy()


@register_policy("dag-carbon", dag=True,
                 description="CarbonFlex-style CI-rank suspend/resume "
                             "applied per ready task (the per-job carbon "
                             "scheduler on DAG structure)")
def _dag_carbon(ctx: PolicyContext) -> Policy:
    return DagCarbonPolicy()


@register_policy("dag-cap", dag=True,
                 description="PCAPS-style criticality: critical-path tasks "
                             "exempt from suspension, slack tasks deferred "
                             "into clean windows")
def _dag_cap(ctx: PolicyContext) -> Policy:
    return DagCapPolicy()


# --- request-serving policies (repro.serving) --------------------------------


@register_policy("serve-static", serve=True,
                 description="all requests on the full-precision tier "
                             "(the serving status quo)")
def _serve_static(ctx: PolicyContext):
    return ServeStaticPolicy()


@register_policy("serve-greedy", serve=True,
                 description="current-CI percentile threshold: degrade "
                             "above p70 of the day-ahead forecast, repay "
                             "below p30, ledger-bounded")
def _serve_greedy(ctx: PolicyContext):
    return ServeGreedyPolicy()


@register_policy("serve-flex", serve=True,
                 description="forecast-aware-global: CI trend + demand "
                             "forecast + quantile look-ahead + emissions "
                             "budget, weighted and ledger-scaled")
def _serve_flex(ctx: PolicyContext):
    return ServeFlexPolicy(quantile=ctx.forecast_quantile)
