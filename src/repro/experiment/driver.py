"""The experiment driver: one call from ``Scenario`` to per-policy results.

``run()`` owns the continuous-learning loop of §4.2 that the examples used
to copy-paste: replay the historical weeks through the offline oracle into
a rolling :class:`KnowledgeBase` (one replay offset per week), construct
every requested policy through the registry, evaluate each week through
``simulate_many`` (one batched dispatch per week, jobs packed once), then
re-learn on the week just evaluated and warm-start history-driven policies
before the next — the violation-feedback loop of Algorithm 2 running
inside the policies across the whole span.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.knowledge import KnowledgeBase
from repro.core.policy import learn_window
from repro.core.simulator import SimCase, simulate_many
from repro.core.types import SimResult
from repro.serving import ServeCase, simulate_serving_many
from repro.telemetry import Telemetry

from .registry import (PolicyContext, check_scenario_policies, get_spec,
                       make_policy, needs_kb)
from .scenario import WEEK, MaterializedScenario, Scenario

#: The §6.1 comparison set (VCC joins only in the Fig. 14 interop study).
DEFAULT_POLICIES: tuple[str, ...] = (
    "carbon-agnostic", "gaia", "wait-awhile", "carbonscaler",
    "carbonflex", "carbonflex-mpc", "oracle",
)

#: The geo-distributed comparison set (scenarios with a ``regions`` axis).
DEFAULT_GEO_POLICIES: tuple[str, ...] = (
    "geo-static", "geo-greedy", "geo-flex",
)

#: The precedence-aware comparison set (scenarios with a DAG workload).
DEFAULT_DAG_POLICIES: tuple[str, ...] = (
    "dag-fcfs", "dag-carbon", "dag-cap",
)

#: The request-serving comparison set (scenarios with a serving workload).
DEFAULT_SERVE_POLICIES: tuple[str, ...] = (
    "serve-static", "serve-greedy", "serve-flex",
)


def prepare_context(
    mat: MaterializedScenario,
    policies: Sequence[str],
    kb_kwargs: dict | None = None,
    backend: str = "numpy",
    forecast_quantile: float = 0.7,
) -> PolicyContext:
    """Build the :class:`PolicyContext` for a materialized scenario,
    running the initial learning phase when any requested policy needs the
    knowledge base.  ``forecast_quantile`` is the band the ``*-robust``
    policy variants threshold on."""
    kb = None
    if needs_kb(policies):
        kb = KnowledgeBase(**(kb_kwargs or {}))
        learn_window(kb, mat.hist, mat.ci, 0, WEEK, mat.cluster,
                     offsets=mat.scenario.learn_offsets(), backend=backend)
    return PolicyContext(
        cluster=mat.cluster, ci=mat.ci, history=list(mat.hist),
        mean_length=mat.mean_length, utilization=mat.scenario.utilization,
        kb=kb, backend=backend, mci=mat.mci, geo=mat.geo,
        forecast_quantile=forecast_quantile, mpc=mat.scenario.mpc)


def _fresh_faults(scenario: Scenario):
    """Fault injection is stateful (seeded RNG stream) — every simulation
    case gets its own instance reset to the configured seed."""
    if scenario.faults is None:
        return None
    return dataclasses.replace(scenario.faults)


@dataclasses.dataclass
class ExperimentResult:
    """Per-policy results of one scenario run (one ``SimResult`` per
    evaluated week, aggregates over the whole span)."""

    scenario: Scenario
    policies: tuple[str, ...]
    weekly: dict[str, list[SimResult]]
    kb_size: int
    runtime_s: float

    # --- aggregates ---------------------------------------------------------

    def carbon_g(self, policy: str) -> float:
        return float(sum(r.carbon_g for r in self.weekly[policy]))

    def energy_kwh(self, policy: str) -> float:
        return float(sum(r.energy_kwh for r in self.weekly[policy]))

    def mean_wait(self, policy: str) -> float:
        waits = np.concatenate([r.wait_slots for r in self.weekly[policy]]) \
            if self.weekly[policy] else np.zeros(0)
        return float(waits.mean()) if len(waits) else 0.0

    def violation_rate(self, policy: str) -> float:
        rs = self.weekly[policy]
        if rs and rs[0].serving is not None:
            # serving runs: request-weighted SLO-violation rate
            req = sum(r.serving.requests for r in rs)
            if req <= 0:
                return 0.0
            return float(sum(r.serving.violated_requests for r in rs) / req)
        v = np.concatenate([r.violations for r in rs]) \
            if rs else np.zeros(0, dtype=bool)
        return float(v.mean()) if len(v) else 0.0

    def quality_mean(self, policy: str) -> float:
        """Request-weighted served quality (serving runs; 1.0 otherwise)."""
        rs = self.weekly[policy]
        if not rs or rs[0].serving is None:
            return 1.0
        req = sum(r.serving.requests for r in rs)
        if req <= 0:
            return 1.0
        return float(sum(r.serving.quality_mean * r.serving.requests
                         for r in rs) / req)

    def savings(self, policy: str, baseline: str | None = None) -> float:
        """Carbon savings (%) of ``policy`` vs ``baseline`` in this run
        (default: carbon-agnostic, or geo-static on geo runs)."""
        baseline = self._baseline(baseline)
        if baseline is None:
            return 0.0
        base = self.carbon_g(baseline)
        if base <= 0:
            return 0.0
        return 100.0 * (1.0 - self.carbon_g(policy) / base)

    # --- presentation / serialization ---------------------------------------

    def _baseline(self, baseline: str | None) -> str | None:
        """Resolve the comparison baseline: an explicit name must be part
        of the run (typos raise, consistently across savings/metrics/
        table); the default falls back to the status-quo policy of the
        run's kind, or None when neither ran."""
        if baseline is not None:
            if baseline not in self.weekly:
                raise KeyError(
                    f"baseline {baseline!r} was not part of this run; "
                    f"policies: {', '.join(self.weekly)}")
            return baseline
        for cand in ("carbon-agnostic", "geo-static", "dag-fcfs",
                     "serve-static"):
            if cand in self.weekly:
                return cand
        return None

    def metrics(self, baseline: str | None = None) -> dict[str, dict]:
        """Per-policy metric dicts (the shape the figure benchmarks cache)."""
        base = self._baseline(baseline)
        out = {}
        for name in self.policies:
            m = {
                "carbon_g": self.carbon_g(name),
                "energy_kwh": self.energy_kwh(name),
                "mean_wait_h": self.mean_wait(name),
                "violation_rate": self.violation_rate(name),
            }
            rs = self.weekly[name]
            if rs and rs[0].serving is not None:
                m["quality_mean"] = round(self.quality_mean(name), 5)
                m["ledger_final"] = round(rs[-1].serving.ledger_final, 4)
            if base:
                m["savings_pct"] = round(self.savings(name, base), 2)
            out[name] = m
        return out

    def table(self, baseline: str | None = None) -> str:
        """Human-readable comparison table (the quickstart report)."""
        base = self._baseline(baseline)
        lines = [f"{'policy':18s} {'carbon kg':>10s} {'savings':>8s} "
                 f"{'wait h':>7s} {'viol':>6s}"]
        for name in self.policies:
            sv = f"{self.savings(name, base):7.1f}%" if base else " " * 8
            lines.append(
                f"{name:18s} {self.carbon_g(name) / 1e3:10.1f} {sv} "
                f"{self.mean_wait(name):7.1f} {self.violation_rate(name):6.3f}")
        return "\n".join(lines)

    def to_dict(self, baseline: str | None = None) -> dict:
        return {
            "scenario": self.scenario.to_dict(),
            "kb_size": self.kb_size,
            "runtime_s": round(self.runtime_s, 3),
            "policies": self.metrics(baseline),
        }


def run(
    scenario: Scenario,
    policies: Sequence[str] | None = None,
    *,
    kb_kwargs: dict | None = None,
    backend: str = "numpy",
    forecast_quantile: float = 0.7,
    progress: Callable[[str], None] | None = None,
    telemetry: Telemetry | None = None,
) -> ExperimentResult:
    """Run ``scenario`` under the named policies (registry names).

    Evaluation week by week: simulate all policies on the week's arrivals
    (one ``simulate_many`` dispatch — the week's jobs are packed once and
    shared across policies), then fold the week back into the learning
    state for the next (rolling KB window + MPC history warm start).
    ``kb_kwargs`` forwards to :class:`KnowledgeBase` (e.g. ``max_windows``
    for the aging window, feature weights for tuning studies).
    ``telemetry`` (README §Observability) attaches a decision-trace
    recorder and/or phase profiler: every engine dispatch records under a
    ``"{policy}/w{week}"`` run label, and the learning/provisioning work
    here brackets the profiler's ``learn``/``provision`` phases.  The
    default ``None`` leaves every engine on its untouched zero-overhead
    path.
    """
    if policies is None:
        policies = (DEFAULT_GEO_POLICIES if scenario.is_geo
                    else DEFAULT_DAG_POLICIES if scenario.is_dag
                    else DEFAULT_SERVE_POLICIES if scenario.is_serving
                    else DEFAULT_POLICIES)
    names = tuple(policies)
    check_scenario_policies(names, scenario.is_geo, scenario.is_dag,
                            scenario.is_serving)
    t_start = time.perf_counter()
    prof = telemetry.profiler if telemetry is not None else None
    if prof is not None:
        with prof.phase("provision"):
            mat = scenario.materialize()
        with prof.phase("learn"):
            ctx = prepare_context(mat, names, kb_kwargs=kb_kwargs,
                                  backend=backend,
                                  forecast_quantile=forecast_quantile)
    else:
        mat = scenario.materialize()
        ctx = prepare_context(mat, names, kb_kwargs=kb_kwargs,
                              backend=backend,
                              forecast_quantile=forecast_quantile)
    instances = {n: make_policy(n, ctx) for n in names}
    weekly: dict[str, list[SimResult]] = {n: [] for n in names}

    if scenario.is_serving:
        # Serving evaluation: week-sliced demand through the serving
        # engine (no learning loop — there is no knowledge base to roll;
        # each week starts a fresh ledger, the debt/credit carry being a
        # per-window contract).
        for w in range(scenario.eval_weeks):
            t0 = mat.t0 + w * WEEK
            cases = [ServeCase(demand=mat.serving.demand[t0: t0 + WEEK],
                               rate=mat.serving.rate, ci=mat.ci,
                               config=mat.serving.config,
                               policy=instances[n], t0=t0, label=n,
                               telemetry=telemetry.for_run(f"{n}/w{w}")
                               if telemetry is not None else None)
                     for n in names]
            for n, res in zip(names, simulate_serving_many(cases)):
                weekly[n].append(res)
            if progress is not None:
                agg = {n: sum(r.carbon_g for r in weekly[n]) for n in names}
                base = agg.get("serve-static")
                parts = [f"week {w + 1}/{scenario.eval_weeks}"]
                if base:
                    parts += [f"{n}={100 * (1 - c / base):.1f}%"
                              for n, c in agg.items() if n != "serve-static"]
                progress("  ".join(parts))
        return ExperimentResult(
            scenario=scenario, policies=names, weekly=weekly, kb_size=0,
            runtime_s=time.perf_counter() - t_start)

    for w in range(scenario.eval_weeks):
        t0 = mat.t0 + w * WEEK
        if w > 0:
            # continuous learning: replay the week just evaluated
            prev = [j for j in mat.jobs if t0 - WEEK <= j.arrival < t0]
            if ctx.kb is not None:
                if prof is not None:
                    with prof.phase("learn"):
                        learn_window(ctx.kb, mat.jobs, mat.ci, 0, WEEK,
                                     mat.cluster, offsets=(t0 - WEEK,),
                                     backend=backend)
                else:
                    learn_window(ctx.kb, mat.jobs, mat.ci, 0, WEEK,
                                 mat.cluster, offsets=(t0 - WEEK,),
                                 backend=backend)
            for n in names:
                if get_spec(n).needs_history and prev:
                    instances[n].warm_start(prev)
        ev = mat.eval_week(w)
        if not ev:
            continue
        ci_w = mat.mci if mat.is_geo else mat.ci
        cluster_w = mat.geo if mat.is_geo else mat.cluster
        cases = [SimCase(jobs=ev, ci=ci_w, cluster=cluster_w,
                         policy=instances[n], t0=t0, horizon=WEEK,
                         faults=_fresh_faults(scenario), label=n,
                         engine=scenario.engine,
                         telemetry=telemetry.for_run(f"{n}/w{w}")
                         if telemetry is not None else None)
                 for n in names]
        for n, res in zip(names, simulate_many(cases)):
            weekly[n].append(res)
        if progress is not None:
            agg = {n: sum(r.carbon_g for r in weekly[n]) for n in names}
            base = agg.get("carbon-agnostic")
            parts = [f"week {w + 1}/{scenario.eval_weeks}"]
            if ctx.kb is not None:
                parts.append(f"kb={len(ctx.kb)} cases")
            if base:
                parts += [f"{n}={100 * (1 - c / base):.1f}%"
                          for n, c in agg.items() if n != "carbon-agnostic"]
            progress("  ".join(parts))

    return ExperimentResult(
        scenario=scenario, policies=names, weekly=weekly,
        kb_size=len(ctx.kb) if ctx.kb is not None else 0,
        runtime_s=time.perf_counter() - t_start)
