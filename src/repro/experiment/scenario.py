"""Declarative scenario description for CarbonFlex experiments.

A ``Scenario`` names everything the paper's sweeps vary — region, trace
family, capacity, seed, learning/evaluation span, queue scaling, workload
elasticity, distribution shift, fault injection — and ``materialize()``
resolves it into the concrete ``(cluster, ci, jobs, hist/eval splits)``
every entry point used to hand-wire.

Materialization is cached on the instance: repeated calls return the *same*
job-list objects, so the simulator's pack cache (``simulator._packed_for``)
packs each scenario's jobs exactly once across a whole sweep.
"""
from __future__ import annotations

import dataclasses

from repro.core.carbon import (REGIONS, CarbonService,
                               MultiRegionCarbonService)
from repro.core.faults import (CarbonDataOutage, FaultProcess,
                               fault_from_dict, fault_to_dict,
                               outage_from_dict, outage_to_dict)
from repro.core.forecast import (ForecastModel, forecast_from_dict,
                                 forecast_to_dict)
from repro.core.mpc import MPCConfig
from repro.core.types import (ClusterConfig, GeoCluster, Job, MigrationModel,
                              QueueConfig, default_queues)
from repro.serving import MaterializedServing, ServingConfig
from repro.traces import (DagConfig, TraceSpec, dag_mean_task_length,
                          expected_request_rate, generate_dag_trace,
                          generate_request_demand, generate_trace,
                          mean_length)

WEEK = 24 * 7
# CI margin past the nominal trace so run-to-completion overruns stay
# on real (not padded) carbon data.
CI_MARGIN_HOURS = 24 * 30


@dataclasses.dataclass
class MaterializedScenario:
    """Concrete world resolved from a :class:`Scenario`."""

    scenario: "Scenario"
    cluster: ClusterConfig
    ci: CarbonService
    spec: TraceSpec
    jobs: list[Job]              # full trace (learning + evaluation weeks)
    hist: list[Job]              # arrivals in the learning weeks
    eval_jobs: list[Job]         # arrivals in the evaluation weeks
    t0: int                      # first evaluation slot
    mean_length: float
    # Geo-scenario extras (None for single-region scenarios).  ``ci`` then
    # aliases the first region's service, anchoring single-region
    # comparisons; ``cluster`` keeps the aggregate total capacity.
    mci: MultiRegionCarbonService | None = None
    geo: GeoCluster | None = None
    # Serving-scenario extras (None for batch scenarios): the serving
    # config + realized demand / expected-rate curves; the job lists are
    # then empty (interactive requests are never materialized per-request).
    serving: MaterializedServing | None = None

    @property
    def is_geo(self) -> bool:
        return self.geo is not None

    @property
    def is_serving(self) -> bool:
        return self.serving is not None

    @property
    def ev(self) -> list[Job]:
        """Alias kept for the historical ``build()`` tuple name."""
        return self.eval_jobs

    def eval_week(self, w: int) -> list[Job]:
        """Arrivals of evaluation week ``w`` (0-based)."""
        lo = self.t0 + w * WEEK
        return [j for j in self.eval_jobs if lo <= j.arrival < lo + WEEK]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One point of the paper's experiment space (Fig. 6-14 axes).

    ``seed`` drives both the CI trace (``seed``) and the workload trace
    (``seed + 1``), so a single integer reproduces the whole world.
    ``eval_shift`` regenerates the evaluation weeks from a +/-shifted
    length/rate distribution (the Fig. 13 learning/execution mismatch)
    while the learning weeks keep the unshifted trace.

    A non-empty ``regions`` tuple turns the scenario geo-distributed:
    ``capacity`` is split evenly across the regions (remainder to the
    first), aligned per-region CI traces are synthesized from the same
    seed, and ``materialize()`` additionally yields the ``GeoCluster`` /
    ``MultiRegionCarbonService`` pair the geo policies run on (``region``
    is then ignored).  ``migration`` overrides the default
    :class:`MigrationModel` cost knobs.

    ``forecast`` selects the carbon-forecast model every policy sees
    (``core/forecast.py``): ``None`` keeps the paper's accurate-day-ahead
    assumption (:class:`~repro.core.forecast.PerfectForecast`,
    bit-identical to the pre-subsystem behaviour); pass
    ``NoisyForecast``/``QuantileForecast``/``PersistenceForecast`` to
    stress robustness to forecast error.  The true trace (and hence the
    oracle, which reads it directly) is unaffected.

    A non-``None`` ``dag`` (:class:`repro.traces.DagConfig`) makes the
    workload precedence-aware: the trace generator emits whole DAG jobs
    (chains / map-reduce stages / random layered DAGs) expanded to tasks
    with ``Job.deps`` edges, the engines gate each task until its
    predecessors complete, and the ``dag-*`` policy family applies.
    ``DagConfig(independent=True)`` generates the same tasks with the
    edges stripped — the independent-task upper-bound twin.
    """

    region: str = "south-australia"
    regions: tuple[str, ...] = ()
    migration: MigrationModel | None = None
    dag: DagConfig | None = None        # DAG workload (precedence gating)
    # Forecast model policies see (core/forecast.py); None = PerfectForecast
    # (the paper's accurate-day-ahead assumption, bit-identical to before).
    forecast: ForecastModel | None = None
    family: str = "azure"
    capacity: int = 60
    utilization: float = 0.5
    learn_weeks: int = 3
    eval_weeks: int = 1
    seed: int = 7
    elasticity: str = "mix"          # "mix" | "high" | "moderate" | "low" | "none" | "tpu"
    mode: str = "cpu"                # "cpu" | "gpu"
    delay_scale: float = 1.0         # queue-slack scaling (Section 6.1 queues)
    length_scale: float = 1.0
    rate_scale: float = 1.0
    delay_override: int | None = None   # uniform slack d (Fig. 9 / Fig. 14)
    eval_shift: float = 0.0             # Fig. 13 distribution shift
    # Fault process injected into every run of the scenario (core/faults.py):
    # IidFaults (the historical FaultModel), CorrelatedFaults, or
    # PreemptionFaults.
    faults: FaultProcess | None = None
    # Carbon-feed outage injection (core/faults.py): the policies' CI view
    # goes stale/ffilled during outage windows while accounting stays true.
    ci_outage: CarbonDataOutage | None = None
    # Serving workload (repro.serving): a non-None ServingConfig turns the
    # scenario into an interactive request-serving world — per-slot demand
    # vectors routed across precision tiers by the serve-* policy family
    # instead of batch jobs.  Serving composes with `forecast` and
    # `ci_outage` (the policies read the same degraded CI views) but not
    # with `dag`, `regions`, or `faults`.
    serving: ServingConfig | None = None
    # Simulation engine every batch case of this scenario runs on:
    # "vector" (default), "scalar" (reference loop), or "scan" (jitted
    # lax.scan slot loop, core/scan_engine.py).  All three are bit-
    # identical; "scan" additionally fuses structurally identical cases
    # of a sweep into one vmapped device program.  Ignored by serving
    # scenarios (the serving engine has a single implementation).
    engine: str = "vector"
    # Receding-horizon execution-phase knobs (core/mpc.py) consumed by the
    # carbonflex-mpc / carbonflex-scale / oracle-estimated builders; None
    # keeps the tuned defaults (MPCConfig()).
    mpc: MPCConfig | None = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "regions", tuple(self.regions))
        if self.region not in REGIONS:
            raise ValueError(f"unknown region {self.region!r}; available "
                             f"regions: {', '.join(sorted(REGIONS))}")
        for r in self.regions:
            if r not in REGIONS:
                raise ValueError(f"unknown region {r!r}; available "
                                 f"regions: {', '.join(sorted(REGIONS))}")
        if self.regions and len(self.regions) < 2:
            raise ValueError("a geo scenario needs >= 2 regions; use "
                             "`region=` for single-region studies")
        if self.dag is not None and self.regions:
            raise ValueError("DAG scenarios are single-region (the geo "
                             "engines do not gate precedence yet); drop "
                             "either `dag` or `regions`")
        if self.learn_weeks < 1 or self.eval_weeks < 1:
            raise ValueError("learn_weeks and eval_weeks must be >= 1")
        if self.engine not in ("scalar", "vector", "scan"):
            raise ValueError(f"unknown engine {self.engine!r}; choose "
                             "'scalar', 'vector', or 'scan'")
        if self.serving is not None:
            if self.dag is not None:
                raise ValueError(
                    "serving scenarios carry no batch workload — a DAG has "
                    "nothing to schedule there; drop either `serving` or "
                    "`dag`")
            if self.regions:
                raise ValueError(
                    "serving scenarios are single-region (the serving "
                    "engine does not route across regions yet); drop "
                    "either `serving` or `regions`")
            if self.faults is not None:
                raise ValueError(
                    "serving scenarios do not take a batch fault process "
                    "(requests are never suspended or evicted); carbon-"
                    "feed outages via `ci_outage` are supported")

    @property
    def is_geo(self) -> bool:
        return bool(self.regions)

    @property
    def is_dag(self) -> bool:
        return self.dag is not None

    @property
    def is_serving(self) -> bool:
        return self.serving is not None

    # --- derived geometry ---------------------------------------------------

    @property
    def hours(self) -> int:
        return WEEK * (self.learn_weeks + self.eval_weeks)

    @property
    def t0(self) -> int:
        return WEEK * self.learn_weeks

    def learn_offsets(self) -> tuple[int, ...]:
        """Replay offsets for the initial learning phase: one per
        historical week (§5 'Continuous Learning')."""
        return tuple(WEEK * i for i in range(self.learn_weeks))

    def queues(self) -> tuple[QueueConfig, ...]:
        if self.delay_override is not None:
            return tuple(
                QueueConfig(q.name, max(self.delay_override, 0), q.max_length)
                for q in default_queues())
        return tuple(default_queues(self.delay_scale))

    def trace_spec(self, shifted: bool = False) -> TraceSpec:
        shift = self.eval_shift if shifted else 0.0
        return TraceSpec(
            family=self.family, hours=self.hours, capacity=self.capacity,
            utilization=self.utilization,
            seed=self.seed + 1 + (99 if shifted else 0),
            elasticity=self.elasticity, mode=self.mode,
            length_scale=self.length_scale * (1 + shift),
            rate_scale=self.rate_scale * (1 + shift))

    # --- materialization ----------------------------------------------------

    def materialize(self) -> MaterializedScenario:
        """Resolve to concrete (cluster, ci, jobs, splits); cached, so the
        same ``Scenario`` instance always yields the same job lists."""
        cached = self.__dict__.get("_materialized")
        if cached is not None:
            return cached
        cluster = ClusterConfig(capacity=self.capacity, queues=self.queues())
        mci = geo = None
        if self.is_geo:
            mci = MultiRegionCarbonService.synthetic(
                self.regions, self.hours + CI_MARGIN_HOURS, seed=self.seed,
                model=self.forecast, outage=self.ci_outage)
            geo = GeoCluster.split(self.capacity, self.regions,
                                   queues=self.queues(),
                                   migration=self.migration)
            ci = mci.service(0)
        else:
            ci = CarbonService.synthetic(self.region,
                                         self.hours + CI_MARGIN_HOURS,
                                         seed=self.seed,
                                         model=self.forecast,
                                         outage=self.ci_outage)
        spec = self.trace_spec()
        if self.serving is not None:
            # Serving worlds have no job trace: the workload is the
            # per-slot demand vector (seed + 2 keeps the request stream
            # independent of the CI trace (seed) and the batch-job stream
            # (seed + 1)); `rate` extends a day past the nominal span so
            # policy look-ahead near the window end stays on real data.
            sv = self.serving
            demand = generate_request_demand(
                self.hours, sv.requests_per_day, seed=self.seed + 2,
                diurnal=sv.diurnal, weekly=sv.weekly,
                peak_hour=sv.peak_hour, burst_rate=sv.burst_rate,
                burst_mult=sv.burst_mult,
                burst_mean_slots=sv.burst_mean_slots)
            rate = expected_request_rate(
                self.hours + 24, sv.requests_per_day, diurnal=sv.diurnal,
                weekly=sv.weekly, peak_hour=sv.peak_hour)
            mat = MaterializedScenario(
                scenario=self, cluster=cluster, ci=ci, spec=spec,
                jobs=[], hist=[], eval_jobs=[], t0=self.t0,
                mean_length=0.0,
                serving=MaterializedServing(config=sv, demand=demand,
                                            rate=rate))
            object.__setattr__(self, "_materialized", mat)
            return mat

        def _gen(s: TraceSpec) -> list[Job]:
            if self.dag is not None:
                return generate_dag_trace(s, self.dag, cluster.queues)
            return generate_trace(s, cluster.queues)

        jobs = _gen(spec)
        t0 = self.t0
        # Arrival-based splits keep DAGs whole: every task of a DAG
        # arrives at the DAG's slot (gating releases it later).
        hist = [j for j in jobs if j.arrival < t0]
        if self.eval_shift:
            shifted = _gen(self.trace_spec(shifted=True))
            eval_jobs = [j for j in shifted if t0 <= j.arrival < self.hours]
            jobs = hist + eval_jobs
        else:
            eval_jobs = [j for j in jobs if t0 <= j.arrival < self.hours]
        mat = MaterializedScenario(
            scenario=self, cluster=cluster, ci=ci, spec=spec, jobs=jobs,
            hist=hist, eval_jobs=eval_jobs, t0=t0,
            mean_length=(dag_mean_task_length(self.dag, self.length_scale)
                         if self.dag is not None else mean_length(spec)),
            mci=mci, geo=geo)
        object.__setattr__(self, "_materialized", mat)
        return mat

    # --- serialization ------------------------------------------------------

    def to_dict(self) -> dict:
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}
        d["regions"] = list(self.regions)
        d["faults"] = fault_to_dict(self.faults)
        d["ci_outage"] = outage_to_dict(self.ci_outage)
        if self.migration is not None:
            d["migration"] = dataclasses.asdict(self.migration)
        if self.dag is not None:
            d["dag"] = {**dataclasses.asdict(self.dag),
                        "shapes": list(self.dag.shapes)}
        d["forecast"] = forecast_to_dict(self.forecast)
        if self.serving is not None:
            d["serving"] = dataclasses.asdict(self.serving)
        if self.mpc is not None:
            d["mpc"] = self.mpc.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        d = dict(d)
        d["regions"] = tuple(d.get("regions", ()))
        if d.get("faults"):
            d["faults"] = fault_from_dict(d["faults"])
        else:
            d.pop("faults", None)
        if d.get("ci_outage"):
            d["ci_outage"] = outage_from_dict(d["ci_outage"])
        else:
            d.pop("ci_outage", None)
        if d.get("migration"):
            d["migration"] = MigrationModel(**d["migration"])
        if d.get("dag"):
            d["dag"] = DagConfig(**d["dag"])
        if d.get("forecast"):
            d["forecast"] = forecast_from_dict(d["forecast"])
        if d.get("serving"):
            d["serving"] = ServingConfig(**d["serving"])
        if d.get("mpc"):
            d["mpc"] = MPCConfig.from_dict(d["mpc"])
        else:
            d.pop("mpc", None)
        return cls(**d)

    def to_json(self, indent: int | None = None) -> str:
        """JSON form of :meth:`to_dict` (round-trips every fault kind)."""
        import json

        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, payload: str) -> "Scenario":
        """Inverse of :meth:`to_json`; unknown fault kinds raise a
        ``ValueError`` naming the registered kinds."""
        import json

        return cls.from_dict(json.loads(payload))
