"""Cartesian experiment sweeps over ``simulate_many`` (Fig. 6-14 style).

A :class:`Sweep` expands a grid — (regions x seeds x faults x policies)
around a base :class:`Scenario` — into :class:`SimCase` s and dispatches
them through ``simulate_many`` in a single batch: each scenario's jobs are
materialized and packed exactly once (the pack cache keys on the job-list
object, which ``Scenario.materialize`` keeps stable), and each scenario's
knowledge base is learned exactly once and shared read-only across its
policies and fault settings.

:class:`SweepResult` aggregates the batch: per-case rows with carbon
savings against a named baseline policy, per-policy summaries with
cross-(region, seed) dispersion, and a JSON round-trip (``to_json`` /
``from_json``) for benchmark caches and plotting.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Sequence

import numpy as np

from repro.core.faults import FaultProcess, fault_label  # noqa: F401  (re-export)
from repro.core.forecast import ForecastModel, forecast_labels
from repro.core.simulator import SimCase, simulate_many
from repro.core.types import SimResult
from repro.serving import ServeCase, simulate_serving_many
from repro.telemetry import Attribution, Telemetry, attribute

from .driver import DEFAULT_POLICIES, _fresh_faults, prepare_context
from .registry import check_scenario_policies, make_policy
from .scenario import WEEK, Scenario


@dataclasses.dataclass
class Sweep:
    """A cartesian grid of scenarios x policies, run as one batch.

    ``regions`` / ``seeds`` default to the base scenario's single values;
    ``faults`` is an explicit fault axis (``None`` entry = fault-free) —
    when omitted it defaults to the base scenario's own fault model.
    ``forecasts`` is a forecast-model axis (``None`` entry = perfect
    forecast); rows then carry a ``"forecast"`` label and savings compare
    within the same forecast model.  ``baseline`` names the policy
    savings are measured against — it is added to the run automatically
    if missing.  The base scenario's ``engine`` selects the simulation
    engine for every cell (``engine="scan"`` additionally fuses
    structurally identical cells into vmapped device programs — the
    fastest way to run large grids).

    Geo sweeps: when the base scenario carries a ``regions`` tuple the
    whole grid is geo-distributed — the sweep's own single-region
    ``regions`` axis must stay empty (vary geo worlds via ``seeds`` or
    several sweeps), the policies must be geo policies, and the default
    baseline becomes ``geo-static``.  Row metadata joins the region tuple
    as ``"a+b"``.

    Unlike :func:`repro.experiment.run`, a sweep evaluates each scenario
    as a *single* window of ``eval_weeks`` weeks against the initially
    learned knowledge base — the weekly §4.2 re-learning loop is the
    driver's job; use ``run()`` per scenario when that is the semantics
    under study.
    """

    base: Scenario = dataclasses.field(default_factory=Scenario)
    regions: Sequence[str] = ()
    seeds: Sequence[int] = ()
    policies: Sequence[str] = DEFAULT_POLICIES
    faults: Sequence[FaultProcess | None] | None = None
    # Forecast-model grid axis (ISSUE 5): each entry replaces the base
    # scenario's `forecast` (None = PerfectForecast), e.g. a
    # forecast-model x sigma grid `[None, NoisyForecast(sigma=0.1),
    # NoisyForecast(sigma=0.2), QuantileForecast(sigma=0.2)]`.  Rows gain
    # a "forecast" label column only when the axis is in play, keeping
    # pre-forecast sweep payloads (and their golden fixtures) unchanged.
    forecasts: Sequence[ForecastModel | None] | None = None
    # quantile the *-robust policy variants threshold on
    forecast_quantile: float = 0.7
    baseline: str = "carbon-agnostic"
    backend: str = "numpy"
    kb_kwargs: dict | None = None
    # Observability (README §Observability): when set, every cell runs
    # with this telemetry's recorder/profiler attached, each under its
    # own run label (the case label), so one sweep yields one decision
    # trace per cell plus learn/provision/decide/execute phase totals.
    # ``None`` (the default) keeps every engine on its untouched path.
    telemetry: Telemetry | None = None

    def fault_axis(self) -> tuple[FaultProcess | None, ...]:
        if self.faults is None:
            return (self.base.faults,)
        return tuple(self.faults)

    def forecast_axis(self) -> tuple[ForecastModel | None, ...]:
        if self.forecasts is None:
            return (self.base.forecast,)
        return tuple(self.forecasts)

    def has_forecast_axis(self) -> bool:
        return self.forecasts is not None or self.base.forecast is not None

    def effective_baseline(self) -> str:
        """The status-quo policy of the grid's kind replaces the
        single-region default on geo / DAG / serving grids."""
        if self.base.is_geo and self.baseline == "carbon-agnostic":
            return "geo-static"
        if self.base.is_dag and self.baseline == "carbon-agnostic":
            return "dag-fcfs"
        if self.base.is_serving and self.baseline == "carbon-agnostic":
            return "serve-static"
        return self.baseline

    def scenarios(self) -> list[Scenario]:
        seeds = tuple(self.seeds) or (self.base.seed,)
        if self.base.is_geo:
            if tuple(self.regions):
                raise ValueError(
                    "a geo base scenario fixes the region tuple; sweep the "
                    "seeds axis (or run one sweep per region tuple) instead "
                    "of the single-region regions axis")
            bases = [dataclasses.replace(self.base, seed=s) for s in seeds]
        else:
            regions = tuple(self.regions) or (self.base.region,)
            bases = [dataclasses.replace(self.base, region=r, seed=s)
                     for r in regions for s in seeds]
        return [dataclasses.replace(b, forecast=f)
                for b in bases for f in self.forecast_axis()]

    def _policy_names(self) -> tuple[str, ...]:
        names = tuple(self.policies)
        baseline = self.effective_baseline()
        if baseline not in names:
            names = (baseline,) + names
        check_scenario_policies(names, self.base.is_geo, self.base.is_dag,
                                self.base.is_serving)
        return names

    def run(self, progress: Callable[[str], None] | None = None) -> "SweepResult":
        names = self._policy_names()
        baseline = self.effective_baseline()
        with_forecast = self.has_forecast_axis()
        if self.base.is_serving:
            return self._run_serving(names, baseline, with_forecast,
                                     progress)
        # Disambiguated per-axis-entry labels (e.g. two NoisyForecasts of
        # equal sigma but different seed -> "noisy(s=0.2)"/"noisy(s=0.2)#2")
        # so the per-cell savings grouping below cannot merge distinct
        # models.  scenarios() expands bases x forecast axis with the
        # forecast as the innermost loop, so the labels tile in order.
        axis_labels = forecast_labels(self.forecast_axis())
        scenarios = self.scenarios()
        # an explicitly empty forecasts axis yields zero scenarios, like
        # faults=[] yields zero rows — nothing to tile then
        assert not axis_labels or len(scenarios) % len(axis_labels) == 0
        cases: list[SimCase] = []
        meta: list[dict] = []
        prof = self.telemetry.profiler if self.telemetry is not None else None
        for i, sc in enumerate(scenarios):
            if prof is not None:
                with prof.phase("provision"):
                    mat = sc.materialize()
            else:
                mat = sc.materialize()
            region_label = "+".join(sc.regions) if sc.is_geo else sc.region
            fc_label = axis_labels[i % len(axis_labels)]
            if prof is not None:
                with prof.phase("learn"):
                    ctx = prepare_context(
                        mat, names, kb_kwargs=self.kb_kwargs,
                        backend=self.backend,
                        forecast_quantile=self.forecast_quantile)
            else:
                ctx = prepare_context(mat, names, kb_kwargs=self.kb_kwargs,
                                      backend=self.backend,
                                      forecast_quantile=self.forecast_quantile)
            if progress is not None:
                progress(f"prepared {region_label}/seed{sc.seed}"
                         + (f"/{fc_label}" if with_forecast else "")
                         + f": {len(mat.eval_jobs)} eval jobs"
                         + (f", kb={len(ctx.kb)}" if ctx.kb is not None else ""))
            horizon = sc.eval_weeks * WEEK
            ci_c = mat.mci if mat.is_geo else mat.ci
            cluster_c = mat.geo if mat.is_geo else mat.cluster
            for fm in self.fault_axis():
                scf = dataclasses.replace(sc, faults=fm)
                for name in names:
                    label = (f"{region_label}/s{sc.seed}/{fault_label(fm)}"
                             f"/{name}"
                             + (f"/{fc_label}" if with_forecast else ""))
                    cases.append(SimCase(
                        jobs=mat.eval_jobs, ci=ci_c, cluster=cluster_c,
                        policy=make_policy(name, ctx), t0=mat.t0,
                        horizon=horizon, faults=_fresh_faults(scf),
                        engine=sc.engine, label=label,
                        telemetry=self.telemetry.for_run(label)
                        if self.telemetry is not None else None))
                    row = {"region": region_label, "seed": sc.seed,
                           "fault": fault_label(fm), "policy": name}
                    if with_forecast:
                        row["forecast"] = fc_label
                    meta.append(row)
        results = simulate_many(cases)       # one batched dispatch
        rows = []
        for m, r in zip(meta, results):
            rows.append({**m, **r.to_dict()})
        _attach_savings(rows, baseline)
        return SweepResult(baseline=baseline, rows_=rows,
                           results=results)

    def _run_serving(self, names, baseline: str, with_forecast: bool,
                     progress) -> "SweepResult":
        """Serving grids: same (regions x seeds x forecasts x policies)
        expansion, dispatched through ``simulate_serving_many`` instead of
        the batch engine.  The fault axis stays batch-only (requests are
        never suspended); Scenario validation already rejects base faults,
        so only an explicit sweep axis needs rejecting here."""
        if self.faults is not None and any(f is not None
                                           for f in self.faults):
            raise ValueError(
                "serving sweeps take no fault axis (requests are never "
                "suspended or evicted); use `forecasts` or a base "
                "`ci_outage` to stress serving policies")
        axis_labels = forecast_labels(self.forecast_axis())
        scenarios = self.scenarios()
        assert not axis_labels or len(scenarios) % len(axis_labels) == 0
        cases: list[ServeCase] = []
        meta: list[dict] = []
        prof = self.telemetry.profiler if self.telemetry is not None else None
        for i, sc in enumerate(scenarios):
            if prof is not None:
                with prof.phase("provision"):
                    mat = sc.materialize()
            else:
                mat = sc.materialize()
            fc_label = axis_labels[i % len(axis_labels)]
            if prof is not None:
                with prof.phase("learn"):
                    ctx = prepare_context(
                        mat, names, kb_kwargs=self.kb_kwargs,
                        backend=self.backend,
                        forecast_quantile=self.forecast_quantile)
            else:
                ctx = prepare_context(mat, names, kb_kwargs=self.kb_kwargs,
                                      backend=self.backend,
                                      forecast_quantile=self.forecast_quantile)
            horizon = sc.eval_weeks * WEEK
            demand = mat.serving.demand[mat.t0: mat.t0 + horizon]
            if progress is not None:
                progress(f"prepared {sc.region}/seed{sc.seed}"
                         + (f"/{fc_label}" if with_forecast else "")
                         + f": {len(demand)} slots, "
                         f"{demand.sum() / 1e6:.2f}M requests")
            for name in names:
                label = (f"{sc.region}/s{sc.seed}/{name}"
                         + (f"/{fc_label}" if with_forecast else ""))
                cases.append(ServeCase(
                    demand=demand, rate=mat.serving.rate, ci=mat.ci,
                    config=mat.serving.config,
                    policy=make_policy(name, ctx), t0=mat.t0, label=label,
                    telemetry=self.telemetry.for_run(label)
                    if self.telemetry is not None else None))
                row = {"region": sc.region, "seed": sc.seed,
                       "fault": "none", "policy": name}
                if with_forecast:
                    row["forecast"] = fc_label
                meta.append(row)
        results = simulate_serving_many(cases)
        rows = []
        for m, r in zip(meta, results):
            rows.append({**m, **r.to_dict()})
        _attach_savings(rows, baseline)
        return SweepResult(baseline=baseline, rows_=rows, results=results)

    def to_csv(self) -> str:
        """Run the sweep and export the rows as CSV
        (:meth:`SweepResult.to_csv`)."""
        return self.run().to_csv()


def _attach_savings(rows: list[dict], baseline: str) -> None:
    def key(r: dict):
        # the "forecast" column exists only on forecast-axis sweeps;
        # savings always compare within the same forecast model
        return (r["region"], r["seed"], r["fault"], r.get("forecast", ""))

    base_carbon = {key(r): r["carbon_g"]
                   for r in rows if r["policy"] == baseline}
    for r in rows:
        base = base_carbon.get(key(r), 0.0)
        r["savings_pct"] = round(100.0 * (1.0 - r["carbon_g"] / base), 3) \
            if base > 0 else 0.0


@dataclasses.dataclass
class SweepResult:
    """Flat per-case rows + per-policy aggregates of one sweep batch.

    ``results`` holds the in-memory ``SimResult`` objects for the run that
    produced this (dropped by the JSON round-trip — rows carry everything
    the figures need)."""

    baseline: str
    rows_: list[dict]
    results: list[SimResult] | None = None

    def rows(self) -> list[dict]:
        return self.rows_

    def attributions(self) -> list[Attribution]:
        """Carbon-attribution of every non-baseline cell against its
        cell's baseline run (same region/seed/fault/forecast), each
        additive to the last bit (``Attribution.check`` passes by
        construction).  Needs the in-memory ``results`` — a same-process
        run, not a JSON round-trip."""
        if self.results is None:
            raise ValueError(
                "attributions need the in-memory results; run the sweep "
                "in-process (SweepResult.from_json drops them)")

        def key(r: dict):
            return (r["region"], r["seed"], r["fault"],
                    r.get("forecast", ""))

        base = {key(r): res for r, res in zip(self.rows_, self.results)
                if r["policy"] == self.baseline}
        out = []
        for r, res in zip(self.rows_, self.results):
            if r["policy"] == self.baseline:
                continue
            b = base.get(key(r))
            if b is None:
                continue
            att = attribute(res, b)
            att.check()
            out.append(att)
        return out

    def summary(self) -> dict[str, dict]:
        """Per-policy aggregates with cross-(region, seed, fault)
        dispersion of the savings."""
        out: dict[str, dict] = {}
        for name in dict.fromkeys(r["policy"] for r in self.rows_):
            rs = [r for r in self.rows_ if r["policy"] == name]
            sv = np.array([r["savings_pct"] for r in rs])
            out[name] = {
                "n_cases": len(rs),
                "savings_mean_pct": round(float(sv.mean()), 3),
                "savings_std_pct": round(float(sv.std()), 3),
                "savings_min_pct": round(float(sv.min()), 3),
                "savings_max_pct": round(float(sv.max()), 3),
                "mean_wait_h": round(float(np.mean([r["mean_wait"] for r in rs])), 3),
                "violation_rate": round(float(np.mean([r["violation_rate"] for r in rs])), 4),
            }
        return out

    def table(self) -> str:
        lines = [f"{'policy':18s} {'savings%':>9s} {'±std':>6s} "
                 f"{'wait h':>7s} {'viol':>6s} {'cases':>6s}"]
        for name, s in self.summary().items():
            lines.append(f"{name:18s} {s['savings_mean_pct']:9.2f} "
                         f"{s['savings_std_pct']:6.2f} {s['mean_wait_h']:7.1f} "
                         f"{s['violation_rate']:6.3f} {s['n_cases']:6d}")
        return "\n".join(lines)

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps({"baseline": self.baseline, "rows": self.rows_,
                           "summary": self.summary()}, indent=indent)

    def to_csv(self) -> str:
        """Per-case rows as CSV text, one column per row key.

        Nested dicts (``resilience``, ``serving``) flatten to dotted
        columns (``serving.violation_rate``); list values (tier names /
        counts) join with ``|`` so the payload stays one value per cell.
        Columns appear in first-seen order across rows; rows missing a
        column leave the cell empty — so heterogeneous sweeps (e.g. a
        fault axis where only some rows carry resilience metrics) still
        export as one rectangular table."""
        import csv
        import io

        def flat(row: dict) -> dict:
            out: dict = {}
            for k, v in row.items():
                if isinstance(v, dict):
                    for kk, vv in v.items():
                        out[f"{k}.{kk}"] = vv
                else:
                    out[k] = v
            return {k: "|".join(str(x) for x in v)
                    if isinstance(v, (list, tuple)) else v
                    for k, v in out.items()}

        flats = [flat(r) for r in self.rows_]
        cols: dict[str, None] = {}
        for f in flats:
            for k in f:
                cols.setdefault(k)
        buf = io.StringIO()
        writer = csv.DictWriter(buf, fieldnames=list(cols),
                                restval="", lineterminator="\n")
        writer.writeheader()
        writer.writerows(flats)
        return buf.getvalue()

    @classmethod
    def from_json(cls, payload: str) -> "SweepResult":
        d = json.loads(payload)
        return cls(baseline=d["baseline"], rows_=d["rows"])
