"""repro.experiment — the declarative experiment API for CarbonFlex.

The single public entry point for running the paper's pipeline:

- ``registry``   — ``register_policy`` / ``PolicySpec`` / ``make_policy``:
                   all nine §6 policies behind deferred constructors that
                   receive runtime context (knowledge base, job history,
                   mean length, oracle backend) from the driver;
- ``Scenario``   — a declarative experiment point (region, trace family,
                   capacity, seed, weeks, queue scaling, fault model) with
                   ``materialize()`` resolving to (cluster, ci, jobs,
                   hist/eval splits);
- ``run``        — the continuous-learning driver (§4.2): weekly oracle
                   replay into a rolling KnowledgeBase, policy
                   construction via the registry, batched evaluation
                   through ``simulate_many``;
- ``Sweep``      — cartesian (regions x seeds x faults x forecasts x
                   policies) grids dispatched as one ``simulate_many``
                   batch, aggregated by ``SweepResult`` (savings vs a
                   named baseline, dispersion, JSON + CSV export);
                   serving grids (``Scenario(serving=...)``) dispatch
                   through the request-serving engine instead;
- ``OracleGap``  — the §Forecast harness: per-cell savings-gap-to-oracle
                   under a forecast-error ladder (``sigma_ladder``) and
                   the degradation curve per policy.

Quickstart::

    from repro.experiment import Scenario, Sweep, run

    print(run(Scenario(region="california", capacity=40)).table())

    sweep = Sweep(base=Scenario(capacity=40),
                  regions=["california", "ontario"], seeds=[1, 2],
                  policies=["carbon-agnostic", "wait-awhile", "carbonflex",
                            "oracle"])
    print(sweep.run().table())
"""
from . import registry  # noqa: F401
from .driver import (DEFAULT_DAG_POLICIES, DEFAULT_GEO_POLICIES,  # noqa: F401
                     DEFAULT_POLICIES, DEFAULT_SERVE_POLICIES,
                     ExperimentResult, prepare_context, run)
from .oracle_gap import (DEFAULT_GAP_POLICIES, OracleGap,  # noqa: F401
                         OracleGapResult, sigma_ladder)
from .registry import (PolicyContext, PolicySpec, available_policies,  # noqa: F401
                       make_policy, register_policy)
from repro.serving import ServingConfig  # noqa: F401  (scenario convenience)

from .scenario import WEEK, MaterializedScenario, Scenario  # noqa: F401
from .sweep import Sweep, SweepResult  # noqa: F401
