"""Oracle-gap harness (ISSUE 5): how far is each policy from the oracle,
and how fast does it degrade as forecast error grows?

The paper's headline robustness claim is that continuous learning keeps
CarbonFlex "within ~2% of an oracle scheduler with perfect knowledge of
future carbon intensity and job length" (§6).  This harness measures that
gap directly and extends it along the forecast-error axis the paper does
not evaluate:

- for every grid cell (region x seed x fault x forecast model) it runs
  the requested policies *plus the oracle* (which reads the true trace,
  so it is forecast-independent by construction) against the same
  baseline;
- the **oracle gap** of a policy in a cell is
  ``oracle_savings_pct - policy_savings_pct`` (percentage points of
  baseline carbon left on the table);
- the **degradation curve** is the mean gap per forecast model, in the
  order the forecast axis was given (typically a sigma ladder: perfect,
  then AR(1) noise of growing sigma).

Usage (also the EXPERIMENTS.md §Forecast generator)::

    from repro.experiment.oracle_gap import OracleGap, sigma_ladder

    res = OracleGap(base=Scenario(capacity=40), seeds=(1, 2, 3),
                    forecasts=sigma_ladder((0.0, 0.1, 0.2, 0.4))).run()
    print(res.table())
    res.degradation_curve("carbonflex")   # [(label, mean_gap_pp), ...]

CLI: ``PYTHONPATH=src python -m repro.experiment.oracle_gap [--tiny]``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Sequence

import numpy as np

from repro.core.forecast import (ForecastModel, NoisyForecast,
                                 QuantileForecast, forecast_labels)

from .scenario import Scenario
from .sweep import Sweep

#: Policies whose oracle gap the §Forecast study tracks: the learned
#: CarbonFlex pipeline (greedy, MPC, and marginal-capacity scale-up
#: variants) and the threshold baseline, each side with its
#: quantile-robust variant.
DEFAULT_GAP_POLICIES: tuple[str, ...] = (
    "carbonflex", "carbonflex-mpc", "carbonflex-scale",
    "carbonflex-robust", "wait-awhile", "wait-awhile-robust",
)


def sigma_ladder(sigmas: Sequence[float] = (0.0, 0.1, 0.2, 0.4),
                 kind: str = "noisy", seed: int = 0,
                 **kw) -> tuple[ForecastModel | None, ...]:
    """A forecast-error ladder for the degradation curve: ``sigma == 0``
    is the perfect forecast (``None``), the rest AR(1) ``noisy`` or
    ensemble ``quantile`` models of growing sigma."""
    if kind not in ("noisy", "quantile"):
        raise ValueError(f"kind must be 'noisy' or 'quantile', got {kind!r}")
    cls = NoisyForecast if kind == "noisy" else QuantileForecast
    return tuple(None if s == 0 else cls(sigma=s, seed=seed, **kw)
                 for s in sigmas)


@dataclasses.dataclass
class OracleGap:
    """Declarative oracle-gap study: a :class:`Sweep` over a forecast
    ladder with the oracle added, reduced to per-cell gaps."""

    base: Scenario = dataclasses.field(default_factory=Scenario)
    policies: Sequence[str] = DEFAULT_GAP_POLICIES
    forecasts: Sequence[ForecastModel | None] = \
        dataclasses.field(default_factory=sigma_ladder)
    regions: Sequence[str] = ()
    seeds: Sequence[int] = ()
    baseline: str = "carbon-agnostic"
    backend: str = "numpy"
    # quantile the *-robust policy variants threshold on
    forecast_quantile: float = 0.7
    # Simulation engine for the grid.  The study defaults to "scan" so the
    # scan-native policies (carbonflex-mpc / carbonflex-scale / the
    # threshold baselines) fuse into vmapped device programs; cells that
    # are not scan-native (the oracles, carbonflex itself) delegate to the
    # vector engine, which the scan batch logs once per dispatch.
    engine: str = "scan"
    # ISSUE 10 S1: also run the oracle on the *learned* length estimates
    # ("oracle-estimated") and report both gaps — the gap to the true
    # oracle (perfect lengths) and the gap to the estimated oracle.  The
    # spread between the two is the price of length-estimation error,
    # separated from scheduling-decision error.
    include_estimated: bool = True

    def sweep(self) -> Sweep:
        names = tuple(self.policies)
        if "oracle" not in names:
            names = names + ("oracle",)
        if self.include_estimated and "oracle-estimated" not in names:
            names = names + ("oracle-estimated",)
        base = self.base
        if base.engine != self.engine:
            base = dataclasses.replace(base, engine=self.engine)
        return Sweep(base=base, regions=self.regions, seeds=self.seeds,
                     policies=names, forecasts=tuple(self.forecasts),
                     forecast_quantile=self.forecast_quantile,
                     baseline=self.baseline, backend=self.backend)

    def run(self, progress: Callable[[str], None] | None = None
            ) -> "OracleGapResult":
        from repro.telemetry import attribute

        sweep = self.sweep()
        res = sweep.run(progress=progress)
        rows = res.rows()
        cell = lambda r: (r["region"], r["seed"], r["fault"], r["forecast"])  # noqa: E731
        oracle_sv = {cell(r): r["savings_pct"]
                     for r in rows if r["policy"] == "oracle"}
        est_sv = {cell(r): r["savings_pct"]
                  for r in rows if r["policy"] == "oracle-estimated"}
        # per-cell SimResults, for attributing each gap by cause
        sims = {(cell(r), r["policy"]): s
                for r, s in zip(res.rows_, res.results or ())}
        base_c = {cell(r): s.carbon_g
                  for r, s in zip(res.rows_, res.results or ())
                  if r["policy"] == res.baseline}
        gap_rows = []
        for r in rows:
            if r["policy"] == "oracle":
                continue
            row = {
                "region": r["region"], "seed": r["seed"], "fault": r["fault"],
                "forecast": r["forecast"], "policy": r["policy"],
                "savings_pct": r["savings_pct"],
                "oracle_savings_pct": oracle_sv[cell(r)],
                "gap_pp": round(oracle_sv[cell(r)] - r["savings_pct"], 3),
            }
            # the second gap of the S1 "both gaps" report: distance to the
            # oracle that only knows the learned length estimates — what a
            # policy could still gain from better *decisions* alone
            if r["policy"] != "oracle-estimated" and cell(r) in est_sv:
                row["est_oracle_savings_pct"] = est_sv[cell(r)]
                row["est_gap_pp"] = round(
                    est_sv[cell(r)] - r["savings_pct"], 3)
            # Attribute the gap itself: the oracle "vs the policy as
            # baseline" decomposes the grams the oracle saves on top into
            # named causes — capacity_scaling is provisioning-phase loss,
            # temporal_shifting execution-phase loss (the ROADMAP
            # "execution-phase-dominated" hypothesis, measured).  In pp
            # of the sweep baseline's carbon, the same unit as gap_pp.
            orc = sims.get((cell(r), "oracle"))
            pol = sims.get((cell(r), r["policy"]))
            bc = base_c.get(cell(r), 0.0)
            if orc is not None and pol is not None and bc > 0:
                att = attribute(orc, pol)
                att.check()
                row["gap_attribution_pp"] = {
                    c: round(100.0 * v / bc, 3)
                    for c, v in att.causes.items() if v != 0.0}
            gap_rows.append(row)
        # the same disambiguated labels Sweep stamps on the rows;
        # dict.fromkeys dedupes (equal models only) while keeping order
        order = forecast_labels(self.forecasts)
        return OracleGapResult(baseline=sweep.effective_baseline(),
                               forecast_order=list(dict.fromkeys(order)),
                               rows_=gap_rows)


@dataclasses.dataclass
class OracleGapResult:
    """Per-cell gap rows + the aggregates EXPERIMENTS.md §Forecast cites."""

    baseline: str
    forecast_order: list[str]
    rows_: list[dict]

    def rows(self) -> list[dict]:
        return self.rows_

    def policies(self) -> list[str]:
        return list(dict.fromkeys(r["policy"] for r in self.rows_))

    def summary(self) -> dict[str, dict[str, dict]]:
        """``{forecast_label: {policy: {savings/gap mean +- std}}}`` in
        ladder order.  Cached: the rows are immutable after ``run()``,
        and table()/curves/to_json all reduce over the same aggregates."""
        cached = self.__dict__.get("_summary")
        if cached is not None:
            return cached
        out: dict[str, dict[str, dict]] = {}
        for fc in self.forecast_order:
            out[fc] = {}
            for pol in self.policies():
                rs = [r for r in self.rows_
                      if r["forecast"] == fc and r["policy"] == pol]
                if not rs:
                    continue
                sv = np.array([r["savings_pct"] for r in rs])
                gap = np.array([r["gap_pp"] for r in rs])
                out[fc][pol] = {
                    "n_cases": len(rs),
                    "savings_mean_pct": round(float(sv.mean()), 3),
                    "savings_std_pct": round(float(sv.std()), 3),
                    "gap_mean_pp": round(float(gap.mean()), 3),
                    "gap_std_pp": round(float(gap.std()), 3),
                }
                est = [r["est_gap_pp"] for r in rs if "est_gap_pp" in r]
                if est:
                    out[fc][pol]["est_gap_mean_pp"] = round(
                        float(np.mean(est)), 3)
                atts = [r["gap_attribution_pp"] for r in rs
                        if "gap_attribution_pp" in r]
                if atts:
                    causes = sorted({c for a in atts for c in a})
                    out[fc][pol]["gap_attribution_mean_pp"] = {
                        c: round(float(np.mean([a.get(c, 0.0)
                                                for a in atts])), 3)
                        for c in causes}
        self._summary = out
        return out

    def perfect_gap(self, policy: str) -> float:
        """Mean gap-to-oracle (pp) under the perfect forecast — the
        paper's ~2% claim, measured."""
        return self.summary()["perfect"][policy]["gap_mean_pp"]

    def degradation_curve(self, policy: str) -> list[tuple[str, float]]:
        """``[(forecast_label, mean_gap_pp), ...]`` in ladder order."""
        s = self.summary()
        return [(fc, s[fc][policy]["gap_mean_pp"])
                for fc in self.forecast_order if policy in s[fc]]

    def table(self) -> str:
        lines = [f"{'forecast':22s} {'policy':20s} {'savings%':>9s} "
                 f"{'gap pp':>7s} {'±std':>6s} {'est pp':>7s} {'cases':>6s}"]
        for fc, pols in self.summary().items():
            for pol, s in pols.items():
                est = (f"{s['est_gap_mean_pp']:7.2f}"
                       if "est_gap_mean_pp" in s else " " * 7)
                lines.append(
                    f"{fc:22s} {pol:20s} {s['savings_mean_pct']:9.2f} "
                    f"{s['gap_mean_pp']:7.2f} {s['gap_std_pp']:6.2f} "
                    f"{est} {s['n_cases']:6d}")
        return "\n".join(lines)

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps({"baseline": self.baseline,
                           "forecast_order": self.forecast_order,
                           "rows": self.rows_,
                           "summary": self.summary()}, indent=indent)

    @classmethod
    def from_json(cls, payload: str) -> "OracleGapResult":
        d = json.loads(payload)
        return cls(baseline=d["baseline"],
                   forecast_order=d["forecast_order"], rows_=d["rows"])


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI-scale smoke (small capacity, 1 seed, 2-point "
                         "ladder)")
    ap.add_argument("--smoke", action="store_true",
                    help="fastest end-to-end check (perfect forecast only, "
                         "1 seed, MPC + greedy vs both oracles) — the CI "
                         "tier-1 step")
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--capacity", type=int, default=40)
    ap.add_argument("--region", default="south-australia")
    ap.add_argument("--engine", default="scan",
                    choices=("scan", "vector", "scalar"))
    ap.add_argument("--kind", default="noisy",
                    choices=("noisy", "quantile"))
    ap.add_argument("--out", default=None, help="write result JSON here")
    args = ap.parse_args()

    if args.smoke:
        base = Scenario(region=args.region, capacity=6, learn_weeks=1,
                        family="alibaba", seed=101)
        gap = OracleGap(base=base, seeds=(11,),
                        policies=("carbonflex", "carbonflex-mpc",
                                  "carbonflex-scale"),
                        forecasts=sigma_ladder((0.0,)), engine=args.engine)
    elif args.tiny:
        base = Scenario(region=args.region, capacity=8, learn_weeks=1,
                        family="alibaba", seed=101)
        gap = OracleGap(base=base, seeds=(11,),
                        forecasts=sigma_ladder((0.0, 0.2), kind=args.kind),
                        engine=args.engine)
    else:
        base = Scenario(region=args.region, capacity=args.capacity,
                        learn_weeks=2, seed=7)
        gap = OracleGap(base=base,
                        seeds=tuple(range(1, args.seeds + 1)),
                        forecasts=sigma_ladder(kind=args.kind),
                        engine=args.engine)
    res = gap.run(progress=print)
    print(res.table())
    for pol in res.policies():
        curve = ", ".join(f"{fc}={g:+.2f}pp"
                          for fc, g in res.degradation_curve(pol))
        print(f"degradation[{pol}]: {curve}")
    perfect = res.summary().get("perfect", {})
    for pol, s in perfect.items():
        att = s.get("gap_attribution_mean_pp")
        if att:
            split = ", ".join(f"{c}={v:+.2f}pp" for c, v in att.items())
            print(f"gap attribution[{pol}] (perfect forecast): {split}")
    if args.out:
        with open(args.out, "w") as f:
            f.write(res.to_json())
            f.write("\n")
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
