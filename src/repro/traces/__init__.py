from .workloads import TraceSpec, generate_trace, mean_length  # noqa: F401
