from .requests import (expected_request_rate,  # noqa: F401
                       generate_request_demand)
from .workloads import (DagConfig, TraceSpec, dag_mean_task_length,  # noqa: F401
                        generate_dag_specs, generate_dag_trace,
                        generate_trace, mean_length)
