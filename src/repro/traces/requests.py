"""Seeded diurnal request-trace generator for the serving tier (ISSUE 7).

Interactive traffic differs from the batch workloads in ``workloads.py`` in
one structural way: requests are far too numerous to simulate individually
(millions per day), and far too short to suspend.  The generator therefore
never materialises a request — it produces a **per-slot demand vector**
(requests arriving in each hourly slot), which is the unit the serving
engine's hot loop is vectorized over.

Shape model (web-traffic stylised facts):

- a sinusoidal daily curve peaking at ``peak_hour`` local time
  (``diurnal`` amplitude — the day/night swing of consumer traffic);
- a weekly modulation (``weekly`` fractional weekend dip);
- Poisson arrivals around the shaped rate (one vectorized draw per trace,
  never per-request Python);
- burst spikes: seeded slot-level events (rate ``burst_rate`` per slot)
  that multiply demand by ``burst_mult`` for a geometric-length window —
  the flash-crowd tail the SLO model has to absorb.
"""
from __future__ import annotations

import numpy as np


def expected_request_rate(
    hours: int,
    requests_per_day: float,
    *,
    diurnal: float = 0.45,
    weekly: float = 0.15,
    peak_hour: int = 14,
) -> np.ndarray:
    """Deterministic expected requests-per-slot curve (no noise, no
    bursts): the daily sinusoid x weekly modulation around the base rate.

    This doubles as the *demand forecast* the serving policies read — the
    realized trace (:func:`generate_request_demand`) adds Poisson noise
    and burst spikes on top, so a policy planning on this curve faces
    genuine demand-forecast error at the spikes."""
    if hours < 1:
        raise ValueError(f"hours must be >= 1, got {hours}")
    if requests_per_day <= 0:
        raise ValueError(f"requests_per_day must be positive, "
                         f"got {requests_per_day}")
    t = np.arange(hours, dtype=np.float64)
    hod = t % 24
    dow = (t // 24) % 7
    base = requests_per_day / 24.0
    rate = base * (1.0 + diurnal * np.cos(2 * np.pi * (hod - peak_hour) / 24.0))
    rate = rate * np.where(dow >= 5, 1.0 - weekly, 1.0)
    return np.maximum(rate, 0.0)


def generate_request_demand(
    hours: int,
    requests_per_day: float,
    seed: int = 0,
    *,
    diurnal: float = 0.45,
    weekly: float = 0.15,
    peak_hour: int = 14,
    burst_rate: float = 0.01,
    burst_mult: float = 3.0,
    burst_mean_slots: float = 2.0,
) -> np.ndarray:
    """Seeded realized demand vector: ``(hours,)`` float64 request counts.

    Poisson arrivals around :func:`expected_request_rate`, with burst
    windows (start probability ``burst_rate`` per slot, geometric duration
    of mean ``burst_mean_slots``) multiplying the rate by ``burst_mult``.
    Overlapping bursts take the max multiplier, not the product — a flash
    crowd during a flash crowd is still one flash crowd.

    Everything is vectorized over slots (one rng.poisson over the whole
    lambda vector); the only Python loop is over burst *starts* (a handful
    per trace), never over requests or slots."""
    rate = expected_request_rate(hours, requests_per_day, diurnal=diurnal,
                                 weekly=weekly, peak_hour=peak_hour)
    rng = np.random.default_rng(np.random.SeedSequence([seed, hours]))
    mult = np.ones(hours)
    if burst_rate > 0 and burst_mult > 1.0:
        starts = np.nonzero(rng.random(hours) < burst_rate)[0]
        if len(starts):
            durations = rng.geometric(1.0 / max(burst_mean_slots, 1.0),
                                      len(starts))
            for s, d in zip(starts, durations):
                end = min(int(s) + int(d), hours)
                mult[s:end] = np.maximum(mult[s:end], burst_mult)
    return rng.poisson(rate * mult).astype(np.float64)
