"""Synthetic workload-trace generators (offline stand-ins, DESIGN.md §5).

The paper evaluates on the Azure 2017 VM trace, the Alibaba-PAI 2022 GPU
trace, and the SURF Lisa HPC trace.  Those datasets are not bundled in this
offline container, so we generate seeded synthetic traces calibrated to the
published characteristics the paper relies on:

- *hour+ jobs only* (the paper filters shorter jobs);
- log-normal job lengths — Azure longer-tailed (high mean length),
  Alibaba-PAI shorter ML jobs, SURF in between with a heavy tail;
- diurnal (and weekday) Poisson arrivals;
- arrival rate calibrated so the expected base-scale demand hits a target
  cluster utilisation (the paper's default: 50%);
- length-based queue assignment (short <= 2 h -> d=6 h, medium <= 12 h ->
  d=24 h, long -> d=48 h);
- elasticity profiles drawn from the Table-3 workload mix (or forced to a
  single class for the Fig. 10 study).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.profiles import TABLE3_WORKLOADS, WorkloadSpec, class_profile
from repro.core.types import ClusterConfig, Job, QueueConfig

# (log-normal mu of hours, sigma, diurnal amplitude)
TRACE_FAMILIES: dict[str, tuple[float, float, float]] = {
    "azure": (1.6, 0.9, 0.35),      # longer jobs (mean ~7 h)
    "alibaba": (0.8, 0.8, 0.45),    # shorter ML training jobs (mean ~3 h)
    "surf": (1.2, 1.1, 0.25),       # HPC mix, heavy tail
}


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    family: str = "azure"
    hours: int = 24 * 7
    utilization: float = 0.5         # target base-scale utilisation of M
    capacity: int = 150
    k_min: int = 1
    k_max: int = 16
    elasticity: str = "mix"          # "mix" | "high" | "moderate" | "low" | "none"
                                     # | "tpu" (roofline-derived per-arch profiles)
    mode: str = "cpu"                # "cpu" fixed power | "gpu" heterogeneous
    seed: int = 0
    length_scale: float = 1.0        # Fig. 13 distribution-shift knobs
    rate_scale: float = 1.0


def mean_length(spec: TraceSpec) -> float:
    mu, sigma, _ = TRACE_FAMILIES[spec.family]
    raw = float(np.exp(mu + sigma**2 / 2)) * spec.length_scale
    return max(1.0, raw)


_TPU_PROFILE_CACHE: dict[str, np.ndarray] = {}


def _tpu_profile(rng: np.random.Generator, spec: TraceSpec):
    """Draw an assigned-architecture job whose scaling profile comes from
    its compiled dry-run roofline terms (DESIGN.md §7).  Falls back to the
    parametric mix when no dry-run results exist."""
    from repro.core.profiles import profile_from_dryrun

    archs = ["stablelm-1.6b", "minicpm-2b", "internvl2-2b", "llama3-8b",
             "rwkv6-7b", "zamba2-7b", "musicgen-large", "dbrx-132b",
             "qwen3-moe-235b-a22b", "command-r-plus-104b"]
    name = archs[rng.integers(len(archs))]
    if name not in _TPU_PROFILE_CACHE:
        try:
            _TPU_PROFILE_CACHE[name] = profile_from_dryrun(
                name, k_min=spec.k_min, k_max=spec.k_max)
        except (FileNotFoundError, OSError):
            return None
    prof = _TPU_PROFILE_CACHE[name]
    # comm volume per slot ~ gradient payload (GB) for Eq. 3 accounting
    from repro.configs import ARCHS

    comm_gb = 2.0 * ARCHS[name].active_param_count() / 16 / 1e9
    return prof, comm_gb, 1.0, name


def _pick_profile(rng: np.random.Generator, spec: TraceSpec) -> tuple[np.ndarray, float, float, str]:
    if spec.elasticity == "none":
        return np.ones(1), 0.0, 1.0, "rigid"
    if spec.elasticity == "tpu":
        out = _tpu_profile(rng, spec)
        if out is not None:
            return out
        # fall through to the parametric mix when dry-run results absent
    if spec.elasticity in ("mix", "tpu"):
        w: WorkloadSpec = TABLE3_WORKLOADS[rng.integers(len(TABLE3_WORKLOADS))]
        prof = w.profile(spec.k_min, spec.k_max)
        power = w.power_kw if spec.mode == "gpu" else 1.0
        return prof, w.comm_size_mb / 1024.0, power, w.name
    prof = class_profile(spec.elasticity, spec.k_min, spec.k_max)
    power = {"high": 1.0, "moderate": 0.85, "low": 0.7}[spec.elasticity] \
        if spec.mode == "gpu" else 1.0
    return prof, 0.05, power, spec.elasticity


@dataclasses.dataclass(frozen=True)
class DagConfig:
    """Shape knobs of the seeded DAG trace generator (all JSON scalars, so
    ``Scenario.to_dict`` round-trips it).

    Calibrated to published pipeline shapes: linear ``chain`` s (ETL /
    retraining pipelines), ``mapreduce`` fan-out/fan-in stages, and random
    ``layered`` DAGs with configurable width/depth (the Alibaba batch-DAG
    shape family).  ``independent=True`` generates the *same* tasks with
    the precedence edges stripped — the independent-task upper bound the
    DAG-vs-per-job savings comparison needs."""

    shapes: tuple[str, ...] = ("chain", "mapreduce", "layered")
    width: int = 4                  # max fan-out / layer width
    depth: int = 3                  # max stages / layers (chains: tasks)
    task_mu: float = 0.5            # log-normal mu of task hours
    task_sigma: float = 0.6
    max_parents: int = 3            # layered: parents drawn per task
    independent: bool = False       # strip edges (upper-bound twin)

    def __post_init__(self) -> None:
        object.__setattr__(self, "shapes", tuple(self.shapes))
        unknown = set(self.shapes) - {"chain", "mapreduce", "layered"}
        if not self.shapes or unknown:
            raise ValueError(f"DagConfig.shapes must be a non-empty subset "
                             f"of chain/mapreduce/layered, got {self.shapes}")
        if self.width < 2 or self.depth < 2:
            raise ValueError("DagConfig needs width >= 2 and depth >= 2")


def dag_mean_task_length(dag: DagConfig, length_scale: float = 1.0) -> float:
    """Expected task length in slots (the mean-historical-length input the
    baselines are granted, per-task for DAG scenarios).  ``length_scale``
    is the Fig.-13 distribution-shift knob — included here so arrival-rate
    calibration stays linear in it, exactly like ``mean_length``."""
    return max(1.0, float(np.exp(dag.task_mu + dag.task_sigma ** 2 / 2))
               * length_scale)


def _expected_tasks(dag: DagConfig) -> float:
    """Expected tasks per DAG under the shape mix (arrival-rate calibration
    only — the same role the log-normal mean plays in ``generate_trace``)."""
    per = {"chain": (2 + dag.depth) / 2,                  # depth ~ U[2, D]
           "mapreduce": (2 + dag.width) / 2 + 2,          # fan-out ~ U[2, W]
           "layered": ((2 + dag.depth) / 2) * (1 + dag.width) / 2}
    return float(np.mean([per[s] for s in dag.shapes]))


def generate_dag_specs(spec: TraceSpec, dag: DagConfig) -> list["DagSpec"]:
    """Seeded DAG-job trace: Poisson diurnal arrivals of whole DAGs, shape
    drawn uniformly from ``dag.shapes``, task lengths log-normal
    (``task_mu``/``task_sigma``, clipped to [1, 48] slots), per-task
    elasticity profiles from the same Table-3 machinery as the flat
    generator.  The arrival rate is calibrated so the expected base-scale
    *task* demand hits ``spec.utilization * spec.capacity``."""
    from repro.core.dag import (DagSpec, chain_tasks, layered_tasks,
                                map_reduce_tasks)

    rng = np.random.default_rng(spec.seed)
    _, _, diurnal = TRACE_FAMILIES[spec.family]
    mean_task = dag_mean_task_length(dag, spec.length_scale)
    base_rate = (spec.utilization * spec.capacity
                 / (_expected_tasks(dag) * mean_task * spec.k_min))
    base_rate *= spec.rate_scale

    def _len(n: int) -> list[float]:
        raw = np.exp(rng.normal(dag.task_mu, dag.task_sigma, n))
        raw = raw * spec.length_scale
        return [float(v) for v in np.clip(raw, 1.0, 48.0)]

    dags: list[DagSpec] = []
    for t in range(spec.hours):
        hod = t % 24
        dow = (t // 24) % 7
        rate = base_rate * (1.0 + diurnal * np.sin(2 * np.pi * (hod - 10) / 24.0))
        if dow >= 5:
            rate *= 0.8
        for _ in range(rng.poisson(max(rate, 0.0))):
            shape = dag.shapes[rng.integers(len(dag.shapes))]
            if shape == "chain":
                d = int(rng.integers(2, dag.depth + 1))
                tasks = chain_tasks(_len(d))
            elif shape == "mapreduce":
                w = int(rng.integers(2, dag.width + 1))
                lens = _len(w + 2)
                tasks = map_reduce_tasks(lens[0], lens[1:w + 1], lens[w + 1])
            else:
                d = int(rng.integers(2, dag.depth + 1))
                sizes = [int(rng.integers(1, dag.width + 1)) for _ in range(d)]
                tasks = layered_tasks(sizes, _len(sum(sizes)), rng,
                                      max_parents=dag.max_parents)
            for task in tasks:          # Table-3 elasticity per task
                prof, comm, power, _ = _pick_profile(rng, spec)
                task.profile = prof
                task.comm_size = comm
                task.power = power
                task.k_min = spec.k_min
            dags.append(DagSpec(dag_id=len(dags), arrival=t, tasks=tasks,
                                name=f"{shape}{len(dags)}"))
    return dags


def generate_dag_trace(spec: TraceSpec, dag: DagConfig,
                       queues: tuple[QueueConfig, ...] | None = None) -> list[Job]:
    """Seeded DAG workload expanded to the engine's ``Job`` list (every
    task one job arriving at its DAG's slot, precedence in ``Job.deps``;
    ``dag.independent`` strips the edges for the upper-bound twin)."""
    from repro.core.dag import expand_dags

    if queues is None:
        queues = ClusterConfig.default(spec.capacity).queues
    return expand_dags(generate_dag_specs(spec, dag), queues,
                       independent=dag.independent)


def generate_trace(spec: TraceSpec, queues: tuple[QueueConfig, ...] | None = None) -> list[Job]:
    """Seeded synthetic job trace over ``spec.hours`` slots."""
    if queues is None:
        queues = ClusterConfig.default(spec.capacity).queues
    rng = np.random.default_rng(spec.seed)
    mu, sigma, diurnal = TRACE_FAMILIES[spec.family]
    mean_len = mean_length(spec)
    # expected demand per slot = rate * mean_len * k_min = util * M
    base_rate = spec.utilization * spec.capacity / (mean_len * spec.k_min)
    base_rate *= spec.rate_scale

    jobs: list[Job] = []
    jid = 0
    for t in range(spec.hours):
        hod = t % 24
        dow = (t // 24) % 7
        rate = base_rate * (1.0 + diurnal * np.sin(2 * np.pi * (hod - 10) / 24.0))
        if dow >= 5:
            rate *= 0.8
        n = rng.poisson(max(rate, 0.0))
        for _ in range(n):
            length = float(np.exp(rng.normal(mu, sigma))) * spec.length_scale
            length = float(np.clip(length, 1.0, 24 * 4))    # hour+ jobs
            qidx = next(i for i, q in enumerate(queues) if length <= q.max_length)
            prof, comm, power, name = _pick_profile(rng, spec)
            jobs.append(Job(
                job_id=jid,
                arrival=t,
                length=length,
                queue=qidx,
                delay=queues[qidx].delay,
                profile=prof,
                k_min=spec.k_min,
                power=power,
                comm_size=comm,
                arch=name,
            ))
            jid += 1
    return jobs
