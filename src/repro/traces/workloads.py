"""Synthetic workload-trace generators (offline stand-ins, DESIGN.md §5).

The paper evaluates on the Azure 2017 VM trace, the Alibaba-PAI 2022 GPU
trace, and the SURF Lisa HPC trace.  Those datasets are not bundled in this
offline container, so we generate seeded synthetic traces calibrated to the
published characteristics the paper relies on:

- *hour+ jobs only* (the paper filters shorter jobs);
- log-normal job lengths — Azure longer-tailed (high mean length),
  Alibaba-PAI shorter ML jobs, SURF in between with a heavy tail;
- diurnal (and weekday) Poisson arrivals;
- arrival rate calibrated so the expected base-scale demand hits a target
  cluster utilisation (the paper's default: 50%);
- length-based queue assignment (short <= 2 h -> d=6 h, medium <= 12 h ->
  d=24 h, long -> d=48 h);
- elasticity profiles drawn from the Table-3 workload mix (or forced to a
  single class for the Fig. 10 study).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.profiles import TABLE3_WORKLOADS, WorkloadSpec, class_profile
from repro.core.types import ClusterConfig, Job, QueueConfig

# (log-normal mu of hours, sigma, diurnal amplitude)
TRACE_FAMILIES: dict[str, tuple[float, float, float]] = {
    "azure": (1.6, 0.9, 0.35),      # longer jobs (mean ~7 h)
    "alibaba": (0.8, 0.8, 0.45),    # shorter ML training jobs (mean ~3 h)
    "surf": (1.2, 1.1, 0.25),       # HPC mix, heavy tail
}


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    family: str = "azure"
    hours: int = 24 * 7
    utilization: float = 0.5         # target base-scale utilisation of M
    capacity: int = 150
    k_min: int = 1
    k_max: int = 16
    elasticity: str = "mix"          # "mix" | "high" | "moderate" | "low" | "none"
                                     # | "tpu" (roofline-derived per-arch profiles)
    mode: str = "cpu"                # "cpu" fixed power | "gpu" heterogeneous
    seed: int = 0
    length_scale: float = 1.0        # Fig. 13 distribution-shift knobs
    rate_scale: float = 1.0


def mean_length(spec: TraceSpec) -> float:
    mu, sigma, _ = TRACE_FAMILIES[spec.family]
    raw = float(np.exp(mu + sigma**2 / 2)) * spec.length_scale
    return max(1.0, raw)


_TPU_PROFILE_CACHE: dict[str, np.ndarray] = {}


def _tpu_profile(rng: np.random.Generator, spec: TraceSpec):
    """Draw an assigned-architecture job whose scaling profile comes from
    its compiled dry-run roofline terms (DESIGN.md §7).  Falls back to the
    parametric mix when no dry-run results exist."""
    from repro.core.profiles import profile_from_dryrun

    archs = ["stablelm-1.6b", "minicpm-2b", "internvl2-2b", "llama3-8b",
             "rwkv6-7b", "zamba2-7b", "musicgen-large", "dbrx-132b",
             "qwen3-moe-235b-a22b", "command-r-plus-104b"]
    name = archs[rng.integers(len(archs))]
    if name not in _TPU_PROFILE_CACHE:
        try:
            _TPU_PROFILE_CACHE[name] = profile_from_dryrun(
                name, k_min=spec.k_min, k_max=spec.k_max)
        except (FileNotFoundError, OSError):
            return None
    prof = _TPU_PROFILE_CACHE[name]
    # comm volume per slot ~ gradient payload (GB) for Eq. 3 accounting
    from repro.configs import ARCHS

    comm_gb = 2.0 * ARCHS[name].active_param_count() / 16 / 1e9
    return prof, comm_gb, 1.0, name


def _pick_profile(rng: np.random.Generator, spec: TraceSpec) -> tuple[np.ndarray, float, float, str]:
    if spec.elasticity == "none":
        return np.ones(1), 0.0, 1.0, "rigid"
    if spec.elasticity == "tpu":
        out = _tpu_profile(rng, spec)
        if out is not None:
            return out
        # fall through to the parametric mix when dry-run results absent
    if spec.elasticity in ("mix", "tpu"):
        w: WorkloadSpec = TABLE3_WORKLOADS[rng.integers(len(TABLE3_WORKLOADS))]
        prof = w.profile(spec.k_min, spec.k_max)
        power = w.power_kw if spec.mode == "gpu" else 1.0
        return prof, w.comm_size_mb / 1024.0, power, w.name
    prof = class_profile(spec.elasticity, spec.k_min, spec.k_max)
    power = {"high": 1.0, "moderate": 0.85, "low": 0.7}[spec.elasticity] \
        if spec.mode == "gpu" else 1.0
    return prof, 0.05, power, spec.elasticity


def generate_trace(spec: TraceSpec, queues: tuple[QueueConfig, ...] | None = None) -> list[Job]:
    """Seeded synthetic job trace over ``spec.hours`` slots."""
    if queues is None:
        queues = ClusterConfig.default(spec.capacity).queues
    rng = np.random.default_rng(spec.seed)
    mu, sigma, diurnal = TRACE_FAMILIES[spec.family]
    mean_len = mean_length(spec)
    # expected demand per slot = rate * mean_len * k_min = util * M
    base_rate = spec.utilization * spec.capacity / (mean_len * spec.k_min)
    base_rate *= spec.rate_scale

    jobs: list[Job] = []
    jid = 0
    for t in range(spec.hours):
        hod = t % 24
        dow = (t // 24) % 7
        rate = base_rate * (1.0 + diurnal * np.sin(2 * np.pi * (hod - 10) / 24.0))
        if dow >= 5:
            rate *= 0.8
        n = rng.poisson(max(rate, 0.0))
        for _ in range(n):
            length = float(np.exp(rng.normal(mu, sigma))) * spec.length_scale
            length = float(np.clip(length, 1.0, 24 * 4))    # hour+ jobs
            qidx = next(i for i, q in enumerate(queues) if length <= q.max_length)
            prof, comm, power, name = _pick_profile(rng, spec)
            jobs.append(Job(
                job_id=jid,
                arrival=t,
                length=length,
                queue=qidx,
                delay=queues[qidx].delay,
                profile=prof,
                k_min=spec.k_min,
                power=power,
                comm_size=comm,
                arch=name,
            ))
            jid += 1
    return jobs
