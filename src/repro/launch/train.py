"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

Runs a real training loop for any assigned architecture.  On this CPU
container use ``--reduced`` (the smoke-scale config); on TPU hardware the
full config runs on the production mesh (``--mesh 16x16`` etc.).  Supports
checkpoint/restart (resume is automatic from --ckpt), elastic DP via
--dp, and int8 gradient compression for cross-pod meshes.
"""
from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host platform devices (CPU elastic demo)")
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    from repro.configs import ARCHS, reduced
    from repro.elastic import ElasticTrainer, RescalePlan, make_compressor
    from repro.train import DataConfig, OptimizerConfig, SyntheticLM

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg)
    from repro.models import param_count

    print(f"arch {cfg.name}: {param_count(cfg) / 1e6:.1f}M params, "
          f"dp={args.dp} tp={args.tp}", flush=True)

    data = SyntheticLM(DataConfig(batch=args.batch, seq_len=args.seq,
                                  vocab_size=cfg.vocab_size, seed=0))
    opt = OptimizerConfig(
        lr=args.lr, warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps,
        schedule="wsd" if cfg.lr_schedule == "wsd" else "cosine")
    ckpt = args.ckpt or f"/tmp/repro_train_{cfg.name}"
    trainer = ElasticTrainer(
        cfg, data, opt, ckpt, model_axis=args.tp,
        compression=make_compressor("int8") if args.compress else None)
    t0 = time.time()
    out = trainer.run([RescalePlan(k=args.dp, steps=args.steps)],
                      checkpoint_every=args.checkpoint_every)
    dt = time.time() - t0
    losses = out["losses"]
    print(f"{len(losses)} steps in {dt:.1f}s "
          f"({dt / max(len(losses), 1):.2f}s/step); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"resumed_from_ckpt={trainer.recoveries > 0}")


if __name__ == "__main__":
    main()
