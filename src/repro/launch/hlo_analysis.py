"""Static HLO analysis: collective-traffic extraction from compiled modules.

``compiled.as_text()`` is the *partitioned* module, so instruction shapes
are per-shard; summing collective payloads therefore yields per-device
wire traffic directly.  Collectives inside ``while`` bodies (layer scans,
CE chunk loops) execute ``trip_count`` times — we parse the call graph
(while/call/cond/fusion edges) and multiply each computation's traffic by
the product of trip counts on its call chain.  Trip counts come from the
``known_trip_count`` backend annotation when XLA recorded one, else from
an explicit hint (the caller knows its scan lengths), else 1.

Wire-cost model per payload byte (ring algorithms, n = group size):
all-reduce 2(n-1)/n, all-gather/reduce-scatter (n-1)/n, all-to-all
(n-1)/n, collective-permute 1.  We report both raw payload bytes and
ring-weighted wire bytes.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_RING_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}


def _shape_bytes(sig: str) -> int:
    """Total bytes of every array shape in a (possibly tuple) signature."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(sig):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    payload_bytes: float = 0.0        # per-device, trip-count weighted
    wire_bytes: float = 0.0           # ring-factor weighted
    by_type: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    count: int = 0

    def as_dict(self) -> dict:
        return {
            "payload_bytes": self.payload_bytes,
            "wire_bytes": self.wire_bytes,
            "by_type": dict(self.by_type),
            "count": self.count,
        }


@dataclasses.dataclass
class ModuleStats:
    """Trip-count-weighted whole-module statistics.

    ``compiled.cost_analysis()`` counts each while-body ONCE (a 32-layer
    scan under-reports flops ~32x), so we re-derive:

    - ``flops``: 2*M*N*K per dot (plus convolutions), weighted by the
      product of trip counts on the call chain;
    - ``hbm_bytes``: an HBM-traffic proxy — every materialised buffer
      (output of a top-level instruction, i.e. not inside a fusion body)
      is written once and read by each consumer;
    - ``collectives``: see CollectiveStats.
    """

    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: CollectiveStats = dataclasses.field(default_factory=CollectiveStats)


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        # computation header: `%name (args...) -> ret {` (args may nest parens)
        if cur is None or not line.startswith(" "):
            m = re.match(r"\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m and line.rstrip().endswith("{") and "->" in line:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _call_edges(comps: dict[str, list[str]]):
    """caller -> list of (callee, kind) edges; kind in {flow, fusion}."""
    edges = defaultdict(list)
    trip_hint = {}
    fusion_called = set()
    for name, lines in comps.items():
        for line in lines:
            for m in re.finditer(r"(?:to_apply|body|condition)=%?([\w\.\-]+)", line):
                edges[name].append((m.group(1), "flow"))
            for m in re.finditer(r"calls=%?([\w\.\-]+)", line):
                edges[name].append((m.group(1), "fusion"))
                fusion_called.add(m.group(1))
            for m in re.finditer(r"branch_computations=\{([^}]*)\}", line):
                for callee in m.group(1).split(","):
                    edges[name].append((callee.strip().lstrip("%"), "flow"))
            if "while(" in line or " while(" in line:
                tc = re.search(r'known_trip_count[":{\s]*[":n\s]*(\d+)', line)
                body = re.search(r"body=%?([\w\.\-]+)", line)
                cond = re.search(r"condition=%?([\w\.\-]+)", line)
                if body and tc:
                    trip_hint[body.group(1)] = int(tc.group(1))
                    if cond:
                        trip_hint[cond.group(1)] = int(tc.group(1))
    return edges, trip_hint, fusion_called


_INSTR_RE = re.compile(r"\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],]+(?:\{[\d,]*\})?)\s+([\w\-]+)\(([^\n]*)")


def _num_elems(sig: str) -> int:
    n = 0
    for dtype, dims in _SHAPE_RE.findall(sig):
        if dtype not in _DTYPE_BYTES:
            continue
        e = 1
        for d in dims.split(","):
            if d:
                e *= int(d)
        n += e
    return n


def _dot_flops(out_sig: str, lhs_shape: str | None, line: str) -> float:
    """2 * output_elems * contraction_size (batch dims cancel out)."""
    out_elems = _num_elems(out_sig)
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if m and lhs_shape:
        dims_m = _SHAPE_RE.search(lhs_shape)
        if dims_m:
            dims = [int(d) for d in dims_m.group(2).split(",") if d]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    k *= dims[int(idx)]
    return 2.0 * out_elems * k


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "reshape", "after-all", "partition-id", "replica-id",
    "while", "conditional", "call", "iota",
}


def analyze_module(hlo: str, scan_trip_hints: dict[str, int] | None = None
                   ) -> ModuleStats:
    """Trip-count-weighted flops / HBM-bytes / collective stats.

    ``scan_trip_hints``: substring -> trip count, applied to while-body
    computations whose name matches when XLA did not record
    ``known_trip_count`` (the caller knows its own scan lengths)."""
    comps = _split_computations(hlo)
    edges, trips, fusion_called = _call_edges(comps)

    # resolve multipliers by walking from the entry computation
    mult: dict[str, float] = defaultdict(float)
    entry = next((n for n in comps if "main" in n or n.startswith("entry")), None)
    if entry is None and comps:
        entry = next(iter(comps))

    def trip_of(callee: str, kind: str) -> float:
        if kind == "fusion":
            return 1.0
        if callee in trips:
            return float(trips[callee])
        if scan_trip_hints:
            for key, n in scan_trip_hints.items():
                if key in callee:
                    return float(n)
        return 1.0

    seen_stack: set[str] = set()

    def walk(name: str, factor: float):
        if name not in comps or name in seen_stack:
            return
        mult[name] += factor
        seen_stack.add(name)
        for callee, kind in edges.get(name, []):
            walk(callee, factor * trip_of(callee, kind))
        seen_stack.discard(name)

    if entry:
        walk(entry, 1.0)
    for name in comps:
        if name not in mult:
            mult[name] = 1.0

    stats = ModuleStats()
    coll = stats.collectives
    for name, lines in comps.items():
        f = mult[name]
        shapes: dict[str, str] = {}      # instr name -> output signature
        in_fusion_body = name in fusion_called
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            iname, out_sig, op, rest = m.groups()
            shapes[iname] = out_sig
            # ---- collectives
            base = next((c for c in _COLLECTIVES if op == c or op.startswith(c)), None)
            if base is not None:
                nbytes = _shape_bytes(out_sig)
                coll.payload_bytes += f * nbytes
                coll.wire_bytes += f * nbytes * _RING_FACTOR[base]
                coll.by_type[base] += f * nbytes
                coll.count += 1
            # ---- flops (dots + convs, wherever they live)
            if op == "dot":
                lhs = re.match(r"\s*%?([\w\.\-]+)", rest)
                lhs_sig = shapes.get(lhs.group(1)) if lhs else None
                if lhs_sig is None and lhs is not None:
                    # operand may carry an inline shape: f32[a,b] %name
                    inline = re.match(r"\s*(\w+\[[\d,]*\])", rest)
                    lhs_sig = inline.group(1) if inline else None
                stats.flops += f * _dot_flops(out_sig, lhs_sig, line)
            elif op == "convolution":
                stats.flops += f * 2.0 * _num_elems(out_sig)  # lower bound
            # ---- HBM proxy: materialised buffers only (skip fusion interiors)
            if not in_fusion_body and op not in _SKIP_BYTES_OPS:
                nbytes = _shape_bytes(out_sig)
                # output written once + operands read once (operand bytes
                # approximated by scanning inline operand shapes)
                op_bytes = sum(_shape_bytes(s) for s in
                               re.findall(r"\w+\[[\d,]*\](?:\{[\d,]*\})?", rest))
                stats.hbm_bytes += f * (nbytes + op_bytes)
    return stats


def analyze_collectives(hlo: str, scan_trip_hints: dict[str, int] | None = None
                        ) -> CollectiveStats:
    return analyze_module(hlo, scan_trip_hints).collectives
