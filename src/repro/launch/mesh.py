"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  The dry-run script
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import to obtain the placeholder devices.

Version compatibility: explicit mesh axis types (``jax.sharding.AxisType``
plus the ``axis_types=`` kwarg on ``jax.make_mesh``/``AbstractMesh``)
landed after jax 0.4.x.  On older versions a plain ``Mesh`` has exactly
the ``Auto`` semantics we would request explicitly, so the helpers below
feature-detect and fall back — callers never touch ``AxisType`` directly.
"""
from __future__ import annotations

import inspect

import jax


def _auto_axis_type():
    """``jax.sharding.AxisType.Auto`` where it exists, else None."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return getattr(axis_type, "Auto", None)


def _make_mesh_kwargs(num_axes: int) -> dict:
    auto = _auto_axis_type()
    if auto is None:
        return {}
    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        return {}
    return {"axis_types": (auto,) * num_axes}


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (elastic scaling uses smaller DP extents)."""
    return jax.make_mesh(shape, axes, **_make_mesh_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Device-free ``AbstractMesh`` across the two constructor generations:
    jax <= 0.4.x takes one ``((name, size), ...)`` tuple; newer versions
    take ``(shape, axis_names)`` plus optional explicit axis types."""
    ctor = jax.sharding.AbstractMesh
    params = list(inspect.signature(ctor.__init__).parameters)
    if len(params) > 1 and params[1] == "shape_tuple":
        return ctor(tuple(zip(axes, shape)))
    auto = _auto_axis_type()
    kw = {}
    if auto is not None and "axis_types" in params:
        kw["axis_types"] = (auto,) * len(axes)
    return ctor(shape, axes, **kw)
