import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes and record memory/cost/collective analyses.

This proves the distribution config is coherent without real hardware:
sharding mismatches, OOM-at-compile and unsupported collectives all
surface here as hard failures.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod        # 2x16x16
  PYTHONPATH=src python -m repro.launch.dryrun --out results/dryrun

Outputs one JSON per cell under --out (consumed by benchmarks/roofline.py
and by core/profiles.py for CarbonFlex scaling profiles).
"""
import argparse
import dataclasses
import json
import time
import traceback

import numpy as np

import jax

from repro.configs import ARCHS, LONG_CONTEXT_ARCHS
from repro.launch.hlo_analysis import analyze_module
from repro.launch.mesh import make_production_mesh
from repro.models import LogicalRules, ModelConfig, SHAPES
from repro.models.common import ShapeConfig
from repro.serve import abstract_cache, make_serve_step, serve_input_specs
from repro.train import OptimizerConfig, abstract_state, batch_specs, make_train_step

# v5e per-chip constants for the roofline terms (EXPERIMENTS.md §Roofline)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def runnable(arch: str, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False          # full-attention archs skip (DESIGN.md §6)
    return True


def _adapted_cfg(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    # bigger attention chunks for long prefill keep the scan shallow
    if shape.seq_len >= 32_768:
        return dataclasses.replace(cfg, attention_chunk=2048)
    return cfg


def input_specs(arch: str, shape_name: str, mesh=None):
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no device
    allocation) for every input of the (arch x shape) cell: the training
    batch for train shapes, (params, cache, tokens) templates for decode."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = mesh or make_production_mesh()
    rules = LogicalRules(mesh)
    if shape.kind in ("train", "prefill"):
        return batch_specs(cfg, shape, rules)
    return {
        "tokens": serve_input_specs(cfg, shape.global_batch, rules),
        "cache": abstract_cache(cfg, shape.global_batch, shape.seq_len, rules),
    }


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Returns (lowered, scan_trip_hints)."""
    rules = LogicalRules(mesh)
    cfg = _adapted_cfg(cfg, shape)
    hints = {"while": float(cfg.num_layers)}   # fallback for unnamed scans
    if shape.kind == "train":
        opt = OptimizerConfig(schedule="wsd" if cfg.lr_schedule == "wsd" else "cosine")
        step = make_train_step(cfg, rules, opt)
        state = abstract_state(cfg, rules)
        batch = batch_specs(cfg, shape, rules)
        lowered = jax.jit(step, donate_argnums=(0,)).lower(state, batch)
    else:
        from repro.models import api

        if shape.kind == "prefill":
            def prefill(params, batch):
                x, head = api.forward(params, batch["tokens"], cfg, rules,
                                      return_hidden=True,
                                      prefix_embeds=batch.get("prefix_embeds"))
                return (x[:, -1] @ head.astype(x.dtype))
            batch = batch_specs(cfg, shape, rules)
            params = api.abstract_params(cfg, rules)
            lowered = jax.jit(prefill).lower(params, batch)
        else:  # decode: one new token against a seq_len context
            step = make_serve_step(cfg, rules)
            params = api.abstract_params(cfg, rules)
            cache = abstract_cache(cfg, shape.global_batch, shape.seq_len, rules)
            toks = serve_input_specs(cfg, shape.global_batch, rules)
            lowered = jax.jit(step, donate_argnums=(1,)).lower(params, cache, toks)
    return lowered, hints


def analyze_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    lowered, hints = lower_cell(cfg, shape, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    # jax <= 0.4.x returns a one-dict list from cost_analysis(); newer
    # versions return the dict itself.
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    stats = analyze_module(hlo, scan_trip_hints=hints)
    coll = stats.collectives

    # cost_analysis() counts while bodies once; the HLO walk re-weights by
    # trip counts (see hlo_analysis.ModuleStats), so prefer it.
    flops_per_dev = float(stats.flops)
    bytes_per_dev = float(stats.hbm_bytes)
    compute_s = flops_per_dev / PEAK_FLOPS
    memory_s = bytes_per_dev / HBM_BW
    collective_s = coll.wire_bytes / LINK_BW

    # MODEL_FLOPS: 6*N*D for train (fwd+bwd), 2*N*D for inference
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch          # one new token per sequence
        model_flops = 2.0 * n_active * tokens
    model_flops_per_dev = model_flops / chips

    out = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float)) and "{" not in k},
        "hlo_stats": {"flops": stats.flops, "hbm_bytes": stats.hbm_bytes},
        "collectives": coll.as_dict(),
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": max(
                [("compute", compute_s), ("memory", memory_s),
                 ("collective", collective_s)], key=lambda kv: kv[1])[0],
            "model_flops_per_dev": model_flops_per_dev,
            "useful_flops_ratio": (model_flops_per_dev / flops_per_dev
                                   if flops_per_dev else None),
        },
        "params_total": cfg.param_count(),
        "params_active": n_active,
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)

    failures = []
    for multi_pod in meshes:
        for arch in archs:
            for shape_name in shapes:
                if not runnable(arch, SHAPES[shape_name]):
                    print(f"SKIP {arch} x {shape_name} (full attention at 500k)")
                    continue
                tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"CACHED {tag}")
                    continue
                try:
                    res = analyze_cell(arch, shape_name, multi_pod)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                    r = res["roofline"]
                    peak = res["memory"]["peak_bytes"]
                    peak_str = f"{peak / 2**30:.2f} GiB/dev" \
                        if peak is not None else "n/a"
                    print(f"OK {tag}: compile {res['compile_s']}s "
                          f"peak {peak_str} "
                          f"compute {r['compute_s']*1e3:.1f}ms "
                          f"memory {r['memory_s']*1e3:.1f}ms "
                          f"coll {r['collective_s']*1e3:.1f}ms "
                          f"-> {r['dominant']}", flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures.append((tag, repr(e)))
                    print(f"FAIL {tag}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
