"""Fault-tolerant checkpoint manager.

Requirements at 1000+-node scale (DESIGN.md §10):

- **atomicity** — a checkpoint is either fully visible or absent: leaves
  are written into ``<dir>/tmp.step_N``, fsynced, then the directory is
  atomically renamed to ``step_N``;
- **async** — a background thread does the serialisation so the train
  loop only blocks on device->host transfer;
- **restart** — ``latest_step`` / ``restore`` pick up the newest complete
  checkpoint; partially-written ``tmp.*`` dirs from a crashed run are
  ignored and garbage-collected;
- **elastic re-shard** — ``restore(..., shardings=...)`` places leaves
  under *any* target sharding, so a checkpoint written on one DP degree
  (or mesh) resumes on another — this is the mechanism CarbonFlex's
  elastic scaling rides on (the paper's scancel + resubmit, §5).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np

import jax

PyTree = Any
_SEP = "__"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_part(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._gc_tmp()

    # --- write ------------------------------------------------------------

    def save(self, step: int, tree: PyTree, blocking: bool = False) -> None:
        host = _flatten(tree)          # device->host happens here
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host), daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, host: dict[str, np.ndarray]) -> None:
        tmp = os.path.join(self.dir, f"tmp.step_{step:09d}")
        final = os.path.join(self.dir, f"step_{step:09d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "leaves.npz"), **host)
        meta = {"step": step, "keys": sorted(host.keys())}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic visibility
        self._gc_old()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    # --- read -------------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "meta.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> PyTree:
        """Load into the structure of ``template``; optionally re-shard
        every leaf onto ``shardings`` (elastic rescale / new mesh)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}", "leaves.npz")
        data = np.load(path)
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
                   if shardings is not None else [None] * len(flat))
        leaves = []
        for (pth, leaf), sh in zip(flat, sh_flat):
            key = _SEP.join(_part(p) for p in pth)
            arr = data[key]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)

    # --- hygiene ----------------------------------------------------------

    def _gc_old(self) -> None:
        steps = self.steps()
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    def _gc_tmp(self) -> None:
        for name in os.listdir(self.dir):
            if name.startswith("tmp."):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)
