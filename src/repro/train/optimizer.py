"""AdamW + LR schedules (cosine and MiniCPM's WSD), sharding-preserving.

The optimizer is hand-rolled (no optax dependency in this container):
moments live in ``cfg.moment_dtype`` — fp32 by default, bf16 for the
>=100B configs so a single 256-chip pod holds params+moments (DESIGN.md
§9) — and inherit the parameter shardings leaf-for-leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"       # "cosine" | "wsd" | "const"
    wsd_stable_frac: float = 0.8   # WSD: fraction of steps at peak LR


def lr_at(step: jnp.ndarray, cfg: OptimizerConfig) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum((s + 1.0) / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        return cfg.lr * warm
    if cfg.schedule == "wsd":
        # Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395): hold peak LR for
        # the stable phase, then decay exponentially to 10%.
        stable_end = cfg.wsd_stable_frac * cfg.total_steps
        decay_len = jnp.maximum(cfg.total_steps - stable_end, 1.0)
        frac = jnp.clip((s - stable_end) / decay_len, 0.0, 1.0)
        decay = jnp.power(0.1, frac)
        return cfg.lr * warm * jnp.where(s < stable_end, 1.0, decay)
    # cosine
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * (0.5 * (1.0 + jnp.cos(jnp.pi * prog)))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: Any, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def init_moments(params: Any, moment_dtype) -> tuple[Any, Any]:
    def zeros(p):
        return jnp.zeros(p.shape, moment_dtype)

    return jax.tree.map(zeros, params), jax.tree.map(zeros, params)


def adamw_update(params, grads, m, v, step, opt: OptimizerConfig, moment_dtype):
    """One AdamW step.  Returns (params, m, v, lr, grad_norm)."""
    grads, gnorm = clip_by_global_norm(grads, opt.grad_clip)
    lr = lr_at(step, opt)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - jnp.power(opt.b1, t)
    bc2 = 1.0 - jnp.power(opt.b2, t)

    def upd(p, g, m_, v_):
        g32 = g.astype(jnp.float32)
        m_new = opt.b1 * m_.astype(jnp.float32) + (1 - opt.b1) * g32
        v_new = opt.b2 * v_.astype(jnp.float32) + (1 - opt.b2) * jnp.square(g32)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + opt.eps) + opt.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(moment_dtype),
                v_new.astype(moment_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(m)
    flat_v = treedef.flatten_up_to(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, new_m, new_v, lr, gnorm
