"""Data pipeline: deterministic synthetic LM batches with host-side
prefetch and device placement.

Offline substitution: no text corpora ship with this container, so the
pipeline generates Zipf-distributed token streams (vocabulary-rank
frequencies match natural-language statistics closely enough to exercise
the embedding/softmax shards).  The generator is seeded per (epoch, step)
so restarts are reproducible: resuming from step N regenerates exactly the
batches N, N+1, ... — which is what makes checkpoint/restart deterministic
end-to-end.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclasses.dataclass
class DataConfig:
    batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2
    prefetch: int = 2


class SyntheticLM:
    """Deterministic Zipf token batches; index-addressable for restart."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / np.power(ranks, cfg.zipf_a)
        self._p = p / p.sum()

    def batch_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step]))
        return rng.choice(
            self.cfg.vocab_size,
            size=(self.cfg.batch, self.cfg.seq_len),
            p=self._p,
        ).astype(np.int32)


class PrefetchLoader:
    """Host-side prefetch thread + device placement with a NamedSharding."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 sharding=None, model_cfg: Optional[ModelConfig] = None):
        self.source = source
        self.sharding = sharding
        self.model_cfg = model_cfg
        self._q: queue.Queue = queue.Queue(maxsize=source.cfg.prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _make(self, step: int) -> dict:
        tokens = self.source.batch_at(step)
        batch = {"tokens": tokens}
        if self.model_cfg is not None and self.model_cfg.prefix_len:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.source.cfg.seed, step, 7]))
            batch["prefix_embeds"] = rng.normal(
                0, 0.02, (tokens.shape[0], self.model_cfg.prefix_len,
                          self.model_cfg.d_model)).astype(np.float32)
        return batch

    def _work(self):
        while not self._stop.is_set():
            try:
                self._q.put(self._make(self._step), timeout=0.5)
                self._step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        host = self._q.get()
        if self.sharding is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        out = {}
        for k, v in host.items():
            sh = self.sharding.get(k) if isinstance(self.sharding, dict) else self.sharding
            out[k] = jax.device_put(v, sh) if sh is not None else jnp.asarray(v)
        return out

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
