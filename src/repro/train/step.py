"""Train-step builder: chunked cross-entropy + AdamW, optional gradient
compression, sharding-aware.

The loss never materialises the full (B, S, V) logits tensor: the final
hidden states are projected to the vocabulary in sequence chunks inside a
rematerialised ``lax.scan`` (so the backward recomputes each chunk's
logits).  At train_4k on qwen3 (V = 152k, 1M tokens) this turns a ~2.4 TB
fp32 logits+softmax footprint into chunk-sized slices.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.common import LogicalRules, ModelConfig, constrain
from .optimizer import OptimizerConfig, adamw_update, init_moments

PyTree = Any


def chunked_cross_entropy(x, head, targets, rules: LogicalRules,
                          chunk: int = 512, prefix: int = 0):
    """Mean next-token CE.  x: (B, S, d) final hidden; head: (d, V);
    targets: (B, St) token ids.  Position ``prefix + i`` predicts
    ``targets[:, i + 1]``."""
    st = targets.shape[1]
    xs = x[:, prefix: prefix + st - 1]
    tg = targets[:, 1:]
    b, s, d = xs.shape
    nchunk = max(int(np.ceil(s / chunk)), 1)
    pad = nchunk * chunk - s
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        tg = jnp.pad(tg, ((0, 0), (0, pad)), constant_values=-1)
    xs = xs.reshape(b, nchunk, chunk, d).transpose(1, 0, 2, 3)
    tg = tg.reshape(b, nchunk, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, inp):
        xc, tc = inp
        logits = jnp.einsum("bsd,dv->bsv", xc, head.astype(xc.dtype))
        logits = constrain(logits, rules, "batch", "seq", "vocab")
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(tc, 0)[..., None], axis=-1)[..., 0]
        valid = tc >= 0
        nll = jnp.where(valid, lse - picked, 0.0)
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    (total, count), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.int32(0)), (xs, tg))
    return total / jnp.maximum(count, 1)


@dataclasses.dataclass
class TrainState:
    params: PyTree
    m: PyTree
    v: PyTree
    step: jnp.ndarray
    ef: Optional[PyTree] = None      # gradient-compression error feedback


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "m", "v", "step", "ef"], meta_fields=[])


def init_state(cfg: ModelConfig, key: jax.Array,
               compression: bool = False) -> TrainState:
    params = api.init_params(cfg, key)
    m, v = init_moments(params, cfg.moment_dtype)
    ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params) \
        if compression else None
    return TrainState(params=params, m=m, v=v, step=jnp.zeros((), jnp.int32), ef=ef)


def abstract_state(cfg: ModelConfig, rules: LogicalRules,
                   compression: bool = False) -> TrainState:
    """ShapeDtypeStruct TrainState for the dry-run (no allocation)."""
    params = api.abstract_params(cfg, rules)

    def like(p, dtype):
        return jax.ShapeDtypeStruct(p.shape, dtype, sharding=p.sharding)

    m = jax.tree.map(lambda p: like(p, cfg.moment_dtype), params)
    v = jax.tree.map(lambda p: like(p, cfg.moment_dtype), params)
    ef = jax.tree.map(lambda p: like(p, jnp.bfloat16), params) if compression else None
    step = jax.ShapeDtypeStruct((), jnp.int32, sharding=rules.sharding())
    return TrainState(params=params, m=m, v=v, step=step, ef=ef)


def state_shardings(cfg: ModelConfig, rules: LogicalRules,
                    compression: bool = False) -> TrainState:
    ps = api.param_shardings(cfg, rules)
    return TrainState(params=ps, m=ps, v=ps,
                      step=rules.sharding(),
                      ef=ps if compression else None)


def batch_specs(cfg: ModelConfig, shape, rules: LogicalRules) -> dict:
    """ShapeDtypeStruct stand-ins for one global training batch."""
    b, s = shape.global_batch, shape.seq_len
    st = s - cfg.prefix_len
    out = {
        "tokens": jax.ShapeDtypeStruct((b, st), jnp.int32,
                                       sharding=rules.sharding("batch", "seq", dims=(b, st))),
    }
    if cfg.prefix_len:
        out["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.prefix_len, cfg.d_model), cfg.compute_dtype,
            sharding=rules.sharding("batch", "seq", "embed",
                                    dims=(b, cfg.prefix_len, cfg.d_model)))
    return out


def make_train_step(cfg: ModelConfig, rules: LogicalRules,
                    opt: OptimizerConfig = OptimizerConfig(),
                    compression: Optional[Callable] = None,
                    ce_chunk: int = 512):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        x, head = api.forward(params, batch["tokens"], cfg, rules,
                              return_hidden=True,
                              prefix_embeds=batch.get("prefix_embeds"))
        return chunked_cross_entropy(x, head, batch["tokens"], rules,
                                     chunk=ce_chunk, prefix=cfg.prefix_len)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        ef = state.ef
        if compression is not None:
            grads, ef = compression(grads, ef)
        params, m, v, lr, gnorm = adamw_update(
            state.params, grads, state.m, state.v, state.step, opt,
            cfg.moment_dtype)
        new_state = TrainState(params=params, m=m, v=v,
                               step=state.step + 1, ef=ef)
        return new_state, {"loss": loss, "lr": lr, "grad_norm": gnorm}

    return train_step
