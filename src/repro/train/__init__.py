from .checkpoint import CheckpointManager  # noqa: F401
from .data import DataConfig, PrefetchLoader, SyntheticLM  # noqa: F401
from .optimizer import OptimizerConfig, adamw_update, lr_at  # noqa: F401
from .step import (TrainState, abstract_state, batch_specs,  # noqa: F401
                   chunked_cross_entropy, init_state, make_train_step,
                   state_shardings)
