"""Jit'd public wrappers for the Pallas kernels.

``interpret=True`` everywhere by default: this container is CPU-only, so
the kernels execute through the Pallas interpreter for correctness; on a
real TPU deployment set ``REPRO_PALLAS_INTERPRET=0`` (or pass
``interpret=False``) to compile to Mosaic.
"""
from __future__ import annotations

import os

import jax

from . import flash_attention as _fa
from . import knn as _knn
from . import score as _score

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def knn_topk(cases: jax.Array, query: jax.Array, k: int,
             interpret: bool | None = None):
    return _knn.knn_topk(cases, query, k,
                         interpret=_INTERPRET if interpret is None else interpret)


def knn_topk_batch(cases: jax.Array, queries: jax.Array, k: int,
                   interpret: bool | None = None):
    return _knn.knn_topk_batch(
        cases, queries, k,
        interpret=_INTERPRET if interpret is None else interpret)


def score_matrix(marginals, ci, t_start, t_end, interpret: bool | None = None):
    return _score.score_matrix(
        marginals, ci, t_start, t_end,
        interpret=_INTERPRET if interpret is None else interpret)


def flash_attention(q, k, v, causal_offset: int = 0,
                    interpret: bool | None = None, **kw):
    return _fa.gqa_flash(q, k, v, causal_offset=causal_offset,
                         interpret=_INTERPRET if interpret is None else interpret,
                         **kw)
