"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def knn_topk_ref(cases: jax.Array, query: jax.Array, k: int):
    """Squared-Euclidean top-k: returns (distances, indices), ascending."""
    d2 = jnp.sum((cases - query[None, :]) ** 2, axis=1)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx


def score_matrix_ref(marginals: jax.Array, ci: jax.Array,
                     t_start: jax.Array, t_end: jax.Array):
    """Oracle score construction (Algorithm 1 lines 2–5), fused + masked.

    marginals: (J,) marginal throughput p_j(k) of each (job, scale) entry;
    ci: (T,) carbon intensities; t_start/t_end: (J,) inclusive/exclusive
    window bounds per entry.  Returns (J, T) scores, 0 outside windows.
    """
    t = jnp.arange(ci.shape[0])
    mask = (t[None, :] >= t_start[:, None]) & (t[None, :] < t_end[:, None])
    scores = marginals[:, None] / jnp.maximum(ci[None, :], 1e-9)
    return jnp.where(mask, scores, 0.0)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal_offset: int = 0):
    """Causal GQA attention oracle.  q: (B,Sq,H,D); k/v: (B,Sk,KV,D)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    qg = q.reshape(b, sq, hkv, hq // hkv, d).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) / np.sqrt(d)
    qpos = causal_offset + jnp.arange(sq)
    kpos = jnp.arange(k.shape[1])
    mask = qpos[:, None] >= kpos[None, :]
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d).astype(q.dtype)
