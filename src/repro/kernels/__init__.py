from . import flash_attention, gating, knn, ops, ref, score  # noqa: F401
