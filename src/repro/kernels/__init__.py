from . import flash_attention, knn, ops, ref, score  # noqa: F401
