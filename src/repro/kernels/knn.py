"""Pallas TPU kernels: tiled squared-Euclidean distances for the KNN
knowledge-base lookup (paper §4.3 / Algorithm 2).

Two entry points:

- ``knn_topk``        — single query against the (N, D) case base.  The
  kernel tiles the case base over N into VMEM blocks and computes the
  fused (x - q)^2 row reduction per block (one pass, no (N, D) temporary
  in HBM).
- ``knn_topk_batch``  — Q queries at once.  The kernel tiles a (Q, N)
  distance matrix into (BLOCK_Q, BLOCK_N) VMEM blocks and uses the MXU
  via the ``||q||^2 + ||x||^2 - 2 q.x`` expansion (one ``jnp.dot`` per
  block), which is the right shape for year-scale sweeps that match many
  slots / many runs per dispatch.

Top-k over the resulting distances runs through ``lax.top_k`` in the jit
wrapper — top-k over a few thousand scalars is not worth a custom kernel.

``interpret`` resolution: ``None`` (the default) auto-detects the backend
— the kernels compile to Mosaic on TPU and fall back to the Pallas
interpreter everywhere else (this container is CPU-only).  Callers can
force either mode explicitly (``KnowledgeBase(pallas_interpret=...)``
plumbs through to here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 256
BLOCK_Q = 128
# pad feature dim to the lane width so the VMEM tile is hardware-aligned
LANE = 128


@functools.cache
def default_interpret() -> bool:
    """Interpret everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def _dist_kernel(cases_ref, query_ref, out_ref):
    x = cases_ref[...].astype(jnp.float32)          # (BLOCK_N, Dp)
    q = query_ref[...].astype(jnp.float32)          # (1, Dp)
    diff = x - q
    out_ref[...] = jnp.sum(diff * diff, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _squared_distances(cases: jax.Array, query: jax.Array,
                       interpret: bool) -> jax.Array:
    n, d = cases.shape
    dp = ((d + LANE - 1) // LANE) * LANE
    np_ = ((n + BLOCK_N - 1) // BLOCK_N) * BLOCK_N
    cases_p = jnp.zeros((np_, dp), cases.dtype).at[:n, :d].set(cases)
    query_p = jnp.zeros((1, dp), query.dtype).at[0, :d].set(query)
    out = pl.pallas_call(
        _dist_kernel,
        grid=(np_ // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((BLOCK_N, dp), lambda i: (i, 0)),
            pl.BlockSpec((1, dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        interpret=interpret,
    )(cases_p, query_p)
    return out[:n, 0]


def squared_distances(cases: jax.Array, query: jax.Array,
                      interpret: bool | None = None) -> jax.Array:
    """(N, D), (D,) -> (N,) squared Euclidean distances."""
    return _squared_distances(cases, query, _resolve_interpret(interpret))


def knn_topk(cases: jax.Array, query: jax.Array, k: int,
             interpret: bool | None = None):
    """Top-k nearest cases: returns (distances, indices) ascending."""
    d2 = squared_distances(cases, query, interpret=interpret)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx


# --- batched multi-query path ---------------------------------------------


def _dist_kernel_batch(queries_ref, cases_ref, out_ref):
    q = queries_ref[...].astype(jnp.float32)        # (BLOCK_Q, Dp)
    x = cases_ref[...].astype(jnp.float32)          # (BLOCK_N, Dp)
    qn = jnp.sum(q * q, axis=1, keepdims=True)      # (BLOCK_Q, 1)
    xn = jnp.sum(x * x, axis=1, keepdims=True)      # (BLOCK_N, 1)
    # MXU block: -2 q.x^T, then the rank-1 norm corrections on the VPU.
    cross = jnp.dot(q, x.T, preferred_element_type=jnp.float32)
    out_ref[...] = qn + xn.T - 2.0 * cross


@functools.partial(jax.jit, static_argnames=("interpret",))
def _squared_distances_batch(cases: jax.Array, queries: jax.Array,
                             interpret: bool) -> jax.Array:
    n, d = cases.shape
    qn, _ = queries.shape
    dp = ((d + LANE - 1) // LANE) * LANE
    np_ = ((n + BLOCK_N - 1) // BLOCK_N) * BLOCK_N
    qp = ((qn + BLOCK_Q - 1) // BLOCK_Q) * BLOCK_Q
    cases_p = jnp.zeros((np_, dp), cases.dtype).at[:n, :d].set(cases)
    queries_p = jnp.zeros((qp, dp), queries.dtype).at[:qn, :d].set(queries)
    out = pl.pallas_call(
        _dist_kernel_batch,
        grid=(qp // BLOCK_Q, np_ // BLOCK_N),
        in_specs=[
            pl.BlockSpec((BLOCK_Q, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_N, dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_Q, BLOCK_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp, np_), jnp.float32),
        interpret=interpret,
    )(queries_p, cases_p)
    return out[:qn, :n]


def squared_distances_batch(cases: jax.Array, queries: jax.Array,
                            interpret: bool | None = None) -> jax.Array:
    """(N, D), (Q, D) -> (Q, N) squared Euclidean distances.

    Uses the dot-product expansion (MXU-friendly); values can differ from
    the fused single-query kernel in the last few ulps and tiny negatives
    are possible — callers clamp at zero.
    """
    return _squared_distances_batch(cases, queries,
                                    _resolve_interpret(interpret))


def knn_topk_batch(cases: jax.Array, queries: jax.Array, k: int,
                   interpret: bool | None = None):
    """Batched top-k: (Q, D) queries -> ((Q, k) distances, (Q, k) indices)."""
    d2 = squared_distances_batch(cases, queries, interpret=interpret)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx
