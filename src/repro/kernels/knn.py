"""Pallas TPU kernel: tiled squared-Euclidean distances for the KNN
knowledge-base lookup (paper §4.3 / Algorithm 2).

The case base is (N, D) with N up to a few thousand z-scored Table-2
states; the query is one state vector.  The kernel tiles the case base
over N into VMEM blocks, computes the fused (x - q)^2 row reduction per
block (one pass, no (N, D) temporary in HBM), and the jit wrapper applies
``lax.top_k`` to the resulting (N,) distance vector — top-k over a few
thousand scalars is not worth a custom kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 256
# pad feature dim to the lane width so the VMEM tile is hardware-aligned
LANE = 128


def _dist_kernel(cases_ref, query_ref, out_ref):
    x = cases_ref[...].astype(jnp.float32)          # (BLOCK_N, Dp)
    q = query_ref[...].astype(jnp.float32)          # (1, Dp)
    diff = x - q
    out_ref[...] = jnp.sum(diff * diff, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def squared_distances(cases: jax.Array, query: jax.Array,
                      interpret: bool = True) -> jax.Array:
    """(N, D), (D,) -> (N,) squared Euclidean distances."""
    n, d = cases.shape
    dp = ((d + LANE - 1) // LANE) * LANE
    np_ = ((n + BLOCK_N - 1) // BLOCK_N) * BLOCK_N
    cases_p = jnp.zeros((np_, dp), cases.dtype).at[:n, :d].set(cases)
    query_p = jnp.zeros((1, dp), query.dtype).at[0, :d].set(query)
    out = pl.pallas_call(
        _dist_kernel,
        grid=(np_ // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((BLOCK_N, dp), lambda i: (i, 0)),
            pl.BlockSpec((1, dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        interpret=interpret,
    )(cases_p, query_p)
    return out[:n, 0]


def knn_topk(cases: jax.Array, query: jax.Array, k: int,
             interpret: bool = True):
    """Top-k nearest cases: returns (distances, indices) ascending."""
    d2 = squared_distances(cases, query, interpret=interpret)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx
