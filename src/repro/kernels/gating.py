"""Pallas kernel for DAG dependency gating: the per-slot gather/scatter
that decrements child in-degree counters when parents finish.

The scan engine (``core/scan_engine.py``) carries a per-row ``pred_left``
vector; each slot it needs ``dec[child] = sum over edges of
fin[parent]`` — a gather over the edge parent list followed by a
segment scatter-add over the edge child list.  Three implementations:

- :func:`dep_decrement` — pure ``jnp`` gather + ``.at[].add`` scatter.
  On XLA:CPU the scatter lowers to a serial per-element loop, so the
  scan engine keeps it only as the fallback for workloads whose max
  in-degree is too wide for the dense transpose.
- :func:`dep_decrement_gather` — the contraction transposed into a
  dense padded predecessor-list gather + row sum; the scan engine's
  default whenever the max in-degree is modest (~6x cheaper on CPU,
  exactly equal counts because integer addition commutes).
- :func:`dep_decrement_pallas` — the same contraction as a Pallas
  kernel.  The edge lists are tiled over the grid; every grid step maps
  to the *same* output block (Pallas serialises revisited output blocks,
  so the accumulation is race-free) and performs its tile's gather +
  scatter in VMEM.  On TPU this keeps the whole decrement on-chip; off
  TPU it runs in interpreter mode (this container is CPU-only), so it is
  exercised for parity, not speed — ``default_interpret`` resolution
  follows ``kernels/knn.py``.

All three return identical int32 counts (asserted in
``tests/test_scan_engine.py``); integer arithmetic, so equality is exact
on every backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .knn import _resolve_interpret

EDGE_BLOCK = 1024


def dep_decrement(fin: jax.Array, parents: jax.Array, children: jax.Array,
                  n: int) -> jax.Array:
    """``dec[c] = #{edges (p, c) with fin[p]}`` as pure jnp ops.

    ``parents``/``children`` may be padded: point padded entries at a row
    whose ``fin`` is always False (the scan engine uses its padding rows).
    """
    contrib = fin[parents].astype(jnp.int32)
    return jnp.zeros(n, dtype=jnp.int32).at[children].add(contrib)


def dep_decrement_gather(fin: jax.Array, pred_rows: jax.Array) -> jax.Array:
    """The same contraction, transposed: ``pred_rows`` is each row's
    padded predecessor list (``(n, max_in_degree)``; padding points at a
    row whose ``fin`` is always False).

    Integer addition, so the counts are exactly equal to the scatter
    form in any summation order — but on XLA:CPU ``.at[].add`` lowers to
    a serial per-element scatter loop (~100us per slot at a few thousand
    edges) while this is one vectorized gather plus a row sum (~6x
    cheaper).  The scan engine uses it whenever the workload's max
    in-degree is small enough for the dense transpose to pay off."""
    return jnp.sum(fin[pred_rows].astype(jnp.int32), axis=1)


def _gating_kernel(fin_ref, parents_ref, children_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    par = parents_ref[...]
    chd = children_ref[...]
    contrib = fin_ref[...][par].astype(jnp.int32)
    out_ref[...] = out_ref[...].at[chd].add(contrib)


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def _dep_decrement_pallas(fin, parents, children, n: int, interpret: bool):
    e = parents.shape[0]
    ep = max(EDGE_BLOCK, ((e + EDGE_BLOCK - 1) // EDGE_BLOCK) * EDGE_BLOCK)
    # pad edges with a self-loop on the last (padding) row: fin there is
    # False by construction, so padded edges contribute 0
    pad_row = n - 1
    parents_p = jnp.full(ep, pad_row, parents.dtype).at[:e].set(parents)
    children_p = jnp.full(ep, pad_row, children.dtype).at[:e].set(children)
    return pl.pallas_call(
        _gating_kernel,
        grid=(ep // EDGE_BLOCK,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(fin, parents_p, children_p)


def dep_decrement_pallas(fin: jax.Array, parents: jax.Array,
                         children: jax.Array, n: int,
                         interpret: bool | None = None) -> jax.Array:
    """Pallas-kernel variant of :func:`dep_decrement` (see module doc).

    The caller guarantees ``fin[n - 1]`` is a padding row that never
    finishes (the scan engine's layout); edge padding self-loops there.
    """
    if parents.shape[0] == 0:
        return jnp.zeros(n, dtype=jnp.int32)
    return _dep_decrement_pallas(fin, parents, children, n,
                                 _resolve_interpret(interpret))
