"""Pallas TPU kernel: GQA causal flash attention (online softmax).

Block-tiled for the MXU: Q tiles of (BLOCK_Q, D) stream against K/V tiles
of (BLOCK_K, D) held in VMEM; the running (m, l, acc) online-softmax state
lives in VMEM scratch and is carried across the innermost (sequential) KV
grid dimension.  GQA is handled in the index maps: query head h reads KV
head ``h // group`` — no KV replication in HBM.

Grid: (batch, q_heads, nQ, nK) with ``dimension_semantics = (parallel,
parallel, parallel, arbitrary)``; the output tile is written at the last
KV step.  Validated in interpret mode against ``ref.flash_attention_ref``
(this container is CPU-only; TPU is the target).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale, block_q, block_k, seq_k, causal_offset, n_k):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                  # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = causal_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = (q_pos >= k_pos) & (k_pos < seq_k)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # (BQ, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _emit():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal_offset", "interpret",
                                             "block_q", "block_k"))
def gqa_flash(q: jax.Array, k: jax.Array, v: jax.Array,
              causal_offset: int = 0, interpret: bool = True,
              block_q: int = BLOCK_Q, block_k: int = BLOCK_K) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D) -> (B, Sq, H, D), causal."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    scale = 1.0 / np.sqrt(d)

    sq_p = ((sq + block_q - 1) // block_q) * block_q
    sk_p = ((sk + block_k - 1) // block_k) * block_k
    qt = jnp.moveaxis(q, 2, 1)                        # (B, H, Sq, D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if sq_p != sq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
    n_q, n_k = sq_p // block_q, sk_p // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_k=sk, causal_offset=causal_offset, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :sq]
    return jnp.moveaxis(out, 1, 2)
