"""Pallas TPU kernel: fused oracle score construction (Algorithm 1, lines
2–5).

The oracle enumerates (job, scale) entries against T time slots and scores
each cell ``p_j(k) / CI_t`` masked to the entry's feasibility window
``[t_start, t_end)``.  Materialising mask and quotient separately costs
3 HBM round-trips over a (J, T) matrix; the kernel fuses reciprocal,
broadcast-multiply and window masking in one VMEM pass, tiled (BJ, BT).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_J = 256
BLOCK_T = 128


def _score_kernel(marg_ref, ts_ref, te_ref, ci_ref, out_ref, *, block_t):
    _ = pl.program_id(1)          # grid order: (t, j)
    t0 = pl.program_id(0) * block_t
    marg = marg_ref[...].astype(jnp.float32)          # (BJ, 1)
    ts = ts_ref[...].astype(jnp.int32)                # (BJ, 1)
    te = te_ref[...].astype(jnp.int32)
    ci = ci_ref[...].astype(jnp.float32)              # (1, BT)
    t_idx = t0 + jax.lax.broadcasted_iota(jnp.int32, (1, block_t), 1)
    score = marg / jnp.maximum(ci, 1e-9)
    mask = (t_idx >= ts) & (t_idx < te)
    out_ref[...] = jnp.where(mask, score, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def score_matrix(marginals: jax.Array, ci: jax.Array, t_start: jax.Array,
                 t_end: jax.Array, interpret: bool = True) -> jax.Array:
    """(J,), (T,), (J,), (J,) -> (J, T) masked scores."""
    j, t = marginals.shape[0], ci.shape[0]
    jp = ((j + BLOCK_J - 1) // BLOCK_J) * BLOCK_J
    tp = ((t + BLOCK_T - 1) // BLOCK_T) * BLOCK_T
    marg = jnp.zeros((jp, 1), jnp.float32).at[:j, 0].set(marginals)
    ts = jnp.zeros((jp, 1), jnp.int32).at[:j, 0].set(t_start)
    te = jnp.zeros((jp, 1), jnp.int32).at[:j, 0].set(t_end)
    civ = jnp.full((1, tp), 1.0, jnp.float32).at[0, :t].set(ci)
    out = pl.pallas_call(
        functools.partial(_score_kernel, block_t=BLOCK_T),
        grid=(tp // BLOCK_T, jp // BLOCK_J),
        in_specs=[
            pl.BlockSpec((BLOCK_J, 1), lambda ti, ji: (ji, 0)),
            pl.BlockSpec((BLOCK_J, 1), lambda ti, ji: (ji, 0)),
            pl.BlockSpec((BLOCK_J, 1), lambda ti, ji: (ji, 0)),
            pl.BlockSpec((1, BLOCK_T), lambda ti, ji: (0, ti)),
        ],
        out_specs=pl.BlockSpec((BLOCK_J, BLOCK_T), lambda ti, ji: (ji, ti)),
        out_shape=jax.ShapeDtypeStruct((jp, tp), jnp.float32),
        interpret=interpret,
    )(marg, ts, te, civ)
    return out[:j, :t]
