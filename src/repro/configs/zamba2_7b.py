"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks
(arXiv:2411.15242).  81 mamba layers; one shared GQA+SwiGLU block applied
after every 6th layer (13 applications, weights reused)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, ssm_state=64, shared_attn_every=6,
)
