"""musicgen-large [audio] — decoder-only over EnCodec tokens
(arXiv:2306.05284).  The EnCodec tokenizer is the modality stub: inputs
are already audio-token ids (vocab 2048); no embedding prefix is needed.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048,
)
