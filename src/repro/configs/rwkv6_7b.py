"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free
(arXiv:2404.05892).  64 heads of dim 64; runs long_500k (O(1) state)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536,
)
