"""internvl2-2b [vlm] — InternViT + InternLM2 backbone (arXiv:2404.16821).

The InternViT frontend is a STUB: input_specs() supplies 256 precomputed
patch embeddings per sample (prefix_len), prepended to the text tokens.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553, prefix_len=256,
)
