"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Every config is from public literature; the source and verification tier
are quoted in each module docstring.  ``reduced()`` produces the
small-footprint variant used by the per-arch CPU smoke tests (same family
and wiring, tiny widths).
"""
from __future__ import annotations

import dataclasses

from repro.models.common import ModelConfig

from .command_r_plus_104b import CONFIG as command_r_plus_104b
from .dbrx_132b import CONFIG as dbrx_132b
from .internvl2_2b import CONFIG as internvl2_2b
from .llama3_8b import CONFIG as llama3_8b
from .minicpm_2b import CONFIG as minicpm_2b
from .musicgen_large import CONFIG as musicgen_large
from .qwen3_moe_235b_a22b import CONFIG as qwen3_moe_235b_a22b
from .rwkv6_7b import CONFIG as rwkv6_7b
from .stablelm_1_6b import CONFIG as stablelm_1_6b
from .zamba2_7b import CONFIG as zamba2_7b

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        internvl2_2b, command_r_plus_104b, minicpm_2b, llama3_8b,
        stablelm_1_6b, musicgen_large, zamba2_7b, rwkv6_7b, dbrx_132b,
        qwen3_moe_235b_a22b,
    ]
}

# long_500k requires sub-quadratic attention: only the SSM/hybrid archs run
# it (full-attention archs skip; recorded in DESIGN.md §6).
LONG_CONTEXT_ARCHS = {"rwkv6-7b", "zamba2-7b"}


def get(name: str) -> ModelConfig:
    return ARCHS[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    import jax.numpy as jnp

    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 3 if cfg.family != "hybrid" else 7),
        d_model=128,
        num_heads=4, num_kv_heads=2 if cfg.num_kv_heads < cfg.num_heads else 4,
        d_ff=256,
        vocab_size=512,
        prefix_len=8 if cfg.prefix_len else 0,
        param_dtype=jnp.float32, moment_dtype=jnp.float32,
        compute_dtype=jnp.float32,
        attention_chunk=64,
        shared_attn_every=3,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, experts_per_token=2)
    if cfg.family == "ssm":
        kw.update(num_heads=2, num_kv_heads=2)   # d_model/64 = 2 heads
    if cfg.family == "hybrid":
        kw.update(ssm_state=16)
    return dataclasses.replace(cfg, **kw)
