"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 (hf:Qwen/Qwen3-30B-A3B
scaled per the assignment).  235B total / 22B active; bf16 params+moments."""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    d_ff=1536, vocab_size=151936,
    num_experts=128, experts_per_token=8,
    param_dtype=jnp.bfloat16, moment_dtype=jnp.bfloat16,
)
