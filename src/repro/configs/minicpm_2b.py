"""minicpm-2b [dense] — WSD schedule, llama-like (arXiv:2404.06395).

36 heads do not divide the 16-way model axis: attention params/activations
fall back to replication over `model` (TP applies to the MLP and vocab),
see LogicalRules size-aware fallback.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753, lr_schedule="wsd",
)
