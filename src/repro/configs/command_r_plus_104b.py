"""command-r-plus-104b [dense] — GQA, no-bias (hf:CohereForAI/c4ai-command-r-v01).

104B params: moments are kept in bf16 so params+Adam fit one 256-chip
v5e pod (see DESIGN.md §9).
"""
import jax.numpy as jnp

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=33792, vocab_size=256000,
    param_dtype=jnp.bfloat16, moment_dtype=jnp.bfloat16,
)
