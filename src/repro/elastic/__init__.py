from .compression import make_compressor  # noqa: F401
from .rescale import ElasticTrainer, RescalePlan  # noqa: F401
