"""Error-feedback gradient compression for the cross-pod data-parallel
all-reduce (DESIGN.md §10).

At 2+ pods the gradient all-reduce crosses the slow inter-pod links; both
compressors cut its payload:

- ``int8``: per-tensor max-abs scaling to int8 (4x vs fp32 on the wire);
- ``topk``: keep the largest ``ratio`` fraction of entries per tensor.

Both keep an error-feedback residual so the quantisation error is fed
back into the next step's gradient — compression is then unbiased *over
time* (Karimireddy et al.'s EF-SGD argument), which the tests check by
verifying the cumulative applied gradient converges to the true sum.

The compressor runs inside the jitted train step: compress -> (wire) ->
decompress is algebraically a no-op plus residual bookkeeping, so XLA
sees the small wire dtype at the collective boundary.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def _int8_roundtrip(g32: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_roundtrip(g32: jax.Array, ratio: float):
    flat = g32.reshape(-1)
    k = max(int(flat.shape[0] * ratio), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    return kept.reshape(g32.shape)


def make_compressor(kind: str = "int8", ratio: float = 0.05
                    ) -> Callable[[PyTree, Optional[PyTree]], tuple[PyTree, PyTree]]:
    """Returns compress(grads, ef) -> (decompressed_grads, new_ef)."""

    def compress(grads: PyTree, ef: Optional[PyTree]):
        if ef is None:
            ef = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.bfloat16), grads)

        def one(g, e):
            g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
            if kind == "int8":
                sent = _int8_roundtrip(g32)
            elif kind == "topk":
                sent = _topk_roundtrip(g32, ratio)
            else:
                raise ValueError(kind)
            resid = g32 - sent
            return sent.astype(g.dtype), resid.astype(jnp.bfloat16)

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(ef)
        pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (tdef.unflatten([p[0] for p in pairs]),
                tdef.unflatten([p[1] for p in pairs]))

    return compress
