"""Elastic training runtime: CarbonFlex-driven rescale + fault tolerance.

This is the mechanism layer the paper delegates to Slurm (`scancel` ->
checkpoint -> resubmit at a new scale, §5): the trainer runs a jitted
train step on a mesh whose ``data`` extent equals the current allocation
``k``; when the resource manager (CarbonFlexPolicy / MPC / any Policy)
changes ``k``, the trainer checkpoints, rebuilds the mesh, restores the
state under the new shardings and re-jits.  Faults are handled the same
way: any step failure (or an injected fault) falls back to the last
checkpoint.

Straggler mitigation: the trainer tracks a rolling median step time; a
step slower than ``straggler_factor`` x median marks the slot degraded —
the driver reports it to the scheduler, which treats the job's throughput
accordingly (and, on a real cluster, would swap the slow host out at the
next rescale boundary — here the rescale path doubles as the swap).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

import jax

from repro.launch.mesh import make_mesh
from repro.models import LogicalRules, ModelConfig
from repro.train import (CheckpointManager, OptimizerConfig, SyntheticLM,
                         TrainState, init_state, make_train_step,
                         state_shardings)


@dataclasses.dataclass
class RescalePlan:
    """One elastic allocation interval."""

    k: int                 # data-parallel degree (paper: servers for the job)
    steps: int             # train steps to run at this scale


class ElasticTrainer:
    def __init__(self, cfg: ModelConfig, data: SyntheticLM,
                 opt: OptimizerConfig, ckpt_dir: str,
                 model_axis: int = 1, seed: int = 0,
                 compression: Optional[Callable] = None,
                 straggler_factor: float = 3.0):
        self.cfg = cfg
        self.data = data
        self.opt = opt
        self.model_axis = model_axis
        self.ckpt = CheckpointManager(ckpt_dir)
        self.compression = compression
        self.straggler_factor = straggler_factor
        self._key = jax.random.key(seed)
        self._state: Optional[TrainState] = None
        self._k = 0
        self._step_fn = None
        self._rules = None
        self.step_times: list[float] = []
        self.stragglers = 0
        self.rescales = 0
        self.recoveries = 0

    # ----- mesh / scale management -----------------------------------------

    def _build(self, k: int) -> None:
        mesh = make_mesh((k, self.model_axis), ("data", "model"))
        self._rules = LogicalRules(mesh)
        self._step_fn = jax.jit(make_train_step(
            self.cfg, self._rules, self.opt, compression=self.compression,
            ce_chunk=128))
        shardings = state_shardings(self.cfg, self._rules,
                                    compression=self.compression is not None)
        if self._state is None:
            latest = self.ckpt.latest_step()
            template = jax.eval_shape(
                lambda: init_state(self.cfg, jax.random.key(0),
                                   compression=self.compression is not None))
            if latest is not None:
                self._state = self.ckpt.restore(template, shardings=shardings)
                self.recoveries += 1
            else:
                self._state = init_state(
                    self.cfg, self._key,
                    compression=self.compression is not None)
        else:
            # live rescale: checkpoint -> re-place under the new shardings
            self.ckpt.save(int(self._state.step), self._state, blocking=True)
            template = jax.eval_shape(lambda: self._state)
            self._state = self.ckpt.restore(template, shardings=shardings)
            self.rescales += 1
        self._k = k

    def set_scale(self, k: int) -> None:
        if k != self._k:
            self._build(k)

    # ----- training ---------------------------------------------------------

    def run(self, plan: list[RescalePlan], checkpoint_every: int = 50,
            fault_at: Optional[int] = None) -> dict:
        """Execute an elastic plan; ``fault_at``: inject a failure at that
        global step (the trainer must recover from the last checkpoint)."""
        losses = []
        faulted = False
        for phase in plan:
            if phase.k <= 0:       # suspended (paper: job paused at high CI)
                continue
            self.set_scale(phase.k)
            # a phase advances state.step by phase.steps — after a fault
            # rollback the re-done steps are NOT double-counted
            target = int(self._state.step) + phase.steps
            while int(self._state.step) < target:
                step_no = int(self._state.step)
                batch = {"tokens": self.data.batch_at(step_no)}
                t0 = time.time()
                try:
                    if fault_at is not None and step_no == fault_at and not faulted:
                        faulted = True
                        raise RuntimeError("injected node failure")
                    self._state, metrics = self._step_fn(self._state, batch)
                    loss = float(metrics["loss"])
                except RuntimeError:
                    # fault: restore last checkpoint and continue
                    template = jax.eval_shape(lambda: self._state)
                    shardings = state_shardings(
                        self.cfg, self._rules,
                        compression=self.compression is not None)
                    if self.ckpt.latest_step() is not None:
                        self._state = self.ckpt.restore(template,
                                                        shardings=shardings)
                    self.recoveries += 1
                    continue
                dt = time.time() - t0
                self.step_times.append(dt)
                med = float(np.median(self.step_times[-20:]))
                if len(self.step_times) > 5 and dt > self.straggler_factor * med:
                    self.stragglers += 1
                losses.append(loss)
                if step_no and step_no % checkpoint_every == 0:
                    self.ckpt.save(step_no, self._state)
        self.ckpt.wait()
        self.ckpt.save(int(self._state.step), self._state, blocking=True)
        return {
            "losses": losses,
            "final_step": int(self._state.step),
            "rescales": self.rescales,
            "recoveries": self.recoveries,
            "stragglers": self.stragglers,
        }
