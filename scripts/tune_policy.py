"""Dev harness: consistent in-process A/B of CarbonFlexPolicy variants.

Each variant is one knowledge-base configuration (feature weights) run
through the same declarative ``Scenario`` — the experiment driver owns the
learn/execute pipeline, so a variant is just ``run(sc, ["carbonflex"],
kb_kwargs=...)`` against the shared reference runs.

Usage: PYTHONPATH=src python scripts/tune_policy.py [--quick]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.experiment import Scenario, run


def run_variants(variants, region="south-australia", seed=1, capacity=150):
    sc = Scenario(region=region, capacity=capacity, learn_weeks=3, seed=seed)
    ref = run(sc, ["carbon-agnostic", "carbonflex-mpc", "oracle"])
    base_carbon = ref.carbon_g("carbon-agnostic")
    print(f"[{region} seed={seed}] oracle {ref.savings('oracle'):6.2f}%  "
          f"wait {ref.mean_wait('oracle'):.1f}")
    print(f"  {'carbonflex-mpc':28s} savings {ref.savings('carbonflex-mpc'):6.2f}%"
          f"  wait {ref.mean_wait('carbonflex-mpc'):5.1f}"
          f"  viol {ref.violation_rate('carbonflex-mpc'):.3f}")
    out = {}
    for name, kb_kwargs in variants.items():
        r = run(sc, ["carbonflex"], kb_kwargs=kb_kwargs)
        sim = r.weekly["carbonflex"][0]
        ms = np.array([s.provisioned for s in sim.slots])
        cis = np.array([s.ci for s in sim.slots])
        savings = 100.0 * (1.0 - r.carbon_g("carbonflex") / base_carbon)
        print(f"  {name:28s} savings {savings:6.2f}%  wait {sim.mean_wait:5.1f}"
              f"  viol {sim.violation_rate:.3f}"
              f"  corr {np.corrcoef(ms, cis)[0, 1]:6.3f}")
        out[name] = savings
    return out


if __name__ == "__main__":
    variants = {
        "ci-only (bw=0)": dict(backlog_weight=0.0),
        "rel-backlog bw=1": dict(backlog_weight=1.0),
        "rel-backlog bw=2": dict(backlog_weight=2.0),
        "bw=1 + qw=0.2": dict(backlog_weight=1.0, queue_weight=0.2),
        "bw=1 + aw=0.5": dict(backlog_weight=1.0, arrival_weight=0.5),
    }
    seeds = [1] if "--quick" in sys.argv else [1, 3]
    for seed in seeds:
        run_variants(variants, seed=seed)
