"""Dev harness: MPC knob-grid tuner for the receding-horizon policies.

Grids :class:`MPCConfig` knobs (horizon, replan cadence, length
percentile, clean-window fraction) through one shared world: the
scenario is materialized and its knowledge base learned exactly once,
then every knob combination becomes one scan-engine ``SimCase`` in a
single ``simulate_many`` batch — structurally identical cells fuse into
vmapped device programs, so the whole grid is a handful of device
dispatches rather than a grid of full runs.

The printed gap is measured against the oracle run in the same batch;
the reference rows (carbon-agnostic / greedy carbonflex / oracle) anchor
the numbers.  This is the harness that picked the shipped
``MPCConfig()`` defaults.

Usage: PYTHONPATH=src python scripts/tune_policy.py [--quick] [--scale]
"""
import dataclasses
import itertools
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.mpc import MPCConfig
from repro.core.simulator import SimCase, simulate_many
from repro.experiment import Scenario
from repro.experiment.driver import prepare_context
from repro.experiment.registry import make_policy
from repro.experiment.scenario import WEEK

REFS = ("carbon-agnostic", "carbonflex", "oracle")


def default_grid(scale: bool):
    """The knob grid: horizon x replan cadence x length percentile, plus
    the clean-window fraction axis when tuning ``carbonflex-scale``."""
    horizons = (24, 48, 72)
    replans = (1, 6)
    percentiles = (75.0, 85.0, 95.0)
    cleans = (0.15, 0.25, 0.4) if scale else (0.25,)
    return [MPCConfig(horizon=h, replan_every=r, percentile=p, clean_frac=c)
            for h, r, p, c in itertools.product(horizons, replans,
                                                percentiles, cleans)]


def tune(policy="carbonflex-mpc", grid=None, region="south-australia",
         seed=1, capacity=40, learn_weeks=2, scale=False):
    if grid is None:
        grid = default_grid(scale)
    sc = Scenario(region=region, capacity=capacity, learn_weeks=learn_weeks,
                  seed=seed, engine="scan")
    mat = sc.materialize()
    names = REFS + (policy,)
    ctx = prepare_context(mat, names)
    horizon = sc.eval_weeks * WEEK

    def case(name, pctx, label):
        return SimCase(jobs=mat.eval_jobs, ci=mat.ci, cluster=mat.cluster,
                       policy=make_policy(name, pctx), t0=mat.t0,
                       horizon=horizon, engine="scan", label=label)

    cases = [case(n, ctx, n) for n in REFS]
    labels = list(REFS)
    for cfg in grid:
        lab = (f"H={cfg.horizon:<3d} R={cfg.replan_every} "
               f"p{cfg.percentile:g}"
               + (f" cf={cfg.clean_frac:g}" if scale else ""))
        cases.append(case(policy, dataclasses.replace(ctx, mpc=cfg), lab))
        labels.append(lab)
    results = simulate_many(cases)      # one batched scan dispatch

    by = dict(zip(labels, results))
    base = by["carbon-agnostic"].carbon_g
    orc_sv = 100.0 * (1.0 - by["oracle"].carbon_g / base)
    print(f"[{policy} | {region} seed={seed} cap={capacity}] "
          f"oracle {orc_sv:6.2f}%")
    out = {}
    for lab in labels:
        r = by[lab]
        sv = 100.0 * (1.0 - r.carbon_g / base)
        out[lab] = orc_sv - sv
        print(f"  {lab:24s} savings {sv:6.2f}%  gap {orc_sv - sv:6.2f}pp"
              f"  wait {r.mean_wait:5.1f}  viol {r.violation_rate:.3f}")
    best = min((lab for lab in labels if lab not in REFS), key=out.get)
    print(f"  -> best: {best}  (gap {out[best]:.2f}pp)")
    return out


if __name__ == "__main__":
    quick = "--quick" in sys.argv
    scale = "--scale" in sys.argv
    policy = "carbonflex-scale" if scale else "carbonflex-mpc"
    grid = None
    if quick:
        grid = [MPCConfig(horizon=h, percentile=p)
                for h in (24, 48) for p in (75.0, 85.0)]
    for seed in ([1] if quick else [1, 3]):
        tune(policy=policy, grid=grid, seed=seed, scale=scale,
             capacity=20 if quick else 40,
             learn_weeks=1 if quick else 2)
