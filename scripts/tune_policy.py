"""Dev harness: consistent in-process A/B of CarbonFlexPolicy variants.

Usage: PYTHONPATH=src python scripts/tune_policy.py [--quick]
"""
import sys

import numpy as np

from repro.core import (CarbonService, ClusterConfig, KnowledgeBase,
                        CarbonFlexPolicy, OraclePolicy, learn_window,
                        simulate, baselines)
from repro.core.policy import CarbonFlexMPCPolicy
from repro.traces import TraceSpec, generate_trace, mean_length


def setup(region="south-australia", family="azure", capacity=150, seed=1):
    cluster = ClusterConfig.default(capacity=capacity)
    hours = 24 * 7 * 4
    ci = CarbonService.synthetic(region, hours + 24 * 30, seed=seed)
    spec = TraceSpec(family=family, hours=hours, capacity=capacity, seed=seed + 1)
    jobs = generate_trace(spec, cluster.queues)
    eval_jobs = [j for j in jobs if 24 * 21 <= j.arrival < 24 * 28]
    return cluster, ci, spec, jobs, eval_jobs


def run_variants(variants, region="south-australia", seed=1):
    cluster, ci, spec, jobs, eval_jobs = setup(region=region, seed=seed)
    base = simulate(eval_jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                    t0=24 * 21, horizon=24 * 7)
    orc = simulate(eval_jobs, ci, cluster, OraclePolicy(backend="numpy"),
                   t0=24 * 21, horizon=24 * 7)
    print(f"[{region} seed={seed}] oracle {orc.savings_vs(base):6.2f}%  wait {orc.mean_wait:.1f}")
    out = {}
    mpc = simulate(eval_jobs, ci, cluster, CarbonFlexMPCPolicy(), t0=24 * 21, horizon=24 * 7)
    print(f"  {'carbonflex-mpc':28s} savings {mpc.savings_vs(base):6.2f}%  wait {mpc.mean_wait:5.1f}"
          f"  viol {mpc.violation_rate:.3f}")
    for name, kb_kwargs in variants.items():
        kb = KnowledgeBase(**kb_kwargs)
        learn_window(kb, jobs, ci, 0, 24 * 7, cluster.capacity, 3,
                     offsets=(0, 24 * 7, 24 * 14), backend="numpy")
        r = simulate(eval_jobs, ci, cluster, CarbonFlexPolicy(kb),
                     t0=24 * 21, horizon=24 * 7)
        ms = np.array([s.provisioned for s in r.slots])
        cis = np.array([s.ci for s in r.slots])
        print(f"  {name:28s} savings {r.savings_vs(base):6.2f}%  wait {r.mean_wait:5.1f}"
              f"  viol {r.violation_rate:.3f}  corr {np.corrcoef(ms, cis)[0, 1]:6.3f}")
        out[name] = r.savings_vs(base)
    return out


if __name__ == "__main__":
    variants = {
        "ci-only (bw=0)": dict(backlog_weight=0.0),
        "rel-backlog bw=1": dict(backlog_weight=1.0),
        "rel-backlog bw=2": dict(backlog_weight=2.0),
        "bw=1 + qw=0.2": dict(backlog_weight=1.0, queue_weight=0.2),
        "bw=1 + aw=0.5": dict(backlog_weight=1.0, arrival_weight=0.5),
    }
    seeds = [1] if "--quick" in sys.argv else [1, 3]
    for seed in seeds:
        run_variants(variants, seed=seed)
