"""Shared benchmark harness: one simulated scenario per paper figure.

Scale note: the paper's evaluation uses 150-server clusters and week-long
traces with year-long simulator sweeps.  The benchmarks reproduce every
figure's *comparison* at a CI-friendly scale (capacity 60, 3 learning
weeks + 1 evaluation week) by default; pass ``--full`` to run the paper's
scale.  Results are cached as JSON under results/bench/.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.core import (CarbonFlexPolicy, CarbonService, ClusterConfig,
                        KnowledgeBase, OraclePolicy, baselines, learn_window,
                        simulate)
from repro.core.policy import CarbonFlexMPCPolicy
from repro.traces import TraceSpec, generate_trace, mean_length

WEEK = 24 * 7
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


@dataclasses.dataclass
class Scenario:
    region: str = "south-australia"
    family: str = "azure"
    capacity: int = 60
    utilization: float = 0.5
    learn_weeks: int = 3
    seed: int = 7
    elasticity: str = "mix"
    mode: str = "cpu"
    delay_scale: float = 1.0
    length_scale: float = 1.0
    rate_scale: float = 1.0
    delay_override: int | None = None   # uniform delay (Fig. 9 / Fig. 14)

    def build(self):
        from repro.core.types import QueueConfig, default_queues

        if self.delay_override is not None:
            queues = tuple(
                QueueConfig(q.name, max(self.delay_override, 0), q.max_length)
                for q in default_queues())
        else:
            queues = tuple(default_queues(self.delay_scale))
        cluster = ClusterConfig(capacity=self.capacity, queues=queues)
        hours = WEEK * (self.learn_weeks + 1)
        ci = CarbonService.synthetic(self.region, hours + 24 * 30, seed=self.seed)
        spec = TraceSpec(family=self.family, hours=hours, capacity=self.capacity,
                         utilization=self.utilization, seed=self.seed + 1,
                         elasticity=self.elasticity, mode=self.mode,
                         length_scale=self.length_scale,
                         rate_scale=self.rate_scale)
        jobs = generate_trace(spec, cluster.queues)
        t_eval = WEEK * self.learn_weeks
        hist = [j for j in jobs if j.arrival < t_eval]
        ev = [j for j in jobs if t_eval <= j.arrival < t_eval + WEEK]
        return cluster, ci, spec, jobs, hist, ev, t_eval


def run_policies(sc: Scenario, policies: list[str] | None = None) -> dict:
    """Runs the named policies on the scenario; returns per-policy metrics."""
    cluster, ci, spec, jobs, hist, ev, t0 = sc.build()
    ml = mean_length(spec)
    out = {}

    def kb_policy():
        kb = KnowledgeBase()
        offs = tuple(WEEK * i for i in range(sc.learn_weeks))
        learn_window(kb, hist, ci, 0, WEEK, cluster.capacity,
                     len(cluster.queues), offsets=offs, backend="numpy")
        return CarbonFlexPolicy(kb)

    def mpc_policy():
        p = CarbonFlexMPCPolicy()
        p.warm_start(hist)
        return p

    registry = {
        "carbon-agnostic": baselines.CarbonAgnosticPolicy,
        "gaia": lambda: baselines.GaiaPolicy(mean_length=ml),
        "wait-awhile": baselines.WaitAwhilePolicy,
        "carbonscaler": lambda: baselines.CarbonScalerPolicy(mean_length=ml),
        "vcc": lambda: baselines.VCCPolicy(utilization=sc.utilization),
        "vcc-scaling": lambda: baselines.VCCPolicy(scaling=True,
                                                   utilization=sc.utilization),
        "carbonflex": kb_policy,
        "carbonflex-mpc": mpc_policy,
        "oracle": lambda: OraclePolicy(backend="numpy"),
    }
    names = policies or ["carbon-agnostic", "gaia", "wait-awhile",
                         "carbonscaler", "carbonflex", "carbonflex-mpc",
                         "oracle"]
    for name in names:
        t = time.time()
        pol = registry[name]()
        r = simulate(ev, ci, cluster, pol, t0=t0, horizon=WEEK)
        out[name] = {
            "carbon_g": r.carbon_g,
            "energy_kwh": r.energy_kwh,
            "mean_wait_h": r.mean_wait,
            "violation_rate": r.violation_rate,
            "runtime_s": round(time.time() - t, 2),
        }
    base = out.get("carbon-agnostic")
    if base:
        for name, m in out.items():
            m["savings_pct"] = round(
                100.0 * (1.0 - m["carbon_g"] / base["carbon_g"]), 2)
    return out


def cached(name: str, fn, force: bool = False):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    res = fn()
    res["_runtime_s"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return res


def csv_rows(bench: str, res: dict) -> list[str]:
    """Flatten a benchmark result into `name,us_per_call,derived` CSV rows."""
    rows = []
    for key, metrics in res.items():
        if key.startswith("_"):
            continue
        if isinstance(metrics, dict) and "carbon_g" in metrics:
            us = metrics.get("runtime_s", 0) * 1e6
            rows.append(f"{bench}/{key},{us:.0f},"
                        f"savings={metrics.get('savings_pct', 0)}%"
                        f";wait={metrics['mean_wait_h']:.1f}h"
                        f";viol={metrics['violation_rate']:.3f}")
        elif isinstance(metrics, dict):
            for sub, m2 in metrics.items():
                if isinstance(m2, dict) and "carbon_g" in m2:
                    rows.append(f"{bench}/{key}/{sub},"
                                f"{m2.get('runtime_s', 0) * 1e6:.0f},"
                                f"savings={m2.get('savings_pct', 0)}%")
    return rows
