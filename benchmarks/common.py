"""Shared benchmark harness over the ``repro.experiment`` API.

Scale note: the paper's evaluation uses 150-server clusters and week-long
traces with year-long simulator sweeps.  The benchmarks reproduce every
figure's *comparison* at a CI-friendly scale (capacity 60, 3 learning
weeks + 1 evaluation week — the experiment ``Scenario`` defaults); pass
``--full`` to run the paper's scale.  Results are cached as JSON under
results/bench/.
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
import time

from repro.experiment import Scenario, run as run_experiment

WEEK = 24 * 7
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")

__all__ = ["Scenario", "run_policies", "cached", "csv_rows", "WEEK",
           "bench_metadata"]


def bench_metadata() -> dict:
    """Provenance stamp for committed BENCH json payloads: the git SHA the
    numbers were measured at plus a UTC timestamp.  ``"unknown"`` outside
    a git checkout so benches still run from tarballs."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    return {
        "git_sha": sha or "unknown",
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
    }


def run_policies(sc: Scenario, policies: list[str] | None = None) -> dict:
    """Run the named registry policies on the scenario through the
    experiment driver; returns the per-policy metric dicts the figure
    caches store.  Per-policy runtimes are not reported: the driver
    evaluates all policies in one batched ``simulate_many`` dispatch
    (``cached`` records the figure-level wall time as ``_runtime_s``)."""
    return run_experiment(sc, policies).metrics()


def cached(name: str, fn, force: bool = False):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    res = fn()
    res["_runtime_s"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return res


def csv_rows(bench: str, res: dict) -> list[str]:
    """Flatten a benchmark result into `name,us_per_call,derived` CSV rows."""
    rows = []
    for key, metrics in res.items():
        if key.startswith("_"):
            continue
        if isinstance(metrics, dict) and "carbon_g" in metrics:
            us = metrics.get("runtime_s", 0) * 1e6
            rows.append(f"{bench}/{key},{us:.0f},"
                        f"savings={metrics.get('savings_pct', 0)}%"
                        f";wait={metrics['mean_wait_h']:.1f}h"
                        f";viol={metrics['violation_rate']:.3f}")
        elif isinstance(metrics, dict):
            for sub, m2 in metrics.items():
                if isinstance(m2, dict) and "carbon_g" in m2:
                    rows.append(f"{bench}/{key}/{sub},"
                                f"{m2.get('runtime_s', 0) * 1e6:.0f},"
                                f"savings={m2.get('savings_pct', 0)}%")
    return rows
