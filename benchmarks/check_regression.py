"""Perf-regression gate: ``PYTHONPATH=src python -m benchmarks.check_regression``.

Re-runs ``bench_engine`` and ``bench_serve`` at ``--smoke`` scale and
compares every *dimensionless* ratio metric (speedups, overhead factors,
the serve-flex savings percentage) against the committed full-scale
``BENCH_engine.json`` / ``BENCH_serve.json``.  Absolute wall times are
never compared — CI machines and the smoke scale make them meaningless —
but the ratios are scale-free: a 20x learn/execute speedup that drops to
4x, or a 1.3x gating overhead that balloons to 3x, signals a performance
collapse regardless of hardware.

The tolerance is deliberately loose (2x either way) so CI noise never
flakes the gate; it exists to catch order-of-magnitude collapses — an
accidentally de-jitted scan loop, a per-slot host sync sneaking into the
vector path, telemetry overhead leaking into the telemetry=None paths.
Exits nonzero (failing the full-CI job) on any violated bound.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")

# (path into the result dict, direction) — "up" means bigger is better
# (speedups, savings: fail when the fresh ratio falls below committed/TOL);
# "down" means smaller is better (overheads: fail above committed*TOL).
RATIO_METRICS: list[tuple[tuple[str, ...], str]] = [
    (("oracle_solve", "speedup"), "up"),
    (("kb_query", "speedup"), "up"),
    (("combined_learn_execute", "speedup"), "up"),
    (("simulate", "carbonflex", "speedup"), "up"),
    (("dag", "gating_overhead_x"), "down"),
    # mpc gates on the scan-vs-vector ratio, not vs_scalar: the scalar
    # MPC reference is so cheap at --smoke scale that jit dispatch
    # overhead dominates vs_scalar (4.4x full vs ~1.7x smoke — not
    # scale-free), while scan/vector share that overhead and stay flat.
    (("mpc", "carbonflex-mpc", "speedup_vs_vector"), "up"),
    (("mpc", "carbonflex-scale", "speedup_vs_vector"), "up"),
    (("scan", "geo-flex", "speedup_vs_scalar"), "up"),
    (("scan", "dag-carbon", "speedup_vs_scalar"), "up"),
    (("telemetry", "scan", "overhead_x"), "down"),
]
SERVE_METRICS: list[tuple[tuple[str, ...], str]] = [
    (("flex_savings_vs_static_pct",), "up"),
]
TOL = 2.0


def _get(d: dict, path: tuple[str, ...]):
    for k in path:
        if not isinstance(d, dict) or k not in d:
            return None
        d = d[k]
    return d


def _check(name: str, committed: dict, fresh: dict,
           metrics: list[tuple[tuple[str, ...], str]]) -> list[str]:
    failures = []
    meta = committed.get("_meta", {})
    stamp = (f" (committed at {meta.get('git_sha', '?')[:12]}"
             f" {meta.get('timestamp', '?')})" if meta else "")
    for path, direction in metrics:
        want = _get(committed, path)
        got = _get(fresh, path)
        label = f"{name}/{'/'.join(path)}"
        if want is None:
            # metric added after the committed file was last regenerated —
            # nothing to compare against yet, not a failure.
            print(f"  skip {label}: not in committed baseline")
            continue
        if got is None:
            failures.append(f"{label}: missing from the fresh run")
            continue
        ok = got >= want / TOL if direction == "up" else got <= want * TOL
        verdict = "ok  " if ok else "FAIL"
        print(f"  {verdict} {label}: fresh {got} vs committed {want}"
              f" ({'>=' if direction == 'up' else '<='} bound"
              f" {want / TOL if direction == 'up' else want * TOL:.3g})")
        if not ok:
            failures.append(
                f"{label}: fresh {got} vs committed {want}{stamp} breaches "
                f"the {TOL}x tolerance — performance collapse")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine-json", default=os.path.join(
        ROOT, "BENCH_engine.json"))
    ap.add_argument("--serve-json", default=os.path.join(
        ROOT, "BENCH_serve.json"))
    args = ap.parse_args()

    from . import bench_engine, bench_serve

    failures: list[str] = []
    for name, path, module, metrics in (
            ("engine", args.engine_json, bench_engine, RATIO_METRICS),
            ("serve", args.serve_json, bench_serve, SERVE_METRICS)):
        if not os.path.exists(path):
            print(f"{name}: no committed {os.path.basename(path)}, skipping")
            continue
        with open(path) as f:
            committed = json.load(f)
        print(f"{name}: fresh --smoke run vs {os.path.basename(path)}")
        fresh = module.run_all(full=False, smoke=True)
        failures += _check(name, committed, fresh, metrics)

    if failures:
        print("\nperformance regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nperformance regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
