"""Engine micro-benchmark: ``PYTHONPATH=src python -m benchmarks.bench_engine``.

Times the three hot paths of the learning/execution stack at CI scale
(capacity 60, 3 learning weeks + 1 evaluation week — the same scale as the
figure benchmarks) and emits ``BENCH_engine.json`` at the repo root so the
perf trajectory is tracked across PRs:

- ``simulate``      — scalar reference engine vs the vectorised engine;
- ``kb_query``      — seed query config (re-z-score whole base + host->device
                      transfer per call) vs the cached device-resident path,
                      plus ``query_batch`` throughput;
- ``oracle_solve``  — seed loop-based entry builder + reference greedy +
                      unconditional retry loop vs the vectorised builder +
                      early-exit greedy;
- ``combined_learn_execute`` — the §6 pipeline (learning windows + one
                      evaluation week of simulate with per-slot KB queries),
                      seed configuration vs new.  This is the ISSUE-1
                      acceptance metric (>= 10x);
- ``geo``           — the multi-region engine (region-axis state vectors):
                      scalar reference vs vectorised path on a 2-region
                      geo-flex week, parity asserted while timing;
- ``dag``           — the dependency-gated engine (packed predecessor
                      counters): scalar vs vector per DAG policy, plus the
                      gating overhead of the vector path against the
                      independent-job vector path at equal task count
                      (acceptance: within 2x);
- ``mpc``           — the receding-horizon execution phase (ISSUE-10):
                      scalar vs vector vs the scan-native ``mpc`` /
                      ``mpc-scale`` programs on one evaluation week,
                      three-way parity asserted while timing;
- ``scan``          — the scan-fused engine (jitted lax.scan slot loop):
                      scalar vs vector vs scan on the geo-flex and
                      dag-carbon headline workloads (three-way parity
                      asserted; the run fails if scan falls below
                      vector), plus a >=512-cell vmapped sweep through
                      ``simulate_many``.

``--smoke`` shrinks every section to a seconds-scale configuration (CI
runs it so the benchmark code cannot silently rot) and skips the
BENCH_engine.json write so recorded numbers stay full-scale.

The seed configuration is reconstructed faithfully: the loop-based entry
builder and the retry loop without the futile-extension early exit live in
``_seed_*`` below (they were removed from the library), the greedy pass uses
the kept ``backend="numpy-ref"`` reference, the simulator runs with
``engine="scalar"``, and the knowledge base with ``cache=False`` plus the
jax backend (per-query base re-normalisation + transfer) — exactly the seed
defaults.  See EXPERIMENTS.md §Perf for methodology and recorded numbers.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

from repro.core import (CarbonFlexPolicy, KnowledgeBase, baselines,
                        learn_window, simulate)
from repro.core import oracle
from repro.core.knowledge import states_from_schedule
from repro.core.simulator import SimCase, simulate_many
from repro.experiment import Scenario

from .common import bench_metadata

WEEK = 24 * 7
ROOT = os.path.join(os.path.dirname(__file__), "..")


# --- seed-engine reference fixtures ----------------------------------------


def _seed_build_entries(jobs, ci, horizon):
    """The seed's per-job x per-scale loop entry builder (pre-ISSUE-1)."""
    js, ts, ks, gains, scores, deadlines = [], [], [], [], [], []
    for idx, job in enumerate(jobs):
        t0 = max(0, job.arrival)
        t1 = min(horizon, job.deadline + 1)
        if t1 <= t0:
            continue
        trange = np.arange(t0, t1, dtype=np.int64)
        civ = ci[trange]
        for k in range(job.k_min, job.k_max + 1):
            p = job.marginal(k)
            if p <= 0:
                continue
            js.append(np.full(len(trange), idx, dtype=np.int64))
            ts.append(trange)
            ks.append(np.full(len(trange), k, dtype=np.int64))
            gains.append(np.full(len(trange), p))
            scores.append(p / civ)
            deadlines.append(np.full(len(trange), job.deadline, dtype=np.int64))
    if not js:
        z = np.zeros(0, dtype=np.int64)
        return z, z, z, np.zeros(0), np.zeros(0)
    order = np.lexsort((np.concatenate(deadlines), -np.concatenate(scores)))
    return tuple(np.concatenate(a)[order] for a in (js, ts, ks, gains, scores))


def _seed_solve(jobs, ci, capacity, horizon, max_extensions=8,
                extension_slots=24):
    """Seed ``oracle.solve``: loop builder, reference greedy, and the retry
    loop that always burns the full extension budget on infeasibility."""
    builder = oracle._build_entries
    oracle._build_entries = _seed_build_entries      # the seed's hot path
    try:
        horizon = int(horizon or len(ci))
        jobs = [dataclasses.replace(j) for j in jobs]
        lengths = np.array([j.length for j in jobs])
        for attempt in range(max_extensions + 1):
            alloc, used, work = oracle._greedy_numpy_ref(
                jobs, ci, capacity, horizon, lengths, None)
            unfinished = work < lengths - 1e-6
            if not unfinished.any() or attempt == max_extensions:
                break
            for idx in np.nonzero(unfinished)[0]:
                jobs[idx] = dataclasses.replace(
                    jobs[idx], delay=jobs[idx].delay + extension_slots)
    finally:
        oracle._build_entries = builder
    return alloc, used.astype(np.int64), oracle._rho_curve(jobs, alloc)


def _seed_learn(kb, hist, ci, horizon, capacity, num_queues, offsets):
    for off in offsets:
        window_jobs = [dataclasses.replace(j, arrival=j.arrival - off)
                       for j in hist if off <= j.arrival < off + horizon]
        if not window_jobs:
            continue
        alloc, used, rho = _seed_solve(window_jobs, ci.trace[off:off + horizon],
                                       capacity, horizon)
        states = states_from_schedule(window_jobs, alloc, ci, num_queues, t0=off)
        kb.add_window(states, used, rho)


# --- scenario ----------------------------------------------------------------


def _scenario(full: bool = False, smoke: bool = False):
    sc = Scenario(region="south-australia",
                  capacity=150 if full else 24 if smoke else 60,
                  learn_weeks=1 if smoke else 3, seed=7)
    mat = sc.materialize()
    return (mat.cluster, mat.ci, mat.hist, mat.eval_jobs, mat.t0,
            sc.learn_offsets())


def _timed(fn, repeats=1):
    best = float("inf")
    out = None
    for _ in range(repeats):
        t = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t)
    return best, out


# --- benchmark sections -------------------------------------------------------


def bench_oracle(cluster, ci, hist) -> dict:
    window = [j for j in hist if j.arrival < WEEK]
    trace = ci.trace[:WEEK]
    t_seed, _ = _timed(lambda: _seed_solve(window, trace, cluster.capacity, WEEK))
    t_new, _ = _timed(lambda: oracle.solve(window, trace, cluster.capacity,
                                           horizon=WEEK, backend="numpy"))
    return {"seed_s": round(t_seed, 3), "new_s": round(t_new, 3),
            "speedup": round(t_seed / t_new, 1), "window_jobs": len(window)}


def bench_kb_query(cluster, ci, hist, offsets) -> dict:
    reps = 200
    kb_seed = KnowledgeBase(cache=False, backend="jax")
    kb_new = KnowledgeBase()
    learn_window(kb_seed, hist, ci, 0, WEEK, cluster,
                 offsets=offsets, backend="numpy")
    learn_window(kb_new, hist, ci, 0, WEEK, cluster,
                 offsets=offsets, backend="numpy")
    state = np.concatenate([[250.0, 0.0, 0.5, 1.0, 1.0], np.ones(6), [1.0, 0.5]])
    kb_seed.query(state)                      # warm (jit, rebuild)
    kb_new.query(state)
    t_seed, _ = _timed(lambda: [kb_seed.query(state) for _ in range(reps)])
    t_new, _ = _timed(lambda: [kb_new.query(state) for _ in range(reps)])
    batch = np.tile(state, (1024, 1))
    kb_new.query_batch(batch[:8])             # warm
    t_batch, _ = _timed(lambda: kb_new.query_batch(batch))
    return {
        "cases": len(kb_new),
        "seed_ms_per_query": round(t_seed / reps * 1e3, 3),
        "new_ms_per_query": round(t_new / reps * 1e3, 3),
        "speedup": round(t_seed / t_new, 1),
        "batch_queries_per_s": int(1024 / t_batch),
    }


def bench_simulate(cluster, ci, hist, ev, t0, offsets) -> dict:
    kb = KnowledgeBase()
    learn_window(kb, hist, ci, 0, WEEK, cluster,
                 offsets=offsets, backend="numpy")
    out = {}
    for name, mk in [("carbon-agnostic", baselines.CarbonAgnosticPolicy),
                     ("carbonflex", lambda: CarbonFlexPolicy(kb))]:
        simulate(ev, ci, cluster, mk(), t0=t0, horizon=WEEK)   # warm pack/jit
        t_s, rs = _timed(lambda m=mk: simulate(ev, ci, cluster, m(), t0=t0,
                                               horizon=WEEK, engine="scalar"))
        t_v, rv = _timed(lambda m=mk: simulate(ev, ci, cluster, m(), t0=t0,
                                               horizon=WEEK, engine="vector"))
        assert rs.carbon_g == rv.carbon_g      # parity while we are here
        out[name] = {"scalar_s": round(t_s, 3), "vector_s": round(t_v, 4),
                     "speedup": round(t_s / t_v, 1)}
    out["eval_jobs"] = len(ev)
    return out


def bench_combined(cluster, ci, hist, ev, t0, offsets) -> dict:
    """The ISSUE-1 acceptance metric: one full learn+execute pipeline
    (oracle learning windows, then an evaluation week of simulate with a
    KB query every slot), seed configuration vs new."""

    def seed_pipeline():
        kb = KnowledgeBase(cache=False, backend="jax")
        _seed_learn(kb, hist, ci, WEEK, cluster.capacity, 3, offsets)
        return simulate(ev, ci, cluster, CarbonFlexPolicy(kb), t0=t0,
                        horizon=WEEK, engine="scalar")

    def new_pipeline():
        kb = KnowledgeBase()
        learn_window(kb, hist, ci, 0, WEEK, cluster,
                     offsets=offsets, backend="numpy")
        return simulate_many([SimCase(jobs=ev, ci=ci, cluster=cluster,
                                      policy=CarbonFlexPolicy(kb), t0=t0,
                                      horizon=WEEK)])[0]

    new_pipeline()                              # warm jit/pack caches
    t_seed, r_seed = _timed(seed_pipeline)
    t_new, r_new = _timed(new_pipeline)
    return {
        "seed_s": round(t_seed, 2),
        "new_s": round(t_new, 2),
        "speedup": round(t_seed / t_new, 1),
        "seed_carbon_g": round(r_seed.carbon_g, 1),
        "new_carbon_g": round(r_new.carbon_g, 1),
    }


def bench_geo(full: bool = False, smoke: bool = False) -> dict:
    """Multi-region engine: scalar reference vs the region-axis vector
    path, one evaluation week of each geo policy on a 2-region world."""
    from repro.experiment import make_policy, prepare_context

    sc = Scenario(regions=("south-australia", "california"),
                  capacity=150 if full else 16 if smoke else 60,
                  learn_weeks=1, seed=7)
    mat = sc.materialize()
    names = ("geo-static", "geo-greedy", "geo-flex")
    ctx = prepare_context(mat, names)
    out = {}
    for name in names:
        mk = lambda n=name: make_policy(n, ctx)  # noqa: E731
        simulate(mat.eval_jobs, mat.mci, mat.geo, mk(), t0=mat.t0,
                 horizon=WEEK)                   # warm the pack cache
        t_s, rs = _timed(lambda m=mk: simulate(mat.eval_jobs, mat.mci,
                                               mat.geo, m(), t0=mat.t0,
                                               horizon=WEEK, engine="scalar"))
        t_v, rv = _timed(lambda m=mk: simulate(mat.eval_jobs, mat.mci,
                                               mat.geo, m(), t0=mat.t0,
                                               horizon=WEEK, engine="vector"))
        assert rs.carbon_g == rv.carbon_g        # parity while we are here
        out[name] = {"scalar_s": round(t_s, 3), "vector_s": round(t_v, 4),
                     "speedup": round(t_s / t_v, 1),
                     "migrations": int(rv.migrations)}
    out["eval_jobs"] = len(mat.eval_jobs)
    out["regions"] = list(sc.regions)
    return out


def bench_dag(full: bool = False, smoke: bool = False) -> dict:
    """Dependency-gated engine (§dag): scalar vs vector per DAG policy
    (parity asserted while timing), and the vector gating overhead against
    the independent-job vector path at equal task count — the ISSUE-4
    acceptance bound is 2x.  Overhead is measured per simulated slot: a
    gated pipeline legitimately runs for more slots than its independent
    twin (chains serialise into the overrun window), so wall-clock alone
    would conflate workload semantics with engine cost."""
    from repro.core import baselines
    from repro.core.dag import DagCapPolicy, DagCarbonPolicy, DagFcfsPolicy
    from repro.traces import DagConfig

    kw = dict(capacity=150 if full else 16 if smoke else 60,
              learn_weeks=1, seed=7)
    mat = Scenario(dag=DagConfig(), **kw).materialize()
    indep = Scenario(dag=DagConfig(independent=True), **kw).materialize()
    assert len(indep.eval_jobs) == len(mat.eval_jobs)   # equal task count
    out = {}
    for name, mk in [("dag-fcfs", DagFcfsPolicy),
                     ("dag-carbon", DagCarbonPolicy),
                     ("dag-cap", DagCapPolicy)]:
        simulate(mat.eval_jobs, mat.ci, mat.cluster, mk(), t0=mat.t0,
                 horizon=WEEK)                           # warm the pack cache
        t_s, rs = _timed(lambda m=mk: simulate(mat.eval_jobs, mat.ci,
                                               mat.cluster, m(), t0=mat.t0,
                                               horizon=WEEK, engine="scalar"))
        # best-of-3: the overhead ratio below compares two ~10ms runs, so
        # a single scheduler hiccup would swamp the signal
        t_v, rv = _timed(lambda m=mk: simulate(mat.eval_jobs, mat.ci,
                                               mat.cluster, m(), t0=mat.t0,
                                               horizon=WEEK, engine="vector"),
                         repeats=3)
        assert rs.carbon_g == rv.carbon_g                # parity while timing
        out[name] = {"scalar_s": round(t_s, 3), "vector_s": round(t_v, 4),
                     "speedup": round(t_s / t_v, 1),
                     "slots": len(rv.slots)}
    simulate(indep.eval_jobs, indep.ci, indep.cluster,
             baselines.CarbonAgnosticPolicy(), t0=indep.t0, horizon=WEEK)
    t_i, r_i = _timed(lambda: simulate(indep.eval_jobs, indep.ci,
                                       indep.cluster,
                                       baselines.CarbonAgnosticPolicy(),
                                       t0=indep.t0, horizon=WEEK),
                      repeats=3)
    out["tasks"] = len(mat.eval_jobs)
    out["independent_vector_s"] = round(t_i, 4)
    out["independent_slots"] = len(r_i.slots)
    fcfs = out["dag-fcfs"]
    out["gating_overhead_x"] = round(
        (fcfs["vector_s"] / fcfs["slots"]) / (t_i / len(r_i.slots)), 2)
    return out


def bench_scan(full: bool = False, smoke: bool = False) -> dict:
    """Scan-fused engine (ISSUE-8): the jitted lax.scan slot loop against
    the scalar and vector paths on the two workloads whose vector-path
    speedup had collapsed — a geo-flex week (region-axis walk) and a
    dag-carbon week (dependency gating) — plus a >=512-cell vmapped sweep
    through ``simulate_many``.  Parity is asserted across all three
    engines while timing; ``run_and_report`` fails the run if the scan
    path regresses below the vector path on either headline workload."""
    from repro.core.dag import DagCarbonPolicy
    from repro.experiment import make_policy, prepare_context
    from repro.traces import DagConfig

    cap = 150 if full else 16 if smoke else 60
    out = {}

    geo_sc = Scenario(regions=("south-australia", "california"),
                      capacity=cap, learn_weeks=1, seed=7)
    geo = geo_sc.materialize()
    ctx = prepare_context(geo, ("geo-flex",))
    mk_geo = lambda: make_policy("geo-flex", ctx)  # noqa: E731
    dag = Scenario(dag=DagConfig(), capacity=cap, learn_weeks=1,
                   seed=7).materialize()
    for name, mat, mk in [("geo-flex", geo, mk_geo),
                          ("dag-carbon", dag, DagCarbonPolicy)]:
        ci_c = mat.mci if mat.is_geo else mat.ci
        cl_c = mat.geo if mat.is_geo else mat.cluster
        for eng in ("vector", "scan"):          # warm pack + jit caches
            simulate(mat.eval_jobs, ci_c, cl_c, mk(), t0=mat.t0,
                     horizon=WEEK, engine=eng)
        times, results = {}, {}
        for eng, reps in (("scalar", 1), ("vector", 3), ("scan", 3)):
            times[eng], results[eng] = _timed(
                lambda m=mk, e=eng: simulate(mat.eval_jobs, ci_c, cl_c,
                                             m(), t0=mat.t0, horizon=WEEK,
                                             engine=e), repeats=reps)
        assert results["scalar"].carbon_g == results["vector"].carbon_g \
            == results["scan"].carbon_g      # three-way parity while timing
        out[name] = {
            "scalar_s": round(times["scalar"], 3),
            "vector_s": round(times["vector"], 4),
            "scan_s": round(times["scan"], 4),
            "speedup_vs_scalar": round(times["scalar"] / times["scan"], 1),
            "speedup_vs_vector": round(times["vector"] / times["scan"], 2),
            "jobs": len(mat.eval_jobs),
        }

    # >=512-cell grid as one batched dispatch: structurally identical
    # cases fuse into vmapped device tiles (8 traces x 16 seeds x 4
    # policies); smoke shrinks the grid, recorded runs keep 512
    regions = ("south-australia", "california", "germany", "texas",
               "ontario", "sweden", "poland", "virginia")
    n_seeds = 2 if smoke else 16
    single = Scenario(region="south-australia", capacity=cap,
                      learn_weeks=1, seed=7).materialize()
    mks = [baselines.CarbonAgnosticPolicy, baselines.WaitAwhilePolicy,
           baselines.RobustWaitAwhilePolicy,
           lambda: baselines.WaitAwhilePolicy(percentile=35.0)]
    cases = [SimCase(jobs=single.eval_jobs,
                     ci=type(single.ci).synthetic(r, WEEK * 2 + 24 * 30,
                                                  seed=s),
                     cluster=single.cluster, policy=mk(), t0=0,
                     horizon=WEEK, engine="scan",
                     label=f"{r}/s{s}/{i}")
             for r in regions for s in range(n_seeds)
             for i, mk in enumerate(mks)]
    simulate_many(cases[:len(mks)])              # warm the batch jit
    t_sweep, rs = _timed(lambda: simulate_many(cases))
    assert all((r.completion >= 0).all() for r in rs)
    out["sweep"] = {"cells": len(cases), "wall_s": round(t_sweep, 2),
                    "cells_per_s": round(len(cases) / t_sweep, 1)}
    return out


def bench_mpc(full: bool = False, smoke: bool = False) -> dict:
    """Receding-horizon execution phase (ISSUE-10): scalar vs vector vs
    the scan-native ``mpc``/``mpc-scale`` programs on one evaluation week.
    The vector path walks the precomputed decision tables per slot in
    Python; the scan path consumes them inside the jitted slot loop.
    Three-way parity is asserted while timing; ``run_and_report`` fails
    the run if the scan-native program falls below the vector path."""
    from repro.experiment import make_policy, prepare_context

    cap = 150 if full else 16 if smoke else 60
    mat = Scenario(region="south-australia", capacity=cap, learn_weeks=1,
                   seed=7).materialize()
    names = ("carbonflex-mpc", "carbonflex-scale")
    ctx = prepare_context(mat, names)
    out = {}
    for name in names:
        mk = lambda n=name: make_policy(n, ctx)  # noqa: E731
        for eng in ("vector", "scan"):           # warm pack + jit caches
            simulate(mat.eval_jobs, mat.ci, mat.cluster, mk(), t0=mat.t0,
                     horizon=WEEK, engine=eng)
        times, results = {}, {}
        for eng, reps in (("scalar", 1), ("vector", 3), ("scan", 3)):
            times[eng], results[eng] = _timed(
                lambda m=mk, e=eng: simulate(mat.eval_jobs, mat.ci,
                                             mat.cluster, m(), t0=mat.t0,
                                             horizon=WEEK, engine=e),
                repeats=reps)
        assert results["scalar"].carbon_g == results["vector"].carbon_g \
            == results["scan"].carbon_g      # three-way parity while timing
        out[name] = {
            "scalar_s": round(times["scalar"], 3),
            "vector_s": round(times["vector"], 4),
            "scan_s": round(times["scan"], 4),
            "speedup_vs_scalar": round(times["scalar"] / times["scan"], 1),
            "speedup_vs_vector": round(times["vector"] / times["scan"], 2),
        }
    out["eval_jobs"] = len(mat.eval_jobs)
    return out


def bench_telemetry(full: bool = False, smoke: bool = False) -> dict:
    """Trace-recording overhead on the scan path (ISSUE-9 acceptance:
    attaching a MemoryRecorder must stay within 1.3x of the bare run,
    and the recorded run must return the identical bytes).  The vector
    path is timed alongside for context; the telemetry=None paths are
    covered implicitly — every other section runs them untouched."""
    from repro.telemetry import MemoryRecorder, Telemetry

    cap = 150 if full else 16 if smoke else 60
    mat = Scenario(region="south-australia", capacity=cap,
                   learn_weeks=1, seed=7).materialize()
    mk = baselines.WaitAwhilePolicy
    out = {}
    for eng in ("vector", "scan"):
        simulate(mat.eval_jobs, mat.ci, mat.cluster, mk(), t0=mat.t0,
                 horizon=WEEK, engine=eng)          # warm pack + jit
        t_off, r_off = _timed(
            lambda e=eng: simulate(mat.eval_jobs, mat.ci, mat.cluster,
                                   mk(), t0=mat.t0, horizon=WEEK, engine=e),
            repeats=5)

        n_events = [0]

        def run_on(e=eng):
            tel = Telemetry(recorder=MemoryRecorder())
            r = simulate(mat.eval_jobs, mat.ci, mat.cluster, mk(),
                         t0=mat.t0, horizon=WEEK, engine=e, telemetry=tel)
            n_events[0] = len(tel.recorder)
            return r

        t_on, r_on = _timed(run_on, repeats=5)
        events = n_events[0]
        assert r_off.carbon_g == r_on.carbon_g      # observation-only
        out[eng] = {
            "off_s": round(t_off, 4), "on_s": round(t_on, 4),
            "overhead_x": round(t_on / t_off, 3), "events": events,
        }
    return out


def run_all(full: bool = False, smoke: bool = False) -> dict:
    cluster, ci, hist, ev, t0, offsets = _scenario(full, smoke)
    res = {
        "scale": {"capacity": cluster.capacity, "learn_weeks": len(offsets),
                  "hist_jobs": len(hist), "eval_jobs": len(ev),
                  "full": bool(full)},
        "oracle_solve": bench_oracle(cluster, ci, hist),
        "kb_query": bench_kb_query(cluster, ci, hist, offsets),
        "simulate": bench_simulate(cluster, ci, hist, ev, t0, offsets),
        "combined_learn_execute": bench_combined(cluster, ci, hist, ev, t0,
                                                 offsets),
        "geo": bench_geo(full, smoke),
        "dag": bench_dag(full, smoke),
        "mpc": bench_mpc(full, smoke),
        "scan": bench_scan(full, smoke),
        "telemetry": bench_telemetry(full, smoke),
    }
    return res


def csv_rows(res: dict) -> list[str]:
    rows = []
    for section in ("oracle_solve", "kb_query", "combined_learn_execute"):
        d = res[section]
        if "seed_s" in d:
            rows.append(f"bench_engine/{section},{d['new_s'] * 1e6:.0f},"
                        f"speedup={d['speedup']}x;seed_s={d['seed_s']}")
        else:
            rows.append(f"bench_engine/{section},"
                        f"{d['new_ms_per_query'] * 1e3:.0f},"
                        f"speedup={d['speedup']}x"
                        f";batch_qps={d['batch_queries_per_s']}")
    for pol, d in res["simulate"].items():
        if isinstance(d, dict):
            rows.append(f"bench_engine/simulate/{pol},{d['vector_s'] * 1e6:.0f},"
                        f"speedup={d['speedup']}x;scalar_s={d['scalar_s']}")
    for pol, d in res["geo"].items():
        if isinstance(d, dict):
            rows.append(f"bench_engine/geo/{pol},{d['vector_s'] * 1e6:.0f},"
                        f"speedup={d['speedup']}x;scalar_s={d['scalar_s']}"
                        f";migrations={d['migrations']}")
    for pol, d in res["dag"].items():
        if isinstance(d, dict):
            rows.append(f"bench_engine/dag/{pol},{d['vector_s'] * 1e6:.0f},"
                        f"speedup={d['speedup']}x;scalar_s={d['scalar_s']}")
    rows.append(f"bench_engine/dag/gating_overhead,"
                f"{res['dag']['independent_vector_s'] * 1e6:.0f},"
                f"overhead_per_slot={res['dag']['gating_overhead_x']}x"
                f";tasks={res['dag']['tasks']}")
    for pol, d in res["mpc"].items():
        if isinstance(d, dict):
            rows.append(f"bench_engine/mpc/{pol},{d['scan_s'] * 1e6:.0f},"
                        f"vs_scalar={d['speedup_vs_scalar']}x"
                        f";vs_vector={d['speedup_vs_vector']}x")
    for wl in ("geo-flex", "dag-carbon"):
        d = res["scan"][wl]
        rows.append(f"bench_engine/scan/{wl},{d['scan_s'] * 1e6:.0f},"
                    f"vs_scalar={d['speedup_vs_scalar']}x"
                    f";vs_vector={d['speedup_vs_vector']}x")
    sw = res["scan"]["sweep"]
    rows.append(f"bench_engine/scan/sweep,{sw['wall_s'] * 1e6:.0f},"
                f"cells={sw['cells']};cells_per_s={sw['cells_per_s']}")
    for eng, d in res["telemetry"].items():
        rows.append(f"bench_engine/telemetry/{eng},{d['on_s'] * 1e6:.0f},"
                    f"overhead={d['overhead_x']}x;events={d['events']}")
    return rows


def run_and_report(out_path: str | None = None, full: bool = False,
                   smoke: bool = False) -> dict:
    res = run_all(full, smoke)
    for row in csv_rows(res):
        print(row)
    over = res["dag"]["gating_overhead_x"]
    assert over < 2.0, (
        f"DAG gating overhead {over}x exceeds the 2x acceptance bound")
    for wl in ("geo-flex", "dag-carbon"):
        d = res["scan"][wl]
        assert d["scan_s"] <= d["vector_s"], (
            f"scan engine regressed below the vector path on {wl}: "
            f"scan {d['scan_s']}s vs vector {d['vector_s']}s")
    # carbonflex-scale is exempt: its heterogeneous k requests force the
    # sequential walk fill (no uniform cumsum), so the per-case vector
    # path stays competitive — the scan program earns its keep in
    # vmapped sweeps, not solo runs (see EXPERIMENTS.md §Forecast).
    d = res["mpc"]["carbonflex-mpc"]
    assert d["scan_s"] <= d["vector_s"], (
        f"scan-native MPC program regressed below the vector path: "
        f"scan {d['scan_s']}s vs vector {d['vector_s']}s")
    tele_x = res["telemetry"]["scan"]["overhead_x"]
    assert tele_x <= 1.3, (
        f"scan-path trace recording costs {tele_x}x vs telemetry off; "
        f"the acceptance bound is 1.3x")
    res["_meta"] = bench_metadata()
    if smoke and out_path is None:
        print("smoke run: BENCH_engine.json left untouched")
        return res
    path = out_path or os.path.join(ROOT, "BENCH_engine.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(path)}")
    return res


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--full", action="store_true",
                    help="paper scale (capacity 150) instead of CI scale")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI smoke (no BENCH_engine.json)")
    args = ap.parse_args()
    run_and_report(args.out, args.full, args.smoke)


if __name__ == "__main__":
    main()
