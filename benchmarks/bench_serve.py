"""Serving-engine benchmark: ``PYTHONPATH=src python -m benchmarks.bench_serve``.

Times the request-serving engine (``repro.serving``) on a 2-week,
1.5M-requests/day diurnal trace — the ISSUE-7 acceptance scale — and emits
``BENCH_serve.json`` at the repo root:

- per serve policy: scalar reference vs vector path (parity asserted on
  every aggregate while timing) and the simulated-requests-routed/sec
  throughput of the vector path (the per-slot demand binning is what makes
  millions of requests per day tractable — the engine never touches a
  request individually);
- the serve-flex vs serve-static carbon savings and both SLO-violation
  rates at this scale, so the headline quality-for-carbon trade is tracked
  across PRs alongside the throughput.

``--smoke`` shrinks to one evaluation week and skips the
BENCH_serve.json write so recorded numbers stay full-scale.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.experiment import Scenario, ServingConfig, WEEK, prepare_context
from repro.experiment.registry import make_policy
from repro.serving import ServeCase, simulate_serving

from .common import bench_metadata

ROOT = os.path.join(os.path.dirname(__file__), "..")
POLICIES = ("serve-static", "serve-greedy", "serve-flex")


def _timed(fn, repeats: int = 3):
    best = float("inf")
    out = None
    for _ in range(repeats):
        t = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t)
    return best, out


def run_all(full: bool = False, smoke: bool = False) -> dict:
    sc = Scenario(
        serving=ServingConfig(requests_per_day=6e6 if full else 1.5e6),
        learn_weeks=1, eval_weeks=1 if smoke else 2, seed=7)
    mat = sc.materialize()
    ctx = prepare_context(mat, POLICIES)
    horizon = sc.eval_weeks * WEEK
    demand = mat.serving.demand[mat.t0: mat.t0 + horizon]
    total_requests = float(demand.sum())

    def case(name: str) -> ServeCase:
        return ServeCase(demand=demand, rate=mat.serving.rate, ci=mat.ci,
                         config=mat.serving.config,
                         policy=make_policy(name, ctx), t0=mat.t0,
                         label=name)

    res: dict = {"scale": {"requests_per_day": sc.serving.requests_per_day,
                           "slots": len(demand),
                           "total_requests": total_requests,
                           "servers": sc.serving.servers,
                           "full": bool(full)}}
    carbon: dict[str, float] = {}
    for name in POLICIES:
        t_s, rs = _timed(lambda n=name: simulate_serving(case(n),
                                                         engine="scalar"))
        t_v, rv = _timed(lambda n=name: simulate_serving(case(n),
                                                         engine="vector"))
        assert rs.carbon_g == rv.carbon_g          # parity while timing
        assert rs.energy_kwh == rv.energy_kwh
        assert np.array_equal(rs.serving.balance, rv.serving.balance)
        assert rs.serving.tier_requests == rv.serving.tier_requests
        carbon[name] = rv.carbon_g
        res[name] = {
            "scalar_s": round(t_s, 4), "vector_s": round(t_v, 4),
            "speedup": round(t_s / t_v, 1),
            "requests_routed_per_s": int(total_requests / t_v),
            "carbon_kg": round(rv.carbon_g / 1e3, 1),
            "violation_rate": round(rv.serving.violation_rate, 5),
            "quality_mean": round(rv.serving.quality_mean, 5),
            "ledger_range": [round(rv.serving.ledger_min, 4),
                             round(rv.serving.ledger_max, 4)],
        }
    res["flex_savings_vs_static_pct"] = round(
        100.0 * (1.0 - carbon["serve-flex"] / carbon["serve-static"]), 2)
    return res


def csv_rows(res: dict) -> list[str]:
    rows = []
    for name in POLICIES:
        d = res[name]
        rows.append(f"bench_serve/{name},{d['vector_s'] * 1e6:.0f},"
                    f"req_per_s={d['requests_routed_per_s']}"
                    f";speedup={d['speedup']}x"
                    f";viol={d['violation_rate']}")
    rows.append(f"bench_serve/flex_vs_static,0,"
                f"savings={res['flex_savings_vs_static_pct']}%"
                f";total_requests={res['scale']['total_requests']:.0f}")
    return rows


def run_and_report(out_path: str | None = None, full: bool = False,
                   smoke: bool = False) -> dict:
    res = run_all(full, smoke)
    for row in csv_rows(res):
        print(row)
    assert res["flex_savings_vs_static_pct"] > 0, (
        "serve-flex shows no carbon savings over serve-static")
    res["_meta"] = bench_metadata()
    if smoke and out_path is None:
        print("smoke run: BENCH_serve.json left untouched")
        return res
    path = out_path or os.path.join(ROOT, "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(path)}")
    return res


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--full", action="store_true",
                    help="6M requests/day instead of the 1.5M default")
    ap.add_argument("--smoke", action="store_true",
                    help="one-week CI smoke (no BENCH_serve.json)")
    args = ap.parse_args()
    run_and_report(args.out, args.full, args.smoke)


if __name__ == "__main__":
    main()
