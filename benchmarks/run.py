"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One benchmark per paper table/figure (see figures.ALL) + the roofline
report.  Prints ``name,us_per_call,derived`` CSV.  Results are cached in
results/bench/ — pass ``--force`` to recompute, ``--only fig6`` to filter.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--engine", action="store_true",
                    help="run the engine micro-benchmark (BENCH_engine.json) "
                         "instead of the figure suite")
    args = ap.parse_args()

    if args.engine:
        from . import bench_engine

        print("name,us_per_call,derived")
        bench_engine.run_and_report()
        return

    from . import figures, roofline
    from .common import cached, csv_rows

    print("name,us_per_call,derived")
    for name, fn in figures.ALL.items():
        if args.only and args.only not in name:
            continue
        try:
            res = cached(name, lambda fn=fn: fn(), force=args.force)
        except Exception as e:  # noqa: BLE001
            print(f"{name},0,ERROR={e!r}", file=sys.stderr)
            continue
        if name == "tab_overheads":
            for k, v in res.items():
                if not k.startswith("_"):
                    print(f"{name}/{k},{float(v) * 1e6:.0f},seconds={v}")
            continue
        if name == "resilience":
            for section in ("degradation", "stale_feed"):
                for regime, pols in res[section].items():
                    for pol, s in pols.items():
                        print(f"{name}/{section}/{regime}/{pol},0,"
                              f"savings={s['savings_mean_pct']}%"
                              f";viol={s['violation_rate']}"
                              f";lost={s.get('lost_work_slots', 0)}")
            continue
        if name == "forecast_gap":
            for fc, pols in res["summary"].items():
                for pol, s in pols.items():
                    print(f"{name}/{fc}/{pol},0,"
                          f"savings={s['savings_mean_pct']}%"
                          f";gap={s['gap_mean_pp']}pp")
            continue
        for row in csv_rows(name, res):
            print(row)
    if not args.skip_roofline and not args.only:
        for row in roofline.csv_rows():
            print(row)


if __name__ == "__main__":
    main()
