"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One benchmark per paper table/figure (see figures.ALL) + the roofline
report.  Prints ``name,us_per_call,derived`` CSV.  Results are cached in
results/bench/ — pass ``--force`` to recompute, ``--only fig6`` to filter.

``--engine`` runs the batch-engine micro-benchmark (BENCH_engine.json),
``--serve`` the serving-engine benchmark (BENCH_serve.json); the two
combine, and either replaces the figure suite.  Every section runs behind
its own failure guard — a crashing section is reported and the rest still
run; the process exits non-zero at the end if anything failed.
"""
from __future__ import annotations

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--engine", action="store_true",
                    help="run the batch-engine micro-benchmark "
                         "(BENCH_engine.json) instead of the figure suite")
    ap.add_argument("--serve", action="store_true",
                    help="run the serving-engine benchmark "
                         "(BENCH_serve.json) instead of the figure suite; "
                         "combines with --engine")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink --engine/--serve to a CI smoke and skip "
                         "the BENCH_*.json writes")
    args = ap.parse_args()

    failures: list[str] = []

    def section(name: str, fn) -> None:
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name},0,ERROR={e!r}", file=sys.stderr)
            failures.append(name)

    if args.engine or args.serve:
        print("name,us_per_call,derived")
        if args.engine:
            from . import bench_engine

            section("bench_engine",
                    lambda: bench_engine.run_and_report(smoke=args.smoke))
        if args.serve:
            from . import bench_serve

            section("bench_serve",
                    lambda: bench_serve.run_and_report(smoke=args.smoke))
    else:
        _figure_suite(args, failures, section)

    if failures:
        print(f"FAILED sections: {', '.join(failures)}", file=sys.stderr)
        sys.exit(1)


def _figure_suite(args, failures: list[str], section) -> None:
    from . import figures, roofline
    from .common import RESULTS_DIR, cached, csv_rows

    print("name,us_per_call,derived")
    for name, fn in figures.ALL.items():
        if args.only and args.only not in name:
            continue
        try:
            res = cached(name, lambda fn=fn: fn(), force=args.force)
        except Exception as e:  # noqa: BLE001
            print(f"{name},0,ERROR={e!r}", file=sys.stderr)
            failures.append(name)
            continue
        if name == "tab_overheads":
            for k, v in res.items():
                if not k.startswith("_"):
                    print(f"{name}/{k},{float(v) * 1e6:.0f},seconds={v}")
            continue
        if name == "resilience":
            for sec in ("degradation", "stale_feed"):
                for regime, pols in res[sec].items():
                    for pol, s in pols.items():
                        print(f"{name}/{sec}/{regime}/{pol},0,"
                              f"savings={s['savings_mean_pct']}%"
                              f";viol={s['violation_rate']}"
                              f";lost={s.get('lost_work_slots', 0)}")
            csv = res.get("csv")
            if csv:
                for sec, text in csv.items():
                    path = os.path.join(RESULTS_DIR,
                                        f"resilience_{sec}.csv")
                    with open(path, "w") as f:
                        f.write(text)
                    print(f"{name}/{sec},0,csv={path}")
            else:
                print(f"{name},0,csv=missing (stale cache; rerun with "
                      f"--force to regenerate per-cell tables)",
                      file=sys.stderr)
            continue
        if name == "attribution":
            for family, runs in res.items():
                if family.startswith("_") or family == "csv":
                    continue
                for d in runs:
                    top = max(d["causes"], key=lambda c: abs(d["causes"][c]))
                    print(f"{name}/{family}/seed{d['seed']},0,"
                          f"savings={round(d['savings_pct'], 2)}%"
                          f";top_cause={top}"
                          f";top_g={d['causes'][top]:.1f}")
            csv = res.get("csv")
            if csv:
                path = os.path.join(RESULTS_DIR, "attribution.csv")
                with open(path, "w") as f:
                    f.write(csv)
                print(f"{name},0,csv={path}")
            else:
                print(f"{name},0,csv=missing (stale cache; rerun with "
                      f"--force to regenerate per-run tables)",
                      file=sys.stderr)
            continue
        if name == "forecast_gap":
            for fc, pols in res["summary"].items():
                for pol, s in pols.items():
                    print(f"{name}/{fc}/{pol},0,"
                          f"savings={s['savings_mean_pct']}%"
                          f";gap={s['gap_mean_pp']}pp")
            continue
        for row in csv_rows(name, res):
            print(row)
    if not args.skip_roofline and not args.only:
        section("roofline", lambda: [print(r) for r in roofline.csv_rows()])


if __name__ == "__main__":
    main()
