"""One benchmark per paper figure/table (§6).  Each returns a dict cached
under results/bench/<name>.json; ``benchmarks.run`` prints the CSV.

Every figure is a declarative ``repro.experiment.Scenario`` (or a small
grid of them) handed to ``run_policies`` — the experiment driver owns the
learn/execute pipeline."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .common import Scenario, run_policies

FAST_POLICIES = ["carbon-agnostic", "gaia", "wait-awhile", "carbonscaler",
                 "carbonflex", "carbonflex-mpc", "oracle"]


def fig6_cpu_cluster() -> dict:
    """Fig. 6: CPU cluster — carbon emissions + delay across policies."""
    return run_policies(Scenario(mode="cpu"))


def fig7_gpu_cluster() -> dict:
    """Fig. 7: GPU cluster — heterogeneous per-workload power."""
    return run_policies(Scenario(mode="gpu", capacity=30, seed=9))


def fig8_capacity() -> dict:
    """Fig. 8: max cluster capacity M (75% / 50% / 37% utilization)."""
    out = {}
    for m, util in [(40, 0.75), (60, 0.5), (80, 0.375)]:
        sc = Scenario(capacity=m, utilization=0.5 * 60 / m)
        out[f"M={m}"] = run_policies(
            sc, ["carbon-agnostic", "carbonscaler", "wait-awhile",
                 "carbonflex", "carbonflex-mpc", "oracle"])
    return out


def fig9_delay() -> dict:
    """Fig. 9: uniform slack d in {0, 6, 12, 24, 36} hours."""
    out = {}
    for d in [0, 6, 12, 24, 36]:
        sc = Scenario(delay_override=d)
        out[f"d={d}h"] = run_policies(
            sc, ["carbon-agnostic", "wait-awhile", "carbonscaler",
                 "carbonflex", "carbonflex-mpc", "oracle"])
    return out


def fig10_elasticity() -> dict:
    """Fig. 10: high / moderate / low / mix / no-scaling workloads."""
    out = {}
    for el in ["high", "moderate", "low", "mix", "none"]:
        sc = Scenario(elasticity=el)
        out[el] = run_policies(
            sc, ["carbon-agnostic", "wait-awhile", "carbonscaler",
                 "carbonflex", "carbonflex-mpc", "oracle"])
    return out


def fig11_traces() -> dict:
    """Fig. 11: Azure / Alibaba-PAI / SURF trace families."""
    out = {}
    for fam in ["azure", "alibaba", "surf"]:
        out[fam] = run_policies(Scenario(family=fam),
                                ["carbon-agnostic", "gaia", "wait-awhile",
                                 "carbonflex", "carbonflex-mpc", "oracle"])
    return out


def fig12_locations() -> dict:
    """Fig. 12: carbon savings across the 10 regions."""
    from repro.core.carbon import REGIONS

    out = {}
    for region in REGIONS:
        out[region] = run_policies(
            Scenario(region=region),
            ["carbon-agnostic", "carbonscaler", "carbonflex",
             "carbonflex-mpc", "oracle"])
    return out


def fig13_shift() -> dict:
    """Fig. 13: ±20% arrival-rate / job-length distribution shift between
    the learning and evaluation phases (``Scenario.eval_shift`` regenerates
    the evaluation weeks from the shifted distribution while learning stays
    on the unshifted trace)."""
    out = {}
    for shift in [-0.2, -0.1, 0.0, 0.1, 0.2]:
        out[f"shift={shift:+.0%}"] = run_policies(
            Scenario(eval_shift=shift),
            ["carbon-agnostic", "carbonflex", "oracle"])
    return out


def fig14_vcc() -> dict:
    """Fig. 14 (§6.7): VCC / VCC(scaling) / CarbonFlex interop, d=24h."""
    sc = Scenario(delay_override=24)
    return run_policies(sc, ["carbon-agnostic", "vcc", "vcc-scaling",
                             "carbonflex", "carbonflex-mpc", "oracle"])


def tab_overheads() -> dict:
    """§6.8 system overheads: oracle runtime, KNN match latency,
    checkpoint/rescale cost."""
    import jax

    from repro.core import KnowledgeBase, learn_window
    from repro.core.oracle import solve

    mat = Scenario().materialize()
    cluster, ci, hist = mat.cluster, mat.ci, mat.hist
    out = {}

    t = time.time()
    solve([dataclasses.replace(j, arrival=j.arrival % (24 * 7))
           for j in hist[:600]], ci.trace[:24 * 7], cluster.capacity,
          backend="numpy")
    out["oracle_week_numpy_s"] = round(time.time() - t, 2)

    t = time.time()
    solve([dataclasses.replace(j, arrival=j.arrival % (24 * 7))
           for j in hist[:600]], ci.trace[:24 * 7], cluster.capacity,
          backend="jax")
    out["oracle_week_jax_s"] = round(time.time() - t, 2)

    kb = KnowledgeBase()
    learn_window(kb, hist, ci, 0, 24 * 7, cluster,
                 offsets=(0, 24 * 7), backend="numpy")
    state = np.concatenate([[250.0, 0.0, 0.5, 1.0, 1.0],
                            np.ones(6), [1.0, 0.5]])
    kb.query(state)                     # warm
    t = time.time()
    for _ in range(100):
        kb.query(state)
    out["knn_match_ms"] = round((time.time() - t) / 100 * 1e3, 3)

    # checkpoint save/restore (the paper's scancel/restore analogue)
    import tempfile

    from repro.configs import ARCHS, reduced
    from repro.train import CheckpointManager, init_state

    cfg = reduced(ARCHS["llama3-8b"])
    st = init_state(cfg, jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        t = time.time()
        cm.save(1, st, blocking=True)
        out["checkpoint_save_s"] = round(time.time() - t, 3)
        t = time.time()
        cm.restore(jax.eval_shape(lambda: st))
        out["checkpoint_restore_s"] = round(time.time() - t, 3)
    return out


def tpu_cluster() -> dict:
    """Beyond-paper capstone: CarbonFlex managing the 10 assigned
    architectures as elastic TPU training jobs, with scaling profiles
    derived from each arch's compiled dry-run roofline terms (DESIGN.md
    §7) — the loop between the scheduling layer and the training substrate
    closed end-to-end."""
    sc = Scenario(elasticity="tpu", capacity=48)
    return run_policies(sc, ["carbon-agnostic", "wait-awhile", "carbonscaler",
                             "carbonflex", "carbonflex-mpc", "oracle"])


def forecast_gap() -> dict:
    """§Forecast (ISSUE 5): savings-gap-to-oracle under a forecast-error
    ladder (perfect, then AR(1) noise of growing sigma) — the degradation
    curve of carbonflex / wait-awhile and their quantile-robust variants.
    The oracle reads the true trace, so its column is the forecast-free
    upper bound every gap is measured against."""
    from repro.experiment import OracleGap, sigma_ladder

    res = OracleGap(base=Scenario(capacity=40, learn_weeks=2, seed=7),
                    seeds=(1, 2, 3),
                    forecasts=sigma_ladder((0.0, 0.1, 0.2, 0.4))).run()
    return {"baseline": res.baseline,
            "summary": res.summary(),
            "curves": {p: res.degradation_curve(p) for p in res.policies()}}


def fault_sensitivity() -> dict:
    """Beyond-paper: carbon savings under injected stragglers/failures —
    the Algorithm-2 violation-feedback loop absorbing degraded slots."""
    from repro.core.simulator import FaultModel

    out = {}
    for rate in [0.0, 0.1, 0.2]:
        faults = FaultModel(straggler_rate=rate, failure_rate=rate / 4,
                            seed=5) if rate else None
        out[f"straggler={rate:.0%}"] = run_policies(
            Scenario(capacity=40, faults=faults),
            ["carbon-agnostic", "carbonflex-mpc"])
    return out


def resilience() -> dict:
    """§Resilience (ISSUE 6): savings/stretch degradation curves under a
    rising correlated-outage intensity ladder, a preemption regime, and a
    stale carbon feed — carbonflex vs wait-awhile vs the oracle, 3 seeds.
    The oracle plans on the true trace but suffers the same capacity
    shocks, so its column separates environment loss from policy loss."""
    from repro.core import (CarbonDataOutage, CorrelatedFaults,
                            PreemptionFaults)
    from repro.experiment import Sweep

    policies = ["carbon-agnostic", "wait-awhile", "carbonflex", "oracle"]
    seeds = (1, 2, 3)

    def agg(rows: list[dict]) -> dict:
        cells: dict[str, dict[str, list[dict]]] = {}
        for r in rows:
            cells.setdefault(r["fault"], {}).setdefault(r["policy"],
                                                        []).append(r)
        out: dict[str, dict] = {}
        for fault, by_pol in cells.items():
            out[fault] = {}
            for pol, rs in by_pol.items():
                cell = {
                    "savings_mean_pct": round(
                        float(np.mean([r["savings_pct"] for r in rs])), 3),
                    "mean_wait_h": round(
                        float(np.mean([r["mean_wait"] for r in rs])), 3),
                    "violation_rate": round(
                        float(np.mean([r["violation_rate"] for r in rs])), 4),
                }
                resil = [r["resilience"] for r in rs if "resilience" in r]
                if resil:
                    for k in ("evictions", "preemptions", "lost_work_slots",
                              "mttr_slots", "degraded_slots"):
                        cell[k] = round(float(np.mean([m[k] for m in resil])), 3)
                out[fault][pol] = cell
        return out

    # rising correlated-outage intensity + one preemption regime
    faults = [CorrelatedFaults(n_domains=4, rate=p, mean_duration=8.0, seed=5)
              if p else None for p in (0.0, 0.02, 0.05, 0.1)]
    faults.append(PreemptionFaults(rate=0.05, checkpoint_every=4, seed=5))
    grid = Sweep(base=Scenario(capacity=40, seed=7), seeds=seeds,
                 policies=policies, faults=faults).run()
    # stale carbon feed: the policies' CI view degrades, accounting doesn't
    blind = Sweep(base=Scenario(capacity=40, seed=7,
                                ci_outage=CarbonDataOutage(
                                    rate=0.05, mean_duration=6.0,
                                    stale_after=3, seed=5)),
                  seeds=seeds, policies=policies).run()
    return {"baseline": grid.baseline,
            "degradation": agg(grid.rows()),
            "stale_feed": agg(blind.rows()),
            # full per-cell tables (SweepResult.to_csv) — benchmarks.run
            # writes these to results/bench/resilience_<section>.csv
            "csv": {"degradation": grid.to_csv(),
                    "stale_feed": blind.to_csv()}}


def attribution() -> dict:
    """§Telemetry (ISSUE 9): carbon attribution for the four headline
    policy families vs their baselines — each savings delta decomposed
    into named causes (temporal shifting, capacity scaling, geo
    placement, migration overhead, precision tiering, fault restore)
    that sum float-exactly to the measured delta (``Attribution.check``
    asserts ``==``, not a tolerance).  The per-run tables are exported
    as results/bench/attribution.csv by ``benchmarks.run``."""
    from repro.experiment import ServingConfig, Sweep
    from repro.telemetry import CAUSES
    from repro.traces import DagConfig

    grids = {
        "carbonflex": Sweep(base=Scenario(capacity=40, seed=7),
                            policies=["carbon-agnostic", "carbonflex"]),
        "geo-flex": Sweep(base=Scenario(regions=("california", "ontario"),
                                        capacity=24, seed=7),
                          policies=["geo-static", "geo-flex"]),
        "dag-cap": Sweep(base=Scenario(dag=DagConfig(), capacity=40, seed=7),
                         policies=["dag-fcfs", "dag-cap"]),
        "serve-flex": Sweep(base=Scenario(serving=ServingConfig(
                                requests_per_day=3e5, servers=16),
                                learn_weeks=1, seed=7),
                            policies=["serve-static", "serve-flex"]),
    }
    out: dict = {}
    csv_lines = ["family,policy,baseline,seed,delta_g,savings_pct,"
                 + ",".join(CAUSES)]
    for family, sweep in grids.items():
        res = sweep.run()
        atts = res.attributions()             # .check() runs inside
        per_seed = []
        for att, row in zip(atts, [r for r in res.rows()
                                   if r["policy"] != res.baseline]):
            d = att.to_dict()
            d["seed"] = row["seed"]
            per_seed.append(d)
            csv_lines.append(
                f"{family},{att.policy},{att.baseline},{row['seed']},"
                f"{att.delta_g!r},{round(att.savings_pct, 2)},"
                + ",".join(repr(att.causes[c]) for c in CAUSES))
        out[family] = per_seed
    out["csv"] = "\n".join(csv_lines) + "\n"
    return out


ALL = {
    "fig6_cpu_cluster": fig6_cpu_cluster,
    "fig7_gpu_cluster": fig7_gpu_cluster,
    "fig8_capacity": fig8_capacity,
    "fig9_delay": fig9_delay,
    "fig10_elasticity": fig10_elasticity,
    "fig11_traces": fig11_traces,
    "fig12_locations": fig12_locations,
    "fig13_shift": fig13_shift,
    "fig14_vcc": fig14_vcc,
    "tab_overheads": tab_overheads,
    "tpu_cluster": tpu_cluster,
    "fault_sensitivity": fault_sensitivity,
    "forecast_gap": forecast_gap,
    "resilience": resilience,
    "attribution": attribution,
}
