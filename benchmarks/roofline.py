"""Roofline report (deliverable g): reads the dry-run JSONs and prints the
per-(arch x shape x mesh) three-term table + dominant bottleneck.

Run the dry-run first:
  PYTHONPATH=src python -m repro.launch.dryrun --both-meshes
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
DRYRUN_OPT_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                              "dryrun_opt")


def load_cells(mesh: str | None = None, directory: str | None = None) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(directory or DRYRUN_DIR, "*.json"))):
        d = json.load(open(path))
        if mesh and d["mesh"] != mesh:
            continue
        cells.append(d)
    return cells


def report(mesh: str = "16x16") -> list[str]:
    rows = []
    header = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
              f"{'collect_s':>10s} {'dominant':>10s} {'useful':>7s} {'GiB/dev':>8s}")
    rows.append(header)
    for d in load_cells(mesh):
        r = d["roofline"]
        mem = d["memory"]
        gib = ((mem["peak_bytes"] or 0) + (mem["argument_bytes"] or 0)) / 2**30
        useful = r["useful_flops_ratio"]
        rows.append(
            f"{d['arch']:24s} {d['shape']:12s} {r['compute_s']:10.3f} "
            f"{r['memory_s']:10.3f} {r['collective_s']:10.3f} "
            f"{r['dominant']:>10s} "
            f"{useful if useful is None else format(useful, '.2f'):>7} "
            f"{gib:8.2f}")
    return rows


def csv_rows() -> list[str]:
    out = []
    variants = [("roofline", None)]
    if os.path.isdir(DRYRUN_OPT_DIR):
        variants.append(("roofline_opt", DRYRUN_OPT_DIR))
    for prefix, directory in variants:
        for d in load_cells(directory=directory):
            r = d["roofline"]
            dom = r["dominant"]
            dom_s = r[f"{dom}_s"]
            frac = (r["model_flops_per_dev"] / 197e12) / max(dom_s, 1e-12)
            out.append(
                f"{prefix}/{d['arch']}/{d['shape']}/{d['mesh']},"
                f"{d['compile_s'] * 1e6:.0f},"
                f"dominant={dom};compute_s={r['compute_s']:.3f};"
                f"memory_s={r['memory_s']:.3f};collective_s={r['collective_s']:.3f};"
                f"roofline_frac={frac:.3f}")
    return out


if __name__ == "__main__":
    for line in report():
        print(line)
