"""Scan-engine internals (ISSUE-8): native-kind dispatch, the gating
kernel's three implementations, delegation boundaries, and the vmapped
batch tile — every path asserted bit-identical to the scalar reference.

Cross-engine *end-to-end* parity per policy family lives in
``test_engine_parity.py`` / ``test_geo.py`` / ``test_dag.py`` /
``test_resilience.py``; this file pins the scan engine's own moving
parts: which cases run natively vs delegate, that the gather-form and
Pallas-form dependency decrements equal the scatter form exactly, and
that ``simulate_many`` fusing structurally identical scan cases into one
vmapped program returns the same bytes as running them one at a time.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CarbonService, ClusterConfig, GeoCluster,
                        GeoFlexPolicy, GeoStaticPolicy,
                        MultiRegionCarbonService, baselines, simulate)
from repro.core.dag import DagCapPolicy, DagCarbonPolicy, DagFcfsPolicy
from repro.core.faults import CarbonDataOutage, FaultModel
from repro.core.forecast import NoisyForecast, QuantileForecast
from repro.core.scan_engine import native_kind
from repro.core.simulator import SimCase, simulate_many
from repro.kernels import gating
from repro.traces import (DagConfig, TraceSpec, generate_dag_trace,
                          generate_trace)

WEEK = 24 * 7


def assert_identical(a, b, ctx=""):
    assert a.carbon_g == b.carbon_g, ctx
    assert a.energy_kwh == b.energy_kwh, ctx
    np.testing.assert_array_equal(a.completion, b.completion, err_msg=ctx)
    np.testing.assert_array_equal(a.violations, b.violations, err_msg=ctx)
    np.testing.assert_array_equal(a.wait_slots, b.wait_slots, err_msg=ctx)
    for la, lb in zip(a.slots, b.slots):
        assert la == lb, f"{ctx}: slot {la.slot}"


# --- native-kind dispatch -----------------------------------------------------


def test_native_kind_dispatch():
    cluster = ClusterConfig.default(capacity=8)
    geo = GeoCluster.split(8, ("ontario", "california"))
    assert native_kind(baselines.CarbonAgnosticPolicy(), cluster, None) == "plain"
    assert native_kind(DagFcfsPolicy(), cluster, None) == "plain"
    assert native_kind(baselines.WaitAwhilePolicy(), cluster, None) == "thresh"
    assert native_kind(baselines.RobustWaitAwhilePolicy(), cluster, None) == "thresh"
    assert native_kind(DagCarbonPolicy(), cluster, None) == "thresh"
    assert native_kind(DagCapPolicy(), cluster, None) == "cap"
    assert native_kind(GeoStaticPolicy(), geo, None) == "geo-static"
    assert native_kind(GeoFlexPolicy(), geo, None) == "geo-flex"
    # unknown policies and any fault process delegate to the vector engine
    assert native_kind(baselines.GaiaPolicy(mean_length=2.0), cluster, None) is None
    assert native_kind(baselines.CarbonAgnosticPolicy(), cluster,
                       FaultModel(straggler_rate=0.1, seed=1)) is None

    class Tweaked(baselines.WaitAwhilePolicy):
        pass

    # exact type() checks: a subclass may override decide()
    assert native_kind(Tweaked(), cluster, None) is None


# --- gating kernel: scatter == gather == pallas -------------------------------


@pytest.mark.parametrize("seed", [0, 3, 11])
@pytest.mark.parametrize("n_edges", [0, 17, 2048])
def test_dep_decrement_three_way_parity(seed, n_edges):
    """The scatter-form jnp decrement, the transposed gather form the
    scan engine prefers on CPU, and the Pallas kernel must return the
    same int32 counts on random edge sets (integer addition: exact in
    any order)."""
    rng = np.random.default_rng(seed)
    n = 256  # row n-1 is padding and never finishes
    fin = np.zeros(n, dtype=bool)
    fin[:n - 1] = rng.random(n - 1) < 0.4
    parents = rng.integers(0, n - 1, size=n_edges)
    children = rng.integers(0, n - 1, size=n_edges)
    # padded transpose: each row's predecessor list, padding -> row n-1
    deg = np.bincount(children, minlength=n)
    d_pad = max(1, int(deg.max()) if n_edges else 1)
    pred_rows = np.full((n, d_pad), n - 1, dtype=np.int64)
    order = np.argsort(children, kind="stable")
    starts = np.concatenate([[0], np.cumsum(deg)])
    sc = children[order]
    pred_rows[sc, np.arange(len(sc)) - starts[sc]] = parents[order]

    fin_j = jnp.asarray(fin)
    scatter = gating.dep_decrement(fin_j, jnp.asarray(parents),
                                   jnp.asarray(children), n)
    gather = gating.dep_decrement_gather(fin_j, jnp.asarray(pred_rows))
    pallas = gating.dep_decrement_pallas(fin_j, jnp.asarray(parents),
                                         jnp.asarray(children), n,
                                         interpret=True)
    np.testing.assert_array_equal(np.asarray(scatter), np.asarray(gather))
    np.testing.assert_array_equal(np.asarray(scatter), np.asarray(pallas))


# --- scan-native parity off the fast paths ------------------------------------


def _single_world(seed=31):
    cluster = ClusterConfig.default(capacity=12)
    ci = CarbonService.synthetic("germany", WEEK * 2 + 24 * 30, seed=seed)
    spec = TraceSpec(family="azure", hours=WEEK, capacity=12, seed=seed + 1)
    jobs = generate_trace(spec, cluster.queues)
    return cluster, ci, jobs


@pytest.mark.parametrize("policy_cls", [baselines.CarbonAgnosticPolicy,
                                        baselines.WaitAwhilePolicy])
def test_scan_parity_under_feed_outage(policy_cls):
    """An outage-degraded CI view disables every batched table fast path
    (the view is a DegradedCIView, not a plain CarbonService) — the scan
    engine must stay native and still match the scalar engine bit-for-bit
    through the per-slot fallback."""
    cluster, ci, jobs = _single_world()
    ci = dataclasses.replace(
        ci, outage=CarbonDataOutage(windows=((10, 40), (80, 100))))
    assert native_kind(policy_cls(), cluster, None) is not None
    rs = simulate(jobs, ci, cluster, policy_cls(), horizon=WEEK,
                  engine="scalar")
    rc = simulate(jobs, ci, cluster, policy_cls(), horizon=WEEK,
                  engine="scan")
    assert_identical(rs, rc, f"outage/{policy_cls.__name__}")


@pytest.mark.parametrize("forecast", [NoisyForecast(sigma=0.25, seed=7),
                                      QuantileForecast(sigma=0.2, seed=7,
                                                       members=5)])
def test_scan_parity_native_under_forecast_models(forecast):
    """Non-perfect forecast models also bypass the batched eligibility
    table; the per-slot fallback must consume the realized error stream
    exactly like the scalar engine (same RNG order, same floats)."""
    cluster, ci, jobs = _single_world(seed=5)
    ci = dataclasses.replace(ci, model=forecast)
    rs = simulate(jobs, ci, cluster, baselines.WaitAwhilePolicy(),
                  horizon=WEEK, engine="scalar")
    rc = simulate(jobs, ci, cluster, baselines.WaitAwhilePolicy(),
                  horizon=WEEK, engine="scan")
    assert_identical(rs, rc, f"forecast/{forecast!r}")


def test_scan_parity_dag_cap_gather_and_scatter_paths():
    """Precedence gating runs through the gather-form decrement for
    ordinary in-degrees; wide fan-in workloads keep the scatter form.
    Both must match the scalar engine exactly."""
    cluster = ClusterConfig.default(capacity=10)
    ci = CarbonService.synthetic("poland", WEEK * 2 + 24 * 30, seed=9)
    spec = TraceSpec(family="azure", hours=WEEK, capacity=10,
                     utilization=0.4, seed=10)
    for dag in (DagConfig(width=3, depth=4),          # gather path
                DagConfig(width=80, depth=2)):        # scatter fallback
        jobs = generate_dag_trace(spec, dag, cluster.queues)
        for policy_cls in (DagCarbonPolicy, DagCapPolicy):
            rs = simulate(jobs, ci, cluster, policy_cls(), horizon=WEEK,
                          engine="scalar")
            rc = simulate(jobs, ci, cluster, policy_cls(), horizon=WEEK,
                          engine="scan")
            assert_identical(rs, rc, f"{dag.width}x{dag.depth}/"
                                     f"{policy_cls.__name__}")


# --- batched dispatch: one vmapped program == per-case runs -------------------


def test_simulate_many_scan_tile_matches_per_case_runs():
    """simulate_many fuses structurally identical scan cases (same
    packed shape/deps/horizon) into one vmapped tile — mixed policy
    kinds included, since the decision tables live in per-member consts.
    The fused results must equal per-case ``engine="scan"`` runs, which
    in turn equal the scalar reference."""
    cluster, _, jobs = _single_world(seed=17)
    mks = [baselines.CarbonAgnosticPolicy, baselines.WaitAwhilePolicy,
           baselines.RobustWaitAwhilePolicy]
    cases, solo = [], []
    for seed in (0, 1):
        ci = CarbonService.synthetic("texas", WEEK * 2 + 24 * 30, seed=seed)
        for mk in mks:
            cases.append(SimCase(jobs=jobs, ci=ci, cluster=cluster,
                                 policy=mk(), horizon=WEEK, engine="scan",
                                 label=f"s{seed}/{mk.__name__}"))
            solo.append((ci, mk))
    batch = simulate_many(cases)
    assert len(batch) == 6
    for case, res, (ci, mk) in zip(cases, batch, solo):
        one = simulate(jobs, ci, cluster, mk(), horizon=WEEK, engine="scan")
        assert_identical(one, res, f"tile/{case.label}")
        ref = simulate(jobs, ci, cluster, mk(), horizon=WEEK,
                       engine="scalar")
        assert_identical(ref, res, f"tile-vs-scalar/{case.label}")


def test_simulate_many_scan_mixed_native_geo_and_delegated():
    """One batch mixing a vmapped-tile case, a geo-native case, a DAG
    case, and a delegating (unknown-policy) case routes each through the
    right path and matches per-case runs."""
    cluster, ci, jobs = _single_world(seed=23)
    geo = GeoCluster.split(12, ("ontario", "sweden"))
    mci = MultiRegionCarbonService.synthetic(
        ("ontario", "sweden"), WEEK * 2 + 24 * 30, seed=3)
    spec = TraceSpec(family="azure", hours=WEEK, capacity=10,
                     utilization=0.4, seed=24)
    dag_jobs = generate_dag_trace(spec, DagConfig(width=3, depth=3),
                                  cluster.queues)
    cases = [
        SimCase(jobs=jobs, ci=ci, cluster=cluster,
                policy=baselines.WaitAwhilePolicy(), horizon=WEEK,
                engine="scan", label="single"),
        SimCase(jobs=jobs, ci=mci, cluster=geo, policy=GeoFlexPolicy(),
                horizon=WEEK, engine="scan", label="geo"),
        SimCase(jobs=dag_jobs, ci=ci, cluster=cluster,
                policy=DagCarbonPolicy(), horizon=WEEK, engine="scan",
                label="dag"),
        SimCase(jobs=jobs, ci=ci, cluster=cluster,
                policy=baselines.GaiaPolicy(mean_length=2.5), horizon=WEEK,
                engine="scan", label="delegated"),
    ]
    batch = simulate_many(cases)
    refs = [
        simulate(jobs, ci, cluster, baselines.WaitAwhilePolicy(),
                 horizon=WEEK, engine="scalar"),
        simulate(jobs, mci, geo, GeoFlexPolicy(), horizon=WEEK,
                 engine="scalar"),
        simulate(dag_jobs, ci, cluster, DagCarbonPolicy(), horizon=WEEK,
                 engine="scalar"),
        simulate(jobs, ci, cluster, baselines.GaiaPolicy(mean_length=2.5),
                 horizon=WEEK, engine="scalar"),
    ]
    for case, res, ref in zip(cases, batch, refs):
        assert_identical(ref, res, f"mixed/{case.label}")


# --- randomized sweep across native kinds -------------------------------------


@pytest.mark.parametrize("seed", range(4))
def test_scan_parity_randomized(seed):
    rng = np.random.default_rng(seed)
    cap = int(rng.integers(4, 16))
    cluster = ClusterConfig.default(capacity=cap)
    ci = CarbonService.synthetic(
        str(rng.choice(["ontario", "texas", "virginia", "sweden"])),
        WEEK * 2 + 24 * 30, seed=seed)
    spec = TraceSpec(family=str(rng.choice(["azure", "alibaba"])),
                     hours=WEEK, capacity=cap,
                     utilization=float(rng.uniform(0.3, 0.8)), seed=seed)
    jobs = generate_trace(spec, cluster.queues)
    for mk in (baselines.CarbonAgnosticPolicy, baselines.WaitAwhilePolicy,
               baselines.RobustWaitAwhilePolicy):
        rs = simulate(jobs, ci, cluster, mk(), horizon=WEEK,
                      engine="scalar")
        rc = simulate(jobs, ci, cluster, mk(), horizon=WEEK, engine="scan")
        assert_identical(rs, rc, f"rand{seed}/{mk.__name__}")
