"""HLO static-analysis tests: trip-count weighting, collectives, flops."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import (_shape_bytes, analyze_collectives,
                                       analyze_module)

SYNTH = """
HloModule test

%body.1 (p: (s32[], f32[128,64])) -> (s32[], f32[128,64]) {
  %p = (s32[], f32[128,64]) parameter(0)
  %ar = f32[128,64]{1,0} all-reduce(%gte), replica_groups={}
  ROOT %t = (s32[], f32[128,64]) tuple(%i, %ar)
}

%cond.1 (p: (s32[], f32[128,64])) -> pred[] {
  %p = (s32[], f32[128,64]) parameter(0)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main.1 (a: f32[128,64]) -> f32[128,64] {
  %a = f32[128,64]{1,0} parameter(0)
  %w = (s32[], f32[128,64]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"7"}}
  %ag = f32[256,64]{1,0} all-gather(%a), dimensions={0}
  ROOT %r = f32[128,64]{1,0} get-tuple-element(%w), index=1
}
"""


class TestShapeBytes:
    def test_simple(self):
        assert _shape_bytes("f32[128,64]{1,0}") == 128 * 64 * 4
        assert _shape_bytes("bf16[2,3]") == 12
        assert _shape_bytes("(f32[4], s32[2])") == 16 + 8

    def test_scalar_and_unknown(self):
        assert _shape_bytes("f32[]") == 4
        assert _shape_bytes("token[]") == 0


class TestSyntheticModule:
    def test_trip_count_weighting(self):
        stats = analyze_collectives(SYNTH)
        ar = 128 * 64 * 4
        ag = 256 * 64 * 4
        # all-reduce inside the while body runs 7x; ring factor 2
        assert stats.by_type["all-reduce"] == 7 * ar
        assert stats.by_type["all-gather"] == ag
        assert stats.wire_bytes == 7 * ar * 2.0 + ag
        assert stats.count == 2


class TestRealModules:
    def test_matmul_flops(self):
        f = jax.jit(lambda a, b: a @ b)
        low = f.lower(jax.ShapeDtypeStruct((64, 32), jnp.float32),
                      jax.ShapeDtypeStruct((32, 16), jnp.float32))
        st = analyze_module(low.compile().as_text())
        assert st.flops == 2 * 64 * 16 * 32

    def test_scan_flops_multiplied(self):
        def f(x, w):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=5)
            return out

        low = jax.jit(f).lower(jax.ShapeDtypeStruct((8, 8), jnp.float32),
                               jax.ShapeDtypeStruct((8, 8), jnp.float32))
        st = analyze_module(low.compile().as_text(),
                            scan_trip_hints={"while": 5})
        assert st.flops == 5 * 2 * 8 * 8 * 8

    def test_no_collectives_single_device(self):
        f = jax.jit(lambda a: a * 2)
        low = f.lower(jax.ShapeDtypeStruct((16,), jnp.float32))
        st = analyze_module(low.compile().as_text())
        assert st.collectives.count == 0
