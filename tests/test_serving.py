"""Serving-tier tests (ISSUE 7): derived precision tiers, the quality
credit ledger, the vectorized serving engine, and the experiment threading.

Invariant families:

- the tier table is *derived*, not asserted: the numpy quantization-error
  replica is pinned against the jax ``elastic/compression.py`` original,
  energy/capacity follow the byte-scaling decode argument;
- the ledger is bounded in [-1, +1] at every slot under arbitrary quality
  streams (hypothesis property + fixed-seed twin);
- vector-vs-scalar engine parity is bit-identical for every serve policy,
  with and without a degraded (noisy) carbon forecast;
- demand conservation: every request lands on exactly one tier;
- the experiment layer threads serving scenarios end-to-end (run / Sweep /
  serialization round-trip) and rejects the axis combinations serving
  excludes (dag, regions, faults, batch policies);
- acceptance scale: a 1.5M-requests/day, 2-week sweep cell runs in
  seconds.
"""
import dataclasses
import json
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import CarbonService, NoisyForecast
from repro.core.faults import IidFaults
from repro.experiment import (DEFAULT_SERVE_POLICIES, Scenario, Sweep, WEEK,
                              run)
from repro.serving import (CreditLedger, ServeCase, ServeFlexPolicy,
                           ServeGreedyPolicy, ServeStaticPolicy,
                           ServingConfig, SloModel, derive_tiers,
                           mix_for_quality, simulate_serving)
from repro.serving.tiers import _bf16_rms_rel_error, _int8_rms_rel_error
from repro.traces import (DagConfig, expected_request_rate,
                          generate_request_demand)

SERVE_POLICIES = {
    "serve-static": ServeStaticPolicy,
    "serve-greedy": ServeGreedyPolicy,
    "serve-flex": ServeFlexPolicy,
}

TINY = dict(requests_per_day=2e5, servers=12)


# --- derived tier table ------------------------------------------------------


def test_int8_error_replica_matches_jax_compression():
    """The numpy replica of the int8 scheme (tiers quality input) must
    track the jax ``_int8_roundtrip`` original on the same tensor."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.elastic.compression import _int8_roundtrip

    g = np.random.default_rng(0).normal(0.0, 1.0, 1 << 14)
    rt = np.asarray(_int8_roundtrip(jnp.asarray(g, dtype=jnp.float32)),
                    dtype=np.float64)
    jax_err = float(np.sqrt(np.mean((rt - g) ** 2) / np.mean(g ** 2)))
    assert _int8_rms_rel_error() == pytest.approx(jax_err, rel=1e-3)


def test_tier_table_byte_scaling_and_quality():
    fp32, bf16, int8 = derive_tiers()
    assert [t.name for t in (fp32, bf16, int8)] == ["fp32", "bf16", "int8"]
    # energy scales with bytes moved, capacity inversely (memory-bound)
    assert bf16.energy_kwh_per_kreq == fp32.energy_kwh_per_kreq / 2
    assert int8.energy_kwh_per_kreq == fp32.energy_kwh_per_kreq / 4
    assert bf16.capacity_per_server == fp32.capacity_per_server * 2
    assert int8.capacity_per_server == fp32.capacity_per_server * 4
    # quality strictly descending, derived from the measured rms errors
    assert fp32.quality == 1.0
    assert bf16.quality == pytest.approx(1.0 - 5.0 * _bf16_rms_rel_error())
    assert int8.quality == pytest.approx(1.0 - 5.0 * _int8_rms_rel_error())
    assert fp32.quality > bf16.quality > int8.quality > 0.9


def test_mix_for_quality_hits_target_between_adjacent_tiers():
    q = np.array([t.quality for t in derive_tiers()])
    for target in (0.99, 0.98, 0.96):
        frac = mix_for_quality(q, target)
        assert frac.sum() == pytest.approx(1.0)
        assert np.all(frac >= 0)
        assert float(frac @ q) == pytest.approx(target)
        assert np.count_nonzero(frac) <= 2        # adjacent pair only
    # out-of-range targets clamp to the nearest pure tier
    assert list(mix_for_quality(q, 1.5)) == [1, 0, 0]
    assert list(mix_for_quality(q, 0.1)) == [0, 0, 1]


def test_slo_model_knee_curve():
    slo = SloModel(knee=0.75, gamma=2.0)
    assert slo.violation_frac(0.5) == 0.0
    assert slo.violation_frac(0.75) == 0.0
    assert slo.violation_frac(1.0) == 1.0
    assert slo.violation_frac(2.0) == 1.0          # saturates
    u = np.linspace(0.0, 1.2, 50)
    v = slo.violation_frac(u)
    assert v.shape == u.shape
    assert np.all(np.diff(v) >= 0)                 # monotone in utilization


# --- request-trace generator -------------------------------------------------


def test_request_trace_deterministic_and_scaled():
    a = generate_request_demand(24 * 14, 1.5e6, seed=3)
    b = generate_request_demand(24 * 14, 1.5e6, seed=3)
    c = generate_request_demand(24 * 14, 1.5e6, seed=4)
    assert a.shape == (24 * 14,)
    assert np.array_equal(a, b)                    # seeded, reproducible
    assert not np.array_equal(a, c)
    assert np.all(a >= 0) and np.all(a == np.floor(a))   # request counts
    # total volume tracks requests_per_day x days (Poisson + rare bursts)
    assert a.sum() == pytest.approx(1.5e6 * 14, rel=0.1)


def test_expected_rate_peaks_at_peak_hour_and_dips_on_weekends():
    rate = expected_request_rate(24 * 7, 1e6, peak_hour=14, weekly=0.15)
    day = rate[:24]
    assert int(np.argmax(day)) == 14
    weekday, weekend = rate[:24 * 5].mean(), rate[24 * 5:].mean()
    assert weekend < weekday


# --- credit ledger bound -----------------------------------------------------


def _check_ledger_bounded(qualities, gain: float, target: float):
    ledger = CreditLedger(gain=gain)
    for q in qualities:
        b = ledger.update(q, target)
        assert -1.0 <= b <= 1.0
        assert ledger.spend_headroom() == pytest.approx((b + 1) / 2)
        assert ledger.repay_headroom() == pytest.approx((1 - b) / 2)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=1,
                max_size=200),
       st.floats(min_value=0.01, max_value=5.0),
       st.floats(min_value=0.1, max_value=1.0))
def test_ledger_bounded_property(qualities, gain, target):
    _check_ledger_bounded(qualities, gain, target)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ledger_bounded_fixed(seed):
    rng = np.random.default_rng(seed)
    _check_ledger_bounded(rng.uniform(0.0, 1.0, 500), gain=2.0, target=0.98)


def test_ledger_saturates_and_recovers():
    ledger = CreditLedger(gain=1.0)
    for _ in range(10):
        ledger.update(0.0, 1.0)                    # pure debt
    assert ledger.balance == -1.0
    ledger.update(1.0, 0.0)                        # one full repayment step
    assert ledger.balance == 0.0


# --- engine parity + conservation --------------------------------------------


def _tiny_case(policy_name: str, seed: int = 3, forecast=None,
               hours: int = WEEK * 2) -> ServeCase:
    cfg = ServingConfig(**TINY)
    trace = np.random.default_rng(seed).uniform(30.0, 700.0, hours + 24)
    return ServeCase(
        demand=generate_request_demand(hours, cfg.requests_per_day,
                                       seed=seed + 1),
        rate=expected_request_rate(hours + 24, cfg.requests_per_day),
        ci=CarbonService(trace=trace, model=forecast),
        config=cfg, policy=SERVE_POLICIES[policy_name](), t0=0,
        label=policy_name)


@pytest.mark.parametrize("noisy", [False, True], ids=["perfect", "noisy"])
@pytest.mark.parametrize("policy", list(SERVE_POLICIES))
def test_vector_scalar_parity(policy, noisy):
    fc = NoisyForecast(sigma=0.3, seed=5) if noisy else None
    rs = simulate_serving(_tiny_case(policy, forecast=fc), engine="scalar")
    rv = simulate_serving(_tiny_case(policy, forecast=fc), engine="vector")
    assert rs.carbon_g == rv.carbon_g
    assert rs.energy_kwh == rv.energy_kwh
    assert rs.serving.tier_requests == rv.serving.tier_requests
    for field in ("balance", "utilization", "quality", "violation_frac"):
        a, b = getattr(rs.serving, field), getattr(rv.serving, field)
        assert np.array_equal(a, b), f"{policy}: {field} diverged"


@pytest.mark.parametrize("policy", list(SERVE_POLICIES))
def test_every_request_lands_on_exactly_one_tier(policy):
    case = _tiny_case(policy)
    res = simulate_serving(case)
    assert sum(res.serving.tier_requests) == \
        pytest.approx(float(case.demand.sum()), rel=1e-9)
    assert res.serving.requests == float(case.demand.sum())
    assert -1.0 <= res.serving.ledger_min <= res.serving.ledger_max <= 1.0


def test_engine_rejects_bad_split_and_short_trace():
    class BadPolicy:
        name = "bad"

        def on_window_start(self, w):
            self.n = len(w.tiers)

        def decide(self, t, demand, balance, cum_carbon_g, cum_requests):
            return np.full(self.n, 0.9)            # sums to 2.7

    case = _tiny_case("serve-static", hours=48)
    case = dataclasses.replace(case, policy=BadPolicy())
    with pytest.raises(ValueError, match="invalid tier split"):
        simulate_serving(case)
    with pytest.raises(ValueError, match="CI trace too short"):
        ServeCase(demand=np.ones(10_000), rate=np.ones(10_024),
                  ci=CarbonService(trace=np.full(100, 300.0)),
                  config=ServingConfig(), policy=ServeStaticPolicy())
    with pytest.raises(ValueError, match="unknown serving engine"):
        simulate_serving(_tiny_case("serve-static", hours=48), engine="jax")


# --- the quality-for-carbon trade --------------------------------------------


def test_serve_flex_saves_carbon_at_bounded_violation_rate():
    res = run(Scenario(serving=ServingConfig(**TINY), learn_weeks=1,
                       eval_weeks=2, seed=7))
    assert res.policies == DEFAULT_SERVE_POLICIES
    static_viol = res.violation_rate("serve-static")
    for pol in ("serve-greedy", "serve-flex"):
        assert res.savings(pol) > 10.0
        # relieving into higher-capacity tiers must not *add* violations
        assert res.violation_rate(pol) <= static_viol + 1e-9
        assert res.violation_rate(pol) < 0.02
        # quality stays in a tight band around the target
        assert 0.95 < res.quality_mean(pol) < 1.0
    assert res.savings("serve-flex") >= res.savings("serve-greedy") - 1.0
    m = res.metrics()
    assert "quality_mean" in m["serve-flex"]
    assert "ledger_final" in m["serve-flex"]


# --- experiment threading ----------------------------------------------------


def test_scenario_serving_round_trip_and_materialize():
    sc = Scenario(serving=ServingConfig(requests_per_day=3e5, servers=16),
                  learn_weeks=1, eval_weeks=1, seed=5)
    assert sc.is_serving
    rt = Scenario.from_dict(json.loads(json.dumps(sc.to_dict())))
    assert rt == sc
    mat = sc.materialize()
    span = (sc.learn_weeks + sc.eval_weeks) * WEEK
    assert mat.serving.demand.shape == (span,)
    assert mat.serving.rate.shape == (span + 24,)   # look-ahead margin
    assert mat.jobs == [] and mat.eval_jobs == []


def test_scenario_rejects_serving_combinations():
    serving = ServingConfig(**TINY)
    with pytest.raises(ValueError, match="serving"):
        Scenario(serving=serving, dag=DagConfig())
    with pytest.raises(ValueError, match="single-region"):
        Scenario(serving=serving, regions=("california", "ontario"))
    with pytest.raises(ValueError, match="ci_outage"):
        Scenario(serving=serving, faults=IidFaults(failure_rate=0.01))


def test_policy_family_and_scenario_kind_must_match():
    with pytest.raises(ValueError, match="serving workload"):
        run(Scenario(), ["serve-flex"])
    with pytest.raises(ValueError, match="serve policy family"):
        run(Scenario(serving=ServingConfig(**TINY)), ["carbon-agnostic"])


def test_serving_sweep_rejects_fault_axis():
    sw = Sweep(base=Scenario(serving=ServingConfig(**TINY), learn_weeks=1,
                             eval_weeks=1),
               policies=DEFAULT_SERVE_POLICIES,
               faults=[IidFaults(failure_rate=0.01)])
    with pytest.raises(ValueError, match="no fault axis"):
        sw.run()


def test_serving_sweep_acceptance_scale_and_csv():
    """The ISSUE-7 acceptance cell: >= 1M requests/day over a 2-week
    window inside one sweep, in seconds not minutes."""
    sw = Sweep(base=Scenario(serving=ServingConfig(requests_per_day=1.5e6),
                             learn_weeks=1, eval_weeks=2, seed=7),
               seeds=[1, 2], policies=DEFAULT_SERVE_POLICIES)
    t = time.perf_counter()
    res = sw.run()
    elapsed = time.perf_counter() - t
    assert elapsed < 20.0, f"serving sweep took {elapsed:.1f}s"
    assert res.baseline == "serve-static"
    rows = res.rows()
    assert len(rows) == 2 * 3
    for r in rows:
        assert r["serving"]["requests"] >= 1e6 * 14
        assert -1.0 <= r["serving"]["ledger_min"] <= 1.0
    flex = [r for r in rows if r["policy"] == "serve-flex"]
    assert all(r["savings_pct"] > 10.0 for r in flex)
    # CSV export flattens the serving dict to dotted columns
    csv_text = res.to_csv()
    header = csv_text.splitlines()[0].split(",")
    assert "serving.violation_rate" in header
    assert "serving.tier_requests" in header
    assert len(csv_text.splitlines()) == len(rows) + 1


def test_serving_sweep_forecast_axis():
    sw = Sweep(base=Scenario(serving=ServingConfig(**TINY), learn_weeks=1,
                             eval_weeks=1, seed=7),
               policies=DEFAULT_SERVE_POLICIES,
               forecasts=[None, NoisyForecast(sigma=0.3, seed=5)])
    rows = sw.run().rows()
    assert {r["forecast"] for r in rows} == {"perfect", "noisy(s=0.3)"}
    # the noisy forecast changes what serve-flex sees, hence what it emits
    flex = {r["forecast"]: r["carbon_g"] for r in rows
            if r["policy"] == "serve-flex"}
    assert flex["perfect"] != flex["noisy(s=0.3)"]
    static = {r["forecast"]: r["carbon_g"] for r in rows
              if r["policy"] == "serve-static"}
    assert static["perfect"] == static["noisy(s=0.3)"]   # forecast-blind
