"""Simulator-level fault/straggler injection + policy compensation."""

from repro.core import CarbonService, ClusterConfig, baselines, simulate
from repro.core.policy import CarbonFlexMPCPolicy
from repro.core.simulator import FaultModel
from repro.traces import TraceSpec, generate_trace

WEEK = 24 * 7


def _world(seed=13, cap=20):
    cluster = ClusterConfig.default(capacity=cap)
    ci = CarbonService.synthetic("california", WEEK * 3, seed=seed)
    jobs = generate_trace(TraceSpec(hours=WEEK, capacity=cap, seed=seed + 1),
                          cluster.queues)
    return cluster, ci, jobs


class TestFaultModel:
    def test_deterministic(self):
        a = FaultModel(straggler_rate=0.2, failure_rate=0.1, seed=5)
        b = FaultModel(straggler_rate=0.2, failure_rate=0.1, seed=5)
        seq_a = [a.progress_factor(t, 0) for t in range(50)]
        seq_b = [b.progress_factor(t, 0) for t in range(50)]
        assert seq_a == seq_b
        assert set(seq_a) <= {0.0, 0.5, 1.0}

    def test_all_jobs_still_complete_under_faults(self):
        cluster, ci, jobs = _world()
        res = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                       horizon=WEEK,
                       faults=FaultModel(straggler_rate=0.15,
                                         failure_rate=0.05, seed=2))
        assert (res.completion >= 0).all()

    def test_faults_cost_energy_and_delay(self):
        cluster, ci, jobs = _world()
        clean = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                         horizon=WEEK)
        faulty = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                          horizon=WEEK,
                          faults=FaultModel(straggler_rate=0.2,
                                            failure_rate=0.1, seed=2))
        assert faulty.energy_kwh > clean.energy_kwh     # lost slots re-run
        assert faulty.completion.max() >= clean.completion.max()

    def test_carbonaware_policy_survives_faults(self):
        """CarbonFlex keeps saving carbon under faults; the violation
        feedback loop (Algorithm 2) absorbs the lost progress."""
        cluster, ci, jobs = _world(cap=20)
        base = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                        horizon=WEEK,
                        faults=FaultModel(straggler_rate=0.15, seed=3))
        pol = CarbonFlexMPCPolicy()
        pol.warm_start(jobs)
        res = simulate(jobs, ci, cluster, pol, horizon=WEEK,
                       faults=FaultModel(straggler_rate=0.15, seed=3))
        assert (res.completion >= 0).all()
        assert res.savings_vs(base) > 5.0
