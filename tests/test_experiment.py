"""Declarative experiment API: registry, Scenario, driver, Sweep.

Covers the ISSUE-2 acceptance points: every registered policy constructs
through ``make_policy`` and completes a tiny scenario; ``simulate_many``
batches are identical to per-case ``simulate`` runs; a sweep packs each
scenario's jobs exactly once and its JSON round-trips; ``learn_window``
takes a ``ClusterConfig`` (loose form deprecated) and reports which replay
offsets contributed."""
import json

import numpy as np
import pytest

import repro.core.simulator as sim_mod
from repro.core import (CarbonService, ClusterConfig, KnowledgeBase,
                        LearnOutcome, baselines, learn_window, simulate,
                        synthesize_trace)
from repro.core.simulator import FaultModel, SimCase, simulate_many
from repro.experiment import (Scenario, Sweep, SweepResult, WEEK,
                              available_policies, make_policy,
                              prepare_context, run)
from repro.experiment.registry import PolicyContext

TINY = dict(capacity=8, learn_weeks=1, seed=3, family="alibaba")


@pytest.fixture(scope="module")
def tiny():
    return Scenario(**TINY)


# --- Scenario ----------------------------------------------------------------


class TestScenario:
    def test_materialize_is_cached_and_split_is_consistent(self, tiny):
        a, b = tiny.materialize(), tiny.materialize()
        assert a is b                       # same job lists -> one packing
        assert a.t0 == tiny.learn_weeks * WEEK
        assert all(j.arrival < a.t0 for j in a.hist)
        assert all(a.t0 <= j.arrival < tiny.hours for j in a.eval_jobs)
        assert len(a.ci) >= tiny.hours

    def test_eval_shift_regenerates_only_eval_weeks(self):
        plain = Scenario(**TINY).materialize()
        shifted = Scenario(**TINY, eval_shift=0.2).materialize()
        assert [j.job_id for j in plain.hist] == [j.job_id for j in shifted.hist]
        assert len(shifted.eval_jobs) != len(plain.eval_jobs) or \
            any(a.length != b.length for a, b in
                zip(plain.eval_jobs, shifted.eval_jobs))

    def test_unknown_region_raises_value_error(self):
        with pytest.raises(ValueError, match="nowhere.*california"):
            Scenario(region="nowhere")
        with pytest.raises(ValueError, match="nowhere"):
            synthesize_trace("nowhere", 24)
        with pytest.raises(ValueError, match="nowhere"):
            CarbonService.synthetic("nowhere", 24)

    def test_to_dict_round_trip(self):
        sc = Scenario(**TINY, faults=FaultModel(straggler_rate=0.1, seed=4))
        rt = Scenario.from_dict(json.loads(json.dumps(sc.to_dict())))
        assert rt.region == sc.region and rt.seed == sc.seed
        assert rt.faults.straggler_rate == 0.1


# --- registry ----------------------------------------------------------------


class TestRegistry:
    def test_all_policies_complete_tiny_scenario(self, tiny):
        """Round-trip: every registered single-region policy constructs via
        make_policy and completes the tiny scenario without error (geo
        policies run on geo scenarios — tests/test_geo.py — dag policies
        on DAG scenarios — tests/test_dag.py — and serve policies on
        serving scenarios — tests/test_serving.py)."""
        from repro.experiment.registry import get_spec

        names = available_policies()
        assert set(names) >= {"carbon-agnostic", "gaia", "wait-awhile",
                              "carbonscaler", "vcc", "vcc-scaling",
                              "carbonflex", "carbonflex-mpc", "oracle",
                              "geo-static", "geo-greedy", "geo-flex",
                              "dag-fcfs", "dag-carbon", "dag-cap",
                              "serve-static", "serve-greedy", "serve-flex"}
        names = tuple(n for n in names
                      if not get_spec(n).geo and not get_spec(n).dag
                      and not get_spec(n).serve)
        res = run(tiny, names)
        for name in names:
            assert len(res.weekly[name]) == 1, name
            r = res.weekly[name][0]
            assert r.carbon_g > 0, name
            assert (r.completion >= 0).all(), name

    def test_kb_policy_requires_learning(self, tiny):
        mat = tiny.materialize()
        ctx = PolicyContext(cluster=mat.cluster, ci=mat.ci)
        with pytest.raises(ValueError, match="KnowledgeBase"):
            make_policy("carbonflex", ctx)

    def test_unknown_policy_lists_registered(self, tiny):
        mat = tiny.materialize()
        ctx = PolicyContext(cluster=mat.cluster, ci=mat.ci)
        with pytest.raises(ValueError, match="carbon-agnostic"):
            make_policy("not-a-policy", ctx)


# --- driver ------------------------------------------------------------------


class TestDriver:
    def test_carbonflex_beats_agnostic_through_driver(self, tiny):
        res = run(tiny, ["carbon-agnostic", "carbonflex", "oracle"])
        assert res.kb_size == tiny.learn_weeks * WEEK
        assert res.savings("carbonflex") > 0.0
        assert res.savings("oracle") >= res.savings("carbonflex") - 5.0
        m = res.metrics()
        assert m["carbonflex"]["savings_pct"] == pytest.approx(
            res.savings("carbonflex"), abs=0.01)

    def test_continuous_learning_grows_kb_weekly(self):
        sc = Scenario(**{**TINY, "seed": 5}, eval_weeks=2)
        res = run(sc, ["carbon-agnostic", "carbonflex"])
        # initial learn week + one re-learned evaluated week
        assert res.kb_size == 2 * WEEK
        assert len(res.weekly["carbonflex"]) == 2

    def test_faulty_scenario_runs_with_fresh_fault_streams(self):
        sc = Scenario(**{**TINY, "seed": 6},
                      faults=FaultModel(straggler_rate=0.2, seed=9))
        res = run(sc, ["carbon-agnostic"])
        again = run(sc, ["carbon-agnostic"])
        # same seeded fault stream both times -> identical results
        assert res.carbon_g("carbon-agnostic") == again.carbon_g("carbon-agnostic")


# --- simulate_many parity ----------------------------------------------------


class TestBatchParity:
    NAMES = ["carbon-agnostic", "wait-awhile", "carbonscaler", "carbonflex"]

    def test_simulate_many_equals_per_case_simulate(self, tiny):
        mat = tiny.materialize()
        ctx = prepare_context(mat, self.NAMES)
        cases = [SimCase(jobs=mat.eval_jobs, ci=mat.ci, cluster=mat.cluster,
                         policy=make_policy(n, ctx), t0=mat.t0, horizon=WEEK,
                         label=n) for n in self.NAMES]
        batch = simulate_many(cases)
        for n, r in zip(self.NAMES, batch):
            solo = simulate(mat.eval_jobs, mat.ci, mat.cluster,
                            make_policy(n, ctx), t0=mat.t0, horizon=WEEK)
            assert solo.carbon_g == r.carbon_g, n
            np.testing.assert_array_equal(solo.wait_slots, r.wait_slots, err_msg=n)
            np.testing.assert_array_equal(solo.violations, r.violations, err_msg=n)

    def test_parity_holds_under_faults(self, tiny):
        mat = tiny.materialize()
        ctx = prepare_context(mat, ["carbon-agnostic"])
        mk_faults = lambda: FaultModel(straggler_rate=0.15,  # noqa: E731
                                       failure_rate=0.05, seed=2)
        [r] = simulate_many([SimCase(
            jobs=mat.eval_jobs, ci=mat.ci, cluster=mat.cluster,
            policy=make_policy("carbon-agnostic", ctx), t0=mat.t0,
            horizon=WEEK, faults=mk_faults())])
        solo = simulate(mat.eval_jobs, mat.ci, mat.cluster,
                        make_policy("carbon-agnostic", ctx), t0=mat.t0,
                        horizon=WEEK, faults=mk_faults())
        assert solo.carbon_g == r.carbon_g
        np.testing.assert_array_equal(solo.wait_slots, r.wait_slots)
        np.testing.assert_array_equal(solo.violations, r.violations)


# --- Sweep -------------------------------------------------------------------


class TestSweep:
    def test_grid_packs_once_per_scenario_and_round_trips(self, monkeypatch):
        packs = []
        orig = sim_mod.PackedJobs

        class CountingPackedJobs(orig):
            def __init__(self, jobs_sorted):
                packs.append(len(jobs_sorted))
                super().__init__(jobs_sorted)

        monkeypatch.setattr(sim_mod, "PackedJobs", CountingPackedJobs)
        sweep = Sweep(
            base=Scenario(capacity=8, learn_weeks=1, family="alibaba"),
            regions=["california", "ontario"], seeds=[31, 32],
            policies=["carbon-agnostic", "wait-awhile", "gaia", "carbonflex"])
        sr = sweep.run()
        # 2 regions x 2 seeds -> 4 scenarios, each packed exactly once
        # even though each runs 4 policies
        assert len(packs) == 4
        assert len(sr.rows()) == 16

        base_rows = [r for r in sr.rows() if r["policy"] == "carbon-agnostic"]
        assert all(r["savings_pct"] == 0.0 for r in base_rows)
        flex = [r for r in sr.rows() if r["policy"] == "carbonflex"]
        assert {(r["region"], r["seed"]) for r in flex} == \
            {("california", 31), ("california", 32),
             ("ontario", 31), ("ontario", 32)}

        payload = sr.to_json()
        restored = SweepResult.from_json(payload)
        assert restored.to_json() == payload
        assert restored.summary()["carbonflex"]["n_cases"] == 4

    def test_to_csv_header_is_union_over_mixed_row_shapes(self):
        """ISSUE-8 satellite: heterogeneous sweeps (fault axes where only
        some rows carry resilience metrics, serving rows with nested
        dicts, columns that first appear mid-list) must export as one
        rectangular CSV — header = first-seen-order union of every row's
        flattened keys, missing cells empty."""
        import csv
        import io

        rows = [
            {"region": "ontario", "seed": 1, "policy": "a", "carbon_g": 10.0},
            {"region": "ontario", "seed": 1, "policy": "b", "carbon_g": 9.0,
             "resilience": {"evictions": 3, "lost_work_slots": 1.5}},
            {"region": "texas", "seed": 2, "policy": "a", "carbon_g": 8.0,
             "forecast": "noisy", "tiers": ["full", "half"]},
        ]
        csv_text = SweepResult(baseline="a", rows_=rows).to_csv()
        lines = csv_text.splitlines()
        assert lines[0].split(",") == [
            "region", "seed", "policy", "carbon_g",
            "resilience.evictions", "resilience.lost_work_slots",
            "forecast", "tiers"]
        parsed = list(csv.DictReader(io.StringIO(csv_text)))
        assert len(parsed) == 3
        # rows missing a column get empty cells, not dropped columns
        assert parsed[0]["resilience.evictions"] == ""
        assert parsed[1]["resilience.evictions"] == "3"
        assert parsed[0]["forecast"] == "" and parsed[2]["forecast"] == "noisy"
        # list values join with | so the table stays one value per cell
        assert parsed[2]["tiers"] == "full|half"
        assert all(len(line.split(",")) == 8 for line in lines)

    def test_base_scenario_faults_inherited(self):
        base = Scenario(capacity=8, learn_weeks=1, family="alibaba", seed=51)
        faulty = Scenario(capacity=8, learn_weeks=1, family="alibaba", seed=51,
                          faults=FaultModel(straggler_rate=0.4,
                                            failure_rate=0.1, seed=7))
        clean = Sweep(base=base, policies=["carbon-agnostic"]).run()
        injected = Sweep(base=faulty, policies=["carbon-agnostic"]).run()
        assert injected.rows()[0]["fault"] != "none"
        assert injected.rows()[0]["carbon_g"] != clean.rows()[0]["carbon_g"]

    def test_baseline_added_when_missing(self):
        sweep = Sweep(base=Scenario(capacity=8, learn_weeks=1,
                                    family="alibaba", seed=41),
                      policies=["wait-awhile"])
        sr = sweep.run()
        assert {r["policy"] for r in sr.rows()} == \
            {"carbon-agnostic", "wait-awhile"}
        assert all("savings_pct" in r for r in sr.rows())


# --- learn_window surface ----------------------------------------------------


class TestLearnWindow:
    def _world(self):
        cluster = ClusterConfig.default(capacity=10)
        ci = CarbonService.synthetic("ontario", WEEK * 3, seed=17)
        from repro.traces import TraceSpec, generate_trace

        jobs = generate_trace(TraceSpec(family="alibaba", hours=WEEK,
                                        capacity=10, seed=18), cluster.queues)
        return cluster, ci, jobs

    def test_cluster_config_form_reports_contributing_offsets(self):
        cluster, ci, jobs = self._world()
        kb = KnowledgeBase()
        # the middle offset's window holds no arrivals (trace spans 1 week)
        out = learn_window(kb, jobs, ci, 0, WEEK, cluster,
                           offsets=(0, WEEK, 0), backend="numpy")
        assert isinstance(out, LearnOutcome)
        assert out.contributed == (0, 0)
        assert out.empty == (WEEK,)
        assert len(out) == 2 and len(list(out)) == 2   # list-compat
        assert len(kb) == 2 * WEEK

    def test_deprecated_loose_form_still_works_and_warns(self):
        cluster, ci, jobs = self._world()
        kb_new, kb_old = KnowledgeBase(), KnowledgeBase()
        learn_window(kb_new, jobs, ci, 0, WEEK, cluster, backend="numpy")
        with pytest.warns(DeprecationWarning, match="ClusterConfig"):
            learn_window(kb_old, jobs, ci, 0, WEEK, cluster.capacity,
                         len(cluster.queues), backend="numpy")
        assert len(kb_old) == len(kb_new)

    def test_cluster_config_plus_num_queues_rejected(self):
        cluster, ci, jobs = self._world()
        with pytest.raises(TypeError, match="implied"):
            learn_window(KnowledgeBase(), jobs, ci, 0, WEEK, cluster, 3)
        with pytest.raises(TypeError, match="num_queues"):
            learn_window(KnowledgeBase(), jobs, ci, 0, WEEK, cluster.capacity)


# --- SimResult serialization -------------------------------------------------


def test_sim_result_to_dict_json_safe(tiny):
    mat = tiny.materialize()
    r = simulate(mat.eval_jobs, mat.ci, mat.cluster,
                 baselines.CarbonAgnosticPolicy(), t0=mat.t0, horizon=WEEK)
    d = r.to_dict()
    assert set(d) == {"policy", "carbon_g", "energy_kwh", "num_jobs",
                      "mean_wait", "violation_rate"}
    full = r.to_dict(include_per_job=True, include_slots=True)
    assert len(full["completion"]) == r.num_jobs
    assert len(full["slots"]) == len(r.slots)
    json.dumps(full)            # everything JSON-serialisable
