"""Edge cases of the error-feedback gradient compressors (ISSUE-7
satellite) — fast, pure-CPU checks that don't need the slow elastic
end-to-end suite: the int8 scale floor on an all-zero gradient, top-k's
k >= 1 clamp under a vanishing ratio, and bit-for-bit determinism of the
compress -> residual step."""
import jax.numpy as jnp
import numpy as np

from repro.elastic.compression import (_int8_roundtrip, _topk_roundtrip,
                                       make_compressor)


def test_int8_zero_gradient_hits_scale_floor():
    """An all-zero tensor must round-trip to zeros (the 1e-12 scale floor
    prevents a 0/0), leaving a zero residual — not NaNs."""
    g = jnp.zeros((4, 8), jnp.float32)
    rt = _int8_roundtrip(g)
    assert np.array_equal(np.asarray(rt), np.zeros((4, 8)))
    compress = make_compressor("int8")
    sent, ef = compress({"w": g}, None)
    assert np.all(np.isfinite(np.asarray(sent["w"])))
    assert np.array_equal(np.asarray(sent["w"]), np.zeros((4, 8)))
    assert np.array_equal(np.asarray(ef["w"], dtype=np.float32),
                          np.zeros((4, 8)))


def test_topk_tiny_ratio_clamps_k_to_one():
    """ratio so small that ratio * n < 1 must still keep the single
    largest-magnitude entry, never an empty selection."""
    g = jnp.asarray(np.arange(1.0, 11.0, dtype=np.float32))
    kept = np.asarray(_topk_roundtrip(g, ratio=1e-6))
    assert np.count_nonzero(kept) == 1
    assert kept[-1] == 10.0                         # the largest survives
    compress = make_compressor("topk", ratio=1e-6)
    sent, ef = compress({"w": g}, None)
    assert np.count_nonzero(np.asarray(sent["w"])) == 1
    # everything dropped lands in the residual for the next step
    resid = np.asarray(ef["w"], dtype=np.float32)
    assert np.count_nonzero(resid) == 9


def test_compressor_residual_deterministic_across_identical_steps():
    """Two runs of the same (grads, ef) step must produce bit-identical
    sent gradients and residuals — the EF state is a pure function of its
    inputs, no hidden RNG."""
    rng = np.random.default_rng(11)
    grads = {"a": jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32)),
             "b": jnp.asarray(rng.normal(size=(32,)).astype(np.float32))}
    for kind in ("int8", "topk"):
        compress = make_compressor(kind, ratio=0.25)
        sent1, ef1 = compress(grads, None)
        sent2, ef2 = compress(grads, None)
        for k in grads:
            assert np.array_equal(np.asarray(sent1[k]), np.asarray(sent2[k]))
            assert np.array_equal(np.asarray(ef1[k], dtype=np.float32),
                                  np.asarray(ef2[k], dtype=np.float32))
        # and feeding the residual back is deterministic too
        sent3, ef3 = compress(grads, ef1)
        sent4, ef4 = compress(grads, ef2)
        for k in grads:
            assert np.array_equal(np.asarray(sent3[k]), np.asarray(sent4[k]))
            assert np.array_equal(np.asarray(ef3[k], dtype=np.float32),
                                  np.asarray(ef4[k], dtype=np.float32))
