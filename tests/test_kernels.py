"""Per-kernel allclose vs ref.py oracles + hypothesis shape/dtype sweeps.

Kernels run in interpret mode (CPU container; TPU is the target)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

pytestmark = pytest.mark.pallas      # interpret mode here, compiled on TPU


class TestKNNKernel:
    @given(n=st.integers(1, 700), d=st.integers(1, 40), seed=st.integers(0, 99))
    @settings(max_examples=20, deadline=None)
    def test_distances_match_ref(self, n, d, seed):
        rng = np.random.default_rng(seed)
        cases = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        q = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        k = min(5, n)
        dist, idx = ops.knn_topk(cases, q, k)
        dist_r, idx_r = ref.knn_topk_ref(cases, q, k)
        np.testing.assert_allclose(np.asarray(dist), np.asarray(dist_r),
                                   rtol=1e-5, atol=1e-5)
        # indices may tie-swap; distances must agree and indices be valid
        d2 = np.sum((np.asarray(cases) - np.asarray(q)) ** 2, axis=1)
        np.testing.assert_allclose(np.sort(d2)[:k], np.sort(np.asarray(dist) ** 2),
                                   rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(0)
        cases = jnp.asarray(rng.normal(size=(300, 11)), dtype)
        q = jnp.asarray(rng.normal(size=(11,)), dtype)
        dist, idx = ops.knn_topk(cases, q, 5)
        dist_r, _ = ref.knn_topk_ref(cases.astype(jnp.float32),
                                     q.astype(jnp.float32), 5)
        np.testing.assert_allclose(np.asarray(dist), np.asarray(dist_r),
                                   rtol=3e-2, atol=3e-2)


class TestScoreKernel:
    @given(j=st.integers(1, 600), t=st.integers(1, 300), seed=st.integers(0, 99))
    @settings(max_examples=20, deadline=None)
    def test_matches_ref(self, j, t, seed):
        rng = np.random.default_rng(seed)
        marg = jnp.asarray(rng.uniform(0, 1, j), jnp.float32)
        ci = jnp.asarray(rng.uniform(20, 600, t), jnp.float32)
        ts = jnp.asarray(rng.integers(0, t, j), jnp.int32)
        te = jnp.asarray(rng.integers(0, t + 5, j), jnp.int32)
        out = ops.score_matrix(marg, ci, ts, te)
        expect = ref.score_matrix_ref(marg, ci, ts, te)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-6, atol=1e-7)

    def test_window_mask_exact(self):
        out = ops.score_matrix(jnp.ones(1), jnp.ones(6),
                               jnp.asarray([2]), jnp.asarray([4]))
        np.testing.assert_array_equal(np.asarray(out)[0],
                                      [0, 0, 1, 1, 0, 0])


class TestFlashAttentionKernel:
    @given(
        sq=st.sampled_from([1, 17, 64, 130]),
        sk_extra=st.integers(0, 200),
        hq=st.sampled_from([2, 4, 8]),
        group=st.sampled_from([1, 2, 4]),
        d=st.sampled_from([32, 64, 128]),
        seed=st.integers(0, 50),
    )
    @settings(max_examples=15, deadline=None)
    def test_matches_ref(self, sq, sk_extra, hq, group, d, seed):
        if hq % group:
            group = 1
        hkv = hq // group
        sk = sq + sk_extra
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(2, sq, hq, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, sk, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, sk, hkv, d)), jnp.float32)
        off = sk - sq
        out = ops.flash_attention(q, k, v, causal_offset=off,
                                  block_q=64, block_k=64)
        expect = ref.flash_attention_ref(q, k, v, causal_offset=off)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.normal(size=(1, 64, 4, 64)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(1, 64, 2, 64)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(1, 64, 2, 64)), jnp.bfloat16)
        out = ops.flash_attention(q, k, v)
        expect = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32),
                                   rtol=5e-2, atol=5e-2)

    def test_block_shape_sweep(self):
        rng = np.random.default_rng(4)
        q = jnp.asarray(rng.normal(size=(1, 96, 4, 64)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 96, 4, 64)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 96, 4, 64)), jnp.float32)
        expect = ref.flash_attention_ref(q, k, v)
        for bq, bk in [(32, 32), (64, 128), (128, 32)]:
            out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
            np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                       rtol=2e-5, atol=2e-5)


class TestKernelIntegration:
    def test_kb_pallas_backend_matches_jax(self):
        from repro.core.knowledge import KnowledgeBase

        rng = np.random.default_rng(0)
        states = np.abs(rng.normal(size=(60, 11)))
        m_vals = rng.integers(0, 100, 60)
        rho_vals = rng.uniform(0, 1, 60)
        kbs = {}
        for backend in ("jax", "pallas"):
            kb = KnowledgeBase(backend=backend)
            kb.add_window(states, m_vals, rho_vals)
            kbs[backend] = kb.query(states[10] + 0.02, k=4)
        np.testing.assert_allclose(kbs["jax"][2], kbs["pallas"][2], rtol=1e-4)
        np.testing.assert_allclose(np.sort(kbs["jax"][0]),
                                   np.sort(kbs["pallas"][0]), rtol=1e-5)
