"""Serving-layer tests: prefill->cache->decode consistency + dry-run CLI."""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.launch.mesh import make_mesh
from repro.models import LogicalRules, init_params
from repro.serve import init_cache, make_prefill, make_serve_step

pytestmark = pytest.mark.slow        # model-substrate end-to-end paths


@pytest.fixture(scope="module")
def rules():
    mesh = make_mesh((1, 1), ("data", "model"))
    return LogicalRules(mesh)


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen3-moe-235b-a22b",
                                  "rwkv6-7b", "zamba2-7b"])
def test_prefill_then_decode_matches_pure_decode(arch, rules):
    cfg = reduced(ARCHS[arch])
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_params(cfg, jax.random.key(0))
    B, P, MAX = 2, 10, 16
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (B, P + 4)), jnp.int32)
    prefill = jax.jit(make_prefill(cfg, rules, MAX))
    step = jax.jit(make_serve_step(cfg, rules))

    logits, cache = prefill(params, toks[:, :P])
    for t in range(P, P + 4):
        logits, cache = step(params, cache, toks[:, t])

    cache_b = init_cache(cfg, B, MAX)
    for t in range(P + 4):
        logits_b, cache_b = step(params, cache_b, toks[:, t])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_b),
                               rtol=2e-3, atol=2e-3)


def test_prefill_reports_length(rules):
    cfg = reduced(ARCHS["llama3-8b"])
    params = init_params(cfg, jax.random.key(1))
    prefill = make_prefill(cfg, rules, 16)
    toks = jnp.zeros((2, 7), jnp.int32)
    logits, cache = prefill(params, toks)
    assert int(cache["length"]) == 7
    assert logits.shape == (2, cfg.vocab_size)


@pytest.mark.slow
def test_dryrun_cli_single_cell(tmp_path):
    """End-to-end: the dry-run CLI lowers+compiles one full-size cell on the
    512-placeholder-device production mesh in a subprocess (keeps this
    test process on 1 device)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "stablelm-1.6b", "--shape", "decode_32k", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "all dry-run cells passed" in out.stdout, out.stdout + out.stderr
    assert any(f.endswith(".json") for f in os.listdir(tmp_path))
