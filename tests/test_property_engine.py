"""Property-based engine invariants (hypothesis, see requirements-dev.txt).

Three invariant families over randomized worlds:

- vector-vs-scalar ``simulate()`` parity on random job sets / CI traces /
  fault seeds (single-region AND geo engines);
- accounting sanity: non-negative per-slot energy, run totals equal to the
  slot-log sums, violations consistent with deadlines;
- profile laws: ``amdahl_profile`` / ``roofline_profile`` marginals are
  monotone non-increasing with ``p(k_min) == 1``.

Each property is a plain ``_check_*`` helper driven twice: by a
hypothesis ``@given`` sweep, and by a small fixed-seed parametrize smoke
so the invariants are exercised even where hypothesis is absent
(tests/conftest.py shims ``@given`` into a skip in that case)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CarbonService, ClusterConfig, GeoCluster,
                        GeoFlexPolicy, GeoGreedyPolicy, GeoStaticPolicy,
                        MultiRegionCarbonService, NoisyForecast,
                        QuantileForecast, baselines, simulate)
from repro.core.carbon import REGIONS, synthesize_trace
from repro.core.profiles import (RooflineTerms, amdahl_profile,
                                 roofline_profile)
from repro.core.simulator import FaultModel
from repro.core.types import Job

POLICIES = {
    "carbon-agnostic": baselines.CarbonAgnosticPolicy,
    "gaia": lambda: baselines.GaiaPolicy(mean_length=3.0),
    "wait-awhile": baselines.WaitAwhilePolicy,
    "wait-awhile-robust": baselines.RobustWaitAwhilePolicy,
    "carbonscaler": lambda: baselines.CarbonScalerPolicy(mean_length=3.0),
    "vcc-scaling": lambda: baselines.VCCPolicy(scaling=True),
}
GEO_POLICIES = {"geo-static": GeoStaticPolicy, "geo-greedy": GeoGreedyPolicy,
                "geo-flex": GeoFlexPolicy}

#: forecast-model axis for the parity sweeps (None = perfect)
FORECASTS = {
    "perfect": lambda seed: None,
    "noisy": lambda seed: NoisyForecast(sigma=0.3, seed=seed),
    "quantile": lambda seed: QuantileForecast(sigma=0.3, seed=seed,
                                              members=5),
}


def _random_world(seed: int, forecast: str = "perfect"):
    """A seeded random (cluster, ci, jobs) world: mixed elasticities,
    heterogeneous power/comm, random arrivals in a 72-slot window."""
    rng = np.random.default_rng(seed)
    cluster = ClusterConfig.default(capacity=int(rng.integers(4, 12)))
    ci = CarbonService(trace=rng.uniform(30.0, 700.0, 24 * 40),
                       model=FORECASTS[forecast](seed % 1009))
    jobs = []
    for i in range(int(rng.integers(3, 22))):
        k_min = int(rng.integers(1, 3))
        k_max = k_min + int(rng.integers(0, 7))
        prof = amdahl_profile(k_min, k_max, float(rng.uniform(0.0, 0.95)))
        q = int(rng.integers(0, 3))
        jobs.append(Job(
            job_id=i, arrival=int(rng.integers(0, 72)),
            length=float(rng.uniform(0.5, 10.0)), queue=q,
            delay=cluster.queues[q].delay, profile=prof, k_min=k_min,
            power=float(rng.uniform(0.5, 1.5)),
            comm_size=float(rng.uniform(0.0, 40.0))))
    return cluster, ci, jobs


def _assert_identical(a, b, ctx):
    assert a.carbon_g == b.carbon_g, ctx
    assert a.energy_kwh == b.energy_kwh, ctx
    np.testing.assert_array_equal(a.completion, b.completion, err_msg=ctx)
    np.testing.assert_array_equal(a.violations, b.violations, err_msg=ctx)
    np.testing.assert_array_equal(a.wait_slots, b.wait_slots, err_msg=ctx)
    assert len(a.slots) == len(b.slots) \
        and all(x == y for x, y in zip(a.slots, b.slots)), ctx


def _check_parity(seed: int, policy_name: str, fault_seed: int | None,
                  forecast: str = "perfect"):
    cluster, ci, jobs = _random_world(seed, forecast)
    mk = POLICIES[policy_name]
    mk_faults = (lambda: None) if fault_seed is None else \
        (lambda: FaultModel(straggler_rate=0.15, failure_rate=0.05,
                            seed=fault_seed))
    rs = simulate(jobs, ci, cluster, mk(), horizon=96, engine="scalar",
                  faults=mk_faults())
    rv = simulate(jobs, ci, cluster, mk(), horizon=96, engine="vector",
                  faults=mk_faults())
    _assert_identical(rs, rv,
                      f"seed={seed} policy={policy_name} fc={forecast}")


def _check_geo_parity(seed: int, policy_name: str, fault_seed: int | None,
                      forecast: str = "perfect"):
    cluster, ci, jobs = _random_world(seed)
    rng = np.random.default_rng(seed + 1)
    regions = tuple(rng.choice(sorted(REGIONS), size=int(rng.integers(2, 4)),
                               replace=False))
    geo = GeoCluster.split(cluster.capacity + 2, regions)
    model = FORECASTS[forecast](seed % 1009)
    mci = MultiRegionCarbonService(
        regions, tuple(CarbonService(trace=synthesize_trace(r, 24 * 40,
                                                            seed=seed),
                                     model=model)
                       for r in regions))
    mk = GEO_POLICIES[policy_name]
    mk_faults = (lambda: None) if fault_seed is None else \
        (lambda: FaultModel(straggler_rate=0.1, failure_rate=0.05,
                            seed=fault_seed))
    rs = simulate(jobs, mci, geo, mk(), horizon=96, engine="scalar",
                  faults=mk_faults())
    rv = simulate(jobs, mci, geo, mk(), horizon=96, engine="vector",
                  faults=mk_faults())
    _assert_identical(rs, rv,
                      f"geo seed={seed} policy={policy_name} fc={forecast}")
    np.testing.assert_array_equal(rs.final_region, rv.final_region)
    assert rs.migrations == rv.migrations
    assert rs.migration_carbon_g == rv.migration_carbon_g


def _check_accounting(seed: int, policy_name: str):
    cluster, ci, jobs = _random_world(seed)
    r = simulate(jobs, ci, cluster, POLICIES[policy_name](), horizon=96)
    assert r.energy_kwh >= 0.0 and r.carbon_g >= 0.0
    assert all(s.energy_kwh >= 0.0 and s.carbon_g >= 0.0 for s in r.slots)
    # run totals are exactly the slot-log sums (same accumulation order)
    e = c = 0.0
    for s in r.slots:
        e += s.energy_kwh
        c += s.carbon_g
    assert e == r.energy_kwh and c == r.carbon_g
    # run-to-completion + deadline bookkeeping
    assert (r.completion >= 0).all()
    rows = sorted(jobs, key=lambda j: (j.arrival, j.job_id))
    deadlines = np.array([j.deadline for j in rows])
    np.testing.assert_array_equal(r.violations, r.completion > deadlines)
    assert (r.wait_slots >= 0).all()


def _check_amdahl(k_min: int, extra: int, sigma: float):
    prof = amdahl_profile(k_min, k_min + extra, sigma)
    assert len(prof) == extra + 1
    assert prof[0] == 1.0                      # p(k_min) == 1 (paper §3)
    assert (prof >= 0.0).all()
    assert (np.diff(prof) <= 1e-12).all()      # monotone non-increasing


def _check_roofline(flops: float, hbm: float, grad: float, k_max: int):
    terms = RooflineTerms(flops=flops, hbm_bytes=hbm, grad_bytes=grad)
    prof = roofline_profile(terms, k_min=1, k_max=k_max)
    assert prof[0] == 1.0
    assert (prof >= 0.0).all()
    assert (np.diff(prof) <= 1e-12).all()


# --- hypothesis sweeps -------------------------------------------------------


@given(seed=st.integers(0, 10**6), policy=st.sampled_from(sorted(POLICIES)),
       faulty=st.booleans(), forecast=st.sampled_from(sorted(FORECASTS)))
@settings(max_examples=20, deadline=None)
def test_engine_parity_random_worlds(seed, policy, faulty, forecast):
    _check_parity(seed, policy, fault_seed=seed % 97 if faulty else None,
                  forecast=forecast)


@given(seed=st.integers(0, 10**6),
       policy=st.sampled_from(sorted(GEO_POLICIES)), faulty=st.booleans(),
       forecast=st.sampled_from(sorted(FORECASTS)))
@settings(max_examples=15, deadline=None)
def test_geo_engine_parity_random_worlds(seed, policy, faulty, forecast):
    _check_geo_parity(seed, policy, fault_seed=seed % 89 if faulty else None,
                      forecast=forecast)


@given(seed=st.integers(0, 10**6), policy=st.sampled_from(sorted(POLICIES)))
@settings(max_examples=15, deadline=None)
def test_accounting_invariants_random_worlds(seed, policy):
    _check_accounting(seed, policy)


@given(k_min=st.integers(1, 4), extra=st.integers(0, 12),
       sigma=st.floats(min_value=0.0, max_value=0.95))
@settings(max_examples=50, deadline=None)
def test_amdahl_profile_laws(k_min, extra, sigma):
    _check_amdahl(k_min, extra, sigma)


@given(flops=st.floats(min_value=1e10, max_value=1e16),
       hbm=st.floats(min_value=1e8, max_value=1e14),
       grad=st.floats(min_value=1e5, max_value=1e12),
       k_max=st.integers(1, 16))
@settings(max_examples=50, deadline=None)
def test_roofline_profile_laws(flops, hbm, grad, k_max):
    _check_roofline(flops, hbm, grad, k_max)


# --- fixed-seed smoke twins (run even without hypothesis) --------------------


@pytest.mark.parametrize("seed", [0, 7, 1234])
@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_engine_parity_smoke(seed, policy):
    _check_parity(seed, policy, fault_seed=None)
    _check_parity(seed + 1, policy, fault_seed=seed + 2)


@pytest.mark.parametrize("seed", [0, 7])
@pytest.mark.parametrize("policy", sorted(POLICIES))
@pytest.mark.parametrize("forecast", ["noisy", "quantile"])
def test_engine_parity_forecast_smoke(seed, policy, forecast):
    _check_parity(seed, policy, fault_seed=None, forecast=forecast)
    _check_parity(seed + 1, policy, fault_seed=seed + 2, forecast=forecast)


@pytest.mark.parametrize("seed", [0, 7, 1234])
@pytest.mark.parametrize("policy", sorted(GEO_POLICIES))
def test_geo_engine_parity_smoke(seed, policy):
    _check_geo_parity(seed, policy, fault_seed=None)
    _check_geo_parity(seed + 1, policy, fault_seed=seed + 2)


@pytest.mark.parametrize("seed", [0, 7])
@pytest.mark.parametrize("policy", sorted(GEO_POLICIES))
@pytest.mark.parametrize("forecast", ["noisy", "quantile"])
def test_geo_engine_parity_forecast_smoke(seed, policy, forecast):
    _check_geo_parity(seed, policy, fault_seed=None, forecast=forecast)
    _check_geo_parity(seed + 1, policy, fault_seed=seed + 2,
                      forecast=forecast)


@pytest.mark.parametrize("seed", [3, 99])
@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_accounting_invariants_smoke(seed, policy):
    _check_accounting(seed, policy)


@pytest.mark.parametrize("k_min,extra,sigma", [
    (1, 0, 0.0), (1, 12, 0.5), (2, 7, 0.95), (4, 3, 0.3)])
def test_amdahl_profile_smoke(k_min, extra, sigma):
    _check_amdahl(k_min, extra, sigma)


@pytest.mark.parametrize("flops,hbm,grad,k_max", [
    (1e14, 1e11, 1e9, 16),    # compute-bound, cheap sync -> elastic
    (1e12, 1e12, 1e11, 8),    # collective-dominated -> inelastic
    (1e10, 1e8, 1e5, 1)])
def test_roofline_profile_smoke(flops, hbm, grad, k_max):
    _check_roofline(flops, hbm, grad, k_max)
