"""Knowledge-base caching/batched-query + batched KNN kernel tests."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.knowledge import KnowledgeBase
from repro.kernels import knn, ref


def _mk_kb(n=80, d=13, seed=0, **kw):
    rng = np.random.default_rng(seed)
    states = np.abs(rng.normal(size=(n, d)))
    kb = KnowledgeBase(**kw)
    kb.add_window(states, rng.integers(0, 100, n), rng.uniform(0, 1, n))
    return kb, states


class TestQueryCache:
    @pytest.mark.parametrize("backend", [
        "numpy", "jax", pytest.param("pallas", marks=pytest.mark.pallas)])
    def test_cached_matches_uncached(self, backend):
        kb_c, states = _mk_kb(backend=backend, cache=True)
        kb_u, _ = _mk_kb(backend=backend, cache=False)
        q = states[5] + 0.03
        for a, b in zip(kb_c.query(q, k=4), kb_u.query(q, k=4)):
            np.testing.assert_array_equal(a, b)

    def test_cache_invalidated_on_add_window(self):
        kb, states = _mk_kb(backend="numpy")
        kb.query(states[0], k=1)               # builds the cache
        rng = np.random.default_rng(99)
        new = np.abs(rng.normal(size=(40, states.shape[1]))) + 50.0
        kb.add_window(new, np.full(40, 777.0), np.ones(40))
        assert len(kb) == 120
        m, _, d = kb.query(new[3], k=1)
        assert m[0] == 777.0 and d[0] < 1e-6

    def test_device_cache_built_for_jax_backend(self):
        kb, states = _mk_kb(backend="jax")
        kb.query(states[0], k=2)
        assert kb._Xn is not None and kb._Xn_dev is not None
        np.testing.assert_allclose(np.asarray(kb._Xn_dev),
                                   kb._Xn.astype(np.float32), rtol=1e-6)


class TestQueryBatch:
    @pytest.mark.parametrize("backend", [
        "numpy", "jax", pytest.param("pallas", marks=pytest.mark.pallas)])
    def test_batch_rows_match_single_queries(self, backend):
        kb, states = _mk_kb(backend=backend)
        rng = np.random.default_rng(1)
        queries = states[:16] + rng.normal(scale=0.05, size=(16, states.shape[1]))
        m_b, rho_b, d_b = kb.query_batch(queries, k=4)
        assert m_b.shape == rho_b.shape == d_b.shape == (16, 4)
        for i, q in enumerate(queries):
            m_s, rho_s, d_s = kb.query(q, k=4)
            np.testing.assert_allclose(d_b[i], d_s, rtol=1e-4, atol=1e-4)
            # ties may reorder between the fused and dot-form distances;
            # compare the neighbour decision sets
            np.testing.assert_allclose(np.sort(m_b[i]), np.sort(m_s), rtol=1e-6)

    def test_single_state_is_promoted_to_batch(self):
        kb, states = _mk_kb(backend="numpy")
        m, rho, d = kb.query_batch(states[7], k=3)
        assert m.shape == (1, 3)
        assert d[0, 0] < 1e-6


@pytest.mark.pallas
class TestBatchedKernel:
    def test_batch_distances_match_reference(self):
        rng = np.random.default_rng(3)
        cases = jnp.asarray(rng.normal(size=(300, 17)), jnp.float32)
        queries = jnp.asarray(rng.normal(size=(33, 17)), jnp.float32)
        d2 = np.asarray(knn.squared_distances_batch(cases, queries))
        expect = np.sum((np.asarray(queries)[:, None, :]
                         - np.asarray(cases)[None, :, :]) ** 2, axis=2)
        np.testing.assert_allclose(d2, expect, rtol=1e-4, atol=1e-4)

    def test_batch_topk_matches_per_row_reference(self):
        rng = np.random.default_rng(4)
        cases = jnp.asarray(rng.normal(size=(150, 9)), jnp.float32)
        queries = jnp.asarray(rng.normal(size=(7, 9)), jnp.float32)
        dist, idx = knn.knn_topk_batch(cases, queries, 5)
        assert dist.shape == idx.shape == (7, 5)
        for i in range(7):
            d_r, _ = ref.knn_topk_ref(cases, queries[i], 5)
            np.testing.assert_allclose(np.asarray(dist)[i], np.asarray(d_r),
                                       rtol=1e-4, atol=1e-4)

    def test_padding_never_wins(self):
        # N and Q far from the block sizes: padded rows/cols must not
        # surface in the top-k
        rng = np.random.default_rng(5)
        cases = jnp.asarray(rng.normal(size=(5, 3)), jnp.float32)
        queries = jnp.asarray(rng.normal(size=(2, 3)), jnp.float32)
        dist, idx = knn.knn_topk_batch(cases, queries, 5)
        assert int(np.asarray(idx).max()) < 5
        assert np.isfinite(np.asarray(dist)).all()

    def test_interpret_auto_detect(self):
        import jax

        expected = jax.default_backend() != "tpu"
        assert knn.default_interpret() is expected
