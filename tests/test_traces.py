"""Workload trace generator properties."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.types import ClusterConfig
from repro.traces import TraceSpec, generate_trace


class TestTraces:
    def test_deterministic(self):
        spec = TraceSpec(hours=24 * 7, seed=3)
        a = generate_trace(spec)
        b = generate_trace(spec)
        assert len(a) == len(b)
        assert all(x.arrival == y.arrival and x.length == y.length
                   for x, y in zip(a, b))

    @given(family=st.sampled_from(["azure", "alibaba", "surf"]),
           util=st.floats(0.25, 0.9), seed=st.integers(0, 50))
    @settings(max_examples=15, deadline=None)
    def test_utilization_calibration(self, family, util, seed):
        cap = 100
        spec = TraceSpec(family=family, hours=24 * 28, capacity=cap,
                         utilization=util, seed=seed)
        jobs = generate_trace(spec)
        demand = sum(j.length * j.k_min for j in jobs)
        implied = demand / (24 * 28 * cap)
        assert abs(implied - util) / util < 0.35

    def test_queue_assignment_consistent(self):
        queues = ClusterConfig.default(50).queues
        for j in generate_trace(TraceSpec(hours=24 * 7, seed=1), queues):
            q = queues[j.queue]
            assert j.length <= q.max_length
            assert j.delay == q.delay
            if j.queue > 0:
                assert j.length > queues[j.queue - 1].max_length

    def test_profiles_monotone_decreasing(self):
        for j in generate_trace(TraceSpec(hours=24 * 3, seed=2))[:100]:
            assert (np.diff(j.profile) <= 1e-9).all()
            assert abs(j.profile[0] - 1.0) < 1e-9

    def test_hour_plus_jobs_only(self):
        jobs = generate_trace(TraceSpec(hours=24 * 7, seed=4))
        assert min(j.length for j in jobs) >= 1.0

    def test_shift_knobs(self):
        base = generate_trace(TraceSpec(hours=24 * 14, seed=5))
        longer = generate_trace(TraceSpec(hours=24 * 14, seed=5,
                                          length_scale=1.5))
        assert (np.mean([j.length for j in longer])
                > np.mean([j.length for j in base]))

    def test_gpu_mode_heterogeneous_power(self):
        jobs = generate_trace(TraceSpec(hours=24 * 7, seed=6, mode="gpu"))
        assert len({j.power for j in jobs}) > 1
