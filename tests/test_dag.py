"""Precedence-aware DAG subsystem (ISSUE-4): model, gating, parity, API.

Five families:

- the ``DagSpec``/``TaskNode`` model and shape builders (topological
  authoring, cycles unrepresentable, published pipeline shapes);
- expansion to engine jobs + the PCAPS criticality analysis;
- engine gating semantics: a task never starts before its predecessors
  complete (the engine invariant), gated tasks burn no waiting budget,
  slack/deadline count from release;
- vector-vs-scalar bit parity for all three DAG policies, with and
  without fault injection, on randomized DAG worlds (fixed-seed smokes +
  hypothesis sweeps, per tests/conftest.py);
- Scenario/Sweep/registry threading (dag axis, default baseline,
  round-trip, policy-family rejection both ways).
"""
import dataclasses
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CarbonService, ClusterConfig, DagCapPolicy,
                        DagCarbonPolicy, DagFcfsPolicy, DagSpec, GeoCluster,
                        MultiRegionCarbonService, TaskNode,
                        criticality_from_jobs, expand_dags, simulate)
from repro.core.dag import chain_tasks, layered_tasks, map_reduce_tasks
from repro.core.simulator import FaultModel, SimCase, simulate_many
from repro.core.types import Job
from repro.experiment import (DEFAULT_DAG_POLICIES, Scenario, Sweep,
                              make_policy, prepare_context, run)
from repro.traces import (DagConfig, TraceSpec, dag_mean_task_length,
                          generate_dag_specs, generate_dag_trace)

WEEK = 24 * 7

_MK = {"dag-fcfs": DagFcfsPolicy, "dag-carbon": DagCarbonPolicy,
       "dag-cap": DagCapPolicy}


def _queues():
    return ClusterConfig.default(8).queues


# --- model and builders ------------------------------------------------------


class TestDagModel:
    def test_chain_shape(self):
        tasks = chain_tasks([2.0, 3.0, 1.0])
        spec = DagSpec(dag_id=0, arrival=5, tasks=tasks)
        assert spec.edges() == [(0, 1), (1, 2)]
        assert spec.depth() == 3
        assert spec.total_work() == 6.0
        assert spec.critical_path_length() == 6.0

    def test_map_reduce_shape(self):
        tasks = map_reduce_tasks(1.0, [2.0, 4.0, 3.0], 1.5)
        spec = DagSpec(dag_id=0, arrival=0, tasks=tasks)
        assert spec.n_tasks == 5
        assert set(spec.edges()) == {(0, 1), (0, 2), (0, 3),
                                     (1, 4), (2, 4), (3, 4)}
        assert spec.depth() == 3
        # critical path goes through the slowest mapper
        assert spec.critical_path_length() == 1.0 + 4.0 + 1.5

    def test_layered_parents_come_from_previous_layer(self):
        rng = np.random.default_rng(3)
        tasks = layered_tasks([3, 4, 2], [1.0] * 9, rng)
        spec = DagSpec(dag_id=0, arrival=0, tasks=tasks)
        assert spec.depth() == 3
        layers = [list(range(0, 3)), list(range(3, 7)), list(range(7, 9))]
        for li, layer in enumerate(layers):
            for i in layer:
                deps = tasks[i].deps
                if li == 0:
                    assert deps == ()
                else:
                    assert deps and all(d in layers[li - 1] for d in deps)

    def test_forward_deps_rejected(self):
        with pytest.raises(ValueError, match="topological"):
            DagSpec(dag_id=0, arrival=0,
                    tasks=(TaskNode(1.0, deps=(1,)), TaskNode(1.0)))
        with pytest.raises(ValueError, match="topological"):
            DagSpec(dag_id=0, arrival=0, tasks=(TaskNode(1.0, deps=(0,)),))
        with pytest.raises(ValueError, match=">= 1 task"):
            DagSpec(dag_id=0, arrival=0, tasks=())

    def test_builder_validation(self):
        with pytest.raises(ValueError, match="mapper"):
            map_reduce_tasks(1.0, [], 1.0)
        with pytest.raises(ValueError, match="lengths"):
            layered_tasks([2, 2], [1.0] * 3, np.random.default_rng(0))
        with pytest.raises(ValueError, match=">= 1"):
            layered_tasks([2, 0], [1.0] * 2, np.random.default_rng(0))


class TestExpandAndCriticality:
    def test_expand_maps_deps_to_job_ids(self):
        specs = [DagSpec(dag_id=0, arrival=2, tasks=chain_tasks([2.0, 8.0])),
                 DagSpec(dag_id=1, arrival=4,
                         tasks=map_reduce_tasks(1.0, [2.0, 2.0], 1.0))]
        jobs = expand_dags(specs, _queues(), id_base=10)
        assert [j.job_id for j in jobs] == list(range(10, 16))
        assert jobs[1].deps == (10,)
        assert jobs[5].deps == (13, 14)           # reduce waits on both maps
        assert all(j.arrival == 2 for j in jobs[:2])
        assert all(j.arrival == 4 for j in jobs[2:])
        # queue assignment follows the existing per-length rule
        assert jobs[0].queue == 0 and jobs[1].queue == 1

    def test_expand_independent_strips_edges(self):
        specs = [DagSpec(dag_id=0, arrival=0, tasks=chain_tasks([1.0, 1.0]))]
        jobs = expand_dags(specs, _queues(), independent=True)
        assert all(j.deps == () for j in jobs)

    def test_chain_is_all_critical(self):
        jobs = expand_dags(
            [DagSpec(dag_id=0, arrival=0, tasks=chain_tasks([1.0, 2.0]))],
            _queues())
        assert all(criticality_from_jobs(jobs).values())

    def test_diamond_slack_branch_not_critical(self):
        tasks = map_reduce_tasks(1.0, [5.0, 1.0], 1.0)
        jobs = expand_dags([DagSpec(dag_id=0, arrival=0, tasks=tasks)],
                           _queues())
        crit = criticality_from_jobs(jobs)
        assert crit[jobs[0].job_id] and crit[jobs[1].job_id]   # source, slow map
        assert not crit[jobs[2].job_id]                        # fast map: slack
        assert crit[jobs[3].job_id]                            # reduce

    def test_isolated_tasks_are_critical(self):
        jobs = [Job(job_id=i, arrival=0, length=2.0, queue=0, delay=6,
                    profile=np.ones(1)) for i in range(3)]
        assert all(criticality_from_jobs(jobs).values())

    def test_cycle_detected(self):
        jobs = [Job(job_id=0, arrival=0, length=1.0, queue=0, delay=6,
                    profile=np.ones(1), deps=(1,)),
                Job(job_id=1, arrival=0, length=1.0, queue=0, delay=6,
                    profile=np.ones(1), deps=(0,))]
        with pytest.raises(ValueError, match="cycle"):
            criticality_from_jobs(jobs)


# --- engine gating semantics -------------------------------------------------


def _mk_job(jid, length, deps=(), arrival=0, delay=6):
    return Job(job_id=jid, arrival=arrival, length=length, queue=0,
               delay=delay, profile=np.ones(1), deps=deps)


@pytest.mark.parametrize("engine", ["scalar", "vector", "scan"])
class TestGatingSemantics:
    def test_chain_serialises(self, engine):
        cluster = ClusterConfig.default(8)
        ci = CarbonService(trace=np.full(24 * 10, 100.0))
        jobs = [_mk_job(0, 3.0), _mk_job(1, 2.0, deps=(0,)),
                _mk_job(2, 1.0, deps=(1,))]
        r = simulate(jobs, ci, cluster, DagFcfsPolicy(), horizon=48,
                     engine=engine)
        # parent completes at t=2; child released t=3, completes t=4; ...
        np.testing.assert_array_equal(r.completion, [2, 4, 5])
        np.testing.assert_array_equal(r.wait_slots, [0.0, 0.0, 0.0])
        assert not r.violations.any()

    def test_gated_tasks_burn_no_slack_and_deadline_counts_from_release(
            self, engine):
        cluster = ClusterConfig.default(8)
        ci = CarbonService(trace=np.full(24 * 10, 100.0))
        # parent runs 10 slots; child's static deadline (arrival 0 + 1 + 6)
        # would long be blown, but release-based accounting clears it
        jobs = [_mk_job(0, 10.0), _mk_job(1, 1.0, deps=(0,))]
        r = simulate(jobs, ci, cluster, DagFcfsPolicy(), horizon=48,
                     engine=engine)
        np.testing.assert_array_equal(r.completion, [9, 10])
        assert r.wait_slots[1] == 0.0            # never burned slack gated
        assert not r.violations[1]               # deadline from release slot
        assert r.completion[1] > jobs[1].deadline   # static one WAS blown

    def test_fan_in_waits_for_all_parents(self, engine):
        cluster = ClusterConfig.default(8)
        ci = CarbonService(trace=np.full(24 * 10, 100.0))
        jobs = [_mk_job(0, 2.0), _mk_job(1, 6.0),
                _mk_job(2, 1.0, deps=(0, 1))]
        r = simulate(jobs, ci, cluster, DagFcfsPolicy(), horizon=48,
                     engine=engine)
        assert r.completion[2] > r.completion[1] > r.completion[0]

    def test_missing_dep_rejected(self, engine):
        cluster = ClusterConfig.default(8)
        ci = CarbonService(trace=np.full(48, 100.0))
        jobs = [_mk_job(0, 1.0, deps=(99,))]
        with pytest.raises(ValueError, match="submitted"):
            simulate(jobs, ci, cluster, DagFcfsPolicy(), horizon=24,
                     engine=engine)

    def test_cycle_rejected(self, engine):
        cluster = ClusterConfig.default(8)
        ci = CarbonService(trace=np.full(48, 100.0))
        jobs = [_mk_job(0, 1.0, deps=(1,)), _mk_job(1, 1.0, deps=(0,))]
        with pytest.raises(ValueError, match="cycle"):
            simulate(jobs, ci, cluster, DagFcfsPolicy(), horizon=24,
                     engine=engine)

    def test_self_dep_rejected(self, engine):
        cluster = ClusterConfig.default(8)
        ci = CarbonService(trace=np.full(48, 100.0))
        with pytest.raises(ValueError, match="itself"):
            simulate([_mk_job(0, 1.0, deps=(0,))], ci, cluster,
                     DagFcfsPolicy(), horizon=24, engine=engine)


@dataclasses.dataclass
class _EvilPackedPolicy:
    """Allocates k_min to EVERY row — including gated ones — through both
    protocols; the engines must trim gated rows identically."""

    name: str = "evil"

    def on_window_start(self, ci, t0, horizon, jobs, cluster) -> None:
        self._jobs = jobs

    def decide(self, t, active, ci, cluster):
        return cluster.capacity, {j.job_id: j.k_min for j in self._jobs}

    def decide_packed(self, t, eng, ci, cluster):
        return cluster.capacity, eng.packed.k_min.copy()

    def on_completion(self, t, job, violated) -> None:
        pass


def test_gated_rows_never_run_even_if_policy_allocates_them():
    cluster = ClusterConfig.default(8)
    ci = CarbonService(trace=np.full(24 * 10, 100.0))
    jobs = [_mk_job(0, 3.0), _mk_job(1, 2.0, deps=(0,)),
            _mk_job(2, 1.0, deps=(1,))]
    rs = simulate(jobs, ci, cluster, _EvilPackedPolicy(), horizon=48,
                  engine="scalar")
    np.testing.assert_array_equal(rs.completion, [2, 4, 5])
    for engine in ("vector", "scan"):   # scan delegates unknown policies
        rv = simulate(jobs, ci, cluster, _EvilPackedPolicy(), horizon=48,
                      engine=engine)
        np.testing.assert_array_equal(rv.completion, [2, 4, 5])
        assert rs.carbon_g == rv.carbon_g, engine


def test_geo_engines_reject_dag_jobs():
    geo = GeoCluster.split(8, ("south-australia", "california"))
    mci = MultiRegionCarbonService.synthetic(
        ("south-australia", "california"), 24 * 10, seed=1)
    from repro.core import GeoStaticPolicy
    jobs = [_mk_job(0, 1.0), _mk_job(1, 1.0, deps=(0,))]
    for engine in ("scalar", "vector", "scan"):
        with pytest.raises(ValueError, match="geo"):
            simulate(jobs, mci, geo, GeoStaticPolicy(), horizon=24,
                     engine=engine)


# --- randomized parity + precedence invariant --------------------------------


def _random_dag_world(seed: int):
    rng = np.random.default_rng(seed)
    cluster = ClusterConfig.default(capacity=int(rng.integers(4, 12)))
    ci = CarbonService(trace=rng.uniform(30.0, 700.0, 24 * 60))
    dag = DagConfig(width=int(rng.integers(2, 5)),
                    depth=int(rng.integers(2, 5)))
    spec = TraceSpec(family="azure", hours=72, capacity=cluster.capacity,
                     utilization=0.4, seed=seed)
    jobs = generate_dag_trace(spec, dag, cluster.queues)
    return cluster, ci, jobs


def _assert_identical(a, b, ctx):
    assert a.carbon_g == b.carbon_g, ctx
    assert a.energy_kwh == b.energy_kwh, ctx
    np.testing.assert_array_equal(a.completion, b.completion, err_msg=ctx)
    np.testing.assert_array_equal(a.violations, b.violations, err_msg=ctx)
    np.testing.assert_array_equal(a.wait_slots, b.wait_slots, err_msg=ctx)
    assert len(a.slots) == len(b.slots) \
        and all(x == y for x, y in zip(a.slots, b.slots)), ctx


def _assert_precedence_invariant(result, jobs, ctx):
    """No task starts (hence completes) before all predecessors complete."""
    rows = sorted(jobs, key=lambda j: (j.arrival, j.job_id))
    comp = {j.job_id: int(result.completion[i]) for i, j in enumerate(rows)}
    for j in rows:
        if comp[j.job_id] < 0:
            continue
        for d in j.deps:
            assert 0 <= comp[d] < comp[j.job_id], \
                f"{ctx}: task {j.job_id} finished at {comp[j.job_id]} " \
                f"but predecessor {d} at {comp[d]}"


def _check_dag_parity(seed: int, policy_name: str, fault_seed: int | None):
    cluster, ci, jobs = _random_dag_world(seed)
    mk = _MK[policy_name]
    mk_faults = (lambda: None) if fault_seed is None else \
        (lambda: FaultModel(straggler_rate=0.15, failure_rate=0.05,
                            seed=fault_seed))
    rs = simulate(jobs, ci, cluster, mk(), horizon=96, engine="scalar",
                  faults=mk_faults())
    for engine in ("vector", "scan"):
        rv = simulate(jobs, ci, cluster, mk(), horizon=96, engine=engine,
                      faults=mk_faults())
        ctx = f"seed={seed} policy={policy_name} faults={fault_seed} " \
              f"engine={engine}"
        _assert_identical(rs, rv, ctx)
        _assert_precedence_invariant(rv, jobs, ctx)


@pytest.mark.parametrize("policy_name", sorted(_MK))
@pytest.mark.parametrize("seed", [0, 3, 11])
def test_dag_engines_identical_fixed(policy_name, seed):
    _check_dag_parity(seed, policy_name, None)


@pytest.mark.parametrize("policy_name", sorted(_MK))
@pytest.mark.parametrize("seed,fault_seed", [(1, 2), (7, 9)])
def test_dag_engines_identical_under_faults_fixed(policy_name, seed,
                                                  fault_seed):
    _check_dag_parity(seed, policy_name, fault_seed)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       policy_name=st.sampled_from(sorted(_MK)),
       fault_seed=st.one_of(st.none(), st.integers(0, 100)))
def test_dag_engines_identical_property(seed, policy_name, fault_seed):
    _check_dag_parity(seed, policy_name, fault_seed)


def test_simulate_many_dispatches_dag_cases():
    cluster, ci, jobs = _random_dag_world(5)
    cases = [SimCase(jobs=jobs, ci=ci, cluster=cluster, policy=_MK[n](),
                     horizon=96, label=n) for n in sorted(_MK)]
    for n, r in zip(sorted(_MK), simulate_many(cases)):
        solo = simulate(jobs, ci, cluster, _MK[n](), horizon=96)
        _assert_identical(solo, r, f"simulate_many/{n}")


# --- trace generator ---------------------------------------------------------


class TestDagTraceGenerator:
    def test_deterministic_per_seed(self):
        spec = TraceSpec(hours=48, capacity=10, seed=9)
        a = generate_dag_trace(spec, DagConfig(), _queues())
        b = generate_dag_trace(spec, DagConfig(), _queues())
        assert len(a) == len(b) > 0
        for x, y in zip(a, b):
            assert (x.job_id, x.arrival, x.length, x.deps) \
                == (y.job_id, y.arrival, y.length, y.deps)

    def test_shapes_and_whole_dag_arrivals(self):
        spec = TraceSpec(hours=24 * 5, capacity=20, seed=2)
        specs = generate_dag_specs(spec, DagConfig())
        shapes = {s.name.rstrip("0123456789") for s in specs}
        assert shapes == {"chain", "mapreduce", "layered"}
        assert all(2 <= s.depth() for s in specs if "chain" in s.name)
        jobs = expand_dags(specs, _queues())
        arr = {}
        for j in jobs:
            arr.setdefault(j.arch.split("/")[0], set()).add(j.arrival)
        assert all(len(v) == 1 for v in arr.values())   # DAGs arrive whole
        assert all(1.0 <= j.length <= 48.0 for j in jobs)

    def test_independent_twin_same_tasks_no_edges(self):
        spec = TraceSpec(hours=48, capacity=10, seed=4)
        gated = generate_dag_trace(spec, DagConfig(), _queues())
        indep = generate_dag_trace(spec, DagConfig(independent=True),
                                   _queues())
        assert len(gated) == len(indep)
        assert any(j.deps for j in gated)
        assert all(j.deps == () for j in indep)
        for g, i in zip(gated, indep):
            assert (g.length, g.arrival, g.k_min) \
                == (i.length, i.arrival, i.k_min)

    def test_config_validation(self):
        with pytest.raises(ValueError, match="shapes"):
            DagConfig(shapes=("chain", "ring"))
        with pytest.raises(ValueError, match="width"):
            DagConfig(width=1)
        assert dag_mean_task_length(DagConfig()) >= 1.0


# --- experiment API threading ------------------------------------------------


TINY_DAG = dict(dag=DagConfig(width=3, depth=3), capacity=10, learn_weeks=1,
                seed=3, family="alibaba")


class TestDagScenario:
    def test_materialize_builds_dag_world(self):
        mat = Scenario(**TINY_DAG).materialize()
        assert mat.scenario.is_dag
        assert any(j.deps for j in mat.eval_jobs)
        assert mat.mean_length == dag_mean_task_length(TINY_DAG["dag"])

    def test_dag_plus_regions_rejected(self):
        with pytest.raises(ValueError, match="single-region"):
            Scenario(dag=DagConfig(),
                     regions=("california", "ontario"))

    def test_round_trip(self):
        sc = Scenario(**TINY_DAG)
        rt = Scenario.from_dict(json.loads(json.dumps(sc.to_dict())))
        assert rt == sc
        assert rt.dag.width == 3 and rt.dag.shapes == sc.dag.shapes

    def test_policy_family_rejection_both_ways(self):
        with pytest.raises(ValueError, match="precedence-aware"):
            run(Scenario(capacity=8, learn_weeks=1), ["dag-cap"])
        with pytest.raises(ValueError, match="independent"):
            run(Scenario(**TINY_DAG), ["carbon-agnostic"])

    def test_driver_defaults_to_dag_set(self):
        res = run(Scenario(**TINY_DAG))
        assert res.policies == DEFAULT_DAG_POLICIES
        for n in DEFAULT_DAG_POLICIES:
            assert (res.weekly[n][0].completion >= 0).all(), n
        assert res.savings("dag-carbon") > 0          # defaults to dag-fcfs
        assert res.savings("dag-cap") > 0

    def test_context_builds_dag_policies(self):
        mat = Scenario(**TINY_DAG).materialize()
        ctx = prepare_context(mat, DEFAULT_DAG_POLICIES)
        assert make_policy("dag-cap", ctx).name == "dag-cap"


class TestDagSweep:
    def test_dag_sweep_defaults_baseline(self):
        sw = Sweep(base=Scenario(**TINY_DAG), seeds=[3, 4],
                   policies=["dag-carbon", "dag-cap"])
        sr = sw.run()
        assert sr.baseline == "dag-fcfs"
        rows = sr.rows()
        assert {r["policy"] for r in rows} == {"dag-fcfs", "dag-carbon",
                                               "dag-cap"}
        carbon = [r for r in rows if r["policy"] == "dag-carbon"]
        assert all(r["savings_pct"] > 0 for r in carbon)
        payload = sr.to_json()
        from repro.experiment import SweepResult
        assert SweepResult.from_json(payload).to_json() == payload
