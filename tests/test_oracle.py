"""Algorithm 1 (offline oracle) unit + property tests."""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import oracle
from repro.core.profiles import amdahl_profile
from repro.core.types import Job


def mk_job(jid, arrival, length, delay, k_max=3, sigma=0.5, k_min=1):
    return Job(job_id=jid, arrival=arrival, length=length, queue=0, delay=delay,
               profile=amdahl_profile(k_min, k_max, sigma), k_min=k_min)


def brute_force_min_carbon(jobs, ci, capacity, horizon):
    """Exhaustive minimum-carbon feasible schedule (tiny instances only)."""
    per_job_options = []
    for job in jobs:
        slots = [t for t in range(horizon) if job.arrival <= t <= job.deadline]
        choices = []
        for ks in itertools.product(range(job.k_max + 1), repeat=len(slots)):
            if any(0 < k < job.k_min for k in ks):
                continue
            work = sum(job.throughput(k) for k in ks)
            if work >= job.length - 1e-9:
                choices.append(dict(zip(slots, ks)))
        per_job_options.append(choices)
    best = np.inf
    for combo in itertools.product(*per_job_options):
        used = np.zeros(horizon)
        for alloc in combo:
            for t, k in alloc.items():
                used[t] += k
        if (used <= capacity).all():
            cost = float(np.sum(used * ci[:horizon]))
            best = min(best, cost)
    return best


class TestOracleOptimality:
    def test_matches_brute_force_small(self):
        ci = np.array([1.0, 5.0, 2.0, 10.0, 1.5])
        jobs = [mk_job(0, 0, 2.0, 2, k_max=2), mk_job(1, 1, 1.0, 2, k_max=2)]
        res = oracle.solve(jobs, ci, capacity=3, backend="numpy")
        assert res.schedule.feasible
        got = float(np.sum(res.capacity_curve * ci[: len(res.capacity_curve)]))
        best = brute_force_min_carbon(jobs, ci, 3, 5)
        assert got <= best + 1e-6

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_near_brute_force_random(self, seed):
        rng = np.random.default_rng(seed)
        horizon = 5
        ci = rng.uniform(1, 10, horizon)
        jobs = [
            mk_job(0, 0, float(rng.integers(1, 3)), 2, k_max=2, sigma=0.6),
            mk_job(1, int(rng.integers(0, 2)), 1.0, 2, k_max=2, sigma=0.6),
        ]
        res = oracle.solve(jobs, ci, capacity=2, backend="numpy")
        got = float(np.sum(res.capacity_curve * ci))
        best = brute_force_min_carbon(jobs, ci, 2, horizon)
        if np.isfinite(best):
            # greedy is provably optimal under Thm 4.1 conditions; integral
            # throughput rounding can cost at most one increment
            assert got <= best * 1.10 + 1e-6


class TestOracleInvariants:
    @given(
        n=st.integers(1, 6),
        cap=st.integers(1, 8),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=40, deadline=None)
    def test_capacity_and_window(self, n, cap, seed):
        rng = np.random.default_rng(seed)
        horizon = 24
        ci = rng.uniform(50, 500, horizon)
        jobs = [
            mk_job(i, int(rng.integers(0, 12)), float(rng.uniform(1, 4)),
                   int(rng.integers(0, 8)), k_max=int(rng.integers(1, 4)))
            for i in range(n)
        ]
        res = oracle.solve(jobs, ci, capacity=cap, backend="numpy")
        alloc = res.schedule.alloc
        assert (alloc.sum(axis=0) <= cap).all()
        for i, job in enumerate(res.schedule.jobs):
            nz = np.nonzero(alloc[i])[0]
            if len(nz):
                assert nz.min() >= job.arrival
                assert nz.max() <= job.deadline
                assert alloc[i].max() <= job.k_max
                assert alloc[i][nz].min() >= job.k_min

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_jax_matches_numpy(self, seed):
        rng = np.random.default_rng(seed)
        horizon = 16
        ci = rng.uniform(50, 500, horizon)
        jobs = [
            mk_job(i, int(rng.integers(0, 8)), float(rng.uniform(1, 3)),
                   int(rng.integers(0, 6)), k_max=3)
            for i in range(4)
        ]
        r_np = oracle.solve(jobs, ci, capacity=5, backend="numpy")
        r_jx = oracle.solve(jobs, ci, capacity=5, backend="jax")
        np.testing.assert_array_equal(r_np.schedule.alloc, r_jx.schedule.alloc)
        np.testing.assert_array_equal(r_np.capacity_curve, r_jx.capacity_curve)

    def test_infeasible_extends_deadlines(self):
        ci = np.ones(40)
        # 3 jobs of length 10 on capacity 1, delay 0 -> must extend
        jobs = [mk_job(i, 0, 10.0, 0, k_max=1) for i in range(3)]
        res = oracle.solve(jobs, ci, capacity=1, backend="numpy")
        assert res.schedule.feasible
        assert res.schedule.extended.sum() > 0

    def test_rho_curve_default_one(self):
        ci = np.ones(8)
        res = oracle.solve([], ci, capacity=4, backend="numpy")
        assert (res.rho_curve == 1.0).all()

    def test_prefers_low_carbon_slots(self):
        ci = np.array([10.0, 1.0, 10.0, 1.0, 10.0, 1.0])
        job = mk_job(0, 0, 2.0, 4, k_max=1)
        res = oracle.solve([job], ci, capacity=1, backend="numpy")
        alloc = res.schedule.alloc[0]
        assert alloc[1] == 1 and alloc[3] == 1
        assert alloc[[0, 2, 4]].sum() == 0


class TestVectorizedEntries:
    """The meshgrid entry builder and the fast greedy pass must reproduce
    the original loop-based implementations exactly."""

    def _build_entries_loop(self, jobs, ci, horizon):
        """The pre-vectorisation builder, inlined as the parity oracle."""
        js, ts, ks, gains, scores, deadlines = [], [], [], [], [], []
        for idx, job in enumerate(jobs):
            t0 = max(0, job.arrival)
            t1 = min(horizon, job.deadline + 1)
            if t1 <= t0:
                continue
            trange = np.arange(t0, t1, dtype=np.int64)
            civ = ci[trange]
            for k in range(job.k_min, job.k_max + 1):
                p = job.marginal(k)
                if p <= 0:
                    continue
                js.append(np.full(len(trange), idx, dtype=np.int64))
                ts.append(trange)
                ks.append(np.full(len(trange), k, dtype=np.int64))
                gains.append(np.full(len(trange), p))
                scores.append(p / civ)
                deadlines.append(np.full(len(trange), job.deadline, dtype=np.int64))
        if not js:
            z = np.zeros(0, dtype=np.int64)
            return z, z, z, np.zeros(0), np.zeros(0)
        order = np.lexsort((np.concatenate(deadlines), -np.concatenate(scores)))
        return tuple(np.concatenate(a)[order]
                     for a in (js, ts, ks, gains, scores))

    @pytest.mark.parametrize("seed", [0, 3, 11])
    def test_meshgrid_builder_matches_loop_builder(self, seed):
        rng = np.random.default_rng(seed)
        horizon = 48
        ci = rng.uniform(30, 600, horizon)
        jobs = [
            mk_job(i, int(rng.integers(0, 40)), float(rng.uniform(0.5, 6)),
                   int(rng.integers(0, 24)), k_max=int(rng.integers(1, 6)),
                   sigma=float(rng.uniform(0.1, 1.0)))
            for i in range(25)
        ]
        got = oracle._build_entries(jobs, ci, horizon)
        want = self._build_entries_loop(jobs, ci, horizon)
        for g, w, name in zip(got, want, ("j", "t", "k", "gain", "score")):
            np.testing.assert_array_equal(g, w, err_msg=name)

    def test_builder_empty_cases(self):
        ci = np.ones(8)
        assert len(oracle._build_entries([], ci, 8)[0]) == 0
        late = [mk_job(0, 20, 1.0, 0)]        # arrives past the horizon
        assert len(oracle._build_entries(late, ci, 8)[0]) == 0

    @pytest.mark.parametrize("seed", [1, 7])
    def test_fast_greedy_matches_reference_backend(self, seed):
        rng = np.random.default_rng(seed)
        horizon = 72
        ci = rng.uniform(30, 600, horizon)
        jobs = [
            mk_job(i, int(rng.integers(0, 48)), float(rng.uniform(0.5, 8)),
                   int(rng.integers(0, 24)), k_max=int(rng.integers(1, 6)),
                   sigma=float(rng.uniform(0.1, 1.0)))
            for i in range(40)
        ]
        r_new = oracle.solve(jobs, ci, capacity=8, backend="numpy")
        r_ref = oracle.solve(jobs, ci, capacity=8, backend="numpy-ref")
        np.testing.assert_array_equal(r_new.schedule.alloc, r_ref.schedule.alloc)
        np.testing.assert_array_equal(r_new.capacity_curve, r_ref.capacity_curve)
        np.testing.assert_array_equal(r_new.rho_curve, r_ref.rho_curve)
        np.testing.assert_array_equal(r_new.work_done, r_ref.work_done)

    def test_rho_curve_lut_matches_per_slot_min(self):
        rng = np.random.default_rng(2)
        jobs = [mk_job(i, 0, 2.0, 4, k_max=4, sigma=0.5) for i in range(6)]
        alloc = rng.integers(0, 5, size=(6, 10))
        rho = oracle._rho_curve(jobs, alloc)
        for t in range(10):
            ks = alloc[:, t]
            marg = [jobs[j].marginal(int(ks[j])) for j in np.nonzero(ks)[0]]
            assert rho[t] == (min(marg) if marg else 1.0)
