"""Resilience layer (ISSUE-6): structured fault processes, feed outages.

Families:

- cross-engine bit parity for every ``FaultProcess`` kind, crossed with
  plain / geo / DAG scenarios and with carbon-feed outage injection;
- process semantics: correlated outages shrink capacity and evict (never
  below zero), preemption rolls back to the last checkpoint and bills the
  restore transfer, iid stays bit-for-bit the historical ``FaultModel``;
- satellite 1: a fault instance reused across ``simulate`` calls re-seeds
  per run, so repeated runs are reproducible;
- ``CarbonDataOutage`` / ``DegradedCIView``: staleness, forward-fill,
  staged forecast fallback, retry/backoff accessor;
- serialization: ``Scenario.to_json``/``from_json`` round-trips every
  fault kind + the outage config, legacy payloads resolve to iid, unknown
  kinds raise naming the registry;
- Sweep integration: fault axis labels + a slow-marked chaos grid.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import (CarbonService, ClusterConfig, GeoCluster,
                        MultiRegionCarbonService, baselines, simulate)
from repro.core.dag import DagCarbonPolicy, DagFcfsPolicy
from repro.core.faults import (CarbonDataOutage, CorrelatedFaults,
                               DegradedCIView, FaultModel, IidFaults,
                               PreemptionFaults, ensure_fault_process,
                               fault_from_dict, fault_label, fault_to_dict,
                               outage_from_dict, outage_to_dict)
from repro.core.forecast import PersistenceForecast
from repro.core.geo import GeoFlexPolicy, GeoStaticPolicy
from repro.core.types import Job, ResilienceMetrics
from repro.experiment import Scenario, Sweep
from repro.traces import DagConfig, TraceSpec, generate_dag_trace, generate_trace

WEEK = 24 * 7
CAP = 12
REGIONS2 = ("south-australia", "ontario")


def _fault_grid():
    return {
        "iid": lambda s: IidFaults(straggler_rate=0.15, failure_rate=0.05,
                                   seed=s),
        "correlated": lambda s: CorrelatedFaults(n_domains=4, rate=0.06,
                                                 mean_duration=5.0, seed=s),
        "preemption": lambda s: PreemptionFaults(rate=0.06, checkpoint_every=3,
                                                 restore_slots=1, seed=s),
    }


FAULT_KINDS = sorted(_fault_grid())


@pytest.fixture(scope="module")
def world():
    cluster = ClusterConfig.default(capacity=CAP)
    ci = CarbonService.synthetic("south-australia", WEEK * 2 + 24 * 30, seed=31)
    jobs = generate_trace(
        TraceSpec(family="azure", hours=WEEK, capacity=CAP, seed=32),
        cluster.queues)
    return cluster, ci, jobs


@pytest.fixture(scope="module")
def geo_world():
    geo = GeoCluster.split(CAP, REGIONS2)
    mci = MultiRegionCarbonService.synthetic(REGIONS2, WEEK * 2 + 24 * 30,
                                             seed=31)
    jobs = generate_trace(
        TraceSpec(family="azure", hours=WEEK, capacity=CAP, seed=32),
        geo.queues)
    return geo, mci, jobs


@pytest.fixture(scope="module")
def dag_world():
    cluster = ClusterConfig.default(capacity=CAP)
    ci = CarbonService.synthetic("california", WEEK * 2 + 24 * 30, seed=31)
    jobs = generate_dag_trace(
        TraceSpec(family="azure", hours=WEEK, capacity=CAP, seed=33),
        DagConfig(), cluster.queues)
    return cluster, ci, jobs


def assert_identical(a, b, ctx=""):
    assert a.carbon_g == b.carbon_g, ctx
    assert a.energy_kwh == b.energy_kwh, ctx
    np.testing.assert_array_equal(a.completion, b.completion, err_msg=ctx)
    np.testing.assert_array_equal(a.violations, b.violations, err_msg=ctx)
    np.testing.assert_array_equal(a.wait_slots, b.wait_slots, err_msg=ctx)
    assert len(a.slots) == len(b.slots), ctx
    for la, lb in zip(a.slots, b.slots):
        assert la == lb, f"{ctx}: slot {la.slot}"
    assert a.resilience == b.resilience, ctx


# --- cross-engine parity per fault process -----------------------------------


@pytest.mark.parametrize("fault_kind", FAULT_KINDS)
@pytest.mark.parametrize("seed", [2, 9])
def test_parity_plain(world, fault_kind, seed):
    cluster, ci, jobs = world
    mk = _fault_grid()[fault_kind]
    for policy in (baselines.CarbonAgnosticPolicy,
                   baselines.WaitAwhilePolicy):
        rs = simulate(jobs, ci, cluster, policy(), horizon=WEEK,
                      engine="scalar", faults=mk(seed))
        for engine in ("vector", "scan"):   # scan delegates faulted cases
            rv = simulate(jobs, ci, cluster, policy(), horizon=WEEK,
                          engine=engine, faults=mk(seed))
            assert_identical(
                rs, rv, f"{fault_kind}/s{seed}/{policy.__name__}/{engine}")
            assert rv.resilience is not None
            assert rv.resilience.lost_work_slots >= 0.0


@pytest.mark.parametrize("fault_kind", FAULT_KINDS)
@pytest.mark.parametrize("policy_cls", [GeoStaticPolicy, GeoFlexPolicy])
def test_parity_geo(geo_world, fault_kind, policy_cls):
    geo, mci, jobs = geo_world
    mk = _fault_grid()[fault_kind]
    rs = simulate(jobs, mci, geo, policy_cls(), horizon=WEEK,
                  engine="scalar", faults=mk(5))
    for engine in ("vector", "scan"):
        rv = simulate(jobs, mci, geo, policy_cls(), horizon=WEEK,
                      engine=engine, faults=mk(5))
        assert_identical(rs, rv,
                         f"geo/{fault_kind}/{policy_cls.__name__}/{engine}")
        np.testing.assert_array_equal(rs.final_region, rv.final_region)
        np.testing.assert_array_equal(rs.region_carbon_g, rv.region_carbon_g)


@pytest.mark.parametrize("fault_kind", FAULT_KINDS)
@pytest.mark.parametrize("policy_cls", [DagFcfsPolicy, DagCarbonPolicy])
def test_parity_dag(dag_world, fault_kind, policy_cls):
    cluster, ci, jobs = dag_world
    mk = _fault_grid()[fault_kind]
    rs = simulate(jobs, ci, cluster, policy_cls(), horizon=WEEK,
                  engine="scalar", faults=mk(5))
    for engine in ("vector", "scan"):
        rv = simulate(jobs, ci, cluster, policy_cls(), horizon=WEEK,
                      engine=engine, faults=mk(5))
        assert_identical(rs, rv,
                         f"dag/{fault_kind}/{policy_cls.__name__}/{engine}")


# --- invariants --------------------------------------------------------------


def test_correlated_outage_invariants(world):
    """Capacity never negative, evicted jobs still run to completion, lost
    work and eviction counters are consistent."""
    cluster, ci, jobs = world
    fm = CorrelatedFaults(n_domains=5, rate=0.15, mean_duration=5.0, seed=4)
    res = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                   horizon=WEEK, faults=fm)
    assert all(sl.provisioned >= 0 for sl in res.slots)
    assert all(sl.used <= max(sl.provisioned, 0) for sl in res.slots)
    assert (res.completion >= 0).all()      # evictions delay, never strand
    r = res.resilience
    assert r.capacity_outages >= 1
    assert r.evictions >= 1
    assert r.lost_work_slots >= 0.0
    assert r.mttr_slots >= 0.0


def test_total_blackout_hits_max_overrun(world):
    """A permanent full-cluster outage stops all progress: the engine still
    terminates (max_overrun) and unfinished jobs stay at completion=-1."""
    cluster, ci, jobs = world
    sub = [j for j in jobs if j.arrival < 12][:6]
    fm = CorrelatedFaults(n_domains=1, rate=1.0, mean_duration=1e9, seed=0)
    res = simulate(sub, ci, cluster, baselines.CarbonAgnosticPolicy(),
                   horizon=24, max_overrun=48, faults=fm)
    assert (res.completion == -1).all()
    # once the outage is revealed the scheduler sees zero capacity
    assert all(sl.provisioned == 0 for sl in res.slots[1:])
    assert all(sl.provisioned >= 0 for sl in res.slots)


def test_available_capacity_never_negative():
    fm = CorrelatedFaults(n_domains=3, rate=0.9, mean_duration=50.0, seed=1)
    caps = np.array([4, 3, 3], dtype=np.int64)
    fm.on_run_start(0, caps)
    lo = 10
    for t in range(60):
        fm.begin_slot(t)
        cap = fm.available_capacity(10)
        assert cap >= 0
        lo = min(lo, cap)
        vec = fm.available_capacity_vec(caps)
        assert (vec >= 0).all()
        assert vec.sum() <= caps.sum()
    # with that failure rate the whole cluster goes dark at some point
    assert lo == 0


# --- preemption semantics ----------------------------------------------------


def _job(jid=0, length=10.0, comm=2.0):
    return Job(job_id=jid, arrival=0, length=length, queue=0, delay=6,
               profile=np.ones(2), comm_size=comm)


def test_preemption_rollback_to_checkpoint():
    fm = PreemptionFaults(rate=0.0, checkpoint_every=2,
                          checkpoint_overhead=0.25, restore_slots=1,
                          energy_kwh_per_gb=0.05, min_gb=1.0, seed=0)
    fm.on_run_start(0, 8)
    job = _job(length=10.0, comm=2.0)
    k = np.array([2])
    rem = 10.0
    thr = np.array([1.0])
    d1 = fm.apply(0, [job], k, np.array([rem]), thr)        # run slot
    assert d1.factors[0] == 1.0 and d1.lost is None
    rem -= thr[0] * d1.factors[0]
    d2 = fm.apply(1, [job], k, np.array([rem]), thr)        # checkpoint slot
    assert d2.factors[0] == 0.75
    rem -= thr[0] * d2.factors[0]                            # rem = 8.25
    d3 = fm.apply(2, [job], k, np.array([rem]), thr)        # run slot
    rem -= thr[0] * d3.factors[0]                            # rem = 7.25
    fm.rate = 1.0                                            # force a kill
    d4 = fm.apply(3, [job], k, np.array([rem]), thr)
    assert d4.factors[0] == 0.0
    assert d4.lost[0] == pytest.approx(1.0)                  # back to ckpt
    assert d4.extra_energy[0] == 0.05 * 2.0                  # restore GBs
    fm.rate = 0.0
    d5 = fm.apply(4, [job], k, np.array([rem + d4.lost[0]]), thr)
    assert d5.factors[0] == 0.0                              # restoring
    m = fm.run_metrics()
    assert m.preemptions == 1
    assert m.restore_energy_kwh == pytest.approx(0.1)
    assert m.lost_work_slots == pytest.approx(2.0)           # rollback + slot


def test_preemption_engine_run_costs_energy(world):
    cluster, ci, jobs = world
    clean = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                     horizon=WEEK)
    faulty = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                      horizon=WEEK,
                      faults=PreemptionFaults(rate=0.08, seed=2))
    r = faulty.resilience
    assert r.preemptions > 0
    assert r.lost_work_slots > 0.0
    assert r.restore_energy_kwh > 0.0
    assert faulty.energy_kwh > clean.energy_kwh
    assert clean.resilience is None


# --- satellite 1: per-run RNG reset ------------------------------------------


@pytest.mark.parametrize("fault_kind", FAULT_KINDS)
def test_fault_instance_reusable_across_runs(world, fault_kind):
    """One fault instance across two simulate() calls must give identical
    results — on_run_start re-seeds the stream per run."""
    cluster, ci, jobs = world
    fm = _fault_grid()[fault_kind](7)
    r1 = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                  horizon=WEEK, faults=fm)
    r2 = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                  horizon=WEEK, faults=fm)
    assert_identical(r1, r2, f"reuse/{fault_kind}")


def test_legacy_draw_factors_adapter(world):
    cluster, ci, jobs = world

    class HalfSpeed:
        def draw_factors(self, n):
            return np.full(n, 0.5)

    res = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                   horizon=WEEK, faults=HalfSpeed())
    clean = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                     horizon=WEEK)
    assert res.carbon_g > clean.carbon_g          # everything runs at half speed
    assert res.resilience == ResilienceMetrics()  # adapter tracks nothing

    with pytest.raises(TypeError, match="draw_factors"):
        ensure_fault_process(object())
    assert ensure_fault_process(None) is None
    fm = IidFaults(seed=1)
    assert ensure_fault_process(fm) is fm


def test_fault_model_alias_is_iid():
    assert FaultModel is IidFaults
    fm = FaultModel(straggler_rate=0.1, failure_rate=0.05, seed=3)
    assert fm.kind == "iid"
    assert dataclasses.replace(fm) == fm


# --- carbon-feed outages -----------------------------------------------------


def _outage_service(**kw):
    outage = CarbonDataOutage(**{"windows": ((10, 15),), "stale_after": 2,
                                 **kw})
    return CarbonService.synthetic("ontario", 400, seed=1, outage=outage)


class TestDegradedView:
    def test_staleness_and_ffill(self):
        svc = _outage_service()
        view = svc.degraded()
        assert isinstance(view, DegradedCIView)
        assert svc.degraded() is view                 # cached
        assert view.staleness(9) == 0
        assert view.staleness(10) == 1
        assert view.staleness(14) == 5
        assert view.staleness(15) == 0
        assert view.ci(12) == svc.ci(9)               # last known good
        assert view.ci(15) == svc.ci(15)
        np.testing.assert_array_equal(view.trace[10:15],
                                      np.full(5, svc.trace[9]))

    def test_forecast_degrades_in_stages(self):
        svc = _outage_service()
        view = svc.degraded()
        # fresh: the true model forecast
        np.testing.assert_array_equal(view.forecast(9, 6), svc.forecast(9, 6))
        # stale within threshold: the forecast issued at the last fresh
        # slot, shifted onto the queried horizon
        np.testing.assert_array_equal(view.forecast(11, 6),
                                      svc.forecast(9, 8)[2:])
        # stale past threshold: last-known-good + persistence
        exp = PersistenceForecast().predict(view.trace, 13, 6)
        np.testing.assert_array_equal(view.forecast(13, 6), exp)
        np.testing.assert_array_equal(view.forecast_quantile(13, 6, q=0.9),
                                      exp)

    def test_fetch_backoff_schedule(self):
        svc = _outage_service(backoff_base=1, backoff_cap=16)
        view = svc.degraded()
        fresh = view.fetch(9)
        assert fresh.fresh and fresh.attempts == 0 and fresh.next_retry_in == 0
        s1 = view.fetch(10)                           # staleness 1
        assert not s1.fresh
        assert (s1.staleness, s1.attempts, s1.next_retry_in) == (1, 1, 2)
        s4 = view.fetch(13)                           # staleness 4
        assert (s4.attempts, s4.next_retry_in) == (2, 3)
        out = svc.outage
        assert [out.retry_delay(a) for a in range(6)] == [1, 2, 4, 8, 16, 16]

    def test_markov_mask_seeded_and_slot0_fresh(self):
        out = CarbonDataOutage(rate=0.2, mean_duration=4.0, seed=5)
        tr_a = np.linspace(100, 200, 300)
        tr_b = np.linspace(300, 400, 300)
        m1, m2 = out.stale_mask(300, tr_a), out.stale_mask(300, tr_a)
        np.testing.assert_array_equal(m1, m2)         # deterministic
        assert not m1[0]
        assert m1.any()
        # per-trace salt: aligned regions see independent outages
        assert (m1 != out.stale_mask(300, tr_b)).any()

    def test_no_outage_degraded_is_self(self):
        svc = CarbonService.synthetic("ontario", 100, seed=1)
        assert svc.degraded() is svc

    def test_outage_validation(self):
        with pytest.raises(ValueError, match="empty outage window"):
            CarbonDataOutage(windows=((5, 5),))
        with pytest.raises(ValueError, match="rate"):
            CarbonDataOutage(rate=1.5)


@pytest.mark.parametrize("policy_cls", [baselines.WaitAwhilePolicy,
                                        baselines.CarbonScalerPolicy])
def test_degraded_run_parity_and_metrics(world, policy_cls):
    """Engines stay bit-identical when the policies read a degraded feed,
    accounting stays on the true trace, and degraded time is recorded."""
    cluster, _, jobs = world
    kw = {"mean_length": 2.5} if policy_cls is baselines.CarbonScalerPolicy \
        else {}
    ci = CarbonService.synthetic(
        "south-australia", WEEK * 2 + 24 * 30, seed=31,
        outage=CarbonDataOutage(rate=0.08, mean_duration=6.0, seed=2))
    rs = simulate(jobs, ci, cluster, policy_cls(**kw), horizon=WEEK,
                  engine="scalar")
    rv = simulate(jobs, ci, cluster, policy_cls(**kw), horizon=WEEK,
                  engine="vector")
    assert_identical(rs, rv, f"degraded/{policy_cls.__name__}")
    assert rv.resilience.degraded_slots > 0


def test_degraded_geo_run(geo_world):
    geo, _, jobs = geo_world
    mci = MultiRegionCarbonService.synthetic(
        REGIONS2, WEEK * 2 + 24 * 30, seed=31,
        outage=CarbonDataOutage(rate=0.08, mean_duration=6.0, seed=2))
    rs = simulate(jobs, mci, geo, GeoFlexPolicy(), horizon=WEEK,
                  engine="scalar")
    rv = simulate(jobs, mci, geo, GeoFlexPolicy(), horizon=WEEK,
                  engine="vector")
    assert_identical(rs, rv, "degraded/geo")
    assert rv.resilience.degraded_slots > 0


def test_degraded_plus_faults_compose(world):
    cluster, _, jobs = world
    ci = CarbonService.synthetic(
        "south-australia", WEEK * 2 + 24 * 30, seed=31,
        outage=CarbonDataOutage(rate=0.08, mean_duration=6.0, seed=2))
    fm = CorrelatedFaults(rate=0.06, seed=3)
    rs = simulate(jobs, ci, cluster, baselines.WaitAwhilePolicy(),
                  horizon=WEEK, engine="scalar", faults=fm)
    rv = simulate(jobs, ci, cluster, baselines.WaitAwhilePolicy(),
                  horizon=WEEK, engine="vector", faults=fm)
    assert_identical(rs, rv, "degraded+correlated")
    assert rv.resilience.degraded_slots > 0
    assert rv.resilience.capacity_outages > 0


# --- serialization -----------------------------------------------------------


class TestSerialization:
    @pytest.mark.parametrize("fm", [
        None,
        IidFaults(straggler_rate=0.1, failure_rate=0.02, seed=3),
        CorrelatedFaults(n_domains=6, rate=0.04, mean_duration=7.0, seed=4),
        PreemptionFaults(rate=0.03, checkpoint_every=6, restore_slots=2,
                         seed=5),
    ], ids=["none", "iid", "correlated", "preemption"])
    def test_scenario_json_round_trip(self, fm):
        sc = Scenario(faults=fm,
                      ci_outage=CarbonDataOutage(rate=0.05, seed=9,
                                                 stale_after=4))
        back = Scenario.from_json(sc.to_json())
        assert back == sc
        assert back.faults == fm
        assert back.ci_outage == sc.ci_outage

    def test_windows_round_trip_through_json_lists(self):
        out = CarbonDataOutage(windows=((3, 7), (20, 24)))
        back = outage_from_dict(json.loads(json.dumps(outage_to_dict(out))))
        assert back == out
        assert back.windows == ((3, 7), (20, 24))
        assert outage_to_dict(None) is None
        assert outage_from_dict(None) is None

    def test_legacy_fault_payload_resolves_to_iid(self):
        legacy = {"straggler_rate": 0.2, "straggler_slowdown": 0.5,
                  "failure_rate": 0.1, "seed": 4}
        fm = fault_from_dict(legacy)
        assert fm == IidFaults(straggler_rate=0.2, failure_rate=0.1, seed=4)
        sc = Scenario.from_dict({"faults": dict(legacy)})
        assert sc.faults == fm

    def test_unknown_fault_kind_names_registry(self):
        with pytest.raises(ValueError) as e:
            fault_from_dict({"kind": "cosmic-rays"})
        msg = str(e.value)
        for kind in ("correlated", "iid", "preemption"):
            assert kind in msg
        with pytest.raises(ValueError, match="cosmic-rays"):
            Scenario.from_json(json.dumps({"faults": {"kind": "cosmic-rays"}}))
        with pytest.raises(ValueError, match="unknown carbon-outage kind"):
            outage_from_dict({"kind": "bogus"})

    def test_fault_to_dict_rejects_foreign_objects(self):
        with pytest.raises(ValueError, match="unregistered fault kind"):
            fault_to_dict(object())
        assert fault_to_dict(None) is None

    def test_fault_labels(self):
        assert fault_label(None) == "none"
        assert fault_label(IidFaults(straggler_rate=0.1, failure_rate=0.05)) \
            == "straggler=0.1,failure=0.05"
        assert fault_label(CorrelatedFaults(n_domains=4, rate=0.05,
                                            mean_duration=8.0)) \
            == "outage(d=4,p=0.05,len=8)"
        assert fault_label(PreemptionFaults(rate=0.05, checkpoint_every=4)) \
            == "preempt(p=0.05,ckpt=4)"

    def test_sweep_fault_label_reexport(self):
        from repro.experiment.sweep import fault_label as sweep_label
        assert sweep_label is fault_label


# --- sweep integration -------------------------------------------------------


def test_sweep_fault_axis_mixes_kinds():
    sweep = Sweep(
        base=Scenario(capacity=16, learn_weeks=1, eval_weeks=1, seed=11,
                      region="ontario"),
        policies=("carbon-agnostic", "wait-awhile"),
        faults=[None, CorrelatedFaults(rate=0.06, seed=2)])
    rows = sweep.run().rows()
    assert len(rows) == 4
    labels = {r["fault"] for r in rows}
    assert labels == {"none", "outage(d=4,p=0.06,len=8)"}
    for r in rows:
        if r["fault"] == "none":
            assert "resilience" not in r
        else:
            assert r["resilience"]["capacity_outages"] >= 0


@pytest.mark.slow
def test_chaos_sweep_outage_x_preemption_grid():
    """Chaos grid: fault kinds x feed outage, three policies, two seeds —
    everything must stay finite, labeled, and savings-comparable."""
    sweep = Sweep(
        base=Scenario(capacity=20, learn_weeks=1, eval_weeks=1,
                      region="south-australia",
                      ci_outage=CarbonDataOutage(rate=0.04, mean_duration=6.0,
                                                 seed=1)),
        seeds=(7, 8),
        policies=("carbon-agnostic", "wait-awhile", "carbonflex"),
        faults=[None,
                CorrelatedFaults(n_domains=4, rate=0.05, seed=2),
                PreemptionFaults(rate=0.05, checkpoint_every=4, seed=2)])
    res = sweep.run()
    rows = res.rows()
    assert len(rows) == 2 * 3 * 3
    assert {r["fault"] for r in rows} == {
        "none", "outage(d=4,p=0.05,len=8)", "preempt(p=0.05,ckpt=4)"}
    for r in rows:
        assert np.isfinite(r["carbon_g"]) and r["carbon_g"] > 0
        assert "resilience" in r     # ci_outage degrades every cell
        assert r["resilience"]["degraded_slots"] > 0
    # the JSON round-trip keeps the resilience columns
    back = json.loads(res.to_json())
    assert back["rows"][0]["resilience"]["degraded_slots"] > 0
