"""CarbonFlex-Simulator engine + emissions accounting tests."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import baselines, emissions, simulate
from repro.core.carbon import CarbonService, REGIONS, synthesize_trace
from repro.core.profiles import amdahl_profile
from repro.core.types import ClusterConfig, Job


def mk_jobs(n, seed=0, hours=48, k_max=3):
    rng = np.random.default_rng(seed)
    cluster = ClusterConfig.default(capacity=10)
    jobs = []
    for i in range(n):
        length = float(rng.uniform(1, 4))
        q = 0 if length <= 2 else 1
        jobs.append(Job(job_id=i, arrival=int(rng.integers(0, hours // 2)),
                        length=length, queue=q, delay=cluster.queues[q].delay,
                        profile=amdahl_profile(1, k_max, 0.5)))
    return jobs, cluster


class TestCarbonService:
    def test_deterministic_under_seed(self):
        a = synthesize_trace("california", 100, seed=7)
        b = synthesize_trace("california", 100, seed=7)
        np.testing.assert_array_equal(a, b)

    def test_regions_calibration(self):
        for region, (mean, cov) in REGIONS.items():
            tr = synthesize_trace(region, 24 * 90, seed=3)
            assert abs(tr.mean() - mean) / mean < 0.25, region
            assert tr.min() >= 10.0

    def test_rank_in_unit_interval(self):
        svc = CarbonService.synthetic("germany", 24 * 7)
        ranks = [svc.rank(t) for t in range(24 * 6)]
        assert min(ranks) >= 0.0 and max(ranks) <= 1.0

    def test_forecast_padding_and_extension(self):
        svc = CarbonService.synthetic("texas", 48)
        assert len(svc.forecast(40)) == 24
        assert len(svc.forecast_extended(0, 72)) == 72


class TestEmissions:
    def test_zero_when_idle(self):
        cluster = ClusterConfig.default(10)
        job = Job(0, 0, 1.0, 0, 6, np.ones(1))
        assert emissions.slot_energy_kwh(job, 0, cluster) == 0.0

    def test_scales_with_k_and_frac(self):
        cluster = ClusterConfig.default(10)
        job = Job(0, 0, 1.0, 0, 6, np.ones(4), power=2.0)
        e1 = emissions.slot_energy_kwh(job, 1, cluster)
        e4 = emissions.slot_energy_kwh(job, 4, cluster)
        assert e4 > e1 * 3.9
        assert emissions.slot_energy_kwh(job, 1, cluster, frac=0.5) == e1 * 0.5

    def test_network_term_positive_for_distributed(self):
        cluster = ClusterConfig.default(10)
        job = Job(0, 0, 1.0, 0, 6, np.ones(4), comm_size=10.0)
        base = emissions.slot_energy_kwh(job, 1, cluster)
        dist = emissions.slot_energy_kwh(job, 2, cluster)
        assert dist > 2 * base  # ring all-reduce traffic appears at k>1


class TestSimulator:
    def test_all_jobs_complete(self):
        jobs, cluster = mk_jobs(12)
        ci = CarbonService.synthetic("ontario", 24 * 30)
        res = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                       horizon=48)
        assert (res.completion >= 0).all()
        assert res.carbon_g > 0 and res.energy_kwh > 0

    def test_agnostic_runs_immediately(self):
        jobs, cluster = mk_jobs(3)
        ci = CarbonService.synthetic("ontario", 24 * 30)
        res = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                       horizon=48)
        assert res.mean_wait == 0.0
        assert not res.violations.any()

    @given(seed=st.integers(0, 500))
    @settings(max_examples=10, deadline=None)
    def test_capacity_invariant_under_policies(self, seed):
        jobs, cluster = mk_jobs(15, seed=seed)
        ci = CarbonService.synthetic("germany", 24 * 30, seed=seed)
        for pol in [baselines.WaitAwhilePolicy(), baselines.GaiaPolicy(mean_length=2.5),
                    baselines.VCCPolicy(), baselines.VCCPolicy(scaling=True)]:
            res = simulate(jobs, ci, cluster, pol, horizon=48)
            for log in res.slots:
                assert log.used <= cluster.capacity
            assert (res.completion >= 0).all(), pol.name

    def test_capacity_enforcement_trims_policy_overcommit(self):
        jobs, cluster = mk_jobs(20, seed=1)
        ci = CarbonService.synthetic("ontario", 24 * 30)

        class Greedy:
            name = "greedy"
            def on_window_start(self, *a): pass
            def decide(self, t, active, ci, cluster):
                return cluster.capacity, {a.job.job_id: a.job.k_max
                                          for a in active if not a.done}
            def on_completion(self, *a): pass

        res = simulate(jobs, ci, cluster, Greedy(), horizon=48)
        for log in res.slots:
            assert log.used <= cluster.capacity

    def test_wait_awhile_runs_in_cleanest_slots(self):
        jobs, cluster = mk_jobs(5, seed=2)
        ci = CarbonService.synthetic("south-australia", 24 * 30, seed=5)
        res_wa = simulate(jobs, ci, cluster, baselines.WaitAwhilePolicy(), horizon=48)
        res_ag = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(), horizon=48)
        assert res_wa.carbon_g <= res_ag.carbon_g * 1.02
