"""Scaling-profile tests: parametric family + roofline derivation."""
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.profiles import (RooflineTerms, amdahl_profile, class_profile,
                                 elasticity_of, roofline_profile)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun_opt")


class TestParametricProfiles:
    @given(sigma=st.floats(0.01, 2.0), k_max=st.integers(2, 32))
    @settings(max_examples=30, deadline=None)
    def test_monotone_decreasing_and_normalised(self, sigma, k_max):
        p = amdahl_profile(1, k_max, sigma)
        assert abs(p[0] - 1.0) < 1e-12
        assert (np.diff(p) <= 1e-12).all()
        assert (p >= 0).all()

    def test_class_ordering(self):
        hi = elasticity_of(class_profile("high"))
        mo = elasticity_of(class_profile("moderate"))
        lo = elasticity_of(class_profile("low"))
        assert hi > mo > lo


class TestRooflineProfiles:
    def _terms(self, flops=1e14, grad=1e9):
        return RooflineTerms(flops=flops, hbm_bytes=flops / 100,
                             grad_bytes=grad)

    def test_monotone_decreasing(self):
        p = roofline_profile(self._terms())
        assert abs(p[0] - 1.0) < 1e-12
        assert (np.diff(p) <= 1e-12).all()

    def test_more_compute_per_sync_is_more_elastic(self):
        small = roofline_profile(self._terms(flops=1e13))
        big = roofline_profile(self._terms(flops=1e15))
        assert elasticity_of(big) > elasticity_of(small)

    def test_step_time_components(self):
        t = self._terms()
        assert t.step_time(1) > t.step_time(16)        # strong scaling helps
        # collective term appears only at k > 1
        t2 = RooflineTerms(flops=1e10, hbm_bytes=1e8, grad_bytes=1e12)
        assert t2.step_time(2) > t2.step_time(1)       # sync dominates


@pytest.mark.skipif(not os.path.isdir(RESULTS),
                    reason="dry-run results not present")
class TestFromDryrun:
    def test_profiles_from_cells(self):
        from repro.core.profiles import profile_from_dryrun

        for arch in ["llama3-8b", "command-r-plus-104b"]:
            p = profile_from_dryrun(arch, dryrun_dir=RESULTS)
            assert abs(p[0] - 1.0) < 1e-12
            assert (np.diff(p) <= 1e-12).all()
            assert 0.3 < elasticity_of(p) <= 1.0

    def test_tpu_trace_mode(self):
        from repro.traces import TraceSpec, generate_trace

        jobs = generate_trace(TraceSpec(hours=24, seed=0, elasticity="tpu"))
        archs = {j.arch for j in jobs}
        assert len(archs) >= 3            # mixes the assigned architectures
        for j in jobs[:50]:
            assert (np.diff(j.profile) <= 1e-9).all()
