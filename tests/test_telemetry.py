"""Telemetry layer (ISSUE 9): per-slot decision traces, carbon
attribution, and phase profiling.

Pins the three tentpole contracts:

- **cross-engine stream equality** — scalar, vector and scan produce the
  identical event list for the same case (the scan engine decodes its
  events host-side from the packed device grids, so this is a real
  equivalence, not a shared code path);
- **observation-only recording** — attaching a recorder changes no
  result float (and ``telemetry=None`` costs the off paths nothing; the
  golden fixtures pin byte-identity separately);
- **attribution additivity** — the cause decomposition sums float-exact
  (``==``, no tolerance) to the measured savings delta, via a hypothesis
  property over synthetic aggregates plus fixed twins on real sweeps.
"""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import CarbonDataOutage, baselines, simulate
from repro.core.faults import (CorrelatedFaults, IidFaults, PreemptionFaults,
                               SlotDisturbance)
from repro.experiment import Scenario, Sweep, prepare_context
from repro.experiment.registry import make_policy
from repro.telemetry import (CAUSES, MemoryRecorder, PhaseProfiler,
                             SlotEventTracker, Telemetry, TraceEvent,
                             attribute, emit_fault_events, explain)

WEEK = 24 * 7
ENGINES = ("scalar", "vector", "scan")


def tiny(seed=101, **kw):
    kw.setdefault("capacity", 8)
    kw.setdefault("learn_weeks", 1)
    kw.setdefault("family", "alibaba")
    return Scenario(seed=seed, **kw).materialize()


def run_with_recorder(mat, policy, engine, **kw):
    tel = Telemetry(recorder=MemoryRecorder())
    res = simulate(mat.eval_jobs, mat.ci, mat.cluster, policy, t0=mat.t0,
                   horizon=WEEK, engine=engine, telemetry=tel, **kw)
    return tel.recorder.events, res


# --- recorder / tracker units ----------------------------------------------


def test_emit_is_noop_without_recorder():
    tel = Telemetry()
    tel.emit(0, "admit", job=1)          # must not raise, records nothing
    assert tel.recorder is None


def test_for_run_stamps_label_on_shared_recorder():
    rec = MemoryRecorder()
    tel = Telemetry(recorder=rec)
    tel.for_run("a").emit(0, "admit", job=1)
    tel.for_run("b").emit(1, "admit", job=2)
    assert [e.run for e in rec.events] == ["a", "b"]
    assert len(rec.for_run("a")) == 1
    assert rec.counts(run="b") == {"admit": 1}


def test_memory_recorder_queries_and_clear():
    rec = MemoryRecorder()
    tel = Telemetry(recorder=rec)
    tel.emit(0, "admit", job=1)
    tel.emit(1, "suspend", job=1)
    tel.emit(2, "resume", job=1, value=2.0)
    assert rec.counts() == {"admit": 1, "suspend": 1, "resume": 1}
    assert [e.t for e in rec.by_kind("suspend")] == [1]
    assert len(rec) == 3
    rec.clear()
    assert len(rec) == 0


def test_trace_event_shape():
    e = TraceEvent(t=3, kind="scale", job=7, value=4.0, detail="from=2")
    assert e.to_dict() == {"t": 3, "kind": "scale", "job": 7, "value": 4.0,
                           "detail": "from=2", "run": ""}


def test_tracker_derives_lifecycle_events():
    rec = MemoryRecorder()
    tr = SlotEventTracker(Telemetry(recorder=rec))
    tr.step(0, [1, 2], [2, 4])           # first starts: no events
    tr.step(1, [1, 2], [2, 8])           # job 2 scales 4 -> 8
    tr.step(2, [2], [8])                 # job 1 suspends
    tr.step(3, [1, 2], [2, 8])           # job 1 resumes
    tr.finish(2)
    tr.step(4, [1], [2])                 # job 2 finished: no suspend
    kinds = [(e.kind, e.job) for e in rec.events]
    assert kinds == [("scale", 2), ("suspend", 1), ("resume", 1)]
    assert rec.by_kind("scale")[0].value == 8.0
    assert rec.by_kind("scale")[0].detail == "from=4"


def test_tracker_steady_state_fast_path_changes_nothing():
    """The identical-stream shortcut must derive the same events as a
    tracker that never takes it (lists vs generators force both paths)."""
    streams = [([1, 2], [2, 4]), ([1, 2], [2, 4]), ([1, 2], [2, 4]),
               ([2], [4]), ([1, 2], [2, 4]), ([1, 2], [3, 4])]
    fast, slow = MemoryRecorder(), MemoryRecorder()
    trf = SlotEventTracker(Telemetry(recorder=fast))
    trs = SlotEventTracker(Telemetry(recorder=slow))
    for t, (ids, ks) in enumerate(streams):
        trf.step(t, ids, ks)                       # lists: fast path eligible
        trs.step(t, iter(ids), iter(ks))           # generators: full walk
    assert fast.events == slow.events


def test_fault_event_decoding():
    rec = MemoryRecorder()
    tel = Telemetry(recorder=rec)
    dist = SlotDisturbance(
        factors=np.array([1.0, 0.0, 0.5]),
        evicted=np.array([True, False, False]),
        lost=np.array([0.0, 3.0, 0.0]),
        extra_energy=np.array([0.0, 0.25, 0.0]))
    emit_fault_events(tel, 5, [10, 11, 12], dist, "preemption")
    kinds = [(e.kind, e.job, e.value) for e in rec.events]
    assert kinds == [("evict", 10, None), ("preempt", 11, 3.0),
                     ("restore", 11, 0.25), ("checkpoint", 12, 0.5)]


# --- cross-engine event-stream parity --------------------------------------


@pytest.mark.parametrize("mk", [baselines.CarbonAgnosticPolicy,
                                baselines.WaitAwhilePolicy])
def test_single_region_stream_parity(mk):
    mat = tiny()
    ref = None
    for eng in ENGINES:
        events, res = run_with_recorder(mat, mk(), eng)
        if ref is None:
            ref = (events, res.carbon_g)
            assert len(events) > 0
            assert all(e.kind == "admit" for e in events
                       if e.t == events[0].t)
        else:
            assert events == ref[0], eng
            assert res.carbon_g == ref[1], eng


def test_carbonflex_stream_parity_with_kb():
    mat = tiny()
    ctx = prepare_context(mat, ["carbonflex"])
    ref = None
    for eng in ENGINES:
        events, res = run_with_recorder(mat, make_policy("carbonflex", ctx),
                                        eng)
        if ref is None:
            ref = (events, res.carbon_g)
        else:
            assert (events, res.carbon_g) == ref, eng


@pytest.mark.parametrize("mkf,expected", [
    (lambda: IidFaults(straggler_rate=0.2, failure_rate=0.05, seed=3), ()),
    (lambda: PreemptionFaults(rate=0.2, seed=3),
     ("preempt", "restore", "checkpoint")),
    (lambda: CorrelatedFaults(n_domains=2, rate=0.1, seed=3), ("evict",)),
])
def test_fault_stream_parity(mkf, expected):
    mat = tiny()
    ref = None
    for eng in ENGINES:
        events, res = run_with_recorder(mat, baselines.WaitAwhilePolicy(),
                                        eng, faults=mkf())
        if ref is None:
            ref = (events, res.carbon_g)
            kinds = {e.kind for e in events}
            for kind in expected:
                assert kind in kinds, kind
        else:
            assert (events, res.carbon_g) == ref, eng


def test_dag_stream_parity():
    from repro.traces import DagConfig

    mat = tiny(dag=DagConfig(width=3, depth=3))
    ctx = prepare_context(mat, ["dag-cap"])
    for pol in ("dag-fcfs", "dag-cap"):
        ref = None
        for eng in ENGINES:
            events, res = run_with_recorder(mat, make_policy(pol, ctx), eng)
            if ref is None:
                ref = (events, res.carbon_g)
            else:
                assert (events, res.carbon_g) == ref, (pol, eng)


def test_geo_stream_parity_with_migrations():
    mat = tiny(regions=("california", "ontario"))
    ctx = prepare_context(mat, ["geo-flex"])
    ref = None
    for eng in ENGINES:
        tel = Telemetry(recorder=MemoryRecorder())
        res = simulate(mat.eval_jobs, mat.mci, mat.geo,
                       make_policy("geo-flex", ctx), t0=mat.t0,
                       horizon=WEEK, engine=eng, telemetry=tel)
        got = (tel.recorder.events, res.carbon_g)
        if ref is None:
            ref = got
            migs = [e for e in got[0] if e.kind == "migrate"]
            assert len(migs) == res.migrations > 0
            assert all(e.detail.startswith("from=") for e in migs)
        else:
            assert got == ref, eng


def test_outage_forecast_read_parity():
    mat = tiny(ci_outage=CarbonDataOutage(rate=0.1, mean_duration=6.0,
                                          stale_after=3, seed=5))
    ref = None
    for eng in ENGINES:
        events, res = run_with_recorder(mat, baselines.WaitAwhilePolicy(),
                                        eng)
        if ref is None:
            ref = (events, res.carbon_g)
            reads = [e for e in events if e.kind == "forecast-read"]
            assert reads and max(e.value for e in reads) > 0
        else:
            assert (events, res.carbon_g) == ref, eng


def test_serving_stream_parity_and_tier_switches():
    from repro.experiment import ServingConfig
    from repro.serving import simulate_serving

    mat = tiny(serving=ServingConfig(requests_per_day=2e5, servers=12),
               capacity=12)
    ctx = prepare_context(mat, ["serve-flex"])
    horizon = min(WEEK, mat.serving.demand.shape[0] - mat.t0)
    from repro.serving import ServeCase

    ref = None
    for eng in ("scalar", "vector"):
        tel = Telemetry(recorder=MemoryRecorder())
        case = ServeCase(demand=mat.serving.demand[mat.t0:mat.t0 + horizon],
                         rate=mat.serving.rate, ci=mat.ci,
                         config=mat.serving.config,
                         policy=make_policy("serve-flex", ctx), t0=mat.t0)
        res = simulate_serving(case, engine=eng, telemetry=tel)
        got = (tel.recorder.events, res.carbon_g)
        if ref is None:
            ref = got
            assert any(e.kind == "tier-switch" for e in got[0])
        else:
            assert got == ref, eng


# --- observation-only recording --------------------------------------------


@pytest.mark.parametrize("eng", ENGINES)
def test_recording_does_not_change_results(eng):
    mat = tiny()
    base = simulate(mat.eval_jobs, mat.ci, mat.cluster,
                    baselines.WaitAwhilePolicy(), t0=mat.t0, horizon=WEEK,
                    engine=eng)
    _, res = run_with_recorder(mat, baselines.WaitAwhilePolicy(), eng)
    assert res.to_dict() == base.to_dict()


# --- attribution -----------------------------------------------------------


def _stub(policy, carbon, energy, mig=0.0, restore=None, serving=False):
    class _R:
        pass

    r = _R()
    r.policy = policy
    r.carbon_g = carbon
    r.energy_kwh = energy
    r.regions = None
    r.slots = []
    r.migration_carbon_g = mig
    r.resilience = None
    r.serving = object() if serving else None
    if restore is not None:
        class _Res:
            restore_energy_kwh = restore

        r.resilience = _Res()
    return r


@settings(max_examples=200, deadline=None)
@given(bc=st.floats(1e-6, 1e9), rc=st.floats(0.0, 1e9),
       be=st.floats(0.0, 1e6), re_=st.floats(0.0, 1e6),
       bm=st.floats(0.0, 1e6), rm=st.floats(0.0, 1e6),
       br=st.floats(0.0, 1e3), rr=st.floats(0.0, 1e3),
       serving=st.booleans())
def test_attribution_additivity_property(bc, rc, be, re_, bm, rm, br, rr,
                                         serving):
    """sum(causes) == delta_g, float-exact, for arbitrary finite
    aggregates; delta_g equals the measured delta up to the documented
    lattice caveat (a few ulps, only under cancelling decompositions)."""
    res = _stub("p", rc, re_, mig=rm, restore=rr, serving=serving)
    base = _stub("b", bc, be, mig=bm, restore=br, serving=serving)
    att = attribute(res, base)
    att.check()                          # raises unless == holds
    total = 0.0
    for c in CAUSES:
        total += att.causes[c]
    assert total == att.delta_g
    scale = max(abs(att.causes[c]) for c in CAUSES) or 1.0
    assert abs(att.delta_g - (bc - rc)) <= 16 * math.ulp(scale)
    energy_axis = ("precision_tiering" if serving else "capacity_scaling")
    off_axis = ("capacity_scaling" if serving else "precision_tiering")
    assert att.causes[off_axis] == 0.0
    assert (att.causes[energy_axis] != 0.0) == (
        be != re_ and bc > 0 and be > 0)


def test_attribution_fixed_twin():
    """The additivity contract on one hand-checked example."""
    res = _stub("carbonflex", 700.0, 9.0)
    base = _stub("carbon-agnostic", 1000.0, 10.0)
    att = attribute(res, base)
    att.check()
    assert att.delta_g == 300.0
    assert att.causes["capacity_scaling"] == 100.0   # 1 kWh at 100 g/kWh
    assert att.causes["temporal_shifting"] == 200.0  # residual
    assert att.savings_pct == 30.0
    assert att.pp_of_baseline("capacity_scaling") == 10.0
    assert "carbonflex vs carbon-agnostic" in att.table()
    d = att.to_dict()
    assert set(d["causes"]) == set(CAUSES)


def test_sweep_attributions_additive_on_real_runs():
    sw = Sweep(base=Scenario(capacity=8, learn_weeks=1, family="alibaba",
                             seed=101),
               seeds=[11], policies=["carbon-agnostic", "wait-awhile"])
    res = sw.run()
    atts = res.attributions()            # check() runs inside
    assert len(atts) == 1
    att = atts[0]
    assert att.policy == "wait-awhile"
    assert att.baseline == "carbon-agnostic"
    row = [r for r in res.rows() if r["policy"] == "wait-awhile"][0]
    assert round(att.savings_pct, 2) == round(row["savings_pct"], 2)


def test_serving_sweep_attributions_use_tiering_axis():
    from repro.experiment import ServingConfig

    sw = Sweep(base=Scenario(serving=ServingConfig(requests_per_day=2e5,
                                                   servers=12),
                             learn_weeks=1, seed=101),
               seeds=[11], policies=["serve-static", "serve-flex"])
    atts = sw.run().attributions()
    assert [a.policy for a in atts] == ["serve-flex"]
    att = atts[0]
    assert att.causes["capacity_scaling"] == 0.0
    assert att.causes["precision_tiering"] != 0.0


# --- profiler / explain ----------------------------------------------------


def test_profiler_brackets_and_summary():
    prof = PhaseProfiler()
    with prof.phase("decide"):
        pass
    with prof.phase("decide"):
        pass
    with prof.phase("execute", sync=np.zeros(3)):
        pass
    s = prof.summary()
    assert list(s) == ["decide", "execute"]
    assert s["decide"]["calls"] == 2
    assert abs(sum(d["share"] for d in s.values()) - 1.0) < 1e-9
    assert prof.total() > 0
    assert "decide" in prof.table()


def test_run_and_sweep_surface_phase_profile():
    from repro.experiment import run

    tel = Telemetry(profiler=PhaseProfiler())
    run(Scenario(capacity=8, learn_weeks=1, family="alibaba", seed=101),
        ["carbon-agnostic", "wait-awhile"], telemetry=tel)
    secs = tel.profiler.seconds
    assert {"provision", "decide", "execute"} <= set(secs)
    assert all(v >= 0 for v in secs.values())


def test_explain_report_sections():
    mat = tiny()
    tel = Telemetry(recorder=MemoryRecorder(), profiler=PhaseProfiler())
    base = simulate(mat.eval_jobs, mat.ci, mat.cluster,
                    baselines.CarbonAgnosticPolicy(), t0=mat.t0,
                    horizon=WEEK, engine="vector")
    res = simulate(mat.eval_jobs, mat.ci, mat.cluster,
                   baselines.WaitAwhilePolicy(), t0=mat.t0, horizon=WEEK,
                   engine="vector", telemetry=tel)
    report = explain(res, baseline=base, recorder=tel.recorder,
                     profiler=tel.profiler)
    assert "run: wait-awhile" in report
    assert "attribution:" in report
    assert "events:" in report
    assert "admit" in report
    assert "phases:" in report


def test_oracle_gap_rows_carry_gap_attribution():
    from repro.experiment import OracleGap, sigma_ladder

    res = OracleGap(base=Scenario(capacity=8, learn_weeks=1,
                                  family="alibaba", seed=101),
                    policies=("wait-awhile",), seeds=(11,),
                    forecasts=sigma_ladder((0.0,))).run()
    rows = res.rows()
    assert rows
    for r in rows:
        att = r["gap_attribution_pp"]
        assert abs(sum(att.values()) - r["gap_pp"]) < 0.02  # rounding only
    s = res.summary()["perfect"]["wait-awhile"]
    assert "gap_attribution_mean_pp" in s
