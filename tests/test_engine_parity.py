"""Vector and scan engines vs scalar reference: bit-for-bit parity.

The vectorised engine (struct-of-arrays accounting, packed policy fast
paths, batched fault draws) and the jitted scan engine (device slot loop
with vector-engine delegation for non-native cases) must reproduce the
scalar reference engine's ``SimResult`` exactly — same
``carbon_g``/``energy_kwh`` floats, same completion/violation/wait
arrays, same per-slot logs — on seeded scenarios, for every policy, with
and without fault injection."""
import dataclasses

import numpy as np
import pytest

from repro.core import (CarbonFlexPolicy, CarbonService, ClusterConfig,
                        KnowledgeBase, NoisyForecast, OraclePolicy,
                        QuantileForecast, baselines, learn_window, simulate)
from repro.core.policy import CarbonFlexMPCPolicy
from repro.core.simulator import FaultModel, SimCase, simulate_many
from repro.core.types import Job
from repro.traces import TraceSpec, generate_trace

WEEK = 24 * 7
CAP = 20


@pytest.fixture(scope="module")
def world():
    cluster = ClusterConfig.default(capacity=CAP)
    ci = CarbonService.synthetic("south-australia", WEEK * 3 + 24 * 30, seed=21)
    spec = TraceSpec(family="azure", hours=WEEK * 2, capacity=CAP, seed=22)
    jobs = generate_trace(spec, cluster.queues)
    hist = [j for j in jobs if j.arrival < WEEK]
    ev = [j for j in jobs if WEEK <= j.arrival < WEEK * 2]
    kb = KnowledgeBase()
    learn_window(kb, hist, ci, 0, WEEK, cluster, backend="numpy")
    return cluster, ci, hist, ev, kb


def _mk_policies(kb, hist):
    def mpc():
        p = CarbonFlexMPCPolicy()
        p.warm_start(hist)
        return p

    return {
        "carbon-agnostic": baselines.CarbonAgnosticPolicy,
        "gaia": lambda: baselines.GaiaPolicy(mean_length=2.5),
        "wait-awhile": baselines.WaitAwhilePolicy,
        "carbonscaler": lambda: baselines.CarbonScalerPolicy(mean_length=2.5),
        "vcc": baselines.VCCPolicy,
        "vcc-scaling": lambda: baselines.VCCPolicy(scaling=True),
        "oracle": lambda: OraclePolicy(backend="numpy"),
        "carbonflex": lambda: CarbonFlexPolicy(kb),
        "carbonflex-mpc": mpc,
    }


def assert_results_identical(a, b, ctx=""):
    assert a.carbon_g == b.carbon_g, ctx
    assert a.energy_kwh == b.energy_kwh, ctx
    np.testing.assert_array_equal(a.completion, b.completion, err_msg=ctx)
    np.testing.assert_array_equal(a.violations, b.violations, err_msg=ctx)
    np.testing.assert_array_equal(a.wait_slots, b.wait_slots, err_msg=ctx)
    assert len(a.slots) == len(b.slots), ctx
    for la, lb in zip(a.slots, b.slots):
        assert la == lb, f"{ctx}: slot {la.slot}"


@pytest.mark.parametrize("policy_name", [
    "carbon-agnostic", "gaia", "wait-awhile", "carbonscaler", "vcc",
    "vcc-scaling", "oracle", "carbonflex", "carbonflex-mpc",
])
def test_engines_identical_per_policy(world, policy_name):
    cluster, ci, hist, ev, kb = world
    mk = _mk_policies(kb, hist)[policy_name]
    rs = simulate(ev, ci, cluster, mk(), t0=WEEK, horizon=WEEK, engine="scalar")
    for engine in ("vector", "scan"):
        rv = simulate(ev, ci, cluster, mk(), t0=WEEK, horizon=WEEK,
                      engine=engine)
        assert_results_identical(rs, rv, f"{policy_name}/{engine}")
        assert (rv.completion >= 0).all()


@pytest.mark.parametrize("policy_name", ["carbon-agnostic", "carbonflex",
                                         "carbonscaler"])
@pytest.mark.parametrize("fault_seed", [2, 9])
def test_engines_identical_under_faults(world, policy_name, fault_seed):
    cluster, ci, hist, ev, kb = world
    mk = _mk_policies(kb, hist)[policy_name]
    mk_faults = lambda: FaultModel(straggler_rate=0.15, failure_rate=0.05,  # noqa: E731
                                   seed=fault_seed)
    rs = simulate(ev, ci, cluster, mk(), t0=WEEK, horizon=WEEK,
                  engine="scalar", faults=mk_faults())
    for engine in ("vector", "scan"):   # scan delegates faulted cases
        rv = simulate(ev, ci, cluster, mk(), t0=WEEK, horizon=WEEK,
                      engine=engine, faults=mk_faults())
        assert_results_identical(rs, rv, f"{policy_name}+faults/{engine}")


FORECASTS = {"noisy": NoisyForecast(sigma=0.3, seed=5),
             "quantile": QuantileForecast(sigma=0.3, seed=5, members=5)}


@pytest.mark.parametrize("policy_name", [
    "wait-awhile", "wait-awhile-robust", "gaia", "carbonscaler",
    "carbonflex", "carbonflex-robust", "carbonflex-mpc"])
@pytest.mark.parametrize("forecast", sorted(FORECASTS))
@pytest.mark.parametrize("faulty", [False, True])
def test_engines_identical_under_noisy_forecasts(world, policy_name,
                                                 forecast, faulty):
    """Forecast consumption must not diverge between engine paths
    (ISSUE-5): both engines see the same realized error stream per query
    slot, so results stay bit-identical under NoisyForecast /
    QuantileForecast, with and without fault injection."""
    cluster, ci, hist, ev, kb = world
    ci_f = dataclasses.replace(ci, model=FORECASTS[forecast])
    mk = {**_mk_policies(kb, hist),
          "wait-awhile-robust": baselines.RobustWaitAwhilePolicy,
          "carbonflex-robust": lambda: CarbonFlexPolicy(
              kb, forecast_quantile=0.7, name="carbonflex-robust"),
          }[policy_name]
    mk_faults = (lambda: FaultModel(straggler_rate=0.15, failure_rate=0.05,
                                    seed=3)) if faulty else (lambda: None)
    rs = simulate(ev, ci_f, cluster, mk(), t0=WEEK, horizon=WEEK,
                  engine="scalar", faults=mk_faults())
    for engine in ("vector", "scan"):
        rv = simulate(ev, ci_f, cluster, mk(), t0=WEEK, horizon=WEEK,
                      engine=engine, faults=mk_faults())
        assert_results_identical(rs, rv, f"{policy_name}+{forecast}/{engine}")
        assert (rv.completion >= 0).all()


def test_fault_batch_draws_match_sequential_stream():
    """draw_factors(m) must consume the RNG exactly like m progress_factor
    calls — the property the cross-engine fault parity rests on."""
    a = FaultModel(straggler_rate=0.2, failure_rate=0.1, seed=5)
    b = FaultModel(straggler_rate=0.2, failure_rate=0.1, seed=5)
    seq = np.array([a.progress_factor(0, i) for i in range(64)])
    batched = np.concatenate([b.draw_factors(10), b.draw_factors(0),
                              b.draw_factors(54)])
    np.testing.assert_array_equal(seq, batched)


def test_zero_length_job_edge():
    """Jobs that are complete on admission finish at their arrival slot
    without progress, waiting charge, or energy — in both engines."""
    cluster = ClusterConfig.default(capacity=4)
    ci = CarbonService.synthetic("ontario", 24 * 30)
    jobs = [
        Job(job_id=0, arrival=0, length=0.0, queue=0, delay=6, profile=np.ones(2)),
        Job(job_id=1, arrival=1, length=2.0, queue=0, delay=6, profile=np.ones(2)),
    ]
    rs = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                  horizon=24, engine="scalar")
    for engine in ("vector", "scan"):
        rv = simulate(jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                      horizon=24, engine=engine)
        assert_results_identical(rs, rv, f"zero-length/{engine}")
        assert rv.completion[0] == 0 and rv.wait_slots[0] == 0


def test_simulate_many_matches_individual_runs(world):
    cluster, ci, hist, ev, kb = world
    mk = _mk_policies(kb, hist)
    names = ["carbon-agnostic", "wait-awhile", "carbonflex"]
    cases = [SimCase(jobs=ev, ci=ci, cluster=cluster, policy=mk[n](),
                     t0=WEEK, horizon=WEEK, label=n) for n in names]
    batch = simulate_many(cases)
    for name, res in zip(names, batch):
        solo = simulate(ev, ci, cluster, mk[name](), t0=WEEK, horizon=WEEK)
        assert_results_identical(solo, res, f"simulate_many/{name}")


def test_simulate_many_sweeps_regions_and_seeds(world):
    """The batch API packs each distinct trace once and sweeps
    (regions x seeds x policies) in one call."""
    cluster, ci, hist, ev, kb = world
    cases = []
    for region in ("ontario", "germany"):
        for seed in (0, 1):
            cases.append(SimCase(
                jobs=ev, ci=CarbonService.synthetic(region, WEEK * 3, seed=seed),
                cluster=cluster, policy=baselines.CarbonAgnosticPolicy(),
                t0=WEEK, horizon=WEEK, label=f"{region}/{seed}"))
    results = simulate_many(cases)
    assert len(results) == 4
    assert all((r.completion >= 0).all() for r in results)
    # distinct CI traces must yield distinct carbon totals
    assert len({round(r.carbon_g, 6) for r in results}) == 4
