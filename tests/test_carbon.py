"""CarbonService edge cases (ISSUE-3 satellite): forecast behaviour at and
past the trace end, forecast-noise determinism per seed, and the
ValueError contract listing known regions.

Plus the ISSUE-4 property suite: for ANY slot ``t`` (including far past
the trace end) and ANY horizon, ``forecast`` / ``forecast_extended`` /
``forecast_matrix`` return finite values of the requested length,
deterministically per seed — driven by a hypothesis sweep and a
fixed-seed parametrize twin (tests/conftest.py shims hypothesis into
skips when absent)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.carbon import (REGIONS, CarbonService,
                               MultiRegionCarbonService, synthesize_trace)
from repro.core.forecast import StaticNoiseForecast


class TestForecastEdges:
    def test_forecast_at_trace_end_pads_with_last_value(self):
        svc = CarbonService(trace=np.arange(1.0, 49.0))     # 48 slots
        fc = svc.forecast(47)                               # one real value left
        assert len(fc) == svc.horizon == 24
        assert fc[0] == 48.0
        np.testing.assert_array_equal(fc[1:], np.full(23, 48.0))

    def test_forecast_window_straddling_end(self):
        svc = CarbonService(trace=np.arange(1.0, 49.0))
        fc = svc.forecast(40)
        np.testing.assert_array_equal(fc[:8], np.arange(41.0, 49.0))
        np.testing.assert_array_equal(fc[8:], np.full(16, 48.0))

    def test_forecast_past_trace_end_is_all_zeros(self):
        """Past the end there is no last-known value; the documented
        behaviour is an all-zero forecast, not an IndexError."""
        svc = CarbonService(trace=np.arange(1.0, 25.0))
        fc = svc.forecast(24)
        assert len(fc) == 24
        np.testing.assert_array_equal(fc, np.zeros(24))

    def test_ci_clamps_to_last_slot(self):
        svc = CarbonService(trace=np.arange(1.0, 25.0))
        assert svc.ci(23) == 24.0
        assert svc.ci(1000) == 24.0

    def test_forecast_extended_tiles_day_ahead(self):
        svc = CarbonService.synthetic("ontario", 24 * 10, seed=3)
        day = svc.forecast(0, 24)
        ext = svc.forecast_extended(0, 60)
        assert len(ext) == 60
        np.testing.assert_array_equal(ext[:24], day)
        np.testing.assert_array_equal(ext[24:48], day)
        np.testing.assert_array_equal(ext[48:], day[:12])

    def test_gradient_at_zero_and_rank_range(self):
        svc = CarbonService.synthetic("germany", 24 * 8, seed=5)
        assert svc.gradient(0) == 0.0
        for t in (0, 10, 100):
            assert 0.0 <= svc.rank(t) <= 1.0


class TestForecastNoise:
    """The static ``forecast_noise`` knob is deprecated since ISSUE-5 (it
    became the ``StaticNoiseForecast`` shim): every construction below
    must warn while reproducing the old outputs bit-for-bit (pinned in
    tests/test_forecast.py::TestDeprecatedShim)."""

    def test_noisy_forecast_deterministic_per_seed(self):
        trace = synthesize_trace("texas", 24 * 7, seed=2)

        def mk(s):
            with pytest.warns(DeprecationWarning, match="forecast_noise"):
                return CarbonService(trace=trace, forecast_noise=0.2, seed=s)

        a, b = mk(11), mk(11)
        np.testing.assert_array_equal(a.forecast(0, 48), b.forecast(0, 48))
        c = mk(12)
        assert not np.array_equal(a.forecast(0, 48), c.forecast(0, 48))

    def test_noise_perturbs_forecast_not_trace(self):
        trace = synthesize_trace("texas", 24 * 7, seed=2)
        with pytest.warns(DeprecationWarning, match="forecast_noise"):
            svc = CarbonService(trace=trace, forecast_noise=0.2, seed=7)
        assert not np.array_equal(svc.forecast(0, 24), trace[:24])
        np.testing.assert_array_equal(svc.trace, trace)   # truth untouched
        assert svc.ci(5) == float(trace[5])
        assert (svc.forecast(0, 24) >= 1.0).all()         # clip floor

    def test_zero_noise_forecast_is_the_trace(self):
        trace = synthesize_trace("texas", 24 * 3, seed=2)
        svc = CarbonService(trace=trace)
        np.testing.assert_array_equal(svc.forecast(0, 24), trace[:24])


def _check_forecast_properties(t: int, horizon: int, noise: float,
                               seed: int) -> None:
    """Any t, any horizon >= 1: finite values, exact length, deterministic
    per seed (including at/past the trace end and with forecast noise)."""
    hours = 24 * 4
    model = StaticNoiseForecast(sigma=noise, seed=seed) if noise else None
    mk = lambda: CarbonService(  # noqa: E731
        trace=synthesize_trace("germany", hours, seed=seed),
        seed=seed, model=model)
    a, b = mk(), mk()
    for svc in (a, b):
        fc = svc.forecast(t, horizon)
        assert len(fc) == horizon
        assert np.isfinite(fc).all()
        assert (fc >= 0.0).all()
        ext = svc.forecast_extended(t, horizon)
        assert len(ext) == horizon
        assert np.isfinite(ext).all()
    np.testing.assert_array_equal(a.forecast(t, horizon),
                                  b.forecast(t, horizon))
    np.testing.assert_array_equal(a.forecast_extended(t, horizon),
                                  b.forecast_extended(t, horizon))
    # extension tiles the day-ahead block it starts from
    day = a.forecast(t, a.horizon)
    ext = a.forecast_extended(t, horizon)
    np.testing.assert_array_equal(ext, np.tile(day, int(np.ceil(
        horizon / len(day))))[:horizon])
    # the multi-region matrix inherits the same contract, row per region
    mci = MultiRegionCarbonService(
        ("germany", "ontario"),
        (a, CarbonService(trace=synthesize_trace("ontario", hours,
                                                 seed=seed))))
    m = mci.forecast_matrix(t, horizon)
    assert m.shape == (2, horizon)
    assert np.isfinite(m).all()
    np.testing.assert_array_equal(m[0], a.forecast(t, horizon))
    np.testing.assert_array_equal(m[1], mci.services[1].forecast(t, horizon))


class TestForecastProperties:
    @pytest.mark.parametrize("t", [0, 50, 95, 96, 500])
    @pytest.mark.parametrize("horizon", [1, 24, 100])
    @pytest.mark.parametrize("noise", [0.0, 0.2])
    def test_fixed(self, t, horizon, noise):
        _check_forecast_properties(t, horizon, noise, seed=13)

    @settings(max_examples=40, deadline=None)
    @given(t=st.integers(0, 24 * 8), horizon=st.integers(1, 24 * 6),
           noise=st.sampled_from([0.0, 0.1, 0.5]),
           seed=st.integers(0, 1000))
    def test_property(self, t, horizon, noise, seed):
        _check_forecast_properties(t, horizon, noise, seed)


class TestRegionErrors:
    def test_unknown_region_error_lists_known_regions(self):
        with pytest.raises(ValueError) as ei:
            synthesize_trace("atlantis", 24)
        msg = str(ei.value)
        assert "atlantis" in msg
        for region in REGIONS:
            assert region in msg

    def test_carbon_service_synthetic_propagates_error(self):
        with pytest.raises(ValueError, match="available regions"):
            CarbonService.synthetic("atlantis", 24)

    def test_seeded_traces_reproducible_and_distinct_by_region(self):
        a = synthesize_trace("sweden", 24 * 7, seed=9)
        b = synthesize_trace("sweden", 24 * 7, seed=9)
        np.testing.assert_array_equal(a, b)
        c = synthesize_trace("poland", 24 * 7, seed=9)
        assert not np.array_equal(a, c)
        assert (a >= 10.0).all()                          # clip floor
