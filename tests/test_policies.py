"""End-to-end policy tests: learning + execution phases (small scale)."""
import pytest

from repro.core import (CarbonFlexPolicy, CarbonService, ClusterConfig,
                        KnowledgeBase, OraclePolicy, baselines, learn_window,
                        simulate)
from repro.core.policy import CarbonFlexMPCPolicy
from repro.traces import TraceSpec, generate_trace, mean_length

CAP = 30
WEEK = 24 * 7


@pytest.fixture(scope="module")
def world():
    cluster = ClusterConfig.default(capacity=CAP)
    ci = CarbonService.synthetic("south-australia", WEEK * 4 + 24 * 30, seed=11)
    spec = TraceSpec(family="azure", hours=WEEK * 3, capacity=CAP, seed=12)
    jobs = generate_trace(spec, cluster.queues)
    eval_jobs = [j for j in jobs if WEEK * 2 <= j.arrival < WEEK * 3]
    hist_jobs = [j for j in jobs if j.arrival < WEEK * 2]
    base = simulate(eval_jobs, ci, cluster, baselines.CarbonAgnosticPolicy(),
                    t0=WEEK * 2, horizon=WEEK)
    return cluster, ci, spec, jobs, hist_jobs, eval_jobs, base


def test_oracle_beats_agnostic(world):
    cluster, ci, spec, jobs, hist, ev, base = world
    r = simulate(ev, ci, cluster, OraclePolicy(backend="numpy"),
                 t0=WEEK * 2, horizon=WEEK)
    assert r.savings_vs(base) > 20.0
    assert r.violation_rate <= 0.02


def test_carbonflex_knn_pipeline(world):
    cluster, ci, spec, jobs, hist, ev, base = world
    kb = KnowledgeBase()
    learn_window(kb, hist, ci, 0, WEEK, cluster, offsets=(0, WEEK), backend="numpy")
    assert len(kb) == 2 * WEEK
    r = simulate(ev, ci, cluster, CarbonFlexPolicy(kb), t0=WEEK * 2, horizon=WEEK)
    # learned policy must clearly beat carbon-agnostic
    assert r.savings_vs(base) > 5.0
    assert (r.completion >= 0).all()


def test_carbonflex_mpc_close_to_oracle(world):
    cluster, ci, spec, jobs, hist, ev, base = world
    orc = simulate(ev, ci, cluster, OraclePolicy(backend="numpy"),
                   t0=WEEK * 2, horizon=WEEK)
    pol = CarbonFlexMPCPolicy()
    pol.warm_start(hist)
    r = simulate(ev, ci, cluster, pol, t0=WEEK * 2, horizon=WEEK)
    assert r.savings_vs(base) > 0.6 * orc.savings_vs(base)


def test_baselines_ordering(world):
    """Qualitative ordering from the paper: elastic/carbon-aware policies
    save carbon vs agnostic; oracle dominates."""
    cluster, ci, spec, jobs, hist, ev, base = world
    ml = mean_length(TraceSpec(family="azure"))
    savings = {}
    for pol in [baselines.WaitAwhilePolicy(), baselines.GaiaPolicy(mean_length=ml),
                baselines.CarbonScalerPolicy(mean_length=ml)]:
        r = simulate(ev, ci, cluster, pol, t0=WEEK * 2, horizon=WEEK)
        savings[pol.name] = r.savings_vs(base)
    for name, s in savings.items():
        assert s > 0.0, (name, s)


def test_vcc_interop(world):
    cluster, ci, spec, jobs, hist, ev, base = world
    plain = simulate(ev, ci, cluster, baselines.VCCPolicy(), t0=WEEK * 2, horizon=WEEK)
    scal = simulate(ev, ci, cluster, baselines.VCCPolicy(scaling=True),
                    t0=WEEK * 2, horizon=WEEK)
    assert plain.carbon_g > 0 and scal.carbon_g > 0
    # §6.7: adding elastic scaling to VCC lowers waiting time
    assert scal.mean_wait <= plain.mean_wait + 1.0
