"""Forecast subsystem (ISSUE 5): model properties, the lead-time fix, the
deprecated ``forecast_noise`` shim, robust policy variants, and the
Scenario/Sweep threading.

Property families (hypothesis sweeps + fixed-seed smoke twins, as in
tests/test_property_engine.py):

- determinism per seed, exact horizon length at/past the trace end, and
  positivity for EVERY model;
- ``PerfectForecast`` bit-identical to the ground-truth
  ``CarbonService.forecast`` slice;
- quantile monotonicity (q10 <= q50 <= q90) at every horizon;
- the lead-time fix: the realized error of a future slot depends on the
  query slot and statistically shrinks as the slot approaches — the old
  static ``forecast_noise`` knob (one realization per trace) is pinned as
  the deprecated shim, warning while matching old outputs bit-for-bit.
"""
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import CarbonService
from repro.core.baselines import RobustWaitAwhilePolicy, WaitAwhilePolicy
from repro.core.carbon import synthesize_trace
from repro.core.forecast import (FORECAST_KINDS, ForecastModel,
                                 NoisyForecast, PerfectForecast,
                                 PersistenceForecast, QuantileCIView,
                                 QuantileForecast, StaticNoiseForecast,
                                 _norm_ppf, forecast_from_dict,
                                 forecast_label, forecast_to_dict)
from repro.experiment import Scenario, Sweep

HOURS = 24 * 6

MODELS = {
    "perfect": PerfectForecast(),
    "persistence": PersistenceForecast(),
    "noisy": NoisyForecast(sigma=0.2, seed=3),
    "quantile": QuantileForecast(sigma=0.2, seed=3, members=7),
    "static-noise": StaticNoiseForecast(sigma=0.2, seed=3),
}


def _mk_model(kind: str, seed: int) -> ForecastModel:
    if kind == "perfect":
        return PerfectForecast()
    if kind == "persistence":
        return PersistenceForecast()
    if kind == "noisy":
        return NoisyForecast(sigma=0.3, seed=seed)
    if kind == "quantile":
        return QuantileForecast(sigma=0.3, seed=seed, members=5)
    return StaticNoiseForecast(sigma=0.3, seed=seed)


# --- core model properties ---------------------------------------------------


def _check_model_properties(kind: str, t: int, horizon: int,
                            seed: int) -> None:
    """Any model, any t (incl. past the trace end), any horizon >= 1:
    exact length, finite, non-negative, deterministic per seed."""
    trace = synthesize_trace("germany", HOURS, seed=seed)
    a, b = _mk_model(kind, seed), _mk_model(kind, seed)
    fa, fb = a.predict(trace, t, horizon), b.predict(trace, t, horizon)
    assert len(fa) == horizon
    assert np.isfinite(fa).all()
    assert (fa >= 0.0).all()
    np.testing.assert_array_equal(fa, fb)          # deterministic per seed
    # a longer horizon extends, never rewrites, the shorter one
    np.testing.assert_array_equal(
        a.predict(trace, t, horizon + 5)[:horizon], fa)
    # the current slot is observed: no model invents error at lead 0
    if kind != "static-noise" and t < len(trace):
        assert fa[0] == trace[t]
    qfn = getattr(a, "quantile", None)
    if qfn is not None:
        q10 = qfn(trace, t, horizon, 0.1)
        q50 = qfn(trace, t, horizon, 0.5)
        q90 = qfn(trace, t, horizon, 0.9)
        for q in (q10, q50, q90):
            assert len(q) == horizon and np.isfinite(q).all()
        assert (q10 <= q50 + 1e-9).all()           # quantile monotonicity
        assert (q50 <= q90 + 1e-9).all()


class TestModelProperties:
    @pytest.mark.parametrize("kind", sorted(MODELS))
    @pytest.mark.parametrize("t,horizon", [
        (0, 24), (50, 24), (HOURS - 1, 24), (HOURS, 24), (HOURS + 100, 24),
        (10, 1), (10, 100)])
    def test_fixed(self, kind, t, horizon):
        _check_model_properties(kind, t, horizon, seed=13)

    @settings(max_examples=40, deadline=None)
    @given(kind=st.sampled_from(sorted(MODELS)),
           t=st.integers(0, HOURS + 48), horizon=st.integers(1, 24 * 5),
           seed=st.integers(0, 1000))
    def test_property(self, kind, t, horizon, seed):
        _check_model_properties(kind, t, horizon, seed)

    def test_distinct_seeds_give_distinct_noise(self):
        trace = synthesize_trace("texas", HOURS, seed=2)
        a = NoisyForecast(sigma=0.2, seed=1).predict(trace, 5, 24)
        b = NoisyForecast(sigma=0.2, seed=2).predict(trace, 5, 24)
        assert not np.array_equal(a, b)

    def test_quantile_ensemble_needs_members(self):
        with pytest.raises(ValueError, match="members"):
            QuantileForecast(members=1)

    def test_norm_ppf_matches_known_values(self):
        # reference values of the standard normal inverse CDF
        for q, z in [(0.5, 0.0), (0.841344746, 1.0), (0.158655254, -1.0),
                     (0.975, 1.959964), (0.01, -2.326348)]:
            assert _norm_ppf(q) == pytest.approx(z, abs=1e-5)
        with pytest.raises(ValueError):
            _norm_ppf(0.0)


class TestPerfectForecast:
    def test_bit_identical_to_ground_truth_service(self):
        """PerfectForecast output == CarbonService.forecast ground truth,
        bit for bit, including the pad-at-end and zeros-past-end edges."""
        trace = synthesize_trace("california", HOURS, seed=5)
        svc = CarbonService(trace=trace)                 # default = perfect
        model = PerfectForecast()
        for t in (0, 7, HOURS - 3, HOURS, HOURS + 50):
            for h in (1, 24, 60):
                np.testing.assert_array_equal(
                    model.predict(trace, t, h), svc.forecast(t, h))
        assert isinstance(svc.model, PerfectForecast)

    def test_explicit_model_equals_default(self):
        trace = synthesize_trace("california", HOURS, seed=5)
        a = CarbonService(trace=trace)
        b = CarbonService(trace=trace, model=PerfectForecast())
        np.testing.assert_array_equal(a.forecast(3, 48), b.forecast(3, 48))
        np.testing.assert_array_equal(a.forecast_quantile(3, 24, 0.9),
                                      a.forecast(3, 24))


class TestPersistence:
    def test_yesterday_as_tomorrow_no_peeking(self):
        trace = np.arange(1.0, HOURS + 1)
        fc = PersistenceForecast().predict(trace, 30, 24)
        assert fc[0] == trace[30]                        # now is observed
        np.testing.assert_array_equal(fc[1:], trace[7:30])
        # nothing beyond slot t is ever read
        assert fc.max() <= trace[30]

    def test_tiles_yesterday_past_one_period(self):
        trace = np.arange(1.0, HOURS + 1)
        fc = PersistenceForecast().predict(trace, 48, 49)
        np.testing.assert_array_equal(fc[25:49], fc[1:25])

    def test_first_day_clamps_into_trace(self):
        trace = np.arange(1.0, HOURS + 1)
        fc = PersistenceForecast().predict(trace, 0, 24)
        assert np.isfinite(fc).all()
        # with no yesterday to read, every lead clamps to slot 0: nothing
        # after the current slot is ever consulted
        assert (fc <= trace[0]).all()


# --- the lead-time fix -------------------------------------------------------


class TestLeadTimeSemantics:
    """Pin the ISSUE-5 fix: the old knob drew ONE noise realization over
    the whole trace at construction, so two queries at different t saw
    the same realized error for the same future slot regardless of lead
    time.  NoisyForecast re-draws per query slot with a lead-dependent
    std."""

    def test_static_shim_error_ignores_lead_time(self):
        trace = synthesize_trace("texas", HOURS, seed=2)
        model = StaticNoiseForecast(sigma=0.3, seed=9)
        s = 40                                            # absolute slot
        far = model.predict(trace, s - 20, 24)[20]        # 20h lead
        near = model.predict(trace, s - 1, 24)[1]         # 1h lead
        assert far == near                                # the old bug

    def test_noisy_error_depends_on_query_slot(self):
        trace = synthesize_trace("texas", HOURS, seed=2)
        model = NoisyForecast(sigma=0.3, seed=9)
        s = 40
        far = model.predict(trace, s - 20, 24)[20]
        near = model.predict(trace, s - 1, 24)[1]
        assert far != near                                # fresh draw per t

    def test_noisy_error_std_grows_with_lead_time(self):
        """Across many query slots, the empirical relative-error std at
        long leads exceeds short leads and tracks the analytic band."""
        trace = synthesize_trace("texas", 24 * 40, seed=2)
        model = NoisyForecast(sigma=0.3, phi=0.9, seed=9)
        errs = {1: [], 6: [], 23: []}
        for t in range(0, 24 * 30):
            fc = model.predict(trace, t, 24)
            for h in errs:
                errs[h].append(fc[h] / trace[t + h] - 1.0)
        stds = {h: float(np.std(v)) for h, v in errs.items()}
        assert stds[1] < stds[6] < stds[23]
        band = model.lead_std(24)
        for h in errs:
            # clipping at the floor only tightens the spread
            assert stds[h] == pytest.approx(band[h], rel=0.25)
        # lead 0 is the observed slot: zero error always
        fc0 = model.predict(trace, 100, 24)
        assert fc0[0] == trace[100]

    def test_requery_is_deterministic_per_slot(self):
        trace = synthesize_trace("texas", HOURS, seed=2)
        model = NoisyForecast(sigma=0.3, seed=9)
        np.testing.assert_array_equal(model.predict(trace, 12, 24),
                                      model.predict(trace, 12, 24))


# --- deprecated forecast_noise shim ------------------------------------------


class TestDeprecatedShim:
    def test_shim_warns_and_matches_old_outputs_bit_for_bit(self):
        trace = synthesize_trace("texas", HOURS, seed=2)
        with pytest.warns(DeprecationWarning, match="forecast_noise"):
            svc = CarbonService(trace=trace, forecast_noise=0.2, seed=7)
        # the pre-subsystem implementation, verbatim
        noise = np.random.default_rng(7).normal(1.0, 0.2, len(trace))
        legacy = np.clip(trace * noise, 1.0, None)
        for t in (0, 10, HOURS - 5):
            want = legacy[t:t + 24]
            if len(want) < 24:                      # old pad-at-end rule
                want = np.concatenate([want, np.full(24 - len(want),
                                                     want[-1])])
            np.testing.assert_array_equal(svc.forecast(t, 24), want)
        np.testing.assert_array_equal(svc.trace, trace)   # truth untouched
        assert isinstance(svc.model, StaticNoiseForecast)

    def test_shim_and_model_are_mutually_exclusive(self):
        trace = synthesize_trace("texas", 24, seed=2)
        with pytest.raises(ValueError, match="not both"):
            CarbonService(trace=trace, forecast_noise=0.2,
                          model=NoisyForecast())

    def test_replace_on_shim_built_service_keeps_model(self):
        """The knob is consumed into the model at construction, so
        dataclasses.replace on a shim-built service must not re-trip the
        model-xor-knob validation."""
        import dataclasses

        trace = synthesize_trace("texas", 24 * 3, seed=2)
        with pytest.warns(DeprecationWarning):
            svc = CarbonService(trace=trace, forecast_noise=0.2, seed=7)
        twin = dataclasses.replace(svc, horizon=48)      # must not raise
        assert twin.horizon == 48
        assert twin.model == svc.model
        np.testing.assert_array_equal(twin.forecast(3, 24),
                                      svc.forecast(3, 24))


# --- quantile view + robust policies -----------------------------------------


class TestQuantileView:
    def test_view_collapses_onto_truth_under_perfect_forecast(self):
        svc = CarbonService.synthetic("germany", HOURS, seed=5)
        view = QuantileCIView(svc, 0.7)
        for t in (0, 10, 50):
            np.testing.assert_array_equal(view.forecast(t), svc.forecast(t))
            assert view.rank(t) == svc.rank(t)
            assert view.percentile_threshold(t, 30.0) == \
                svc.percentile_threshold(t, 30.0)
            assert view.ci(t) == svc.ci(t)
            assert view.gradient(t) == svc.gradient(t)
        np.testing.assert_array_equal(view.forecast_extended(3, 60),
                                      svc.forecast_extended(3, 60))
        assert len(view) == len(svc)

    def test_view_orders_with_quantile_under_ensemble(self):
        svc = CarbonService.synthetic(
            "germany", HOURS, seed=5,
            model=QuantileForecast(sigma=0.3, seed=1))
        lo = QuantileCIView(svc, 0.2).forecast(10)
        hi = QuantileCIView(svc, 0.8).forecast(10)
        assert (lo <= hi + 1e-9).all()
        assert (lo < hi).any()

    def test_robust_wait_awhile_identical_under_perfect_forecast(self):
        from repro.core import ClusterConfig, simulate
        from repro.traces import TraceSpec, generate_trace

        cluster = ClusterConfig.default(capacity=10)
        ci = CarbonService.synthetic("south-australia", 24 * 40, seed=3)
        jobs = generate_trace(TraceSpec(family="azure", hours=24 * 7,
                                        capacity=10, seed=4),
                              cluster.queues)
        a = simulate(jobs, ci, cluster, WaitAwhilePolicy(), horizon=24 * 7)
        b = simulate(jobs, ci, cluster, RobustWaitAwhilePolicy(),
                     horizon=24 * 7)
        assert a.carbon_g == b.carbon_g
        np.testing.assert_array_equal(a.completion, b.completion)


# --- serialization + labels --------------------------------------------------


class TestSerialization:
    @pytest.mark.parametrize("kind", sorted(MODELS))
    def test_model_round_trip(self, kind):
        m = MODELS[kind]
        d = forecast_to_dict(m)
        assert d["kind"] == kind
        assert forecast_from_dict(json.loads(json.dumps(d))) == m

    def test_none_round_trips(self):
        assert forecast_to_dict(None) is None
        assert forecast_from_dict(None) is None

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown forecast kind"):
            forecast_from_dict({"kind": "astrology"})
        assert set(FORECAST_KINDS) == set(MODELS)

    def test_labels(self):
        assert forecast_label(None) == "perfect"
        assert forecast_label(PerfectForecast()) == "perfect"
        assert forecast_label(NoisyForecast(sigma=0.25)) == "noisy(s=0.25)"
        assert forecast_label(QuantileForecast(sigma=0.1, members=9)) \
            == "quantile(s=0.1,m=9)"

    def test_axis_labels_disambiguate_colliding_models(self):
        """Two distinct models sharing a display label (same sigma,
        different seed/phi) must get distinct axis labels, or their
        savings cells would silently merge; equal models keep equal
        labels."""
        from repro.core.forecast import forecast_labels

        axis = (None, NoisyForecast(sigma=0.2, seed=1),
                NoisyForecast(sigma=0.2, seed=2),
                NoisyForecast(sigma=0.2, seed=1),      # equal to entry 1
                NoisyForecast(sigma=0.2, seed=1, phi=0.5))
        assert forecast_labels(axis) == [
            "perfect", "noisy(s=0.2)", "noisy(s=0.2)#2", "noisy(s=0.2)",
            "noisy(s=0.2)#3"]

    def test_scenario_round_trip_with_forecast(self):
        sc = Scenario(capacity=8, learn_weeks=1,
                      forecast=NoisyForecast(sigma=0.2, seed=5))
        rt = Scenario.from_dict(json.loads(json.dumps(sc.to_dict())))
        assert rt == sc
        assert rt.forecast == NoisyForecast(sigma=0.2, seed=5)

    def test_scenario_default_round_trip_unchanged(self):
        sc = Scenario(capacity=8, learn_weeks=1)
        d = sc.to_dict()
        assert d["forecast"] is None
        assert Scenario.from_dict(json.loads(json.dumps(d))) == sc


# --- Scenario / Sweep threading ----------------------------------------------


class TestExperimentThreading:
    def test_materialize_threads_model_single_region(self):
        m = NoisyForecast(sigma=0.2, seed=1)
        mat = Scenario(capacity=8, learn_weeks=1, forecast=m).materialize()
        assert mat.ci.model is m

    def test_materialize_threads_model_geo(self):
        m = NoisyForecast(sigma=0.2, seed=1)
        mat = Scenario(regions=("california", "ontario"), capacity=8,
                       learn_weeks=1, forecast=m).materialize()
        assert all(s.model is m for s in mat.mci.services)
        # shared model, but per-region error streams stay independent
        t = mat.t0
        fm = mat.mci.forecast_matrix(t, 24)
        r0 = fm[0] / np.clip(mat.mci.services[0].trace[t:t + 24], 1e-9, None)
        r1 = fm[1] / np.clip(mat.mci.services[1].trace[t:t + 24], 1e-9, None)
        assert not np.array_equal(r0[1:], r1[1:])

    def test_sweep_without_axis_has_no_forecast_column(self):
        sw = Sweep(base=Scenario(capacity=8, learn_weeks=1,
                                 family="alibaba", seed=101),
                   policies=["carbon-agnostic", "wait-awhile"])
        rows = sw.run().rows()
        assert all("forecast" not in r for r in rows)

    def test_sweep_forecast_axis_rows_and_savings_grouping(self):
        sw = Sweep(base=Scenario(capacity=8, learn_weeks=1,
                                 family="alibaba", seed=101),
                   policies=["carbon-agnostic", "wait-awhile",
                             "wait-awhile-robust"],
                   forecasts=[None, NoisyForecast(sigma=0.3, seed=2)])
        sr = sw.run()
        rows = sr.rows()
        assert {r["forecast"] for r in rows} == {"perfect", "noisy(s=0.3)"}
        # savings compare within the same forecast cell: every baseline
        # row is its own cell's zero
        for r in rows:
            if r["policy"] == "carbon-agnostic":
                assert r["savings_pct"] == 0.0
        # perfect-forecast cells: robust == plain, bit for bit
        for fc in ("perfect",):
            plain = [r for r in rows if r["forecast"] == fc
                     and r["policy"] == "wait-awhile"]
            robust = [r for r in rows if r["forecast"] == fc
                      and r["policy"] == "wait-awhile-robust"]
            assert [r["carbon_g"] for r in plain] \
                == [r["carbon_g"] for r in robust]
        payload = sr.to_json()
        from repro.experiment import SweepResult
        assert SweepResult.from_json(payload).to_json() == payload

    def test_sweep_colliding_forecast_models_get_own_cells(self):
        """Regression: two NoisyForecasts of equal sigma but different
        seed (a forecast-realization average, a natural grid) must land
        in separate savings cells — each with its own zero baseline."""
        sw = Sweep(base=Scenario(capacity=8, learn_weeks=1,
                                 family="alibaba", seed=101),
                   policies=["carbon-agnostic", "wait-awhile"],
                   forecasts=[NoisyForecast(sigma=0.3, seed=1),
                              NoisyForecast(sigma=0.3, seed=2)])
        rows = sw.run().rows()
        labels = {r["forecast"] for r in rows}
        assert labels == {"noisy(s=0.3)", "noisy(s=0.3)#2"}
        for fc in labels:
            cell = [r for r in rows if r["forecast"] == fc]
            assert len(cell) == 2
            base = [r for r in cell if r["policy"] == "carbon-agnostic"]
            assert base[0]["savings_pct"] == 0.0
        # the two realizations genuinely differ
        wa = {r["forecast"]: r["carbon_g"] for r in rows
              if r["policy"] == "wait-awhile"}
        assert wa["noisy(s=0.3)"] != wa["noisy(s=0.3)#2"]

    def test_oracle_gap_harness_tiny(self):
        from repro.experiment import OracleGap, OracleGapResult, sigma_ladder

        gap = OracleGap(base=Scenario(capacity=8, learn_weeks=1,
                                      family="alibaba", seed=101),
                        policies=("wait-awhile", "wait-awhile-robust"),
                        seeds=(11,),
                        forecasts=sigma_ladder((0.0, 0.3)))
        res = gap.run()
        s = res.summary()
        assert list(s) == ["perfect", "noisy(s=0.3)"]
        # robust == plain under the perfect forecast, gap 0 for nobody
        assert s["perfect"]["wait-awhile"]["gap_mean_pp"] \
            == s["perfect"]["wait-awhile-robust"]["gap_mean_pp"]
        assert res.perfect_gap("wait-awhile") == \
            s["perfect"]["wait-awhile"]["gap_mean_pp"]
        curve = res.degradation_curve("wait-awhile")
        assert [fc for fc, _ in curve] == ["perfect", "noisy(s=0.3)"]
        rt = OracleGapResult.from_json(res.to_json())
        assert rt.to_json() == res.to_json()

    @pytest.mark.slow
    def test_oracle_gap_degradation_curve_moderate_scale(self):
        """Slow forecast sweep (registered under the `slow` marker so
        tier-1 stays fast): at capacity 24 x 3 seeds x a 4-point sigma
        ladder, (a) a forecast-blind policy's gap is forecast-invariant,
        (b) robust == plain under the perfect forecast, (c) wait-awhile
        loses savings at every noisy point, and (d) the quantile-robust
        variant recovers part of that loss at every noisy point."""
        from repro.experiment import OracleGap, sigma_ladder

        res = OracleGap(base=Scenario(capacity=24, learn_weeks=2, seed=7),
                        seeds=(1, 2, 3),
                        forecasts=sigma_ladder((0.0, 0.1, 0.2, 0.4))).run()
        curves = {p: dict(res.degradation_curve(p)) for p in res.policies()}
        noisy_pts = [fc for fc in res.forecast_order if fc != "perfect"]
        assert len(noisy_pts) == 3
        # (a) carbon-agnostic never reads a forecast
        agn = curves["carbon-agnostic"]
        assert all(agn[fc] == agn["perfect"] for fc in noisy_pts)
        # (b) perfect forecast: quantile bands collapse onto the truth
        for plain, robust in [("wait-awhile", "wait-awhile-robust"),
                              ("carbonflex", "carbonflex-robust")]:
            assert curves[plain]["perfect"] == curves[robust]["perfect"]
        # (c) + (d)
        for fc in noisy_pts:
            assert curves["wait-awhile"][fc] > curves["wait-awhile"]["perfect"]
            assert curves["wait-awhile-robust"][fc] \
                < curves["wait-awhile"][fc]

    def test_sigma_ladder_shapes(self):
        from repro.experiment import sigma_ladder

        ladder = sigma_ladder((0.0, 0.1, 0.2), kind="quantile", members=5)
        assert ladder[0] is None
        assert all(isinstance(m, QuantileForecast) for m in ladder[1:])
        assert [m.sigma for m in ladder[1:]] == [0.1, 0.2]
        with pytest.raises(ValueError, match="kind"):
            sigma_ladder(kind="tarot")
