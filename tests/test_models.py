"""Per-arch smoke tests (reduced configs) + model-substrate unit tests.

Each assigned architecture instantiates a REDUCED config of the same
family and runs one forward/train step on CPU, asserting output shapes and
no NaNs (full configs are exercised only via the dry-run).
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.launch.mesh import make_mesh
from repro.models import LogicalRules, forward, init_params
from repro.models.common import chunked_attention
from repro.models.ssm import chunked_linear_attention, reference_scan
from repro.serve import init_cache, make_serve_step
from repro.train import OptimizerConfig, init_state, lr_at, make_train_step

pytestmark = pytest.mark.slow        # per-arch smokes dominate suite runtime


@pytest.fixture(scope="module")
def rules():
    mesh = make_mesh((1, 1), ("data", "model"))
    return LogicalRules(mesh)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_step(arch, rules):
    cfg = reduced(ARCHS[arch])
    state = init_state(cfg, jax.random.key(0))
    step = make_train_step(cfg, rules, OptimizerConfig(total_steps=4), ce_chunk=16)
    B, S = 2, 32
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.prefix_len:
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.prefix_len, cfg.d_model)), jnp.float32)
    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state.step) == 1
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda p, q: float(jnp.abs(p - q).sum()),
                     state.params, new_state.params))
    assert delta > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_forward_shapes(arch, rules):
    cfg = reduced(ARCHS[arch])
    params = init_params(cfg, jax.random.key(1))
    B, S = 2, 16
    toks = jnp.zeros((B, S), jnp.int32)
    kw = {}
    if cfg.prefix_len:
        kw["prefix_embeds"] = jnp.zeros((B, cfg.prefix_len, cfg.d_model), jnp.float32)
    logits = forward(params, toks, cfg, rules, **kw)
    assert logits.shape == (B, S + cfg.prefix_len, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen3-moe-235b-a22b",
                                  "rwkv6-7b", "zamba2-7b"])
def test_decode_matches_forward(arch, rules):
    cfg = reduced(ARCHS[arch])
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no token drops
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 12
    cache = init_cache(cfg, B, 16)
    step = jax.jit(make_serve_step(cfg, rules))
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (B, S)), jnp.int32)
    for t in range(S):
        logits_dec, cache = step(params, cache, toks[:, t])
    logits_full = forward(params, toks, cfg, rules)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-3, atol=2e-3)


class TestChunkedAttention:
    def _naive(self, q, k, v, offset):
        b, sq, hq, d = q.shape
        hkv = k.shape[2]
        qg = q.reshape(b, sq, hkv, hq // hkv, d)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                       k.astype(jnp.float32)) / np.sqrt(d)
        qpos = offset + jnp.arange(sq)
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
        return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)

    @given(sq=st.integers(1, 9), sk=st.integers(1, 33), chunk=st.integers(2, 16),
           seed=st.integers(0, 100))
    @settings(max_examples=25, deadline=None)
    def test_matches_naive(self, sq, sk, chunk, seed):
        if sq > sk:
            sq = sk
        rng = np.random.default_rng(seed)
        b, hq, hkv, d = 2, 4, 2, 8
        q = jnp.asarray(rng.normal(size=(b, sq, hq, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, sk, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, sk, hkv, d)), jnp.float32)
        offset = sk - sq
        out = chunked_attention(q, k, v, offset, chunk)
        ref = self._naive(q, k, v, offset)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestLinearRecurrence:
    @given(s=st.integers(1, 40), chunk=st.sampled_from([4, 8, 16]),
           rwkv=st.booleans(), seed=st.integers(0, 50))
    @settings(max_examples=20, deadline=None)
    def test_chunked_matches_sequential(self, s, chunk, rwkv, seed):
        rng = np.random.default_rng(seed)
        b, h, dk, dv = 2, 2, 4, 4
        q = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32) * 0.5
        k = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32) * 0.5
        v = jnp.asarray(rng.normal(size=(b, s, h, dv)), jnp.float32) * 0.5
        logw = -jnp.asarray(rng.uniform(0.01, 1.5, (b, s, h, dk)), jnp.float32)
        u = jnp.asarray(rng.normal(size=(h, dk)), jnp.float32) * 0.5 if rwkv else None
        y1, s1 = chunked_linear_attention(q, k, v, logw, u=u, chunk=chunk,
                                          return_state=True)
        y2, s2 = reference_scan(q, k, v, logw, u=u)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-4, atol=1e-5)


class TestSchedules:
    def test_wsd_shape(self):
        opt = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              schedule="wsd", wsd_stable_frac=0.8)
        lrs = np.array([float(lr_at(jnp.int32(s), opt)) for s in range(100)])
        assert lrs[0] <= 0.2
        assert abs(lrs[10] - 1.0) < 1e-6        # after warmup: peak
        assert abs(lrs[79] - 1.0) < 1e-6        # stable phase holds peak
        assert lrs[99] < 0.15                   # decayed to ~10%
        assert (np.diff(lrs[80:]) <= 1e-9).all()

    def test_cosine_monotone_decay(self):
        opt = OptimizerConfig(lr=1.0, warmup_steps=5, total_steps=50)
        lrs = np.array([float(lr_at(jnp.int32(s), opt)) for s in range(50)])
        assert (np.diff(lrs[5:]) <= 1e-9).all()
